#!/usr/bin/env bash
# Engine scaling bench: ranks-per-second and peak RSS for the thread-backed
# oracle vs the deterministic event engine (DESIGN.md §12) — both engines
# head-to-head at 256 ranks (with a digest cross-check), event engine only
# at 4096 and 16384 ranks.  Emits BENCH_scale.json at the repository root.
#
# Usage: tools/bench_scale.sh [extra cargo bench args]
#        BENCH_SMOKE=1 tools/bench_scale.sh   # CI quick pass
set -euo pipefail
cd "$(dirname "$0")/.."
cargo bench --bench bench_scale "$@"
echo "BENCH_scale.json:"
cat BENCH_scale.json
