#!/usr/bin/env bash
# Engine scaling bench: ranks-per-second and peak RSS for the thread-backed
# oracle vs the deterministic event engine (DESIGN.md §12) — both engines
# head-to-head at 256 ranks (with a digest cross-check), event engine only
# at 4096 and 16384 ranks.  Emits BENCH_scale.json.  Shim onto
# tools/bench.sh.
#
# Usage: tools/bench_scale.sh [extra cargo bench args]
#        BENCH_SMOKE=1 tools/bench_scale.sh   # CI quick pass
exec "$(dirname "$0")/bench.sh" scale "$@"
