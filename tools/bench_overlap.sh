#!/usr/bin/env bash
# Commit/compute overlap bench for non-blocking checkpoints (DESIGN.md
# §15): sync vs async at xor:4 and rs2:4, emits BENCH_overlap.json and
# fails unless async mode hides >= 50% of the commit-plane receive wait
# with zero global restarts.  Shim onto tools/bench.sh.
exec "$(dirname "$0")/bench.sh" overlap "$@"
