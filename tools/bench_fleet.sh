#!/usr/bin/env bash
# Multi-tenant fleet bench: throughput vs failure rate over one shared
# spare pool, contention ratio, and circuit-breaker quarantines
# (DESIGN.md §16).  Emits BENCH_fleet.json; gates documented in the bench
# itself.  Shim onto tools/bench.sh.
#
# Usage: tools/bench_fleet.sh              # full grid (cube16)
#        BENCH_SMOKE=1 tools/bench_fleet.sh   # CI quick pass (cube12)
exec "$(dirname "$0")/bench.sh" fleet "$@"
