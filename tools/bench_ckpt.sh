#!/usr/bin/env bash
# Checkpoint-volume bench: mirror vs xor vs rs2 double parity, full vs
# delta, compressed vs raw, on the FT-GMRES workload.  Emits
# BENCH_ckpt.json; gates documented in the bench itself.  Shim onto
# tools/bench.sh.
#
# Usage: tools/bench_ckpt.sh [extra cargo bench args]
exec "$(dirname "$0")/bench.sh" ckpt "$@"
