#!/usr/bin/env bash
# Checkpoint-volume bench: mirror vs xor vs rs2 double parity, full vs
# delta, compressed vs raw, on the FT-GMRES workload.  Emits
# BENCH_ckpt.json at the repository root (bytes shipped per commit, raw
# vs compressed, commit latency per leg) and fails if xor:4+delta does
# not cut per-commit redundant bytes by at least 2x vs mirror:1, if
# compressed rs2:4+delta does not undercut uncompressed xor:4+delta, or
# if the same-group double fault does not escalate under xor while
# recovering in situ under rs2.
#
# Usage: tools/bench_ckpt.sh [extra cargo bench args]
set -euo pipefail
cd "$(dirname "$0")/.."
cargo bench --bench bench_ckpt "$@"
echo "BENCH_ckpt.json:"
cat BENCH_ckpt.json
