#!/usr/bin/env bash
# Checkpoint-volume bench: mirror vs xor, full vs delta, on the FT-GMRES
# workload.  Emits BENCH_ckpt.json at the repository root (bytes shipped
# per commit + commit latency per leg) and fails if xor:4+delta does not
# cut per-commit redundant bytes by at least 2x vs mirror:1.
#
# Usage: tools/bench_ckpt.sh [extra cargo bench args]
set -euo pipefail
cd "$(dirname "$0")/.."
cargo bench --bench bench_ckpt "$@"
echo "BENCH_ckpt.json:"
cat BENCH_ckpt.json
