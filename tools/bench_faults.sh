#!/usr/bin/env bash
# Degraded-mode fault bench: stragglers, lossy links and the checkpoint
# corruption scrubber (DESIGN.md §14).  Emits BENCH_faults.json; gates
# documented in the bench itself.  Shim onto tools/bench.sh.
#
# Usage: tools/bench_faults.sh              # full grid (cube16)
#        BENCH_SMOKE=1 tools/bench_faults.sh   # CI quick pass (cube12)
exec "$(dirname "$0")/bench.sh" faults "$@"
