#!/usr/bin/env bash
# Degraded-mode fault bench: stragglers, lossy links and the checkpoint
# corruption scrubber (DESIGN.md §14).  Emits BENCH_faults.json at the
# repository root with the three headline numbers — scrub repair rate
# (must be 1.0 across mirror/xor/rs2 for a single flip), straggler-shrink
# latency (detector decision -> executed shrink), and lossy-link retry
# overhead vs the identical clean run — and fails if a flip goes
# undetected, a repair escalates, or the 1.2x/3x straggler pricing
# inverts.
#
# Usage: tools/bench_faults.sh              # full grid (cube16)
#        BENCH_SMOKE=1 tools/bench_faults.sh   # CI quick pass (cube12)
set -euo pipefail
cd "$(dirname "$0")/.."
cargo bench --bench bench_faults "$@"
echo "BENCH_faults.json:"
cat BENCH_faults.json
