#!/usr/bin/env python3
"""Summarize a ulfm_ftgmres trace (Chrome trace-event JSON, DESIGN.md §13).

Usage:  python tools/trace_report.py out/trace.json

Validates the file against the `ulfm-ftgmres-1` schema (phase span names,
event categories, protocol-phase instant names, flow-edge pairing) and
prints the per-phase table: span counts, virtual-time totals across ranks,
the share of total traced time, and — when the run recorded recovery
events — each phase's share of the recovery critical path.  Exits non-zero
on malformed input, so CI uses it as the trace validator.

For runs recorded with `--ckpt-async on` (detected from the `+async`
marker in otherData's ckpt summary) it additionally reports how much of
the commit plane overlapped solver compute, and exits non-zero if every
steady-state checkpoint span fully serialized against compute on all
other ranks — the regression the non-blocking commit pipeline
(DESIGN.md §15) exists to prevent.
"""

import json
import sys

PHASES = ("compute", "comm", "checkpoint", "recovery", "reconfig", "recompute", "idle")
INSTANT_CATS = ("proto", "mark", "recovery")
# ProtoPhase names, including the async-only windows (ckpt-ship fires on
# the publish half of a non-blocking commit, recon-pipeline inside the
# arrival-order reconstruction folds).
PROTO_PHASES = (
    "ckpt-commit",
    "detect",
    "agree",
    "reconstruct",
    "spare-join",
    "redistribute",
    "ckpt-ship",
    "recon-pipeline",
)
# Checkpoint spans shorter than this are phase-bookkeeping noise, not a
# commit window worth judging for overlap.
CKPT_SPAN_EPS_US = 0.5


def fail(msg):
    print(f"trace_report: {msg}", file=sys.stderr)
    sys.exit(1)


def load(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot load {path}: {e}")
    if not isinstance(doc, dict):
        fail("top level must be an object")
    other = doc.get("otherData")
    if not isinstance(other, dict):
        fail("missing otherData")
    if other.get("trace_format") != "ulfm-ftgmres-1":
        fail(f"unknown trace_format {other.get('trace_format')!r}")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail("traceEvents must be a non-empty list")
    return doc


def validate(events):
    """Schema checks over the event stream; returns (spans, instants, flows)."""
    spans, instants = [], []
    send_ids, recv_ids = set(), set()
    ranks = set()
    for i, e in enumerate(events):
        if not isinstance(e, dict) or "ph" not in e:
            fail(f"event {i}: not an object with 'ph'")
        ph = e["ph"]
        if ph == "M":
            if e.get("name") not in ("thread_name", "thread_sort_index"):
                fail(f"event {i}: unknown metadata {e.get('name')!r}")
            continue
        tid = e.get("tid")
        if not isinstance(tid, int) or tid < 0:
            fail(f"event {i}: bad tid {tid!r}")
        ranks.add(tid)
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            fail(f"event {i}: bad ts {ts!r}")
        if ph == "X":
            if e.get("cat") != "phase" or e.get("name") not in PHASES:
                fail(f"event {i}: span must be a known phase, got {e.get('name')!r}")
            # Sub-nanosecond spans round to 0.000 in the fixed µs format,
            # so only negative durations are malformed.
            if not isinstance(e.get("dur"), (int, float)) or e["dur"] < 0:
                fail(f"event {i}: span dur must be non-negative")
            spans.append(e)
        elif ph == "i":
            if e.get("cat") not in INSTANT_CATS:
                fail(f"event {i}: unknown instant cat {e.get('cat')!r}")
            if e.get("cat") == "proto" and e.get("name") not in PROTO_PHASES:
                fail(f"event {i}: unknown protocol phase {e.get('name')!r}")
            instants.append(e)
        elif ph == "C":
            if not e.get("name", "").startswith("iters-r"):
                fail(f"event {i}: unknown counter {e.get('name')!r}")
        elif ph in ("s", "f"):
            fid = e.get("id")
            if not isinstance(fid, str) or not fid.startswith("0x"):
                fail(f"event {i}: flow id must be a hex string, got {fid!r}")
            (send_ids if ph == "s" else recv_ids).add(fid)
        else:
            fail(f"event {i}: unknown ph {ph!r}")
    unmatched = recv_ids - send_ids
    if unmatched:
        fail(f"{len(unmatched)} flow ends without a matching start, e.g. {sorted(unmatched)[0]}")
    return spans, instants, (send_ids, recv_ids), ranks


def ckpt_overlap(spans, asynchronous):
    """Report commit-plane/compute overlap; enforce it for async runs.

    For every steady-state checkpoint span (each rank's earliest one is
    the establishment commit — deliberately synchronous, it creates the
    protection recovery relies on — and is skipped), sum its temporal
    intersection with compute spans on *other* ranks.  A span with zero
    such intersection fully serialized the machine.  With `--ckpt-async
    on` at least one steady-state commit window must overlap someone
    else's compute, or the non-blocking pipeline has regressed into a
    fence and we exit non-zero.
    """
    ckpt, compute = {}, {}
    for s in spans:
        bucket = {"checkpoint": ckpt, "compute": compute}.get(s["name"])
        if bucket is not None:
            bucket.setdefault(s["tid"], []).append((s["ts"], s["ts"] + s["dur"]))
    steady = []
    for tid, windows in ckpt.items():
        windows.sort()
        steady += [(tid, a, b) for a, b in windows[1:] if b - a > CKPT_SPAN_EPS_US]
    overlapping, hidden_us = 0, 0.0
    for tid, a, b in steady:
        got = 0.0
        for other, windows in compute.items():
            if other == tid:
                continue
            got += sum(max(0.0, min(b, d) - max(a, c)) for c, d in windows)
        if got > 0.0:
            overlapping += 1
            hidden_us += got
    mode = "async (non-blocking)" if asynchronous else "sync (fenced)"
    print(
        f"commit plane [{mode}]: {overlapping}/{len(steady)} steady-state "
        f"checkpoint spans overlap compute on another rank "
        f"({hidden_us / 1e6:.6f}s of cross-rank ckpt||compute time)"
    )
    if asynchronous and steady and overlapping == 0:
        fail(
            "async commit plane fully serialized: no steady-state checkpoint "
            "span overlaps compute on any other rank"
        )


def table(rows, header):
    widths = [max(len(str(r[i])) for r in [header] + rows) for i in range(len(header))]
    fmt = "  ".join(f"{{:>{w}}}" for w in widths)
    print(fmt.format(*header))
    for r in rows:
        print(fmt.format(*r))


def main():
    if len(sys.argv) != 2:
        fail("usage: trace_report.py <trace.json>")
    doc = load(sys.argv[1])
    spans, instants, (send_ids, recv_ids), ranks = validate(doc["traceEvents"])

    by_phase = {p: [0, 0.0] for p in PHASES}  # name -> [count, total_us]
    for s in spans:
        by_phase[s["name"]][0] += 1
        by_phase[s["name"]][1] += float(s["dur"])
    total_us = sum(t for _, t in by_phase.values()) or 1.0

    cp = doc["otherData"].get("critical_path")
    path_s = cp.get("path_phases_s", {}) if isinstance(cp, dict) else {}

    rows = []
    for p in PHASES:
        n, us = by_phase[p]
        rows.append(
            (
                p,
                n,
                f"{us / 1e6:.6f}",
                f"{100.0 * us / total_us:.2f}%",
                f"{float(path_s.get(p, 0.0)):.6f}" if path_s else "-",
            )
        )
    print(f"# trace: {len(ranks)} ranks, {len(spans)} spans, "
          f"{len(instants)} instants, {len(recv_ids)} message edges")
    table(rows, ("phase", "spans", "total_s", "share", "critical_path_s"))

    if isinstance(cp, dict):
        print(
            f"recovery critical path: {cp.get('events', 0)} events, "
            f"wall {float(cp.get('total_wall_s', 0.0)):.6f}s, "
            f"serial {float(cp.get('total_serial_s', 0.0)):.6f}s, "
            f"overlap efficiency {float(cp.get('overlap_efficiency', 0.0)):.3f} "
            f"(wire {float(path_s.get('wire', 0.0)):.6f}s)"
        )
    ckpt_overlap(spans, "+async" in doc["otherData"].get("ckpt", ""))
    print("trace OK")


if __name__ == "__main__":
    main()
