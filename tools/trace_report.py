#!/usr/bin/env python3
"""Summarize a ulfm_ftgmres trace (Chrome trace-event JSON, DESIGN.md §13).

Usage:  python tools/trace_report.py out/trace.json

Validates the file against the `ulfm-ftgmres-1` schema (phase span names,
event categories, flow-edge pairing) and prints the per-phase table: span
counts, virtual-time totals across ranks, the share of total traced time,
and — when the run recorded recovery events — each phase's share of the
recovery critical path.  Exits non-zero on malformed input, so CI uses it
as the trace validator.
"""

import json
import sys

PHASES = ("compute", "comm", "checkpoint", "recovery", "reconfig", "recompute", "idle")
INSTANT_CATS = ("proto", "mark", "recovery")


def fail(msg):
    print(f"trace_report: {msg}", file=sys.stderr)
    sys.exit(1)


def load(path):
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        fail(f"cannot load {path}: {e}")
    if not isinstance(doc, dict):
        fail("top level must be an object")
    other = doc.get("otherData")
    if not isinstance(other, dict):
        fail("missing otherData")
    if other.get("trace_format") != "ulfm-ftgmres-1":
        fail(f"unknown trace_format {other.get('trace_format')!r}")
    events = doc.get("traceEvents")
    if not isinstance(events, list) or not events:
        fail("traceEvents must be a non-empty list")
    return doc


def validate(events):
    """Schema checks over the event stream; returns (spans, instants, flows)."""
    spans, instants = [], []
    send_ids, recv_ids = set(), set()
    ranks = set()
    for i, e in enumerate(events):
        if not isinstance(e, dict) or "ph" not in e:
            fail(f"event {i}: not an object with 'ph'")
        ph = e["ph"]
        if ph == "M":
            if e.get("name") not in ("thread_name", "thread_sort_index"):
                fail(f"event {i}: unknown metadata {e.get('name')!r}")
            continue
        tid = e.get("tid")
        if not isinstance(tid, int) or tid < 0:
            fail(f"event {i}: bad tid {tid!r}")
        ranks.add(tid)
        ts = e.get("ts")
        if not isinstance(ts, (int, float)) or ts < 0:
            fail(f"event {i}: bad ts {ts!r}")
        if ph == "X":
            if e.get("cat") != "phase" or e.get("name") not in PHASES:
                fail(f"event {i}: span must be a known phase, got {e.get('name')!r}")
            # Sub-nanosecond spans round to 0.000 in the fixed µs format,
            # so only negative durations are malformed.
            if not isinstance(e.get("dur"), (int, float)) or e["dur"] < 0:
                fail(f"event {i}: span dur must be non-negative")
            spans.append(e)
        elif ph == "i":
            if e.get("cat") not in INSTANT_CATS:
                fail(f"event {i}: unknown instant cat {e.get('cat')!r}")
            instants.append(e)
        elif ph == "C":
            if not e.get("name", "").startswith("iters-r"):
                fail(f"event {i}: unknown counter {e.get('name')!r}")
        elif ph in ("s", "f"):
            fid = e.get("id")
            if not isinstance(fid, str) or not fid.startswith("0x"):
                fail(f"event {i}: flow id must be a hex string, got {fid!r}")
            (send_ids if ph == "s" else recv_ids).add(fid)
        else:
            fail(f"event {i}: unknown ph {ph!r}")
    unmatched = recv_ids - send_ids
    if unmatched:
        fail(f"{len(unmatched)} flow ends without a matching start, e.g. {sorted(unmatched)[0]}")
    return spans, instants, (send_ids, recv_ids), ranks


def table(rows, header):
    widths = [max(len(str(r[i])) for r in [header] + rows) for i in range(len(header))]
    fmt = "  ".join(f"{{:>{w}}}" for w in widths)
    print(fmt.format(*header))
    for r in rows:
        print(fmt.format(*r))


def main():
    if len(sys.argv) != 2:
        fail("usage: trace_report.py <trace.json>")
    doc = load(sys.argv[1])
    spans, instants, (send_ids, recv_ids), ranks = validate(doc["traceEvents"])

    by_phase = {p: [0, 0.0] for p in PHASES}  # name -> [count, total_us]
    for s in spans:
        by_phase[s["name"]][0] += 1
        by_phase[s["name"]][1] += float(s["dur"])
    total_us = sum(t for _, t in by_phase.values()) or 1.0

    cp = doc["otherData"].get("critical_path")
    path_s = cp.get("path_phases_s", {}) if isinstance(cp, dict) else {}

    rows = []
    for p in PHASES:
        n, us = by_phase[p]
        rows.append(
            (
                p,
                n,
                f"{us / 1e6:.6f}",
                f"{100.0 * us / total_us:.2f}%",
                f"{float(path_s.get(p, 0.0)):.6f}" if path_s else "-",
            )
        )
    print(f"# trace: {len(ranks)} ranks, {len(spans)} spans, "
          f"{len(instants)} instants, {len(recv_ids)} message edges")
    table(rows, ("phase", "spans", "total_s", "share", "critical_path_s"))

    if isinstance(cp, dict):
        print(
            f"recovery critical path: {cp.get('events', 0)} events, "
            f"wall {float(cp.get('total_wall_s', 0.0)):.6f}s, "
            f"serial {float(cp.get('total_serial_s', 0.0)):.6f}s, "
            f"overlap efficiency {float(cp.get('overlap_efficiency', 0.0)):.3f} "
            f"(wire {float(path_s.get('wire', 0.0)):.6f}s)"
        )
    print("trace OK")


if __name__ == "__main__":
    main()
