#!/usr/bin/env python3
"""Plot the regenerated paper figures from out/fig{4,5,6}.csv.

Usage:  python tools/plot_figures.py [--out-dir out]

Produces out/fig4.png, out/fig5.png, out/fig6.png in the paper's layout
(grouped bars per process count; shrink patterned, substitute solid —
mirroring the originals).
"""

import argparse
import csv
import os
from collections import defaultdict

import matplotlib

matplotlib.use("Agg")
import matplotlib.pyplot as plt  # noqa: E402


def read(path):
    with open(path) as f:
        return list(csv.DictReader(f))


def grouped(rows, value_key):
    """-> {(strategy, failures): {p: value}}, sorted p list."""
    data = defaultdict(dict)
    ps = set()
    for r in rows:
        p = int(r["p"])
        ps.add(p)
        data[(r["strategy"], int(r["failures"]))][p] = float(r[value_key])
    return data, sorted(ps)


def bars(ax, data, ps, f_range, title, ylabel):
    width = 0.8 / (2 * len(f_range))
    xs = range(len(ps))
    for si, strategy in enumerate(["shrink", "substitute"]):
        for fi, f in enumerate(f_range):
            series = data.get((strategy, f))
            if not series:
                continue
            offs = (si * len(f_range) + fi - len(f_range) + 0.5) * width
            vals = [series.get(p, 0.0) for p in ps]
            ax.bar(
                [x + offs for x in xs],
                vals,
                width=width,
                label=f"{strategy} {f}F",
                hatch="//" if strategy == "shrink" else None,
                edgecolor="black",
                linewidth=0.3,
            )
    ax.set_xticks(list(xs))
    ax.set_xticklabels([str(p) for p in ps])
    ax.set_xlabel("processes")
    ax.set_ylabel(ylabel)
    ax.set_title(title)
    ax.legend(fontsize=6, ncol=2)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="out")
    args = ap.parse_args()
    od = args.out_dir

    # Figure 4
    rows = read(os.path.join(od, "fig4.csv"))
    data, ps = grouped(rows, "slowdown")
    fig, ax = plt.subplots(figsize=(7, 3.2), dpi=150)
    bars(ax, data, ps, range(0, 5), "Fig. 4: slowdown vs no protection", "normalized time")
    ax.axhline(1.0, color="gray", lw=0.5)
    fig.tight_layout()
    fig.savefig(os.path.join(od, "fig4.png"))

    # Figure 5
    rows = read(os.path.join(od, "fig5.csv"))
    data, ps = grouped(rows, "ckpt_norm")
    pct, _ = grouped(rows, "ckpt_pct_of_total")
    fig, ax = plt.subplots(figsize=(7, 3.2), dpi=150)
    bars(ax, data, ps, range(1, 5), "Fig. 5: checkpoint time (normalized to 0F)", "normalized ckpt time")
    ax2 = ax.twinx()
    for strategy, style in [("shrink", "--o"), ("substitute", "-s")]:
        series = pct.get((strategy, 4), {})
        ax2.plot(
            [ps.index(p) for p in ps if p in series],
            [series[p] for p in ps if p in series],
            style,
            color="black",
            markersize=3,
            lw=0.8,
            label=f"{strategy} 4F % of total",
        )
    ax2.set_ylabel("% of total (4F)")
    ax2.legend(fontsize=6, loc="upper right")
    fig.tight_layout()
    fig.savefig(os.path.join(od, "fig5.png"))

    # Figure 6
    rows = read(os.path.join(od, "fig6.csv"))
    data, ps = grouped(rows, "recovery_norm")
    pct, _ = grouped(rows, "recovery_pct")
    fig, ax = plt.subplots(figsize=(7, 3.2), dpi=150)
    bars(ax, data, ps, range(1, 5), "Fig. 6: recovery time (normalized to 1F)", "normalized recovery time")
    ax2 = ax.twinx()
    for strategy, style in [("shrink", "--o"), ("substitute", "-s")]:
        series = pct.get((strategy, 4), {})
        ax2.plot(
            [ps.index(p) for p in ps if p in series],
            [series[p] for p in ps if p in series],
            style,
            color="black",
            markersize=3,
            lw=0.8,
            label=f"{strategy} 4F % of total",
        )
    ax2.set_ylabel("% of total (4F)")
    ax2.legend(fontsize=6, loc="upper right")
    fig.tight_layout()
    fig.savefig(os.path.join(od, "fig6.png"))

    print(f"wrote {od}/fig4.png {od}/fig5.png {od}/fig6.png")


if __name__ == "__main__":
    main()
