#!/usr/bin/env bash
# Hot-path bench: widened GF(2^8) kernels, shared-buffer message layer,
# arena-backed delta codecs and the end-to-end commit pipeline (DESIGN.md
# §11).  Emits BENCH_hotpath.json; gates documented in the bench itself.
# Shim onto tools/bench.sh.
#
# Usage: tools/bench_hotpath.sh [extra cargo bench args]
#        BENCH_SMOKE=1 tools/bench_hotpath.sh   # CI quick pass
exec "$(dirname "$0")/bench.sh" hotpath "$@"
