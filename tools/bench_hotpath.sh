#!/usr/bin/env bash
# Hot-path bench: widened GF(2^8) kernels, shared-buffer message layer,
# arena-backed delta codecs and the end-to-end commit pipeline (DESIGN.md
# §11).  Emits BENCH_hotpath.json at the repository root and fails unless
# the widened GF kernel beats the bytewise reference >= 4x and the
# zero-copy wire cuts deep-copied bytes per commit >= 2x on the
# xor:4+delta and rs2:4+delta legs (vs the forced-deep-clone baseline,
# i.e. the pre-refactor wire), with bit-identical run digests.
#
# Usage: tools/bench_hotpath.sh [extra cargo bench args]
#        BENCH_SMOKE=1 tools/bench_hotpath.sh   # CI quick pass
set -euo pipefail
cd "$(dirname "$0")/.."
cargo bench --bench hotpath "$@"
echo "BENCH_hotpath.json:"
cat BENCH_hotpath.json
