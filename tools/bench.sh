#!/usr/bin/env bash
# Parameterized bench runner: every in-repo bench follows the same recipe
# (cargo bench --bench <target>, then print the BENCH_*.json it emitted at
# the repository root), so the per-bench scripts are one-line shims onto
# this one.
#
# Usage: tools/bench.sh <hotpath|ckpt|scale|faults|overlap|fleet> [cargo bench args]
#        BENCH_SMOKE=1 tools/bench.sh <name>   # CI quick pass
#        BENCH_FULL=1  tools/bench.sh <name>   # full paper grid
set -euo pipefail
name="${1:?usage: tools/bench.sh <hotpath|ckpt|scale|faults|overlap|fleet> [cargo bench args]}"
shift
case "$name" in
  hotpath) bench=hotpath;       json=BENCH_hotpath.json ;;
  ckpt)    bench=bench_ckpt;    json=BENCH_ckpt.json ;;
  scale)   bench=bench_scale;   json=BENCH_scale.json ;;
  faults)  bench=bench_faults;  json=BENCH_faults.json ;;
  overlap) bench=bench_overlap; json=BENCH_overlap.json ;;
  fleet)   bench=bench_fleet;   json=BENCH_fleet.json ;;
  *) echo "unknown bench '$name' (hotpath|ckpt|scale|faults|overlap|fleet)" >&2; exit 2 ;;
esac
cd "$(dirname "$0")/.."
cargo bench --bench "$bench" "$@"
echo "$json:"
cat "$json"
