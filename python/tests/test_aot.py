"""AOT pipeline tests: artifacts are valid HLO text, the manifest is complete
and consistent, and lowering is deterministic."""

import json
import os

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def built(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    manifest = aot.build(out, buckets=[256, 512], dtype_name="float64",
                         quiet=True)
    return out, manifest


class TestArtifacts:
    def test_all_graphs_emitted(self, built):
        out, manifest = built
        assert set(manifest["graphs"]) == set(model.GRAPHS)
        for entries in manifest["graphs"].values():
            assert set(entries) == {"256", "512"}
            for e in entries.values():
                assert os.path.exists(os.path.join(out, e["file"]))

    def test_hlo_text_parses_header(self, built):
        out, manifest = built
        for entries in manifest["graphs"].values():
            for e in entries.values():
                text = open(os.path.join(out, e["file"])).read()
                assert text.startswith("HloModule")
                assert "ENTRY" in text

    def test_no_custom_calls(self, built):
        """interpret=True pallas must lower to plain HLO: a Mosaic
        custom-call would be unloadable by the CPU PJRT client."""
        out, manifest = built
        for entries in manifest["graphs"].values():
            for e in entries.values():
                text = open(os.path.join(out, e["file"])).read()
                assert "custom-call" not in text, e["file"]

    def test_manifest_constants(self, built):
        _, manifest = built
        assert manifest["m"] == model.M
        assert manifest["k"] == 7
        assert manifest["halo_pad"] == model.HALO_PAD
        assert manifest["dtype"] == "float64"

    def test_arg_shapes_match_model(self, built):
        _, manifest = built
        import jax.numpy as jnp
        for name, entries in manifest["graphs"].items():
            _, argspec = model.GRAPHS[name]
            for rows_s, e in entries.items():
                want = argspec(int(rows_s), jnp.float64)
                got = e["args"]
                assert len(got) == len(want)
                for g, w in zip(got, want):
                    assert tuple(g["shape"]) == w.shape
                    assert g["dtype"] == str(w.dtype)

    def test_deterministic(self, built, tmp_path):
        out, manifest = built
        m2 = aot.build(str(tmp_path), buckets=[256, 512],
                       dtype_name="float64", quiet=True)
        for name in manifest["graphs"]:
            for rows in manifest["graphs"][name]:
                assert (manifest["graphs"][name][rows]["sha256"]
                        == m2["graphs"][name][rows]["sha256"])

    def test_manifest_roundtrip(self, built):
        out, manifest = built
        on_disk = json.load(open(os.path.join(out, "manifest.json")))
        assert on_disk == manifest
