"""Kernel-vs-ref allclose: the CORE correctness signal for L1.

Every Pallas kernel is checked against the pure-jnp oracle in
``compile.kernels.ref`` on fixed cases here, and across a hypothesis sweep of
shapes/dtypes in ``test_kernel_property.py``.
"""

import numpy as np
import pytest
import jax.numpy as jnp

from compile.kernels import fused, ref
from compile.kernels.spmv_ell import K, spmv_ell
from compile.model import M


def rng(seed=0):
    return np.random.default_rng(seed)


def make_ell(r, rh, dtype, seed=0, pad_rows=0):
    """Random ELL block; the last ``pad_rows`` rows are zero padding that
    must not contribute to the product."""
    g = rng(seed)
    vals = g.standard_normal((r, K)).astype(dtype)
    cols = g.integers(0, rh, (r, K)).astype(np.int32)
    if pad_rows:
        vals[r - pad_rows:] = 0.0
        cols[r - pad_rows:] = 0
    x = g.standard_normal(rh).astype(dtype)
    return jnp.array(vals), jnp.array(cols), jnp.array(x)


TOL = {np.float32: dict(rtol=1e-5, atol=1e-5),
       np.float64: dict(rtol=1e-12, atol=1e-12)}


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("r,tile", [(256, 256), (512, 128), (2048, 1024)])
class TestSpmv:
    def test_matches_ref(self, dtype, r, tile):
        vals, cols, x = make_ell(r, r + 64, dtype)
        got = spmv_ell(vals, cols, x, tile=tile)
        np.testing.assert_allclose(got, ref.spmv_ell(vals, cols, x),
                                   **TOL[dtype])

    def test_padding_rows_are_zero(self, dtype, r, tile):
        vals, cols, x = make_ell(r, r + 64, dtype, pad_rows=r // 4)
        got = np.asarray(spmv_ell(vals, cols, x, tile=tile))
        assert np.all(got[r - r // 4:] == 0.0)


@pytest.mark.parametrize("dtype", [np.float32, np.float64])
@pytest.mark.parametrize("r,tile", [(256, 256), (512, 128), (4096, 2048)])
class TestFused:
    def _vw(self, dtype, r, seed=1):
        g = rng(seed)
        v = jnp.array(g.standard_normal((M, r)).astype(dtype))
        w = jnp.array(g.standard_normal(r).astype(dtype))
        return v, w

    def test_dot_partials(self, dtype, r, tile):
        v, w = self._vw(dtype, r)
        mask = (jnp.arange(M) <= 7).astype(v.dtype)
        got = fused.dot_partials(v, w, mask, tile=tile)
        np.testing.assert_allclose(got, ref.dot_partials(v, w, mask),
                                   **TOL[dtype])

    def test_dot_partials_mask_zeroes_unused(self, dtype, r, tile):
        v, w = self._vw(dtype, r)
        mask = (jnp.arange(M) <= 3).astype(v.dtype)
        got = np.asarray(fused.dot_partials(v, w, mask, tile=tile))
        assert np.all(got[4:] == 0.0)

    def test_update_w(self, dtype, r, tile):
        v, w = self._vw(dtype, r)
        h = jnp.array(rng(2).standard_normal(M).astype(dtype))
        wn, nsq = fused.update_w(v, w, h, tile=tile)
        wn_r, nsq_r = ref.update_w(v, w, h)
        np.testing.assert_allclose(wn, wn_r, **TOL[dtype])
        np.testing.assert_allclose(nsq, nsq_r, **TOL[dtype])

    def test_update_w_norm_consistent(self, dtype, r, tile):
        """The fused norm partial must equal the norm of the fused output."""
        v, w = self._vw(dtype, r)
        h = jnp.array(rng(3).standard_normal(M).astype(dtype))
        wn, nsq = fused.update_w(v, w, h, tile=tile)
        np.testing.assert_allclose(float(nsq[0]),
                                   float(jnp.sum(wn * wn)), **TOL[dtype])

    def test_update_x(self, dtype, r, tile):
        v, x = self._vw(dtype, r)
        y = jnp.array(rng(4).standard_normal(M).astype(dtype))
        got = fused.update_x(v, y, x, tile=tile)
        np.testing.assert_allclose(got, ref.update_x(v, y, x), **TOL[dtype])


def test_spmv_identity_matrix():
    """ELL encoding of I must reproduce x exactly."""
    r = 256
    vals = np.zeros((r, K)); vals[:, 0] = 1.0
    cols = np.zeros((r, K), dtype=np.int32)
    cols[:, 0] = np.arange(r)
    x = rng(5).standard_normal(r + 16)
    got = spmv_ell(jnp.array(vals), jnp.array(cols), jnp.array(x))
    np.testing.assert_array_equal(np.asarray(got), x[:r])


def test_spmv_laplacian_row_sums():
    """1D Laplacian (2 on diag, -1 off) times ones: interior rows -> 0."""
    r = 512
    vals = np.zeros((r, K)); cols = np.zeros((r, K), dtype=np.int32)
    for i in range(r):
        vals[i, 0], cols[i, 0] = 2.0, i
        if i > 0:
            vals[i, 1], cols[i, 1] = -1.0, i - 1
        if i < r - 1:
            vals[i, 2], cols[i, 2] = -1.0, i + 1
    y = np.asarray(spmv_ell(jnp.array(vals), jnp.array(cols),
                            jnp.array(np.ones(r))))
    np.testing.assert_allclose(y[1:-1], 0.0, atol=1e-14)
    np.testing.assert_allclose([y[0], y[-1]], [1.0, 1.0], atol=1e-14)


def test_arnoldi_composition_orthogonal_step():
    """ref.arnoldi_cgs_step produces a unit vector orthogonal to the basis."""
    r = 256
    g = rng(6)
    vals, cols, x = make_ell(r, r, np.float64, seed=6)
    v = np.zeros((M, r))
    q0 = g.standard_normal(r); q0 /= np.linalg.norm(q0)
    v[0] = q0
    h, beta, vnext = ref.arnoldi_cgs_step(
        jnp.array(vals), jnp.array(cols), jnp.array(v), 0, jnp.array(x))
    vnext = np.asarray(vnext)
    np.testing.assert_allclose(np.linalg.norm(vnext), 1.0, rtol=1e-12)
    assert abs(np.dot(vnext, q0)) < 1e-10
