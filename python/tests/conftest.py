import jax

# The solver runs in f64 (GMRES orthogonalization is sensitive); enable x64
# before any kernel module traces anything.
jax.config.update("jax_enable_x64", True)
