"""L2 model-graph tests: shape contracts, padding invariance, and a full
single-process GMRES built from the exact graphs the Rust runtime executes —
proving the graph set is sufficient to run the solver."""

import numpy as np
import pytest
import jax.numpy as jnp

from compile import model
from compile.kernels.spmv_ell import K


def laplacian_1d_ell(r, rh=None, dtype=np.float64):
    """1D Laplacian in ELL layout (well-conditioned enough for tiny GMRES)."""
    rh = rh or r
    vals = np.zeros((r, K), dtype=dtype)
    cols = np.zeros((r, K), dtype=np.int32)
    for i in range(r):
        vals[i, 0], cols[i, 0] = 2.0, i
        if i > 0:
            vals[i, 1], cols[i, 1] = -1.0, i - 1
        if i < r - 1:
            vals[i, 2], cols[i, 2] = -1.0, i + 1
    return vals, cols


class TestGraphContracts:
    """Every graph must lower at every bucket with the manifest's shapes."""

    @pytest.mark.parametrize("name", list(model.GRAPHS))
    def test_lowers_smallest_bucket(self, name):
        lowered = model.lower_graph(name, 256)
        text = lowered.as_text()
        assert "func.func public @main" in text or "ENTRY" in text

    @pytest.mark.parametrize("name", list(model.GRAPHS))
    def test_argspec_shapes(self, name):
        _, argspec = model.GRAPHS[name]
        args = argspec(512, jnp.float64)
        for a in args:
            assert all(d > 0 for d in a.shape)

    def test_halo_rows(self):
        assert model.halo_rows(256) == 256 + model.HALO_PAD


class TestPaddingInvariance:
    """Row buckets are padded; zero padding must not change live results."""

    def test_spmv_padding(self):
        r_live, r_bucket = 300, 512
        vals, cols = laplacian_1d_ell(r_live)
        vals_p = np.zeros((r_bucket, K)); vals_p[:r_live] = vals
        cols_p = np.zeros((r_bucket, K), dtype=np.int32)
        cols_p[:r_live] = cols
        g = np.random.default_rng(0)
        x_live = g.standard_normal(r_live)
        x_p = np.zeros(model.halo_rows(r_bucket)); x_p[:r_live] = x_live
        (y_p,) = model.spmv(jnp.array(vals_p), jnp.array(cols_p),
                            jnp.array(x_p))
        (y_ref,) = model.spmv(jnp.array(vals), jnp.array(cols),
                              jnp.array(np.concatenate([x_live, [0.0]])[:r_live]))
        np.testing.assert_allclose(np.asarray(y_p)[:r_live],
                                   np.asarray(y_ref), rtol=1e-12)
        assert np.all(np.asarray(y_p)[r_live:] == 0.0)

    def test_dot_partials_padding(self):
        r_live, r_bucket = 200, 256
        g = np.random.default_rng(1)
        v = np.zeros((model.M, r_bucket)); w = np.zeros(r_bucket)
        v[:, :r_live] = g.standard_normal((model.M, r_live))
        w[:r_live] = g.standard_normal(r_live)
        mask = (np.arange(model.M) <= 5).astype(np.float64)
        (h,) = model.dot_partials(jnp.array(v), jnp.array(w), jnp.array(mask))
        h_live = (v[:, :r_live] @ w[:r_live]) * mask
        np.testing.assert_allclose(np.asarray(h), h_live, rtol=1e-12)


def gmres_via_graphs(vals, cols, b, m=10, outer=20, tol=1e-10):
    """Restarted GMRES(m) using ONLY the model graphs (plus tiny host-side
    Givens math, exactly as the Rust coordinator does)."""
    r = b.shape[0]
    vals_j, cols_j = jnp.array(vals), jnp.array(cols)
    x = jnp.zeros(r)
    bnorm = float(jnp.linalg.norm(b))
    for _ in range(outer):
        (ax,) = model.spmv(vals_j, cols_j, x)
        res = b - ax
        beta = float(jnp.linalg.norm(res))
        if beta / bnorm < tol:
            return x, beta / bnorm
        v = jnp.zeros((model.M, r))
        v = v.at[0].set(res / beta)
        hess = np.zeros((m + 1, m))
        g_vec = np.zeros(m + 1); g_vec[0] = beta
        cs, sn = np.zeros(m), np.zeros(m)
        k_used = m
        for j in range(m):
            (w,) = model.spmv(vals_j, cols_j, v[j])
            mask = (jnp.arange(model.M) <= j).astype(jnp.float64)
            (h,) = model.dot_partials(v, w, mask)
            wn, nsq = model.update_w(v, w, h)
            hnext = float(jnp.sqrt(nsq[0]))
            hess[:j + 1, j] = np.asarray(h)[:j + 1]
            hess[j + 1, j] = hnext
            if hnext > 1e-14:
                (vnext,) = model.scale(wn, jnp.array([1.0 / hnext]))
                v = v.at[j + 1].set(vnext)
            # host-side Givens (same as rust/src/solver/givens.rs)
            for i in range(j):
                t = cs[i] * hess[i, j] + sn[i] * hess[i + 1, j]
                hess[i + 1, j] = -sn[i] * hess[i, j] + cs[i] * hess[i + 1, j]
                hess[i, j] = t
            d = np.hypot(hess[j, j], hess[j + 1, j])
            cs[j], sn[j] = hess[j, j] / d, hess[j + 1, j] / d
            hess[j, j] = d; hess[j + 1, j] = 0.0
            g_vec[j + 1] = -sn[j] * g_vec[j]
            g_vec[j] = cs[j] * g_vec[j]
            if abs(g_vec[j + 1]) / bnorm < tol or hnext <= 1e-14:
                k_used = j + 1
                break
        k = k_used
        y = np.linalg.solve(hess[:k, :k], g_vec[:k])
        y_full = np.zeros(model.M); y_full[:k] = y
        (x,) = model.update_x(v, jnp.array(y_full), x)
    (ax,) = model.spmv(vals_j, cols_j, x)
    return x, float(jnp.linalg.norm(b - ax)) / bnorm


class TestGmresFromGraphs:
    def test_converges_on_1d_laplacian(self):
        r = 64
        vals, cols = laplacian_1d_ell(r)
        x_true = np.random.default_rng(2).standard_normal(r)
        from compile.kernels import ref
        b = np.asarray(ref.spmv_ell(jnp.array(vals), jnp.array(cols),
                                    jnp.array(x_true)))
        x, rel = gmres_via_graphs(vals, cols, jnp.array(b), m=20, outer=30)
        assert rel < 1e-8
        np.testing.assert_allclose(np.asarray(x), x_true, atol=1e-6)

    def test_residual_monotone_over_restarts(self):
        r = 128
        vals, cols = laplacian_1d_ell(r)
        b = jnp.array(np.random.default_rng(3).standard_normal(r))
        _, rel1 = gmres_via_graphs(vals, cols, b, m=10, outer=2)
        _, rel2 = gmres_via_graphs(vals, cols, b, m=10, outer=8)
        assert rel2 <= rel1 + 1e-12
