"""Hypothesis sweeps: Pallas kernels vs the jnp oracle over random
shapes/dtypes/tiles.  These are the property-based layer of the L1 signal."""

import numpy as np
import jax.numpy as jnp
from hypothesis import given, settings, strategies as st

from compile.kernels import fused, ref
from compile.kernels.spmv_ell import K, spmv_ell
from compile.model import M

DTYPES = st.sampled_from([np.float32, np.float64])
# Power-of-two row counts (the runtime only ever requests bucket shapes) and
# tiles that divide them.
POW2_ROWS = st.sampled_from([128, 256, 512, 1024, 2048])
TILES = st.sampled_from([64, 128, 256, 512])
SEEDS = st.integers(0, 2**31 - 1)


def tol(dtype):
    return dict(rtol=2e-4, atol=2e-4) if dtype == np.float32 \
        else dict(rtol=1e-11, atol=1e-11)


@settings(max_examples=25, deadline=None)
@given(r=POW2_ROWS, tile=TILES, dtype=DTYPES, seed=SEEDS,
       halo=st.integers(0, 300))
def test_spmv_matches_ref(r, tile, dtype, seed, halo):
    g = np.random.default_rng(seed)
    rh = r + halo
    vals = jnp.array(g.standard_normal((r, K)).astype(dtype))
    cols = jnp.array(g.integers(0, rh, (r, K)).astype(np.int32))
    x = jnp.array(g.standard_normal(rh).astype(dtype))
    got = spmv_ell(vals, cols, x, tile=min(tile, r))
    np.testing.assert_allclose(got, ref.spmv_ell(vals, cols, x), **tol(dtype))


@settings(max_examples=25, deadline=None)
@given(r=POW2_ROWS, tile=TILES, dtype=DTYPES, seed=SEEDS,
       j=st.integers(0, M - 1))
def test_dot_partials_matches_ref(r, tile, dtype, seed, j):
    g = np.random.default_rng(seed)
    v = jnp.array(g.standard_normal((M, r)).astype(dtype))
    w = jnp.array(g.standard_normal(r).astype(dtype))
    mask = (jnp.arange(M) <= j).astype(v.dtype)
    got = fused.dot_partials(v, w, mask, tile=min(tile, r))
    np.testing.assert_allclose(got, ref.dot_partials(v, w, mask), **tol(dtype))


@settings(max_examples=25, deadline=None)
@given(r=POW2_ROWS, tile=TILES, dtype=DTYPES, seed=SEEDS)
def test_update_w_matches_ref(r, tile, dtype, seed):
    g = np.random.default_rng(seed)
    v = jnp.array(g.standard_normal((M, r)).astype(dtype))
    w = jnp.array(g.standard_normal(r).astype(dtype))
    h = jnp.array(g.standard_normal(M).astype(dtype))
    wn, nsq = fused.update_w(v, w, h, tile=min(tile, r))
    wn_r, nsq_r = ref.update_w(v, w, h)
    np.testing.assert_allclose(wn, wn_r, **tol(dtype))
    np.testing.assert_allclose(nsq, nsq_r, **tol(dtype))


@settings(max_examples=25, deadline=None)
@given(r=POW2_ROWS, tile=TILES, dtype=DTYPES, seed=SEEDS)
def test_update_x_matches_ref(r, tile, dtype, seed):
    g = np.random.default_rng(seed)
    v = jnp.array(g.standard_normal((M, r)).astype(dtype))
    y = jnp.array(g.standard_normal(M).astype(dtype))
    x = jnp.array(g.standard_normal(r).astype(dtype))
    got = fused.update_x(v, y, x, tile=min(tile, r))
    np.testing.assert_allclose(got, ref.update_x(v, y, x), **tol(dtype))


@settings(max_examples=15, deadline=None)
@given(r=st.sampled_from([128, 256, 512]), seed=SEEDS)
def test_spmv_linearity(r, seed):
    """A(ax + by) == a*Ax + b*Ay — linearity must hold exactly in structure."""
    g = np.random.default_rng(seed)
    vals = jnp.array(g.standard_normal((r, K)))
    cols = jnp.array(g.integers(0, r, (r, K)).astype(np.int32))
    x = jnp.array(g.standard_normal(r))
    y = jnp.array(g.standard_normal(r))
    a, b = 2.5, -1.25
    lhs = spmv_ell(vals, cols, a * x + b * y)
    rhs = a * spmv_ell(vals, cols, x) + b * spmv_ell(vals, cols, y)
    np.testing.assert_allclose(lhs, rhs, rtol=1e-10, atol=1e-10)
