"""AOT pipeline: lower every L2 graph x row-bucket to HLO *text* artifacts.

Interchange format is HLO text, NOT a serialized ``HloModuleProto``: jax>=0.5
emits protos with 64-bit instruction ids that the Rust side's xla_extension
0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser reassigns ids and
round-trips cleanly (see /opt/xla-example/README.md).

Outputs, under ``--out-dir`` (default ``artifacts/``):

  <graph>_r<rows>.hlo.txt     one HLO module per (graph, row bucket)
  manifest.json               machine-readable index consumed by the Rust
                              runtime: graph names, buckets, arg shapes,
                              dtypes, constants (M, K, HALO_PAD)

Python runs ONCE at build time (``make artifacts``); the Rust binary is
self-contained afterwards.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from compile import model
from compile.kernels.spmv_ell import K


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _shape_entry(s: jax.ShapeDtypeStruct) -> dict:
    return {"shape": list(s.shape), "dtype": str(s.dtype)}


def build(out_dir: str, buckets: list[int], dtype_name: str,
          quiet: bool = False) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {
        "dtype": dtype_name,
        "m": model.M,
        "k": K,
        "halo_pad": model.HALO_PAD,
        "row_buckets": buckets,
        "graphs": {},
    }
    dt = jnp.dtype(dtype_name)
    for name, (fn, argspec) in model.GRAPHS.items():
        entries = {}
        for rows in buckets:
            lowered = model.lower_graph(name, rows, dtype_name)
            text = to_hlo_text(lowered)
            fname = f"{name}_r{rows}.hlo.txt"
            with open(os.path.join(out_dir, fname), "w") as f:
                f.write(text)
            entries[str(rows)] = {
                "file": fname,
                "args": [_shape_entry(s) for s in argspec(rows, dt)],
                "sha256": hashlib.sha256(text.encode()).hexdigest()[:16],
                "bytes": len(text),
            }
            if not quiet:
                print(f"  {fname}: {len(text)} chars", file=sys.stderr)
        manifest["graphs"][name] = entries
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    # Flat TSV twin for the (dependency-free) Rust loader.
    with open(os.path.join(out_dir, "manifest.tsv"), "w") as f:
        f.write(f"dtype\t{dtype_name}\n")
        f.write(f"m\t{model.M}\n")
        f.write(f"k\t{K}\n")
        f.write(f"halo_pad\t{model.HALO_PAD}\n")
        f.write("buckets\t" + " ".join(str(b) for b in buckets) + "\n")
        for name, entries in manifest["graphs"].items():
            for rows_s, e in entries.items():
                f.write(f"graph\t{name}\t{rows_s}\t{e['file']}\n")
    return manifest


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument("--buckets", type=int, nargs="*", default=model.ROW_BUCKETS)
    p.add_argument("--dtype", default="float64")
    p.add_argument("--quiet", action="store_true")
    args = p.parse_args()
    manifest = build(args.out_dir, args.buckets, args.dtype, args.quiet)
    n = sum(len(v) for v in manifest["graphs"].values())
    print(f"wrote {n} HLO modules + manifest.json to {args.out_dir}")


if __name__ == "__main__":
    main()
