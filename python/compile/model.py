"""L2: JAX graphs for the *local* (per-rank) FT-GMRES solver steps.

The distributed FT-GMRES solver lives in the Rust coordinator (L3); global
reductions (dot products, norms) are allreduces performed there.  What gets
AOT-lowered here are the five fixed-shape local step graphs each rank executes
between communications, all calling the L1 Pallas kernels:

  spmv          (vals[R,K], cols[R,K], x_halo[RH])       -> y[R]
  dot_partials  (V[M,R],   w[R],      mask[M])           -> h_part[M]
  update_w      (V[M,R],   w[R],      h[M])              -> (w'[R], nsq[1])
  update_x      (V[M,R],   y[M],      x[R])              -> x'[R]
  scale         (w[R],     alpha[1])                     -> w*alpha[R]

Shapes are bucketed: HLO is fixed-shape but local row counts vary with the
process count P and with shrink-recovery redistribution, so ``aot.py`` lowers
every graph once per row bucket (powers of two) and the Rust runtime pads the
local block up to the next bucket.  Padding rows carry zero matrix values and
zero vector entries, so every graph is padding-invariant (verified in
python/tests/test_model.py::test_padding_invariance).
"""

from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp

from compile.kernels import fused, spmv_ell
from compile.kernels.spmv_ell import K

# Krylov basis slots: inner restart length m=25 (the paper checkpoints after
# each inner solve of 25 iterations) plus one for the new direction.
M = 26

# Row buckets the runtime may request.  48^3 at P=512 gives 216 rows/rank
# (bucket 256); a 4-rank quickstart of 48^3 gives 27648 (bucket 32768).
ROW_BUCKETS = [256, 512, 1024, 2048, 4096, 8192, 16384, 32768]

# Halo padding: a block-row of a 7-point stencil needs at most two planes of
# nx*ny ghost rows; 8192 covers grids up to 64x64 planes (nx*ny <= 4096).
HALO_PAD = 8192

DEFAULT_DTYPE = jnp.float64


def halo_rows(r: int) -> int:
    """Halo-extended length of the SpMV source vector for row bucket ``r``."""
    return r + HALO_PAD


def spmv(vals, cols, x_halo):
    """Local block SpMV (L1 Pallas kernel)."""
    return (spmv_ell.spmv_ell(vals, cols, x_halo),)


def dot_partials(v, w, mask):
    """Local partials of masked basis dots; allreduced by L3."""
    return (fused.dot_partials(v, w, mask),)


def update_w(v, w, h):
    """Fused CGS update + local norm partial; ``h`` is the allreduced dots."""
    wn, nsq = fused.update_w(v, w, h)
    return (wn, nsq)


def update_x(v, y, x):
    """Solution update at the end of a restart cycle."""
    return (fused.update_x(v, y, x),)


def scale(w, alpha):
    """w * alpha (alpha shaped (1,)): basis normalization after allreduce."""
    return (w * alpha[0],)


# graph name -> (fn, example-arg builder given (rows, dtype))
GRAPHS: dict[str, tuple[Callable, Callable]] = {
    "spmv": (
        spmv,
        lambda r, dt: (
            jax.ShapeDtypeStruct((r, K), dt),
            jax.ShapeDtypeStruct((r, K), jnp.int32),
            jax.ShapeDtypeStruct((halo_rows(r),), dt),
        ),
    ),
    "dot_partials": (
        dot_partials,
        lambda r, dt: (
            jax.ShapeDtypeStruct((M, r), dt),
            jax.ShapeDtypeStruct((r,), dt),
            jax.ShapeDtypeStruct((M,), dt),
        ),
    ),
    "update_w": (
        update_w,
        lambda r, dt: (
            jax.ShapeDtypeStruct((M, r), dt),
            jax.ShapeDtypeStruct((r,), dt),
            jax.ShapeDtypeStruct((M,), dt),
        ),
    ),
    "update_x": (
        update_x,
        lambda r, dt: (
            jax.ShapeDtypeStruct((M, r), dt),
            jax.ShapeDtypeStruct((M,), dt),
            jax.ShapeDtypeStruct((r,), dt),
        ),
    ),
    "scale": (
        scale,
        lambda r, dt: (
            jax.ShapeDtypeStruct((r,), dt),
            jax.ShapeDtypeStruct((1,), dt),
        ),
    ),
}


@functools.cache
def lower_graph(name: str, rows: int, dtype_name: str = "float64"):
    """Lower one graph at one row bucket; returns the jax Lowered object."""
    fn, argspec = GRAPHS[name]
    dt = jnp.dtype(dtype_name)
    args = argspec(rows, dt)
    return jax.jit(fn).lower(*args)
