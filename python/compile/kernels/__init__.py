"""L1 Pallas kernels for the FT-GMRES hot path (build-time only).

Import the submodules (``spmv_ell``, ``fused``, ``ref``) directly; the package
namespace deliberately does not re-export functions, to avoid shadowing the
``spmv_ell`` module with the ``spmv_ell`` function.
"""

from compile.kernels import fused, ref, spmv_ell  # noqa: F401
from compile.kernels.spmv_ell import K  # noqa: F401

__all__ = ["K", "fused", "ref", "spmv_ell"]
