"""Pure-jnp oracle for every L1 Pallas kernel.

These are the correctness ground truth: pytest (and the hypothesis sweeps in
python/tests/) assert ``assert_allclose(kernel(...), ref(...))`` across shapes
and dtypes.  Keep these dead simple -- no tiling, no pallas, no cleverness.
"""

from __future__ import annotations

import jax.numpy as jnp


def spmv_ell(vals, cols, x):
    """y[r] = sum_k vals[r, k] * x[cols[r, k]]."""
    return jnp.sum(vals * x[cols], axis=1)


def dot_partials(v, w, mask):
    """h[i] = mask[i] * <V[i, :], w>."""
    return (v @ w) * mask


def update_w(v, w, h):
    """w' = w - V^T h ; nsq = <w', w'> (shape (1,))."""
    wn = w - v.T @ h
    return wn, jnp.sum(wn * wn)[None]


def update_x(v, y, x):
    """x' = x + V^T y."""
    return x + v.T @ y


def arnoldi_cgs_step(vals, cols, v, j, x_halo):
    """Reference composition of one classical-Gram-Schmidt Arnoldi step on a
    single process (no distribution): used to validate model.py wiring.

    Returns (h, beta, v_next) where h are the projection coefficients, beta
    the norm of the orthogonalized vector.
    """
    m, r = v.shape
    w = spmv_ell(vals, cols, x_halo)
    mask = (jnp.arange(m) <= j).astype(v.dtype)
    h = dot_partials(v, w, mask)
    wn, nsq = update_w(v, w, h)
    beta = jnp.sqrt(nsq[0])
    return h, beta, wn / beta
