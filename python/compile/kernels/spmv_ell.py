"""L1 Pallas kernel: ELLPACK sparse-matrix--vector product.

The paper's compute hot-spot is the local block SpMV inside each GMRES
iteration (a 3D 7-point stencil matrix, so every row has at most K=7
nonzeros).  ELLPACK gives dense, regular ``(TILE, K)`` tiles, which is the
TPU-friendly reshaping of the paper's CSR/Tpetra layout: no per-row
indirection in the inner loop, and the HBM->VMEM schedule is expressed with
``BlockSpec`` over the row dimension while the gathered source vector ``x``
(local rows + halo) stays resident.

The kernel MUST be lowered with ``interpret=True``: the CPU PJRT plugin used
by the Rust runtime cannot execute Mosaic custom-calls.  Correctness is
checked against the pure-jnp oracle in ``ref.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Max nonzeros per row for a 7-point stencil.
K = 7

# Default row-tile.  At f64 a (1024, 7) vals+cols tile is 1024*7*(8+4) = 84 KiB;
# with the resident x block this keeps the per-grid-step VMEM footprint well
# under the ~1 MiB budget documented in DESIGN.md section 7.
DEFAULT_TILE = 1024


def _spmv_kernel(vals_ref, cols_ref, x_ref, y_ref):
    """One row-tile: y[r] = sum_k vals[r, k] * x[cols[r, k]].

    Padding rows/slots carry vals == 0.0 and cols pointing at a valid (zero)
    slot, so no masking is needed here.
    """
    vals = vals_ref[...]          # (TILE, K)
    cols = cols_ref[...]          # (TILE, K) int32
    x = x_ref[...]                # (RH,) resident across the whole grid
    y_ref[...] = jnp.sum(vals * x[cols], axis=1)


def spmv_ell(vals: jax.Array, cols: jax.Array, x: jax.Array, *,
             tile: int = DEFAULT_TILE) -> jax.Array:
    """ELL SpMV over a block of rows.

    Args:
      vals: ``(R, K)`` nonzero values (zero-padded).
      cols: ``(R, K)`` int32 column indices into ``x`` (halo-extended local
        indexing; padded slots must point at a zero entry of ``x``).
      x: ``(RH,)`` halo-extended source vector, ``RH >= R``.
      tile: row-tile size; must divide ``R`` (buckets are powers of two).

    Returns:
      ``(R,)`` product vector.
    """
    r, k = vals.shape
    assert k == K, f"expected K={K} nonzeros per row, got {k}"
    assert cols.shape == (r, k)
    (rh,) = x.shape
    t = min(tile, r)
    assert r % t == 0, f"tile {t} must divide rows {r}"

    return pl.pallas_call(
        _spmv_kernel,
        grid=(r // t,),
        in_specs=[
            pl.BlockSpec((t, K), lambda i: (i, 0)),
            pl.BlockSpec((t, K), lambda i: (i, 0)),
            pl.BlockSpec((rh,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((t,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), vals.dtype),
        interpret=True,
    )(vals, cols, x)


@functools.partial(jax.jit, static_argnames=("tile",))
def spmv_ell_jit(vals, cols, x, tile: int = DEFAULT_TILE):
    return spmv_ell(vals, cols, x, tile=tile)
