"""L1 Pallas kernels: fused Arnoldi vector operations.

GMRES spends its non-SpMV time in BLAS-1/BLAS-2 style operations over the
Krylov basis ``V`` (stored row-major as ``(M, R)``: M basis vectors of R local
rows).  Distributed dot products split into a *local partial* (these kernels)
followed by an allreduce performed by the Rust coordinator, then a local
update.  Three kernels:

* ``dot_partials``  -- h_part[i] = mask[i] * <V[i, :], w>        (CGS step 1)
* ``update_w``      -- w' = w - V^T h ; nsq_part = <w', w'>      (CGS step 2,
  fused with the norm partial so the hot path is one kernel launch)
* ``update_x``      -- x' = x + V^T y                            (solution
  update at the end of a restart cycle)

All are tiled over the row dimension R; reduction outputs are accumulated
across grid steps by revisiting the output block (``index_map -> 0``).
``interpret=True`` everywhere -- see spmv_ell.py.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_TILE = 2048


def _dot_partials_kernel(v_ref, w_ref, mask_ref, h_ref):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    # (M, TILE) @ (TILE,) -> (M,), masked so untouched basis slots stay zero.
    h_ref[...] += (v_ref[...] @ w_ref[...]) * mask_ref[...]


def dot_partials(v: jax.Array, w: jax.Array, mask: jax.Array, *,
                 tile: int = DEFAULT_TILE) -> jax.Array:
    """Local partials of the masked dots ``h[i] = mask[i] * <V[i], w>``."""
    m, r = v.shape
    assert w.shape == (r,) and mask.shape == (m,)
    t = min(tile, r)
    assert r % t == 0
    return pl.pallas_call(
        _dot_partials_kernel,
        grid=(r // t,),
        in_specs=[
            pl.BlockSpec((m, t), lambda i: (0, i)),
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((m,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((m,), v.dtype),
        interpret=True,
    )(v, w, mask)


def _update_w_kernel(v_ref, w_ref, h_ref, out_ref, nsq_ref):
    i = pl.program_id(0)
    wn = w_ref[...] - v_ref[...].T @ h_ref[...]
    out_ref[...] = wn

    @pl.when(i == 0)
    def _init():
        nsq_ref[...] = jnp.zeros_like(nsq_ref)

    nsq_ref[0] += jnp.sum(wn * wn)


def update_w(v: jax.Array, w: jax.Array, h: jax.Array, *,
             tile: int = DEFAULT_TILE):
    """Fused orthogonalization update: ``w' = w - V^T h`` plus local ``<w',w'>``.

    Returns ``(w_new, nsq_partial)`` with ``nsq_partial`` shaped ``(1,)``.
    """
    m, r = v.shape
    assert w.shape == (r,) and h.shape == (m,)
    t = min(tile, r)
    assert r % t == 0
    return pl.pallas_call(
        _update_w_kernel,
        grid=(r // t,),
        in_specs=[
            pl.BlockSpec((m, t), lambda i: (0, i)),
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((m,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((t,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((r,), v.dtype),
            jax.ShapeDtypeStruct((1,), v.dtype),
        ],
        interpret=True,
    )(v, w, h)


def _update_x_kernel(v_ref, y_ref, x_ref, out_ref):
    out_ref[...] = x_ref[...] + v_ref[...].T @ y_ref[...]


def update_x(v: jax.Array, y: jax.Array, x: jax.Array, *,
             tile: int = DEFAULT_TILE) -> jax.Array:
    """Solution update ``x' = x + V^T y`` at the end of a restart cycle."""
    m, r = v.shape
    assert y.shape == (m,) and x.shape == (r,)
    t = min(tile, r)
    assert r % t == 0
    return pl.pallas_call(
        _update_x_kernel,
        grid=(r // t,),
        in_specs=[
            pl.BlockSpec((m, t), lambda i: (0, i)),
            pl.BlockSpec((m,), lambda i: (0,)),
            pl.BlockSpec((t,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((t,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((r,), v.dtype),
        interpret=True,
    )(v, y, x)
