//! Correlated group failures under the parity checkpoint schemes
//! (DESIGN.md §8–§9): one failure per parity group reconstructs in situ
//! from the group's XOR stripe; two failures inside *one* group before a
//! re-encode destroy both the data and its only `xor:4` redundancy — the
//! policy engine detects the unrecoverable loss and escalates to a global
//! restart, recording why, and the survivors still produce the right
//! answer by rebuilding from scratch.  The same correlated double fault
//! under `rs2:4` (double parity, DESIGN.md §9) instead reconstructs via
//! the two-erasure GF(2^8) solve and recovers in situ — no restart.
//!
//! ```sh
//! cargo run --release --example group_failure
//! ```

use std::sync::Arc;

use ulfm_ftgmres::backend::native::NativeBackend;
use ulfm_ftgmres::ckptstore::Scheme;
use ulfm_ftgmres::config::RunConfig;
use ulfm_ftgmres::coordinator;
use ulfm_ftgmres::failure::InjectionPlan;
use ulfm_ftgmres::figures::decision_table;
use ulfm_ftgmres::problem::Grid3D;
use ulfm_ftgmres::recovery::Strategy;

fn xor_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.grid = Grid3D::cube(12);
    cfg.p = 8;
    cfg.strategy = Strategy::Shrink;
    cfg.solver.tol = 1e-10;
    cfg.solver.m_inner = 10;
    cfg.solver.m_outer = 20;
    cfg.solver.max_cycles = 20;
    cfg.solver.ckpt.scheme = Scheme::Xor { g: 4 };
    cfg
}

fn main() -> anyhow::Result<()> {
    let cfg = xor_cfg();
    let backend = Arc::new(NativeBackend::new(cfg.compute.clone()));

    // --- Leg 1: one failure per parity group -> in-situ reconstruction ---
    println!("# leg 1: xor:4, one failure in each parity group (recoverable)");
    let plan = InjectionPlan::cross_group_campaign(cfg.p, 4, 2, cfg.solver.m_inner as u64);
    let rep = coordinator::run_custom(&cfg, backend.clone(), plan)?;
    println!(
        "tts={:.4}s iters={} relres={:.2e} converged={} failures={}",
        rep.time_to_solution, rep.iterations, rep.final_relres, rep.converged, rep.failures
    );
    println!("{}", decision_table(&rep).to_text());
    assert!(rep.converged);
    assert!(
        rep.decisions.iter().all(|d| d.decision == "shrink"),
        "single in-group losses reconstruct from parity and recover in situ"
    );

    // --- Leg 2: two failures in ONE parity group -> escalation ---
    println!("# leg 2: xor:4, two simultaneous failures in parity group 1 (unrecoverable)");
    let plan = InjectionPlan::same_group_burst(cfg.p, 4, 1, 2, 25);
    let rep = coordinator::run_custom(&cfg, backend, plan)?;
    println!(
        "tts={:.4}s iters={} relres={:.2e} converged={} failures={}",
        rep.time_to_solution, rep.iterations, rep.final_relres, rep.converged, rep.failures
    );
    println!("{}", decision_table(&rep).to_text());
    assert_eq!(rep.decisions.len(), 1, "one correlated event");
    assert_eq!(
        rep.decisions[0].decision, "global-restart",
        "a double in-group loss must escalate"
    );
    assert!(
        rep.decisions[0].reason.contains("unrecoverable"),
        "the decision log records why: {}",
        rep.decisions[0].reason
    );
    assert!(rep.converged, "the restarted run still converges to the right answer");

    // --- Leg 3: the same double fault under rs2:4 -> in-situ recovery ---
    println!("# leg 3: rs2:4, the same two-in-group burst (double parity recovers it)");
    let mut cfg = xor_cfg();
    cfg.solver.ckpt.scheme = Scheme::Rs2 { g: 4 };
    let backend = Arc::new(NativeBackend::new(cfg.compute.clone()));
    let plan = InjectionPlan::same_group_burst(cfg.p, 4, 1, 2, 25);
    let rep = coordinator::run_custom(&cfg, backend, plan)?;
    println!(
        "tts={:.4}s iters={} relres={:.2e} converged={} failures={}",
        rep.time_to_solution, rep.iterations, rep.final_relres, rep.converged, rep.failures
    );
    println!("{}", decision_table(&rep).to_text());
    assert!(rep.converged);
    assert!(
        rep.decisions.iter().all(|d| d.decision != "global-restart"),
        "rs2's two-erasure solve turns the forced restart into in-situ recovery"
    );

    println!(
        "group-failure walkthrough passed: in-situ parity reconstruction for isolated \
         losses, recorded global-restart escalation for correlated in-group losses under \
         xor:4, and in-situ double-fault recovery under rs2:4"
    );
    Ok(())
}
