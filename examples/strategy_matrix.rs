//! Strategy decision matrix: the paper's conclusion is that shrink and
//! substitute "may be flexibly applied on an application-specific basis" —
//! this example produces the decision table for one workload: every
//! strategy (including cold spares, §IV-A) x failure count, with the
//! overhead decomposition that drives the choice.
//!
//! Run with: `cargo run --release --example strategy_matrix [p]`

use ulfm_ftgmres::config::RunConfig;
use ulfm_ftgmres::coordinator;
use ulfm_ftgmres::problem::Grid3D;
use ulfm_ftgmres::recovery::Strategy;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let p: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(16);

    let mut cfg = RunConfig::default();
    cfg.grid = Grid3D { nx: 16, ny: 16, nz: 48 };
    cfg.p = p;
    cfg.solver.tol = 1e-10;
    // Short inner solves compress the kill schedule so that even the
    // 4-failure campaign completes before convergence on this small grid.
    cfg.solver.m_inner = 15;

    let mut base = cfg.clone();
    base.strategy = Strategy::NoProtection;
    base.failures = 0;
    let baseline = coordinator::run(&base)?;
    println!(
        "p = {p}, {} rows; baseline (no protection) tts = {:.4}s\n",
        cfg.grid.n(),
        baseline.time_to_solution
    );
    println!(
        "{:<16} {:>2} {:>9} {:>9} {:>8} {:>8} {:>9} {:>9}",
        "strategy", "f", "tts[s]", "slowdown", "ckpt%", "recov%", "reconfig%", "recomp%"
    );

    for strategy in [Strategy::Shrink, Strategy::Substitute, Strategy::SubstituteCold] {
        for failures in [1usize, 2, 4] {
            let mut c = cfg.clone();
            c.strategy = strategy;
            c.failures = failures;
            let rep = coordinator::run(&c)?;
            assert!(rep.converged, "{} f={failures}", strategy.name());
            let pct = |v: f64| 100.0 * v / rep.time_to_solution;
            println!(
                "{:<16} {:>2} {:>9.4} {:>9.3} {:>8.2} {:>8.2} {:>9.2} {:>8.2}",
                strategy.name(),
                failures,
                rep.time_to_solution,
                rep.time_to_solution / baseline.time_to_solution,
                pct(rep.max_phases.checkpoint),
                pct(rep.max_phases.recovery),
                pct(rep.max_phases.reconfig),
                pct(rep.max_phases.recompute),
            );
        }
        println!();
    }
    println!(
        "Reading the table: shrink needs no spare resources but its slowdown\n\
         grows with workload-per-survivor; warm substitution restores the\n\
         original configuration at the cost of idle spares; cold substitution\n\
         avoids idle resources but pays the spawn latency in reconfiguration\n\
         (paper SIV-A) — prohibitive when failures are frequent."
    );
    Ok(())
}
