//! Adaptive recovery policy walkthrough: inject *more* failures than warm
//! spares and watch the `spares-first` policy substitute while the pool
//! lasts, then degrade gracefully to shrink — the paper's §IV tradeoff
//! decided per failure event at runtime instead of per run.
//!
//! Run with: `cargo run --release --example adaptive_policy`

use ulfm_ftgmres::config::RunConfig;
use ulfm_ftgmres::coordinator;
use ulfm_ftgmres::failure::InjectionPlan;
use ulfm_ftgmres::figures::decision_table;
use ulfm_ftgmres::problem::Grid3D;

fn main() -> anyhow::Result<()> {
    let mut cfg = RunConfig::default();
    cfg.grid = Grid3D::cube(16);
    cfg.p = 8;
    cfg.failures = 3;
    // One warm spare against three failures: the pool WILL run dry.
    cfg.warm_spares = Some(1);
    anyhow::ensure!(cfg.set("policy", "spares-first")?, "policy key");
    // Short inner solves compress the kill schedule (kills at iterations
    // 25, 35, 45) so the run stays seconds-scale.
    cfg.solver.m_inner = 10;
    cfg.solver.m_outer = 20;
    cfg.solver.max_cycles = 20;
    cfg.solver.tol = 1e-10;

    println!(
        "p = {} ranks, warm spares = {}, injected failures = {}, policy = {}",
        cfg.p,
        cfg.warm_spare_count(),
        cfg.failures,
        cfg.policy().name()
    );

    // A dense back-to-back campaign (one checkpoint window apart) so the
    // pool is exhausted mid-run, not at the end.
    let plan = InjectionPlan::exhaustion_campaign(cfg.p, cfg.failures, cfg.solver.m_inner as u64);
    let backend = coordinator::make_backend(&cfg)?;
    let rep = coordinator::run_custom(&cfg, backend, plan)?;

    println!(
        "\nconverged = {}  relres = {:.3e}  iterations = {}  failures = {}",
        rep.converged, rep.final_relres, rep.iterations, rep.failures
    );
    println!("virtual time-to-solution = {:.4}s\n", rep.time_to_solution);
    println!("{}", decision_table(&rep).to_text());

    // The hybrid timeline the fixed strategies cannot express: substitute
    // while a spare is free, shrink afterwards.
    assert!(rep.converged, "adaptive run must converge");
    let names: Vec<&str> = rep.decisions.iter().map(|d| d.decision).collect();
    assert_eq!(names.first(), Some(&"substitute"), "decisions: {names:?}");
    let first_shrink = names.iter().position(|&n| n == "shrink");
    assert!(
        first_shrink.is_some_and(|i| i >= 1),
        "expected a shrink decision after pool exhaustion, got {names:?}"
    );
    println!(
        "hybrid run: {} substitution(s) while the pool lasted, then {} shrink(s)",
        names.iter().filter(|&&n| n == "substitute").count(),
        names.iter().filter(|&&n| n == "shrink").count()
    );
    println!("\nOK");
    Ok(())
}
