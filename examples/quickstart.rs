//! Quickstart: solve a small 3D Poisson system with FT-GMRES on a simulated
//! 8-rank cluster, survive one injected process failure via *shrink*
//! recovery, and print the overhead breakdown.
//!
//! Run with: `cargo run --release --example quickstart`

use ulfm_ftgmres::config::RunConfig;
use ulfm_ftgmres::coordinator;
use ulfm_ftgmres::problem::Grid3D;
use ulfm_ftgmres::recovery::Strategy;

fn main() -> anyhow::Result<()> {
    let mut cfg = RunConfig::default();
    cfg.grid = Grid3D::cube(16);
    cfg.p = 8;
    cfg.strategy = Strategy::Shrink;
    cfg.failures = 1;
    cfg.solver.tol = 1e-10;

    println!(
        "solving a {} x {} x {} Poisson system ({} rows) on {} ranks, \
         injecting {} failure(s), strategy = {}",
        cfg.grid.nx,
        cfg.grid.ny,
        cfg.grid.nz,
        cfg.grid.n(),
        cfg.p,
        cfg.failures,
        cfg.strategy.name()
    );

    let rep = coordinator::run(&cfg)?;

    println!(
        "\nconverged = {}  relres = {:.3e}  inner iterations = {}  failures = {}",
        rep.converged, rep.final_relres, rep.iterations, rep.failures
    );
    println!("virtual time-to-solution = {:.4}s", rep.time_to_solution);
    let m = &rep.max_phases;
    let pct = |v: f64| 100.0 * v / rep.time_to_solution;
    println!("  compute    {:8.4}s ({:5.2}%)", m.compute, pct(m.compute));
    println!("  comm       {:8.4}s ({:5.2}%)", m.comm, pct(m.comm));
    println!("  checkpoint {:8.4}s ({:5.2}%)", m.checkpoint, pct(m.checkpoint));
    println!("  recovery   {:8.4}s ({:5.2}%)", m.recovery, pct(m.recovery));
    println!("  reconfig   {:8.4}s ({:5.2}%)", m.reconfig, pct(m.reconfig));
    println!("  recompute  {:8.4}s ({:5.2}%)", m.recompute, pct(m.recompute));

    assert!(rep.converged, "quickstart must converge");
    println!("\nOK");
    Ok(())
}
