//! END-TO-END DRIVER: the full three-layer stack on a real workload.
//!
//! * L1/L2: the Pallas ELL-SpMV kernel and the JAX solver step graphs,
//!   AOT-lowered to `artifacts/*.hlo.txt` by `make artifacts` (Python runs
//!   once, never here);
//! * runtime: the Rust PJRT engine loads and executes those artifacts;
//! * L3: the ULFM coordinator runs a distributed FT-GMRES solve across
//!   simulated ranks, injects a real process failure mid-solve, repairs the
//!   communicator with *substitute* (warm spare), restores state from
//!   in-memory buddy checkpoints, and converges.
//!
//! The wall-clock numbers below are *measured* PJRT execution (not the cost
//! model): this is the run recorded in EXPERIMENTS.md §End-to-end.
//!
//! Run with: `make artifacts && cargo run --release --example e2e_pjrt_solve`

use std::time::Instant;

use ulfm_ftgmres::config::{BackendKind, RunConfig};
use ulfm_ftgmres::coordinator;
use ulfm_ftgmres::problem::Grid3D;
use ulfm_ftgmres::recovery::Strategy;

fn main() -> anyhow::Result<()> {
    let mut cfg = RunConfig::default();
    cfg.grid = Grid3D { nx: 24, ny: 24, nz: 48 }; // 27,648 rows, ~187k nnz
    cfg.p = 8;
    cfg.strategy = Strategy::Substitute;
    cfg.failures = 1;
    cfg.solver.tol = 1e-9;
    cfg.backend = BackendKind::Pjrt;
    cfg.pjrt_measured = true; // charge measured wall time of the artifacts
    cfg.artifacts_dir = if std::path::Path::new("artifacts/manifest.tsv").exists() {
        "artifacts".into()
    } else {
        "../artifacts".into()
    };

    println!("=== end-to-end: JAX/Pallas artifacts -> PJRT -> ULFM coordinator ===");
    println!(
        "problem: {}x{}x{} Poisson ({} rows, {} nnz), p = {}, strategy = {}, failures = {}",
        cfg.grid.nx,
        cfg.grid.ny,
        cfg.grid.nz,
        cfg.grid.n(),
        cfg.grid.nnz(),
        cfg.p,
        cfg.strategy.name(),
        cfg.failures
    );

    let t0 = Instant::now();
    let rep = coordinator::run(&cfg)?;
    let wall = t0.elapsed().as_secs_f64();

    println!(
        "\nconverged = {}  relres = {:.3e}  inner iterations = {}  failures survived = {}",
        rep.converged, rep.final_relres, rep.iterations, rep.failures
    );
    println!("wall time (real PJRT execution): {wall:.2}s");
    println!(
        "virtual time-to-solution (measured kernel time + modeled network): {:.4}s",
        rep.time_to_solution
    );
    let m = &rep.max_phases;
    println!(
        "phases [s]: compute={:.4} comm={:.4} checkpoint={:.4} recovery={:.4} reconfig={:.6} recompute={:.4}",
        m.compute, m.comm, m.checkpoint, m.recovery, m.reconfig, m.recompute
    );
    let spare_used = rep.ranks.iter().any(|r| r.was_spare && r.iterations > 0);
    println!(
        "spare adopted = {spare_used}; per-iteration kernel throughput = {:.1} iters/s (wall)",
        rep.iterations as f64 / wall
    );

    assert!(rep.converged, "e2e solve must converge");
    assert_eq!(rep.failures, 1, "the injected failure must fire");
    assert!(spare_used, "substitute must adopt the spare");
    println!("\nE2E OK — all three layers composed.");
    Ok(())
}
