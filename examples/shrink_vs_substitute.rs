//! Shrink vs Substitute head-to-head (the paper's core comparison): same
//! problem, same failure campaign, both strategies plus the no-protection
//! baseline, printed as a normalized table.
//!
//! Run with: `cargo run --release --example shrink_vs_substitute [p] [failures]`

use ulfm_ftgmres::config::RunConfig;
use ulfm_ftgmres::coordinator;
use ulfm_ftgmres::metrics::RunReport;
use ulfm_ftgmres::problem::Grid3D;
use ulfm_ftgmres::recovery::Strategy;

fn leg(cfg: &RunConfig, strategy: Strategy, failures: usize) -> anyhow::Result<RunReport> {
    let mut c = cfg.clone();
    c.strategy = strategy;
    c.failures = failures;
    coordinator::run(&c)
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let p: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(16);
    let failures: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(2);

    let mut cfg = RunConfig::default();
    cfg.grid = Grid3D { nx: 16, ny: 16, nz: 48 };
    cfg.p = p;
    cfg.solver.tol = 1e-10;

    println!(
        "p = {p}, failures = {failures}, grid = {}x{}x{} ({} rows)\n",
        cfg.grid.nx, cfg.grid.ny, cfg.grid.nz, cfg.grid.n()
    );

    let base = leg(&cfg, Strategy::NoProtection, 0)?;
    println!("{:<14} {:>9} {:>9} {:>10} {:>10} {:>10} {:>9}",
             "strategy", "tts[s]", "slowdown", "ckpt[s]", "recov[s]", "reconf[s]", "iters");
    println!("{:<14} {:>9.4} {:>9.3} {:>10.4} {:>10.4} {:>10.6} {:>9}",
             "no-protection", base.time_to_solution, 1.0, 0.0, 0.0, 0.0, base.iterations);

    for strategy in [Strategy::Shrink, Strategy::Substitute] {
        let rep = leg(&cfg, strategy, failures)?;
        assert!(rep.converged, "{} failed to converge", strategy.name());
        println!(
            "{:<14} {:>9.4} {:>9.3} {:>10.4} {:>10.4} {:>10.6} {:>9}",
            strategy.name(),
            rep.time_to_solution,
            rep.time_to_solution / base.time_to_solution,
            rep.max_phases.checkpoint,
            rep.max_phases.recovery,
            rep.max_phases.reconfig,
            rep.iterations,
        );
    }
    println!(
        "\nBoth strategies converge to the same tolerance; the overheads\n\
         differ exactly along the axes the paper's Figures 4-6 plot."
    );
    Ok(())
}
