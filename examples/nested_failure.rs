//! Failures *during* recovery (DESIGN.md §10): the epoch-fenced
//! restartable recovery protocol survives a second rank dying in the
//! middle of the first failure's recovery — mid-reconstruction on the
//! shrink path, and mid-join on the substitute path (a spare lease that
//! rolls back when the joiner dies before activation).  Both legs must
//! complete **in situ**: zero executed global restarts, a converged solve,
//! and the retries visible in the decision log's `attempt` column.
//!
//! ```sh
//! cargo run --release --example nested_failure
//! ```
//!
//! The same campaigns are reachable from the CLI via `--inject-phase`,
//! e.g. `ftgmres run p=8 failures=1 ckpt_scheme=xor:4 --inject-phase
//! 3:reconstruct`.

use std::sync::Arc;

use ulfm_ftgmres::backend::native::NativeBackend;
use ulfm_ftgmres::ckptstore::Scheme;
use ulfm_ftgmres::config::RunConfig;
use ulfm_ftgmres::coordinator;
use ulfm_ftgmres::failure::{InjectionPlan, ProtoPhase};
use ulfm_ftgmres::figures::decision_table;
use ulfm_ftgmres::problem::Grid3D;
use ulfm_ftgmres::recovery::Strategy;

fn base_cfg() -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.grid = Grid3D::cube(12);
    cfg.p = 8;
    cfg.solver.tol = 1e-10;
    cfg.solver.m_inner = 10;
    cfg.solver.m_outer = 20;
    cfg.solver.max_cycles = 20;
    cfg
}

fn main() -> anyhow::Result<()> {
    // --- Leg 1: shrink recovery poisoned at the reconstruction read ---
    // Rank 7 (xor:4 parity group 1) dies at iteration 25; rank 3 (group 0)
    // dies entering the reconstruction of that recovery.  The union is one
    // loss per group — still recoverable — so the fence must retry and
    // finish without a restart.
    println!("# leg 1: shrink, second failure at Phase::Reconstruct");
    let mut cfg = base_cfg();
    cfg.strategy = Strategy::Shrink;
    cfg.solver.ckpt.scheme = Scheme::Xor { g: 4 };
    let backend = Arc::new(NativeBackend::new(cfg.compute.clone()));
    let plan = InjectionPlan::nested(7, 25, 3, ProtoPhase::Reconstruct, 1);
    let rep = coordinator::run_custom(&cfg, backend.clone(), plan)?;
    println!(
        "tts={:.4}s iters={} relres={:.2e} converged={} failures={} epoch_retries={}",
        rep.time_to_solution,
        rep.iterations,
        rep.final_relres,
        rep.converged,
        rep.failures,
        rep.recovery_retries,
    );
    println!("{}", decision_table(&rep).to_text());
    assert!(rep.converged);
    assert_eq!(rep.global_restarts(), 0, "recoverable nested pattern must not restart");
    assert!(rep.recovery_retries >= 1, "the poisoned attempt was fenced and retried");

    // --- Leg 2: substitute recovery poisoned at the spare join ---
    // Rank 5 dies at iteration 25; the first warm spare (world rank 8)
    // dies entering its join, before the lease activates.  The retry
    // re-derives availability from the registry and stitches spare 9.
    println!("# leg 2: substitute, second failure at Phase::SpareJoin");
    let mut cfg = base_cfg();
    cfg.strategy = Strategy::Substitute;
    cfg.failures = 1;
    cfg.warm_spares = Some(2);
    let backend = Arc::new(NativeBackend::new(cfg.compute.clone()));
    let plan = InjectionPlan::nested(5, 25, 8, ProtoPhase::SpareJoin, 1);
    let rep = coordinator::run_custom(&cfg, backend, plan)?;
    println!(
        "tts={:.4}s iters={} relres={:.2e} converged={} failures={} epoch_retries={}",
        rep.time_to_solution,
        rep.iterations,
        rep.final_relres,
        rep.converged,
        rep.failures,
        rep.recovery_retries,
    );
    println!("{}", decision_table(&rep).to_text());
    assert!(rep.converged);
    assert_eq!(rep.global_restarts(), 0);
    assert!(rep.recovery_retries >= 1, "the interrupted join was fenced and retried");
    assert_eq!(rep.decisions.len(), 1);
    assert_eq!(rep.decisions[0].decision, "substitute");
    let adopted = rep
        .ranks
        .iter()
        .find(|r| r.world_rank == 9)
        .expect("second spare in the report");
    assert!(
        adopted.was_spare && !adopted.killed && adopted.iterations > 0,
        "spare 9 took over after spare 8's lease rolled back"
    );

    println!("nested-failure legs complete: in-situ recovery survived failures during recovery");
    Ok(())
}
