//! Multi-failure sustainability (paper §VI: "we inject up to four
//! independent process failures"): sweep 0..=4 failures for both in-situ
//! strategies and show that overheads compose additively — the property the
//! paper uses to extrapolate multi-failure cost from single-failure runs.
//!
//! Run with: `cargo run --release --example multi_failure_campaign [p]`

use ulfm_ftgmres::config::RunConfig;
use ulfm_ftgmres::coordinator;
use ulfm_ftgmres::problem::Grid3D;
use ulfm_ftgmres::recovery::Strategy;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let p: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(16);

    let mut cfg = RunConfig::default();
    cfg.grid = Grid3D { nx: 16, ny: 16, nz: 48 };
    cfg.p = p;
    cfg.solver.tol = 1e-10;

    println!("p = {p}, grid = {} rows; sweeping failures 0..=4\n", cfg.grid.n());

    for strategy in [Strategy::Shrink, Strategy::Substitute] {
        println!("--- {} ---", strategy.name());
        println!(
            "{:>8} {:>9} {:>10} {:>10} {:>12} {:>9}",
            "failures", "tts[s]", "recov[s]", "recov/f1", "recompute[s]", "iters"
        );
        let mut recov1 = None;
        for failures in 0..=4usize {
            let mut c = cfg.clone();
            c.strategy = strategy;
            c.failures = failures;
            let rep = coordinator::run(&c)?;
            assert!(rep.converged);
            if failures == 1 {
                recov1 = Some(rep.max_phases.recovery);
            }
            let norm = match (failures, recov1) {
                (0, _) | (_, None) => "-".to_string(),
                (_, Some(r1)) => format!("{:.2}", rep.max_phases.recovery / r1),
            };
            println!(
                "{:>8} {:>9.4} {:>10.4} {:>10} {:>12.4} {:>9}",
                failures,
                rep.time_to_solution,
                rep.max_phases.recovery,
                norm,
                rep.max_phases.recompute,
                rep.iterations,
            );
        }
        println!();
    }
    println!(
        "recov/f1 tracks the failure count (paper Fig. 6: \"it is relatively\n\
         straightforward to estimate the overheads for multiple failures from\n\
         the recovery costs of a single failure\")."
    );
    Ok(())
}
