//! Compile-time stub of the `xla-rs` PJRT surface.
//!
//! The offline build environment has no XLA/PJRT shared libraries, so this
//! crate provides the exact API shape `crate::runtime` compiles against
//! while reporting the client as unavailable at runtime:
//! [`PjRtClient::cpu`] returns an error, which the runtime service thread
//! turns into per-request error replies.  Callers already gate PJRT work on
//! artifact presence, so the native backend (the default) is unaffected.
//!
//! Swapping in the real `xla` crate re-enables the PJRT path without any
//! source change — only this path dependency goes away.

use std::fmt;

/// Error type shared by every stubbed operation.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: XLA/PJRT is unavailable in this build (vendored stub; \
             use the native backend or link the real xla crate)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Parsed HLO module text (stub: never constructed successfully).
#[derive(Debug)]
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(path: &str) -> Result<HloModuleProto> {
        let _ = path;
        Err(Error::unavailable("HloModuleProto::from_text_file"))
    }
}

/// An XLA computation built from an HLO proto.
#[derive(Debug)]
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Device-resident buffer handle.
#[derive(Debug)]
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Host-side literal value.
#[derive(Debug)]
pub struct Literal;

impl Literal {
    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    pub fn to_tuple2(&self) -> Result<(Literal, Literal)> {
        Err(Error::unavailable("Literal::to_tuple2"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Compiled, device-loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b(&self, args: &[&PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let _ = args;
        Err(Error::unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// PJRT client handle (stub: construction always fails).
#[derive(Debug)]
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T>(
        &self,
        data: &[T],
        dims: &[usize],
        device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let _ = (data, dims, device);
        Err(Error::unavailable("PjRtClient::buffer_from_host_buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("unavailable"));
    }
}
