//! Offline, dependency-free subset of the `anyhow` crate API.
//!
//! The build environment has no crates.io access, so this vendored shim
//! provides exactly the surface the workspace uses: [`Error`], [`Result`],
//! and the [`anyhow!`], [`bail!`] and [`ensure!`] macros.  Semantics match
//! upstream for that subset: any `std::error::Error + Send + Sync` value
//! converts into [`Error`] via `?`, and `Error` intentionally does *not*
//! implement `std::error::Error` itself (just like upstream, which is what
//! keeps the blanket `From` impl coherent).

use std::fmt;

/// A type-erased error, convertible from any standard error type.
pub struct Error(Box<dyn std::error::Error + Send + Sync + 'static>);

/// `Result<T, anyhow::Error>` with an overridable error type, mirroring
/// upstream's signature.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Create an error from a printable message (what [`anyhow!`] expands to).
    pub fn msg<M>(message: M) -> Error
    where
        M: fmt::Display + fmt::Debug + Send + Sync + 'static,
    {
        struct MessageError<M>(M);
        impl<M: fmt::Display> fmt::Display for MessageError<M> {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                fmt::Display::fmt(&self.0, f)
            }
        }
        impl<M: fmt::Debug> fmt::Debug for MessageError<M> {
            fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
                fmt::Debug::fmt(&self.0, f)
            }
        }
        impl<M: fmt::Display + fmt::Debug> std::error::Error for MessageError<M> {}
        Error(Box::new(MessageError(message)))
    }

    /// Wrap a concrete error value.
    pub fn new<E>(error: E) -> Error
    where
        E: std::error::Error + Send + Sync + 'static,
    {
        Error(Box::new(error))
    }

    /// Borrow the underlying error.
    pub fn as_dyn(&self) -> &(dyn std::error::Error + Send + Sync + 'static) {
        &*self.0
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(error: E) -> Error {
        Error(Box::new(error))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        fmt::Display::fmt(&self.0, f)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // Match upstream: Debug prints the message plus the source chain.
        write!(f, "{}", self.0)?;
        let mut source = self.0.source();
        if source.is_some() {
            write!(f, "\n\nCaused by:")?;
        }
        while let Some(cause) = source {
            write!(f, "\n    {cause}")?;
            source = cause.source();
        }
        Ok(())
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg(format!("{}", $err))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: `{}`", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<i32> {
        Ok(s.parse::<i32>()?)
    }

    #[test]
    fn question_mark_converts_std_errors() {
        assert_eq!(parse("42").unwrap(), 42);
        let err = parse("nope").unwrap_err();
        assert!(err.to_string().contains("invalid digit"));
    }

    #[test]
    fn macros_build_messages() {
        let e = anyhow!("bad value {}", 7);
        assert_eq!(e.to_string(), "bad value 7");
        let v = 9;
        let e = anyhow!("inline capture {v}");
        assert_eq!(e.to_string(), "inline capture 9");

        fn fails() -> Result<()> {
            bail!("nope {}", 1);
        }
        assert_eq!(fails().unwrap_err().to_string(), "nope 1");

        fn checked(x: i32) -> Result<i32> {
            ensure!(x > 0, "x must be positive, got {x}");
            Ok(x)
        }
        assert!(checked(1).is_ok());
        assert_eq!(checked(-1).unwrap_err().to_string(), "x must be positive, got -1");
    }
}
