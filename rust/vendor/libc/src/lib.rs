//! Offline `libc` subset: exactly the allocator-tuning surface the PJRT
//! runtime service thread uses (`mallopt` with the mmap/trim thresholds).
//!
//! On glibc targets this calls the real `mallopt`; elsewhere it is a no-op
//! that reports success, so the tuning degrades gracefully instead of
//! failing to link.

#![allow(non_camel_case_types)]

pub type c_int = i32;

/// glibc `M_MMAP_THRESHOLD` mallopt parameter.
pub const M_MMAP_THRESHOLD: c_int = -3;
/// glibc `M_TRIM_THRESHOLD` mallopt parameter.
pub const M_TRIM_THRESHOLD: c_int = -1;

#[cfg(all(target_os = "linux", target_env = "gnu"))]
mod imp {
    use super::c_int;
    extern "C" {
        #[link_name = "mallopt"]
        fn glibc_mallopt(param: c_int, value: c_int) -> c_int;
    }
    pub unsafe fn mallopt(param: c_int, value: c_int) -> c_int {
        glibc_mallopt(param, value)
    }
}

#[cfg(not(all(target_os = "linux", target_env = "gnu")))]
mod imp {
    use super::c_int;
    /// No glibc: accept and ignore the hint (1 = success, as glibc returns).
    pub unsafe fn mallopt(_param: c_int, _value: c_int) -> c_int {
        1
    }
}

/// Tune a glibc malloc parameter.  Returns 1 on success (glibc convention).
///
/// # Safety
/// Directly adjusts process-global allocator state; callers must uphold the
/// same contract as the C `mallopt`.
pub unsafe fn mallopt(param: c_int, value: c_int) -> c_int {
    imp::mallopt(param, value)
}
