//! Native-vs-PJRT backend equivalence: the AOT HLO artifacts must produce
//! the same numerics as the pure-Rust kernels, op by op and end-to-end.
//!
//! Requires `artifacts/` (run `make artifacts`); tests are skipped with a
//! message if the manifest is missing (e.g., a cargo-only environment).

mod common;

use std::path::Path;
use std::sync::Arc;

use common::{quick_config, Rng};
use ulfm_ftgmres::backend::native::NativeBackend;
use ulfm_ftgmres::backend::{Backend, DenseBasis};
use ulfm_ftgmres::config::BackendKind;
use ulfm_ftgmres::coordinator;
use ulfm_ftgmres::netsim::ComputeModel;
use ulfm_ftgmres::problem::{EllBlock, Grid3D, MatrixRows, Partition};
use ulfm_ftgmres::recovery::Strategy;
use ulfm_ftgmres::runtime::PjrtEngine;

fn artifacts_dir() -> Option<&'static Path> {
    // Tests run from the crate root (rust/); artifacts live one level up.
    for p in ["../artifacts", "artifacts"] {
        let path = Path::new(p);
        if path.join("manifest.tsv").exists() {
            return Some(Box::leak(path.to_path_buf().into_boxed_path()));
        }
    }
    None
}

fn engine() -> Option<PjrtEngine> {
    let dir = artifacts_dir()?;
    Some(PjrtEngine::load(dir, ComputeModel::default(), false).expect("load artifacts"))
}

fn close(a: &[f64], b: &[f64], tol: f64, what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + x.abs().max(y.abs())),
            "{what}[{i}]: {x} vs {y}"
        );
    }
}

#[test]
fn ops_match_native_exactly() {
    let Some(eng) = engine() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let native = NativeBackend::default();
    let mut rng = Rng::new(11);

    // A real localized block (not just random data): 6^3 grid, 2 ranks.
    let g = Grid3D::cube(6);
    let part = Partition::balanced(g.n(), 2);
    let range = part.range(0);
    let mat = MatrixRows::generate(&g, range.start, range.len());
    let blk = EllBlock::build(&mat, &part, 0);

    let xh: Vec<f64> = (0..blk.x_halo_len()).map(|_| rng.f64()).collect();
    let mut y_n = vec![0.0; blk.rows];
    let mut y_p = vec![0.0; blk.rows];
    native.spmv(&blk, &xh, &mut y_n);
    eng.spmv(&blk, &xh, &mut y_p);
    close(&y_n, &y_p, 1e-13, "spmv");

    // Basis ops at the artifact's M = 26.
    let r = blk.rows;
    let mut v = DenseBasis::zeros(26, r);
    for j in 0..26 {
        for i in 0..r {
            v.row_mut(j)[i] = rng.f64();
        }
    }
    let w: Vec<f64> = (0..r).map(|_| rng.f64()).collect();
    for m_used in [1usize, 5, 26] {
        let mut h_n = vec![0.0; 26];
        let mut h_p = vec![0.0; 26];
        native.dot_partials(&v, m_used, &w, &mut h_n);
        eng.dot_partials(&v, m_used, &w, &mut h_p);
        close(&h_n, &h_p, 1e-12, "dot_partials");

        let mut wn = w.clone();
        let mut wp = w.clone();
        let (nsq_n, _) = native.update_w(&v, m_used, &mut wn, &h_n);
        let (nsq_p, _) = eng.update_w(&v, m_used, &mut wp, &h_p);
        close(&wn, &wp, 1e-12, "update_w");
        assert!((nsq_n - nsq_p).abs() < 1e-10 * (1.0 + nsq_n));

        let mut xn = w.clone();
        let mut xp = w.clone();
        native.update_x(&v, m_used, &h_n, &mut xn);
        eng.update_x(&v, m_used, &h_p, &mut xp);
        close(&xn, &xp, 1e-12, "update_x");
    }

    let mut sn = w.clone();
    let mut sp = w.clone();
    native.scale(&mut sn, 0.37);
    eng.scale(&mut sp, 0.37);
    close(&sn, &sp, 1e-15, "scale");
}

#[test]
fn full_solve_matches_native_backend() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    // PJRT artifacts are fixed at M=26, so use the default m=25 solver
    // shape on a small grid.
    let mut cfg = quick_config(2, Strategy::NoProtection, 0);
    cfg.grid = Grid3D::cube(8);
    cfg.solver.m_inner = 25;
    cfg.solver.m_outer = 25;
    cfg.solver.max_cycles = 8;
    let native_rep = coordinator::run(&cfg).unwrap();

    let mut pcfg = cfg.clone();
    pcfg.backend = BackendKind::Pjrt;
    pcfg.artifacts_dir = dir.to_string_lossy().into_owned();
    let eng = coordinator::make_backend(&pcfg).unwrap();
    let pjrt_rep = coordinator::run_with_backend(&pcfg, Arc::clone(&eng)).unwrap();

    assert!(native_rep.converged && pjrt_rep.converged);
    assert_eq!(native_rep.iterations, pjrt_rep.iterations, "same iteration path");
    let rel_diff = (native_rep.final_relres - pjrt_rep.final_relres).abs()
        / native_rep.final_relres.max(1e-300);
    assert!(rel_diff < 1e-3, "residuals close: {} vs {}",
        native_rep.final_relres, pjrt_rep.final_relres);
}

#[test]
fn pjrt_solve_with_failure_recovers() {
    let Some(dir) = artifacts_dir() else {
        eprintln!("skipping: artifacts/ not built");
        return;
    };
    let mut cfg = quick_config(4, Strategy::Shrink, 1);
    cfg.grid = Grid3D::cube(12);
    cfg.solver.m_inner = 25;
    cfg.solver.m_outer = 25;
    cfg.solver.tol = 1e-10;
    cfg.backend = BackendKind::Pjrt;
    cfg.artifacts_dir = dir.to_string_lossy().into_owned();
    // Kill schedule for m_inner=25 fires at iteration 62; the 12^3 problem
    // at 1e-10 runs ~75+ iterations, so the kill lands.
    let rep = coordinator::run(&cfg).unwrap();
    assert!(rep.converged);
    assert_eq!(rep.failures, 1, "kill fired on the PJRT path");
}
