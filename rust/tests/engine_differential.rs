//! Differential test of the two execution engines (DESIGN.md §12): the
//! deterministic event loop (`--engine events`) must be observationally
//! indistinguishable from the thread-per-rank oracle (`--engine threads`).
//!
//! Virtual time lives entirely in message timestamps and per-rank clocks,
//! never in OS scheduling, so the event loop is just one valid
//! serialization of the same distributed execution: every campaign shape —
//! redundancy scheme × delta/compression × recovery strategy × nested
//! protocol-phase kills — must produce a bit-identical `RunReport` digest
//! under both engines.
//!
//! Every leg also runs traced (DESIGN.md §13) and asserts the exported
//! Perfetto trace JSON is **byte-identical** across engines: spans, message
//! edges and flow ids are pure functions of virtual time, so the trace file
//! is part of the observational-equivalence contract.

mod common;

use common::{digest, quick_config};
use ulfm_ftgmres::ckptstore::Scheme;
use ulfm_ftgmres::config::RunConfig;
use ulfm_ftgmres::coordinator;
use ulfm_ftgmres::failure::{BitFlip, InjectionPlan, Kill, LinkFault, ProtoPhase, Straggler};
use ulfm_ftgmres::metrics::RunReport;
use ulfm_ftgmres::recovery::Strategy;
use ulfm_ftgmres::simmpi::Engine;

fn run_engine(cfg: &RunConfig, plan: &InjectionPlan, engine: Engine) -> (RunReport, String) {
    let mut cfg = cfg.clone();
    cfg.engine = engine;
    cfg.trace = true;
    let backend = coordinator::make_backend(&cfg).unwrap();
    let rep = coordinator::run_custom(&cfg, backend, plan.clone()).unwrap();
    let trace = ulfm_ftgmres::trace::perfetto_json(&rep, &cfg);
    (rep, trace)
}

/// Run one campaign under both engines and assert digest equality plus
/// byte-identical trace exports.
fn assert_engines_agree(name: &str, cfg: &RunConfig, plan: &InjectionPlan) -> RunReport {
    let (threads, threads_trace) = run_engine(cfg, plan, Engine::Threads);
    let (events, events_trace) = run_engine(cfg, plan, Engine::Events);
    assert_eq!(
        digest(&threads),
        digest(&events),
        "{name}: event engine diverged from the thread oracle"
    );
    assert_eq!(
        threads_trace, events_trace,
        "{name}: trace files diverged across engines"
    );
    events
}

#[test]
fn engines_agree_failure_free() {
    let cfg = quick_config(4, Strategy::NoProtection, 0);
    let rep = assert_engines_agree("failure-free", &cfg, &InjectionPlan::none());
    assert!(rep.converged);
}

#[test]
fn engines_agree_on_checkpointed_run_without_failures() {
    let cfg = quick_config(4, Strategy::Shrink, 0);
    let rep = assert_engines_agree("ckpt-only", &cfg, &InjectionPlan::none());
    assert!(rep.converged && !rep.ckpt.is_empty());
}

#[test]
fn engines_agree_shrink_multi_failure() {
    let cfg = quick_config(8, Strategy::Shrink, 3);
    let rep = assert_engines_agree("shrink-3f", &cfg, &cfg.injection_plan());
    assert_eq!(rep.failures, 3);
    assert!(rep.converged);
}

#[test]
fn engines_agree_substitute_with_spares() {
    let cfg = quick_config(8, Strategy::Substitute, 2);
    let rep = assert_engines_agree("substitute-2f", &cfg, &cfg.injection_plan());
    assert_eq!(rep.failures, 2);
    assert!(rep.converged);
    assert!(rep.ranks.iter().any(|r| r.was_spare && r.iterations > 0));
}

#[test]
fn engines_agree_cold_spares() {
    let cfg = quick_config(6, Strategy::SubstituteCold, 1);
    let rep = assert_engines_agree("substitute-cold", &cfg, &cfg.injection_plan());
    assert!(rep.converged);
}

/// The full campaign matrix from the transport-equivalence suite: every
/// redundancy scheme, delta + compression on, and a *nested* second kill
/// inside the first recovery (protocol-phase injection).  These are the
/// hardest schedules the repo knows how to produce: if the event engine
/// serializes anything differently, the fence retries, decision log or
/// checkpoint accounting shift and the digests split.
#[test]
fn engines_agree_nested_failures_all_schemes() {
    let legs: Vec<(Scheme, Strategy, Option<usize>, ProtoPhase, usize)> = vec![
        (Scheme::Mirror { k: 1 }, Strategy::Shrink, None, ProtoPhase::Reconstruct, 3),
        (Scheme::Xor { g: 4 }, Strategy::Shrink, None, ProtoPhase::Reconstruct, 3),
        (Scheme::Rs2 { g: 4 }, Strategy::Substitute, Some(2), ProtoPhase::SpareJoin, 8),
    ];
    for (scheme, strategy, warm, phase, second) in legs {
        let mut cfg = quick_config(8, strategy, 0);
        cfg.warm_spares = warm;
        cfg.solver.ckpt.scheme = scheme;
        cfg.solver.ckpt.delta = true;
        cfg.solver.ckpt.compress = true;
        let first = if phase == ProtoPhase::SpareJoin { 5 } else { 7 };
        let plan = InjectionPlan::nested(first, 25, second, phase, 1);
        let rep = assert_engines_agree("nested", &cfg, &plan);
        assert!(rep.converged, "{scheme:?}: nested campaign must converge");
        assert_eq!(rep.global_restarts(), 0, "{scheme:?}: recoverable pattern");
        assert!(rep.recovery_retries >= 1, "{scheme:?}: the nested kill must fence");
    }
}

/// Delta shipping alone (no compression) exercises a different wire format
/// per scheme; keep it differentially pinned too.
#[test]
fn engines_agree_delta_without_compression() {
    for scheme in [Scheme::Mirror { k: 1 }, Scheme::Rs2 { g: 4 }] {
        let mut cfg = quick_config(8, Strategy::Shrink, 2);
        cfg.solver.ckpt.scheme = scheme;
        cfg.solver.ckpt.delta = true;
        let rep = assert_engines_agree("delta", &cfg, &cfg.injection_plan());
        assert!(rep.converged, "{scheme:?}");
        assert_eq!(rep.failures, 2, "{scheme:?}");
    }
}

/// Simultaneous kills at the same iteration: one shrink absorbs both dead
/// ranks; the event engine must discover and agree on the identical set.
#[test]
fn engines_agree_simultaneous_failures() {
    let cfg = quick_config(8, Strategy::Shrink, 0);
    let plan = InjectionPlan {
        kills: vec![
            ulfm_ftgmres::failure::Kill::at_iter(2, 25),
            ulfm_ftgmres::failure::Kill::at_iter(5, 25),
        ],
        ..Default::default()
    };
    let rep = assert_engines_agree("simultaneous", &cfg, &plan);
    assert!(rep.converged);
    assert_eq!(rep.failures, 2);
}

/// Non-blocking commits (`--ckpt-async on`, DESIGN.md §15), failure-free:
/// the publish/drain split moves every redundancy receive one checkpoint
/// window later, so the whole commit-plane schedule shifts — and must
/// shift identically under both engines, down to the trace bytes.
#[test]
fn engines_agree_async_commit_failure_free() {
    let mut cfg = quick_config(4, Strategy::Shrink, 0);
    cfg.solver.ckpt.async_commit = true;
    let rep = assert_engines_agree("async-ckpt-only", &cfg, &InjectionPlan::none());
    assert!(rep.converged && !rep.ckpt.is_empty());
    assert_eq!(rep.global_restarts(), 0);
}

/// Async commits under the paper campaign, per scheme: kills land inside
/// the in-flight window (the window now spans the whole inter-commit
/// interval), so every leg exercises the survivors' cancel-at-recovery
/// path plus the pipelined reconstruction gathers.
#[test]
fn engines_agree_async_commit_all_schemes_under_failures() {
    for scheme in [Scheme::Mirror { k: 1 }, Scheme::Xor { g: 4 }, Scheme::Rs2 { g: 4 }] {
        let mut cfg = quick_config(8, Strategy::Shrink, 2);
        cfg.solver.ckpt.scheme = scheme;
        cfg.solver.ckpt.async_commit = true;
        let rep = assert_engines_agree("async", &cfg, &cfg.injection_plan());
        assert!(rep.converged, "{scheme:?}");
        assert_eq!(rep.failures, 2, "{scheme:?}");
        assert_eq!(rep.global_restarts(), 0, "{scheme:?}: async mode must stay in situ");
    }
}

/// Kills at the two async-only protocol phases: a member dying inside its
/// ship window (`ckpt-ship`), and a nested kill entering the pipelined
/// reconstruction drain (`recon-pipeline`).  Both must serialize
/// identically under both engines.
#[test]
fn engines_agree_async_phase_kills() {
    let mut cfg = quick_config(8, Strategy::Shrink, 0);
    cfg.solver.ckpt.scheme = Scheme::Xor { g: 4 };
    cfg.solver.ckpt.async_commit = true;
    let ship = InjectionPlan {
        kills: vec![Kill::at_phase(5, ProtoPhase::CkptShip, 2)],
        ..Default::default()
    };
    let rep = assert_engines_agree("async-ship-kill", &cfg, &ship);
    assert!(rep.converged);
    assert_eq!(rep.failures, 1);
    assert_eq!(rep.global_restarts(), 0);
    let recon = InjectionPlan::nested(7, 25, 3, ProtoPhase::ReconPipeline, 1);
    let rep = assert_engines_agree("async-recon-pipeline-kill", &cfg, &recon);
    assert!(rep.converged);
    assert_eq!(rep.failures, 2);
    assert_eq!(rep.global_restarts(), 0);
}

/// Fleet leg (DESIGN.md §16): a two-job fleet contending for one warm
/// spare must produce a bit-identical [`FleetReport::digest`] — per-job
/// decision logs, the arbitration ledger, every virtual clock — and
/// byte-identical per-job Perfetto trace exports under both engines.
#[test]
fn engines_agree_on_fleet_campaign() {
    use ulfm_ftgmres::coordinator::fleet::{run_fleet_custom, FleetSpec};
    let mut base = quick_config(8, Strategy::Shrink, 0);
    base.trace = true;
    base.fleet = Some(
        FleetSpec::parse("jobs=urgent,prio=5+batch,prio=1;warm=1;breaker_k=10;breaker_w=1000")
            .unwrap(),
    );
    let kill = |r: usize| InjectionPlan {
        kills: vec![Kill::at_iter(r, 25)],
        ..Default::default()
    };
    let run = |engine: Engine| {
        let mut cfg = base.clone();
        cfg.engine = engine;
        let frep = run_fleet_custom(&cfg, &[kill(2), kill(2)]).unwrap();
        let trace = ulfm_ftgmres::trace::perfetto_json_fleet(&frep, &cfg);
        (frep, trace)
    };
    let (threads, threads_trace) = run(Engine::Threads);
    let (events, events_trace) = run(Engine::Events);
    assert_eq!(
        threads.digest(),
        events.digest(),
        "fleet: event engine diverged from the thread oracle"
    );
    assert_eq!(threads_trace, events_trace, "fleet trace files diverged across engines");
    assert_eq!(events.preemptions, 1, "the contention actually happened");
    assert!(events.jobs.iter().all(|j| j.rep.converged));
}

/// Degraded-mode leg 1 — straggler shrink-away (DESIGN.md §14): the
/// detector's allgather, the cost-model decision and the victim's
/// conversion to a crash-stop loss must serialize identically under both
/// engines, down to the `degraded-shrink` decision record.
#[test]
fn engines_agree_straggler_shrink_away() {
    let cfg = quick_config(8, Strategy::Shrink, 0);
    let plan = InjectionPlan {
        stragglers: vec![Straggler { world_rank: 6, mult: 3.0 }],
        ..Default::default()
    };
    let rep = assert_engines_agree("straggler-shrink", &cfg, &plan);
    assert!(rep.converged);
    assert_eq!(rep.failures, 1);
    assert!(
        rep.decisions.iter().any(|d| d.decision == "degraded-shrink" && d.failed_ranks == vec![6]),
        "straggler decision missing: {:?}",
        rep.decisions
    );
}

/// Degraded-mode leg 2 — lossy link below budget: the timeout-and-retry
/// loop advances virtual time at the sender, so retry count *and* clocks
/// must agree across engines.
#[test]
fn engines_agree_lossy_link_retries() {
    let cfg = quick_config(8, Strategy::Shrink, 0);
    let plan = InjectionPlan {
        links: vec![LinkFault { src: 1, dst: 2, drops: 3 }],
        ..Default::default()
    };
    let rep = assert_engines_agree("lossy-link", &cfg, &plan);
    assert!(rep.converged);
    assert_eq!(rep.failures, 0);
    assert_eq!(rep.faults.link_retries, 3);
}

/// Degraded-mode leg 3 — silent corruption and the scrubber: injection,
/// detection at the next commit, and the repair traffic all ride collective
/// schedules, so the scrub counters and checkpoint accounting must be
/// engine-invariant.
#[test]
fn engines_agree_bitflip_scrub() {
    let cfg = quick_config(8, Strategy::Shrink, 0);
    let plan = InjectionPlan {
        bitflips: vec![BitFlip { world_rank: 4, at_version: 1, bits: 3 }],
        ..Default::default()
    };
    let rep = assert_engines_agree("bitflip-scrub", &cfg, &plan);
    assert!(rep.converged);
    assert!(rep.faults.scrub_detected >= 1);
    assert_eq!(rep.faults.scrub_detected, rep.faults.scrub_repaired);
}

/// The acceptance campaign: all three degraded fault kinds *plus* a real
/// crash-stop kill in one run.  The straggler is shrunk away early, the
/// lossy link retries without revoking, the corruption (injected after the
/// straggler recovery's re-establishment commit) is scrubbed and repaired,
/// and the late kill recovers in place — zero global restarts, and the
/// whole composite schedule is digest- and trace-identical across engines.
#[test]
fn engines_agree_mixed_degraded_campaign() {
    let cfg = quick_config(8, Strategy::Shrink, 0);
    let plan = InjectionPlan {
        kills: vec![Kill::at_iter(2, 70)],
        stragglers: vec![Straggler { world_rank: 6, mult: 3.0 }],
        links: vec![LinkFault { src: 1, dst: 2, drops: 3 }],
        bitflips: vec![BitFlip { world_rank: 4, at_version: 3, bits: 3 }],
    };
    let rep = assert_engines_agree("mixed-degraded", &cfg, &plan);
    assert!(rep.converged, "mixed degraded campaign must converge");
    assert_eq!(rep.failures, 2, "the straggler victim and the scheduled kill");
    assert_eq!(rep.global_restarts(), 0, "everything recovers in place");
    assert!(
        rep.decisions.iter().any(|d| d.decision == "degraded-shrink" && d.failed_ranks == vec![6]),
        "the straggler must be priced out: {:?}",
        rep.decisions
    );
    assert!(rep.faults.link_retries >= 3, "the drops must surface as retries");
    assert!(rep.faults.scrub_detected >= 1, "the flip must be caught");
    assert_eq!(
        rep.faults.scrub_detected, rep.faults.scrub_repaired,
        "every detection repaired in situ"
    );
}
