//! The core fault-tolerance invariants: runs with injected failures converge
//! to the same answer as failure-free runs, recomputation is bounded, and
//! both strategies are numerically equivalent.

mod common;

use common::quick_config;
use ulfm_ftgmres::ckptstore::Scheme;
use ulfm_ftgmres::config::RunConfig;
use ulfm_ftgmres::coordinator;
use ulfm_ftgmres::failure::{InjectionPlan, ProtoPhase};
use ulfm_ftgmres::metrics::RunReport;
use ulfm_ftgmres::recovery::Strategy;
use ulfm_ftgmres::simmpi::shared;

#[test]
fn shrink_single_failure_converges_to_same_answer() {
    let base = coordinator::run(&quick_config(4, Strategy::NoProtection, 0)).unwrap();
    let rep = coordinator::run(&quick_config(4, Strategy::Shrink, 1)).unwrap();
    assert_eq!(rep.failures, 1, "kill fired");
    assert!(rep.converged);
    // Same convergence target; the paths differ only by the rollback.
    assert!(rep.final_relres < 1e-10);
    assert!(base.final_relres < 1e-10);
}

#[test]
fn substitute_single_failure_converges() {
    let rep = coordinator::run(&quick_config(4, Strategy::Substitute, 1)).unwrap();
    assert_eq!(rep.failures, 1);
    assert!(rep.converged, "relres={}", rep.final_relres);
    // A spare was adopted: some rank report is a spare with iterations > 0.
    assert!(
        rep.ranks.iter().any(|r| r.was_spare && r.iterations > 0),
        "spare must have been used"
    );
}

#[test]
fn multi_failure_campaigns_converge() {
    for strategy in [Strategy::Shrink, Strategy::Substitute] {
        for failures in [2usize, 3] {
            let rep =
                coordinator::run(&quick_config(8, strategy, failures)).unwrap();
            assert_eq!(rep.failures, failures, "{strategy:?} f={failures}");
            assert!(rep.converged, "{strategy:?} f={failures}");
            assert!(rep.final_relres < 1e-10);
        }
    }
}

#[test]
fn strategies_agree_on_convergence() {
    // Both strategies roll back at the same kill schedule; shrink continues
    // on P-f ranks (different reduction grouping, so bitwise equality is
    // not expected) but both must converge in a comparable iteration count.
    let a = coordinator::run(&quick_config(8, Strategy::Shrink, 2)).unwrap();
    let b = coordinator::run(&quick_config(8, Strategy::Substitute, 2)).unwrap();
    assert!(a.converged && b.converged);
    let (lo, hi) = (a.iterations.min(b.iterations), a.iterations.max(b.iterations));
    assert!(hi - lo <= 2 * 10, "iteration counts comparable: {lo} vs {hi}");
    assert!(a.final_relres < 1e-10 && b.final_relres < 1e-10);
}

#[test]
fn recomputation_bounded_by_one_window_per_failure() {
    let base = coordinator::run(&quick_config(8, Strategy::NoProtection, 0)).unwrap();
    let m_inner = 10u64;
    for failures in [1usize, 2, 3] {
        let rep = coordinator::run(&quick_config(8, Strategy::Shrink, failures)).unwrap();
        let extra = rep.iterations - base.iterations;
        assert!(
            extra <= (failures as u64) * m_inner,
            "f={failures}: replay {extra} iters > bound {}",
            failures as u64 * m_inner
        );
        // And some recomputation must actually have happened.
        assert!(rep.max_phases.recompute > 0.0);
    }
}

#[test]
fn failure_overheads_show_up_in_phases() {
    let rep = coordinator::run(&quick_config(8, Strategy::Shrink, 2)).unwrap();
    assert!(rep.max_phases.recovery > 0.0, "recovery time charged");
    assert!(rep.max_phases.reconfig > 0.0, "reconfiguration time charged");
    assert!(rep.time_to_solution > 0.0);
    // Recovery should be well below total (sane calibration).
    assert!(rep.max_phases.recovery < rep.time_to_solution * 0.5);
}

#[test]
fn shrink_continues_with_fewer_ranks() {
    let rep = coordinator::run(&quick_config(6, Strategy::Shrink, 2)).unwrap();
    assert!(rep.converged);
    let killed = rep.ranks.iter().filter(|r| r.killed).count();
    assert_eq!(killed, 2);
    // Survivors did more iterations than the dead ranks.
    let max_survivor = rep
        .ranks
        .iter()
        .filter(|r| !r.killed)
        .map(|r| r.iterations)
        .max()
        .unwrap();
    let max_killed =
        rep.ranks.iter().filter(|r| r.killed).map(|r| r.iterations).max().unwrap();
    assert!(max_survivor > max_killed);
}

#[test]
fn substitute_requires_spares() {
    // failures > spares cannot work: config derives spares=failures, so
    // emulate exhaustion by running substitute with failures but a plan
    // that kills more ranks than spares exist.  Covered at the config
    // level: spares() == failures.
    let cfg = quick_config(8, Strategy::Substitute, 3);
    assert_eq!(cfg.spares(), 3);
}

#[test]
fn back_to_back_failures_roll_back_each_time() {
    let rep = coordinator::run(&quick_config(8, Strategy::Shrink, 3)).unwrap();
    // Each failure adds recompute: with kills at 25/40/55 and ckpt window
    // 10, the replay per failure is <= 10 iterations (positive).
    assert!(rep.max_phases.recompute > 0.0);
    assert!(rep.converged);
}

#[test]
fn simultaneous_failures_recovered_in_one_shrink() {
    // Two ranks die at the SAME iteration (non-adjacent, so each dead
    // rank's buddy survives): one shrink event must absorb both.
    let cfg = quick_config(8, Strategy::Shrink, 0);
    let plan = ulfm_ftgmres::failure::InjectionPlan {
        kills: vec![
            ulfm_ftgmres::failure::Kill::at_iter(2, 25),
            ulfm_ftgmres::failure::Kill::at_iter(5, 25),
        ],
        ..Default::default()
    };
    let backend = coordinator::make_backend(&cfg).unwrap();
    let rep = coordinator::run_custom(&cfg, backend, plan).unwrap();
    assert!(rep.converged, "relres={}", rep.final_relres);
    assert_eq!(rep.failures, 2, "both kills fired in the same window");
    assert!(rep.final_relres < 1e-10);
}

/// Everything observable about a run that the wire influences: solver
/// outcome bits, iteration history, failure/recovery bookkeeping, and the
/// exact checkpoint byte accounting.
#[allow(clippy::type_complexity)]
fn wire_digest(
    rep: &RunReport,
) -> (bool, u64, usize, u64, (usize, usize, usize), usize, usize, u64, usize) {
    (
        rep.converged,
        rep.iterations,
        rep.failures,
        rep.final_relres.to_bits(),
        rep.ckpt_totals(),
        rep.ckpt_raw_bytes(),
        rep.global_restarts(),
        rep.recovery_retries,
        rep.decisions.len(),
    )
}

fn run_with_clone_mode(cfg: &RunConfig, plan: &InjectionPlan, deep: bool) -> RunReport {
    shared::force_deep_clones(deep);
    let backend = coordinator::make_backend(cfg).unwrap();
    let rep = coordinator::run_custom(cfg, backend, plan.clone());
    shared::force_deep_clones(false);
    rep.unwrap()
}

/// Transport equivalence of the zero-copy refactor (DESIGN.md §11): the
/// shared-buffer data plane must be bit-identical to the pre-refactor
/// deep-copy wire.  `force_deep_clones` re-enacts the old clone-is-memcpy
/// semantics on the *same* code, so re-running a mirror/xor/rs2 + delta +
/// compression + nested-failure campaign under both modes and comparing
/// `RunReport` digests pins every solver result, recovery decision and
/// checkpoint byte count of the new wire to the old one.
#[test]
fn transport_equivalence_zero_copy_vs_deep_wire() {
    // (scheme, strategy, warm spares, nested second-failure phase+rank)
    let legs: Vec<(Scheme, Strategy, Option<usize>, ProtoPhase, usize)> = vec![
        (Scheme::Mirror { k: 1 }, Strategy::Shrink, None, ProtoPhase::Reconstruct, 3),
        (Scheme::Xor { g: 4 }, Strategy::Shrink, None, ProtoPhase::Reconstruct, 3),
        (Scheme::Rs2 { g: 4 }, Strategy::Substitute, Some(2), ProtoPhase::SpareJoin, 8),
    ];
    for (scheme, strategy, warm, phase, second) in legs {
        let mut cfg = quick_config(8, strategy, 0);
        cfg.warm_spares = warm;
        cfg.solver.ckpt.scheme = scheme;
        cfg.solver.ckpt.delta = true;
        cfg.solver.ckpt.compress = true;
        let first = if phase == ProtoPhase::SpareJoin { 5 } else { 7 };
        let plan = InjectionPlan::nested(first, 25, second, phase, 1);
        let cow = run_with_clone_mode(&cfg, &plan, false);
        let deep = run_with_clone_mode(&cfg, &plan, true);
        assert!(cow.converged, "{scheme:?}: zero-copy run must converge");
        assert_eq!(cow.global_restarts(), 0, "{scheme:?}: recoverable nested pattern");
        assert!(cow.recovery_retries >= 1, "{scheme:?}: the nested kill must fence");
        assert_eq!(
            wire_digest(&cow),
            wire_digest(&deep),
            "{scheme:?}: shared-buffer wire diverged from the deep-copy wire"
        );
    }
}

#[test]
fn cold_spare_recovery_pays_spawn_latency() {
    let warm = coordinator::run(&quick_config(6, Strategy::Substitute, 1)).unwrap();
    let cold = coordinator::run(&quick_config(6, Strategy::SubstituteCold, 1)).unwrap();
    assert!(warm.converged && cold.converged);
    assert_eq!(warm.failures, 1);
    assert_eq!(cold.failures, 1);
    // Cold spawn latency (2 s default) dominates reconfiguration.
    assert!(
        cold.max_phases.reconfig > warm.max_phases.reconfig + 1.0,
        "cold reconfig {} vs warm {}",
        cold.max_phases.reconfig,
        warm.max_phases.reconfig
    );
    // ... and the answer is the same.
    assert!(cold.final_relres < 1e-10);
}
