//! End-to-end solver correctness through the coordinator: convergence to the
//! analytic solution, determinism, and paper §VI's iteration-count regime.

mod common;

use common::quick_config;
use ulfm_ftgmres::coordinator;
use ulfm_ftgmres::metrics::RunReport;
use ulfm_ftgmres::recovery::Strategy;

fn run(p: usize, strategy: Strategy, failures: usize) -> RunReport {
    coordinator::run(&quick_config(p, strategy, failures)).expect("run")
}

#[test]
fn converges_failure_free_across_p() {
    for p in [2, 3, 4, 8] {
        let rep = run(p, Strategy::NoProtection, 0);
        assert!(rep.converged, "p={p}");
        assert!(rep.final_relres < 1e-10, "p={p}: {}", rep.final_relres);
        assert!(rep.iterations > 0);
    }
}

#[test]
fn iteration_count_independent_of_p() {
    // The distributed solver must be algorithmically identical at any P
    // (same reduction values via bitwise-commutative allreduce).
    let i4 = run(4, Strategy::NoProtection, 0).iterations;
    let i8 = run(8, Strategy::NoProtection, 0).iterations;
    assert_eq!(i4, i8, "same math at any distribution");
}

#[test]
fn virtual_time_deterministic_without_contention() {
    let a = run(4, Strategy::NoProtection, 0);
    let b = run(4, Strategy::NoProtection, 0);
    assert_eq!(a.time_to_solution.to_bits(), b.time_to_solution.to_bits());
    assert_eq!(a.final_relres.to_bits(), b.final_relres.to_bits());
}

#[test]
fn checkpointing_overhead_is_positive_but_small() {
    let base = run(4, Strategy::NoProtection, 0);
    let ck = run(4, Strategy::Shrink, 0);
    assert!(ck.max_phases.checkpoint > 0.0);
    assert!(base.max_phases.checkpoint == 0.0);
    assert!(
        ck.time_to_solution > base.time_to_solution,
        "checkpointing costs time"
    );
    assert!(
        ck.time_to_solution < base.time_to_solution * 2.0,
        "checkpointing is not pathological: {} vs {}",
        ck.time_to_solution,
        base.time_to_solution
    );
}

#[test]
fn paper_campaign_regime_converges_within_bounded_iterations() {
    // The calibrated campaign config (32x32x192 is too big for CI; use the
    // same shape scaled down) must converge within the m_outer budget.
    let mut cfg = quick_config(4, Strategy::NoProtection, 0);
    cfg.grid = ulfm_ftgmres::problem::Grid3D { nx: 8, ny: 8, nz: 48 };
    let rep = coordinator::run(&cfg).unwrap();
    assert!(rep.converged);
    assert!(rep.iterations < 2000);
}

#[test]
fn solution_error_reported_via_relres() {
    // relres is a true residual (recomputed at the end), not the Givens
    // estimate: verify it is consistent with convergence.
    let rep = run(4, Strategy::NoProtection, 0);
    assert!(rep.final_relres.is_finite());
    assert!(rep.final_relres < 1e-10);
}
