//! Buddy-checkpointing protocol over live ranks: ring shipping, version
//! commit semantics, restore-version agreement, and multi-buddy redundancy.

mod common;

use common::{run_ranks, wait_dead};
use ulfm_ftgmres::simmpi::ulfm;
use ulfm_ftgmres::checkpoint::{self, agree_restore_version, obj, CkptStore};
use ulfm_ftgmres::simmpi::{Blob, Comm};

#[test]
fn ring_exchange_stores_local_and_remote() {
    let n = 5;
    let results = run_ranks(n, move |mut ctx| async move {
        let mut comm = Comm::world(n, ctx.rank);
        let mut store = CkptStore::new();
        let objs = vec![(obj::X, Blob::scalar(ctx.rank as f64))];
        checkpoint::checkpoint(&mut ctx, &mut comm, &mut store, &objs, 1, 1).await.unwrap();
        let ward = (ctx.rank + n - 1) % n;
        let local_ok = store.get_local(obj::X, 1).unwrap().f == vec![ctx.rank as f64];
        let remote_ok = store.get_remote(ward, obj::X, 1).unwrap().f == vec![ward as f64];
        (local_ok, remote_ok, store.committed())
    });
    for (local_ok, remote_ok, committed) in results {
        assert!(local_ok && remote_ok);
        assert_eq!(committed, 1);
    }
}

#[test]
fn two_buddies_hold_two_copies() {
    let n = 5;
    let results = run_ranks(n, move |mut ctx| async move {
        let mut comm = Comm::world(n, ctx.rank);
        let mut store = CkptStore::new();
        let objs = vec![(obj::X, Blob::scalar(ctx.rank as f64))];
        checkpoint::checkpoint(&mut ctx, &mut comm, &mut store, &objs, 1, 2).await.unwrap();
        let w1 = (ctx.rank + n - 1) % n;
        let w2 = (ctx.rank + n - 2) % n;
        store.get_remote(w1, obj::X, 1).is_some() && store.get_remote(w2, obj::X, 1).is_some()
    });
    assert!(results.into_iter().all(|b| b));
}

#[test]
fn versions_accumulate_and_gc_keeps_two() {
    let n = 3;
    let results = run_ranks(n, move |mut ctx| async move {
        let mut comm = Comm::world(n, ctx.rank);
        let mut store = CkptStore::new();
        for v in 1..=4 {
            let objs = vec![(obj::X, Blob::scalar(v as f64))];
            checkpoint::checkpoint(&mut ctx, &mut comm, &mut store, &objs, v, 1).await.unwrap();
        }
        (
            store.get_local(obj::X, 4).is_some(),
            store.get_local(obj::X, 3).is_some(),
            store.get_local(obj::X, 2).is_none(), // gc'd
            store.committed(),
        )
    });
    for (v4, v3, v2_gone, committed) in results {
        assert!(v4 && v3 && v2_gone);
        assert_eq!(committed, 4);
    }
}

#[test]
fn restore_version_is_min_committed() {
    let n = 4;
    let results = run_ranks(n, move |mut ctx| async move {
        let mut comm = Comm::world(n, ctx.rank);
        let mut store = CkptStore::new();
        // Everyone commits v1; simulate a straggler that missed v2 by only
        // committing further on some ranks via direct put (no commit).
        let objs = vec![(obj::X, Blob::scalar(1.0))];
        checkpoint::checkpoint(&mut ctx, &mut comm, &mut store, &objs, 1, 1).await.unwrap();
        if ctx.rank != 2 {
            // These ranks ALSO ran a v2 checkpoint in a hypothetical
            // timeline; rank 2 did not commit v2.
            store.put_local(obj::X, 2, Blob::scalar(2.0));
        }
        agree_restore_version(&mut ctx, &mut comm, &store).await.unwrap()
    });
    for v in results {
        assert_eq!(v, 1, "restore version = min committed across ranks");
    }
}

#[test]
fn dead_buddy_fails_checkpoint_but_previous_commit_survives() {
    let n = 4;
    let results = run_ranks(n, move |mut ctx| async move {
        let mut comm = Comm::world(n, ctx.rank);
        let mut store = CkptStore::new();
        let objs = vec![(obj::X, Blob::scalar(ctx.rank as f64))];
        checkpoint::checkpoint(&mut ctx, &mut comm, &mut store, &objs, 1, 1).await.unwrap();
        if ctx.rank == 3 {
            let _ = ctx.die();
            return (true, 1);
        }
        wait_dead(&ctx.world, 3);
        // Next checkpoint must fail for someone (3 is dead) and the commit
        // must stay at 1 on the failing ranks.  Revoke on error so blocked
        // peers unblock (what the recovery driver does).
        let objs2 = vec![(obj::X, Blob::scalar(10.0))];
        let r = checkpoint::checkpoint(&mut ctx, &mut comm, &mut store, &objs2, 2, 1).await;
        if r.is_err() {
            ulfm::revoke(&mut ctx, &comm);
        }
        (r.is_err(), store.committed())
    });
    // Rank 2 (buddy of dead 3) and rank 0 (ward of 3) must error; their
    // committed version stays 1.
    let mut failed = 0;
    for (r, (is_err, committed)) in results.iter().enumerate() {
        if r == 3 {
            continue;
        }
        if *is_err {
            failed += 1;
            assert_eq!(*committed, 1, "rank {r} must not commit v2");
        }
    }
    assert!(failed >= 1, "at least the dead rank's neighbors fail");
}

#[test]
fn checkpoint_bytes_accounted_on_virtual_clock() {
    let n = 2;
    let results = run_ranks(n, move |mut ctx| async move {
        let mut comm = Comm::world(n, ctx.rank);
        let mut store = CkptStore::new();
        let t0 = ctx.clock;
        let objs = vec![(obj::X, Blob::from_f64s(vec![0.0; 100_000]))];
        checkpoint::checkpoint(&mut ctx, &mut comm, &mut store, &objs, 1, 1).await.unwrap();
        ctx.clock - t0
    });
    // 800 kB through the intra-node path (two ranks, same node) at 6 GB/s
    // is ~0.13 ms; ensure a sane nonzero charge below the inter-node time.
    for dt in results {
        assert!(dt > 1e-5, "checkpoint charged time: {dt}");
        assert!(dt < 0.1, "checkpoint absurdly slow: {dt}");
    }
}
