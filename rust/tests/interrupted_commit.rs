//! Committed-floor atomicity under interrupted commits: a member (or
//! stripe holder) dies *mid-commit* and the previous committed version
//! must remain fully reconstructable, bit-identically, under every
//! redundancy scheme — including an rs2 rotation boundary, where the
//! incoming holder dying mid-re-encode must not orphan the restore
//! version's stripes (they live on the *previous* rotation's holders).

mod common;

use common::{run_ranks_plan, wait_dead};
use ulfm_ftgmres::checkpoint::{agree_restore_version, obj, CkptStore};
use ulfm_ftgmres::ckptstore::{self, scheme, CkptCfg, Scheme};
use ulfm_ftgmres::failure::{InjectionPlan, Kill, ProtoPhase};
use ulfm_ftgmres::simmpi::ulfm::{self, EpochFence};
use ulfm_ftgmres::simmpi::{Blob, Comm, MpiError};

const N: usize = 8;

/// Deterministic, rank-distinct v1 payload (what must survive the torn v2).
fn v1_blob(rank: usize) -> Blob {
    Blob::new(
        (0..33).map(|k| (rank * 100 + k) as f64 * 0.5 + 0.125).collect(),
        vec![rank as i64, 7, -3],
    )
}

/// Drive one interrupted-commit scenario: commit v1 cleanly, let `victim`
/// die entering the v2 commit, then repair and assert the survivors can
/// still reconstruct the victim's v1 object bit-identically.
fn interrupted_commit_case(name: &str, cfg: CkptCfg, victim: usize) {
    let plan = InjectionPlan { kills: vec![Kill::at_phase(victim, ProtoPhase::CkptCommit, 2)], ..Default::default() };
    let cfg2 = cfg.clone();
    let results = run_ranks_plan(N, plan, move |mut ctx| {
        let cfg = cfg2.clone();
        async move {
            let mut comm = Comm::world(N, ctx.rank);
            let mut store = CkptStore::new();
            // v1: clean establishment commit.
            ckptstore::commit(
                &mut ctx,
                &mut comm,
                &mut store,
                &[(obj::X, v1_blob(ctx.rank))],
                1,
                &cfg,
                true,
            )
            .await
            .unwrap();
            // v2: the victim dies entering the commit; survivors see a torn
            // exchange (or a torn agreement) and must not advance the floor.
            let v2 = Blob {
                f: v1_blob(ctx.rank).f.iter().map(|x| x + 1000.0).collect(),
                i: v1_blob(ctx.rank).i,
                wire: None,
            };
            let r2 = ckptstore::commit(
                &mut ctx,
                &mut comm,
                &mut store,
                &[(obj::X, v2)],
                2,
                &cfg,
                false,
            )
            .await;
            if ctx.rank == victim {
                assert!(matches!(r2, Err(MpiError::Killed)), "victim dies inside the commit");
                return None;
            }
            assert!(r2.is_err(), "the torn commit must error, not hang");
            assert_eq!(store.committed(), 1, "v2 must not commit on any survivor");
            // Repair like the recovery driver: revoke, fenced shrink, agree.
            wait_dead(&ctx.world, victim);
            ulfm::revoke(&mut ctx, &comm);
            let mut fence = EpochFence::new(&comm);
            let mut shrunk = ulfm::shrink_fenced(&mut ctx, &comm, &mut fence).await.unwrap();
            let v = agree_restore_version(&mut ctx, &mut shrunk, &store).await.unwrap();
            assert_eq!(v, 1, "survivors restore the pre-interruption floor");
            // My own v1 payload is intact despite the uncommitted v2 residue.
            let (lv, local) = store.get_local_at_most(obj::X, v).expect("own v1 retained");
            assert_eq!((lv, local.f.clone()), (1, v1_blob(ctx.rank).f), "local floor intact");
            // Recovery reader: materialize the victim's objects on its server.
            let old_members: Vec<usize> = (0..N).collect();
            ckptstore::reconstruct_failed(
                &mut ctx,
                &shrunk,
                &mut store,
                &cfg,
                &old_members,
                v,
                &[obj::X],
            )
            .await
            .unwrap();
            let world = ctx.world.clone();
            let alive_cr = move |cr: usize| world.is_alive(cr);
            let server = cfg
                .scheme
                .server_cr_for(victim, N, &alive_cr, 1)
                .expect("single loss must be recoverable");
            if ctx.rank == server {
                let (gv, got) =
                    store.get_remote_at_most(victim, obj::X, v).expect("victim's v1 served");
                let want = v1_blob(victim);
                assert_eq!(gv, 1);
                assert_eq!(got.f, want.f, "reconstructed f lane bit-identical");
                assert_eq!(got.i, want.i, "reconstructed i lane bit-identical");
            }
            Some(ctx.rank)
        }
    });
    assert!(results[victim].is_none(), "{name}: victim excluded");
    for (r, res) in results.iter().enumerate() {
        if r != victim {
            assert_eq!(*res, Some(r), "{name}: survivor {r} completed");
        }
    }
}

#[test]
fn interrupted_commit_mirror_member() {
    interrupted_commit_case("mirror", CkptCfg::mirror(1), 3);
}

#[test]
fn interrupted_commit_xor_member() {
    // Victim 1 is a plain member of parity group 0 (holder: rank 4).
    let cfg = CkptCfg { scheme: Scheme::Xor { g: 4 }, ..CkptCfg::default() };
    interrupted_commit_case("xor-member", cfg, 1);
}

#[test]
fn interrupted_commit_xor_holder() {
    // Victim 4 holds group 0's stripe but is itself a member of group 1,
    // so its own v1 data must come back through group 1's stripe.
    let cfg = CkptCfg { scheme: Scheme::Xor { g: 4 }, ..CkptCfg::default() };
    interrupted_commit_case("xor-holder", cfg, 4);
}

#[test]
fn interrupted_commit_rs2_member() {
    let cfg = CkptCfg { scheme: Scheme::Rs2 { g: 4 }, ..CkptCfg::default() };
    interrupted_commit_case("rs2-member", cfg, 1);
}

#[test]
fn interrupted_commit_rs2_rotation_boundary_holder() {
    // rebase_every = 1 puts every version in its own rotation epoch: v1's
    // stripes live on the rot-1 holder pair, v2's re-encode targets the
    // rot-2 pair.  The victim is v2's *incoming* P holder for group 0
    // (which happens to be v1's Q holder): its death mid-re-encode must
    // not orphan the restore version's stripes — the v=1 solve runs off
    // the rot-1 pair's surviving stripe.
    let cfg =
        CkptCfg { scheme: Scheme::Rs2 { g: 4 }, rebase_every: 1, ..CkptCfg::default() };
    let (p2, _) = scheme::rs2_holders(0, 4, N, cfg.rot_index(2));
    assert_eq!(p2, 6, "rotation schedule moved under the test's feet");
    interrupted_commit_case("rs2-rotation", cfg, p2);
}
