//! Committed-floor atomicity under interrupted commits: a member (or
//! stripe holder) dies *mid-commit* and the previous committed version
//! must remain fully reconstructable, bit-identically, under every
//! redundancy scheme — including an rs2 rotation boundary, where the
//! incoming holder dying mid-re-encode must not orphan the restore
//! version's stripes (they live on the *previous* rotation's holders).

mod common;

use common::{run_ranks_plan, wait_dead};
use ulfm_ftgmres::checkpoint::{agree_restore_version, obj, CkptStore};
use ulfm_ftgmres::ckptstore::{self, scheme, CkptCfg, Scheme};
use ulfm_ftgmres::failure::{InjectionPlan, Kill, ProtoPhase};
use ulfm_ftgmres::simmpi::ulfm::{self, EpochFence};
use ulfm_ftgmres::simmpi::{Blob, Comm, MpiError};

const N: usize = 8;

/// Deterministic, rank-distinct v1 payload (what must survive the torn v2).
fn v1_blob(rank: usize) -> Blob {
    Blob::new(
        (0..33).map(|k| (rank * 100 + k) as f64 * 0.5 + 0.125).collect(),
        vec![rank as i64, 7, -3],
    )
}

/// Drive one interrupted-commit scenario: commit v1 cleanly, let `victim`
/// die entering the v2 commit, then repair and assert the survivors can
/// still reconstruct the victim's v1 object bit-identically.
fn interrupted_commit_case(name: &str, cfg: CkptCfg, victim: usize) {
    let plan = InjectionPlan { kills: vec![Kill::at_phase(victim, ProtoPhase::CkptCommit, 2)], ..Default::default() };
    let cfg2 = cfg.clone();
    let results = run_ranks_plan(N, plan, move |mut ctx| {
        let cfg = cfg2.clone();
        async move {
            let mut comm = Comm::world(N, ctx.rank);
            let mut store = CkptStore::new();
            // v1: clean establishment commit.
            ckptstore::commit(
                &mut ctx,
                &mut comm,
                &mut store,
                &[(obj::X, v1_blob(ctx.rank))],
                1,
                &cfg,
                true,
            )
            .await
            .unwrap();
            // v2: the victim dies entering the commit; survivors see a torn
            // exchange (or a torn agreement) and must not advance the floor.
            let v2 = Blob {
                f: v1_blob(ctx.rank).f.iter().map(|x| x + 1000.0).collect(),
                i: v1_blob(ctx.rank).i,
                wire: None,
            };
            let r2 = ckptstore::commit(
                &mut ctx,
                &mut comm,
                &mut store,
                &[(obj::X, v2)],
                2,
                &cfg,
                false,
            )
            .await;
            if ctx.rank == victim {
                assert!(matches!(r2, Err(MpiError::Killed)), "victim dies inside the commit");
                return None;
            }
            assert!(r2.is_err(), "the torn commit must error, not hang");
            assert_eq!(store.committed(), 1, "v2 must not commit on any survivor");
            // Repair like the recovery driver: revoke, fenced shrink, agree.
            wait_dead(&ctx.world, victim);
            ulfm::revoke(&mut ctx, &comm);
            let mut fence = EpochFence::new(&comm);
            let mut shrunk = ulfm::shrink_fenced(&mut ctx, &comm, &mut fence).await.unwrap();
            let v = agree_restore_version(&mut ctx, &mut shrunk, &store).await.unwrap();
            assert_eq!(v, 1, "survivors restore the pre-interruption floor");
            // My own v1 payload is intact despite the uncommitted v2 residue.
            let (lv, local) = store.get_local_at_most(obj::X, v).expect("own v1 retained");
            assert_eq!((lv, local.f.clone()), (1, v1_blob(ctx.rank).f), "local floor intact");
            // Recovery reader: materialize the victim's objects on its server.
            let old_members: Vec<usize> = (0..N).collect();
            ckptstore::reconstruct_failed(
                &mut ctx,
                &shrunk,
                &mut store,
                &cfg,
                &old_members,
                v,
                &[obj::X],
            )
            .await
            .unwrap();
            let world = ctx.world.clone();
            let alive_cr = move |cr: usize| world.is_alive(cr);
            let server = cfg
                .scheme
                .server_cr_for(victim, N, &alive_cr, 1)
                .expect("single loss must be recoverable");
            if ctx.rank == server {
                let (gv, got) =
                    store.get_remote_at_most(victim, obj::X, v).expect("victim's v1 served");
                let want = v1_blob(victim);
                assert_eq!(gv, 1);
                assert_eq!(got.f, want.f, "reconstructed f lane bit-identical");
                assert_eq!(got.i, want.i, "reconstructed i lane bit-identical");
            }
            Some(ctx.rank)
        }
    });
    assert!(results[victim].is_none(), "{name}: victim excluded");
    for (r, res) in results.iter().enumerate() {
        if r != victim {
            assert_eq!(*res, Some(r), "{name}: survivor {r} completed");
        }
    }
}

#[test]
fn interrupted_commit_mirror_member() {
    interrupted_commit_case("mirror", CkptCfg::mirror(1), 3);
}

#[test]
fn interrupted_commit_xor_member() {
    // Victim 1 is a plain member of parity group 0 (holder: rank 4).
    let cfg = CkptCfg { scheme: Scheme::Xor { g: 4 }, ..CkptCfg::default() };
    interrupted_commit_case("xor-member", cfg, 1);
}

#[test]
fn interrupted_commit_xor_holder() {
    // Victim 4 holds group 0's stripe but is itself a member of group 1,
    // so its own v1 data must come back through group 1's stripe.
    let cfg = CkptCfg { scheme: Scheme::Xor { g: 4 }, ..CkptCfg::default() };
    interrupted_commit_case("xor-holder", cfg, 4);
}

#[test]
fn interrupted_commit_rs2_member() {
    let cfg = CkptCfg { scheme: Scheme::Rs2 { g: 4 }, ..CkptCfg::default() };
    interrupted_commit_case("rs2-member", cfg, 1);
}

/// Async variant of [`interrupted_commit_case`]: with `ckpt_async` on, the
/// v2 commit *publishes* and returns immediately — the victim dies at the
/// `CkptShip` phase point, inside the in-flight window between publish and
/// drain (the window that only exists in async mode).  Survivors must
/// CANCEL (never drain) the torn in-flight commit at recovery entry and
/// still reconstruct the committed floor bit-identically.
fn interrupted_async_ship_case(name: &str, cfg: CkptCfg, victim: usize) {
    let cfg = CkptCfg { async_commit: true, ..cfg };
    // CkptShip entry 1 is the v2 commit: the establishment commit (fresh)
    // takes the synchronous seal path even in async mode and never emits
    // the ship phase point.
    let plan = InjectionPlan {
        kills: vec![Kill::at_phase(victim, ProtoPhase::CkptShip, 1)],
        ..Default::default()
    };
    let cfg2 = cfg.clone();
    let results = run_ranks_plan(N, plan, move |mut ctx| {
        let cfg = cfg2.clone();
        async move {
            let mut comm = Comm::world(N, ctx.rank);
            let mut store = CkptStore::new();
            ckptstore::commit(
                &mut ctx,
                &mut comm,
                &mut store,
                &[(obj::X, v1_blob(ctx.rank))],
                1,
                &cfg,
                true,
            )
            .await
            .unwrap();
            assert!(!store.has_in_flight(), "fresh commits seal synchronously");
            assert_eq!(store.committed(), 1);
            let v2 = Blob {
                f: v1_blob(ctx.rank).f.iter().map(|x| x + 1000.0).collect(),
                i: v1_blob(ctx.rank).i,
                wire: None,
            };
            let r2 = ckptstore::commit(
                &mut ctx,
                &mut comm,
                &mut store,
                &[(obj::X, v2)],
                2,
                &cfg,
                false,
            )
            .await;
            if ctx.rank == victim {
                assert!(matches!(r2, Err(MpiError::Killed)), "victim dies in the ship window");
                return None;
            }
            match r2 {
                // Common case: the publish half saw no failure, the commit
                // went non-blocking and this rank "resumed compute" with
                // the ship in flight.
                Ok(()) => assert!(
                    store.has_in_flight(),
                    "non-blocking commit must return with the ship in flight"
                ),
                // A publish send aimed at the victim may observe the death
                // first (threads engine: the registry is real time); either
                // way the floor must not have moved.
                Err(e) => assert!(!matches!(e, MpiError::Killed), "survivor must not die: {e}"),
            }
            assert_eq!(store.committed(), 1, "the floor advances only when the drain seals");
            // The in-flight residue must be invisible to floor readers.
            let (lv, local) = store.get_local_at_most(obj::X, 1).expect("own v1 retained");
            assert_eq!((lv, local.f.clone()), (1, v1_blob(ctx.rank).f));
            wait_dead(&ctx.world, victim);
            // Recovery entry: survivors cancel, exactly like
            // `handle_failure_fenced` does before building its fence.
            ckptstore::cancel_in_flight(&mut store);
            assert!(!store.has_in_flight(), "cancel clears the in-flight slot");
            ulfm::revoke(&mut ctx, &comm);
            let mut fence = EpochFence::new(&comm);
            let mut shrunk = ulfm::shrink_fenced(&mut ctx, &comm, &mut fence).await.unwrap();
            let v = agree_restore_version(&mut ctx, &mut shrunk, &store).await.unwrap();
            assert_eq!(v, 1, "survivors restore the pre-interruption floor");
            let old_members: Vec<usize> = (0..N).collect();
            ckptstore::reconstruct_failed(
                &mut ctx,
                &shrunk,
                &mut store,
                &cfg,
                &old_members,
                v,
                &[obj::X],
            )
            .await
            .unwrap();
            let world = ctx.world.clone();
            let alive_cr = move |cr: usize| world.is_alive(cr);
            let server = cfg
                .scheme
                .server_cr_for(victim, N, &alive_cr, 1)
                .expect("single loss must be recoverable");
            if ctx.rank == server {
                let (gv, got) =
                    store.get_remote_at_most(victim, obj::X, v).expect("victim's v1 served");
                let want = v1_blob(victim);
                assert_eq!(gv, 1);
                assert_eq!(got.f, want.f, "reconstructed f lane bit-identical");
                assert_eq!(got.i, want.i, "reconstructed i lane bit-identical");
            }
            Some(ctx.rank)
        }
    });
    assert!(results[victim].is_none(), "{name}: victim excluded");
    for (r, res) in results.iter().enumerate() {
        if r != victim {
            assert_eq!(*res, Some(r), "{name}: survivor {r} completed");
        }
    }
}

#[test]
fn async_ship_kill_xor_member() {
    let cfg = CkptCfg { scheme: Scheme::Xor { g: 4 }, ..CkptCfg::default() };
    interrupted_async_ship_case("async-xor-member", cfg, 1);
}

#[test]
fn async_ship_kill_xor_holder() {
    // Victim 4 holds group 0's stripe: its death strands the in-flight
    // contributions group 0 shipped to it; the cancel must leave them as
    // invisible above-floor residue.
    let cfg = CkptCfg { scheme: Scheme::Xor { g: 4 }, ..CkptCfg::default() };
    interrupted_async_ship_case("async-xor-holder", cfg, 4);
}

#[test]
fn async_ship_kill_rs2_rotation_boundary_holder() {
    // Same rotation-boundary shape as the sync test, but the incoming P
    // holder dies inside the ship window: v2's re-encode to the rot-2 pair
    // never drains, and the v=1 solve must run off the rot-1 stripes.
    let cfg =
        CkptCfg { scheme: Scheme::Rs2 { g: 4 }, rebase_every: 1, ..CkptCfg::default() };
    let (p2, _) = scheme::rs2_holders(0, 4, N, cfg.rot_index(2));
    assert_eq!(p2, 6, "rotation schedule moved under the test's feet");
    interrupted_async_ship_case("async-rs2-rotation", cfg, p2);
}

/// Failure-free async pipeline: commit N+1 drains commit N before
/// publishing (the pipeline is one deep), and an explicit final drain
/// seals the last in-flight version — the coordinator does exactly this at
/// solver convergence.
#[test]
fn async_commit_drains_at_next_commit() {
    let cfg = CkptCfg {
        scheme: Scheme::Xor { g: 4 },
        async_commit: true,
        ..CkptCfg::default()
    };
    let cfg2 = cfg.clone();
    let results = run_ranks_plan(N, InjectionPlan::none(), move |mut ctx| {
        let cfg = cfg2.clone();
        async move {
            let mut comm = Comm::world(N, ctx.rank);
            let mut store = CkptStore::new();
            let blob = |v: i64| Blob {
                f: v1_blob(ctx.rank).f.iter().map(|x| x + 1000.0 * v as f64).collect(),
                i: v1_blob(ctx.rank).i,
                wire: None,
            };
            ckptstore::commit(&mut ctx, &mut comm, &mut store, &[(obj::X, blob(0))], 1, &cfg, true)
                .await
                .unwrap();
            assert_eq!(store.committed(), 1, "fresh establishment seals in line");
            assert!(!store.has_in_flight());
            // v2 publishes and returns: still floor 1, ship in flight.
            ckptstore::commit(&mut ctx, &mut comm, &mut store, &[(obj::X, blob(1))], 2, &cfg, false)
                .await
                .unwrap();
            assert!(store.has_in_flight());
            assert_eq!(store.committed(), 1);
            // v3 drains v2 first (sealing it), then publishes itself.
            ckptstore::commit(&mut ctx, &mut comm, &mut store, &[(obj::X, blob(2))], 3, &cfg, false)
                .await
                .unwrap();
            assert!(store.has_in_flight());
            assert_eq!(store.committed(), 2, "entering commit v3 sealed v2");
            // Final drain (what the coordinator runs at convergence).
            ckptstore::drain_in_flight(&mut ctx, &mut comm, &mut store).await.unwrap();
            assert!(!store.has_in_flight());
            assert_eq!(store.committed(), 3);
            // Draining with nothing in flight is a no-op.
            ckptstore::drain_in_flight(&mut ctx, &mut comm, &mut store).await.unwrap();
            assert_eq!(store.committed(), 3);
            let (lv, local) = store.get_local_at_most(obj::X, 3).expect("v3 local");
            assert_eq!(lv, 3);
            assert_eq!(local.f, blob(2).f, "sealed payload bit-identical");
            Some(ctx.rank)
        }
    });
    for (r, res) in results.iter().enumerate() {
        assert_eq!(*res, Some(r), "rank {r} completed");
    }
}

#[test]
fn interrupted_commit_rs2_rotation_boundary_holder() {
    // rebase_every = 1 puts every version in its own rotation epoch: v1's
    // stripes live on the rot-1 holder pair, v2's re-encode targets the
    // rot-2 pair.  The victim is v2's *incoming* P holder for group 0
    // (which happens to be v1's Q holder): its death mid-re-encode must
    // not orphan the restore version's stripes — the v=1 solve runs off
    // the rot-1 pair's surviving stripe.
    let cfg =
        CkptCfg { scheme: Scheme::Rs2 { g: 4 }, rebase_every: 1, ..CkptCfg::default() };
    let (p2, _) = scheme::rs2_holders(0, 4, N, cfg.rot_index(2));
    assert_eq!(p2, 6, "rotation schedule moved under the test's feet");
    interrupted_commit_case("rs2-rotation", cfg, p2);
}
