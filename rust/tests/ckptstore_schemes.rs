//! End-to-end checkpoint-store scheme tests: xor parity recovery through
//! both in-situ strategies, rs2 double-parity recovery of every
//! two-in-group loss pattern, delta commits, wire compression, and
//! group-failure escalation to a global restart (DESIGN.md §8–§9).

mod common;

use std::sync::Arc;

use common::{quick_config, Rng};
use ulfm_ftgmres::backend::native::NativeBackend;
use ulfm_ftgmres::ckptstore::delta::{
    compress_blob, decompress_blob, rle_compress, rle_decompress,
};
use ulfm_ftgmres::ckptstore::Scheme;
use ulfm_ftgmres::config::RunConfig;
use ulfm_ftgmres::coordinator;
use ulfm_ftgmres::failure::InjectionPlan;
use ulfm_ftgmres::metrics::RunReport;
use ulfm_ftgmres::recovery::Strategy;
use ulfm_ftgmres::simmpi::Blob;

fn with_scheme(mut cfg: RunConfig, scheme: Scheme, delta: bool) -> RunConfig {
    cfg.solver.ckpt.scheme = scheme;
    cfg.solver.ckpt.delta = delta;
    cfg
}

fn run_with_plan(cfg: &RunConfig, plan: InjectionPlan) -> RunReport {
    let backend = Arc::new(NativeBackend::new(cfg.compute.clone()));
    coordinator::run_custom(cfg, backend, plan).expect("run completes")
}

/// A single in-group failure under xor:4 reconstructs from parity and the
/// shrink recovery restores the *same* committed state as mirror:1 — the
/// iteration sequence afterwards is bit-identical.
#[test]
fn xor_shrink_restores_the_same_committed_state_as_mirror() {
    let mirror = coordinator::run(&with_scheme(
        quick_config(8, Strategy::Shrink, 1),
        Scheme::Mirror { k: 1 },
        false,
    ))
    .unwrap();
    let xor = coordinator::run(&with_scheme(
        quick_config(8, Strategy::Shrink, 1),
        Scheme::Xor { g: 4 },
        false,
    ))
    .unwrap();
    assert_eq!(mirror.failures, 1);
    assert_eq!(xor.failures, 1);
    assert!(mirror.converged && xor.converged);
    assert!(mirror.final_relres < 1e-10 && xor.final_relres < 1e-10);
    // Parity reconstruction is bit-exact, so the restored state and hence
    // the whole post-recovery iteration history must match.
    assert_eq!(mirror.iterations, xor.iterations);
    assert!(
        (mirror.final_relres - xor.final_relres).abs() <= 1e-14,
        "mirror {} vs xor {}",
        mirror.final_relres,
        xor.final_relres
    );
}

/// Substitute recovery under xor: the parity holder reconstructs the failed
/// rank's objects and serves them to the spare.
#[test]
fn xor_substitute_single_failure_converges() {
    let cfg = with_scheme(
        quick_config(8, Strategy::Substitute, 1),
        Scheme::Xor { g: 4 },
        false,
    );
    let rep = coordinator::run(&cfg).unwrap();
    assert_eq!(rep.failures, 1);
    assert!(rep.converged, "relres={}", rep.final_relres);
    assert!(
        rep.ranks.iter().any(|r| r.was_spare && r.iterations > 0),
        "spare must have been used"
    );
}

/// One failure per parity group across separate events: each loss is
/// covered by its stripe and the re-encode between events restores full
/// redundancy, so the campaign survives failures in every group.
#[test]
fn xor_cross_group_campaign_recovers_in_situ() {
    let cfg = with_scheme(quick_config(8, Strategy::Shrink, 2), Scheme::Xor { g: 4 }, false);
    let plan = InjectionPlan::cross_group_campaign(8, 4, 2, cfg.solver.m_inner as u64);
    let rep = run_with_plan(&cfg, plan);
    assert_eq!(rep.failures, 2);
    assert!(rep.converged, "relres={}", rep.final_relres);
    assert!(rep.final_relres < 1e-10);
    let names: Vec<&str> = rep.decisions.iter().map(|d| d.decision).collect();
    assert_eq!(names, vec!["shrink", "shrink"], "both events recovered in situ");
}

/// The delta layer changes transport only: the solve (and its answer) is
/// identical, while the redundancy bytes shipped drop by a lot.
#[test]
fn delta_cuts_shipped_bytes_without_changing_the_answer() {
    let full =
        coordinator::run(&with_scheme(quick_config(4, Strategy::Shrink, 0), Scheme::Mirror { k: 1 }, false))
            .unwrap();
    let delta =
        coordinator::run(&with_scheme(quick_config(4, Strategy::Shrink, 0), Scheme::Mirror { k: 1 }, true))
            .unwrap();
    assert!(full.converged && delta.converged);
    assert_eq!(full.iterations, delta.iterations, "transport must not change the math");
    assert!((full.final_relres - delta.final_relres).abs() <= 1e-14);
    let (full_shipped, full_logical, full_commits) = full.ckpt_totals();
    let (delta_shipped, delta_logical, delta_commits) = delta.ckpt_totals();
    assert_eq!(full_commits, delta_commits);
    assert_eq!(full_logical, delta_logical);
    assert!(full_shipped > 0 && delta_shipped > 0);
    assert!(
        2 * delta_shipped < full_shipped,
        "delta must at least halve shipped bytes: {delta_shipped} vs {full_shipped}"
    );
    // Delta survives recovery too: same campaign with one failure.
    let rec =
        coordinator::run(&with_scheme(quick_config(8, Strategy::Shrink, 1), Scheme::Mirror { k: 1 }, true))
            .unwrap();
    assert!(rec.converged, "relres={}", rec.final_relres);
    assert!(rec.final_relres < 1e-10);
}

/// xor + delta compose: parity contributions ship as chunk deltas and a
/// failure still reconstructs the exact committed state.
#[test]
fn xor_delta_recovers_after_failure() {
    let cfg = with_scheme(quick_config(8, Strategy::Shrink, 1), Scheme::Xor { g: 4 }, true);
    let rep = coordinator::run(&cfg).unwrap();
    assert_eq!(rep.failures, 1);
    assert!(rep.converged, "relres={}", rep.final_relres);
    assert!(rep.final_relres < 1e-10);
    let mirror = coordinator::run(&with_scheme(
        quick_config(8, Strategy::Shrink, 1),
        Scheme::Mirror { k: 1 },
        false,
    ))
    .unwrap();
    assert_eq!(rep.iterations, mirror.iterations, "same restored state, same history");
}

/// Two simultaneous failures inside one parity group before any re-encode:
/// the loss is unrecoverable in situ and must deterministically escalate to
/// a recorded `GlobalRestart` — and the run must still produce the right
/// answer (survivors rebuild from scratch), not a wrong one or a hang.
#[test]
fn same_group_double_failure_escalates_to_global_restart() {
    let cfg = with_scheme(quick_config(8, Strategy::Shrink, 0), Scheme::Xor { g: 4 }, false);
    let plan = InjectionPlan::same_group_burst(8, 4, 1, 2, 25);
    let rep = run_with_plan(&cfg, plan);
    assert_eq!(rep.failures, 2, "both kills fired");
    assert_eq!(rep.decisions.len(), 1, "one event");
    assert_eq!(rep.decisions[0].decision, "global-restart");
    assert!(
        rep.decisions[0].reason.contains("unrecoverable"),
        "escalation reason recorded: {}",
        rep.decisions[0].reason
    );
    assert!(rep.converged, "restarted run must still converge: relres={}", rep.final_relres);
    assert!(rep.final_relres < 1e-10, "and produce the right answer");
}

/// Losing a group member together with that group's parity holder is just
/// as fatal as two in-group losses: escalate, restart, converge.
#[test]
fn member_plus_holder_failure_escalates() {
    let cfg = with_scheme(quick_config(8, Strategy::Shrink, 0), Scheme::Xor { g: 4 }, false);
    // Rank 5 is in group 1; rank 0 holds group 1's parity stripe.
    let plan = InjectionPlan::burst(&[0, 5], 25);
    let rep = run_with_plan(&cfg, plan);
    assert_eq!(rep.failures, 2);
    assert_eq!(rep.decisions[0].decision, "global-restart");
    assert!(rep.decisions[0].reason.contains("unrecoverable"));
    assert!(rep.converged, "relres={}", rep.final_relres);
}

/// Under mirror:1, losing a rank and its only buddy likewise escalates
/// instead of panicking mid-redistribution.
#[test]
fn adjacent_pair_loss_under_mirror1_escalates() {
    let cfg = with_scheme(quick_config(8, Strategy::Shrink, 0), Scheme::Mirror { k: 1 }, false);
    let plan = InjectionPlan::burst(&[3, 4], 25);
    let rep = run_with_plan(&cfg, plan);
    assert_eq!(rep.failures, 2);
    assert_eq!(rep.decisions[0].decision, "global-restart");
    assert!(rep.decisions[0].reason.contains("unrecoverable"));
    assert!(rep.converged, "relres={}", rep.final_relres);
}

/// rs2 tentpole: a member+member double fault inside ONE parity group —
/// exactly the pattern that forces a global restart under xor:4 — is
/// solved in situ by the double-parity two-erasure solve: no
/// `GlobalRestart` is ever recorded and the run converges to the right
/// answer.
#[test]
fn rs2_same_group_double_fault_recovers_in_situ() {
    let cfg = with_scheme(quick_config(8, Strategy::Shrink, 0), Scheme::Rs2 { g: 4 }, false);
    let plan = InjectionPlan::same_group_burst(8, 4, 0, 2, 25);
    let rep = run_with_plan(&cfg, plan);
    assert_eq!(rep.failures, 2, "both kills fired");
    assert!(!rep.decisions.is_empty());
    assert!(
        rep.decisions.iter().all(|d| d.decision != "global-restart"),
        "double parity must solve the double fault: {:?}",
        rep.decisions.iter().map(|d| d.decision).collect::<Vec<_>>()
    );
    assert!(rep.converged, "relres={}", rep.final_relres);
    assert!(rep.final_relres < 1e-10);
}

/// Every member+holder / member+outside-rank pairing recovers under rs2:
/// rank 1 (group 0) dies together with each rank of the outside ring
/// {4..7} in turn — whichever pair of them holds group 0's stripes at the
/// restore rotation, at least one stripe survives a single-holder loss, so
/// all four pairings stay in situ (and the set provably covers the
/// member+P-holder and member+Q-holder solves).
#[test]
fn rs2_member_plus_holder_double_faults_recover() {
    for outside in 4..8 {
        let cfg =
            with_scheme(quick_config(8, Strategy::Shrink, 0), Scheme::Rs2 { g: 4 }, false);
        let plan = InjectionPlan::burst(&[1, outside], 25);
        let rep = run_with_plan(&cfg, plan);
        assert_eq!(rep.failures, 2, "outside={outside}");
        assert!(
            rep.decisions.iter().all(|d| d.decision != "global-restart"),
            "member 1 + rank {outside} must recover in situ"
        );
        assert!(rep.converged, "outside={outside}: relres={}", rep.final_relres);
    }
}

/// Losing both of a group's stripe holders at once destroys no group data
/// (it is simultaneously a two-member loss of the holders' own group,
/// which the double parity of THAT group solves): recover in situ, and the
/// next commits re-home the orphaned stripes.
#[test]
fn rs2_double_holder_loss_recovers_and_rehomes() {
    let cfg = with_scheme(quick_config(8, Strategy::Shrink, 0), Scheme::Rs2 { g: 4 }, false);
    // Ranks 4+5: two members of group 1, and (at rotation 0) group 0's
    // (P, Q) holder pair.
    let plan = InjectionPlan::burst(&[4, 5], 25);
    let rep = run_with_plan(&cfg, plan);
    assert_eq!(rep.failures, 2);
    assert!(
        rep.decisions.iter().all(|d| d.decision != "global-restart"),
        "holder-only loss per group 0 + double member loss of group 1 both stay in situ"
    );
    assert!(rep.converged, "relres={}", rep.final_relres);
    assert!(rep.final_relres < 1e-10);
}

/// Three concurrent losses in one rs2 group exceed the double parity and
/// must deterministically escalate to a recorded global restart — which
/// still produces the right answer.
#[test]
fn rs2_triple_fault_escalates_to_global_restart() {
    let cfg = with_scheme(quick_config(8, Strategy::Shrink, 0), Scheme::Rs2 { g: 4 }, false);
    let plan = InjectionPlan::same_group_burst(8, 4, 0, 3, 25);
    let rep = run_with_plan(&cfg, plan);
    assert_eq!(rep.failures, 3);
    assert_eq!(rep.decisions[0].decision, "global-restart");
    assert!(
        rep.decisions[0].reason.contains("unrecoverable"),
        "escalation reason recorded: {}",
        rep.decisions[0].reason
    );
    assert!(rep.converged, "relres={}", rep.final_relres);
}

/// Substitute recovery under rs2: the reconstruction leader solves the
/// double fault and serves both spares their slots' state.
#[test]
fn rs2_substitute_double_fault_uses_spares() {
    let cfg =
        with_scheme(quick_config(8, Strategy::Substitute, 2), Scheme::Rs2 { g: 4 }, false);
    let plan = InjectionPlan::same_group_burst(8, 4, 0, 2, 25);
    let rep = run_with_plan(&cfg, plan);
    assert_eq!(rep.failures, 2);
    assert!(
        rep.decisions.iter().all(|d| d.decision == "substitute"),
        "{:?}",
        rep.decisions.iter().map(|d| d.decision).collect::<Vec<_>>()
    );
    assert!(rep.converged, "relres={}", rep.final_relres);
    assert_eq!(
        rep.ranks.iter().filter(|r| r.was_spare && r.iterations > 0).count(),
        2,
        "both spares adopted the failed slots"
    );
}

/// rs2 reconstruction is bit-exact: a single failure restores the same
/// committed state as mirror:1, so the post-recovery iteration history is
/// identical — and rs2+delta composes the same way.
#[test]
fn rs2_restores_the_same_committed_state_as_mirror() {
    let mirror = coordinator::run(&with_scheme(
        quick_config(8, Strategy::Shrink, 1),
        Scheme::Mirror { k: 1 },
        false,
    ))
    .unwrap();
    for delta in [false, true] {
        let rs2 = coordinator::run(&with_scheme(
            quick_config(8, Strategy::Shrink, 1),
            Scheme::Rs2 { g: 4 },
            delta,
        ))
        .unwrap();
        assert_eq!(rs2.failures, 1);
        assert!(rs2.converged, "delta={delta}: relres={}", rs2.final_relres);
        assert_eq!(
            mirror.iterations, rs2.iterations,
            "delta={delta}: same restored state, same history"
        );
    }
}

/// Holder rotation actually happens: over a failure-free rs2+delta run the
/// per-commit rotation index advances through at least three distinct
/// epochs, and every commit records its rotation position.
#[test]
fn rs2_rotation_advances_across_commits() {
    let mut cfg =
        with_scheme(quick_config(8, Strategy::Shrink, 0), Scheme::Rs2 { g: 4 }, true);
    cfg.solver.ckpt.rebase_every = 4;
    let rep = coordinator::run(&cfg).unwrap();
    assert!(rep.converged);
    let rotations: std::collections::BTreeSet<i64> =
        rep.ckpt.iter().map(|c| c.rotation).collect();
    assert!(!rotations.contains(&-1), "every rs2 commit records its rotation");
    assert!(
        rotations.len() >= 3,
        "rotation must sweep >= 3 epochs over the run, got {rotations:?}"
    );
    // Rotation follows version / rebase_every exactly.
    for c in &rep.ckpt {
        assert_eq!(c.rotation, c.version / 4, "version {}", c.version);
    }
    // Non-rotating schemes record -1.
    let xor =
        coordinator::run(&with_scheme(quick_config(8, Strategy::Shrink, 0), Scheme::Xor { g: 4 }, false))
            .unwrap();
    assert!(xor.ckpt.iter().all(|c| c.rotation == -1));
}

/// Compression round-trip property test on random sparse deltas: RLE
/// encode/decode is the identity on word streams, never expands beyond the
/// documented bound, and collapses sparse vectors.
#[test]
fn compression_roundtrips_random_sparse_deltas() {
    let mut rng = Rng::new(0xC0FFEE);
    for case in 0..200 {
        let n = rng.below(600);
        let density_pct = rng.below(100);
        let words: Vec<i64> = (0..n)
            .map(|_| {
                if rng.below(100) < density_pct {
                    // Mix of arbitrary values and short repeats.
                    if rng.below(4) == 0 {
                        7
                    } else {
                        rng.next_u64() as i64
                    }
                } else {
                    0
                }
            })
            .collect();
        let toks = rle_compress(&words);
        assert!(toks.len() <= words.len() + 2, "case {case}: bound violated");
        assert_eq!(rle_decompress(&toks), words, "case {case}: roundtrip broke");
    }
    // Blob envelope: bit-exact f64 lane, exact i lane, preserved factor.
    for case in 0..50 {
        let nf = rng.below(300);
        let ni = rng.below(50);
        let f: Vec<f64> = (0..nf)
            .map(|_| if rng.below(3) == 0 { 0.0 } else { rng.f64() })
            .collect();
        let i: Vec<i64> = (0..ni).map(|_| rng.next_u64() as i64 % 9).collect();
        let blob = Blob::new(f, i).scaled(1.0 + rng.below(40) as f64);
        let out = decompress_blob(&compress_blob(&blob));
        assert_eq!(out.i, blob.i, "case {case}");
        assert_eq!(out.f.len(), blob.f.len());
        for (x, y) in out.f.iter().zip(&blob.f) {
            assert_eq!(x.to_bits(), y.to_bits(), "case {case}: f64 bits changed");
        }
        assert_eq!(out.bytes(), blob.bytes(), "case {case}: charged size changed");
    }
}

/// Compression is transport-only: the solve (and its answer) is identical
/// with and without `ckpt_compress`, recoveries still work, and the
/// recorded raw bytes of the compressed run equal the shipped bytes of the
/// uncompressed one.  On the parity schemes with coarse chunks the wire
/// bill drops hard — zero-run elision recovers word-granular deltas from
/// chunk-granular shipping (`old ^ new` zeroes every unchanged word inside
/// a changed chunk); mirror deltas carry *new* words, so there compression
/// is only asserted not to blow up the bill.
#[test]
fn compression_changes_transport_not_math() {
    for scheme in [Scheme::Mirror { k: 1 }, Scheme::Xor { g: 4 }, Scheme::Rs2 { g: 4 }] {
        let parity = scheme != Scheme::Mirror { k: 1 };
        let mut base = with_scheme(quick_config(8, Strategy::Shrink, 1), scheme, true);
        if parity {
            // Coarse chunks: the uncompressed wire pays the chunk padding,
            // compression elides it.
            base.solver.ckpt.chunk_kib = 32;
        }
        let plain = coordinator::run(&base).unwrap();
        let mut cfg = base.clone();
        cfg.solver.ckpt.compress = true;
        let comp = coordinator::run(&cfg).unwrap();
        assert!(plain.converged && comp.converged, "{scheme:?}");
        assert_eq!(
            plain.iterations, comp.iterations,
            "{scheme:?}: compression must not change the math"
        );
        let (plain_shipped, _, plain_commits) = plain.ckpt_totals();
        let (comp_shipped, _, comp_commits) = comp.ckpt_totals();
        assert_eq!(plain_commits, comp_commits);
        assert_eq!(
            comp.ckpt_raw_bytes(),
            plain_shipped,
            "{scheme:?}: raw accounting must match the uncompressed wire bill"
        );
        if parity {
            assert!(
                10 * comp_shipped < 9 * plain_shipped,
                "{scheme:?}: compression must cut the parity wire bill by >10% \
                 ({comp_shipped} vs {plain_shipped})"
            );
        } else {
            assert!(
                comp_shipped <= plain_shipped + plain_shipped / 10,
                "{scheme:?}: compression overhead must stay marginal \
                 ({comp_shipped} vs {plain_shipped})"
            );
        }
        // Uncompressed runs report raw == shipped.
        assert_eq!(plain.ckpt_raw_bytes(), plain_shipped, "{scheme:?}");
    }
}

/// Checkpoint metrics land in the run report: commits are recorded with
/// positive logical and shipped bytes under every scheme.
#[test]
fn ckpt_records_populate_the_report() {
    for (scheme, delta) in [
        (Scheme::Mirror { k: 1 }, false),
        (Scheme::Mirror { k: 2 }, false),
        (Scheme::Xor { g: 4 }, false),
        (Scheme::Xor { g: 4 }, true),
        (Scheme::Rs2 { g: 4 }, false),
        (Scheme::Rs2 { g: 4 }, true),
    ] {
        let rep =
            coordinator::run(&with_scheme(quick_config(8, Strategy::Shrink, 0), scheme, delta))
                .unwrap();
        let (shipped, logical, commits) = rep.ckpt_totals();
        assert!(commits > 1, "{scheme:?}: establishment + dynamic commits");
        assert!(logical > 0 && shipped > 0, "{scheme:?}");
        // mirror:2 ships two copies of everything; rs2 one contribution
        // plus the amortized group-level Q forward (~(1 + 1/g) x state);
        // everyone else at most one copy's worth.
        if scheme == (Scheme::Mirror { k: 2 }) {
            assert!(shipped > logical, "{scheme:?}: k=2 ships 2x state");
        } else if matches!(scheme, Scheme::Rs2 { .. }) {
            assert!(
                2 * shipped <= 3 * logical,
                "{scheme:?}: double parity stays well under 1.5x state \
                 ({shipped} vs {logical})"
            );
        } else {
            assert!(shipped <= logical + logical / 8, "{scheme:?}: at most ~1x state");
        }
    }
}
