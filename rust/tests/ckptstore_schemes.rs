//! End-to-end checkpoint-store scheme tests: xor parity recovery through
//! both in-situ strategies, delta commits, and group-failure escalation to
//! a global restart (DESIGN.md §8).

mod common;

use std::sync::Arc;

use common::quick_config;
use ulfm_ftgmres::backend::native::NativeBackend;
use ulfm_ftgmres::ckptstore::Scheme;
use ulfm_ftgmres::config::RunConfig;
use ulfm_ftgmres::coordinator;
use ulfm_ftgmres::failure::InjectionPlan;
use ulfm_ftgmres::metrics::RunReport;
use ulfm_ftgmres::recovery::Strategy;

fn with_scheme(mut cfg: RunConfig, scheme: Scheme, delta: bool) -> RunConfig {
    cfg.solver.ckpt.scheme = scheme;
    cfg.solver.ckpt.delta = delta;
    cfg
}

fn run_with_plan(cfg: &RunConfig, plan: InjectionPlan) -> RunReport {
    let backend = Arc::new(NativeBackend::new(cfg.compute.clone()));
    coordinator::run_custom(cfg, backend, plan).expect("run completes")
}

/// A single in-group failure under xor:4 reconstructs from parity and the
/// shrink recovery restores the *same* committed state as mirror:1 — the
/// iteration sequence afterwards is bit-identical.
#[test]
fn xor_shrink_restores_the_same_committed_state_as_mirror() {
    let mirror = coordinator::run(&with_scheme(
        quick_config(8, Strategy::Shrink, 1),
        Scheme::Mirror { k: 1 },
        false,
    ))
    .unwrap();
    let xor = coordinator::run(&with_scheme(
        quick_config(8, Strategy::Shrink, 1),
        Scheme::Xor { g: 4 },
        false,
    ))
    .unwrap();
    assert_eq!(mirror.failures, 1);
    assert_eq!(xor.failures, 1);
    assert!(mirror.converged && xor.converged);
    assert!(mirror.final_relres < 1e-10 && xor.final_relres < 1e-10);
    // Parity reconstruction is bit-exact, so the restored state and hence
    // the whole post-recovery iteration history must match.
    assert_eq!(mirror.iterations, xor.iterations);
    assert!(
        (mirror.final_relres - xor.final_relres).abs() <= 1e-14,
        "mirror {} vs xor {}",
        mirror.final_relres,
        xor.final_relres
    );
}

/// Substitute recovery under xor: the parity holder reconstructs the failed
/// rank's objects and serves them to the spare.
#[test]
fn xor_substitute_single_failure_converges() {
    let cfg = with_scheme(
        quick_config(8, Strategy::Substitute, 1),
        Scheme::Xor { g: 4 },
        false,
    );
    let rep = coordinator::run(&cfg).unwrap();
    assert_eq!(rep.failures, 1);
    assert!(rep.converged, "relres={}", rep.final_relres);
    assert!(
        rep.ranks.iter().any(|r| r.was_spare && r.iterations > 0),
        "spare must have been used"
    );
}

/// One failure per parity group across separate events: each loss is
/// covered by its stripe and the re-encode between events restores full
/// redundancy, so the campaign survives failures in every group.
#[test]
fn xor_cross_group_campaign_recovers_in_situ() {
    let cfg = with_scheme(quick_config(8, Strategy::Shrink, 2), Scheme::Xor { g: 4 }, false);
    let plan = InjectionPlan::cross_group_campaign(8, 4, 2, cfg.solver.m_inner as u64);
    let rep = run_with_plan(&cfg, plan);
    assert_eq!(rep.failures, 2);
    assert!(rep.converged, "relres={}", rep.final_relres);
    assert!(rep.final_relres < 1e-10);
    let names: Vec<&str> = rep.decisions.iter().map(|d| d.decision).collect();
    assert_eq!(names, vec!["shrink", "shrink"], "both events recovered in situ");
}

/// The delta layer changes transport only: the solve (and its answer) is
/// identical, while the redundancy bytes shipped drop by a lot.
#[test]
fn delta_cuts_shipped_bytes_without_changing_the_answer() {
    let full =
        coordinator::run(&with_scheme(quick_config(4, Strategy::Shrink, 0), Scheme::Mirror { k: 1 }, false))
            .unwrap();
    let delta =
        coordinator::run(&with_scheme(quick_config(4, Strategy::Shrink, 0), Scheme::Mirror { k: 1 }, true))
            .unwrap();
    assert!(full.converged && delta.converged);
    assert_eq!(full.iterations, delta.iterations, "transport must not change the math");
    assert!((full.final_relres - delta.final_relres).abs() <= 1e-14);
    let (full_shipped, full_logical, full_commits) = full.ckpt_totals();
    let (delta_shipped, delta_logical, delta_commits) = delta.ckpt_totals();
    assert_eq!(full_commits, delta_commits);
    assert_eq!(full_logical, delta_logical);
    assert!(full_shipped > 0 && delta_shipped > 0);
    assert!(
        2 * delta_shipped < full_shipped,
        "delta must at least halve shipped bytes: {delta_shipped} vs {full_shipped}"
    );
    // Delta survives recovery too: same campaign with one failure.
    let rec =
        coordinator::run(&with_scheme(quick_config(8, Strategy::Shrink, 1), Scheme::Mirror { k: 1 }, true))
            .unwrap();
    assert!(rec.converged, "relres={}", rec.final_relres);
    assert!(rec.final_relres < 1e-10);
}

/// xor + delta compose: parity contributions ship as chunk deltas and a
/// failure still reconstructs the exact committed state.
#[test]
fn xor_delta_recovers_after_failure() {
    let cfg = with_scheme(quick_config(8, Strategy::Shrink, 1), Scheme::Xor { g: 4 }, true);
    let rep = coordinator::run(&cfg).unwrap();
    assert_eq!(rep.failures, 1);
    assert!(rep.converged, "relres={}", rep.final_relres);
    assert!(rep.final_relres < 1e-10);
    let mirror = coordinator::run(&with_scheme(
        quick_config(8, Strategy::Shrink, 1),
        Scheme::Mirror { k: 1 },
        false,
    ))
    .unwrap();
    assert_eq!(rep.iterations, mirror.iterations, "same restored state, same history");
}

/// Two simultaneous failures inside one parity group before any re-encode:
/// the loss is unrecoverable in situ and must deterministically escalate to
/// a recorded `GlobalRestart` — and the run must still produce the right
/// answer (survivors rebuild from scratch), not a wrong one or a hang.
#[test]
fn same_group_double_failure_escalates_to_global_restart() {
    let cfg = with_scheme(quick_config(8, Strategy::Shrink, 0), Scheme::Xor { g: 4 }, false);
    let plan = InjectionPlan::same_group_burst(8, 4, 1, 2, 25);
    let rep = run_with_plan(&cfg, plan);
    assert_eq!(rep.failures, 2, "both kills fired");
    assert_eq!(rep.decisions.len(), 1, "one event");
    assert_eq!(rep.decisions[0].decision, "global-restart");
    assert!(
        rep.decisions[0].reason.contains("unrecoverable"),
        "escalation reason recorded: {}",
        rep.decisions[0].reason
    );
    assert!(rep.converged, "restarted run must still converge: relres={}", rep.final_relres);
    assert!(rep.final_relres < 1e-10, "and produce the right answer");
}

/// Losing a group member together with that group's parity holder is just
/// as fatal as two in-group losses: escalate, restart, converge.
#[test]
fn member_plus_holder_failure_escalates() {
    let cfg = with_scheme(quick_config(8, Strategy::Shrink, 0), Scheme::Xor { g: 4 }, false);
    // Rank 5 is in group 1; rank 0 holds group 1's parity stripe.
    let plan = InjectionPlan::burst(&[0, 5], 25);
    let rep = run_with_plan(&cfg, plan);
    assert_eq!(rep.failures, 2);
    assert_eq!(rep.decisions[0].decision, "global-restart");
    assert!(rep.decisions[0].reason.contains("unrecoverable"));
    assert!(rep.converged, "relres={}", rep.final_relres);
}

/// Under mirror:1, losing a rank and its only buddy likewise escalates
/// instead of panicking mid-redistribution.
#[test]
fn adjacent_pair_loss_under_mirror1_escalates() {
    let cfg = with_scheme(quick_config(8, Strategy::Shrink, 0), Scheme::Mirror { k: 1 }, false);
    let plan = InjectionPlan::burst(&[3, 4], 25);
    let rep = run_with_plan(&cfg, plan);
    assert_eq!(rep.failures, 2);
    assert_eq!(rep.decisions[0].decision, "global-restart");
    assert!(rep.decisions[0].reason.contains("unrecoverable"));
    assert!(rep.converged, "relres={}", rep.final_relres);
}

/// Checkpoint metrics land in the run report: commits are recorded with
/// positive logical and shipped bytes under every scheme.
#[test]
fn ckpt_records_populate_the_report() {
    for (scheme, delta) in [
        (Scheme::Mirror { k: 1 }, false),
        (Scheme::Mirror { k: 2 }, false),
        (Scheme::Xor { g: 4 }, false),
        (Scheme::Xor { g: 4 }, true),
    ] {
        let rep =
            coordinator::run(&with_scheme(quick_config(8, Strategy::Shrink, 0), scheme, delta))
                .unwrap();
        let (shipped, logical, commits) = rep.ckpt_totals();
        assert!(commits > 1, "{scheme:?}: establishment + dynamic commits");
        assert!(logical > 0 && shipped > 0, "{scheme:?}");
        // mirror:2 ships two copies of everything; everyone else at most
        // one copy's worth.
        if scheme == (Scheme::Mirror { k: 2 }) {
            assert!(shipped > logical, "{scheme:?}: k=2 ships 2x state");
        } else {
            assert!(shipped <= logical + logical / 8, "{scheme:?}: at most ~1x state");
        }
    }
}
