//! Property tests pinning the widened GF(2^8) kernels (DESIGN.md §11) to
//! the bytewise log/exp reference: every coefficient, random words, slice
//! lengths straddling the SWAR/table/SIMD cutover and vector tails, and
//! two-erasure solve round-trips.  The widened kernels carry the `rs2`
//! Q-stripe encode and the in-situ double-erasure recovery, so a single
//! wrong byte here is silent checkpoint corruption.

mod common;

use common::Rng;
use ulfm_ftgmres::ckptstore::delta::xor_into;
use ulfm_ftgmres::ckptstore::gf256::{
    coef, div_words, gdiv, gmul, mul_word, mul_word_bytewise, mul_xor_into,
    mul_xor_into_bytewise, solve_two_erasures, solve_two_erasures_bytewise, WideMul,
};

#[test]
fn every_coefficient_matches_bytewise_on_random_words() {
    let mut rng = Rng::new(0xC0FFEE);
    let words: Vec<i64> = (0..64).map(|_| rng.next_u64() as i64).collect();
    for c in 0..=255u8 {
        let wm = WideMul::new(c);
        assert_eq!(wm.coef(), c);
        let tab = wm.table();
        for &w in &words {
            let want = mul_word_bytewise(w, c);
            assert_eq!(wm.mul(w), want, "SWAR kernel diverged at c={c}, w={w:#018x}");
            assert_eq!(mul_word(w, c), want, "mul_word diverged at c={c}");
            // The byte table is exactly gmul against this coefficient.
            let b = (w & 0xff) as u8;
            assert_eq!(tab[b as usize], gmul(b, c), "table entry c={c} b={b}");
        }
    }
}

#[test]
fn slice_kernel_matches_bytewise_for_all_lengths_and_coefficients() {
    let mut rng = Rng::new(7);
    // Lengths cover: empty, below the table cutover, exactly at it, above
    // it with every SIMD tail residue (the AVX2 path works 4 words at a
    // time), and a large block.
    for len in [0usize, 1, 2, 7, 31, 63, 64, 65, 66, 67, 68, 127, 500] {
        let words: Vec<i64> = (0..len).map(|_| rng.next_u64() as i64).collect();
        let seed: Vec<i64> = (0..len / 2).map(|_| rng.next_u64() as i64).collect();
        for c in [0u8, 1, 2, 3, 0x1d, 0x35, 0x80, 0xfd, 0xff] {
            let mut wide = seed.clone();
            let mut byte = seed.clone();
            mul_xor_into(&mut wide, &words, c);
            mul_xor_into_bytewise(&mut byte, &words, c);
            assert_eq!(wide, byte, "len={len} c={c}");
        }
    }
}

#[test]
fn div_words_inverts_mul_for_every_nonzero_coefficient() {
    let mut rng = Rng::new(99);
    let original: Vec<i64> = (0..130).map(|_| rng.next_u64() as i64).collect();
    for c in 1..=255u8 {
        let mut scaled = vec![0i64; original.len()];
        mul_xor_into(&mut scaled, &original, c);
        div_words(&mut scaled, c);
        assert_eq!(scaled, original, "div_words(mul(c)) != id at c={c}");
    }
}

#[test]
fn two_erasure_solve_round_trips_across_slot_pairs() {
    let mut rng = Rng::new(2026);
    // A parity group of 6 members with ragged lengths; every failed-slot
    // pair must solve back to the original payloads through both the
    // widened and the bytewise solver.
    let members: Vec<Vec<i64>> = (0..6)
        .map(|k| (0..80 + 13 * k).map(|_| rng.next_u64() as i64).collect())
        .collect();
    let mut pp: Vec<i64> = Vec::new();
    let mut qq: Vec<i64> = Vec::new();
    for (k, m) in members.iter().enumerate() {
        xor_into(&mut pp, m);
        mul_xor_into(&mut qq, m, coef(k));
    }
    for i in 0..members.len() {
        for j in i + 1..members.len() {
            // Fold every survivor back out of both stripes.
            let mut p = pp.clone();
            let mut q = qq.clone();
            for (k, m) in members.iter().enumerate() {
                if k != i && k != j {
                    xor_into(&mut p, m);
                    mul_xor_into(&mut q, m, coef(k));
                }
            }
            let (mi, mj) = solve_two_erasures(&p, &q, coef(i), coef(j));
            assert_eq!(&mi[..members[i].len()], &members[i][..], "pair ({i},{j})");
            assert_eq!(&mj[..members[j].len()], &members[j][..], "pair ({i},{j})");
            assert!(mi[members[i].len()..].iter().all(|&w| w == 0), "pad ({i},{j})");
            let (bi, bj) = solve_two_erasures_bytewise(&p, &q, coef(i), coef(j));
            assert_eq!(mi, bi, "widened vs bytewise solve, pair ({i},{j})");
            assert_eq!(mj, bj, "widened vs bytewise solve, pair ({i},{j})");
        }
    }
}

#[test]
fn single_erasure_via_q_alone_matches_reference_division() {
    let mut rng = Rng::new(4);
    let members: Vec<Vec<i64>> =
        (0..4).map(|_| (0..200).map(|_| rng.next_u64() as i64).collect()).collect();
    for lost in 0..members.len() {
        let mut q: Vec<i64> = Vec::new();
        for (k, m) in members.iter().enumerate() {
            mul_xor_into(&mut q, m, coef(k));
        }
        for (k, m) in members.iter().enumerate() {
            if k != lost {
                mul_xor_into(&mut q, m, coef(k));
            }
        }
        // Widened in-place division...
        let mut wide = q.clone();
        div_words(&mut wide, coef(lost));
        // ...against the bytewise inverse multiply.
        let inv = gdiv(1, coef(lost));
        let byte: Vec<i64> = q.iter().map(|&w| mul_word_bytewise(w, inv)).collect();
        assert_eq!(wide, byte, "lost={lost}");
        assert_eq!(wide, members[lost], "lost={lost}: wrong payload recovered");
    }
}
