//! Fleet contention semantics (DESIGN.md §16): two jobs racing for the
//! last warm spare resolve deterministically (priority first, job id on
//! ties), the loser degrades to shrink with a recorded `fleet-preempt`
//! reason, and a failure-concentrated victim job is quarantined by its
//! circuit breaker — one recorded global restart, zero unintended global
//! restarts anywhere else in the fleet.

mod common;

use common::quick_config;
use ulfm_ftgmres::config::RunConfig;
use ulfm_ftgmres::coordinator::fleet::{
    fleet_layout, run_fleet_campaign, run_fleet_custom, FleetReport, FleetSpec,
};
use ulfm_ftgmres::failure::{InjectionPlan, Kill};
use ulfm_ftgmres::recovery::Strategy;

/// Base config for a fleet of 8-rank jobs; the per-job pool dimensions are
/// injected by the fleet driver from the spec, so only the solver shape
/// matters here.
fn fleet_config(spec: &str) -> RunConfig {
    let mut cfg = quick_config(8, Strategy::Shrink, 0);
    cfg.fleet = Some(FleetSpec::parse(spec).unwrap());
    cfg
}

/// One kill at inner iteration 25, job-local rank `r`.
fn kill_plan(r: usize) -> InjectionPlan {
    InjectionPlan { kills: vec![Kill::at_iter(r, 25)], ..Default::default() }
}

fn assert_no_unintended_restarts(frep: &FleetReport, allowed: &[&str]) {
    for j in &frep.jobs {
        if allowed.contains(&j.name.as_str()) {
            continue;
        }
        assert_eq!(
            j.rep.global_restarts(),
            0,
            "job {} must not globally restart: {:?}",
            j.name,
            j.rep.decisions
        );
    }
}

/// Two same-shaped jobs, one warm spare, one failure each at the same
/// inner iteration: the high-priority job wins the spare (substitute), the
/// low-priority job is preempted into a degraded shrink with the blame
/// recorded, and nobody globally restarts.
#[test]
fn last_warm_spare_goes_to_higher_priority_job() {
    let cfg = fleet_config("jobs=urgent,prio=5+batch,prio=1;warm=1;breaker_k=10;breaker_w=1000");
    let frep = run_fleet_custom(&cfg, &[kill_plan(2), kill_plan(2)]).unwrap();

    assert!(frep.jobs.iter().all(|j| j.rep.converged), "both jobs converge");
    assert_eq!(frep.preemptions, 1);
    assert_eq!(frep.quarantines, 0);
    assert_no_unintended_restarts(&frep, &[]);

    // The arbiter saw urgent first (priority order) and granted the spare.
    assert_eq!(frep.arbitrations[0].job_name, "urgent");
    assert_eq!(frep.arbitrations[0].verdict, "granted");
    assert_eq!(frep.arbitrations[0].granted, "substitute");
    // Batch arbitrated into the leased-out pool: preempted, blamed.
    assert_eq!(frep.arbitrations[1].job_name, "batch");
    assert_eq!(frep.arbitrations[1].verdict, "preempted");
    assert_eq!(frep.arbitrations[1].preempted_by.as_deref(), Some("urgent"));
    assert_eq!(frep.arbitrations[1].granted, "shrink");
    assert_eq!(frep.arbitrations[1].warm_free, 0, "pool empty at batch's event");

    // The loser's own decision log records the degraded shrink with the
    // fleet-preempt reason every survivor observed.
    let batch = &frep.jobs[1];
    assert_eq!(batch.name, "batch");
    assert!(
        batch.rep.decisions.iter().any(|d| d.decision == "shrink"
            && d.reason.contains("fleet-preempt")
            && d.reason.contains("urgent")),
        "missing fleet-preempt decision: {:?}",
        batch.rep.decisions
    );
    let urgent = &frep.jobs[0];
    assert!(
        urgent.rep.decisions.iter().any(|d| d.decision == "substitute"),
        "winner substitutes: {:?}",
        urgent.rep.decisions
    );
}

/// Equal priorities: the tie breaks by job id (spec order), so the first
/// job wins the spare and the second is preempted — deterministically.
#[test]
fn tie_priority_breaks_by_job_id() {
    let cfg = fleet_config("jobs=a+b;warm=1;breaker_k=10;breaker_w=1000");
    let frep = run_fleet_custom(&cfg, &[kill_plan(2), kill_plan(2)]).unwrap();
    assert_eq!(frep.arbitrations[0].job_name, "a");
    assert_eq!(frep.arbitrations[0].verdict, "granted");
    assert_eq!(frep.arbitrations[1].job_name, "b");
    assert_eq!(frep.arbitrations[1].verdict, "preempted");
    assert_eq!(frep.arbitrations[1].preempted_by.as_deref(), Some("a"));
    assert_no_unintended_restarts(&frep, &[]);
}

/// The acceptance campaign: three jobs, contended spares (warm=1), repeated
/// failures concentrated on one job.  The victim burns its first two
/// recoveries against the leased-out pool (degraded shrinks), trips the
/// breaker on the third window-local recovery, and is quarantined — one
/// recorded global restart with the breaker-open reason — while every other
/// job converges with zero global restarts.
#[test]
fn breaker_quarantines_repeat_offender() {
    let cfg = fleet_config(
        "jobs=steady,prio=4+victim,prio=2+calm,prio=3;warm=1;breaker_k=3;breaker_w=1000",
    );
    let layout = fleet_layout(&cfg).unwrap();
    assert_eq!(layout[1].0, "victim");
    assert_eq!(layout[1].1, 8..16);

    // Three kills walking the victim's block one checkpoint window apart,
    // plus one failure in steady that takes the only warm spare first.
    let mut plan = InjectionPlan::fleet_concentrated(&layout, 1, 3, 10);
    plan.kills.push(Kill::at_iter(7, 25));
    let frep = run_fleet_campaign(&cfg, &plan).unwrap();

    let victim = frep.jobs.iter().find(|j| j.name == "victim").unwrap();
    assert!(victim.quarantined, "breaker must quarantine the victim");
    assert_eq!(victim.trips, 1);
    assert_eq!(victim.rep.global_restarts(), 1, "exactly one recorded global restart");
    assert!(victim.rep.converged, "the victim still converges after the restart");
    assert!(
        victim
            .rep
            .decisions
            .iter()
            .any(|d| d.decision == "global-restart" && d.reason.contains("breaker-open")),
        "missing breaker-open escalation: {:?}",
        victim.rep.decisions
    );

    assert_eq!(frep.quarantines, 1);
    assert_eq!(frep.total_trips(), 1);
    assert_no_unintended_restarts(&frep, &["victim"]);
    for name in ["steady", "calm"] {
        let j = frep.jobs.iter().find(|j| j.name == name).unwrap();
        assert!(j.rep.converged, "job {name} converges");
        assert_eq!(j.trips, 0, "job {name} never trips");
    }

    // The quarantine is the victim's last ruling, made against a pool still
    // leased out to steady — contention all the way to the escalation.
    let q = frep.arbitrations.iter().find(|a| a.verdict == "quarantine").unwrap();
    assert_eq!(q.job_name, "victim");
    assert_eq!(q.granted, "global-restart");
    assert_eq!(q.warm_free, 0, "the pool was still contended at the trip");
    // The two pre-trip recoveries were preempted into degraded shrinks.
    let victim_preempts = frep
        .arbitrations
        .iter()
        .filter(|a| a.job_name == "victim" && a.verdict == "preempted")
        .count();
    assert_eq!(victim_preempts, 2);
}

/// Reruns of the same fleet campaign are bit-identical down to the full
/// fleet digest (arbitration ledger, per-job decision logs, virtual
/// clocks): the shared arbiter introduces no scheduling freedom.
#[test]
fn fleet_campaign_is_rerun_stable() {
    let cfg = fleet_config("jobs=urgent,prio=5+batch,prio=1;warm=1;breaker_k=10;breaker_w=1000");
    let digest = || {
        let frep = run_fleet_custom(&cfg, &[kill_plan(2), kill_plan(2)]).unwrap();
        frep.digest()
    };
    let first = digest();
    assert!(first.contains("verdict=preempted"), "contention present:\n{first}");
    for rerun in 0..2 {
        assert_eq!(first, digest(), "fleet rerun {rerun} diverged");
    }
}
