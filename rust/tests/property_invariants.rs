//! Property-based tests (hand-rolled shrink-less quickcheck on SplitMix64 —
//! the offline environment has no proptest crate): coordinator-level
//! invariants on partitioning, redistribution planning, halo symmetry,
//! checkpoint blobs and the small dense solver.

mod common;

use common::Rng;
use ulfm_ftgmres::backend::native::NativeBackend;
use ulfm_ftgmres::backend::{Backend, DenseBasis};
use ulfm_ftgmres::problem::{sources, EllBlock, Grid3D, MatrixRows, Partition};
use ulfm_ftgmres::ckptstore::Scheme;
use ulfm_ftgmres::recovery::plan::{my_transfers, transfer_segments_scheme};
use ulfm_ftgmres::simmpi::Blob;
use ulfm_ftgmres::solver::givens::GivensLs;

const CASES: usize = 60;

#[test]
fn prop_partition_covers_and_is_monotone() {
    let mut rng = Rng::new(1);
    for _ in 0..CASES {
        let p = 1 + rng.below(40);
        let n = p * (1 + rng.below(50)) + rng.below(p);
        if n < p {
            continue;
        }
        let part = Partition::balanced(n, p);
        assert_eq!(part.n(), n);
        let mut total = 0;
        for r in 0..p {
            let range = part.range(r);
            total += range.len();
            // Balanced within 1.
            assert!(range.len() >= n / p && range.len() <= n / p + 1);
            for row in range.clone() {
                assert_eq!(part.owner(row), r);
            }
        }
        assert_eq!(total, n);
    }
}

#[test]
fn prop_sources_exactly_cover_any_interval() {
    let mut rng = Rng::new(2);
    for _ in 0..CASES {
        let p = 2 + rng.below(20);
        let n = p * (2 + rng.below(30));
        let part = Partition::balanced(n, p);
        let a = rng.below(n);
        let b = a + rng.below(n - a + 1);
        let srcs = sources(&part, a..b);
        let mut row = a;
        for s in &srcs {
            assert_eq!(s.rows.start, row, "gapless");
            assert!(s.rows.end <= b);
            row = s.rows.end;
        }
        assert_eq!(row, b, "complete cover");
    }
}

#[test]
fn prop_transfer_segments_cover_once_with_random_failures() {
    let mut rng = Rng::new(3);
    for _ in 0..CASES {
        let p_old = 3 + rng.below(20);
        let n = p_old * (4 + rng.below(20));
        let dead_cr = rng.below(p_old);
        let old_members: Vec<usize> = (0..p_old).collect();
        let new_members: Vec<usize> =
            (0..p_old).filter(|&r| r != dead_cr).collect();
        let old = Partition::balanced(n, p_old);
        let new = Partition::balanced(n, p_old - 1);
        let alive = move |r: usize| r != dead_cr;
        let segs = transfer_segments_scheme(
            &old,
            &old_members,
            &new,
            &new_members,
            &alive,
            &Scheme::Mirror { k: 1 },
            1,
        );
        // 1. Exact cover.
        let mut seen = vec![false; n];
        for s in &segs {
            for r in s.rows.clone() {
                assert!(!seen[r]);
                seen[r] = true;
            }
        }
        assert!(seen.iter().all(|&x| x));
        // 2. No dead server or destination.
        for s in &segs {
            assert!(alive(s.server_wr));
            assert!(alive(s.dest_wr));
        }
        // 3. Per-rank views partition the list.
        let mut claimed = 0;
        for &me in &new_members {
            let t = my_transfers(&segs, me);
            claimed += t.incoming.len() + t.local.len();
        }
        assert_eq!(claimed, segs.len());
    }
}

#[test]
fn prop_halo_plans_symmetric_on_random_grids() {
    let mut rng = Rng::new(4);
    for _ in 0..20 {
        let g = Grid3D {
            nx: 2 + rng.below(6),
            ny: 2 + rng.below(6),
            nz: 2 + rng.below(12),
        };
        let p = 2 + rng.below(6.min(g.n() / 4));
        if g.n() < 4 * p {
            continue;
        }
        let part = Partition::balanced(g.n(), p);
        let blocks: Vec<EllBlock> = (0..p)
            .map(|r| {
                let range = part.range(r);
                let m = MatrixRows::generate(&g, range.start, range.len());
                EllBlock::build(&m, &part, r)
            })
            .collect();
        for (a, ba) in blocks.iter().enumerate() {
            for nb in &ba.neighbors {
                let back = blocks[nb.cr]
                    .neighbors
                    .iter()
                    .find(|x| x.cr == a)
                    .unwrap_or_else(|| panic!("asymmetric {a}<->{}", nb.cr));
                assert_eq!(nb.send_rows.len(), back.recv_count);
                assert_eq!(back.send_rows.len(), nb.recv_count);
            }
        }
    }
}

#[test]
fn prop_matrix_rows_slice_concat_roundtrip() {
    let mut rng = Rng::new(5);
    for _ in 0..CASES {
        let g = Grid3D::cube(2 + rng.below(8));
        let n = g.n();
        let start = rng.below(n / 2);
        let rows = 1 + rng.below(n - start);
        let m = MatrixRows::generate(&g, start, rows);
        // Split at random interior points and reassemble.
        let cut1 = start + rng.below(rows + 1);
        let pieces = vec![m.slice(start, cut1), m.slice(cut1, start + rows)];
        let pieces: Vec<MatrixRows> =
            pieces.into_iter().filter(|p| p.rows > 0).collect();
        if pieces.is_empty() {
            continue;
        }
        assert_eq!(MatrixRows::concat(pieces), m);
        // Blob roundtrip.
        assert_eq!(MatrixRows::from_blob(&m.to_blob()), m);
    }
}

#[test]
fn prop_blob_scaled_wire_size() {
    let mut rng = Rng::new(6);
    for _ in 0..CASES {
        let nf = rng.below(100);
        let ni = rng.below(100);
        let b = Blob::new(vec![0.0; nf], vec![0; ni]);
        let base = 8 * (nf + ni);
        assert_eq!(b.bytes(), base);
        let s = 1.0 + rng.below(50) as f64;
        assert_eq!(b.clone().scaled(s).bytes(), (base as f64 * s) as usize);
    }
}

#[test]
fn prop_givens_matches_normal_equations() {
    let mut rng = Rng::new(7);
    for _ in 0..30 {
        let m = 2 + rng.below(6);
        let beta = 0.5 + rng.below(10) as f64;
        // Random upper-Hessenberg with dominant subdiagonal (well-posed).
        let mut cols: Vec<Vec<f64>> = Vec::new();
        for j in 0..m {
            let mut c: Vec<f64> = (0..j + 2).map(|_| rng.f64()).collect();
            c[j] += 3.0;
            c[j + 1] += 1.5;
            cols.push(c);
        }
        let mut ls = GivensLs::new(m, beta);
        let mut prev = beta;
        for c in &cols {
            let r = ls.push_col(c);
            assert!(r <= prev + 1e-9, "residual monotone");
            prev = r;
        }
        let y = ls.solve_y();
        // Residual check: ||beta e1 - H y|| == ls.residual().
        let mut r = vec![0.0; m + 1];
        r[0] = beta;
        for (j, c) in cols.iter().enumerate() {
            for (i, &h) in c.iter().enumerate() {
                r[i] -= h * y[j];
            }
        }
        let norm = r.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(
            (norm - ls.residual()).abs() < 1e-8 * (1.0 + norm),
            "givens residual {} vs direct {}",
            ls.residual(),
            norm
        );
        // Roundtrip through the checkpoint flattening.
        let ls2 = GivensLs::from_flat(&ls.to_flat());
        assert_eq!(ls2.solve_y(), y);
    }
}

#[test]
fn prop_native_backend_linearity_and_masks() {
    let mut rng = Rng::new(8);
    let be = NativeBackend::default();
    for _ in 0..30 {
        let r = 16 + rng.below(200);
        let m = 3 + rng.below(8);
        let m_used = 1 + rng.below(m - 1);
        let mut v = DenseBasis::zeros(m, r);
        for j in 0..m {
            for i in 0..r {
                v.row_mut(j)[i] = rng.f64();
            }
        }
        let w: Vec<f64> = (0..r).map(|_| rng.f64()).collect();
        let mut h = vec![0.0; m];
        be.dot_partials(&v, m_used, &w, &mut h);
        // Masked slots zero.
        for &x in &h[m_used..] {
            assert_eq!(x, 0.0);
        }
        // update_w with those h must reduce the norm (projection).
        let nsq_before: f64 = w.iter().map(|x| x * x).sum();
        let mut w2 = w.clone();
        let (_nsq1, _) = be.update_w(&v, m_used, &mut w2, &h);
        // CGS with a random (non-orthonormal) basis doesn't guarantee a
        // decrease, but the fused nsq must equal the actual norm.
        let manual: f64 = w2.iter().map(|x| x * x).sum();
        let (nsq, _) = be.update_w(&v, 0, &mut w2.clone(), &h); // no-op path
        assert!((nsq - manual).abs() <= 1e-9 * (1.0 + manual));
        let _ = nsq_before;
    }
}
