//! Property-based tests (hand-rolled shrink-less quickcheck on SplitMix64 —
//! the offline environment has no proptest crate): coordinator-level
//! invariants on partitioning, redistribution planning, halo symmetry,
//! checkpoint blobs and the small dense solver.

mod common;

use common::{quick_config, Rng};
use ulfm_ftgmres::backend::native::NativeBackend;
use ulfm_ftgmres::backend::{Backend, DenseBasis};
use ulfm_ftgmres::ckptstore::{chunk_sums, delta, Scheme};
use ulfm_ftgmres::coordinator;
use ulfm_ftgmres::failure::{BitFlip, InjectionPlan};
use ulfm_ftgmres::problem::{sources, EllBlock, Grid3D, MatrixRows, Partition};
use ulfm_ftgmres::recovery::plan::{my_transfers, transfer_segments_scheme};
use ulfm_ftgmres::recovery::Strategy;
use ulfm_ftgmres::simmpi::Blob;
use ulfm_ftgmres::solver::givens::GivensLs;

const CASES: usize = 60;

#[test]
fn prop_partition_covers_and_is_monotone() {
    let mut rng = Rng::new(1);
    for _ in 0..CASES {
        let p = 1 + rng.below(40);
        let n = p * (1 + rng.below(50)) + rng.below(p);
        if n < p {
            continue;
        }
        let part = Partition::balanced(n, p);
        assert_eq!(part.n(), n);
        let mut total = 0;
        for r in 0..p {
            let range = part.range(r);
            total += range.len();
            // Balanced within 1.
            assert!(range.len() >= n / p && range.len() <= n / p + 1);
            for row in range.clone() {
                assert_eq!(part.owner(row), r);
            }
        }
        assert_eq!(total, n);
    }
}

#[test]
fn prop_sources_exactly_cover_any_interval() {
    let mut rng = Rng::new(2);
    for _ in 0..CASES {
        let p = 2 + rng.below(20);
        let n = p * (2 + rng.below(30));
        let part = Partition::balanced(n, p);
        let a = rng.below(n);
        let b = a + rng.below(n - a + 1);
        let srcs = sources(&part, a..b);
        let mut row = a;
        for s in &srcs {
            assert_eq!(s.rows.start, row, "gapless");
            assert!(s.rows.end <= b);
            row = s.rows.end;
        }
        assert_eq!(row, b, "complete cover");
    }
}

#[test]
fn prop_transfer_segments_cover_once_with_random_failures() {
    let mut rng = Rng::new(3);
    for _ in 0..CASES {
        let p_old = 3 + rng.below(20);
        let n = p_old * (4 + rng.below(20));
        let dead_cr = rng.below(p_old);
        let old_members: Vec<usize> = (0..p_old).collect();
        let new_members: Vec<usize> =
            (0..p_old).filter(|&r| r != dead_cr).collect();
        let old = Partition::balanced(n, p_old);
        let new = Partition::balanced(n, p_old - 1);
        let alive = move |r: usize| r != dead_cr;
        let segs = transfer_segments_scheme(
            &old,
            &old_members,
            &new,
            &new_members,
            &alive,
            &Scheme::Mirror { k: 1 },
            1,
        );
        // 1. Exact cover.
        let mut seen = vec![false; n];
        for s in &segs {
            for r in s.rows.clone() {
                assert!(!seen[r]);
                seen[r] = true;
            }
        }
        assert!(seen.iter().all(|&x| x));
        // 2. No dead server or destination.
        for s in &segs {
            assert!(alive(s.server_wr));
            assert!(alive(s.dest_wr));
        }
        // 3. Per-rank views partition the list.
        let mut claimed = 0;
        for &me in &new_members {
            let t = my_transfers(&segs, me);
            claimed += t.incoming.len() + t.local.len();
        }
        assert_eq!(claimed, segs.len());
    }
}

#[test]
fn prop_halo_plans_symmetric_on_random_grids() {
    let mut rng = Rng::new(4);
    for _ in 0..20 {
        let g = Grid3D {
            nx: 2 + rng.below(6),
            ny: 2 + rng.below(6),
            nz: 2 + rng.below(12),
        };
        let p = 2 + rng.below(6.min(g.n() / 4));
        if g.n() < 4 * p {
            continue;
        }
        let part = Partition::balanced(g.n(), p);
        let blocks: Vec<EllBlock> = (0..p)
            .map(|r| {
                let range = part.range(r);
                let m = MatrixRows::generate(&g, range.start, range.len());
                EllBlock::build(&m, &part, r)
            })
            .collect();
        for (a, ba) in blocks.iter().enumerate() {
            for nb in &ba.neighbors {
                let back = blocks[nb.cr]
                    .neighbors
                    .iter()
                    .find(|x| x.cr == a)
                    .unwrap_or_else(|| panic!("asymmetric {a}<->{}", nb.cr));
                assert_eq!(nb.send_rows.len(), back.recv_count);
                assert_eq!(back.send_rows.len(), nb.recv_count);
            }
        }
    }
}

#[test]
fn prop_matrix_rows_slice_concat_roundtrip() {
    let mut rng = Rng::new(5);
    for _ in 0..CASES {
        let g = Grid3D::cube(2 + rng.below(8));
        let n = g.n();
        let start = rng.below(n / 2);
        let rows = 1 + rng.below(n - start);
        let m = MatrixRows::generate(&g, start, rows);
        // Split at random interior points and reassemble.
        let cut1 = start + rng.below(rows + 1);
        let pieces = vec![m.slice(start, cut1), m.slice(cut1, start + rows)];
        let pieces: Vec<MatrixRows> =
            pieces.into_iter().filter(|p| p.rows > 0).collect();
        if pieces.is_empty() {
            continue;
        }
        assert_eq!(MatrixRows::concat(pieces), m);
        // Blob roundtrip.
        assert_eq!(MatrixRows::from_blob(&m.to_blob()), m);
    }
}

#[test]
fn prop_blob_scaled_wire_size() {
    let mut rng = Rng::new(6);
    for _ in 0..CASES {
        let nf = rng.below(100);
        let ni = rng.below(100);
        let b = Blob::new(vec![0.0; nf], vec![0; ni]);
        let base = 8 * (nf + ni);
        assert_eq!(b.bytes(), base);
        let s = 1.0 + rng.below(50) as f64;
        assert_eq!(b.clone().scaled(s).bytes(), (base as f64 * s) as usize);
    }
}

#[test]
fn prop_givens_matches_normal_equations() {
    let mut rng = Rng::new(7);
    for _ in 0..30 {
        let m = 2 + rng.below(6);
        let beta = 0.5 + rng.below(10) as f64;
        // Random upper-Hessenberg with dominant subdiagonal (well-posed).
        let mut cols: Vec<Vec<f64>> = Vec::new();
        for j in 0..m {
            let mut c: Vec<f64> = (0..j + 2).map(|_| rng.f64()).collect();
            c[j] += 3.0;
            c[j + 1] += 1.5;
            cols.push(c);
        }
        let mut ls = GivensLs::new(m, beta);
        let mut prev = beta;
        for c in &cols {
            let r = ls.push_col(c);
            assert!(r <= prev + 1e-9, "residual monotone");
            prev = r;
        }
        let y = ls.solve_y();
        // Residual check: ||beta e1 - H y|| == ls.residual().
        let mut r = vec![0.0; m + 1];
        r[0] = beta;
        for (j, c) in cols.iter().enumerate() {
            for (i, &h) in c.iter().enumerate() {
                r[i] -= h * y[j];
            }
        }
        let norm = r.iter().map(|x| x * x).sum::<f64>().sqrt();
        assert!(
            (norm - ls.residual()).abs() < 1e-8 * (1.0 + norm),
            "givens residual {} vs direct {}",
            ls.residual(),
            norm
        );
        // Roundtrip through the checkpoint flattening.
        let ls2 = GivensLs::from_flat(&ls.to_flat());
        assert_eq!(ls2.solve_y(), y);
    }
}

/// The integrity layer's chunk digests (DESIGN.md §14) must catch *every*
/// 1..4-bit flip in a committed blob, and must localize the damage: the
/// mismatching chunk set is exactly the set of chunks whose words were
/// touched, for chunk sizes from one word to past the blob length.
#[test]
fn prop_chunk_sums_detect_every_small_flip() {
    let mut rng = Rng::new(9);
    for case in 0..CASES {
        let nf = 1 + rng.below(300);
        let ni = rng.below(100);
        let f: Vec<f64> = (0..nf).map(|_| rng.f64()).collect();
        let i: Vec<i64> = (0..ni).map(|_| rng.next_u64() as i64).collect();
        let blob = Blob::new(f, i);
        let cw = [1usize, 7, 64, 512][case % 4];
        let clean = chunk_sums(&blob, cw);
        let (f_len, i_len) = (blob.f.len(), blob.i.len());
        let mut words = delta::pack_words(&blob);
        let nbits = words.len() * 64;
        let k = 1 + rng.below(4);
        let mut flipped = std::collections::BTreeSet::new();
        while flipped.len() < k.min(nbits) {
            flipped.insert(rng.below(nbits));
        }
        for &p in &flipped {
            words[p / 64] ^= 1i64 << (p % 64);
        }
        let corrupt = delta::unpack_words(&words, f_len, i_len);
        let dirty = chunk_sums(&corrupt, cw);
        assert_eq!(clean.len(), dirty.len());
        let mismatched: Vec<usize> =
            (0..clean.len()).filter(|&c| clean[c] != dirty[c]).collect();
        let expected: Vec<usize> = {
            let set: std::collections::BTreeSet<usize> =
                flipped.iter().map(|&p| (p / 64) / cw).collect();
            set.into_iter().collect()
        };
        assert_eq!(
            mismatched, expected,
            "cw={cw} flips={flipped:?}: digests must flag exactly the touched chunks"
        );
    }
}

/// End-to-end scrub property: for every redundancy scheme × delta ×
/// compression combination, a random small bit-flip in the committed
/// solution block is detected at the next commit and repaired — and the
/// repair is bit-identical, which the scrubber itself enforces by only
/// installing blobs whose chunk digests match the recorded ones (a
/// mismatching rebuild escalates instead of counting as repaired, so
/// `detected == repaired` is the bit-identicality assertion).
#[test]
fn prop_scrub_repair_bit_identical_all_schemes() {
    let mut rng = Rng::new(10);
    for scheme in [Scheme::Mirror { k: 1 }, Scheme::Xor { g: 4 }, Scheme::Rs2 { g: 4 }] {
        for combo in 0..4u32 {
            let mut cfg = quick_config(8, Strategy::Shrink, 0);
            cfg.solver.ckpt.scheme = scheme;
            cfg.solver.ckpt.delta = combo & 1 != 0;
            cfg.solver.ckpt.compress = combo & 2 != 0;
            let plan = InjectionPlan {
                bitflips: vec![BitFlip {
                    world_rank: 1 + rng.below(7),
                    at_version: 1,
                    bits: 1 + rng.below(16) as u32,
                }],
                ..Default::default()
            };
            let backend = coordinator::make_backend(&cfg).unwrap();
            let rep = coordinator::run_custom(&cfg, backend, plan.clone()).unwrap();
            let tag = format!(
                "{scheme:?} delta={} compress={}",
                cfg.solver.ckpt.delta, cfg.solver.ckpt.compress
            );
            assert!(rep.converged, "{tag}: corrupted-then-repaired run must converge");
            assert_eq!(rep.failures, 0, "{tag}: scrub repair must not kill anyone");
            assert!(rep.faults.scrub_detected >= 1, "{tag}: flip {plan:?} went undetected");
            assert_eq!(
                rep.faults.scrub_detected, rep.faults.scrub_repaired,
                "{tag}: every detection must be repaired bit-identically in situ"
            );
            assert_eq!(rep.global_restarts(), 0, "{tag}");
        }
    }
}

/// Wire-level corruption repair composes with RLE: XOR-ing a corrupted word
/// stream against parity (clean ^ bad) restores the exact clean words, and
/// the repaired stream round-trips through `rle_compress`/`rle_decompress`
/// to the same tokens and words as the original — corruption leaves no
/// residue in the compression layer.
#[test]
fn prop_rle_roundtrips_corrupted_then_repaired_wires() {
    let mut rng = Rng::new(11);
    for _ in 0..CASES {
        let n = 1 + rng.below(200);
        // Sparse stream (mostly zero runs) so RLE actually compresses.
        let words: Vec<i64> = (0..n)
            .map(|_| if rng.below(4) == 0 { rng.next_u64() as i64 } else { 0 })
            .collect();
        let mut bad = words.clone();
        let nbits = n * 64;
        for _ in 0..1 + rng.below(8) {
            let p = rng.below(nbits);
            bad[p / 64] ^= 1i64 << (p % 64);
        }
        // Parity captures exactly the damage; repair is one XOR fold.
        let mut parity = words.clone();
        delta::xor_into(&mut parity, &bad);
        let mut repaired = bad;
        delta::xor_into(&mut repaired, &parity);
        assert_eq!(repaired, words, "xor repair must be exact");
        assert_eq!(delta::rle_decompress(&delta::rle_compress(&repaired)), words);
        assert_eq!(delta::rle_compress(&repaired), delta::rle_compress(&words));
    }
}

#[test]
fn prop_native_backend_linearity_and_masks() {
    let mut rng = Rng::new(8);
    let be = NativeBackend::default();
    for _ in 0..30 {
        let r = 16 + rng.below(200);
        let m = 3 + rng.below(8);
        let m_used = 1 + rng.below(m - 1);
        let mut v = DenseBasis::zeros(m, r);
        for j in 0..m {
            for i in 0..r {
                v.row_mut(j)[i] = rng.f64();
            }
        }
        let w: Vec<f64> = (0..r).map(|_| rng.f64()).collect();
        let mut h = vec![0.0; m];
        be.dot_partials(&v, m_used, &w, &mut h);
        // Masked slots zero.
        for &x in &h[m_used..] {
            assert_eq!(x, 0.0);
        }
        // update_w with those h must reduce the norm (projection).
        let nsq_before: f64 = w.iter().map(|x| x * x).sum();
        let mut w2 = w.clone();
        let (_nsq1, _) = be.update_w(&v, m_used, &mut w2, &h);
        // CGS with a random (non-orthonormal) basis doesn't guarantee a
        // decrease, but the fused nsq must equal the actual norm.
        let manual: f64 = w2.iter().map(|x| x * x).sum();
        let (nsq, _) = be.update_w(&v, 0, &mut w2.clone(), &h); // no-op path
        assert!((nsq - manual).abs() <= 1e-9 * (1.0 + manual));
        let _ = nsq_before;
    }
}
