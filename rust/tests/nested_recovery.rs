//! Failures *during* recovery (DESIGN.md §10): a second rank dies at a
//! protocol phase of the first failure's recovery — mid-agreement,
//! mid-reconstruction, mid-redistribution, mid-commit or mid-spare-join —
//! and the epoch-fenced restartable recovery protocol must abandon the
//! poisoned attempt, re-agree on the union failure set, and complete in
//! situ: recoverable nested patterns finish with **zero** executed global
//! restarts and a converged solve.

mod common;

use std::sync::Arc;

use common::quick_config;
use ulfm_ftgmres::backend::native::NativeBackend;
use ulfm_ftgmres::ckptstore::Scheme;
use ulfm_ftgmres::config::RunConfig;
use ulfm_ftgmres::coordinator;
use ulfm_ftgmres::failure::{InjectionPlan, Kill, ProtoPhase};
use ulfm_ftgmres::metrics::RunReport;
use ulfm_ftgmres::recovery::Strategy;

fn run_plan(cfg: &RunConfig, plan: InjectionPlan) -> RunReport {
    let backend = Arc::new(NativeBackend::new(cfg.compute.clone()));
    coordinator::run_custom(cfg, backend, plan).expect("run completes")
}

#[test]
fn second_failure_at_reconstruct_recovers_without_restart() {
    // xor:4 over p=8: rank 7 (parity group 1) dies at iteration 25; rank 3
    // (group 0) dies entering the reconstruction of that recovery.  The
    // union is one loss per group — recoverable — so the fenced retry must
    // complete in situ.
    let mut cfg = quick_config(8, Strategy::Shrink, 0);
    cfg.solver.ckpt.scheme = Scheme::Xor { g: 4 };
    let rep = run_plan(&cfg, InjectionPlan::nested(7, 25, 3, ProtoPhase::Reconstruct, 1));
    assert!(rep.converged, "relres={}", rep.final_relres);
    assert_eq!(rep.failures, 2);
    assert_eq!(rep.global_restarts(), 0, "recoverable nested pattern must not restart");
    assert!(rep.recovery_retries >= 1, "the poisoned attempt must be fenced and retried");
    // One executed decision, covering the union failure set, on a retried
    // attempt (abandoned attempts are never logged).
    assert_eq!(rep.decisions.len(), 1, "decisions: {:?}", rep.decisions);
    let d = &rep.decisions[0];
    assert_eq!(d.decision, "shrink");
    assert!(d.attempt >= 1, "the executed decision came from a retry: {d:?}");
    let mut failed = d.failed_ranks.clone();
    failed.sort_unstable();
    assert_eq!(failed, vec![3, 7]);
}

#[test]
fn spare_dying_mid_join_rolls_back_the_lease() {
    // Substitute with two warm spares: rank 5 dies at iteration 25; the
    // first spare (world rank 8) dies entering its join — before its lease
    // activated.  The retry must re-derive spare availability from the
    // registry and stitch the second spare (world rank 9) instead.
    let mut cfg = quick_config(8, Strategy::Substitute, 1);
    cfg.warm_spares = Some(2);
    let rep = run_plan(&cfg, InjectionPlan::nested(5, 25, 8, ProtoPhase::SpareJoin, 1));
    assert!(rep.converged, "relres={}", rep.final_relres);
    assert_eq!(rep.global_restarts(), 0);
    assert!(rep.recovery_retries >= 1, "the interrupted join must be fenced and retried");
    assert_eq!(rep.decisions.len(), 1);
    assert_eq!(rep.decisions[0].decision, "substitute");
    assert_eq!(
        rep.decisions[0].failed_ranks,
        vec![5],
        "the dead joiner was never an application member"
    );
    // Spare 8's lease rolled back with its death; spare 9 did the work.
    let r8 = rep.ranks.iter().find(|r| r.world_rank == 8).unwrap();
    assert!(r8.killed, "spare 8 died mid-join");
    let r9 = rep.ranks.iter().find(|r| r.world_rank == 9).unwrap();
    assert!(r9.was_spare && !r9.killed && r9.iterations > 0, "spare 9 was adopted: {r9:?}");
}

#[test]
fn nested_kills_across_protocol_phases_recover_in_situ() {
    // Sweep the remaining recovery-side fault points under the default
    // mirror scheme; ranks 3 and 7 are never ring-adjacent at p=8, so the
    // union loss stays recoverable and no leg may escalate.
    for phase in [ProtoPhase::Detect, ProtoPhase::Agree, ProtoPhase::Redistribute] {
        let cfg = quick_config(8, Strategy::Shrink, 0);
        let rep = run_plan(&cfg, InjectionPlan::nested(7, 25, 3, phase, 1));
        assert!(rep.converged, "{phase:?}: relres={}", rep.final_relres);
        assert_eq!(rep.failures, 2, "{phase:?}");
        assert_eq!(rep.global_restarts(), 0, "{phase:?}");
    }
}

#[test]
fn member_dying_mid_steady_state_commit_recovers() {
    // A death inside an ordinary checkpoint commit (occurrence 3 = third
    // commit entry: setup establishment, then two dynamic commits): the
    // torn version must not advance anywhere, recovery restores the
    // previous committed floor, and the run converges.
    let cfg = quick_config(8, Strategy::Shrink, 0);
    let plan = InjectionPlan { kills: vec![Kill::at_phase(5, ProtoPhase::CkptCommit, 3)], ..Default::default() };
    let rep = run_plan(&cfg, plan);
    assert!(rep.converged, "relres={}", rep.final_relres);
    assert_eq!(rep.failures, 1);
    assert_eq!(rep.global_restarts(), 0);
    assert_eq!(rep.decisions.len(), 1);
    assert_eq!(rep.decisions[0].failed_ranks, vec![5]);
}

#[test]
fn death_during_setup_establishment_shrinks_and_reruns_setup() {
    // Occurrence 1 of CkptCommit is the establishment commit of initial
    // setup: no committed state exists anywhere yet, so survivors shrink
    // through the fence and re-run setup from scratch.
    let cfg = quick_config(8, Strategy::Shrink, 0);
    let plan = InjectionPlan { kills: vec![Kill::at_phase(2, ProtoPhase::CkptCommit, 1)], ..Default::default() };
    let rep = run_plan(&cfg, plan);
    assert!(rep.converged, "relres={}", rep.final_relres);
    assert_eq!(rep.failures, 1);
    // No recovery event: the death predates any solver state.
    assert!(rep.decisions.is_empty(), "decisions: {:?}", rep.decisions);
}

#[test]
fn async_ship_window_kill_recovers_without_restart() {
    // `--ckpt-async on` (DESIGN.md §15): the commit publishes and returns
    // non-blocking; rank 5 (a plain xor member) dies at its second ship
    // window (`ckpt-ship` occurrence 2 = second dynamic commit), i.e.
    // *between* publish and drain, while the torn version is in flight
    // everywhere.  Survivors must cancel the in-flight commit at recovery
    // entry, restore the committed floor, and finish in situ.
    let mut cfg = quick_config(8, Strategy::Shrink, 0);
    cfg.solver.ckpt.scheme = Scheme::Xor { g: 4 };
    cfg.solver.ckpt.async_commit = true;
    let plan = InjectionPlan {
        kills: vec![Kill::at_phase(5, ProtoPhase::CkptShip, 2)],
        ..Default::default()
    };
    let rep = run_plan(&cfg, plan);
    assert!(rep.converged, "relres={}", rep.final_relres);
    assert_eq!(rep.failures, 1);
    assert_eq!(rep.global_restarts(), 0, "cancel + floor restore, no escalation");
    assert_eq!(rep.decisions.len(), 1);
    assert_eq!(rep.decisions[0].failed_ranks, vec![5]);
}

#[test]
fn nested_kill_inside_pipelined_reconstruction_recovers() {
    // Async reconstruction folds contribution blocks in arrival order; rank
    // 3 dies entering that pipelined drain (`recon-pipeline`) of rank 7's
    // recovery.  Same contract as the sync `Reconstruct` leg: the fence
    // retries on the union failure set with zero executed restarts.
    let mut cfg = quick_config(8, Strategy::Shrink, 0);
    cfg.solver.ckpt.scheme = Scheme::Xor { g: 4 };
    cfg.solver.ckpt.async_commit = true;
    let rep = run_plan(&cfg, InjectionPlan::nested(7, 25, 3, ProtoPhase::ReconPipeline, 1));
    assert!(rep.converged, "relres={}", rep.final_relres);
    assert_eq!(rep.failures, 2);
    assert_eq!(rep.global_restarts(), 0);
    assert!(rep.recovery_retries >= 1, "the poisoned attempt must be fenced and retried");
}

#[test]
fn out_of_range_injection_target_is_rejected() {
    // A typo'd `--inject-phase` rank must error up front, not report a
    // failure-free "success" for a campaign that never ran.
    let cfg = quick_config(8, Strategy::Shrink, 0);
    let plan = InjectionPlan { kills: vec![Kill::at_phase(99, ProtoPhase::Agree, 1)], ..Default::default() };
    let backend = Arc::new(NativeBackend::new(cfg.compute.clone()));
    let err = coordinator::run_custom(&cfg, backend, plan).unwrap_err();
    assert!(err.to_string().contains("out of range"), "{err}");
}

#[test]
fn nested_failure_under_rs2_double_parity_stays_in_situ() {
    // rs2:4 tolerates two in-group losses; kill two ranks of group 0 —
    // one at an iteration boundary, one inside the resulting recovery's
    // reconstruction — and the two-erasure solve must still carry the
    // retry without escalation.
    let mut cfg = quick_config(8, Strategy::Shrink, 0);
    cfg.solver.ckpt.scheme = Scheme::Rs2 { g: 4 };
    let rep = run_plan(&cfg, InjectionPlan::nested(1, 25, 2, ProtoPhase::Reconstruct, 1));
    assert!(rep.converged, "relres={}", rep.final_relres);
    assert_eq!(rep.failures, 2);
    assert_eq!(rep.global_restarts(), 0, "rs2 solves the two-in-group union in situ");
    assert!(rep.recovery_retries >= 1);
}
