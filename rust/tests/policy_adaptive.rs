//! Integration tests for the adaptive recovery policy engine: multi-failure
//! campaigns that exhaust the spare pool mid-run and must degrade
//! gracefully from substitute to shrink (DESIGN.md §3).

mod common;

use std::sync::Arc;

use common::quick_config;
use ulfm_ftgmres::backend::native::NativeBackend;
use ulfm_ftgmres::config::RunConfig;
use ulfm_ftgmres::coordinator;
use ulfm_ftgmres::failure::InjectionPlan;
use ulfm_ftgmres::metrics::RunReport;
use ulfm_ftgmres::recovery::Strategy;

fn run_with_plan(cfg: &RunConfig, plan: InjectionPlan) -> RunReport {
    let backend = Arc::new(NativeBackend::new(cfg.compute.clone()));
    coordinator::run_custom(cfg, backend, plan).expect("run completes")
}

/// The acceptance scenario: more failures than warm spares under
/// `spares-first` — the run must substitute while the pool lasts, then
/// shrink, and still converge.
#[test]
fn spares_first_survives_pool_exhaustion() {
    let mut cfg = quick_config(8, Strategy::Shrink, 2);
    cfg.warm_spares = Some(1);
    assert!(cfg.set("policy", "spares-first").unwrap());
    assert_eq!(cfg.spares(), 1, "one warm spare against two failures");

    let plan = InjectionPlan::exhaustion_campaign(cfg.p, 2, cfg.solver.m_inner as u64);
    let rep = run_with_plan(&cfg, plan);

    assert!(rep.converged, "hybrid run must converge, relres={}", rep.final_relres);
    assert_eq!(rep.failures, 2);
    let names: Vec<&str> = rep.decisions.iter().map(|d| d.decision).collect();
    assert_eq!(
        names,
        vec!["substitute", "shrink"],
        "substitute while the pool lasts, shrink after exhaustion"
    );
    // The decision log carries the pool drain: one warm spare free at the
    // first event, none at the second.
    assert_eq!(rep.decisions[0].warm_free, 1);
    assert_eq!(rep.decisions[1].warm_free, 0);
    assert!(rep.decisions[1].reason.contains("exhausted"), "{}", rep.decisions[1].reason);
}

/// Every survivor must make the identical per-event decision (the policy is
/// a deterministic function of registry + config); divergent decisions
/// would deadlock the repair protocol, so check the per-rank logs agree.
#[test]
fn decisions_are_identical_across_survivors() {
    let mut cfg = quick_config(8, Strategy::Shrink, 2);
    cfg.warm_spares = Some(1);
    assert!(cfg.set("policy", "spares-first").unwrap());
    let plan = InjectionPlan::exhaustion_campaign(cfg.p, 2, cfg.solver.m_inner as u64);
    let rep = run_with_plan(&cfg, plan);

    let full: Vec<&str> = rep.decisions.iter().map(|d| d.decision).collect();
    assert_eq!(full.len(), 2);
    for r in rep.ranks.iter().filter(|r| !r.killed) {
        let mine: Vec<&str> = r.decisions.iter().map(|d| d.decision).collect();
        // Ranks adopted mid-run saw a suffix of the events; everyone else
        // the full log.  No rank may disagree on a shared event.
        assert!(
            full.ends_with(&mine),
            "rank {} decision log {mine:?} diverges from {full:?}",
            r.world_rank
        );
    }
}

/// Cold slots extend the pool once warm spares run dry: with one warm spare
/// and one cold slot against three failures, the policy must walk the full
/// substitute → substitute-cold → shrink ladder.
#[test]
fn spares_first_walks_warm_cold_shrink_ladder() {
    let mut cfg = quick_config(8, Strategy::Shrink, 3);
    cfg.warm_spares = Some(1);
    cfg.cold_spares = Some(1);
    assert!(cfg.set("policy", "spares-first").unwrap());
    assert_eq!(cfg.spares(), 2);

    let plan = InjectionPlan::exhaustion_campaign(cfg.p, 3, cfg.solver.m_inner as u64);
    let rep = run_with_plan(&cfg, plan);

    assert!(rep.converged, "relres={}", rep.final_relres);
    let names: Vec<&str> = rep.decisions.iter().map(|d| d.decision).collect();
    assert_eq!(names, vec!["substitute", "substitute-cold", "shrink"]);
    // The cold join must have charged the spawn latency somewhere: the
    // reconfiguration phase of the cold event dwarfs a warm stitch.
    assert!(
        rep.max_phases.reconfig >= cfg.net.cold_spawn_latency,
        "cold spawn latency must appear in reconfiguration time: {:.4}s",
        rep.max_phases.reconfig
    );
}

/// One simultaneous two-rank burst (whole-node loss) handled as a single
/// event: both slots must be re-filled by spares in one substitution.
/// The ranks are non-adjacent on the buddy ring so each dead rank's buddy
/// survives to serve its state (losing a rank *and* its only buddy is
/// unrecoverable by design with k = 1).
#[test]
fn burst_failure_substitutes_both_slots_in_one_event() {
    let mut cfg = quick_config(8, Strategy::Shrink, 2);
    cfg.warm_spares = Some(2);
    assert!(cfg.set("policy", "spares-first").unwrap());
    let rep = run_with_plan(&cfg, InjectionPlan::burst(&[2, 5], 25));

    assert!(rep.converged);
    assert_eq!(rep.failures, 2);
    assert_eq!(rep.decisions.len(), 1, "one event, not two");
    assert_eq!(rep.decisions[0].decision, "substitute");
    assert_eq!(rep.decisions[0].failed_ranks, vec![2, 5]);
}

/// cost-min completes a failure campaign end-to-end and records its
/// estimates in the reason string (the "why" of the figures extension).
#[test]
fn cost_min_runs_and_explains_itself() {
    let mut cfg = quick_config(8, Strategy::Shrink, 1);
    cfg.warm_spares = Some(1);
    assert!(cfg.set("policy", "cost-min").unwrap());
    let plan = InjectionPlan::exhaustion_campaign(cfg.p, 1, cfg.solver.m_inner as u64);
    let rep = run_with_plan(&cfg, plan);

    assert!(rep.converged);
    assert_eq!(rep.decisions.len(), 1);
    let d = &rep.decisions[0];
    assert!(
        d.decision == "substitute" || d.decision == "shrink",
        "cost-min must pick an in-situ strategy here, got {}",
        d.decision
    );
    assert!(d.reason.contains("cost-min"), "{}", d.reason);
    assert!(d.reason.contains("est[s]"), "{}", d.reason);
}

/// A long horizon prices shrink's lost capacity high enough that cost-min
/// substitutes; a zero horizon (nothing left to compute) makes shrink's
/// smaller redistribution bill win.  Same cluster, opposite decisions —
/// the crossover the fixed strategies cannot express.
#[test]
fn cost_min_horizon_flips_the_decision() {
    let base = {
        let mut cfg = quick_config(8, Strategy::Shrink, 1);
        cfg.warm_spares = Some(1);
        assert!(cfg.set("policy", "cost-min").unwrap());
        cfg
    };
    let plan = || InjectionPlan::exhaustion_campaign(8, 1, base.solver.m_inner as u64);

    // Pinning the horizon key disables the leader's dynamic estimate, so
    // the configured prior alone drives the crossover.
    let mut long = base.clone();
    long.policy_horizon = Some(1_000_000);
    let rep = run_with_plan(&long, plan());
    assert_eq!(rep.decisions[0].decision, "substitute", "{}", rep.decisions[0].reason);

    let mut short = base.clone();
    short.policy_horizon = Some(0);
    let rep = run_with_plan(&short, plan());
    assert_eq!(rep.decisions[0].decision, "shrink", "{}", rep.decisions[0].reason);
}
