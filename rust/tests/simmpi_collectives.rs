//! Multi-rank integration tests of the simulated MPI collectives: values,
//! clock behaviour, tag isolation, and the non-power-of-two allreduce path.

mod common;

use common::run_ranks;
use ulfm_ftgmres::simmpi::{Blob, Comm};

#[test]
fn allreduce_sum_all_sizes() {
    // Cover pow2 and non-pow2 sizes (the recursive-doubling pre/post path).
    for n in [2usize, 3, 4, 5, 7, 8, 12, 16, 21] {
        let results = run_ranks(n, move |mut ctx| async move {
            let mut comm = Comm::world(n, ctx.rank);
            let mut data = [ctx.rank as f64 + 1.0, 1.0];
            comm.allreduce_sum(&mut ctx, &mut data).await.unwrap();
            data
        });
        let expect = (n * (n + 1) / 2) as f64;
        for (r, d) in results.iter().enumerate() {
            assert_eq!(d[0], expect, "n={n} rank={r}");
            assert_eq!(d[1], n as f64);
        }
    }
}

#[test]
fn allreduce_results_bitwise_identical_across_ranks() {
    let n = 13;
    let results = run_ranks(n, move |mut ctx| async move {
        let mut comm = Comm::world(n, ctx.rank);
        // Values chosen so naive per-rank orderings would differ in rounding.
        let mut data = [0.1 * (ctx.rank as f64 + 1.0), 1e-17 + ctx.rank as f64];
        comm.allreduce_sum(&mut ctx, &mut data).await.unwrap();
        data
    });
    for d in &results[1..] {
        assert_eq!(d[0].to_bits(), results[0][0].to_bits());
        assert_eq!(d[1].to_bits(), results[0][1].to_bits());
    }
}

#[test]
fn allreduce_min_i64() {
    let n = 6;
    let results = run_ranks(n, move |mut ctx| async move {
        let mut comm = Comm::world(n, ctx.rank);
        let mut v = [ctx.rank as i64 + 10, -(ctx.rank as i64)];
        comm.allreduce_min_i64(&mut ctx, &mut v).await.unwrap();
        v
    });
    for v in results {
        assert_eq!(v, [10, -(n as i64 - 1)]);
    }
}

#[test]
fn bcast_from_root() {
    let n = 9;
    let results = run_ranks(n, move |mut ctx| async move {
        let mut comm = Comm::world(n, ctx.rank);
        let mine = if ctx.rank == 0 {
            Blob::from_f64s(vec![3.5, 4.5])
        } else {
            Blob::empty()
        };
        comm.bcast(&mut ctx, mine).await.unwrap().f
    });
    for r in results {
        assert_eq!(r, vec![3.5, 4.5]);
    }
}

#[test]
fn barrier_synchronizes_clocks() {
    let n = 8;
    let clocks = run_ranks(n, move |mut ctx| async move {
        let mut comm = Comm::world(n, ctx.rank);
        // Skew the clocks, then barrier.
        ctx.advance(ctx.rank as f64 * 1e-3);
        comm.barrier(&mut ctx).await.unwrap();
        ctx.clock
    });
    let max = clocks.iter().cloned().fold(0.0, f64::max);
    // After the barrier no clock may be before the slowest pre-barrier rank.
    for c in clocks {
        assert!(c >= 7e-3 && c <= max + 1e-2, "clock {c}");
    }
}

#[test]
fn allgather_variable_sizes() {
    let n = 5;
    let results = run_ranks(n, move |mut ctx| async move {
        let mut comm = Comm::world(n, ctx.rank);
        let mine = Blob::from_f64s(vec![ctx.rank as f64; ctx.rank + 1]);
        comm.allgather(&mut ctx, mine).await.unwrap()
    });
    for blobs in results {
        assert_eq!(blobs.len(), n);
        for (r, b) in blobs.iter().enumerate() {
            assert_eq!(b.f, vec![r as f64; r + 1]);
        }
    }
}

#[test]
fn agree_bitwise_and() {
    let n = 7;
    let results = run_ranks(n, move |mut ctx| async move {
        let mut comm = Comm::world(n, ctx.rank);
        let flag = if ctx.rank == 3 { 0b101 } else { 0b111 };
        comm.agree(&mut ctx, flag).await.unwrap()
    });
    for r in results {
        assert_eq!(r, 0b101);
    }
}

#[test]
fn back_to_back_collectives_do_not_mix() {
    let n = 4;
    let results = run_ranks(n, move |mut ctx| async move {
        let mut comm = Comm::world(n, ctx.rank);
        let mut out = Vec::new();
        for round in 0..20 {
            let mut v = [ctx.rank as f64 + round as f64];
            comm.allreduce_sum(&mut ctx, &mut v).await.unwrap();
            out.push(v[0]);
        }
        out
    });
    for r in results {
        for (round, v) in r.iter().enumerate() {
            assert_eq!(*v, 6.0 + 4.0 * round as f64);
        }
    }
}

#[test]
fn sendrecv_pairs() {
    let n = 6;
    let results = run_ranks(n, move |mut ctx| async move {
        let mut comm = Comm::world(n, ctx.rank);
        let peer = ctx.rank ^ 1;
        let payload = Blob::scalar(ctx.rank as f64);
        let got = comm.sendrecv(&mut ctx, peer, 42, payload).await.unwrap();
        let _ = &mut comm;
        got.f[0]
    });
    for (r, v) in results.iter().enumerate() {
        assert_eq!(*v, (r ^ 1) as f64);
    }
}

#[test]
fn clock_monotone_through_collectives() {
    let n = 5;
    let ok = run_ranks(n, move |mut ctx| async move {
        let mut comm = Comm::world(n, ctx.rank);
        let mut prev = ctx.clock;
        for _ in 0..10 {
            let mut v = [1.0];
            comm.allreduce_sum(&mut ctx, &mut v).await.unwrap();
            if ctx.clock < prev {
                return false;
            }
            prev = ctx.clock;
        }
        true
    });
    assert!(ok.into_iter().all(|b| b));
}
