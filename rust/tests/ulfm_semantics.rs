//! ULFM semantics under real rank threads: failure notification, revoke
//! unblocking, shrink renumbering, and spare stitching.

mod common;

use common::{run_ranks, run_ranks_plan, wait_dead};
use ulfm_ftgmres::failure::{InjectionPlan, Kill, ProtoPhase};
use ulfm_ftgmres::simmpi::ulfm::EpochFence;
use ulfm_ftgmres::simmpi::{ulfm, Blob, Comm, Ctl, MpiError};

#[test]
fn collective_fails_or_revokes_when_rank_dies() {
    // Rank 2 dies before the collective; everyone else must get ProcFailed
    // or Revoked (after the first detector revokes) rather than hanging.
    let n = 6;
    let results = run_ranks(n, move |mut ctx| async move {
        let mut comm = Comm::world(n, ctx.rank);
        if ctx.rank == 2 {
            let _ = ctx.die();
            return "died".to_string();
        }
        let mut v = [1.0];
        match comm.allreduce_sum(&mut ctx, &mut v).await {
            Err(e @ (MpiError::ProcFailed(_) | MpiError::Revoked)) => {
                // Propagate so blocked peers unblock, like the recovery
                // driver does.
                ulfm::revoke(&mut ctx, &comm);
                format!("err:{}", matches!(e, MpiError::Revoked))
            }
            Ok(_) => "ok".to_string(),
            Err(e) => format!("unexpected:{e}"),
        }
    });
    assert_eq!(results[2], "died");
    for (r, s) in results.iter().enumerate() {
        if r != 2 {
            assert!(s.starts_with("err:") || s == "ok", "rank {r}: {s}");
        }
    }
    // At least the ranks that talk to 2 directly must error.
    assert!(results.iter().filter(|s| s.starts_with("err:")).count() >= 1);
}

#[test]
fn shrink_renumbers_survivors_densely() {
    let n = 7;
    let results = run_ranks(n, move |mut ctx| async move {
        let comm = Comm::world(n, ctx.rank);
        if ctx.rank == 3 {
            let _ = ctx.die();
            return None;
        }
        // Synchronize with the registry (production reaches shrink only
        // after failure detection).
        wait_dead(&ctx.world, 3);
        ulfm::revoke(&mut ctx, &comm);
        let new_comm = ulfm::shrink(&mut ctx, &comm).await.unwrap();
        Some((new_comm.epoch, new_comm.members.clone(), new_comm.rank))
    });
    let survivors: Vec<usize> = vec![0, 1, 2, 4, 5, 6];
    for (r, res) in results.iter().enumerate() {
        if r == 3 {
            assert!(res.is_none());
            continue;
        }
        let (epoch, members, my) = res.clone().unwrap();
        assert_eq!(epoch, 2);
        assert_eq!(members, survivors);
        assert_eq!(members[my], r, "dense renumbering preserves order");
    }
}

#[test]
fn shrink_supports_collectives_afterwards() {
    let n = 5;
    let results = run_ranks(n, move |mut ctx| async move {
        let comm = Comm::world(n, ctx.rank);
        if ctx.rank == 4 {
            let _ = ctx.die();
            return -1.0;
        }
        wait_dead(&ctx.world, 4);
        ulfm::revoke(&mut ctx, &comm);
        let mut new_comm = ulfm::shrink(&mut ctx, &comm).await.unwrap();
        let mut v = [comm.rank as f64];
        new_comm.allreduce_sum(&mut ctx, &mut v).await.unwrap();
        v[0]
    });
    for (r, v) in results.iter().enumerate() {
        if r != 4 {
            assert_eq!(*v, 6.0, "0+1+2+3 over survivors");
        }
    }
}

/// Shared driver for the agreement-poisoning tests: survivors repair the
/// failed world communicator through the epoch fence exactly like the
/// recovery driver does (a round may transiently adopt a membership whose
/// casualty registered late; the next collective then errors and the fence
/// re-runs the agree), and return their final (members, allreduce, retries).
async fn fenced_repair_to_quiescence(
    ctx: &mut ulfm_ftgmres::simmpi::Ctx,
    comm: &Comm,
) -> Option<(Vec<usize>, f64, u64)> {
    ulfm::revoke(ctx, comm);
    let mut fence = EpochFence::new(comm);
    loop {
        let mut c = match ulfm::shrink_fenced(ctx, comm, &mut fence).await {
            Ok(c) => c,
            Err(MpiError::Killed) => return None,
            Err(e) => panic!("rank {}: {e}", ctx.rank),
        };
        let mut v = [comm.rank as f64];
        match c.allreduce_sum(ctx, &mut v).await {
            Ok(()) => return Some((c.members.clone(), v[0], fence.retries())),
            Err(MpiError::Killed) => return None,
            Err(_) => {
                ulfm::revoke_epoch_world(ctx, c.epoch);
                fence.abandon();
            }
        }
    }
}

/// The agreement's vote set is NOT fixed once collected.  Rank 4
/// participates in the round-0 agreement to the end (vote counted,
/// decision received — its liveness through the round is what makes the
/// round's membership deterministic) and dies before any survivor can use
/// the agreed communicator.  The old protocol left survivors waiting; the
/// fenced protocol must detect the death and re-run the agree, so every
/// survivor records at least one re-run and converges on {0, 1, 3}.
#[test]
fn death_after_the_decision_broadcast_reruns_the_round() {
    let n = 5;
    let results = run_ranks(n, move |mut ctx| async move {
        let comm = Comm::world(n, ctx.rank);
        if ctx.rank == 2 {
            // The first failure, whose repair rank 4 then poisons.
            let _ = ctx.die();
            return None;
        }
        wait_dead(&ctx.world, 2);
        if ctx.rank == 4 {
            // Full round-0 participant: vote contributed, decision
            // received... then death, with the agreed membership unusable.
            ulfm::revoke(&mut ctx, &comm);
            let c =
                ulfm::shrink_at(&mut ctx, &comm, comm.epoch + 1).await.expect("round 0 agrees");
            assert_eq!(c.members, vec![0, 1, 3, 4]);
            let _ = ctx.die();
            return None;
        }
        fenced_repair_to_quiescence(&mut ctx, &comm).await
    });
    assert!(results[2].is_none());
    assert!(results[4].is_none(), "rank 4 died after the decision broadcast");
    for r in [0usize, 1, 3] {
        let (members, sum, retries) = results[r].clone().expect("survivor completes");
        assert_eq!(members, vec![0, 1, 3], "rank {r}: re-agreed on the union");
        assert_eq!(sum, 4.0, "rank {r}: 0 + 1 + 3 over the final comm");
        assert!(retries >= 1, "rank {r}: the poisoned round was re-run");
    }
}

/// A rank dying *between contributing its vote and the decision broadcast*
/// (the `ProtoPhase::Agree` fault point): survivors must never hang — the
/// leader's dead-send (or a voter's dead-recv) aborts the round, revokes
/// its epoch machine-wide, and the re-run converges on the enlarged set.
/// (Whether a re-run is *recorded* depends on whether any survivor's
/// snapshot still included rank 4, which is schedule-dependent — the
/// deterministic re-run accounting is covered by the test above.)
#[test]
fn mid_vote_death_does_not_hang_survivors() {
    let n = 5;
    let plan = InjectionPlan { kills: vec![Kill::at_phase(4, ProtoPhase::Agree, 1)], ..Default::default() };
    let results = run_ranks_plan(n, plan, move |mut ctx| async move {
        let comm = Comm::world(n, ctx.rank);
        if ctx.rank == 2 {
            let _ = ctx.die();
            return None;
        }
        wait_dead(&ctx.world, 2);
        fenced_repair_to_quiescence(&mut ctx, &comm).await
    });
    assert!(results[2].is_none());
    assert!(results[4].is_none(), "rank 4 died mid-vote");
    for r in [0usize, 1, 3] {
        let (members, sum, _retries) = results[r].clone().expect("survivor completes");
        assert_eq!(members, vec![0, 1, 3], "rank {r}");
        assert_eq!(sum, 4.0, "rank {r}");
    }
}

#[test]
fn revoke_unblocks_pending_recv() {
    // Rank 1 blocks receiving from rank 0 (which never sends); rank 2
    // revokes the epoch; rank 1 must return Revoked.
    let n = 3;
    let results = run_ranks(n, move |mut ctx| async move {
        let comm = Comm::world(n, ctx.rank);
        match ctx.rank {
            1 => match comm.recv(&mut ctx, 0, 7).await {
                Err(MpiError::Revoked) => "revoked".into(),
                other => format!("{other:?}"),
            },
            2 => {
                ulfm::revoke(&mut ctx, &comm);
                "sent".into()
            }
            _ => {
                // Rank 0 must outlive the test without sending tag 7.
                "idle".to_string()
            }
        }
    });
    assert_eq!(results[1], "revoked");
}

#[test]
fn stitch_spare_restores_original_size() {
    // 4 app ranks + 1 spare; rank 2 dies; the spare (world 4) takes slot 2.
    let n_app = 4;
    let w = ulfm_ftgmres::simmpi::World::new(
        n_app,
        1,
        ulfm_ftgmres::netsim::NetParams::default(),
        ulfm_ftgmres::failure::Injector::new(ulfm_ftgmres::failure::InjectionPlan::none()),
    );
    let handles: Vec<_> = (0..5)
        .map(|rank| {
            let w = w.clone();
            std::thread::spawn(move || {
                let mut ctx = ulfm_ftgmres::simmpi::Ctx::new(w, rank);
                ulfm_ftgmres::simmpi::block_on(async move {
                    if rank == 4 {
                        // Spare: wait for the invitation, then join + allreduce.
                        let (epoch, members, old_members, as_rank) =
                            ctx.wait_join().await.expect("join");
                        assert_eq!(as_rank, 2);
                        // The invitation names the failed communicator's
                        // membership so the spare can evaluate the survivors'
                        // serving functions.
                        assert_eq!(old_members, vec![0, 1, 2, 3]);
                        let mut comm = ulfm::join_as_spare(&mut ctx, epoch, members, as_rank)
                            .await
                            .unwrap();
                        let mut v = [100.0];
                        comm.allreduce_sum(&mut ctx, &mut v).await.unwrap();
                        return v[0];
                    }
                    let comm = Comm::world(n_app, rank);
                    if rank == 2 {
                        let _ = ctx.die();
                        return -1.0;
                    }
                    common::wait_dead(&ctx.world, 2);
                    ulfm::revoke(&mut ctx, &comm);
                    let shrunk = ulfm::shrink(&mut ctx, &comm).await.unwrap();
                    let assignment = vec![(2usize, 4usize)];
                    let mut stitched = ulfm::stitch_spares(&mut ctx, &comm, &shrunk, &assignment)
                        .await
                        .unwrap();
                    assert_eq!(stitched.size(), 4);
                    assert_eq!(stitched.members, vec![0, 1, 4, 3]);
                    let mut v = [comm.rank as f64];
                    stitched.allreduce_sum(&mut ctx, &mut v).await.unwrap();
                    v[0]
                })
            })
        })
        .collect();
    let results: Vec<f64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    // Sum over stitched comm: ranks 0,1,3 contribute their old rank ids,
    // spare contributes 100 -> 0 + 1 + 3 + 100 = 104.
    for (r, v) in results.iter().enumerate() {
        if r != 2 {
            assert_eq!(*v, 104.0, "rank {r}");
        }
    }
}

/// A failure arriving *during* the checkpoint-commit agreement: the dying
/// rank completes the whole data exchange (its copies are delivered) and
/// dies inside the agreement.  No survivor may hang, none may commit the
/// torn version, and after the repair the survivors agree to restore the
/// previous committed version — which the GC must still be holding.
#[test]
fn failure_during_commit_agreement_preserves_previous_commit() {
    use ulfm_ftgmres::checkpoint::{self, agree_restore_version, obj, CkptStore};
    use ulfm_ftgmres::ckptstore::ship_tag;

    let n = 4;
    let results = run_ranks(n, move |mut ctx| async move {
        let mut comm = Comm::world(n, ctx.rank);
        let mut store = CkptStore::new();
        let objs = vec![(obj::X, Blob::scalar(ctx.rank as f64))];
        checkpoint::checkpoint(&mut ctx, &mut comm, &mut store, &objs, 1, 1).await.unwrap();
        if ctx.rank == 1 {
            // Re-play the v2 data exchange by hand (same wire protocol:
            // ship to buddy 2, receive ward 0's copy), then die *before*
            // the commit agreement — a failure mid-agreement.
            comm.send(&mut ctx, 2, ship_tag(obj::X, 1), Blob::scalar(10.0)).unwrap();
            let _ = comm.recv(&mut ctx, 0, ship_tag(obj::X, 1)).await.unwrap();
            let _ = ctx.die();
            return (true, 1, 1);
        }
        // Survivors run the full v2 checkpoint: their data exchange
        // completes (rank 1's copies were delivered), so the error can
        // only surface inside the agreement.
        let objs2 = vec![(obj::X, Blob::scalar(10.0 + ctx.rank as f64))];
        let r = checkpoint::checkpoint(&mut ctx, &mut comm, &mut store, &objs2, 2, 1).await;
        if r.is_err() {
            ulfm::revoke(&mut ctx, &comm);
        }
        // Repair and agree on the restore version like the recovery driver.
        wait_dead(&ctx.world, 1);
        let mut shrunk = ulfm::shrink(&mut ctx, &comm).await.unwrap();
        let v = agree_restore_version(&mut ctx, &mut shrunk, &store).await.unwrap();
        // The restore version's payload must still exist locally (the
        // committed-floor GC may not have collected it).
        assert!(store.get_local_at_most(obj::X, v).is_some());
        (r.is_err(), store.committed(), v)
    });
    for (rank, (is_err, committed, v)) in results.iter().enumerate() {
        if rank == 1 {
            continue;
        }
        assert!(*is_err, "rank {rank}: the torn commit must error, not hang");
        assert_eq!(*committed, 1, "rank {rank}: v2 must not commit");
        assert_eq!(*v, 1, "rank {rank}: survivors restore the last full commit");
    }
}

/// Torn commit: some ranks advanced their committed watermark, a straggler
/// did not.  `agree_restore_version` must return min(committed), and every
/// rank — including the ones already committed past it — must still hold
/// the agreed version's data after the committed-floor GC.
#[test]
fn torn_commit_survivors_agree_on_min_and_retain_the_floor() {
    use ulfm_ftgmres::checkpoint::{self, agree_restore_version, obj, CkptStore};

    let n = 3;
    let results = run_ranks(n, move |mut ctx| async move {
        let mut comm = Comm::world(n, ctx.rank);
        let mut store = CkptStore::new();
        for v in 1..=2 {
            let objs = vec![(obj::X, Blob::scalar(v as f64))];
            checkpoint::checkpoint(&mut ctx, &mut comm, &mut store, &objs, v, 1).await.unwrap();
        }
        // Model a torn v3: ranks 0 and 1 stored + committed it, rank 2
        // never advanced (e.g. it errored first in the agreement).
        if ctx.rank != 2 {
            store.put_local(obj::X, 3, Blob::scalar(3.0));
            store.force_committed(3);
            store.gc_committed();
        }
        let v = agree_restore_version(&mut ctx, &mut comm, &store).await.unwrap();
        // min(committed) = 2, and version 2 must have survived the GC on
        // the ranks whose own committed watermark is already 3.
        let have = store.get_local_at_most(obj::X, v).map(|(got, b)| (got, b.f[0]));
        (v, have)
    });
    for (rank, (v, have)) in results.iter().enumerate() {
        assert_eq!(*v, 2, "rank {rank}");
        assert_eq!(*have, Some((2, 2.0)), "rank {rank} must retain the agreed floor");
    }
}

#[test]
fn detection_latency_charged_once() {
    let n = 2;
    let results = run_ranks(n, move |mut ctx| async move {
        if ctx.rank == 1 {
            let _ = ctx.die();
            return 0.0;
        }
        wait_dead(&ctx.world, 1);
        let comm = Comm::world(n, ctx.rank);
        let t0 = ctx.clock;
        let e1 = comm.send(&mut ctx, 1, 0, Blob::scalar(1.0));
        let t1 = ctx.clock;
        let e2 = comm.send(&mut ctx, 1, 0, Blob::scalar(1.0));
        let t2 = ctx.clock;
        assert!(e1.is_err() && e2.is_err());
        // First detection pays detect_latency; the second is immediate.
        assert!(t1 - t0 >= 1e-3, "first detection charged: {}", t1 - t0);
        assert!(t2 - t1 < 1e-4, "second detection cheap: {}", t2 - t1);
        1.0
    });
    assert_eq!(results[0], 1.0);
}

#[test]
fn shutdown_releases_idle_spare() {
    let w = ulfm_ftgmres::simmpi::World::new(
        1,
        1,
        ulfm_ftgmres::netsim::NetParams::default(),
        ulfm_ftgmres::failure::Injector::new(ulfm_ftgmres::failure::InjectionPlan::none()),
    );
    let w2 = w.clone();
    let spare = std::thread::spawn(move || {
        let mut ctx = ulfm_ftgmres::simmpi::Ctx::new(w2, 1);
        ulfm_ftgmres::simmpi::block_on(async move { ctx.wait_join().await.is_none() })
    });
    let mut ctx0 = ulfm_ftgmres::simmpi::Ctx::new(w, 0);
    ctx0.send_ctl(1, Ctl::Shutdown);
    assert!(spare.join().unwrap(), "spare exits on shutdown");
}
