//! Degraded-mode failure universe (DESIGN.md §14): faults that are *not*
//! crash-stop deaths — stragglers, lossy links, silent checkpoint
//! corruption — and the in-situ responses that keep them from ever
//! escalating to a global restart.
//!
//! The contracts pinned here:
//!
//! - a **straggler** is shrunk away iff tolerating it prices above losing
//!   its rank under the cost model (`recovery::degraded`), and the decision
//!   is recorded as `degraded-shrink` *before* the ordinary shrink executes;
//! - a **lossy link** is retried at the sender (`link-retry` marks, the
//!   `link_retries` counter) and only ever *revokes* the epoch when the
//!   retry budget is exhausted — it never kills anyone, and the stale-revoke
//!   recovery path resolves it with an empty failed set;
//! - **silent corruption** of a committed checkpoint is caught by the
//!   per-chunk digests and repaired bit-identically from the scheme's own
//!   redundancy by the scrubber, composing with real crash-stop kills in the
//!   same campaign without a single global restart.

mod common;

use common::quick_config;
use ulfm_ftgmres::ckptstore::Scheme;
use ulfm_ftgmres::config::RunConfig;
use ulfm_ftgmres::coordinator;
use ulfm_ftgmres::failure::{BitFlip, InjectionPlan, Kill, LinkFault, Straggler};
use ulfm_ftgmres::metrics::RunReport;
use ulfm_ftgmres::recovery::Strategy;

fn run(cfg: &RunConfig, plan: InjectionPlan) -> RunReport {
    let backend = coordinator::make_backend(cfg).unwrap();
    coordinator::run_custom(cfg, backend, plan).unwrap()
}

fn straggler_plan(world_rank: usize, mult: f64) -> InjectionPlan {
    InjectionPlan {
        stragglers: vec![Straggler { world_rank, mult }],
        ..Default::default()
    }
}

/// A 1.2x straggler on the quick shape prices below the shrink cost
/// (crossover sits near 1.5x — pinned in `recovery::degraded`'s unit
/// tests), so the detector must tolerate it: no decision, no kill, and the
/// slow rank visibly accumulates more compute time than its healthy peers.
#[test]
fn mild_straggler_is_tolerated() {
    let cfg = quick_config(8, Strategy::Shrink, 0);
    let rep = run(&cfg, straggler_plan(6, 1.2));
    assert!(rep.converged);
    assert_eq!(rep.failures, 0, "tolerating must not kill anyone");
    assert!(rep.decisions.is_empty(), "tolerate is a mark, not a decision: {:?}", rep.decisions);
    assert!(!rep.ranks[6].killed);
    assert!(
        rep.ranks[6].phases.compute > 1.1 * rep.ranks[0].phases.compute,
        "the straggler must actually run slow: w6={} w0={}",
        rep.ranks[6].phases.compute,
        rep.ranks[0].phases.compute,
    );
}

/// A 3x straggler prices well above the shrink cost: the detector records
/// exactly one `degraded-shrink` decision naming the victim, the ordinary
/// shrink recovery executes it, and the run converges on the survivors
/// without a global restart.
#[test]
fn severe_straggler_is_shrunk_away() {
    let cfg = quick_config(8, Strategy::Shrink, 0);
    let rep = run(&cfg, straggler_plan(6, 3.0));
    assert!(rep.converged);
    assert_eq!(rep.failures, 1, "the victim is converted to one crash-stop loss");
    assert!(rep.ranks[6].killed, "the named straggler is the rank that dies");
    let degraded: Vec<_> =
        rep.decisions.iter().filter(|d| d.decision == "degraded-shrink").collect();
    assert_eq!(degraded.len(), 1, "exactly one degraded decision: {:?}", rep.decisions);
    assert_eq!(degraded[0].failed_ranks, vec![6]);
    assert!(
        degraded[0].reason.contains("m_est"),
        "reason carries the estimated multiplier: {}",
        degraded[0].reason
    );
    assert!(
        rep.decisions.iter().any(|d| d.decision == "shrink" && d.failed_ranks == vec![6]),
        "the policy shrink that executes the decision must also be logged: {:?}",
        rep.decisions
    );
    assert_eq!(rep.global_restarts(), 0);
}

/// Three scheduled drops on a live halo edge: the sender retries each one
/// (virtual-time timeout, `link_retries` counts them) and delivers on the
/// fourth attempt — below the budget of 5 nothing is revoked, nobody dies,
/// and the decision log stays empty.
#[test]
fn link_retries_below_budget_never_revoke() {
    let cfg = quick_config(8, Strategy::Shrink, 0);
    let plan = InjectionPlan {
        links: vec![LinkFault { src: 1, dst: 2, drops: 3 }],
        ..Default::default()
    };
    let rep = run(&cfg, plan);
    assert!(rep.converged);
    assert_eq!(rep.failures, 0, "a lossy link is not a death");
    assert_eq!(rep.faults.link_retries, 3, "one retry per scheduled drop");
    assert!(rep.decisions.is_empty(), "below budget no recovery fires: {:?}", rep.decisions);
}

/// Seven scheduled drops exhaust the budget of 5: the sender revokes the
/// epoch, recovery finds *no* dead member (the stale-revoke path) and
/// resolves with an empty failed set, after which the two remaining drops
/// burn as ordinary retries and the message finally lands.  Observably
/// distinct from ULFM death: `failures == 0` and nobody is killed.
#[test]
fn link_exhaustion_revokes_but_never_kills() {
    let cfg = quick_config(8, Strategy::Shrink, 0);
    let plan = InjectionPlan {
        links: vec![LinkFault { src: 1, dst: 2, drops: 7 }],
        ..Default::default()
    };
    let rep = run(&cfg, plan);
    assert!(rep.converged);
    assert_eq!(rep.failures, 0, "revocation must not kill anyone");
    assert!(rep.ranks.iter().all(|r| !r.killed));
    assert_eq!(rep.faults.link_retries, 7, "all seven drops surface as retries");
    assert!(
        rep.decisions
            .iter()
            .any(|d| d.failed_ranks.is_empty() && d.decision == "shrink"),
        "budget exhaustion resolves via the stale-revoke decision: {:?}",
        rep.decisions
    );
    assert_eq!(rep.global_restarts(), 0);
}

/// The acceptance campaign for the integrity layer: a 5-bit flip in a
/// committed checkpoint plus a real crash-stop kill later in the run, once
/// per redundancy scheme.  The scrubber must detect the corruption at the
/// next commit, repair it bit-identically from the scheme's own redundancy
/// (buddy copy / XOR stripe / GF(2^8) solve), and the subsequent kill must
/// recover in place — zero global restarts anywhere.
#[test]
fn scrubber_and_crash_stop_compose_without_global_restart() {
    for scheme in [Scheme::Mirror { k: 1 }, Scheme::Xor { g: 4 }, Scheme::Rs2 { g: 4 }] {
        let mut cfg = quick_config(8, Strategy::Shrink, 0);
        cfg.solver.ckpt.scheme = scheme;
        let plan = InjectionPlan {
            kills: vec![Kill::at_iter(5, 40)],
            bitflips: vec![BitFlip { world_rank: 2, at_version: 1, bits: 5 }],
            ..Default::default()
        };
        let rep = run(&cfg, plan);
        assert!(rep.converged, "{scheme:?}: campaign must converge");
        assert_eq!(rep.failures, 1, "{scheme:?}: only the scheduled kill dies");
        assert!(rep.faults.scrub_detected >= 1, "{scheme:?}: the flip must be caught");
        assert_eq!(
            rep.faults.scrub_detected, rep.faults.scrub_repaired,
            "{scheme:?}: every detection repaired in situ"
        );
        assert_eq!(rep.global_restarts(), 0, "{scheme:?}: nothing escalates globally");
    }
}
