//! Trace determinism and critical-path integration tests (DESIGN.md §13).
//!
//! The trace is part of a run's observable state: the same campaign must
//! export a **byte-identical** Perfetto trace across repeated runs and
//! across both execution engines; different campaigns must produce
//! different traces; and enabling tracing must not perturb the run at all
//! (observation only — the digest of `common::digest` is unchanged).
//! The suite also pins the run-level virtual-time invariant the satellite
//! fix to `RunReport::from_ranks` relies on: every virtual second is
//! charged to exactly one phase, so per-rank `phases.total()` equals the
//! rank's finish time and the element-wise `max_with` merge cannot
//! double-count overlapping recovery attempts.

mod common;

use common::{digest, quick_config};
use ulfm_ftgmres::ckptstore::Scheme;
use ulfm_ftgmres::config::RunConfig;
use ulfm_ftgmres::coordinator;
use ulfm_ftgmres::failure::{InjectionPlan, ProtoPhase};
use ulfm_ftgmres::metrics::RunReport;
use ulfm_ftgmres::recovery::Strategy;
use ulfm_ftgmres::simmpi::Engine;
use ulfm_ftgmres::trace::{perfetto_json, TraceEvent};

fn run_traced(cfg: &RunConfig, plan: &InjectionPlan, engine: Engine) -> (RunReport, String) {
    let mut cfg = cfg.clone();
    cfg.engine = engine;
    cfg.trace = true;
    let backend = coordinator::make_backend(&cfg).unwrap();
    let rep = coordinator::run_custom(&cfg, backend, plan.clone()).unwrap();
    let json = perfetto_json(&rep, &cfg);
    (rep, json)
}

/// The hardest traced schedule the repo produces: a nested second kill
/// inside the first recovery, xor parity + delta shipping.
fn nested_campaign() -> (RunConfig, InjectionPlan) {
    let mut cfg = quick_config(8, Strategy::Shrink, 0);
    cfg.solver.ckpt.scheme = Scheme::Xor { g: 4 };
    cfg.solver.ckpt.delta = true;
    let plan = InjectionPlan::nested(7, 25, 3, ProtoPhase::Reconstruct, 1);
    (cfg, plan)
}

#[test]
fn same_campaign_produces_byte_identical_traces() {
    let (cfg, plan) = nested_campaign();
    let (_, t1) = run_traced(&cfg, &plan, Engine::Threads);
    let (_, t2) = run_traced(&cfg, &plan, Engine::Threads);
    let (_, t3) = run_traced(&cfg, &plan, Engine::Threads);
    assert_eq!(t1, t2, "repeat run 2 diverged");
    assert_eq!(t1, t3, "repeat run 3 diverged");
    let (_, te) = run_traced(&cfg, &plan, Engine::Events);
    assert_eq!(t1, te, "event-engine trace diverged from the thread oracle");
}

#[test]
fn different_campaign_produces_a_different_trace() {
    let one = quick_config(8, Strategy::Shrink, 1);
    let two = quick_config(8, Strategy::Shrink, 2);
    let (_, t1) = run_traced(&one, &one.injection_plan(), Engine::Events);
    let (_, t2) = run_traced(&two, &two.injection_plan(), Engine::Events);
    assert_ne!(t1, t2, "distinct campaigns must not share a trace");
}

#[test]
fn tracing_is_observation_only() {
    let (cfg, plan) = nested_campaign();
    let (traced, _) = run_traced(&cfg, &plan, Engine::Events);
    let mut off = cfg.clone();
    off.engine = Engine::Events;
    off.trace = false;
    let backend = coordinator::make_backend(&off).unwrap();
    let plain = coordinator::run_custom(&off, backend, plan.clone()).unwrap();
    assert_eq!(
        digest(&traced),
        digest(&plain),
        "enabling tracing changed the run"
    );
    assert!(plain.ranks.iter().all(|r| r.trace.is_empty()));
    assert!(plain.critical_path.is_none(), "untraced runs have no critical path");
    assert!(traced.critical_path.is_some(), "traced runs always report one");
}

#[test]
fn critical_path_sanity_under_nested_failures() {
    let (cfg, plan) = nested_campaign();
    let (rep, _) = run_traced(&cfg, &plan, Engine::Events);
    assert!(rep.converged);
    assert!(rep.recovery_retries >= 1, "the nested kill must fence");
    let cp = rep.critical_path.as_ref().expect("traced run");
    assert!(!cp.events.is_empty(), "two kills must produce recovery events");
    assert!(cp.events.iter().any(|e| e.attempts >= 1), "abandoned fence attempts recorded");
    assert!((0.0..=1.0).contains(&cp.overlap_efficiency));
    for e in &cp.events {
        assert!(e.wall > 0.0, "event {} has an empty window", e.event);
        assert!(e.serial_secs <= e.wall + 1e-9, "serial work cannot exceed the wall");
        assert!((0.0..=1.0).contains(&e.overlap_efficiency));
        // The backward walk partitions [t_begin, t_end] into receiver-local,
        // wire, and sender-local time: attributed phases + wire == wall.
        let covered = e.by_phase.total() + e.wire_secs;
        assert!(
            (covered - e.wall).abs() <= 1e-9 * e.wall.max(1.0),
            "event {}: path covers {covered} of a {} s window",
            e.event,
            e.wall
        );
    }
    let (by_phase, wire) = cp.path_phase_totals();
    assert!((by_phase.total() + wire - cp.total_wall).abs() <= 1e-9 * cp.total_wall.max(1.0));
}

/// The virtual-time conservation law behind the satellite-1 verdict: every
/// rank's clock moves only through `advance`/`advance_to`, each charging
/// exactly one phase, so the phase timers sum to the finish time — and
/// span coverage (which mirrors the charges) does too.
#[test]
fn every_virtual_second_charged_once() {
    let (cfg, plan) = nested_campaign();
    let (rep, _) = run_traced(&cfg, &plan, Engine::Events);
    for r in &rep.ranks {
        let total = r.phases.total();
        assert!(
            (total - r.finish_time).abs() <= 1e-9 * r.finish_time.max(1.0),
            "rank {}: charged {total} s over a {} s lifetime",
            r.world_rank,
            r.finish_time
        );
        let spans: f64 = r
            .trace
            .iter()
            .filter_map(|e| match *e {
                TraceEvent::Span { t0, t1, .. } => Some(t1 - t0),
                _ => None,
            })
            .sum();
        assert!(
            (spans - total).abs() <= 1e-9 * total.max(1.0),
            "rank {}: span coverage {spans} != charged {total}",
            r.world_rank
        );
    }
}
