//! Determinism properties of the event engine (DESIGN.md §12): the same
//! seed must reproduce the run bit-for-bit, different failure seeds must
//! actually change the execution (the determinism is not vacuous), and the
//! single-threaded scheduler must carry worlds far beyond what
//! thread-per-rank can launch — the 4096-rank smoke campaign here is ~16x
//! past the point where 2 MB rank stacks alone would cost 8 GB of address
//! space.

mod common;

use std::time::Instant;

use common::{digest, quick_config, Rng};
use ulfm_ftgmres::config::RunConfig;
use ulfm_ftgmres::coordinator;
use ulfm_ftgmres::failure::{BitFlip, InjectionPlan, Kill, LinkFault, Straggler};
use ulfm_ftgmres::metrics::RunReport;
use ulfm_ftgmres::problem::Grid3D;
use ulfm_ftgmres::recovery::Strategy;
use ulfm_ftgmres::simmpi::Engine;

/// A failure schedule derived from `seed`: `failures` distinct victims
/// (never rank 0) killed one checkpoint-window-plus apart, so every kill
/// is a separate recovery event with a committed floor in between.
fn seeded_plan(p: usize, failures: usize, seed: u64) -> InjectionPlan {
    let mut rng = Rng::new(seed);
    let mut victims: Vec<usize> = Vec::new();
    while victims.len() < failures {
        let v = 1 + rng.below(p - 1);
        if !victims.contains(&v) {
            victims.push(v);
        }
    }
    InjectionPlan {
        kills: victims
            .iter()
            .enumerate()
            .map(|(i, &v)| Kill::at_iter(v, 25 + 15 * i as u64))
            .collect(),
        ..Default::default()
    }
}

fn run_events(cfg: &RunConfig, plan: InjectionPlan) -> RunReport {
    let mut cfg = cfg.clone();
    cfg.engine = Engine::Events;
    let backend = coordinator::make_backend(&cfg).unwrap();
    coordinator::run_custom(&cfg, backend, plan).unwrap()
}

/// Same seed, three reruns: the event loop owns every scheduling choice, so
/// reruns must be bit-identical down to virtual clocks, decision logs and
/// checkpoint byte counts.
#[test]
fn same_seed_reproduces_bit_identical_runs() {
    let cfg = quick_config(8, Strategy::Shrink, 0);
    let first = digest(&run_events(&cfg, seeded_plan(8, 2, 3)));
    for rerun in 0..2 {
        let again = digest(&run_events(&cfg, seeded_plan(8, 2, 3)));
        assert_eq!(first, again, "rerun {rerun} diverged under the event engine");
    }
}

/// Different failure seeds must produce different executions — different
/// victims, hence different decision tables and digests.  Guards against a
/// determinism test that passes because the injection plumbing is inert.
#[test]
fn different_seeds_change_the_decision_table() {
    let cfg = quick_config(8, Strategy::Shrink, 0);
    let (plan_a, plan_b) = (seeded_plan(8, 2, 3), seeded_plan(8, 2, 12));
    let victims = |p: &InjectionPlan| p.kills.iter().map(|k| k.world_rank).collect::<Vec<_>>();
    assert_ne!(victims(&plan_a), victims(&plan_b), "seeds 3 and 12 pick distinct victims");
    let a = run_events(&cfg, plan_a);
    let b = run_events(&cfg, plan_b);
    assert!(a.converged && b.converged);
    assert_eq!(a.failures, 2);
    assert_eq!(b.failures, 2);
    let table = |r: &RunReport| {
        r.decisions.iter().map(|d| d.failed_ranks.clone()).collect::<Vec<_>>()
    };
    assert_ne!(table(&a), table(&b), "decision tables must track the failure schedule");
    assert_ne!(digest(&a), digest(&b));
}

/// The full degraded-mode universe — straggler shrink-away, lossy-link
/// retries, a scrubbed bit-flip *and* a crash-stop kill in one campaign —
/// is rerun-stable under the event engine: timeout loops, detector
/// allgathers and scrub repair traffic introduce no scheduling freedom.
#[test]
fn same_seed_degraded_campaign_is_rerun_stable() {
    let cfg = quick_config(8, Strategy::Shrink, 0);
    let plan = || InjectionPlan {
        kills: vec![Kill::at_iter(2, 70)],
        stragglers: vec![Straggler { world_rank: 6, mult: 3.0 }],
        links: vec![LinkFault { src: 1, dst: 2, drops: 3 }],
        bitflips: vec![BitFlip { world_rank: 4, at_version: 3, bits: 4 }],
    };
    let first = run_events(&cfg, plan());
    assert!(first.converged);
    assert_eq!(first.failures, 2);
    assert_eq!(first.global_restarts(), 0);
    assert!(first.faults.link_retries >= 3 && first.faults.scrub_detected >= 1);
    let first = digest(&first);
    for rerun in 0..2 {
        let again = digest(&run_events(&cfg, plan()));
        assert_eq!(first, again, "degraded rerun {rerun} diverged");
    }
}

/// Same seed, three fleet runs under the event engine: the shared arbiter
/// (lease ledger, bandwidth gate, breakers) only ever advances through
/// arbitrations made in the fixed arbiter order, so the whole fleet digest
/// — per-job decision logs, arbitration ledger, virtual clocks — must be
/// bit-identical across reruns.
#[test]
fn same_seed_fleet_campaign_is_rerun_stable() {
    use ulfm_ftgmres::coordinator::fleet::{run_fleet_custom, FleetSpec};
    let mut cfg = quick_config(8, Strategy::Shrink, 0);
    cfg.engine = Engine::Events;
    cfg.fleet = Some(
        FleetSpec::parse("jobs=urgent,prio=5+batch,prio=1;warm=1;breaker_k=10;breaker_w=1000")
            .unwrap(),
    );
    let kill = |r: usize| InjectionPlan {
        kills: vec![Kill::at_iter(r, 25)],
        ..Default::default()
    };
    let digest = || run_fleet_custom(&cfg, &[kill(2), kill(2)]).unwrap().digest();
    let first = digest();
    assert!(first.contains("verdict=preempted"), "contention present:\n{first}");
    for rerun in 0..2 {
        assert_eq!(first, digest(), "fleet rerun {rerun} diverged under the event engine");
    }
}

/// The thread oracle is itself rerun-stable (a prerequisite for using it as
/// the differential baseline in engine_differential.rs).
#[test]
fn thread_oracle_is_rerun_stable() {
    let cfg = quick_config(8, Strategy::Shrink, 0);
    let run = |plan: InjectionPlan| {
        let backend = coordinator::make_backend(&cfg).unwrap();
        digest(&coordinator::run_custom(&cfg, backend, plan).unwrap())
    };
    assert_eq!(run(seeded_plan(8, 2, 3)), run(seeded_plan(8, 2, 3)));
}

/// 4096-rank weak-scaling smoke: a world far past thread-per-rank territory
/// survives eight sequential failures under shrink with zero global
/// restarts.  The kills stay inside the first ~90 inner iterations (one
/// checkpoint window apart, bounded replay) so the campaign completes well
/// within the cycle budget whether or not the residual target is reached.
#[test]
fn four_thousand_ranks_eight_failures_no_global_restart() {
    const P: usize = 4096;
    let mut cfg = quick_config(P, Strategy::Shrink, 0);
    cfg.grid = Grid3D::cube(26); // 17576 rows >= 4*P
    // Bound total work, not correctness: one outer cycle of 12 windows is
    // 120 net inner iterations — past the last kill at 85 with margin, and
    // the residual target is unreachable on this grid anyway (the smoke
    // asserts survival and in-place recovery, not convergence).
    cfg.solver.m_outer = 12;
    cfg.solver.max_cycles = 1;
    let victims = [4095usize, 2047, 3000, 1000, 500, 1500, 2500, 3500];
    let plan = InjectionPlan {
        kills: victims
            .iter()
            .enumerate()
            .map(|(i, &v)| Kill::at_iter(v, 15 + 10 * i as u64))
            .collect(),
        ..Default::default()
    };
    let started = Instant::now();
    let rep = run_events(&cfg, plan);
    let wall = started.elapsed();
    assert_eq!(rep.failures, 8, "all eight kills must fire");
    assert_eq!(rep.global_restarts(), 0, "every failure recovered in place");
    assert_eq!(rep.decisions.len(), 8, "one decision per failure event");
    let killed = rep.ranks.iter().filter(|r| r.killed).count();
    assert_eq!(killed, 8);
    assert!(rep.iterations > 95, "ran past the last kill: {}", rep.iterations);
    // Generous bound: catches accidental O(n^2) scheduling, not CI jitter
    // (release builds finish this in single-digit seconds).
    assert!(wall.as_secs() < 180, "4k-rank smoke took {wall:?}");
}
