//! Shared helpers for the integration tests: tiny-world builders, quick run
//! configs, and a dependency-free PRNG for the property-based tests.

#![allow(dead_code)]

use std::sync::mpsc::Receiver;
use std::sync::Arc;

use ulfm_ftgmres::config::RunConfig;
use ulfm_ftgmres::failure::{InjectionPlan, Injector};
use ulfm_ftgmres::netsim::NetParams;
use ulfm_ftgmres::problem::Grid3D;
use ulfm_ftgmres::recovery::Strategy;
use ulfm_ftgmres::simmpi::{Ctx, Msg, World};

/// SplitMix64 — deterministic, seedable, no dependencies.
pub struct Rng(pub u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.wrapping_add(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform f64 in [-1, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 52) as f64 * 2.0 - 1.0
    }
}

/// Spin until `rank` is registered dead (tests that kill a rank and then
/// immediately act on membership must synchronize with the registry write,
/// as the production path does via failure detection).
pub fn wait_dead(world: &World, rank: usize) {
    while world.is_alive(rank) {
        std::thread::yield_now();
    }
}

/// Build a world of `n` app ranks (no spares) with per-rank contexts.
pub fn tiny_world(n: usize) -> (Arc<World>, Vec<(usize, Receiver<Msg>)>) {
    let (w, rxs) = World::new(
        n,
        0,
        NetParams::default(),
        Injector::new(InjectionPlan::none()),
    );
    (w, rxs.into_iter().enumerate().collect())
}

/// Run `f` on `n` rank threads, each given its `Ctx`; returns per-rank
/// results in rank order.
pub fn run_ranks<T, F>(n: usize, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(Ctx) -> T + Send + Sync + 'static,
{
    run_ranks_plan(n, InjectionPlan::none(), f)
}

/// Like [`run_ranks`], but with a failure-injection plan driving the world
/// (protocol-phase kills, scheduled iteration kills).
pub fn run_ranks_plan<T, F>(n: usize, plan: InjectionPlan, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(Ctx) -> T + Send + Sync + 'static,
{
    let (w, rxs) = World::new(n, 0, NetParams::default(), Injector::new(plan));
    let f = Arc::new(f);
    let handles: Vec<_> = rxs
        .into_iter()
        .enumerate()
        .map(|(rank, rx)| {
            let w = w.clone();
            let f = f.clone();
            std::thread::spawn(move || f(Ctx::new(w, rank, rx)))
        })
        .collect();
    handles.into_iter().map(|h| h.join().expect("rank thread panicked")).collect()
}

/// A seconds-scale solver config for integration tests.
pub fn quick_config(p: usize, strategy: Strategy, failures: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.grid = Grid3D::cube(12);
    cfg.p = p;
    cfg.strategy = strategy;
    cfg.failures = failures;
    cfg.solver.tol = 1e-10;
    // A short inner solve compresses the kill schedule (kills at iterations
    // 25, 40, 55, 70) so multi-failure campaigns fit small problems.
    cfg.solver.m_inner = 10;
    cfg.solver.m_outer = 20;
    cfg.solver.max_cycles = 20;
    cfg
}
