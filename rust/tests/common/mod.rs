//! Shared helpers for the integration tests: tiny-world builders, quick run
//! configs, and a dependency-free PRNG for the property-based tests.

#![allow(dead_code)]

use std::fmt::Write as _;
use std::future::Future;
use std::sync::Arc;

use ulfm_ftgmres::config::RunConfig;
use ulfm_ftgmres::failure::{InjectionPlan, Injector};
use ulfm_ftgmres::metrics::RunReport;
use ulfm_ftgmres::netsim::NetParams;
use ulfm_ftgmres::problem::Grid3D;
use ulfm_ftgmres::recovery::Strategy;
use ulfm_ftgmres::simmpi::{block_on, Ctx, World};

/// SplitMix64 — deterministic, seedable, no dependencies.
pub struct Rng(pub u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.wrapping_add(0x9E3779B97F4A7C15))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.0 = self.0.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.0;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform f64 in [-1, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 52) as f64 * 2.0 - 1.0
    }
}

/// Spin until `rank` is registered dead (tests that kill a rank and then
/// immediately act on membership must synchronize with the registry write,
/// as the production path does via failure detection).
pub fn wait_dead(world: &World, rank: usize) {
    while world.is_alive(rank) {
        std::thread::yield_now();
    }
}

/// Build a world of `n` app ranks (no spares).
pub fn tiny_world(n: usize) -> Arc<World> {
    World::new(n, 0, NetParams::default(), Injector::new(InjectionPlan::none()))
}

/// Run async rank body `f` on `n` rank threads (thread engine), each given
/// its `Ctx`; returns per-rank results in rank order.
pub fn run_ranks<T, F, Fut>(n: usize, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(Ctx) -> Fut + Send + Sync + 'static,
    Fut: Future<Output = T>,
{
    run_ranks_plan(n, InjectionPlan::none(), f)
}

/// Like [`run_ranks`], but with a failure-injection plan driving the world
/// (protocol-phase kills, scheduled iteration kills).
pub fn run_ranks_plan<T, F, Fut>(n: usize, plan: InjectionPlan, f: F) -> Vec<T>
where
    T: Send + 'static,
    F: Fn(Ctx) -> Fut + Send + Sync + 'static,
    Fut: Future<Output = T>,
{
    let w = World::new(n, 0, NetParams::default(), Injector::new(plan));
    let f = Arc::new(f);
    let handles: Vec<_> = (0..n)
        .map(|rank| {
            let w = w.clone();
            let f = f.clone();
            std::thread::spawn(move || block_on(f(Ctx::new(w, rank))))
        })
        .collect();
    handles.into_iter().map(|h| h.join().expect("rank thread panicked")).collect()
}

/// Everything observable about a run, rendered deterministically: solver
/// outcome bits, virtual-time bits, per-rank fates, the merged decision log
/// and the exact per-version checkpoint byte accounting.  Two runs are "the
/// same execution" iff these strings are equal (engine_differential.rs,
/// scheduler_determinism.rs).
pub fn digest(rep: &RunReport) -> String {
    let mut s = String::new();
    writeln!(
        s,
        "tts={:016x} relres={:016x} iters={} conv={} fails={} retries={} restarts={} \
         linkretry={} scrubdet={} scrubfix={}",
        rep.time_to_solution.to_bits(),
        rep.final_relres.to_bits(),
        rep.iterations,
        rep.converged,
        rep.failures,
        rep.recovery_retries,
        rep.global_restarts(),
        rep.faults.link_retries,
        rep.faults.scrub_detected,
        rep.faults.scrub_repaired,
    )
    .unwrap();
    for r in &rep.ranks {
        writeln!(
            s,
            "rank {} t={:016x} it={} killed={} spare={} retries={} faults={}/{}/{}",
            r.world_rank,
            r.finish_time.to_bits(),
            r.iterations,
            r.killed,
            r.was_spare,
            r.recovery_retries,
            r.faults.link_retries,
            r.faults.scrub_detected,
            r.faults.scrub_repaired,
        )
        .unwrap();
    }
    for d in &rep.decisions {
        writeln!(
            s,
            "decision {} at={:016x} failed={:?} {} attempt={} warm={} cold={} reason={}",
            d.seq,
            d.at.to_bits(),
            d.failed_ranks,
            d.decision,
            d.attempt,
            d.warm_free,
            d.cold_free,
            d.reason,
        )
        .unwrap();
    }
    for c in &rep.ckpt {
        writeln!(
            s,
            "ckpt v={} at={:016x} log={} ship={} raw={} delta={} rot={} enc={:016x}",
            c.version,
            c.at.to_bits(),
            c.logical_bytes,
            c.shipped_bytes,
            c.raw_bytes,
            c.delta,
            c.rotation,
            c.encode_secs.to_bits(),
        )
        .unwrap();
    }
    s
}

/// A seconds-scale solver config for integration tests.
pub fn quick_config(p: usize, strategy: Strategy, failures: usize) -> RunConfig {
    let mut cfg = RunConfig::default();
    cfg.grid = Grid3D::cube(12);
    cfg.p = p;
    cfg.strategy = strategy;
    cfg.failures = failures;
    cfg.solver.tol = 1e-10;
    // A short inner solve compresses the kill schedule (kills at iterations
    // 25, 40, 55, 70) so multi-failure campaigns fit small problems.
    cfg.solver.m_inner = 10;
    cfg.solver.m_outer = 20;
    cfg.solver.max_cycles = 20;
    cfg
}
