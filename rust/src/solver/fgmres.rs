//! FT-GMRES: flexible outer GMRES preconditioned by an inner GMRES solve
//! (Hoemmen & Heroux's inner-outer partitioning, as used by the paper).
//!
//! The outer iteration builds a flexible Krylov basis (V, Z); each outer
//! step j runs one *inner solve* of `m_inner` unrestarted GMRES iterations
//! (the paper's "every 25 iterations"), then checkpoints the dynamic state
//! — cycle-start solution x0, the bases built so far, and the replicated
//! least-squares state — so recovery resumes the cycle exactly where it
//! stopped and recomputes at most one inner solve.  Orthogonalization is
//! CGS with optional re-orthogonalization (CGS2), matching Trilinos' ICGS.
//!
//! Process failures surface as `MpiError` out of any communication call and
//! propagate out of [`FtGmres::solve`]; the recovery driver in
//! [`crate::recovery`] repairs the communicator and state, then re-enters
//! `solve` — the Rust rendering of the paper's "C++ exception handling to
//! jump to the beginning of the iterative block".

use crate::backend::{Backend, DenseBasis};
use crate::checkpoint::CkptStore;
use crate::ckptstore::CkptCfg;
use crate::metrics::Phase;
use crate::netsim::ComputeModel;
use crate::simmpi::{Comm, Ctx, MpiResult};
use crate::solver::givens::GivensLs;
use crate::solver::parops::{allreduce, charge_host, matvec, norm2_sq, Scratch};
use crate::solver::state::{CycleCtl, SolverState};

/// Numerical breakdown threshold for Arnoldi (relative to the cycle norm).
const BREAKDOWN: f64 = 1e-13;

#[derive(Debug, Clone)]
pub struct FtGmresCfg {
    /// Outer (flexible) basis size per restart cycle.
    pub m_outer: usize,
    /// Inner GMRES iterations per outer step (the paper's 25).
    pub m_inner: usize,
    /// Outer relative-residual convergence tolerance.
    pub tol: f64,
    /// Maximum outer restart cycles before giving up.
    pub max_cycles: usize,
    /// CGS2 re-orthogonalization (Trilinos ICGS-style).
    pub reorth: bool,
    /// Checkpoint-store configuration: redundancy scheme (`mirror:<k>` /
    /// `xor:<g>`) and the delta layer (see [`crate::ckptstore`]).
    pub ckpt: CkptCfg,
    /// Checkpointing on/off (off for the no-protection baseline).
    pub ckpt_enabled: bool,
    /// Early-exit tolerance for the inner solve (0 = fixed m_inner iters,
    /// the paper's configuration).
    pub inner_tol: f64,
    /// Straggler detector configuration ([`crate::recovery::degraded`]);
    /// `None` (the default) disables the per-cycle detector allgather so
    /// failure-only campaigns keep their exact wire schedule.
    pub degraded: Option<crate::recovery::degraded::DegradedCfg>,
}

impl Default for FtGmresCfg {
    fn default() -> Self {
        FtGmresCfg {
            m_outer: 25,
            m_inner: 25,
            tol: 1e-8,
            max_cycles: 8,
            reorth: true,
            ckpt: CkptCfg::default(),
            ckpt_enabled: true,
            inner_tol: 0.0,
            degraded: None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Outcome {
    pub converged: bool,
    /// Final *true* relative residual ||b - Ax|| / ||b||.
    pub relres: f64,
    /// Outer restart cycles used.
    pub cycles: usize,
}

/// Per-solve workspace (inner basis is not checkpointed: losing it costs at
/// most one inner solve of recomputation).
struct Workspace {
    v_in: DenseBasis,
    h: Vec<f64>,
    scratch: Scratch,
}

pub struct FtGmres<'a> {
    pub cfg: &'a FtGmresCfg,
    pub backend: &'a dyn Backend,
    pub host: ComputeModel,
}

impl<'a> FtGmres<'a> {
    pub fn new(cfg: &'a FtGmresCfg, backend: &'a dyn Backend, host: ComputeModel) -> Self {
        FtGmres { cfg, backend, host }
    }

    /// Run (or resume, after recovery) the solve.  On process failure the
    /// error propagates out with `state`/`store` in a recoverable condition:
    /// the last committed checkpoint plus consistent scalars.
    pub async fn solve(
        &self,
        ctx: &mut Ctx,
        comm: &mut Comm,
        state: &mut SolverState,
        store: &mut CkptStore,
    ) -> MpiResult<Outcome> {
        let cfg = self.cfg;
        let r = state.rows();
        debug_assert_eq!(state.v_out.m, cfg.m_outer + 1, "basis sized by setup");
        let mut ws = Workspace {
            v_in: DenseBasis::zeros(cfg.m_inner + 1, r),
            h: vec![0.0; cfg.m_outer.max(cfg.m_inner) + 1],
            scratch: Scratch::default(),
        };
        let mut resid = vec![0.0; r];

        for cycle in 0..cfg.max_cycles {
            // --- start of the iterative block (recovery re-entry point) ---
            let (mut ls, j_start) = match state.cycle.take() {
                Some(c) => {
                    // Resuming a checkpointed cycle: V, Z, ls are restored.
                    let j = c.j_done;
                    state.cycle = Some(c.clone());
                    (c.ls, j + 1)
                }
                None => {
                    // Fresh cycle: r0 = b - A x0.
                    matvec(
                        ctx,
                        comm,
                        self.backend,
                        &state.blk,
                        &state.x,
                        &mut resid,
                        &mut ws.scratch,
                    )
                    .await?;
                    for i in 0..r {
                        resid[i] = state.b[i] - resid[i];
                    }
                    charge_host(ctx, &self.host, r as f64, 24.0 * r as f64);
                    let beta = norm2_sq(ctx, comm, &self.host, &resid).await?.sqrt();
                    if beta / state.scalars.bnorm < cfg.tol {
                        return Ok(Outcome {
                            converged: true,
                            relres: beta / state.scalars.bnorm,
                            cycles: cycle,
                        });
                    }
                    state.v_out.row_mut(0).copy_from_slice(&resid);
                    let prev = ctx.set_phase(Phase::Compute);
                    let secs = self.backend.scale(state.v_out.row_mut(0), 1.0 / beta);
                    ctx.advance(secs);
                    ctx.set_phase(prev);
                    (GivensLs::new(cfg.m_outer, beta), 0)
                }
            };

            let mut done = false;
            for j in j_start..cfg.m_outer {
                // Inner solve: z_j ~= A^{-1} v_j  (m_inner iterations).
                let vj = state.v_out.row(j).to_vec();
                let zj = self.inner_solve(ctx, comm, state, &mut ws, &vj).await?;
                state.z_out.row_mut(j).copy_from_slice(&zj);

                // w = A z_j.
                let mut w = vec![0.0; r];
                matvec(ctx, comm, self.backend, &state.blk, &zj, &mut w, &mut ws.scratch)
                    .await?;

                // Orthogonalize against V[0..=j].
                let hnext = self
                    .orthogonalize(ctx, comm, &state.v_out, j + 1, &mut w, &mut ws.h)
                    .await?;

                let mut col = ws.h[..j + 1].to_vec();
                col.push(hnext);
                let est = ls.push_col(&col);
                charge_host(ctx, &self.host, ls.push_flops(), 8.0 * ls.push_flops());
                let relres_est = est / state.scalars.bnorm;

                let breakdown = hnext <= BREAKDOWN * ls.residual().max(state.scalars.bnorm);
                if relres_est < cfg.tol || breakdown || j + 1 == cfg.m_outer {
                    // Cycle over: fold the correction into x (x = x0 + Z y).
                    let y = ls.solve_y();
                    charge_host(ctx, &self.host, ls.solve_flops(), 8.0 * ls.solve_flops());
                    let mut y_full = vec![0.0; state.z_out.m];
                    y_full[..y.len()].copy_from_slice(&y);
                    let mut x_new = state.x.clone();
                    let prev = ctx.set_phase(Phase::Compute);
                    let secs =
                        self.backend.update_x(&state.z_out, y.len(), &y_full, &mut x_new);
                    ctx.advance(secs);
                    ctx.set_phase(prev);
                    state.x = x_new;
                    state.cycle = None;
                    done = relres_est < cfg.tol;
                    break;
                }

                // Extend the basis and checkpoint the completed step
                // (dynamic state after each inner solve — paper §VI).
                state.v_out.row_mut(j + 1).copy_from_slice(&w);
                let prev = ctx.set_phase(Phase::Compute);
                let secs = self.backend.scale(state.v_out.row_mut(j + 1), 1.0 / hnext);
                ctx.advance(secs);
                ctx.set_phase(prev);

                state.cycle = Some(CycleCtl { j_done: j, ls: ls.clone() });
                if cfg.ckpt_enabled {
                    state.checkpoint_dynamic(ctx, comm, store, &cfg.ckpt).await?;
                }
                // Degraded-rank detection rides the same outer-cycle
                // cadence: compare useful-work timers across the cohort
                // and shrink away a straggler when tolerating it prices
                // above losing its rank (no-op unless configured).
                crate::recovery::degraded::straggler_check(ctx, comm, state, cfg, &self.host)
                    .await?;
            }
            let _ = done; // true residual verified at the next loop top
        }

        // Out of cycles: report the true residual.
        matvec(ctx, comm, self.backend, &state.blk, &state.x, &mut resid, &mut ws.scratch)
            .await?;
        for i in 0..r {
            resid[i] = state.b[i] - resid[i];
        }
        let beta = norm2_sq(ctx, comm, &self.host, &resid).await?.sqrt();
        let relres = beta / state.scalars.bnorm;
        Ok(Outcome { converged: relres < cfg.tol, relres, cycles: cfg.max_cycles })
    }

    /// One inner solve: z ~= A^{-1} rhs via `m_inner` unrestarted GMRES
    /// iterations with zero initial guess.  Returns z.
    async fn inner_solve(
        &self,
        ctx: &mut Ctx,
        comm: &mut Comm,
        state: &mut SolverState,
        ws: &mut Workspace,
        rhs: &[f64],
    ) -> MpiResult<Vec<f64>> {
        let cfg = self.cfg;
        let r = state.rows();
        let beta = norm2_sq(ctx, comm, &self.host, rhs).await?.sqrt();
        let mut z = vec![0.0; r];
        if beta == 0.0 {
            return Ok(z);
        }

        ws.v_in.row_mut(0).copy_from_slice(rhs);
        let prev = ctx.set_phase(Phase::Compute);
        let secs = self.backend.scale(ws.v_in.row_mut(0), 1.0 / beta);
        ctx.advance(secs);
        ctx.set_phase(prev);

        let mut ls = GivensLs::new(cfg.m_inner, beta);
        let mut k_used = 0;
        for i in 0..cfg.m_inner {
            self.tick_iteration(ctx, state)?;

            let vi = ws.v_in.row(i).to_vec();
            let mut w = vec![0.0; r];
            matvec(ctx, comm, self.backend, &state.blk, &vi, &mut w, &mut ws.scratch).await?;
            let hnext = self
                .orthogonalize(ctx, comm, &ws.v_in, i + 1, &mut w, &mut ws.h)
                .await?;

            let mut col = ws.h[..i + 1].to_vec();
            col.push(hnext);
            let est = ls.push_col(&col);
            charge_host(ctx, &self.host, ls.push_flops(), 8.0 * ls.push_flops());
            k_used = i + 1;

            if hnext <= BREAKDOWN * beta {
                break;
            }
            ws.v_in.row_mut(i + 1).copy_from_slice(&w);
            let prev = ctx.set_phase(Phase::Compute);
            let secs = self.backend.scale(ws.v_in.row_mut(i + 1), 1.0 / hnext);
            ctx.advance(secs);
            ctx.set_phase(prev);

            if cfg.inner_tol > 0.0 && est / beta < cfg.inner_tol {
                break;
            }
        }

        let y = ls.solve_y();
        charge_host(ctx, &self.host, ls.solve_flops(), 8.0 * ls.solve_flops());
        let mut y_full = vec![0.0; ws.v_in.m];
        y_full[..y.len()].copy_from_slice(&y);
        let prev = ctx.set_phase(Phase::Compute);
        let secs = self.backend.update_x(&ws.v_in, k_used, &y_full, &mut z);
        ctx.advance(secs);
        ctx.set_phase(prev);
        Ok(z)
    }

    /// CGS(2) orthogonalization of `w` against `v[0..m_used]`.
    /// On return `h_out[0..m_used]` holds the (accumulated) projection
    /// coefficients and the result is the *global* norm of the new w.
    async fn orthogonalize(
        &self,
        ctx: &mut Ctx,
        comm: &mut Comm,
        v: &DenseBasis,
        m_used: usize,
        w: &mut [f64],
        h_out: &mut [f64],
    ) -> MpiResult<f64> {
        let passes = if self.cfg.reorth { 2 } else { 1 };
        let mut h_acc = vec![0.0; m_used];
        let mut nsq_local = 0.0;
        for _ in 0..passes {
            let mut h = vec![0.0; v.m];
            let prev = ctx.set_phase(Phase::Compute);
            let secs = self.backend.dot_partials(v, m_used, w, &mut h);
            ctx.advance(secs);
            ctx.set_phase(prev);
            allreduce(ctx, comm, &mut h[..m_used]).await?;
            let prev = ctx.set_phase(Phase::Compute);
            let (nsq, secs) = self.backend.update_w(v, m_used, w, &h);
            ctx.advance(secs);
            ctx.set_phase(prev);
            nsq_local = nsq;
            for i in 0..m_used {
                h_acc[i] += h[i];
            }
        }
        let mut buf = [nsq_local];
        allreduce(ctx, comm, &mut buf).await?;
        h_out[..m_used].copy_from_slice(&h_acc);
        Ok(buf[0].sqrt())
    }

    /// Per-inner-iteration bookkeeping: failure injection, progress counter,
    /// recompute-phase routing.
    fn tick_iteration(&self, ctx: &mut Ctx, state: &mut SolverState) -> MpiResult<()> {
        let next = state.scalars.inner_iters_done + 1;
        // A rank already marked dead in the registry (co-scheduled
        // simultaneous kill claimed by a peer) must also terminate.
        if ctx.world.injector.should_die(ctx.rank, next) || !ctx.world.is_alive(ctx.rank) {
            return Err(ctx.die());
        }
        ctx.recompute = next <= state.hwm_iters;
        state.scalars.inner_iters_done = next;
        state.hwm_iters = state.hwm_iters.max(next);
        ctx.iterations += 1;
        let (n, at) = (ctx.iterations, ctx.clock);
        ctx.trace_push(|| crate::trace::TraceEvent::Iter { n, t: at });
        Ok(())
    }
}
