//! Per-rank solver state: the distributed objects the paper checkpoints
//! (static matrix block + rhs; dynamic solution vector, Krylov basis and
//! iteration state) plus the localized compute structures rebuilt after
//! every recovery.
//!
//! The dynamic checkpoint taken after each inner solve contains everything
//! needed to resume the outer FGMRES cycle exactly where it stopped:
//! the cycle-start solution x0, the flexible bases V and Z built so far,
//! and the (replicated) rotated-Hessenberg least-squares state.  Recovery
//! therefore recomputes at most one inner solve — the paper's "upper bound
//! on the amount of re-computation".

use crate::backend::DenseBasis;
use crate::checkpoint::{obj, CkptStore, Version};
use crate::ckptstore::CkptCfg;
use crate::metrics::Phase;
use crate::netsim::ComputeModel;
use crate::problem::{EllBlock, Grid3D, MatrixRows, Partition};
use crate::simmpi::{Blob, Comm, Ctx, MpiResult};
use crate::solver::givens::GivensLs;

/// The synthetic truth vector: analytic, so RHS generation and solution
/// verification need no communication.
pub fn x_true(g: usize) -> f64 {
    (g as f64 * 0.017).sin() + 0.5 * (g as f64 * 0.003).cos()
}

/// Generate this rank's block of the analytic test problem under `part`:
/// matrix rows, localized ELL block, and the analytic RHS (`b = A x_true`,
/// computable locally), charging the modeled generation costs.  The single
/// source of the rebuild recipe — used by initial [`SolverState::setup`]
/// and by the global-restart escalation path
/// ([`crate::recovery::global_restart::restart_on_survivors`]), so both
/// construct the identical problem at identical virtual cost.
pub fn generate_local_problem(
    ctx: &mut Ctx,
    host: &ComputeModel,
    grid: Grid3D,
    part: &Partition,
    me: usize,
) -> (MatrixRows, EllBlock, Vec<f64>) {
    use crate::problem::K;
    let range = part.range(me);
    let mat = MatrixRows::generate(&grid, range.start, range.len());
    // Generation cost: touch every slot once.
    ctx.advance(host.cost((mat.rows * K) as f64, (12 * mat.rows * K) as f64));
    let blk = EllBlock::build(&mat, part, me);
    let mut b = vec![0.0; mat.rows];
    for r in 0..mat.rows {
        let mut acc = 0.0;
        for k in 0..K {
            let idx = r * K + k;
            acc += mat.vals[idx] * x_true(mat.gcols[idx] as usize);
        }
        b[r] = acc;
    }
    ctx.advance(host.cost((2 * mat.rows * K) as f64, (16 * mat.rows * K) as f64));
    (mat, blk, b)
}

/// Iteration scalars kept consistent across ranks (the paper's "local state
/// which is supposed to be consistent across processes").
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct IterScalars {
    /// Global inner-iteration progress counter.
    pub inner_iters_done: u64,
    /// Next checkpoint version to write.
    pub next_version: Version,
    /// Global ||b||.
    pub bnorm: f64,
}

/// Mid-cycle outer-iteration state (replicated small data).
#[derive(Debug, Clone)]
pub struct CycleCtl {
    /// Index of the last fully completed outer step.
    pub j_done: usize,
    /// Rotated Hessenberg least-squares state for the cycle.
    pub ls: GivensLs,
}

/// Full per-rank solver state.
#[derive(Debug)]
pub struct SolverState {
    pub grid: Grid3D,
    /// Current block-row partition (over the current communicator size).
    pub part: Partition,
    /// My matrix rows (global columns) — the redistribution currency.
    pub mat: MatrixRows,
    /// Localized ELL block + halo plan.
    pub blk: EllBlock,
    /// Cycle-start solution block x0 (live rows).  Only updated at cycle
    /// boundaries; mid-cycle progress lives in (V, Z, ls).
    pub x: Vec<f64>,
    /// RHS block.
    pub b: Vec<f64>,
    /// Outer flexible basis V (m_outer + 1 slots).
    pub v_out: DenseBasis,
    /// Outer preconditioned basis Z (m_outer slots).
    pub z_out: DenseBasis,
    /// Mid-cycle control (None between cycles).
    pub cycle: Option<CycleCtl>,
    pub scalars: IterScalars,
    /// Iteration high-water mark: work below this is recomputation.
    pub hwm_iters: u64,
}

/// Rollback image of everything a recovery attempt mutates in
/// [`SolverState`] (restore, redistribution, relocalization).
///
/// The epoch-fenced recovery driver
/// ([`crate::recovery::handle_failure_fenced`]) snapshots the state once
/// per failure event and rolls back before re-entering after a nested
/// failure poisoned an attempt: a half-redistributed partition must never
/// leak into the next attempt's transfer planning, which derives the
/// segment list from `state.part` *as of the failed communicator*.  The
/// checkpoint store needs no counterpart — commits are atomic-by-version
/// (a torn commit never advances the committed floor) and reconstruction
/// writes are idempotent at fixed versions.
#[derive(Debug, Clone)]
pub struct StateSnapshot {
    part: Partition,
    mat: MatrixRows,
    blk: EllBlock,
    x: Vec<f64>,
    b: Vec<f64>,
    v_out: DenseBasis,
    z_out: DenseBasis,
    cycle: Option<CycleCtl>,
    scalars: IterScalars,
    hwm_iters: u64,
}

impl SolverState {
    /// Capture the rollback image for one recovery event (see
    /// [`StateSnapshot`]).
    pub fn snapshot(&self) -> StateSnapshot {
        StateSnapshot {
            part: self.part.clone(),
            mat: self.mat.clone(),
            blk: self.blk.clone(),
            x: self.x.clone(),
            b: self.b.clone(),
            v_out: self.v_out.clone(),
            z_out: self.z_out.clone(),
            cycle: self.cycle.clone(),
            scalars: self.scalars,
            hwm_iters: self.hwm_iters,
        }
    }

    /// Roll the solver state back to a [`StateSnapshot`] (abandoned
    /// recovery attempt; grid never changes, so only the mutable pieces
    /// move).
    pub fn rollback(&mut self, snap: &StateSnapshot) {
        self.part = snap.part.clone();
        self.mat = snap.mat.clone();
        self.blk = snap.blk.clone();
        self.x = snap.x.clone();
        self.b = snap.b.clone();
        self.v_out = snap.v_out.clone();
        self.z_out = snap.z_out.clone();
        self.cycle = snap.cycle.clone();
        self.scalars = snap.scalars;
        self.hwm_iters = snap.hwm_iters;
    }

    /// Initial setup at comm rank `me` of `comm`: generate my rows (the
    /// paper's initial data distribution), build the halo plan, compute the
    /// analytic RHS, agree on ||b||, and seed the checkpoint store with the
    /// static objects and the initial dynamic state (version 0).
    #[allow(clippy::too_many_arguments)]
    pub async fn setup(
        ctx: &mut Ctx,
        comm: &mut Comm,
        store: &mut CkptStore,
        grid: Grid3D,
        host: &ComputeModel,
        m_outer: usize,
        ckpt: &CkptCfg,
        ckpt_enabled: bool,
    ) -> MpiResult<SolverState> {
        let me = comm.rank;
        let part = Partition::balanced(grid.n(), comm.size());
        let (mat, blk, b) = generate_local_problem(ctx, host, grid, &part, me);

        let prev = ctx.set_phase(Phase::Comm);
        let mut nsq = [b.iter().map(|v| v * v).sum::<f64>()];
        comm.allreduce_sum(ctx, &mut nsq).await?;
        ctx.set_phase(prev);
        let bnorm = nsq[0].sqrt();

        let rows = mat.rows;
        let mut state = SolverState {
            grid,
            part,
            mat,
            blk,
            x: vec![0.0; rows],
            b,
            v_out: DenseBasis::zeros(m_outer + 1, rows),
            z_out: DenseBasis::zeros(m_outer, rows),
            cycle: None,
            scalars: IterScalars { inner_iters_done: 0, next_version: 1, bnorm },
            hwm_iters: 0,
        };
        // Initial full checkpoint (static + dynamic) at version 0.
        if ckpt_enabled {
            state.establish_checkpoints(ctx, comm, store, 0, ckpt).await?;
        }
        Ok(state)
    }

    /// My live row count.
    pub fn rows(&self) -> usize {
        self.mat.rows
    }

    // ------------------------------------------------------------------
    // Checkpoint object (de)serialization
    // ------------------------------------------------------------------

    /// Dynamic basis payload: the live V rows (j_done + 2) and Z rows
    /// (j_done + 1) *interleaved* in creation order
    /// (`V0, Z0, V1, Z1, ..., V_{nv-1}`); empty between cycles.
    ///
    /// The interleaving makes consecutive versions of the blob pure
    /// *appends* — each outer step adds `[Z_j, V_{j+1}]` at the tail and
    /// never shifts existing bytes — which is exactly what the checkpoint
    /// delta layer ([`crate::ckptstore::delta`]) turns into two-row
    /// commits instead of reshipping the whole basis.  Everything that
    /// redistributes the blob (shrink's per-vector slicing and
    /// reassembly) treats it as `nv + nz` opaque rows and is agnostic to
    /// row order; only this function and [`SolverState::restore_basis`]
    /// know the interleaving.
    pub fn basis_blob(&self) -> Blob {
        match &self.cycle {
            None => Blob::from_i64s(vec![0, 0]),
            Some(c) => {
                let nv = c.j_done + 2;
                let nz = c.j_done + 1;
                let r = self.rows();
                let mut f = Vec::with_capacity((nv + nz) * r);
                for t in 0..nv {
                    f.extend_from_slice(self.v_out.row(t));
                    if t < nz {
                        f.extend_from_slice(self.z_out.row(t));
                    }
                }
                Blob::new(f, vec![nv as i64, nz as i64])
            }
        }
    }

    /// Iteration scalars + replicated least-squares state.
    pub fn iter_blob(&self) -> Blob {
        let (j, ls_flat) = match &self.cycle {
            None => (-1i64, Vec::new()),
            Some(c) => (c.j_done as i64, c.ls.to_flat()),
        };
        let mut f = vec![self.scalars.bnorm];
        f.extend_from_slice(&ls_flat);
        Blob::new(
            f,
            vec![self.scalars.inner_iters_done as i64, self.scalars.next_version, j],
        )
    }

    /// Restore scalars + cycle control from an ITER blob.
    pub fn restore_iter(&mut self, blob: &Blob) {
        self.scalars = IterScalars {
            inner_iters_done: blob.i[0] as u64,
            next_version: blob.i[1],
            bnorm: blob.f[0],
        };
        let j = blob.i[2];
        self.cycle = if j < 0 {
            None
        } else {
            Some(CycleCtl { j_done: j as usize, ls: GivensLs::from_flat(&blob.f[1..]) })
        };
    }

    /// Restore V/Z from a BASIS blob (already sliced to my current rows),
    /// undoing the interleaved layout of [`SolverState::basis_blob`].
    pub fn restore_basis(&mut self, blob: &Blob) {
        let r = self.rows();
        self.v_out = DenseBasis::zeros(self.v_out.m, r);
        self.z_out = DenseBasis::zeros(self.z_out.m, r);
        let nv = blob.i[0] as usize;
        let nz = blob.i[1] as usize;
        debug_assert_eq!(blob.f.len(), (nv + nz) * r, "basis blob shape mismatch");
        let (mut iv, mut iz) = (0usize, 0usize);
        for k in 0..nv + nz {
            let row = &blob.f[k * r..(k + 1) * r];
            // V leads on even positions while both kinds remain, then the
            // leftover kind finishes the tail (nv = nz + 1 in practice).
            if (k % 2 == 0 && iv < nv) || iz >= nz {
                self.v_out.row_mut(iv).copy_from_slice(row);
                iv += 1;
            } else {
                self.z_out.row_mut(iz).copy_from_slice(row);
                iz += 1;
            }
        }
        debug_assert!(iv == nv && iz == nz, "interleaved basis rows exhausted unevenly");
    }

    /// Bundle every checkpointed object at `version` and commit it through
    /// the configured redundancy scheme.  Used for the initial distribution
    /// and for post-recovery re-establishment (the paper's "update all the
    /// in-memory checkpoints") — always a *fresh* full commit, because
    /// membership or layout just changed.
    pub async fn establish_checkpoints(
        &mut self,
        ctx: &mut Ctx,
        comm: &mut Comm,
        store: &mut CkptStore,
        version: Version,
        ckpt: &CkptCfg,
    ) -> MpiResult<()> {
        let ds = ctx.world.net.params.data_scale;
        let objs = vec![
            (obj::MAT, self.mat.to_blob().scaled(ds)),
            (obj::RHS, Blob::from_f64s(self.b.clone()).scaled(ds)),
            (obj::X, Blob::from_f64s(self.x.clone()).scaled(ds)),
            (obj::BASIS, self.basis_blob().scaled(ds)),
            (obj::ITER, self.iter_blob()),
        ];
        crate::ckptstore::commit(ctx, comm, store, &objs, version, ckpt, true).await?;
        self.scalars.next_version = version + 1;
        Ok(())
    }

    /// Periodic dynamic-state checkpoint (x0 + basis + iteration state) —
    /// taken after each completed inner solve, per the paper.  Ships chunk
    /// deltas when the delta layer is on.
    ///
    /// Under `rs2`, commits at rotation/rebase boundaries
    /// ([`CkptCfg::static_reencode_due`]) additionally re-encode the static
    /// objects: the incoming holder pair starts with no stripes, so the
    /// matrix and rhs stripes must move along with the rotation for the
    /// whole restorable state to live on one holder pair.
    pub async fn checkpoint_dynamic(
        &mut self,
        ctx: &mut Ctx,
        comm: &mut Comm,
        store: &mut CkptStore,
        ckpt: &CkptCfg,
    ) -> MpiResult<()> {
        let version = self.scalars.next_version;
        let ds = ctx.world.net.params.data_scale;
        let mut objs = Vec::with_capacity(5);
        if ckpt.static_reencode_due(version) {
            objs.push((obj::MAT, self.mat.to_blob().scaled(ds)));
            objs.push((obj::RHS, Blob::from_f64s(self.b.clone()).scaled(ds)));
        }
        objs.push((obj::X, Blob::from_f64s(self.x.clone()).scaled(ds)));
        objs.push((obj::BASIS, self.basis_blob().scaled(ds)));
        objs.push((obj::ITER, self.iter_blob()));
        crate::ckptstore::commit(ctx, comm, store, &objs, version, ckpt, false).await?;
        self.scalars.next_version = version + 1;
        Ok(())
    }

    /// Rebuild localized structures after `mat`/`part` changed (recovery).
    pub fn relocalize(&mut self, me: usize) {
        self.blk = EllBlock::build(&self.mat, &self.part, me);
    }

    /// Verification: max |x - x_true| over local rows (examples/tests).
    pub fn local_error(&self) -> f64 {
        self.x
            .iter()
            .enumerate()
            .map(|(i, &v)| (v - x_true(self.mat.start + i)).abs())
            .fold(0.0, f64::max)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mini_state() -> SolverState {
        let grid = Grid3D::cube(4);
        let part = Partition::balanced(grid.n(), 1);
        let mat = MatrixRows::generate(&grid, 0, grid.n());
        let blk = EllBlock::build(&mat, &part, 0);
        let rows = mat.rows;
        SolverState {
            grid,
            part,
            mat,
            blk,
            x: vec![1.0; rows],
            b: vec![0.0; rows],
            v_out: DenseBasis::zeros(5, rows),
            z_out: DenseBasis::zeros(4, rows),
            cycle: None,
            scalars: IterScalars { inner_iters_done: 42, next_version: 3, bnorm: 2.5 },
            hwm_iters: 42,
        }
    }

    #[test]
    fn iter_blob_roundtrip_no_cycle() {
        let mut s = mini_state();
        let blob = s.iter_blob();
        s.scalars.bnorm = 0.0;
        s.restore_iter(&blob);
        assert_eq!(s.scalars.bnorm, 2.5);
        assert_eq!(s.scalars.inner_iters_done, 42);
        assert!(s.cycle.is_none());
    }

    #[test]
    fn iter_blob_roundtrip_mid_cycle() {
        let mut s = mini_state();
        let mut ls = GivensLs::new(4, 2.0);
        ls.push_col(&[1.0, 0.5]);
        s.cycle = Some(CycleCtl { j_done: 0, ls });
        let blob = s.iter_blob();
        s.cycle = None;
        s.restore_iter(&blob);
        let c = s.cycle.as_ref().unwrap();
        assert_eq!(c.j_done, 0);
        assert_eq!(c.ls.k(), 1);
    }

    #[test]
    fn basis_blob_roundtrip() {
        let mut s = mini_state();
        for i in 0..s.rows() {
            s.v_out.row_mut(0)[i] = i as f64;
            s.v_out.row_mut(1)[i] = 2.0 * i as f64;
            s.z_out.row_mut(0)[i] = 3.0 * i as f64;
        }
        let mut ls = GivensLs::new(4, 1.0);
        ls.push_col(&[1.0, 0.0]);
        s.cycle = Some(CycleCtl { j_done: 0, ls });
        let blob = s.basis_blob();
        assert_eq!(blob.i, vec![2, 1]);
        let v0: Vec<f64> = s.v_out.row(0).to_vec();
        s.v_out.reset();
        s.z_out.reset();
        s.restore_basis(&blob);
        assert_eq!(s.v_out.row(0), &v0[..]);
        assert_eq!(s.z_out.row(0)[2], 6.0);
    }

    #[test]
    fn basis_blob_empty_between_cycles() {
        let s = mini_state();
        let blob = s.basis_blob();
        assert_eq!(blob.i, vec![0, 0]);
        assert!(blob.f.is_empty());
    }

    #[test]
    fn snapshot_rollback_restores_mutated_state() {
        let mut s = mini_state();
        let snap = s.snapshot();
        // Mutate everything a recovery attempt touches.
        s.x.iter_mut().for_each(|v| *v = -9.0);
        s.b[0] = 123.0;
        s.scalars.inner_iters_done = 999;
        s.scalars.next_version = 77;
        s.hwm_iters = 999;
        s.v_out.row_mut(0)[0] = 5.0;
        s.cycle = Some(CycleCtl { j_done: 2, ls: GivensLs::new(4, 1.0) });
        s.rollback(&snap);
        assert_eq!(s.x, vec![1.0; s.rows()]);
        assert_eq!(s.b[0], 0.0);
        assert_eq!(s.scalars.inner_iters_done, 42);
        assert_eq!(s.scalars.next_version, 3);
        assert_eq!(s.hwm_iters, 42);
        assert_eq!(s.v_out.row(0)[0], 0.0);
        assert!(s.cycle.is_none());
    }

    #[test]
    fn x_true_is_bounded() {
        for g in 0..10_000 {
            assert!(x_true(g).abs() < 1.6);
        }
    }
}
