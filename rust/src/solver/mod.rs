//! Distributed FT-GMRES solver (paper §V-§VI).
//!
//! * [`fgmres`] — the flexible inner-outer iteration with checkpointing
//!   after every inner solve;
//! * [`givens`] — host-side Hessenberg least-squares;
//! * [`parops`] — halo-exchanged SpMV and global reductions;
//! * [`state`] — the distributed objects the paper checkpoints and the
//!   per-rank localized structures.

pub mod fgmres;
pub mod givens;
pub mod parops;
pub mod state;

pub use fgmres::{FtGmres, FtGmresCfg, Outcome};
pub use state::{IterScalars, SolverState};
