//! Distributed vector primitives: halo-exchanged SpMV and global reductions,
//! with phase accounting (Compute for local kernels, Comm for messages).

use crate::backend::Backend;
use crate::metrics::Phase;
use crate::netsim::ComputeModel;
use crate::problem::{exchange_halo, EllBlock};
use crate::simmpi::{Comm, Ctx, MpiResult};

/// Shared scratch for the halo-extended source vector.
#[derive(Debug, Default)]
pub struct Scratch {
    pub x_halo: Vec<f64>,
}

impl Scratch {
    pub fn ensure(&mut self, len: usize) {
        if self.x_halo.len() < len {
            self.x_halo.resize(len, 0.0);
        }
    }
}

/// y = A_local x  (halo exchange + local SpMV).
pub async fn matvec(
    ctx: &mut Ctx,
    comm: &mut Comm,
    backend: &dyn Backend,
    blk: &EllBlock,
    x: &[f64],
    y: &mut [f64],
    scratch: &mut Scratch,
) -> MpiResult<()> {
    scratch.ensure(blk.x_halo_len());
    scratch.x_halo[..blk.rows].copy_from_slice(&x[..blk.rows]);
    let prev = ctx.set_phase(Phase::Comm);
    let res = exchange_halo(ctx, comm, blk, &mut scratch.x_halo).await;
    ctx.set_phase(prev);
    res?;
    let prev = ctx.set_phase(Phase::Compute);
    let secs = backend.spmv(blk, &scratch.x_halo, y);
    ctx.advance(secs);
    ctx.set_phase(prev);
    Ok(())
}

/// Global squared 2-norm of a distributed vector.
pub async fn norm2_sq(
    ctx: &mut Ctx,
    comm: &mut Comm,
    host: &ComputeModel,
    v: &[f64],
) -> MpiResult<f64> {
    let prev = ctx.set_phase(Phase::Compute);
    let local: f64 = v.iter().map(|x| x * x).sum();
    ctx.advance(host.cost(2.0 * v.len() as f64, 8.0 * v.len() as f64));
    ctx.set_phase(Phase::Comm);
    let mut buf = [local];
    let res = comm.allreduce_sum(ctx, &mut buf).await;
    ctx.set_phase(prev);
    res?;
    Ok(buf[0])
}

/// Allreduce a small coefficient slice (phase = Comm).
pub async fn allreduce(ctx: &mut Ctx, comm: &mut Comm, data: &mut [f64]) -> MpiResult<()> {
    let prev = ctx.set_phase(Phase::Comm);
    let res = comm.allreduce_sum(ctx, data).await;
    ctx.set_phase(prev);
    res
}

/// Charge a host-side vector op (copy/axpy-style) to Compute.
pub fn charge_host(ctx: &mut Ctx, host: &ComputeModel, flops: f64, bytes: f64) {
    let prev = ctx.set_phase(Phase::Compute);
    ctx.advance(host.cost(flops, bytes));
    ctx.set_phase(prev);
}
