//! Host-side small dense math for GMRES: the Hessenberg least-squares
//! problem, updated incrementally with Givens rotations.
//!
//! This is O(m^2) work on an (m+1) x m matrix with m <= 25 — each rank keeps
//! a replicated copy (exactly as the reference Trilinos implementation does)
//! so no communication is needed.  The cost is charged to the virtual clock
//! by the caller via the host compute model.

/// Incrementally-rotated Hessenberg least-squares state for one GMRES cycle.
#[derive(Debug, Clone)]
pub struct GivensLs {
    m: usize,
    /// Column-major upper-triangular-ish storage: h[(j, i)] for i <= j+1.
    h: Vec<f64>,
    /// Rotated residual vector g (length m+1).
    g: Vec<f64>,
    cs: Vec<f64>,
    sn: Vec<f64>,
    /// Columns pushed so far.
    k: usize,
}

impl GivensLs {
    /// Start a cycle with initial residual norm `beta`.
    pub fn new(m: usize, beta: f64) -> Self {
        let mut g = vec![0.0; m + 1];
        g[0] = beta;
        GivensLs { m, h: vec![0.0; (m + 1) * m], g, cs: vec![0.0; m], sn: vec![0.0; m], k: 0 }
    }

    pub fn k(&self) -> usize {
        self.k
    }

    fn h_idx(&self, i: usize, j: usize) -> usize {
        j * (self.m + 1) + i
    }

    /// Push Arnoldi column `j = self.k`: `col[i] = H[i][j]` for
    /// `i in 0..=j+1`.  Returns the new least-squares residual |g[j+1]|
    /// (the un-normalized GMRES residual estimate).
    pub fn push_col(&mut self, col: &[f64]) -> f64 {
        let j = self.k;
        assert!(j < self.m, "cycle already full");
        assert!(col.len() >= j + 2);
        let mut c = col[..j + 2].to_vec();
        // Apply previous rotations.
        for i in 0..j {
            let t = self.cs[i] * c[i] + self.sn[i] * c[i + 1];
            c[i + 1] = -self.sn[i] * c[i] + self.cs[i] * c[i + 1];
            c[i] = t;
        }
        // New rotation annihilating c[j+1].
        let d = c[j].hypot(c[j + 1]);
        let (cs, sn) = if d == 0.0 { (1.0, 0.0) } else { (c[j] / d, c[j + 1] / d) };
        self.cs[j] = cs;
        self.sn[j] = sn;
        c[j] = d;
        c[j + 1] = 0.0;
        for i in 0..=j + 1 {
            let idx = self.h_idx(i, j);
            self.h[idx] = c[i];
        }
        self.g[j + 1] = -sn * self.g[j];
        self.g[j] = cs * self.g[j];
        self.k = j + 1;
        self.g[j + 1].abs()
    }

    /// Current residual estimate |g[k]|.
    pub fn residual(&self) -> f64 {
        self.g[self.k].abs()
    }

    /// Solve the k x k upper-triangular system for the coefficient vector y.
    pub fn solve_y(&self) -> Vec<f64> {
        let k = self.k;
        let mut y = vec![0.0; k];
        for i in (0..k).rev() {
            let mut s = self.g[i];
            for j in i + 1..k {
                s -= self.h[self.h_idx(i, j)] * y[j];
            }
            let d = self.h[self.h_idx(i, i)];
            y[i] = if d == 0.0 { 0.0 } else { s / d };
        }
        y
    }

    /// Flatten for checkpointing (paper: the iteration state must be
    /// consistent across processes; each rank stores a replicated copy).
    pub fn to_flat(&self) -> Vec<f64> {
        let mut out = vec![self.m as f64, self.k as f64];
        out.extend_from_slice(&self.h);
        out.extend_from_slice(&self.g);
        out.extend_from_slice(&self.cs);
        out.extend_from_slice(&self.sn);
        out
    }

    pub fn from_flat(flat: &[f64]) -> GivensLs {
        let m = flat[0] as usize;
        let k = flat[1] as usize;
        let mut off = 2;
        let mut take = |n: usize| {
            let s = flat[off..off + n].to_vec();
            off += n;
            s
        };
        let h = take((m + 1) * m);
        let g = take(m + 1);
        let cs = take(m);
        let sn = take(m);
        GivensLs { m, h, g, cs, sn, k }
    }

    /// Approximate flop count of one push (for the host cost model).
    pub fn push_flops(&self) -> f64 {
        (6 * (self.k + 2)) as f64
    }

    /// Approximate flop count of a triangular solve.
    pub fn solve_flops(&self) -> f64 {
        (self.k * self.k) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Dense reference: solve min ||beta*e1 - H y|| via normal equations for
    /// a tiny case and compare.
    #[test]
    fn solves_small_least_squares_exactly() {
        // H: 3x2 upper-Hessenberg, full column rank.
        let h = [[2.0, 1.0], [1.0, 3.0], [0.0, 0.5]];
        let beta = 2.0;
        let mut ls = GivensLs::new(2, beta);
        ls.push_col(&[h[0][0], h[1][0], 0.0]);
        ls.push_col(&[h[0][1], h[1][1], h[2][1]]);
        let y = ls.solve_y();

        // Normal equations H^T H y = H^T (beta e1).
        let hth = [
            [
                h[0][0] * h[0][0] + h[1][0] * h[1][0],
                h[0][0] * h[0][1] + h[1][0] * h[1][1],
            ],
            [
                h[0][0] * h[0][1] + h[1][0] * h[1][1],
                h[0][1] * h[0][1] + h[1][1] * h[1][1] + h[2][1] * h[2][1],
            ],
        ];
        let rhs = [beta * h[0][0], beta * h[0][1]];
        let det = hth[0][0] * hth[1][1] - hth[0][1] * hth[1][0];
        let y_ref = [
            (rhs[0] * hth[1][1] - rhs[1] * hth[0][1]) / det,
            (hth[0][0] * rhs[1] - hth[1][0] * rhs[0]) / det,
        ];
        assert!((y[0] - y_ref[0]).abs() < 1e-12, "{y:?} vs {y_ref:?}");
        assert!((y[1] - y_ref[1]).abs() < 1e-12);
    }

    #[test]
    fn residual_decreases_monotonically() {
        // Random-ish Hessenberg columns: the LS residual can never grow.
        let m = 8;
        let mut ls = GivensLs::new(m, 1.0);
        let mut prev = 1.0;
        for j in 0..m {
            let col: Vec<f64> =
                (0..j + 2).map(|i| ((i * 7 + j * 13) as f64 * 0.7).sin() + if i == j { 2.0 } else { 0.0 }).collect();
            let r = ls.push_col(&col);
            assert!(r <= prev + 1e-12, "j={j}: {r} > {prev}");
            prev = r;
        }
    }

    #[test]
    fn identity_hessenberg_converges_in_one_step() {
        let mut ls = GivensLs::new(3, 5.0);
        let r = ls.push_col(&[1.0, 0.0]);
        assert!(r.abs() < 1e-14);
        let y = ls.solve_y();
        assert!((y[0] - 5.0).abs() < 1e-14);
    }
}
