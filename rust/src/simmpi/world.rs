//! The simulated machine: one mailbox per rank, a liveness registry, the
//! network model, and the failure injector.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Mutex};

use crate::failure::Injector;
use crate::netsim::{NetParams, Network, NodeId};
use crate::simmpi::msg::Msg;

pub type WorldRank = usize;

/// Shared, thread-safe state of the simulated machine.
pub struct World {
    pub size: usize,
    /// Application ranks; world ranks >= n_app are warm spares.
    pub n_app: usize,
    senders: Vec<Sender<Msg>>,
    alive: Vec<AtomicBool>,
    death_time: Vec<Mutex<Option<f64>>>,
    /// Physical node of each world rank.  Application ranks are packed
    /// `ranks_per_node` to a node; spares start on their own fresh node(s) —
    /// the paper's "spares are mapped to the later nodes" placement.
    node_map: Vec<NodeId>,
    pub net: Network,
    pub injector: Injector,
}

impl World {
    /// Build a world with `n_app` application ranks plus `n_spares` warm
    /// spares, returning per-rank receivers to hand to the rank threads.
    pub fn new(
        n_app: usize,
        n_spares: usize,
        params: NetParams,
        injector: Injector,
    ) -> (Arc<World>, Vec<Receiver<Msg>>) {
        let size = n_app + n_spares;
        let mut senders = Vec::with_capacity(size);
        let mut receivers = Vec::with_capacity(size);
        for _ in 0..size {
            let (tx, rx) = channel();
            senders.push(tx);
            receivers.push(rx);
        }
        let rpn = params.ranks_per_node;
        let app_nodes = n_app.div_ceil(rpn);
        let mut node_map: Vec<NodeId> = (0..n_app).map(|r| r / rpn).collect();
        // Spares one per fresh node after all application nodes — the
        // paper's "spare processes are mapped to the later nodes".
        node_map.extend((0..n_spares).map(|s| app_nodes + s));
        // Network sized by node count: create with enough "world" for both.
        let net = Network::new(params, (app_nodes + n_spares.max(1)) * rpn);
        let world = World {
            size,
            n_app,
            senders,
            alive: (0..size).map(|_| AtomicBool::new(true)).collect(),
            death_time: (0..size).map(|_| Mutex::new(None)).collect(),
            node_map,
            net,
            injector,
        };
        (Arc::new(world), receivers)
    }

    pub fn node_of(&self, r: WorldRank) -> NodeId {
        self.node_map[r]
    }

    pub fn same_node(&self, a: WorldRank, b: WorldRank) -> bool {
        self.node_map[a] == self.node_map[b]
    }

    pub fn is_alive(&self, r: WorldRank) -> bool {
        self.alive[r].load(Ordering::Acquire)
    }

    /// Idempotent: the first writer's timestamp wins (simultaneous deaths
    /// are pre-marked by whichever co-scheduled rank dies first).
    pub fn mark_dead(&self, r: WorldRank, at: f64) {
        let mut t = self.death_time[r].lock().unwrap();
        if t.is_none() {
            *t = Some(at);
        }
        drop(t);
        self.alive[r].store(false, Ordering::Release);
    }

    pub fn death_time(&self, r: WorldRank) -> Option<f64> {
        *self.death_time[r].lock().unwrap()
    }

    /// Ground-truth dead set (the simulated failure detector's eventual
    /// knowledge; ULFM's consensus cost is charged separately by `shrink`).
    pub fn dead_set(&self) -> Vec<WorldRank> {
        (0..self.size).filter(|&r| !self.is_alive(r)).collect()
    }

    /// Raw mailbox push; does NOT check liveness (callers in `Ctx` do).
    pub(crate) fn push(&self, dst: WorldRank, msg: Msg) {
        // Receiver can only be dropped after its rank died; losing the
        // message is then equivalent to the network dropping it.
        let _ = self.senders[dst].send(msg);
    }

    /// Transit through the network model using the world's node mapping
    /// (application ranks packed, spares on trailing nodes).
    pub fn transit(&self, src: WorldRank, dst: WorldRank, bytes: usize, depart: f64) -> crate::netsim::Transit {
        self.net.transit_nodes(self.node_map[src], self.node_map[dst], bytes, depart)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::failure::InjectionPlan;

    fn world(n_app: usize, n_spares: usize) -> (Arc<World>, Vec<Receiver<Msg>>) {
        World::new(
            n_app,
            n_spares,
            NetParams { ranks_per_node: 4, ..NetParams::default() },
            Injector::new(InjectionPlan::none()),
        )
    }

    #[test]
    fn spares_live_on_fresh_nodes() {
        let (w, _rx) = world(10, 3);
        // 10 app ranks on nodes 0..=2 (4 per node), spares on nodes 3,4,5.
        assert_eq!(w.node_of(0), 0);
        assert_eq!(w.node_of(9), 2);
        assert_eq!(w.node_of(10), 3);
        assert_eq!(w.node_of(11), 4);
        assert_eq!(w.node_of(12), 5);
        for app in 0..10 {
            for sp in 10..13 {
                assert!(!w.same_node(app, sp), "spare shares node with app rank");
            }
        }
    }

    #[test]
    fn liveness_registry() {
        let (w, _rx) = world(4, 0);
        assert!(w.is_alive(2));
        assert!(w.dead_set().is_empty());
        w.mark_dead(2, 1.5);
        assert!(!w.is_alive(2));
        assert_eq!(w.dead_set(), vec![2]);
        assert_eq!(w.death_time(2), Some(1.5));
    }

    #[test]
    fn inter_node_transit_slower_than_intra() {
        let (w, _rx) = world(10, 2);
        let intra = w.transit(0, 1, 1 << 20, 0.0);
        w.net.reset();
        let inter = w.transit(0, 10, 1 << 20, 0.0); // app -> spare node
        assert!(inter.arrival > intra.arrival);
    }
}
