//! The simulated machine: one mailbox per rank, a liveness registry, the
//! network model, the failure injector, and the execution-engine selector.
//!
//! Mailboxes live inside the `World` (not in per-rank `Receiver`s) so that
//! the same rank bodies can run under either engine (DESIGN.md §12):
//!
//! * [`Engine::Threads`] — one OS thread per rank; a rank with nothing to
//!   receive parks on its mailbox condvar and is woken by the next push.
//! * [`Engine::Events`] — one cooperative task per rank on a single thread;
//!   a rank with nothing to receive returns `Pending` and the push marks it
//!   ready in the deterministic FIFO ready-queue drained by the event loop.
//!
//! Every mailbox keeps a monotone push counter: blocking primitives snapshot
//! the counter while draining and only park/pend if it has not moved since,
//! which closes the lost-wakeup window in both engines.

use std::collections::VecDeque;
use std::future::Future;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::task::Poll;

use crate::failure::Injector;
use crate::netsim::{NetParams, Network, NodeId};
use crate::simmpi::msg::{Ctl, Msg, Payload};

pub type WorldRank = usize;

/// Execution engine for rank bodies (see `--engine` / DESIGN.md §12).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Engine {
    /// One OS thread per rank (the differential-testing oracle).
    #[default]
    Threads,
    /// Deterministic single-threaded event loop (scales to 10k+ ranks).
    Events,
}

impl Engine {
    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "threads" => Some(Engine::Threads),
            "events" => Some(Engine::Events),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Engine::Threads => "threads",
            Engine::Events => "events",
        }
    }
}

/// Per-rank mailbox: message queue plus a monotone push counter.  The
/// counter lets receivers distinguish "no new pushes since my last drain"
/// from "pushed while I was deciding to block".
struct MailboxInner {
    msgs: VecDeque<Msg>,
    pushes: u64,
}

struct Mailbox {
    inner: Mutex<MailboxInner>,
    cv: Condvar,
}

/// Deterministic FIFO of ranks with undrained pushes (event engine only).
/// `enqueued` dedupes so a rank appears at most once.
struct ReadySet {
    queue: VecDeque<WorldRank>,
    enqueued: Vec<bool>,
}

/// Shared, thread-safe state of the simulated machine.
pub struct World {
    pub size: usize,
    /// Application ranks; world ranks >= n_app are warm spares.
    pub n_app: usize,
    pub engine: Engine,
    mailboxes: Vec<Mailbox>,
    ready: Mutex<ReadySet>,
    alive: Vec<AtomicBool>,
    death_time: Vec<Mutex<Option<f64>>>,
    /// Physical node of each world rank.  Application ranks are packed
    /// `ranks_per_node` to a node; spares start on their own fresh node(s) —
    /// the paper's "spares are mapped to the later nodes" placement.
    node_map: Vec<NodeId>,
    pub net: Network,
    pub injector: Injector,
}

impl World {
    /// Build a world with `n_app` application ranks plus `n_spares` warm
    /// spares under the default (thread) engine.
    pub fn new(n_app: usize, n_spares: usize, params: NetParams, injector: Injector) -> Arc<World> {
        World::new_with_engine(n_app, n_spares, params, injector, Engine::Threads)
    }

    /// Build a world for a specific execution engine.
    pub fn new_with_engine(
        n_app: usize,
        n_spares: usize,
        params: NetParams,
        injector: Injector,
        engine: Engine,
    ) -> Arc<World> {
        let size = n_app + n_spares;
        let mailboxes = (0..size)
            .map(|_| Mailbox {
                inner: Mutex::new(MailboxInner { msgs: VecDeque::new(), pushes: 0 }),
                cv: Condvar::new(),
            })
            .collect();
        let rpn = params.ranks_per_node;
        let app_nodes = n_app.div_ceil(rpn);
        let mut node_map: Vec<NodeId> = (0..n_app).map(|r| r / rpn).collect();
        // Spares one per fresh node after all application nodes — the
        // paper's "spare processes are mapped to the later nodes".
        node_map.extend((0..n_spares).map(|s| app_nodes + s));
        // Network sized by node count: create with enough "world" for both.
        let net = Network::new(params, (app_nodes + n_spares.max(1)) * rpn);
        Arc::new(World {
            size,
            n_app,
            engine,
            mailboxes,
            ready: Mutex::new(ReadySet { queue: VecDeque::new(), enqueued: vec![false; size] }),
            alive: (0..size).map(|_| AtomicBool::new(true)).collect(),
            death_time: (0..size).map(|_| Mutex::new(None)).collect(),
            node_map,
            net,
            injector,
        })
    }

    pub fn node_of(&self, r: WorldRank) -> NodeId {
        self.node_map[r]
    }

    pub fn same_node(&self, a: WorldRank, b: WorldRank) -> bool {
        self.node_map[a] == self.node_map[b]
    }

    pub fn is_alive(&self, r: WorldRank) -> bool {
        self.alive[r].load(Ordering::Acquire)
    }

    /// Idempotent: the first writer's timestamp wins (simultaneous deaths
    /// are pre-marked by whichever co-scheduled rank dies first).
    pub fn mark_dead(&self, r: WorldRank, at: f64) {
        let mut t = self.death_time[r].lock().unwrap();
        if t.is_none() {
            *t = Some(at);
        }
        drop(t);
        self.alive[r].store(false, Ordering::Release);
    }

    pub fn death_time(&self, r: WorldRank) -> Option<f64> {
        *self.death_time[r].lock().unwrap()
    }

    /// Ground-truth dead set (the simulated failure detector's eventual
    /// knowledge; ULFM's consensus cost is charged separately by `shrink`).
    pub fn dead_set(&self) -> Vec<WorldRank> {
        (0..self.size).filter(|&r| !self.is_alive(r)).collect()
    }

    /// Raw mailbox push; does NOT check liveness (callers in `Ctx` do).
    /// Messages to dead ranks just accumulate unread, which is equivalent
    /// to the network dropping them.
    pub(crate) fn push(&self, dst: WorldRank, msg: Msg) {
        {
            let mut inner = self.mailboxes[dst].inner.lock().unwrap();
            inner.msgs.push_back(msg);
            inner.pushes += 1;
        }
        self.mailboxes[dst].cv.notify_all();
        if self.engine == Engine::Events {
            self.mark_ready(dst);
        }
    }

    /// Release every idle spare with a `Shutdown` control message (sent by
    /// the coordinator / event loop once the last application rank is done).
    pub(crate) fn shutdown_spares(&self) {
        for s in self.n_app..self.size {
            self.push(
                s,
                Msg {
                    src: 0,
                    epoch: 0,
                    tag: 0,
                    arrival: 0.0,
                    payload: Payload::Ctl(Ctl::Shutdown),
                },
            );
        }
    }

    /// Drain all queued messages for `rank` into `into` (appending), and
    /// return the mailbox's push-counter snapshot taken under the same lock.
    pub(crate) fn drain_mail(&self, rank: WorldRank, into: &mut Vec<Msg>) -> u64 {
        let mut inner = self.mailboxes[rank].inner.lock().unwrap();
        into.extend(inner.msgs.drain(..));
        inner.pushes
    }

    /// Resolve once `rank`'s mailbox push counter exceeds `seen` (the value
    /// returned by the [`World::drain_mail`] that found nothing useful).
    ///
    /// Threads engine: parks on the mailbox condvar inside `poll` and always
    /// returns `Ready` (a thread has nothing better to do than block).
    /// Events engine: returns `Pending`; the next push to `rank` marks it
    /// ready and the event loop re-polls the task.
    pub(crate) fn wait_push(&self, rank: WorldRank, seen: u64) -> impl Future<Output = ()> + '_ {
        std::future::poll_fn(move |_cx| {
            let mb = &self.mailboxes[rank];
            match self.engine {
                Engine::Threads => {
                    let mut inner = mb.inner.lock().unwrap();
                    while inner.pushes == seen {
                        inner = mb.cv.wait(inner).unwrap();
                    }
                    Poll::Ready(())
                }
                Engine::Events => {
                    let inner = mb.inner.lock().unwrap();
                    if inner.pushes > seen {
                        Poll::Ready(())
                    } else {
                        Poll::Pending
                    }
                }
            }
        })
    }

    /// Mark `rank` runnable in the event loop's FIFO (idempotent).
    pub(crate) fn mark_ready(&self, rank: WorldRank) {
        let mut rs = self.ready.lock().unwrap();
        if !rs.enqueued[rank] {
            rs.enqueued[rank] = true;
            rs.queue.push_back(rank);
        }
    }

    /// Pop the next runnable rank (event engine), clearing its dedupe flag.
    pub(crate) fn pop_ready(&self) -> Option<WorldRank> {
        let mut rs = self.ready.lock().unwrap();
        let r = rs.queue.pop_front()?;
        rs.enqueued[r] = false;
        Some(r)
    }

    /// Queued-message count for `rank` (deadlock diagnostics).
    pub(crate) fn mail_len(&self, rank: WorldRank) -> usize {
        self.mailboxes[rank].inner.lock().unwrap().msgs.len()
    }

    /// Transit through the network model using the world's node mapping
    /// (application ranks packed, spares on trailing nodes).
    pub fn transit(
        &self,
        src: WorldRank,
        dst: WorldRank,
        bytes: usize,
        depart: f64,
    ) -> crate::netsim::Transit {
        self.net.transit_nodes(self.node_map[src], self.node_map[dst], bytes, depart)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::failure::InjectionPlan;
    use crate::simmpi::msg::{Ctl, Payload};

    fn world(n_app: usize, n_spares: usize) -> Arc<World> {
        World::new(
            n_app,
            n_spares,
            NetParams { ranks_per_node: 4, ..NetParams::default() },
            Injector::new(InjectionPlan::none()),
        )
    }

    #[test]
    fn spares_live_on_fresh_nodes() {
        let w = world(10, 3);
        // 10 app ranks on nodes 0..=2 (4 per node), spares on nodes 3,4,5.
        assert_eq!(w.node_of(0), 0);
        assert_eq!(w.node_of(9), 2);
        assert_eq!(w.node_of(10), 3);
        assert_eq!(w.node_of(11), 4);
        assert_eq!(w.node_of(12), 5);
        for app in 0..10 {
            for sp in 10..13 {
                assert!(!w.same_node(app, sp), "spare shares node with app rank");
            }
        }
    }

    #[test]
    fn liveness_registry() {
        let w = world(4, 0);
        assert!(w.is_alive(2));
        assert!(w.dead_set().is_empty());
        w.mark_dead(2, 1.5);
        assert!(!w.is_alive(2));
        assert_eq!(w.dead_set(), vec![2]);
        assert_eq!(w.death_time(2), Some(1.5));
    }

    #[test]
    fn inter_node_transit_slower_than_intra() {
        let w = world(10, 2);
        let intra = w.transit(0, 1, 1 << 20, 0.0);
        w.net.reset();
        let inter = w.transit(0, 10, 1 << 20, 0.0); // app -> spare node
        assert!(inter.arrival > intra.arrival);
    }

    fn ctl_msg(src: WorldRank) -> Msg {
        Msg { src, epoch: 0, tag: 0, arrival: 0.0, payload: Payload::Ctl(Ctl::Shutdown) }
    }

    #[test]
    fn push_counter_closes_lost_wakeup_window() {
        let w = world(2, 0);
        let mut batch = Vec::new();
        let seen = w.drain_mail(1, &mut batch);
        assert!(batch.is_empty());
        // A push lands *after* the drain snapshot but *before* the wait.
        w.push(1, ctl_msg(0));
        // Threads engine: wait_push must return immediately (counter moved),
        // not park forever on the condvar.
        crate::simmpi::engine::block_on(w.wait_push(1, seen));
        let seen2 = w.drain_mail(1, &mut batch);
        assert_eq!(batch.len(), 1);
        assert_eq!(seen2, seen + 1);
    }

    #[test]
    fn event_engine_marks_pushed_ranks_ready_once() {
        let w = World::new_with_engine(
            3,
            0,
            NetParams::default(),
            Injector::new(InjectionPlan::none()),
            Engine::Events,
        );
        w.push(2, ctl_msg(0));
        w.push(2, ctl_msg(1)); // deduped
        w.push(0, ctl_msg(1));
        assert_eq!(w.pop_ready(), Some(2));
        assert_eq!(w.pop_ready(), Some(0));
        assert_eq!(w.pop_ready(), None);
        // After popping, a fresh push re-enqueues.
        w.push(2, ctl_msg(0));
        assert_eq!(w.pop_ready(), Some(2));
    }
}
