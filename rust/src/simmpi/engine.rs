//! Execution engines for rank bodies (DESIGN.md §12).
//!
//! Rank bodies are `async fn`s whose only suspension points are the blocking
//! message primitives ([`crate::simmpi::Ctx::recv_match`] and
//! [`crate::simmpi::Ctx::wait_join`]).  Two drivers share those bodies:
//!
//! * [`block_on`] — the thread engine.  Every blocking primitive parks the
//!   calling OS thread inside `poll`, so the future completes in a single
//!   poll and `Pending` is a bug.
//! * [`run_event_loop`] — the event engine.  All ranks run as cooperative
//!   tasks on one thread; a task that returns `Pending` is parked until a
//!   mailbox push marks its rank ready again.  Scheduling is a deterministic
//!   FIFO, so a given (campaign, seed) always replays the same interleaving.
//!
//! Neither driver needs trace-specific code: trace buffers (DESIGN.md §13)
//! are per-rank state inside `Ctx` and record only virtual-time facts, so
//! the exported trace is byte-identical across both engines — asserted for
//! the whole campaign matrix by `tests/engine_differential.rs`.

use std::future::Future;
use std::pin::Pin;
use std::task::{Context, Poll, RawWaker, RawWakerVTable, Waker};

use crate::simmpi::world::World;

/// A no-op waker: neither engine uses waker-based wakeups (threads park on
/// condvars; the event loop is driven by the world's ready-queue).
fn noop_raw_waker() -> RawWaker {
    fn clone(_: *const ()) -> RawWaker {
        noop_raw_waker()
    }
    fn noop(_: *const ()) {}
    static VTABLE: RawWakerVTable = RawWakerVTable::new(clone, noop, noop, noop);
    RawWaker::new(std::ptr::null(), &VTABLE)
}

fn noop_waker() -> Waker {
    // SAFETY: the vtable functions are all no-ops over a null pointer.
    unsafe { Waker::from_raw(noop_raw_waker()) }
}

/// Drive a rank body to completion on the current thread (thread engine).
///
/// Blocking primitives park inside `poll` under [`crate::simmpi::Engine::Threads`],
/// so the future must finish in one poll; `Pending` means a primitive built
/// for the event engine leaked into a thread-engine world.
pub fn block_on<F: Future>(fut: F) -> F::Output {
    let waker = noop_waker();
    let mut cx = Context::from_waker(&waker);
    let fut = std::pin::pin!(fut);
    match fut.poll(&mut cx) {
        Poll::Ready(v) => v,
        Poll::Pending => panic!("blocking primitive returned Pending under the thread engine"),
    }
}

/// A rank task: a pinned, boxed rank body.  Not `Send` — the event loop is
/// single-threaded by design.
pub type RankTask<'a, R> = Pin<Box<dyn Future<Output = R> + 'a>>;

/// Run one task per world rank to completion under the deterministic event
/// loop, returning results in rank order.
///
/// The ready-queue is seeded with every rank in ascending order; afterwards
/// a rank is re-queued exactly when its mailbox receives a push (FIFO,
/// deduped).  Once every application rank (`rank < world.n_app`) has
/// finished, idle spares are released with the same `Shutdown` control
/// message the thread-engine coordinator sends after joining app threads.
///
/// Virtual time lives in message timestamps and per-rank clocks, not in the
/// scheduling order, so this serialization produces the same `RunReport`
/// digest as any OS-thread interleaving (see `tests/engine_differential.rs`).
///
/// Panics with per-rank diagnostics if tasks remain but nothing is runnable
/// (a genuine deadlock: the thread engine would hang at the same point).
pub fn run_event_loop<'a, R>(world: &World, mut tasks: Vec<RankTask<'a, R>>) -> Vec<R> {
    assert_eq!(tasks.len(), world.size, "one task per world rank");
    let n = tasks.len();
    let waker = noop_waker();
    let mut cx = Context::from_waker(&waker);
    let mut results: Vec<Option<R>> = (0..n).map(|_| None).collect();
    let mut n_done = 0usize;
    let mut apps_left = world.n_app;
    for rank in 0..n {
        world.mark_ready(rank);
    }
    while n_done < n {
        let Some(rank) = world.pop_ready() else {
            let stuck: Vec<_> = (0..n)
                .filter(|&r| results[r].is_none())
                .map(|r| {
                    format!("rank {r} (mail={}, alive={})", world.mail_len(r), world.is_alive(r))
                })
                .collect();
            panic!(
                "event engine deadlock: {} of {n} tasks blocked with an empty ready queue: {}",
                stuck.len(),
                stuck.join(", ")
            );
        };
        if results[rank].is_some() {
            continue; // late push to a finished rank
        }
        if let Poll::Ready(v) = tasks[rank].as_mut().poll(&mut cx) {
            results[rank] = Some(v);
            n_done += 1;
            if rank < world.n_app {
                apps_left -= 1;
                if apps_left == 0 {
                    world.shutdown_spares();
                }
            }
        }
    }
    results.into_iter().map(|r| r.expect("all tasks completed")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::failure::{InjectionPlan, Injector};
    use crate::netsim::NetParams;
    use crate::simmpi::msg::{Ctl, Msg, Payload};
    use crate::simmpi::world::Engine;

    #[test]
    fn block_on_runs_ready_future() {
        assert_eq!(block_on(async { 2 + 2 }), 4);
    }

    #[test]
    fn event_loop_runs_tasks_in_rank_order_and_collects_results() {
        let w = World::new_with_engine(
            3,
            0,
            NetParams::default(),
            Injector::new(InjectionPlan::none()),
            Engine::Events,
        );
        let tasks: Vec<RankTask<usize>> =
            (0..3).map(|r| Box::pin(async move { r * 10 }) as RankTask<usize>).collect();
        assert_eq!(run_event_loop(&w, tasks), vec![0, 10, 20]);
    }

    #[test]
    fn event_loop_wakes_receiver_after_send() {
        let w = World::new_with_engine(
            2,
            0,
            NetParams::default(),
            Injector::new(InjectionPlan::none()),
            Engine::Events,
        );
        // Rank 1 waits for a push; rank 0 supplies it.  Under a FIFO seeded
        // 0,1 the sender runs first, but the test also passes if rank 1 is
        // polled first and pends.
        let w0 = w.clone();
        let w1 = w.clone();
        let tasks: Vec<RankTask<u64>> = vec![
            Box::pin(async move {
                w0.push(
                    1,
                    Msg {
                        src: 0,
                        epoch: 0,
                        tag: 0,
                        arrival: 0.0,
                        payload: Payload::Ctl(Ctl::Shutdown),
                    },
                );
                0
            }),
            Box::pin(async move {
                let mut batch = Vec::new();
                loop {
                    let seen = w1.drain_mail(1, &mut batch);
                    if !batch.is_empty() {
                        return seen;
                    }
                    w1.wait_push(1, seen).await;
                }
            }),
        ];
        assert_eq!(run_event_loop(&w, tasks), vec![0, 1]);
    }
}
