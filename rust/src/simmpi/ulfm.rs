//! ULFM (user-level failure mitigation) extension surface.
//!
//! Mirrors the MPI-ULFM primitives the paper relies on:
//!
//! * failure *notification* — ops return `MPI_ERR_PROC_FAILED`
//!   ([`crate::simmpi::MpiError::ProcFailed`], raised by `Ctx` send/recv);
//! * [`revoke`] — `MPI_Comm_revoke`: poison a communicator so every member's
//!   pending/future operations return `Revoked` (this is how ranks that did
//!   not observe the failure directly are pulled into recovery);
//! * [`shrink`] — `MPI_Comm_shrink`: build a pristine communicator from the
//!   survivors, densely renumbered;
//! * [`Comm::agree`] — `MPI_Comm_agree` (in comm.rs).
//!
//! On a real machine shrink runs a consensus protocol among survivors; here
//! membership comes from the registry (the detector's eventual ground truth)
//! and is *validated* by a two-round leader-based agreement on the tentative
//! epoch ([`shrink_at`]: fingerprint vote, then decision broadcast, each
//! charged a fixed agreement overhead plus its real messages) so that
//! survivors with divergent liveness snapshots can never adopt the same
//! communicator — the epoch-fence building block of the restartable
//! recovery protocol (DESIGN.md §10, [`EpochFence`], [`shrink_fenced`]).
//! The paper measures reconfiguration at 0.01%-0.05% of total time; the
//! calibration test in tests/ulfm_semantics.rs keeps us in that regime.

use crate::failure::ProtoPhase;
use crate::simmpi::msg::{tags, Blob, Ctl, Payload};
use crate::simmpi::{Comm, Ctx, MpiError, MpiResult, WorldRank};

/// Per-round CPU overhead of the agreement protocol (consensus bookkeeping,
/// in addition to the tree messages actually sent).
pub const AGREEMENT_OVERHEAD: f64 = 150e-6;

/// `MPI_Comm_revoke`: notify every member that `comm`'s epoch is dead.
/// Best-effort, idempotent, skips dead peers, never errors.
pub fn revoke(ctx: &mut Ctx, comm: &Comm) {
    for &wr in &comm.members {
        if wr != ctx.rank && ctx.world.is_alive(wr) {
            ctx.send_ctl(wr, Ctl::Revoke { epoch: comm.epoch });
        }
    }
    ctx.mark_revoked(comm.epoch);
}

/// Revoke a bare epoch at **every** world rank — the recovery-epoch fence
/// (DESIGN.md §10).  When a survivor abandons a recovery attempt it cannot
/// know who else is blocked inside the attempt's protocol (a survivor it
/// never heard of, a spare mid-join), so the fence poisons the attempt's
/// epoch window machine-wide: every rank blocked on an in-flight protocol
/// message of that epoch returns `Revoked` and re-enters a fresh agree.
/// Best-effort, idempotent, skips dead peers.
pub fn revoke_epoch_world(ctx: &mut Ctx, epoch: u64) {
    for dst in 0..ctx.world.size {
        if dst != ctx.rank && ctx.world.is_alive(dst) {
            ctx.send_ctl(dst, Ctl::Revoke { epoch });
        }
    }
    ctx.mark_revoked(epoch);
}

/// Epoch fence of one recovery *event*: hands out a fresh, disjoint epoch
/// window per attempt so that an abandoned attempt's in-flight protocol
/// messages can never be matched by a later attempt (tag-epoch poisoning).
///
/// Every survivor derives the identical schedule from shared state: the
/// base is the last *successful* communicator's epoch (only replaced on a
/// globally-agreed recovery completion), and attempts advance in lockstep
/// because an attempt can only complete globally (its final fault-aware
/// agreement spans all survivors) or be abandoned by everyone — a rank
/// lagging on an older, already-revoked attempt epoch fails fast and
/// catches up (see `shrink_at`).
#[derive(Debug, Clone)]
pub struct EpochFence {
    base: u64,
    attempt: u64,
}

impl EpochFence {
    /// Epochs one attempt may consume: the shrunk communicator and the
    /// stitched (spare-extended) communicator.
    const EPOCHS_PER_ATTEMPT: u64 = 2;

    pub fn new(comm: &Comm) -> EpochFence {
        EpochFence { base: comm.epoch, attempt: 0 }
    }

    /// Epoch of the current attempt's shrunk communicator.
    pub fn shrink_epoch(&self) -> u64 {
        self.base + 1 + self.attempt * Self::EPOCHS_PER_ATTEMPT
    }

    /// Epoch of the current attempt's stitched communicator (what
    /// [`stitch_spares`] derives as `shrunk.epoch + 1`).
    pub fn stitch_epoch(&self) -> u64 {
        self.shrink_epoch() + 1
    }

    /// Abandon the current attempt: later protocol messages move to the
    /// next epoch window.  The caller revokes the abandoned window
    /// ([`revoke_epoch_world`]).
    pub fn abandon(&mut self) {
        self.attempt += 1;
    }

    /// Completed attempts that were abandoned so far (0 on first try).
    pub fn retries(&self) -> u64 {
        self.attempt
    }
}

/// Order-sensitive fingerprint of a tentative membership, folded through
/// the shrink validation round so that two survivors with different
/// liveness snapshots can never both adopt the same communicator.
fn membership_fingerprint(epoch: u64, members: &[WorldRank]) -> i64 {
    // FNV-1a over the epoch and the member list.
    let mut h: u64 = 0xcbf29ce484222325;
    let mut eat = |v: u64| {
        for b in v.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    };
    eat(epoch);
    eat(members.len() as u64);
    for &m in members {
        eat(m as u64);
    }
    h as i64
}

/// Survivor membership of `comm` according to the failure detector.
pub fn survivors(ctx: &Ctx, comm: &Comm) -> Vec<WorldRank> {
    comm.members
        .iter()
        .copied()
        .filter(|&wr| ctx.world.is_alive(wr))
        .collect()
}

/// Failed members of `comm`.
pub fn failed(ctx: &Ctx, comm: &Comm) -> Vec<WorldRank> {
    comm.members
        .iter()
        .copied()
        .filter(|&wr| !ctx.world.is_alive(wr))
        .collect()
}

/// `MPI_Comm_shrink`: all survivors of `comm` call this; each returns the
/// same pristine communicator (epoch + 1 relative to the *caller's* comm,
/// survivors densely renumbered in old comm-rank order).
///
/// Must be called with the caller's phase set to `Reconfig` so the consensus
/// cost lands in the right bucket.
pub async fn shrink(ctx: &mut Ctx, comm: &Comm) -> MpiResult<Comm> {
    shrink_at(ctx, comm, comm.epoch + 1).await
}

/// One *validated* shrink round at an explicit target epoch (the epoch-fence
/// building block; [`shrink`] is the `epoch + 1` special case).
///
/// Survivor membership is taken from the registry, then sealed by a
/// leader-based membership agreement on the tentative epoch: every member
/// sends a fingerprint *vote* of (epoch, membership) to the round leader
/// (the first member of the survivor snapshot), which checks all votes
/// match and broadcasts the *decision*.  Two survivors with different
/// liveness snapshots therefore can never both adopt the round — the
/// divergent view necessarily names a dead rank, so its holder errors on a
/// dead send/recv (or on a fingerprint mismatch), and the failing rank
/// **revokes the round's epoch machine-wide** before returning the error,
/// which pulls every peer blocked in the round back out with `Revoked`.
/// The caller then re-enters at the next fence epoch
/// ([`shrink_fenced`]) — ULFM's revoke-and-re-agree loop.
///
/// The [`ProtoPhase::Agree`] fault point sits between contributing the vote
/// and the decision broadcast, so campaigns can kill a rank mid-agreement.
pub async fn shrink_at(ctx: &mut Ctx, comm: &Comm, epoch: u64) -> MpiResult<Comm> {
    if ctx.is_revoked(epoch) {
        // A peer already poisoned this round (it abandoned it before we
        // even entered); fail fast so the caller advances the fence.
        return Err(MpiError::Revoked);
    }
    let members = survivors(ctx, comm);
    let my_new = members
        .iter()
        .position(|&wr| wr == ctx.rank)
        .expect("shrink caller must be a survivor");
    let fp = membership_fingerprint(epoch, &members);
    let leader = members[0];
    let result = shrink_round(ctx, epoch, &members, fp, leader).await;
    match result {
        Ok(()) => {
            let new_comm = Comm::new(epoch, members, my_new);
            // Drop any stale traffic from revoked epochs.
            ctx.purge_epochs_below(epoch);
            Ok(new_comm)
        }
        Err(MpiError::Killed) => Err(MpiError::Killed),
        Err(e) => {
            revoke_epoch_world(ctx, epoch);
            Err(e)
        }
    }
}

/// The vote + decision rounds of [`shrink_at`] (split out so the `?`-heavy
/// protocol body can early-return without committing the round).
async fn shrink_round(
    ctx: &mut Ctx,
    epoch: u64,
    members: &[WorldRank],
    fp: i64,
    leader: WorldRank,
) -> MpiResult<()> {
    // Vote round.
    ctx.advance(AGREEMENT_OVERHEAD);
    if ctx.rank == leader {
        for &m in members {
            if m == ctx.rank {
                continue;
            }
            let vote = ctx.recv_match(m, epoch, tags::FENCE_BASE).await?;
            if vote.data().i[0] != fp {
                // Divergent snapshot somewhere: abort the round rather
                // than broadcast a decision some member cannot honor.
                return Err(MpiError::Revoked);
            }
        }
    } else {
        ctx.send_raw(leader, epoch, tags::FENCE_BASE, Payload::Data(Blob::from_i64s(vec![fp])))?;
    }
    // A member dying between its vote and the decision broadcast must
    // not leave survivors waiting: the leader's decision send errors on
    // the registry death (or a survivor's decision recv does), the
    // failing rank revokes the round, and everyone re-agrees.
    ctx.phase_point(ProtoPhase::Agree)?;
    // Decision round.
    ctx.advance(AGREEMENT_OVERHEAD);
    if ctx.rank == leader {
        for &m in members {
            if m != ctx.rank {
                ctx.send_raw(
                    m,
                    epoch,
                    tags::FENCE_BASE + 1,
                    Payload::Data(Blob::from_i64s(vec![fp])),
                )?;
            }
        }
    } else {
        let decision = ctx.recv_match(leader, epoch, tags::FENCE_BASE + 1).await?;
        if decision.data().i[0] != fp {
            return Err(MpiError::Revoked);
        }
    }
    Ok(())
}

/// Fenced shrink: re-run [`shrink_at`] rounds along the fence's epoch
/// schedule until one round both validates *and* still names only live
/// members — any death observed during a round bumps the fence (recorded as
/// a recovery retry), poisons the abandoned epoch machine-wide and sends
/// every survivor back to a fresh agree.  Only `Killed` (this rank's own
/// death) escapes.
pub async fn shrink_fenced(ctx: &mut Ctx, comm: &Comm, fence: &mut EpochFence) -> MpiResult<Comm> {
    loop {
        if !ctx.world.is_alive(ctx.rank) {
            return Err(ctx.die());
        }
        let (attempt, at) = (fence.retries() as i64, ctx.clock);
        ctx.trace_push(|| crate::trace::TraceEvent::Mark {
            label: "fence-attempt",
            arg: attempt,
            t: at,
        });
        match shrink_at(ctx, comm, fence.shrink_epoch()).await {
            Ok(c) => {
                // A member may have died after voting but before the
                // decision landed; adopting a communicator with a dead
                // member only defers the error, so detect it here and
                // re-run the round on the enlarged failure set.
                if c.members.iter().all(|&m| ctx.world.is_alive(m)) {
                    return Ok(c);
                }
                revoke_epoch_world(ctx, c.epoch);
                fence.abandon();
                ctx.recovery_retries += 1;
            }
            Err(MpiError::Killed) => return Err(MpiError::Killed),
            // `shrink_at` already revoked the poisoned round.
            Err(_) => {
                fence.abandon();
                ctx.recovery_retries += 1;
            }
        }
    }
}

/// Substitute recovery, survivor side: extend `shrunk` with spare world
/// ranks standing in at the comm-rank positions the failed ranks held in
/// `old_comm`.  Comm rank 0 of the shrunken comm (the recovery leader)
/// invites each spare; everyone returns the stitched communicator.
///
/// `spare_assignment` maps (failed old comm rank) -> (spare world rank) and
/// must be identical at every caller (it is derived deterministically from
/// the registry by the recovery driver).
pub async fn stitch_spares(
    ctx: &mut Ctx,
    old_comm: &Comm,
    shrunk: &Comm,
    spare_assignment: &[(usize, WorldRank)],
) -> MpiResult<Comm> {
    // Rebuild the original size: survivors keep their old comm ranks, spares
    // take the failed slots — the paper's Figure 1 rank layout.
    let mut members = vec![usize::MAX; old_comm.size()];
    for (old_cr, &wr) in old_comm.members.iter().enumerate() {
        if ctx.world.is_alive(wr) {
            members[old_cr] = wr;
        }
    }
    for &(failed_cr, spare_wr) in spare_assignment {
        debug_assert_eq!(members[failed_cr], usize::MAX, "slot not failed");
        members[failed_cr] = spare_wr;
    }
    debug_assert!(members.iter().all(|&m| m != usize::MAX), "unfilled slot");

    let epoch = shrunk.epoch + 1;
    let my_new = members
        .iter()
        .position(|&wr| wr == ctx.rank)
        .expect("stitch caller must be a member");
    let mut stitched = Comm::new(epoch, members.clone(), my_new);

    // The leader invites the spares (they are blocked in `wait_join`).
    // The invitation carries the failed communicator's membership so the
    // spare can evaluate the same registry-derived serving functions the
    // survivors use (see `Ctl::Join`).
    if shrunk.rank == 0 {
        for &(failed_cr, spare_wr) in spare_assignment {
            ctx.send_ctl(
                spare_wr,
                Ctl::Join {
                    epoch,
                    members: members.clone(),
                    old_members: old_comm.members.clone(),
                    as_rank: failed_cr,
                },
            );
        }
    }
    ctx.purge_epochs_below(epoch);
    // One agreement round over the stitched comm synchronizes everyone
    // (including the spares, which enter via `join_as_spare`).
    ctx.advance(AGREEMENT_OVERHEAD);
    stitched.agree(ctx, u64::MAX).await?;
    Ok(stitched)
}

/// Substitute recovery, spare side: accept a Join invitation and synchronize
/// with the stitched communicator.
///
/// A spare grant is a *lease* until this synchronization completes: the
/// [`ProtoPhase::SpareJoin`] fault point lets campaigns kill the joiner
/// before activation, in which case the survivors' stitched agreement
/// errors, the recovery attempt is abandoned through the epoch fence, and
/// the re-decided attempt grants the slot to another spare (or shrinks) —
/// the dead joiner's lease rolls back because spare availability is always
/// re-derived from the liveness registry.
pub async fn join_as_spare(
    ctx: &mut Ctx,
    epoch: u64,
    members: Vec<WorldRank>,
    as_rank: usize,
) -> MpiResult<Comm> {
    ctx.phase_point(ProtoPhase::SpareJoin)?;
    let mut comm = Comm::new(epoch, members, as_rank);
    ctx.purge_epochs_below(epoch);
    ctx.advance(AGREEMENT_OVERHEAD);
    comm.agree(ctx, u64::MAX).await?;
    Ok(comm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::failure::{InjectionPlan, Injector};
    use crate::netsim::NetParams;
    use crate::simmpi::World;

    #[test]
    fn survivors_and_failed_partition_members() {
        let w = World::new(4, 0, NetParams::default(), Injector::new(InjectionPlan::none()));
        let ctx = Ctx::new(w.clone(), 0);
        let comm = Comm::world(4, 0);
        w.mark_dead(2, 1.0);
        assert_eq!(survivors(&ctx, &comm), vec![0, 1, 3]);
        assert_eq!(failed(&ctx, &comm), vec![2]);
    }

    // Full shrink/stitch protocols need live rank threads; covered in
    // tests/ulfm_semantics.rs.
}
