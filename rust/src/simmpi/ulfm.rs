//! ULFM (user-level failure mitigation) extension surface.
//!
//! Mirrors the MPI-ULFM primitives the paper relies on:
//!
//! * failure *notification* — ops return `MPI_ERR_PROC_FAILED`
//!   ([`crate::simmpi::MpiError::ProcFailed`], raised by `Ctx` send/recv);
//! * [`revoke`] — `MPI_Comm_revoke`: poison a communicator so every member's
//!   pending/future operations return `Revoked` (this is how ranks that did
//!   not observe the failure directly are pulled into recovery);
//! * [`shrink`] — `MPI_Comm_shrink`: build a pristine communicator from the
//!   survivors, densely renumbered;
//! * [`Comm::agree`] — `MPI_Comm_agree` (in comm.rs).
//!
//! On a real machine shrink runs a consensus protocol among survivors; here
//! membership comes from the registry (the detector's eventual ground truth)
//! and the consensus *cost* is charged as two fault-aware rounds over the new
//! communicator plus a fixed per-round agreement overhead.  The paper
//! measures reconfiguration at 0.01%-0.05% of total time; the calibration
//! test in tests/ulfm_semantics.rs keeps us in that regime.

use crate::simmpi::msg::Ctl;
use crate::simmpi::{Comm, Ctx, MpiResult, WorldRank};

/// Per-round CPU overhead of the agreement protocol (consensus bookkeeping,
/// in addition to the tree messages actually sent).
pub const AGREEMENT_OVERHEAD: f64 = 150e-6;

/// `MPI_Comm_revoke`: notify every member that `comm`'s epoch is dead.
/// Best-effort, idempotent, skips dead peers, never errors.
pub fn revoke(ctx: &mut Ctx, comm: &Comm) {
    for &wr in &comm.members {
        if wr != ctx.rank && ctx.world.is_alive(wr) {
            ctx.send_ctl(wr, Ctl::Revoke { epoch: comm.epoch });
        }
    }
}

/// Survivor membership of `comm` according to the failure detector.
pub fn survivors(ctx: &Ctx, comm: &Comm) -> Vec<WorldRank> {
    comm.members
        .iter()
        .copied()
        .filter(|&wr| ctx.world.is_alive(wr))
        .collect()
}

/// Failed members of `comm`.
pub fn failed(ctx: &Ctx, comm: &Comm) -> Vec<WorldRank> {
    comm.members
        .iter()
        .copied()
        .filter(|&wr| !ctx.world.is_alive(wr))
        .collect()
}

/// `MPI_Comm_shrink`: all survivors of `comm` call this; each returns the
/// same pristine communicator (epoch + 1 relative to the *caller's* comm,
/// survivors densely renumbered in old comm-rank order).
///
/// Must be called with the caller's phase set to `Reconfig` so the consensus
/// cost lands in the right bucket.
pub fn shrink(ctx: &mut Ctx, comm: &Comm) -> MpiResult<Comm> {
    let members = survivors(ctx, comm);
    let my_new = members
        .iter()
        .position(|&wr| wr == ctx.rank)
        .expect("shrink caller must be a survivor");
    let mut new_comm = Comm::new(comm.epoch + 1, members, my_new);
    // Drop any stale traffic from the revoked epoch.
    ctx.purge_epochs_below(new_comm.epoch);
    // Consensus cost: two agreement rounds over the survivor set.
    for _ in 0..2 {
        ctx.advance(AGREEMENT_OVERHEAD);
        new_comm.agree(ctx, u64::MAX)?;
    }
    Ok(new_comm)
}

/// Substitute recovery, survivor side: extend `shrunk` with spare world
/// ranks standing in at the comm-rank positions the failed ranks held in
/// `old_comm`.  Comm rank 0 of the shrunken comm (the recovery leader)
/// invites each spare; everyone returns the stitched communicator.
///
/// `spare_assignment` maps (failed old comm rank) -> (spare world rank) and
/// must be identical at every caller (it is derived deterministically from
/// the registry by the recovery driver).
pub fn stitch_spares(
    ctx: &mut Ctx,
    old_comm: &Comm,
    shrunk: &Comm,
    spare_assignment: &[(usize, WorldRank)],
) -> MpiResult<Comm> {
    // Rebuild the original size: survivors keep their old comm ranks, spares
    // take the failed slots — the paper's Figure 1 rank layout.
    let mut members = vec![usize::MAX; old_comm.size()];
    for (old_cr, &wr) in old_comm.members.iter().enumerate() {
        if ctx.world.is_alive(wr) {
            members[old_cr] = wr;
        }
    }
    for &(failed_cr, spare_wr) in spare_assignment {
        debug_assert_eq!(members[failed_cr], usize::MAX, "slot not failed");
        members[failed_cr] = spare_wr;
    }
    debug_assert!(members.iter().all(|&m| m != usize::MAX), "unfilled slot");

    let epoch = shrunk.epoch + 1;
    let my_new = members
        .iter()
        .position(|&wr| wr == ctx.rank)
        .expect("stitch caller must be a member");
    let mut stitched = Comm::new(epoch, members.clone(), my_new);

    // The leader invites the spares (they are blocked in `wait_join`).
    // The invitation carries the failed communicator's membership so the
    // spare can evaluate the same registry-derived serving functions the
    // survivors use (see `Ctl::Join`).
    if shrunk.rank == 0 {
        for &(failed_cr, spare_wr) in spare_assignment {
            ctx.send_ctl(
                spare_wr,
                Ctl::Join {
                    epoch,
                    members: members.clone(),
                    old_members: old_comm.members.clone(),
                    as_rank: failed_cr,
                },
            );
        }
    }
    ctx.purge_epochs_below(epoch);
    // One agreement round over the stitched comm synchronizes everyone
    // (including the spares, which enter via `join_as_spare`).
    ctx.advance(AGREEMENT_OVERHEAD);
    stitched.agree(ctx, u64::MAX)?;
    Ok(stitched)
}

/// Substitute recovery, spare side: accept a Join invitation and synchronize
/// with the stitched communicator.
pub fn join_as_spare(
    ctx: &mut Ctx,
    epoch: u64,
    members: Vec<WorldRank>,
    as_rank: usize,
) -> MpiResult<Comm> {
    let mut comm = Comm::new(epoch, members, as_rank);
    ctx.purge_epochs_below(epoch);
    ctx.advance(AGREEMENT_OVERHEAD);
    comm.agree(ctx, u64::MAX)?;
    Ok(comm)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::failure::{InjectionPlan, Injector};
    use crate::netsim::NetParams;
    use crate::simmpi::World;

    #[test]
    fn survivors_and_failed_partition_members() {
        let (w, mut rxs) = World::new(4, 0, NetParams::default(), Injector::new(InjectionPlan::none()));
        let rx0 = rxs.remove(0);
        let ctx = Ctx::new(w.clone(), 0, rx0);
        let comm = Comm::world(4, 0);
        w.mark_dead(2, 1.0);
        assert_eq!(survivors(&ctx, &comm), vec![0, 1, 3]);
        assert_eq!(failed(&ctx, &comm), vec![2]);
    }

    // Full shrink/stitch protocols need live rank threads; covered in
    // tests/ulfm_semantics.rs.
}
