//! Message types for the simulated MPI runtime, and the shared-buffer
//! payload storage behind them (DESIGN.md §11).
//!
//! Payload lanes are [`SharedVec`]s: cheaply-clonable `Arc`-backed buffers
//! with copy-on-write mutation and zero-copy sub-slicing.  Cloning a
//! [`Blob`] to fan it out (broadcast trees, buddy shipping, parity
//! contributions) bumps a reference count instead of deep-copying the
//! payload; a deep copy happens only if someone later *mutates* a still-
//! shared buffer, which the commit/recovery paths never do.  The
//! [`shared`] module counts both kinds of copies so the `hotpath` bench
//! can assert the data plane stays copy-free.

use std::ops::{Deref, DerefMut, Range};
use std::sync::Arc;

use crate::simmpi::WorldRank;

/// Message tag. Tags below [`tags::COLL_BASE`] are free for point-to-point
/// application use; collectives allocate from a rolling window above it.
pub type Tag = u32;

/// Reserved tag namespaces.
pub mod tags {
    use super::Tag;
    /// Base of the collective-operation tag window.
    pub const COLL_BASE: Tag = 1 << 24;
    /// Width of one collective's tag window (steps within one collective;
    /// recursive doubling needs log2(P) + pre/post rounds).
    pub const COLL_WINDOW: Tag = 16;
    /// Number of in-flight collective sequence slots before wraparound.
    pub const COLL_SEQS: Tag = 1 << 16;
    /// Halo exchange tags: HALO_BASE + peer rank.
    pub const HALO_BASE: Tag = 1 << 22;
    /// Checkpoint shipping tags: CKPT_BASE + object id * 16 + buddy
    /// distance (mirror copies and deltas).
    pub const CKPT_BASE: Tag = 1 << 21;
    /// XOR parity contributions (member -> group holder), one tag per
    /// object id, inside the checkpoint window above the mirror tags.
    pub const CKPT_PARITY_BASE: Tag = CKPT_BASE + (1 << 12);
    /// rs2 combined Q-stripe forwards (P holder -> Q holder):
    /// CKPT_QPAR_BASE + object id * 1024 + parity group, inside the
    /// checkpoint window above the parity-contribution tags.
    pub const CKPT_QPAR_BASE: Tag = CKPT_BASE + (1 << 13);
    /// Checkpoint-scrubber repair traffic (DESIGN.md §14):
    /// SCRUB_BASE + object id * 65536 + comm rank, inside the checkpoint
    /// window above the Q-forward tags.  Carries parity/mirror material a
    /// corrupt rank pulls from peers to repair a committed chunk in place.
    pub const SCRUB_BASE: Tag = CKPT_BASE + (1 << 14);
    /// Recovery / redistribution transfers.
    pub const RECOVER_BASE: Tag = 1 << 20;
    /// Epoch-fence shrink validation (DESIGN.md §10): FENCE_BASE carries the
    /// membership vote (member -> round leader), FENCE_BASE + 1 the
    /// decision (leader -> members).  Point-to-point on the *tentative*
    /// epoch of one recovery attempt, above the spare-transfer ids and
    /// below the reconstruction window.
    pub const FENCE_BASE: Tag = RECOVER_BASE + (1 << 18) + (1 << 10);
    /// Parity reconstruction (surviving group member -> holder):
    /// RECON_BASE + object id * 4096 + failed comm rank, inside the
    /// recovery window above the redistribution and spare-transfer tags.
    pub const RECON_BASE: Tag = RECOVER_BASE + (1 << 19);
    /// rs2 reconstruction gathers (surviving member -> reconstruction
    /// leader): RECON_MEMBER_BASE + object id * 1024 + parity group.
    pub const RECON_MEMBER_BASE: Tag = RECON_BASE + (1 << 17);
    /// rs2 stripe transfers (holder -> reconstruction leader):
    /// RECON_STRIPE_BASE + object id * 2048 + group * 2 + which (0 = P,
    /// 1 = Q).
    pub const RECON_STRIPE_BASE: Tag = RECON_BASE + (1 << 18);
}

/// Copy accounting for the shared-buffer layer, plus the forced-deep-clone
/// switch the benches use to reproduce the pre-refactor (clone = memcpy)
/// wire as an A/B baseline.  Forcing deep clones changes *nothing* about
/// results — copy-on-write is semantically transparent — only about bytes
/// moved, which is exactly what makes it a fair baseline.
pub mod shared {
    use std::sync::atomic::{AtomicBool, AtomicU64, Ordering::Relaxed};

    static FORCE_DEEP: AtomicBool = AtomicBool::new(false);
    static SHARED_CLONES: AtomicU64 = AtomicU64::new(0);
    static DEEP_COPIES: AtomicU64 = AtomicU64::new(0);
    static DEEP_BYTES: AtomicU64 = AtomicU64::new(0);

    /// When on, [`super::SharedVec`] clones and slices deep-copy their
    /// payload (the pre-refactor behaviour).  Results are bit-identical
    /// either way; only the copy counters and wall time differ.
    pub fn force_deep_clones(on: bool) {
        FORCE_DEEP.store(on, Relaxed);
    }

    pub(super) fn force_deep() -> bool {
        FORCE_DEEP.load(Relaxed)
    }

    pub(super) fn note_shared_clone() {
        SHARED_CLONES.fetch_add(1, Relaxed);
    }

    pub(super) fn note_deep_copy(bytes: usize) {
        DEEP_COPIES.fetch_add(1, Relaxed);
        DEEP_BYTES.fetch_add(bytes as u64, Relaxed);
    }

    /// Process-wide copy counters since the last [`reset_stats`].
    #[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
    pub struct CopyStats {
        /// O(1) reference-count clones (shared, no bytes moved).
        pub shared_clones: u64,
        /// Deep copies: forced clones plus copy-on-write materializations.
        pub deep_copies: u64,
        /// Total payload bytes moved by those deep copies.
        pub deep_bytes: u64,
    }

    pub fn stats() -> CopyStats {
        CopyStats {
            shared_clones: SHARED_CLONES.load(Relaxed),
            deep_copies: DEEP_COPIES.load(Relaxed),
            deep_bytes: DEEP_BYTES.load(Relaxed),
        }
    }

    pub fn reset_stats() {
        SHARED_CLONES.store(0, Relaxed);
        DEEP_COPIES.store(0, Relaxed);
        DEEP_BYTES.store(0, Relaxed);
    }
}

/// A cheaply-clonable, sliceable, copy-on-write vector.
///
/// *Reads* go through `Deref<Target = [T]>`, so indexing, iteration and
/// sub-slicing work exactly as on a `Vec`.  *Clones* and [`SharedVec::slice`]
/// views share the underlying buffer in O(1).  *Mutation* (`DerefMut`,
/// [`SharedVec::push`], …) materializes a private copy first if — and only
/// if — the buffer is shared or a partial view; uniquely-owned full-range
/// buffers mutate in place with no copy at all.
pub struct SharedVec<T> {
    /// `None` encodes the empty vector without touching the allocator.
    buf: Option<Arc<Vec<T>>>,
    off: usize,
    len: usize,
}

impl<T> SharedVec<T> {
    pub fn new() -> Self {
        SharedVec { buf: None, off: 0, len: 0 }
    }

    /// Take ownership of `v` without copying it.
    pub fn from_vec(v: Vec<T>) -> Self {
        let len = v.len();
        if len == 0 {
            return SharedVec::new();
        }
        SharedVec { buf: Some(Arc::new(v)), off: 0, len }
    }

    pub fn as_slice(&self) -> &[T] {
        match &self.buf {
            Some(b) => &b[self.off..self.off + self.len],
            None => &[],
        }
    }

    /// Zero-copy sub-view sharing this buffer (a deep copy under the
    /// benches' forced-deep baseline, mirroring the old `to_vec` splits).
    pub fn slice(&self, range: Range<usize>) -> SharedVec<T>
    where
        T: Clone,
    {
        assert!(
            range.start <= range.end && range.end <= self.len,
            "slice {range:?} out of bounds (len {})",
            self.len
        );
        if range.start == range.end {
            return SharedVec::new();
        }
        if shared::force_deep() {
            shared::note_deep_copy(std::mem::size_of::<T>() * (range.end - range.start));
            return SharedVec::from_vec(self.as_slice()[range].to_vec());
        }
        shared::note_shared_clone();
        SharedVec {
            buf: self.buf.clone(),
            off: self.off + range.start,
            len: range.end - range.start,
        }
    }

    pub fn to_vec(&self) -> Vec<T>
    where
        T: Clone,
    {
        self.as_slice().to_vec()
    }

    /// Unwrap into a `Vec`, copy-free when uniquely owned and full-range.
    pub fn into_vec(mut self) -> Vec<T>
    where
        T: Clone,
    {
        match self.buf.take() {
            None => Vec::new(),
            Some(b) if self.off == 0 && self.len == b.len() => {
                Arc::try_unwrap(b).unwrap_or_else(|b| b[..].to_vec())
            }
            Some(b) => b[self.off..self.off + self.len].to_vec(),
        }
    }

    /// Private full-range buffer for mutation: in place when uniquely owned
    /// and unsliced, otherwise a (counted) copy-on-write materialization.
    fn owned(&mut self) -> &mut Vec<T>
    where
        T: Clone,
    {
        let in_place = match &mut self.buf {
            Some(b) => self.off == 0 && self.len == b.len() && Arc::get_mut(b).is_some(),
            None => false,
        };
        if !in_place {
            let v: Vec<T> = self.as_slice().to_vec();
            if !v.is_empty() {
                shared::note_deep_copy(std::mem::size_of::<T>() * v.len());
            }
            self.off = 0;
            self.len = v.len();
            self.buf = Some(Arc::new(v));
        }
        Arc::get_mut(self.buf.as_mut().expect("buffer just materialized"))
            .expect("buffer just made unique")
    }

    pub fn push(&mut self, v: T)
    where
        T: Clone,
    {
        let b = self.owned();
        b.push(v);
        self.len = b.len();
    }

    pub fn extend_from_slice(&mut self, other: &[T])
    where
        T: Clone,
    {
        if other.is_empty() {
            return;
        }
        let b = self.owned();
        b.extend_from_slice(other);
        self.len = b.len();
    }

    pub fn resize(&mut self, new_len: usize, value: T)
    where
        T: Clone,
    {
        if new_len == self.len {
            return;
        }
        if new_len < self.len {
            self.len = new_len; // zero-copy view truncation
            return;
        }
        let b = self.owned();
        b.resize(new_len, value);
        self.len = new_len;
    }

    /// Zero-copy: shortens the view without touching the buffer.
    pub fn truncate(&mut self, new_len: usize) {
        if new_len < self.len {
            self.len = new_len;
        }
    }

    pub fn clear(&mut self) {
        self.buf = None;
        self.off = 0;
        self.len = 0;
    }
}

impl<T> Default for SharedVec<T> {
    fn default() -> Self {
        SharedVec::new()
    }
}

impl<T: Clone> Clone for SharedVec<T> {
    fn clone(&self) -> Self {
        if self.len == 0 {
            return SharedVec::new();
        }
        if shared::force_deep() {
            shared::note_deep_copy(std::mem::size_of::<T>() * self.len);
            return SharedVec::from_vec(self.as_slice().to_vec());
        }
        shared::note_shared_clone();
        SharedVec { buf: self.buf.clone(), off: self.off, len: self.len }
    }
}

impl<T> Deref for SharedVec<T> {
    type Target = [T];
    fn deref(&self) -> &[T] {
        self.as_slice()
    }
}

impl<T: Clone> DerefMut for SharedVec<T> {
    fn deref_mut(&mut self) -> &mut [T] {
        let b = self.owned();
        &mut b[..]
    }
}

impl<T: std::fmt::Debug> std::fmt::Debug for SharedVec<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        std::fmt::Debug::fmt(self.as_slice(), f)
    }
}

impl<T: PartialEq> PartialEq for SharedVec<T> {
    fn eq(&self, other: &Self) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: PartialEq> PartialEq<Vec<T>> for SharedVec<T> {
    fn eq(&self, other: &Vec<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: PartialEq> PartialEq<SharedVec<T>> for Vec<T> {
    fn eq(&self, other: &SharedVec<T>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T: PartialEq> PartialEq<[T]> for SharedVec<T> {
    fn eq(&self, other: &[T]) -> bool {
        self.as_slice() == other
    }
}

impl<T: PartialEq, const N: usize> PartialEq<[T; N]> for SharedVec<T> {
    fn eq(&self, other: &[T; N]) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<T> From<Vec<T>> for SharedVec<T> {
    fn from(v: Vec<T>) -> Self {
        SharedVec::from_vec(v)
    }
}

impl<T: Clone> From<&[T]> for SharedVec<T> {
    fn from(s: &[T]) -> Self {
        SharedVec::from_vec(s.to_vec())
    }
}

impl<T> FromIterator<T> for SharedVec<T> {
    fn from_iter<I: IntoIterator<Item = T>>(iter: I) -> Self {
        SharedVec::from_vec(iter.into_iter().collect())
    }
}

impl<T: Clone> Extend<T> for SharedVec<T> {
    fn extend<I: IntoIterator<Item = T>>(&mut self, iter: I) {
        let b = self.owned();
        b.extend(iter);
        self.len = b.len();
    }
}

impl<'a, T> IntoIterator for &'a SharedVec<T> {
    type Item = &'a T;
    type IntoIter = std::slice::Iter<'a, T>;
    fn into_iter(self) -> Self::IntoIter {
        self.as_slice().iter()
    }
}

impl<T: Clone> IntoIterator for SharedVec<T> {
    type Item = T;
    type IntoIter = std::vec::IntoIter<T>;
    fn into_iter(self) -> Self::IntoIter {
        self.into_vec().into_iter()
    }
}

/// Reusable pool of 64-bit-word scratch buffers for the commit-path
/// codecs ([`crate::ckptstore::delta`]): `pack_words`, RLE and
/// changed-chunk scans borrow a cleared buffer and hand it back instead
/// of allocating fresh `Vec`s every commit.  One lives on every
/// [`crate::simmpi::Ctx`].
#[derive(Debug, Default)]
pub struct WordArena {
    pool: Vec<Vec<i64>>,
}

impl WordArena {
    /// Keep at most this many parked buffers (the commit path needs ~3 at
    /// a time; anything beyond that is churn from error paths).
    const MAX_POOL: usize = 8;

    /// Borrow a cleared buffer (capacity retained from earlier use).
    pub fn take(&mut self) -> Vec<i64> {
        match self.pool.pop() {
            Some(mut v) => {
                v.clear();
                v
            }
            None => Vec::new(),
        }
    }

    /// Return a buffer to the pool.
    pub fn put(&mut self, v: Vec<i64>) {
        if v.capacity() > 0 && self.pool.len() < Self::MAX_POOL {
            self.pool.push(v);
        }
    }
}

/// Typed payload container: every application message is some mix of f64 and
/// i64 words (vector blocks, matrix rows, counters).  Byte size feeds the
/// network cost model.  Lanes are [`SharedVec`]s, so cloning a blob to fan
/// it out shares the payload instead of copying it.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Blob {
    pub f: SharedVec<f64>,
    pub i: SharedVec<i64>,
    /// Wire-size override for workload scaling (see `NetParams::data_scale`):
    /// campaigns simulate the paper's full problem size by scaling the
    /// *charged* bytes of rows-proportional payloads while computing on the
    /// 1/36-scale arrays.  `None` = physical size.
    pub wire: Option<usize>,
}

impl Blob {
    pub fn empty() -> Self {
        Blob::default()
    }

    /// Build from owned lanes without copying either.
    pub fn new(f: Vec<f64>, i: Vec<i64>) -> Self {
        Blob { f: f.into(), i: i.into(), wire: None }
    }

    pub fn from_f64s(f: Vec<f64>) -> Self {
        Blob { f: f.into(), i: SharedVec::new(), wire: None }
    }

    pub fn from_i64s(i: Vec<i64>) -> Self {
        Blob { f: SharedVec::new(), i: i.into(), wire: None }
    }

    /// Scale the charged wire size (rows-proportional payloads only).
    pub fn scaled(mut self, factor: f64) -> Self {
        if factor != 1.0 {
            let base = 8 * (self.f.len() + self.i.len());
            self.wire = Some((base as f64 * factor) as usize);
        }
        self
    }

    pub fn scalar(v: f64) -> Self {
        Blob::from_f64s(vec![v])
    }

    /// Payload size as charged on the wire.
    pub fn bytes(&self) -> usize {
        self.wire.unwrap_or(8 * (self.f.len() + self.i.len()))
    }
}

/// System-level control messages (outside any communicator epoch).
#[derive(Debug, Clone)]
pub enum Ctl {
    /// `rank` died at virtual time `at` — the simulated failure detector's
    /// notification, broadcast by the dying rank to every mailbox.
    Died { rank: WorldRank, at: f64 },
    /// ULFM `MPI_Comm_revoke` on communicator `epoch`.
    Revoke { epoch: u64 },
    /// Substitute recovery: spare adopts communicator `epoch` with comm rank
    /// `as_rank` over `members`.  `old_members` is the failed
    /// communicator's membership, so the spare can evaluate the same
    /// registry-derived serving/liveness functions the survivors used (the
    /// stitched membership already has spares in the failed slots and would
    /// skew them).
    Join {
        epoch: u64,
        members: Vec<WorldRank>,
        old_members: Vec<WorldRank>,
        as_rank: usize,
    },
    /// Run is over; unused spares exit their wait loop.
    Shutdown,
}

#[derive(Debug, Clone)]
pub enum Payload {
    Data(Blob),
    Ctl(Ctl),
}

/// One in-flight message.
#[derive(Debug, Clone)]
pub struct Msg {
    pub src: WorldRank,
    /// Communicator epoch the message belongs to (0 = system).
    pub epoch: u64,
    pub tag: Tag,
    /// Virtual time at which the message is fully received.
    pub arrival: f64,
    pub payload: Payload,
}

impl Msg {
    pub fn data(self) -> Blob {
        match self.payload {
            Payload::Data(b) => b,
            Payload::Ctl(c) => panic!("expected data message, got ctl {c:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blob_bytes() {
        let b = Blob::new(vec![0.0; 10], vec![0; 3]);
        assert_eq!(b.bytes(), 104);
        assert_eq!(Blob::empty().bytes(), 0);
        assert_eq!(Blob::scalar(1.0).bytes(), 8);
        assert_eq!(b.scaled(36.0).bytes(), 104 * 36);
        assert_eq!(Blob::scalar(1.0).scaled(1.0).bytes(), 8);
    }

    #[test]
    fn shared_vec_reads_like_a_vec() {
        let v: SharedVec<i64> = vec![1, 2, 3, 4].into();
        assert_eq!(v.len(), 4);
        assert_eq!(v[2], 3);
        assert_eq!(&v[1..3], &[2, 3]);
        assert_eq!(v.iter().sum::<i64>(), 10);
        assert_eq!(v, vec![1, 2, 3, 4]);
        assert_eq!(vec![1, 2, 3, 4], v);
        assert!(SharedVec::<f64>::new().is_empty());
    }

    #[test]
    fn clone_shares_and_cow_materializes() {
        let a: SharedVec<i64> = vec![7; 100].into();
        let mut b = a.clone(); // shared
        assert_eq!(a, b);
        b[0] = -1; // copy-on-write: a must not see the mutation
        assert_eq!(a[0], 7);
        assert_eq!(b[0], -1);
        // Unique buffers mutate in place (no further materialization
        // needed for repeated edits).
        b[1] = -2;
        assert_eq!(b[1], -2);
        assert_eq!(a[1], 7);
    }

    #[test]
    fn slice_views_share_then_cow() {
        let a: SharedVec<i64> = (0..10).collect();
        let s = a.slice(3..7);
        assert_eq!(s, vec![3, 4, 5, 6]);
        let mut s2 = s.clone();
        s2.push(99); // materializes the 4-word window, then appends
        assert_eq!(s2, vec![3, 4, 5, 6, 99]);
        assert_eq!(s, vec![3, 4, 5, 6]);
        assert_eq!(a.len(), 10);
        // Empty slices and out-of-range are handled.
        assert!(a.slice(4..4).is_empty());
    }

    #[test]
    fn mutators_keep_view_length_in_sync() {
        let mut v: SharedVec<i64> = vec![1, 2, 3].into();
        v.truncate(2);
        assert_eq!(v, vec![1, 2]);
        v.push(9);
        assert_eq!(v, vec![1, 2, 9]);
        v.extend_from_slice(&[4, 5]);
        v.extend([6]);
        assert_eq!(v, vec![1, 2, 9, 4, 5, 6]);
        v.resize(2, 0);
        assert_eq!(v, vec![1, 2]);
        v.resize(4, -1);
        assert_eq!(v, vec![1, 2, -1, -1]);
        v.clear();
        assert!(v.is_empty());
        assert_eq!(v.into_vec(), Vec::<i64>::new());
    }

    #[test]
    fn into_vec_roundtrip() {
        let v: SharedVec<f64> = vec![1.5, -2.5].into();
        let w = v.clone();
        assert_eq!(w.into_vec(), vec![1.5, -2.5]); // shared: copies
        assert_eq!(v.into_vec(), vec![1.5, -2.5]); // unique: unwraps
    }

    #[test]
    fn deep_copy_counters_move_on_cow() {
        // Only >=-deltas: other tests run concurrently in this process and
        // may even have forced-deep clones on (which counts the clone
        // itself as the deep copy — either way >= 8000 bytes move here).
        let before = shared::stats();
        let a: SharedVec<i64> = vec![1; 1000].into();
        let mut b = a.clone();
        b[0] = 2; // CoW of 1000 words
        let after = shared::stats();
        assert!(after.deep_bytes >= before.deep_bytes + 8000);
        assert!(after.deep_copies >= before.deep_copies + 1);
    }

    #[test]
    fn arena_recycles_buffers() {
        let mut a = WordArena::default();
        let mut v = a.take();
        v.extend_from_slice(&[1, 2, 3]);
        let cap = v.capacity();
        a.put(v);
        let v2 = a.take();
        assert!(v2.is_empty());
        assert_eq!(v2.capacity(), cap, "capacity survives the round trip");
    }

    #[test]
    fn tag_namespaces_disjoint() {
        use tags::*;
        assert!(HALO_BASE + 100_000 < COLL_BASE);
        assert!(CKPT_BASE + 10_000 < HALO_BASE);
        assert!(RECOVER_BASE + 10_000 < CKPT_BASE);
        // Sub-windows nest inside their parents without touching siblings.
        assert!(CKPT_BASE + 6 * 16 < CKPT_PARITY_BASE); // mirror ship tags below parity
        assert!(CKPT_PARITY_BASE + 1_000 < CKPT_QPAR_BASE); // parity tags below Q forwards
        assert!(CKPT_QPAR_BASE + 6 * 1024 < SCRUB_BASE); // Q forwards below scrub repairs
        assert!(SCRUB_BASE + 6 * 65_536 < HALO_BASE);
        assert!(CKPT_QPAR_BASE + 6 * 1024 < HALO_BASE);
        assert!(RECON_BASE > RECOVER_BASE + (1 << 18) + 10_000); // above spare tags
        // Fence window: above the spare-transfer ids, below reconstruction.
        assert!(FENCE_BASE > RECOVER_BASE + (1 << 18) + 100);
        assert!(FENCE_BASE + 1 < RECON_BASE);
        assert!(RECON_BASE + 6 * 4096 < RECON_MEMBER_BASE);
        assert!(RECON_MEMBER_BASE + 6 * 1024 < RECON_STRIPE_BASE);
        assert!(RECON_STRIPE_BASE + 6 * 2048 < CKPT_BASE);
    }
}
