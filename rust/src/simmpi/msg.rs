//! Message types for the simulated MPI runtime.

use crate::simmpi::WorldRank;

/// Message tag. Tags below [`tags::COLL_BASE`] are free for point-to-point
/// application use; collectives allocate from a rolling window above it.
pub type Tag = u32;

/// Reserved tag namespaces.
pub mod tags {
    use super::Tag;
    /// Base of the collective-operation tag window.
    pub const COLL_BASE: Tag = 1 << 24;
    /// Width of one collective's tag window (steps within one collective;
    /// recursive doubling needs log2(P) + pre/post rounds).
    pub const COLL_WINDOW: Tag = 16;
    /// Number of in-flight collective sequence slots before wraparound.
    pub const COLL_SEQS: Tag = 1 << 16;
    /// Halo exchange tags: HALO_BASE + peer rank.
    pub const HALO_BASE: Tag = 1 << 22;
    /// Checkpoint shipping tags: CKPT_BASE + object id * 16 + buddy
    /// distance (mirror copies and deltas).
    pub const CKPT_BASE: Tag = 1 << 21;
    /// XOR parity contributions (member -> group holder), one tag per
    /// object id, inside the checkpoint window above the mirror tags.
    pub const CKPT_PARITY_BASE: Tag = CKPT_BASE + (1 << 12);
    /// rs2 combined Q-stripe forwards (P holder -> Q holder):
    /// CKPT_QPAR_BASE + object id * 1024 + parity group, inside the
    /// checkpoint window above the parity-contribution tags.
    pub const CKPT_QPAR_BASE: Tag = CKPT_BASE + (1 << 13);
    /// Recovery / redistribution transfers.
    pub const RECOVER_BASE: Tag = 1 << 20;
    /// Epoch-fence shrink validation (DESIGN.md §10): FENCE_BASE carries the
    /// membership vote (member -> round leader), FENCE_BASE + 1 the
    /// decision (leader -> members).  Point-to-point on the *tentative*
    /// epoch of one recovery attempt, above the spare-transfer ids and
    /// below the reconstruction window.
    pub const FENCE_BASE: Tag = RECOVER_BASE + (1 << 18) + (1 << 10);
    /// Parity reconstruction (surviving group member -> holder):
    /// RECON_BASE + object id * 4096 + failed comm rank, inside the
    /// recovery window above the redistribution and spare-transfer tags.
    pub const RECON_BASE: Tag = RECOVER_BASE + (1 << 19);
    /// rs2 reconstruction gathers (surviving member -> reconstruction
    /// leader): RECON_MEMBER_BASE + object id * 1024 + parity group.
    pub const RECON_MEMBER_BASE: Tag = RECON_BASE + (1 << 17);
    /// rs2 stripe transfers (holder -> reconstruction leader):
    /// RECON_STRIPE_BASE + object id * 2048 + group * 2 + which (0 = P,
    /// 1 = Q).
    pub const RECON_STRIPE_BASE: Tag = RECON_BASE + (1 << 18);
}

/// Typed payload container: every application message is some mix of f64 and
/// i64 words (vector blocks, matrix rows, counters).  Byte size feeds the
/// network cost model.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Blob {
    pub f: Vec<f64>,
    pub i: Vec<i64>,
    /// Wire-size override for workload scaling (see `NetParams::data_scale`):
    /// campaigns simulate the paper's full problem size by scaling the
    /// *charged* bytes of rows-proportional payloads while computing on the
    /// 1/36-scale arrays.  `None` = physical size.
    pub wire: Option<usize>,
}

impl Blob {
    pub fn empty() -> Self {
        Blob::default()
    }

    pub fn from_f64s(f: Vec<f64>) -> Self {
        Blob { f, i: Vec::new(), wire: None }
    }

    pub fn from_i64s(i: Vec<i64>) -> Self {
        Blob { f: Vec::new(), i, wire: None }
    }

    /// Scale the charged wire size (rows-proportional payloads only).
    pub fn scaled(mut self, factor: f64) -> Self {
        if factor != 1.0 {
            let base = 8 * (self.f.len() + self.i.len());
            self.wire = Some((base as f64 * factor) as usize);
        }
        self
    }

    pub fn scalar(v: f64) -> Self {
        Blob::from_f64s(vec![v])
    }

    /// Payload size as charged on the wire.
    pub fn bytes(&self) -> usize {
        self.wire.unwrap_or(8 * (self.f.len() + self.i.len()))
    }
}

/// System-level control messages (outside any communicator epoch).
#[derive(Debug, Clone)]
pub enum Ctl {
    /// `rank` died at virtual time `at` — the simulated failure detector's
    /// notification, broadcast by the dying rank to every mailbox.
    Died { rank: WorldRank, at: f64 },
    /// ULFM `MPI_Comm_revoke` on communicator `epoch`.
    Revoke { epoch: u64 },
    /// Substitute recovery: spare adopts communicator `epoch` with comm rank
    /// `as_rank` over `members`.  `old_members` is the failed
    /// communicator's membership, so the spare can evaluate the same
    /// registry-derived serving/liveness functions the survivors used (the
    /// stitched membership already has spares in the failed slots and would
    /// skew them).
    Join {
        epoch: u64,
        members: Vec<WorldRank>,
        old_members: Vec<WorldRank>,
        as_rank: usize,
    },
    /// Run is over; unused spares exit their wait loop.
    Shutdown,
}

#[derive(Debug, Clone)]
pub enum Payload {
    Data(Blob),
    Ctl(Ctl),
}

/// One in-flight message.
#[derive(Debug, Clone)]
pub struct Msg {
    pub src: WorldRank,
    /// Communicator epoch the message belongs to (0 = system).
    pub epoch: u64,
    pub tag: Tag,
    /// Virtual time at which the message is fully received.
    pub arrival: f64,
    pub payload: Payload,
}

impl Msg {
    pub fn data(self) -> Blob {
        match self.payload {
            Payload::Data(b) => b,
            Payload::Ctl(c) => panic!("expected data message, got ctl {c:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blob_bytes() {
        let b = Blob { f: vec![0.0; 10], i: vec![0; 3], wire: None };
        assert_eq!(b.bytes(), 104);
        assert_eq!(Blob::empty().bytes(), 0);
        assert_eq!(Blob::scalar(1.0).bytes(), 8);
        assert_eq!(b.scaled(36.0).bytes(), 104 * 36);
        assert_eq!(Blob::scalar(1.0).scaled(1.0).bytes(), 8);
    }

    #[test]
    fn tag_namespaces_disjoint() {
        use tags::*;
        assert!(HALO_BASE + 100_000 < COLL_BASE);
        assert!(CKPT_BASE + 10_000 < HALO_BASE);
        assert!(RECOVER_BASE + 10_000 < CKPT_BASE);
        // Sub-windows nest inside their parents without touching siblings.
        assert!(CKPT_BASE + 6 * 16 < CKPT_PARITY_BASE); // mirror ship tags below parity
        assert!(CKPT_PARITY_BASE + 1_000 < CKPT_QPAR_BASE); // parity tags below Q forwards
        assert!(CKPT_QPAR_BASE + 6 * 1024 < HALO_BASE);
        assert!(RECON_BASE > RECOVER_BASE + (1 << 18) + 10_000); // above spare tags
        // Fence window: above the spare-transfer ids, below reconstruction.
        assert!(FENCE_BASE > RECOVER_BASE + (1 << 18) + 100);
        assert!(FENCE_BASE + 1 < RECON_BASE);
        assert!(RECON_BASE + 6 * 4096 < RECON_MEMBER_BASE);
        assert!(RECON_MEMBER_BASE + 6 * 1024 < RECON_STRIPE_BASE);
        assert!(RECON_STRIPE_BASE + 6 * 2048 < CKPT_BASE);
    }
}
