//! Communicator: rank translation plus point-to-point and collective
//! operations, all built over `Ctx::send_raw`/`recv_match` so the network
//! cost model sees every constituent message.
//!
//! Collectives use binomial trees (reduce/bcast) — the same asymptotics as
//! the paper's Open MPI 1.7.1.  Each collective call consumes one sequence
//! slot in the collective tag window so that back-to-back collectives with
//! equal shapes cannot mix messages.

use std::collections::HashMap;

use crate::simmpi::msg::{tags, Blob, Payload, Tag};
use crate::simmpi::world::WorldRank;
use crate::simmpi::Ctx;
use crate::simmpi::MpiResult;

/// A communicator as seen by one rank.
#[derive(Debug, Clone)]
pub struct Comm {
    /// Epoch: unique per communicator generation; bumped by shrink/stitch.
    pub epoch: u64,
    /// Comm rank -> world rank.
    ///
    /// **Invariant:** read-only after construction.  Membership changes go
    /// through [`Comm::new`] (shrink/stitch build fresh communicators), so
    /// the private `w2c` reverse map built there stays consistent — do not
    /// mutate this vec in place.
    pub members: Vec<WorldRank>,
    /// This rank's comm rank.
    pub rank: usize,
    /// Rolling collective sequence (kept in lockstep by identical program
    /// order across members).
    coll_seq: u32,
    /// World rank -> comm rank, precomputed at construction so the
    /// recv/translate paths ([`Comm::rank_of_world`]) are O(1) instead of
    /// a linear membership scan per message.
    w2c: HashMap<WorldRank, usize>,
}

impl Comm {
    pub fn new(epoch: u64, members: Vec<WorldRank>, rank: usize) -> Self {
        debug_assert!(rank < members.len());
        let w2c = members.iter().enumerate().map(|(cr, &wr)| (wr, cr)).collect();
        Comm { epoch, members, rank, coll_seq: 0, w2c }
    }

    /// World communicator over ranks `0..n`.
    pub fn world(n: usize, my_world_rank: WorldRank) -> Self {
        Comm::new(crate::simmpi::ctx::FIRST_EPOCH, (0..n).collect(), my_world_rank)
    }

    pub fn size(&self) -> usize {
        self.members.len()
    }

    pub fn world_of(&self, cr: usize) -> WorldRank {
        self.members[cr]
    }

    pub fn rank_of_world(&self, wr: WorldRank) -> Option<usize> {
        self.w2c.get(&wr).copied()
    }

    // ------------------------------------------------------------------
    // Point-to-point
    // ------------------------------------------------------------------

    pub fn send(&self, ctx: &mut Ctx, dst: usize, tag: Tag, blob: Blob) -> MpiResult<()> {
        ctx.send_raw(self.members[dst], self.epoch, tag, Payload::Data(blob))
    }

    pub async fn recv(&self, ctx: &mut Ctx, src: usize, tag: Tag) -> MpiResult<Blob> {
        Ok(ctx.recv_match(self.members[src], self.epoch, tag).await?.data())
    }

    /// Exchange with a peer: send then receive (mailboxes are unbounded, so
    /// symmetric send-first cannot deadlock).
    pub async fn sendrecv(
        &self,
        ctx: &mut Ctx,
        peer: usize,
        tag: Tag,
        blob: Blob,
    ) -> MpiResult<Blob> {
        self.send(ctx, peer, tag, blob)?;
        self.recv(ctx, peer, tag).await
    }

    /// Batch receive over the split-phase layer ([`Ctx::wait_all`],
    /// DESIGN.md §15): post one receive per `(comm src rank, tag)` entry and
    /// deliver the matches in virtual-arrival order.  Returns
    /// `(comm src rank, tag, blob)` triples in that delivery order — the
    /// deterministic "fold blocks as they land" primitive the pipelined
    /// commit drain and reconstruction gathers are built on.  Posts must be
    /// pairwise distinct.
    pub async fn recv_all(
        &self,
        ctx: &mut Ctx,
        posts: &[(usize, Tag)],
    ) -> MpiResult<Vec<(usize, Tag, Blob)>> {
        let handles: Vec<crate::simmpi::RecvHandle> = posts
            .iter()
            .map(|&(src, tag)| ctx.irecv_match(self.members[src], self.epoch, tag))
            .collect();
        let msgs = ctx.wait_all(&handles).await?;
        Ok(msgs
            .into_iter()
            .map(|m| {
                let src = self
                    .rank_of_world(m.src)
                    .expect("wait_all delivers only posted members");
                (src, m.tag, m.data())
            })
            .collect())
    }

    // ------------------------------------------------------------------
    // Collectives
    // ------------------------------------------------------------------

    fn next_coll_tags(&mut self) -> Tag {
        let seq = self.coll_seq;
        self.coll_seq = (self.coll_seq + 1) % tags::COLL_SEQS;
        tags::COLL_BASE + seq * tags::COLL_WINDOW
    }

    /// Binomial-tree barrier (gather-to-0 then broadcast).
    pub async fn barrier(&mut self, ctx: &mut Ctx) -> MpiResult<()> {
        let base = self.next_coll_tags();
        self.reduce_tree(ctx, base, Blob::empty(), |_, _| Blob::empty()).await?;
        self.bcast_tree(ctx, base + 1, Blob::empty()).await?;
        Ok(())
    }

    /// Broadcast from comm rank 0.  `blob` is the payload at the root and
    /// ignored elsewhere; every rank returns the broadcast value.
    pub async fn bcast(&mut self, ctx: &mut Ctx, blob: Blob) -> MpiResult<Blob> {
        let base = self.next_coll_tags();
        self.bcast_tree(ctx, base, blob).await
    }

    /// Allreduce(sum) over an f64 slice, in place.
    pub async fn allreduce_sum(&mut self, ctx: &mut Ctx, data: &mut [f64]) -> MpiResult<()> {
        let out = self
            .allreduce_rd(ctx, Blob::from_f64s(data.to_vec()), |mut a, b| {
                for (x, y) in a.f.iter_mut().zip(&b.f) {
                    *x += *y;
                }
                a
            })
            .await?;
        data.copy_from_slice(&out.f);
        Ok(())
    }

    /// Allreduce(min) over an i64 slice, in place (used to agree on the
    /// newest mutually-committed checkpoint version).
    pub async fn allreduce_min_i64(&mut self, ctx: &mut Ctx, data: &mut [i64]) -> MpiResult<()> {
        let out = self
            .allreduce_rd(ctx, Blob::from_i64s(data.to_vec()), |mut a, b| {
                for (x, y) in a.i.iter_mut().zip(&b.i) {
                    *x = (*x).min(*y);
                }
                a
            })
            .await?;
        data.copy_from_slice(&out.i);
        Ok(())
    }

    /// Recursive-doubling allreduce — the algorithm MPI implementations use
    /// for small payloads.  Process counts that are not a power of two pay
    /// an extra pre-reduction/post-broadcast exchange, which is exactly the
    /// post-shrink collective degradation the paper discusses (citing Fang
    /// et al.: "MPI implementations commonly optimize process counts in
    /// terms of powers of two").
    ///
    /// `combine` must be commutative bit-for-bit (sum/min are), so every
    /// rank converges to an identical result.
    async fn allreduce_rd<F>(&mut self, ctx: &mut Ctx, mine: Blob, combine: F) -> MpiResult<Blob>
    where
        F: Fn(Blob, Blob) -> Blob,
    {
        let n = self.size();
        if n == 1 {
            return Ok(mine);
        }
        let base = self.next_coll_tags();
        let me = self.rank;
        let pow2 = 1usize << (usize::BITS - 1 - n.leading_zeros());
        let rem = n - pow2;
        let mut acc = mine;

        // Pre-phase: the first 2*rem ranks fold pairwise; evens drop out.
        let active_id = if me < 2 * rem {
            if me % 2 == 0 {
                self.send(ctx, me + 1, base, acc)?;
                // Wait for the final result from the partner (post-phase).
                return self.recv(ctx, me + 1, base + 15).await;
            }
            let other = self.recv(ctx, me - 1, base).await?;
            acc = combine(acc, other);
            me / 2
        } else {
            me - rem
        };

        // Recursive doubling among the pow2 active ranks.  The per-round
        // send ships a *shared reference* to the accumulator (Blob clones
        // are O(1) refcount bumps over `SharedVec` storage); `combine`
        // then updates the accumulator copy-on-write, so at most one
        // materialization can happen per round — and none once the in-
        // flight reference has been consumed by the partner.
        let unmap = |id: usize| if id < rem { 2 * id + 1 } else { id + rem };
        let mut round = 0u32;
        let mut dist = 1usize;
        while dist < pow2 {
            let partner = unmap(active_id ^ dist);
            self.send(ctx, partner, base + 1 + round, acc.clone())?;
            let other = self.recv(ctx, partner, base + 1 + round).await?;
            acc = combine(acc, other);
            dist <<= 1;
            round += 1;
        }

        // Post-phase: odds hand the result back to their dropped partner —
        // previously a second full deep copy of the accumulator per fold;
        // now a shared reference (the partner only reads it).
        if me < 2 * rem {
            self.send(ctx, me - 1, base + 15, acc.clone())?;
        }
        Ok(acc)
    }

    /// Allgather of one blob per rank; returns blobs indexed by comm rank.
    /// (Gather to 0 + bcast of the concatenation; sizes may differ.)
    pub async fn allgather(&mut self, ctx: &mut Ctx, mine: Blob) -> MpiResult<Vec<Blob>> {
        let base = self.next_coll_tags();
        let n = self.size();
        let me = self.rank;
        // Gather to root as individual messages (simple linear gather: the
        // call sites are rare, recovery-path only).
        let mut all: Vec<Blob> = Vec::new();
        if me == 0 {
            all = vec![Blob::empty(); n];
            all[0] = mine;
            for src in 1..n {
                all[src] = self.recv(ctx, src, base + 2).await?;
            }
        } else {
            self.send(ctx, 0, base + 2, mine)?;
        }
        // Broadcast concatenation with a size prefix.
        let packed = if me == 0 { pack_blobs(&all) } else { Blob::empty() };
        let packed = self.bcast_tree(ctx, base + 3, packed).await?;
        Ok(unpack_blobs(&packed))
    }

    /// ULFM-style agreement on a u64 (bitwise AND), also functioning as a
    /// fault-aware barrier.  Cost-equivalent to allreduce.
    pub async fn agree(&mut self, ctx: &mut Ctx, flag: u64) -> MpiResult<u64> {
        let base = self.next_coll_tags();
        let reduced = self
            .reduce_tree(ctx, base, Blob::from_i64s(vec![flag as i64]), |mut a, b| {
                a.i[0] &= b.i[0];
                a
            })
            .await?;
        let out = self.bcast_tree(ctx, base + 1, reduced).await?;
        let at = ctx.clock;
        ctx.trace_push(|| crate::trace::TraceEvent::Mark {
            label: "agree",
            arg: out.i[0],
            t: at,
        });
        Ok(out.i[0] as u64)
    }

    // ------------------------------------------------------------------
    // Tree primitives
    // ------------------------------------------------------------------

    /// Binomial reduce to comm rank 0.  Returns the reduction at rank 0 and
    /// the local contribution elsewhere.
    async fn reduce_tree<F>(
        &self,
        ctx: &mut Ctx,
        tag: Tag,
        mine: Blob,
        combine: F,
    ) -> MpiResult<Blob>
    where
        F: Fn(Blob, Blob) -> Blob,
    {
        let n = self.size();
        let me = self.rank;
        let mut acc = mine;
        let mut dist = 1;
        while dist < n {
            if me % (2 * dist) == 0 {
                let src = me + dist;
                if src < n {
                    let other = self.recv(ctx, src, tag).await?;
                    acc = combine(acc, other);
                }
            } else {
                let dst = me - dist;
                self.send(ctx, dst, tag, acc)?;
                return Ok(Blob::empty());
            }
            dist *= 2;
        }
        Ok(acc)
    }

    /// Binomial broadcast from comm rank 0.
    async fn bcast_tree(&self, ctx: &mut Ctx, tag: Tag, mine: Blob) -> MpiResult<Blob> {
        let n = self.size();
        let me = self.rank;
        // Highest power of two <= n.
        let mut top = 1;
        while top * 2 < n {
            top *= 2;
        }
        let val = if me == 0 {
            mine
        } else {
            // Receive from parent: clear lowest set bit.
            let parent = me & (me - 1);
            self.recv(ctx, parent, tag).await?
        };
        // Forward to children at me + lowestbit(me)/2, me + lowestbit/4, ...
        // (rank 0 starts at `top`).
        let mut d = if me == 0 { top } else { (me & me.wrapping_neg()) / 2 };
        while d >= 1 {
            let child = me + d;
            if child < n {
                self.send(ctx, child, tag, val.clone())?;
            }
            d /= 2;
        }
        Ok(val)
    }
}

/// Pack variable-size blobs into one blob with a length prefix table.
fn pack_blobs(blobs: &[Blob]) -> Blob {
    let mut fl: Vec<f64> = Vec::new();
    let mut il: Vec<i64> = Vec::with_capacity(1 + 2 * blobs.len());
    il.push(blobs.len() as i64);
    for b in blobs {
        il.push(b.f.len() as i64);
        il.push(b.i.len() as i64);
    }
    for b in blobs {
        fl.extend_from_slice(&b.f);
        il.extend_from_slice(&b.i);
    }
    Blob::new(fl, il)
}

/// Split a packed concatenation back into per-rank blobs as *zero-copy
/// views* of the shared packed buffer (previously a `to_vec` per lane per
/// rank — n deep copies of the whole gather on every rank).
fn unpack_blobs(packed: &Blob) -> Vec<Blob> {
    let n = packed.i[0] as usize;
    let mut blobs = Vec::with_capacity(n);
    let mut fo = 0usize;
    let mut io = 1 + 2 * n;
    for k in 0..n {
        let nf = packed.i[1 + 2 * k] as usize;
        let ni = packed.i[2 + 2 * k] as usize;
        blobs.push(Blob {
            f: packed.f.slice(fo..fo + nf),
            i: packed.i.slice(io..io + ni),
            wire: None,
        });
        fo += nf;
        io += ni;
    }
    blobs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pack_unpack_roundtrip() {
        let blobs = vec![
            Blob::new(vec![1.0, 2.0], vec![7]),
            Blob::empty(),
            Blob::new(vec![], vec![1, 2, 3]),
        ];
        let packed = pack_blobs(&blobs);
        assert_eq!(unpack_blobs(&packed), blobs);
    }

    #[test]
    fn world_rank_translation_is_total() {
        let c = Comm::new(5, vec![9, 4, 7], 1);
        assert_eq!(c.rank_of_world(9), Some(0));
        assert_eq!(c.rank_of_world(4), Some(1));
        assert_eq!(c.rank_of_world(7), Some(2));
        assert_eq!(c.rank_of_world(8), None);
        // The map survives cloning (recovery hands comms around by clone).
        assert_eq!(c.clone().rank_of_world(7), Some(2));
    }

    // Multi-rank collective behaviour is exercised in tests/simmpi_collectives.rs
    // with real rank threads.
}
