//! Per-rank execution context: virtual clock, phase accounting, mailbox
//! matching, and ULFM-style failure surfacing.
//!
//! The blocking primitives ([`Ctx::recv_match`], [`Ctx::wait_join`]) are
//! `async`: under the thread engine they park the OS thread inside a single
//! poll, under the event engine they suspend the rank's task until the next
//! mailbox push (DESIGN.md §12).  Everything else — sends, clock advances,
//! phase accounting — is synchronous and engine-agnostic.

use std::collections::{BTreeMap, BTreeSet, VecDeque};
use std::sync::Arc;

use crate::failure::ProtoPhase;
use crate::metrics::{CkptRecord, DecisionRecord, FaultCounters, Phase, PhaseTimers};
use crate::simmpi::msg::{Ctl, Msg, Payload, Tag, WordArena};
use crate::simmpi::world::{World, WorldRank};
use crate::simmpi::{MpiError, MpiResult};
use crate::trace::{TraceBuf, TraceEvent};

/// Epoch used by system (non-communicator) messages.
pub const SYS_EPOCH: u64 = 0;
/// First epoch usable by communicators.
pub const FIRST_EPOCH: u64 = 1;

/// A posted split-phase receive (DESIGN.md §15): the match criteria of a
/// message this rank is owed but has not yet delivered.  Handles are plain
/// values — nothing is reserved in the mailbox when one is created — so
/// posting via [`Ctx::irecv_match`] is free and dropping a handle leaks
/// nothing.  Complete one with [`Ctx::test`], [`Ctx::wait`] or (in a batch,
/// with deterministic arrival-order delivery) [`Ctx::wait_all`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RecvHandle {
    pub src: WorldRank,
    pub epoch: u64,
    pub tag: Tag,
}

pub struct Ctx {
    pub world: Arc<World>,
    pub rank: WorldRank,
    /// Virtual clock, seconds since run start.
    pub clock: f64,
    /// Phase that subsequent time advances are charged to.
    pub phase: Phase,
    /// When replaying work already done before a rollback, Compute/Comm time
    /// is re-routed to [`Phase::Recompute`] (the paper's recomputation
    /// overhead).  Managed by the solver's iteration tick.
    pub recompute: bool,
    pub timers: PhaseTimers,
    /// Inner iterations executed (for reports and the injector).
    pub iterations: u64,
    /// Recovery-policy decisions this rank made, in event order (the
    /// coordinator copies these into the [`crate::metrics::RankReport`]).
    pub decisions: Vec<DecisionRecord>,
    /// Checkpoint commits this rank participated in (bytes shipped, encode
    /// time), recorded by [`crate::ckptstore::commit`].
    pub ckpt_log: Vec<CkptRecord>,
    /// Recovery attempts this rank abandoned because a *further* failure
    /// poisoned the round (epoch-fence retries; see
    /// [`crate::recovery::handle_failure_fenced`]).
    pub recovery_retries: u64,
    /// Degraded-fault counters (link retransmits, scrub detections and
    /// repairs), copied into the [`crate::metrics::RankReport`].
    pub faults: FaultCounters,
    /// Whether this rank's scheduled checkpoint bitflip
    /// ([`crate::failure::BitFlip`]) has already landed (one corruption per
    /// plan entry, consumed at the first qualifying commit).
    pub bitflip_done: bool,
    /// Compute slowdown multiplier from the injector's straggler schedule
    /// (1.0 = healthy); scales Compute/Recompute charges in
    /// [`Ctx::advance`].
    slowdown: f64,
    /// Data messages already dropped per destination on this rank's faulty
    /// outgoing links; consumed in program order, so both engines observe
    /// the identical drop sequence.
    link_drops_used: BTreeMap<WorldRank, u32>,
    /// Reusable scratch buffers for the checkpoint codecs (DESIGN.md §11):
    /// `pack_words` / RLE / changed-chunk scans on this rank's commit path
    /// borrow from here instead of allocating per commit.
    pub arena: WordArena,
    /// Entries into each protocol phase, consulted by the phase-triggered
    /// failure injector ([`Ctx::phase_point`]).
    phase_hits: BTreeMap<ProtoPhase, u32>,
    /// Reusable scratch for mailbox drains (avoids a per-receive alloc).
    inbox: Vec<Msg>,
    /// Out-of-order buffer (matched by (epoch, src, tag)).
    pending: VecDeque<Msg>,
    /// Ranks this context has learned are dead.
    pub known_dead: BTreeSet<WorldRank>,
    /// Dead ranks whose detection latency has already been charged.
    detected: BTreeSet<WorldRank>,
    /// Communicator epochs known to be revoked.
    revoked: BTreeSet<u64>,
    /// Pending Join invitations (spares): (epoch, members, old members,
    /// adopted comm rank).
    joins: VecDeque<(u64, Vec<WorldRank>, Vec<WorldRank>, usize)>,
    /// Shutdown received.
    shutdown: bool,
    /// Virtual-time trace accumulator ([`crate::trace`]); `None` unless the
    /// run was started with tracing on, keeping the disabled hot path to a
    /// single branch per hook (gated by the `trace_off_commit` bench leg).
    pub trace: Option<Box<TraceBuf>>,
}

impl Ctx {
    pub fn new(world: Arc<World>, rank: WorldRank) -> Self {
        let slowdown = world.injector.straggler_mult(rank);
        Ctx {
            world,
            rank,
            clock: 0.0,
            phase: Phase::Compute,
            recompute: false,
            timers: PhaseTimers::default(),
            iterations: 0,
            decisions: Vec::new(),
            ckpt_log: Vec::new(),
            recovery_retries: 0,
            faults: FaultCounters::default(),
            bitflip_done: false,
            slowdown,
            link_drops_used: BTreeMap::new(),
            arena: WordArena::default(),
            phase_hits: BTreeMap::new(),
            inbox: Vec::new(),
            pending: VecDeque::new(),
            known_dead: BTreeSet::new(),
            detected: BTreeSet::new(),
            revoked: BTreeSet::new(),
            joins: VecDeque::new(),
            shutdown: false,
            trace: None,
        }
    }

    /// Start recording a virtual-time trace (idempotent; normally called by
    /// the coordinator right after construction when `RunConfig::trace` is
    /// set, so the stream covers the whole rank lifetime).
    pub fn enable_trace(&mut self) {
        if self.trace.is_none() {
            self.trace = Some(Box::default());
        }
    }

    /// Harvest the trace stream, closing the open phase span at the current
    /// clock.  Returns an empty vec when tracing was off.
    pub fn take_trace(&mut self) -> Vec<TraceEvent> {
        match self.trace.take() {
            Some(buf) => buf.into_events(self.clock),
            None => Vec::new(),
        }
    }

    /// Record one trace event; the closure is only evaluated when tracing is
    /// on, so callers pay nothing on the disabled path.
    #[inline]
    pub fn trace_push(&mut self, make: impl FnOnce() -> TraceEvent) {
        if let Some(tr) = self.trace.as_deref_mut() {
            tr.push(make());
        }
    }

    /// Phase that time is actually charged to (recompute re-routing).
    fn effective_phase(&self) -> Phase {
        if self.recompute && matches!(self.phase, Phase::Compute | Phase::Comm) {
            Phase::Recompute
        } else {
            self.phase
        }
    }

    /// Advance the virtual clock by `dt`, charging the current phase.  On a
    /// straggler ([`crate::failure::Straggler`]) compute-bound charges run
    /// `slowdown`× longer: the fault degrades local work, not the network,
    /// so Comm/Checkpoint/Recovery advances stay unscaled.
    pub fn advance(&mut self, dt: f64) {
        debug_assert!(dt >= 0.0, "negative advance {dt}");
        let eff = self.effective_phase();
        let dt = if self.slowdown > 1.0 && matches!(eff, Phase::Compute | Phase::Recompute) {
            dt * self.slowdown
        } else {
            dt
        };
        if let Some(tr) = self.trace.as_deref_mut() {
            tr.pre_charge(eff, self.clock);
        }
        self.clock += dt;
        self.timers.charge(eff, dt);
    }

    /// Advance the clock to absolute virtual time `t` (no-op if in the past).
    pub fn advance_to(&mut self, t: f64) {
        if t > self.clock {
            let eff = self.effective_phase();
            if let Some(tr) = self.trace.as_deref_mut() {
                tr.pre_charge(eff, self.clock);
            }
            let dt = t - self.clock;
            self.clock = t;
            self.timers.charge(eff, dt);
        }
    }

    /// Switch accounting phase, returning the previous one.
    pub fn set_phase(&mut self, p: Phase) -> Phase {
        std::mem::replace(&mut self.phase, p)
    }

    pub fn is_revoked(&self, epoch: u64) -> bool {
        self.revoked.contains(&epoch)
    }

    /// Poison `epoch` locally (the sender side of a revoke: peers learn via
    /// [`Ctl::Revoke`], the revoker must not keep using the epoch either).
    pub fn mark_revoked(&mut self, epoch: u64) {
        self.revoked.insert(epoch);
    }

    /// Protocol-phase fault point: count this rank's entry into `phase` and
    /// die if the injector scheduled a kill at this occurrence (or if a
    /// co-scheduled kill already marked this rank dead in the registry).
    ///
    /// Placed at every phase of the checkpoint/recovery pipeline
    /// ([`crate::failure::ProtoPhase`]), this is what makes failures
    /// *during* recovery reachable by campaigns.
    pub fn phase_point(&mut self, phase: ProtoPhase) -> MpiResult<()> {
        let hits = self.phase_hits.entry(phase).or_insert(0);
        *hits += 1;
        let n = *hits;
        let at = self.clock;
        self.trace_push(|| TraceEvent::Proto { phase, n, t: at });
        if self.world.injector.should_die_at_phase(self.rank, phase, n)
            || !self.world.is_alive(self.rank)
        {
            return Err(self.die());
        }
        Ok(())
    }

    pub fn shutdown_requested(&self) -> bool {
        self.shutdown
    }

    // ------------------------------------------------------------------
    // Send path
    // ------------------------------------------------------------------

    /// Point-to-point send to a world rank within `epoch`.
    ///
    /// Surfaces `ProcFailed` if the destination is already known dead (ULFM
    /// reports the error on the first operation that cannot complete).
    ///
    /// On a lossy link ([`crate::failure::LinkFault`]) each scheduled drop
    /// costs the sender one retransmit timeout
    /// ([`crate::netsim::NetParams::link_timeout`], GASPI-style detection:
    /// a timeout, not a death notice); exhausting
    /// [`crate::netsim::NetParams::link_retry_budget`] consecutive retries
    /// on one message revokes the epoch instead of declaring anyone dead —
    /// the observable difference between congestion and crash-stop.  Only
    /// data payloads are droppable: the 16-byte control plane (death
    /// notices, revokes, joins) models an out-of-band reliable channel.
    pub fn send_raw(
        &mut self,
        dst: WorldRank,
        epoch: u64,
        tag: Tag,
        payload: Payload,
    ) -> MpiResult<()> {
        if !self.world.is_alive(dst) {
            self.note_death(dst);
            return Err(MpiError::ProcFailed(vec![dst]));
        }
        if matches!(payload, Payload::Data(_)) && self.world.injector.has_link_faults() {
            let scheduled = self.world.injector.link_drops(self.rank, dst);
            let mut used = self.link_drops_used.get(&dst).copied().unwrap_or(0);
            let mut consecutive = 0u32;
            while used < scheduled {
                used += 1;
                self.link_drops_used.insert(dst, used);
                consecutive += 1;
                self.faults.link_retries += 1;
                let timeout = self.world.net.params.link_timeout;
                self.advance(timeout);
                let (at, d) = (self.clock, dst);
                self.trace_push(|| TraceEvent::Mark { label: "link-retry", arg: d as i64, t: at });
                if consecutive >= self.world.net.params.link_retry_budget {
                    self.mark_revoked(epoch);
                    return Err(MpiError::Revoked);
                }
            }
        }
        let bytes = match &payload {
            Payload::Data(b) => b.bytes(),
            Payload::Ctl(_) => 16,
        };
        let t = self.world.transit(self.rank, dst, bytes, self.clock);
        let (send_at, arrival) = (self.clock, t.arrival);
        self.trace_push(|| TraceEvent::Send {
            dst,
            epoch,
            tag,
            bytes: bytes as u64,
            t: send_at,
            arrival,
        });
        self.world
            .push(dst, Msg { src: self.rank, epoch, tag, arrival: t.arrival, payload });
        self.advance(t.sender_busy);
        Ok(())
    }

    /// Fire-and-forget control message (used by revoke / death broadcast /
    /// join).  Never fails; dead destinations just drop it.
    pub fn send_ctl(&mut self, dst: WorldRank, ctl: Ctl) {
        let t = self.world.transit(self.rank, dst, 16, self.clock);
        self.world.push(
            dst,
            Msg {
                src: self.rank,
                epoch: SYS_EPOCH,
                tag: 0,
                arrival: t.arrival,
                payload: Payload::Ctl(ctl),
            },
        );
        self.advance(self.world.net.params.send_overhead);
    }

    // ------------------------------------------------------------------
    // Receive path
    // ------------------------------------------------------------------

    /// Blocking receive of a data message matching (src, epoch, tag).
    ///
    /// Errors with `ProcFailed` once `src` is known dead and no matching
    /// message was buffered, or `Revoked` if `epoch` gets revoked while
    /// waiting (this is what unblocks ranks stuck in a collective when a
    /// peer dies elsewhere — the recovery driver revokes the communicator).
    pub async fn recv_match(&mut self, src: WorldRank, epoch: u64, tag: Tag) -> MpiResult<Msg> {
        loop {
            // 0. Did a co-scheduled simultaneous kill claim THIS rank?  The
            //    survivors have already excluded it; it must stop
            //    communicating and exit (the caller turns Killed into a
            //    clean death).
            if !self.world.is_alive(self.rank) {
                return Err(MpiError::Killed);
            }
            // 1. Buffered?
            if let Some(pos) = self
                .pending
                .iter()
                .position(|m| m.src == src && m.epoch == epoch && m.tag == tag)
            {
                let msg = self.pending.remove(pos).unwrap();
                self.deliver(&msg);
                return Ok(msg);
            }
            // 2. Revoked while waiting?
            if self.revoked.contains(&epoch) {
                return Err(MpiError::Revoked);
            }
            // 3. Drain the mailbox without blocking.
            let (got_any, seen) = self.drain_absorb();
            if got_any {
                continue;
            }
            // 4. Nothing buffered: is the peer dead?
            if self.known_dead.contains(&src) || !self.world.is_alive(src) {
                self.note_death(src);
                return Err(MpiError::ProcFailed(vec![src]));
            }
            // 5. Park (threads) / pend (events) until the next push; a
            //    Died/Revoke broadcast will wake us if needed.  The `seen`
            //    counter from step 3's drain closes the lost-wakeup window.
            self.world.wait_push(self.rank, seen).await;
        }
    }

    // ------------------------------------------------------------------
    // Split-phase primitives (DESIGN.md §15)
    // ------------------------------------------------------------------
    //
    // The progress-hook contract shared by both engines: `progress` (and
    // the blocking loops built on it) drains the rank's mailbox and then,
    // if a caller must wait, blocks through `World::wait_push(rank, seen)`
    // — where `seen` is the push-counter snapshot taken *by the drain*.
    // Under the thread engine `wait_push` parks the OS thread on the
    // mailbox condvar; under the event engine it pends the rank's task on
    // the deterministic ready-queue; in both, a push with a counter above
    // `seen` wakes the rank, so the drain→snapshot→wait sequence can never
    // lose a wakeup.  Everything observable (delivery order, clock jumps)
    // is derived from virtual arrival timestamps, never from which engine
    // (or OS schedule) physically moved the bytes — this is what keeps
    // split-phase completions digest-identical across engines.

    /// Post a non-blocking receive for `(src, epoch, tag)`.
    pub fn irecv_match(&self, src: WorldRank, epoch: u64, tag: Tag) -> RecvHandle {
        RecvHandle { src, epoch, tag }
    }

    /// Non-blocking send.  Sends in simmpi complete locally — mailboxes are
    /// unbounded and wire latency is modeled at the receiver — so `isend`
    /// *is* [`Ctx::send_raw`]; it exists so split-phase call sites can
    /// spell their intent and stay source-compatible if buffering ever
    /// becomes bounded.
    pub fn isend(&mut self, dst: WorldRank, epoch: u64, tag: Tag, payload: Payload) -> MpiResult<()> {
        self.send_raw(dst, epoch, tag, payload)
    }

    /// Drive message progress without blocking: drain the mailbox,
    /// absorbing control traffic and buffering data payloads.  Returns
    /// whether anything new arrived.
    pub fn progress(&mut self) -> bool {
        self.drain_absorb().0
    }

    /// Non-blocking completion test for a posted receive: delivers and
    /// returns the message if it is (or just) arrived, `Ok(None)` if it is
    /// still in flight, and the usual failure surfacing otherwise.
    ///
    /// Note `test`-based completion *order* across multiple handles is an
    /// OS-schedule artifact under the thread engine; deterministic code
    /// that completes a batch must use [`Ctx::wait_all`], which orders by
    /// virtual arrival.
    pub fn test(&mut self, h: &RecvHandle) -> MpiResult<Option<Msg>> {
        if !self.world.is_alive(self.rank) {
            return Err(MpiError::Killed);
        }
        self.progress();
        if let Some(pos) = self
            .pending
            .iter()
            .position(|m| m.src == h.src && m.epoch == h.epoch && m.tag == h.tag)
        {
            let msg = self.pending.remove(pos).unwrap();
            self.deliver(&msg);
            return Ok(Some(msg));
        }
        if self.revoked.contains(&h.epoch) {
            return Err(MpiError::Revoked);
        }
        if self.known_dead.contains(&h.src) || !self.world.is_alive(h.src) {
            self.note_death(h.src);
            return Err(MpiError::ProcFailed(vec![h.src]));
        }
        Ok(None)
    }

    /// Blocking completion of one posted receive — identical to
    /// [`Ctx::recv_match`] on the handle's criteria.
    pub async fn wait(&mut self, h: RecvHandle) -> MpiResult<Msg> {
        self.recv_match(h.src, h.epoch, h.tag).await
    }

    /// Complete a batch of posted receives, delivering in **virtual-arrival
    /// order** (ties broken by source rank, then tag).
    ///
    /// Blocks until *every* handle has a physically-buffered match, then
    /// sorts the matches by modeled arrival and delivers them in that
    /// order.  Arrival timestamps are pure functions of virtual time, so
    /// the delivery sequence — and with it every clock jump and trace
    /// event — is identical across engines, even though the messages may
    /// have been pushed in any physical order.  Handles must be pairwise
    /// distinct in `(src, epoch, tag)`.
    ///
    /// Errors like [`Ctx::recv_match`]: `ProcFailed` once a handle's source
    /// is known dead with no buffered match, `Revoked` if any handle's
    /// epoch is revoked while waiting, `Killed` if this rank was claimed by
    /// a co-scheduled kill.
    pub async fn wait_all(&mut self, handles: &[RecvHandle]) -> MpiResult<Vec<Msg>> {
        debug_assert!(
            (1..handles.len()).all(|i| !handles[..i].contains(&handles[i])),
            "wait_all handles must be pairwise distinct"
        );
        let matched = |pending: &VecDeque<Msg>, h: &RecvHandle| {
            pending.iter().any(|m| m.src == h.src && m.epoch == h.epoch && m.tag == h.tag)
        };
        loop {
            if !self.world.is_alive(self.rank) {
                return Err(MpiError::Killed);
            }
            if handles.iter().all(|h| matched(&self.pending, h)) {
                break;
            }
            for h in handles {
                if self.revoked.contains(&h.epoch) {
                    return Err(MpiError::Revoked);
                }
            }
            let (got_any, seen) = self.drain_absorb();
            if got_any {
                continue;
            }
            for h in handles {
                if !matched(&self.pending, h)
                    && (self.known_dead.contains(&h.src) || !self.world.is_alive(h.src))
                {
                    self.note_death(h.src);
                    return Err(MpiError::ProcFailed(vec![h.src]));
                }
            }
            self.world.wait_push(self.rank, seen).await;
        }
        let mut msgs: Vec<Msg> = Vec::with_capacity(handles.len());
        for h in handles {
            let pos = self
                .pending
                .iter()
                .position(|m| m.src == h.src && m.epoch == h.epoch && m.tag == h.tag)
                .expect("all-present loop exited with every handle matched");
            msgs.push(self.pending.remove(pos).unwrap());
        }
        msgs.sort_by(|a, b| {
            a.arrival.total_cmp(&b.arrival).then(a.src.cmp(&b.src)).then(a.tag.cmp(&b.tag))
        });
        for m in &msgs {
            self.deliver(m);
        }
        Ok(msgs)
    }

    /// Drain every queued mailbox message through [`Ctx::absorb`], returning
    /// whether anything arrived plus the push-counter snapshot to hand to
    /// [`World::wait_push`] if nothing did.
    fn drain_absorb(&mut self) -> (bool, u64) {
        let mut batch = std::mem::take(&mut self.inbox);
        let seen = self.world.drain_mail(self.rank, &mut batch);
        let got_any = !batch.is_empty();
        for m in batch.drain(..) {
            self.absorb(m);
        }
        self.inbox = batch;
        (got_any, seen)
    }

    /// Classify an incoming message: control messages mutate local knowledge,
    /// data messages go to the pending buffer.
    fn absorb(&mut self, m: Msg) {
        match &m.payload {
            Payload::Ctl(Ctl::Died { rank, .. }) => {
                self.known_dead.insert(*rank);
            }
            Payload::Ctl(Ctl::Revoke { epoch }) => {
                self.revoked.insert(*epoch);
            }
            Payload::Ctl(Ctl::Join { epoch, members, old_members, as_rank }) => {
                self.joins.push_back((*epoch, members.clone(), old_members.clone(), *as_rank));
            }
            Payload::Ctl(Ctl::Shutdown) => {
                self.shutdown = true;
            }
            Payload::Data(_) => self.pending.push_back(m),
        }
    }

    /// Clock bookkeeping for a delivered message.
    fn deliver(&mut self, m: &Msg) {
        let t_before = self.clock;
        self.advance_to(m.arrival);
        self.advance(self.world.net.params.recv_overhead);
        let (src, epoch, tag, arrival, t) = (m.src, m.epoch, m.tag, m.arrival, self.clock);
        self.trace_push(|| TraceEvent::Recv { src, epoch, tag, t_before, arrival, t });
    }

    /// Charge failure-detection latency once per dead peer.
    fn note_death(&mut self, r: WorldRank) {
        self.known_dead.insert(r);
        if self.detected.insert(r) {
            let base = self.world.death_time(r).unwrap_or(self.clock);
            self.advance_to(base + self.world.net.params.detect_latency);
            let at = self.clock;
            self.trace_push(|| TraceEvent::Mark { label: "detect-death", arg: r as i64, t: at });
        }
    }

    /// This rank dies: mark the registry, notify every mailbox (simulated
    /// failure-detector propagation), and return the error the caller
    /// propagates out of the rank body.
    ///
    /// Kills co-scheduled at the same instant are marked atomically with
    /// this one so that no survivor can observe a half-dead group (they are
    /// *simultaneous* by definition; the co-scheduled ranks still exit at
    /// their own tick, with idempotent registry marking).  Deaths of
    /// co-scheduled ranks are broadcast too: under the event engine a
    /// co-victim's own `die` only runs when its task is next scheduled, so
    /// survivors must be able to learn the whole group from their mailboxes
    /// rather than from registry-read timing (see
    /// `die_broadcasts_co_scheduled_deaths`).
    pub fn die(&mut self) -> MpiError {
        let (rank, at) = (self.rank, self.clock);
        self.trace_push(|| TraceEvent::Mark { label: "died", arg: rank as i64, t: at });
        let co = self.world.injector.co_scheduled(self.rank, u64::MAX);
        for &c in &co {
            self.world.mark_dead(c, self.clock);
        }
        self.world.mark_dead(self.rank, self.clock);
        // Broadcast to EVERY mailbox, including registry-dead ranks: a
        // co-scheduled rank that has not reached its own kill tick yet may
        // be blocked in a receive and needs a wake-up to discover its own
        // death (see `recv_match`).
        for dst in 0..self.world.size {
            if dst == self.rank {
                continue;
            }
            self.send_ctl(dst, Ctl::Died { rank: self.rank, at: self.clock });
            for &c in &co {
                if dst != c {
                    self.send_ctl(dst, Ctl::Died { rank: c, at: self.clock });
                }
            }
        }
        MpiError::Killed
    }

    /// Spare-side: block until a Join invitation (or Shutdown) arrives.
    /// Returns `None` on shutdown, else
    /// `(epoch, members, old members, adopted comm rank)`.
    pub async fn wait_join(&mut self) -> Option<(u64, Vec<WorldRank>, Vec<WorldRank>, usize)> {
        loop {
            if let Some(j) = self.joins.pop_front() {
                return Some(j);
            }
            if self.shutdown {
                return None;
            }
            let (got_any, seen) = self.drain_absorb();
            if got_any {
                continue;
            }
            self.world.wait_push(self.rank, seen).await;
        }
    }

    /// Drop buffered data messages from epochs older than `epoch` (stale
    /// traffic from before a recovery).
    pub fn purge_epochs_below(&mut self, epoch: u64) {
        self.pending.retain(|m| m.epoch >= epoch);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::failure::{InjectionPlan, Injector, LinkFault, Straggler};
    use crate::netsim::NetParams;
    use crate::simmpi::engine::block_on;
    use crate::simmpi::Blob;

    fn two_rank_world() -> Arc<World> {
        World::new(2, 0, NetParams::default(), Injector::new(InjectionPlan::none()))
    }

    #[test]
    fn send_recv_advances_clocks() {
        let w = two_rank_world();
        let mut c0 = Ctx::new(w.clone(), 0);
        let mut c1 = Ctx::new(w, 1);
        c0.send_raw(1, 1, 7, Payload::Data(Blob::scalar(42.0))).unwrap();
        assert!(c0.clock > 0.0, "sender charged");
        let m = block_on(c1.recv_match(0, 1, 7)).unwrap();
        assert_eq!(m.data().f, vec![42.0]);
        assert!(c1.clock >= c0.clock * 0.0, "receiver clock advanced to arrival");
        assert!(c1.clock > 0.0);
    }

    #[test]
    fn recv_out_of_order_by_tag() {
        let w = two_rank_world();
        let mut c0 = Ctx::new(w.clone(), 0);
        let mut c1 = Ctx::new(w, 1);
        c0.send_raw(1, 1, 1, Payload::Data(Blob::scalar(1.0))).unwrap();
        c0.send_raw(1, 1, 2, Payload::Data(Blob::scalar(2.0))).unwrap();
        // Receive tag 2 first, then tag 1 (buffered).
        assert_eq!(block_on(c1.recv_match(0, 1, 2)).unwrap().data().f, vec![2.0]);
        assert_eq!(block_on(c1.recv_match(0, 1, 1)).unwrap().data().f, vec![1.0]);
    }

    #[test]
    fn send_to_dead_rank_fails() {
        let w = two_rank_world();
        let mut c0 = Ctx::new(w.clone(), 0);
        w.mark_dead(1, 0.5);
        match c0.send_raw(1, 1, 0, Payload::Data(Blob::empty())) {
            Err(MpiError::ProcFailed(v)) => assert_eq!(v, vec![1]),
            other => panic!("expected ProcFailed, got {other:?}"),
        }
        // Detection latency charged.
        assert!(c0.clock >= 0.5 + w.net.params.detect_latency);
    }

    #[test]
    fn recv_from_dead_rank_fails_but_drains_buffered() {
        let w = two_rank_world();
        let mut c0 = Ctx::new(w.clone(), 0);
        let mut c1 = Ctx::new(w.clone(), 1);
        // Rank 0 sends one message, then dies.
        c0.send_raw(1, 1, 9, Payload::Data(Blob::scalar(3.0))).unwrap();
        let _ = c0.die();
        // The pre-death message is still delivered...
        assert_eq!(block_on(c1.recv_match(0, 1, 9)).unwrap().data().f, vec![3.0]);
        // ...the next receive errors.
        match block_on(c1.recv_match(0, 1, 10)) {
            Err(MpiError::ProcFailed(v)) => assert_eq!(v, vec![0]),
            other => panic!("expected ProcFailed, got {other:?}"),
        }
    }

    #[test]
    fn revoke_unblocks_matching_epoch() {
        let w = two_rank_world();
        let mut c0 = Ctx::new(w.clone(), 0);
        let mut c1 = Ctx::new(w, 1);
        c0.send_ctl(1, Ctl::Revoke { epoch: 3 });
        match block_on(c1.recv_match(0, 3, 0)) {
            Err(MpiError::Revoked) => {}
            other => panic!("expected Revoked, got {other:?}"),
        }
        // Other epochs unaffected.
        c0.send_raw(1, 4, 0, Payload::Data(Blob::scalar(8.0))).unwrap();
        assert_eq!(block_on(c1.recv_match(0, 4, 0)).unwrap().data().f, vec![8.0]);
    }

    #[test]
    fn purge_drops_stale_epochs() {
        let w = two_rank_world();
        let mut c0 = Ctx::new(w.clone(), 0);
        let mut c1 = Ctx::new(w, 1);
        c0.send_raw(1, 1, 0, Payload::Data(Blob::scalar(1.0))).unwrap();
        c0.send_raw(1, 2, 0, Payload::Data(Blob::scalar(2.0))).unwrap();
        // Force both into pending.
        assert_eq!(block_on(c1.recv_match(0, 2, 0)).unwrap().data().f, vec![2.0]);
        c1.purge_epochs_below(2);
        // Epoch-1 message is gone; epoch-2 message with another tag arrives.
        c0.send_raw(1, 2, 5, Payload::Data(Blob::scalar(5.0))).unwrap();
        assert_eq!(block_on(c1.recv_match(0, 2, 5)).unwrap().data().f, vec![5.0]);
        assert!(c1.pending.is_empty());
    }

    #[test]
    fn trace_hooks_record_send_recv_and_spans() {
        let w = two_rank_world();
        let mut c0 = Ctx::new(w.clone(), 0);
        let mut c1 = Ctx::new(w, 1);
        assert!(c0.take_trace().is_empty(), "untraced ctx yields no events");
        c0.enable_trace();
        c1.enable_trace();
        c0.send_raw(1, 1, 7, Payload::Data(Blob::scalar(42.0))).unwrap();
        block_on(c1.recv_match(0, 1, 7)).unwrap();
        let t0 = c0.take_trace();
        let t1 = c1.take_trace();
        let send = t0
            .iter()
            .find_map(|e| match *e {
                TraceEvent::Send { dst, epoch, tag, arrival, .. } => {
                    Some((dst, epoch, tag, arrival))
                }
                _ => None,
            })
            .expect("sender recorded a Send edge");
        let recv = t1
            .iter()
            .find_map(|e| match *e {
                TraceEvent::Recv { src, epoch, tag, arrival, .. } => {
                    Some((src, epoch, tag, arrival))
                }
                _ => None,
            })
            .expect("receiver recorded a Recv edge");
        // Both endpoints can derive the same edge key independently.
        assert_eq!(send, (1, 1, 7, recv.3));
        assert_eq!(recv.0, 0);
        // Spans cover the whole charged lifetime of each rank.
        for (ctx_total, trace) in [(c0.timers.total(), &t0), (c1.timers.total(), &t1)] {
            let spanned: f64 = trace
                .iter()
                .map(|e| match *e {
                    TraceEvent::Span { t0, t1, .. } => t1 - t0,
                    _ => 0.0,
                })
                .sum();
            assert!((spanned - ctx_total).abs() < 1e-12, "{spanned} vs {ctx_total}");
        }
    }

    #[test]
    fn straggler_scales_compute_and_recompute_charges_only() {
        let w = World::new(
            2,
            0,
            NetParams::default(),
            Injector::new(InjectionPlan {
                stragglers: vec![Straggler { world_rank: 1, mult: 3.0 }],
                ..Default::default()
            }),
        );
        let mut healthy = Ctx::new(w.clone(), 0);
        let mut slow = Ctx::new(w, 1);
        healthy.advance(1.0);
        slow.advance(1.0);
        assert_eq!(healthy.timers.compute, 1.0);
        assert_eq!(slow.timers.compute, 3.0, "compute runs mult x slower");
        // Communication is not degraded.
        slow.set_phase(Phase::Comm);
        slow.advance(1.0);
        assert_eq!(slow.timers.comm, 1.0);
        // Recomputation replays compute work, so it is slowed too.
        slow.set_phase(Phase::Compute);
        slow.recompute = true;
        slow.advance(1.0);
        assert_eq!(slow.timers.recompute, 3.0);
        // advance_to is absolute (message arrival), never scaled.
        let target = slow.clock + 1.0;
        slow.advance_to(target);
        assert_eq!(slow.clock, target);
    }

    #[test]
    fn lossy_link_retries_then_delivers() {
        let w = World::new(
            2,
            0,
            NetParams::default(),
            Injector::new(InjectionPlan {
                links: vec![LinkFault { src: 0, dst: 1, drops: 3 }],
                ..Default::default()
            }),
        );
        let mut c0 = Ctx::new(w.clone(), 0);
        let mut c1 = Ctx::new(w.clone(), 1);
        // Three drops are under the default budget: the send succeeds after
        // three timeout-and-retry rounds, charged to the sender.
        c0.send_raw(1, 1, 7, Payload::Data(Blob::scalar(42.0))).unwrap();
        assert_eq!(c0.faults.link_retries, 3);
        assert!(c0.clock >= 3.0 * w.net.params.link_timeout);
        assert_eq!(block_on(c1.recv_match(0, 1, 7)).unwrap().data().f, vec![42.0]);
        // The schedule is consumed: the link has healed.
        c0.send_raw(1, 1, 8, Payload::Data(Blob::scalar(1.0))).unwrap();
        assert_eq!(c0.faults.link_retries, 3);
        // The reverse direction was never faulty.
        c1.send_raw(0, 1, 9, Payload::Data(Blob::scalar(2.0))).unwrap();
        assert_eq!(c1.faults.link_retries, 0);
    }

    #[test]
    fn link_budget_exhaustion_revokes_the_epoch_but_kills_nobody() {
        let w = World::new(
            2,
            0,
            NetParams::default(),
            Injector::new(InjectionPlan {
                links: vec![LinkFault { src: 0, dst: 1, drops: 99 }],
                ..Default::default()
            }),
        );
        let mut c0 = Ctx::new(w.clone(), 0);
        match c0.send_raw(1, 7, 0, Payload::Data(Blob::scalar(1.0))) {
            Err(MpiError::Revoked) => {}
            other => panic!("expected Revoked, got {other:?}"),
        }
        // Observably distinct from ULFM death: the epoch is poisoned so the
        // recovery driver rebuilds the communicator, but both endpoints are
        // alive and no death was detected.
        assert!(c0.is_revoked(7));
        assert!(w.is_alive(0) && w.is_alive(1));
        assert!(c0.known_dead.is_empty());
        assert_eq!(c0.faults.link_retries, w.net.params.link_retry_budget as u64);
        // Control messages never drop: the revoke still reaches the peer.
        let mut c1 = Ctx::new(w, 1);
        c0.send_ctl(1, Ctl::Revoke { epoch: 7 });
        match block_on(c1.recv_match(0, 7, 0)) {
            Err(MpiError::Revoked) => {}
            other => panic!("expected Revoked at the peer, got {other:?}"),
        }
    }

    /// Regression (ordering audit, DESIGN.md §12): a whole co-scheduled kill
    /// group must be learnable from mailbox messages alone.  Under the event
    /// engine a co-victim's own `die` runs only when its task is next
    /// scheduled, so the first victim's broadcast has to carry the group.
    #[test]
    fn die_broadcasts_co_scheduled_deaths() {
        let w = World::new(
            3,
            0,
            NetParams::default(),
            Injector::new(InjectionPlan::burst(&[0, 1], 5)),
        );
        let mut c0 = Ctx::new(w.clone(), 0);
        let mut c2 = Ctx::new(w, 2);
        let _ = c0.die();
        // Rank 2 waits on rank 1 (which never ran its own `die`): the
        // failure must surface from rank 0's broadcast.
        match block_on(c2.recv_match(1, 1, 0)) {
            Err(MpiError::ProcFailed(v)) => assert_eq!(v, vec![1]),
            other => panic!("expected ProcFailed, got {other:?}"),
        }
        assert!(c2.known_dead.contains(&0), "own death broadcast absorbed");
        assert!(c2.known_dead.contains(&1), "co-scheduled death broadcast absorbed");
    }
}
