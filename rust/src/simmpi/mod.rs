//! Simulated MPI runtime with ULFM fault-tolerance semantics.
//!
//! Substitutes for the paper's Open MPI 1.7.1 + ULFM 1.1 stack (DESIGN.md
//! §1): ranks are cooperative tasks (or OS threads under the oracle engine,
//! see [`engine`]), links are in-world mailboxes, and every message is
//! priced by the virtual-clock network model in [`crate::netsim`].  The ULFM
//! surface (`ProcFailed` errors, revoke, shrink, agree) matches what the
//! paper's recovery strategies are built on.

pub mod comm;
pub mod ctx;
pub mod engine;
pub mod msg;
pub mod ulfm;
pub mod world;

pub use comm::Comm;
pub use ctx::{Ctx, RecvHandle};
pub use engine::{block_on, run_event_loop, RankTask};
pub use msg::{shared, tags, Blob, Ctl, Msg, Payload, SharedVec, Tag, WordArena};
pub use world::{Engine, World, WorldRank};

/// ULFM-visible error classes.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum MpiError {
    /// `MPI_ERR_PROC_FAILED`: the listed world ranks are dead.
    ProcFailed(Vec<WorldRank>),
    /// `MPI_ERR_REVOKED`: the communicator was revoked by a peer.
    Revoked,
    /// The failure injector killed *this* rank (propagates out of the rank
    /// body; never observed by peers as anything but a dead process).
    Killed,
}

impl std::fmt::Display for MpiError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            MpiError::ProcFailed(r) => write!(f, "process failure detected: ranks {r:?}"),
            MpiError::Revoked => write!(f, "communicator revoked"),
            MpiError::Killed => write!(f, "killed by failure injector"),
        }
    }
}

impl std::error::Error for MpiError {}

pub type MpiResult<T> = Result<T, MpiError>;
