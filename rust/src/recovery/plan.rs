//! Redistribution planning: who ships which global rows to whom after a
//! failure, and who serves data on behalf of dead ranks (their buddies).
//!
//! Every rank derives the *same* deterministic segment list locally (old and
//! new partitions, communicator membership, the registry's dead set and the
//! buddy ring are all globally known), so no negotiation round is needed —
//! only the data transfers themselves, which is what the paper measures as
//! state-recovery cost (§IV-B, Fig. 3: redistribution traffic peaks when
//! high ranks fail).  The same no-negotiation construction carries the
//! policy engine's per-event decisions (see [`crate::recovery::policy`]).

use std::ops::Range;

use crate::ckptstore::Scheme;
use crate::problem::{sources, Partition};
use crate::simmpi::WorldRank;

/// One planned transfer of global rows.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Segment {
    /// Stable index (tags derive from it).
    pub idx: usize,
    /// Global row range.
    pub rows: Range<usize>,
    /// Original owner (keys the remote checkpoint store).
    pub owner_wr: WorldRank,
    /// Who serves the bytes: the owner if alive, else its first live buddy.
    pub server_wr: WorldRank,
    /// New owner (destination).
    pub dest_wr: WorldRank,
}

/// Scheme-aware segment list: dead owners' rows are served by whichever
/// rank the redundancy scheme designates — a live mirror buddy, or the
/// parity holder that the recovery reader
/// ([`crate::ckptstore::reconstruct_failed`]) materialized the owner's
/// objects on.  Unrecoverable losses must have been escalated *before*
/// planning (see [`crate::ckptstore::assess_loss`]); hitting one here is a
/// protocol bug, not a runtime condition.
///
/// The plan is a pure function of its inputs and is re-derived from
/// scratch by every recovery attempt: when a nested failure aborts an
/// attempt mid-transfer, the fenced driver rolls `old_part` back to the
/// event-entry partition ([`crate::solver::state::StateSnapshot`]) and the
/// retry plans against the *enlarged* dead set — half-executed plans are
/// never resumed (DESIGN.md §10).  Survivors whose liveness snapshots
/// straddle a nested death may transiently derive different server sets;
/// the divergence always names a dead rank, so the stale plan's executor
/// errors on its first dead send/recv and the attempt is abandoned for
/// everyone.
pub fn transfer_segments_scheme(
    old_part: &Partition,
    old_members: &[WorldRank],
    new_part: &Partition,
    new_members: &[WorldRank],
    alive: &dyn Fn(WorldRank) -> bool,
    scheme: &Scheme,
    stride: usize,
) -> Vec<Segment> {
    assert_eq!(old_part.n(), new_part.n(), "row space must be preserved");
    let n_old = old_members.len();
    let alive_cr = |cr: usize| alive(old_members[cr]);
    let mut segs = Vec::new();
    let mut idx = 0;
    for (new_cr, &dest_wr) in new_members.iter().enumerate() {
        for src in sources(old_part, new_part.range(new_cr)) {
            let server_wr = if alive(old_members[src.owner]) {
                old_members[src.owner]
            } else {
                let cr = scheme
                    .server_cr_for(src.owner, n_old, &alive_cr, stride)
                    .expect("no live holder of a required segment — unrecoverable");
                old_members[cr]
            };
            segs.push(Segment {
                idx,
                rows: src.rows,
                owner_wr: old_members[src.owner],
                server_wr,
                dest_wr,
            });
            idx += 1;
        }
    }
    segs
}

/// This rank's view of a segment list.
#[derive(Debug, Default)]
pub struct MyTransfers {
    /// Segments I must send (server == me, dest != me).
    pub outgoing: Vec<Segment>,
    /// Segments I will receive (dest == me, server != me).
    pub incoming: Vec<Segment>,
    /// Segments I satisfy locally (dest == me, server == me).
    pub local: Vec<Segment>,
}

pub fn my_transfers(segs: &[Segment], me: WorldRank) -> MyTransfers {
    let mut t = MyTransfers::default();
    for s in segs {
        if s.dest_wr == me && s.server_wr == me {
            t.local.push(s.clone());
        } else if s.dest_wr == me {
            t.incoming.push(s.clone());
        } else if s.server_wr == me {
            t.outgoing.push(s.clone());
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn alive_except(dead: Vec<WorldRank>) -> impl Fn(WorldRank) -> bool {
        move |r| !dead.contains(&r)
    }

    const MIRROR1: Scheme = Scheme::Mirror { k: 1 };

    #[test]
    fn segments_cover_new_partition_exactly() {
        let n = 100;
        let old = Partition::balanced(n, 5);
        let new = Partition::balanced(n, 4);
        let old_members: Vec<usize> = (0..5).collect();
        let new_members = vec![0, 1, 2, 3];
        let alive = alive_except(vec![4]);
        let segs = transfer_segments_scheme(
            &old, &old_members, &new, &new_members, &alive, &MIRROR1, 1,
        );
        // Coverage: every global row exactly once.
        let mut seen = vec![false; n];
        for s in &segs {
            for r in s.rows.clone() {
                assert!(!seen[r], "row {r} covered twice");
                seen[r] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
        // Dead rank 4's rows are served by its buddy (old cr 0 — ring wrap).
        for s in segs.iter().filter(|s| s.owner_wr == 4) {
            assert_eq!(s.server_wr, 0);
        }
    }

    #[test]
    fn high_rank_failure_causes_more_transfers_than_low_rank() {
        // Paper Fig. 3 worst case: redistribution traffic (bytes moved
        // between distinct ranks) is larger when a high rank fails.
        let n = 10_000;
        let old = Partition::balanced(n, 10);
        let moved = |dead: usize| -> usize {
            let old_members: Vec<usize> = (0..10).collect();
            let new_members: Vec<usize> = (0..10).filter(|&r| r != dead).collect();
            let new = Partition::balanced(n, 9);
            let alive = move |r: usize| r != dead;
            transfer_segments_scheme(
                &old, &old_members, &new, &new_members, &alive, &MIRROR1, 1,
            )
            .iter()
            .filter(|s| s.server_wr != s.dest_wr)
            .map(|s| s.rows.len())
            .sum()
        };
        assert!(
            moved(9) > moved(0),
            "high-rank failure should move more rows: {} vs {}",
            moved(9),
            moved(0)
        );
    }

    #[test]
    fn xor_segments_are_served_by_the_parity_holder() {
        let n = 800;
        let old = Partition::balanced(n, 8);
        let new = Partition::balanced(n, 7);
        let old_members: Vec<usize> = (0..8).collect();
        // Rank 5 (group 1 = {4..7}) dies; group 1's parity holder is 0.
        let new_members: Vec<usize> = (0..8).filter(|&r| r != 5).collect();
        let alive = |r: usize| r != 5;
        let segs = transfer_segments_scheme(
            &old,
            &old_members,
            &new,
            &new_members,
            &alive,
            &Scheme::Xor { g: 4 },
            1,
        );
        let mut seen = vec![false; n];
        for s in &segs {
            for r in s.rows.clone() {
                assert!(!seen[r]);
                seen[r] = true;
            }
            if s.owner_wr == 5 {
                assert_eq!(s.server_wr, 0, "holder of group 1 serves the dead member");
            } else {
                assert_eq!(s.server_wr, s.owner_wr);
            }
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn my_transfers_partitions_segments() {
        let n = 100;
        let old = Partition::balanced(n, 4);
        let new = Partition::balanced(n, 3);
        let old_members = vec![0, 1, 2, 3];
        let new_members = vec![0, 1, 2];
        let alive = alive_except(vec![3]);
        let segs = transfer_segments_scheme(
            &old, &old_members, &new, &new_members, &alive, &MIRROR1, 1,
        );
        let total: usize = (0..4)
            .map(|me| {
                let t = my_transfers(&segs, me);
                t.incoming.len() + t.local.len()
            })
            .sum();
        assert_eq!(total, segs.len());
    }

    #[test]
    fn identity_repartition_is_all_local() {
        let old = Partition::balanced(64, 4);
        let members = vec![0, 1, 2, 3];
        let alive = |_r: usize| true;
        let segs =
            transfer_segments_scheme(&old, &members, &old, &members, &alive, &MIRROR1, 1);
        assert!(segs.iter().all(|s| s.server_wr == s.dest_wr));
    }
}
