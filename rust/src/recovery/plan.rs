//! Redistribution planning: who ships which global rows to whom after a
//! failure, and who serves data on behalf of dead ranks (their buddies).
//!
//! Every rank derives the *same* deterministic segment list locally (old and
//! new partitions, communicator membership, the registry's dead set and the
//! buddy ring are all globally known), so no negotiation round is needed —
//! only the data transfers themselves, which is what the paper measures as
//! state-recovery cost (§IV-B, Fig. 3: redistribution traffic peaks when
//! high ranks fail).  The same no-negotiation construction carries the
//! policy engine's per-event decisions (see [`crate::recovery::policy`]).

use std::ops::Range;

use crate::checkpoint::buddy_of_stride;
use crate::problem::{sources, Partition};
use crate::simmpi::WorldRank;

/// One planned transfer of global rows.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Segment {
    /// Stable index (tags derive from it).
    pub idx: usize,
    /// Global row range.
    pub rows: Range<usize>,
    /// Original owner (keys the remote checkpoint store).
    pub owner_wr: WorldRank,
    /// Who serves the bytes: the owner if alive, else its first live buddy.
    pub server_wr: WorldRank,
    /// New owner (destination).
    pub dest_wr: WorldRank,
}

/// Pick the serving rank for data of old comm rank `owner_cr`: the owner if
/// alive, otherwise the first alive buddy on the ring (the paper's redundant
/// in-memory copies).
pub fn server_for(
    owner_cr: usize,
    old_members: &[WorldRank],
    alive: &dyn Fn(WorldRank) -> bool,
    buddy_k: usize,
    stride: usize,
) -> Option<WorldRank> {
    let n = old_members.len();
    let owner_wr = old_members[owner_cr];
    if alive(owner_wr) {
        return Some(owner_wr);
    }
    (1..=buddy_k.min(n - 1))
        .map(|d| old_members[buddy_of_stride(owner_cr, d, n, stride)])
        .find(|&wr| alive(wr))
}

/// Full deterministic segment list for a repartition
/// `old_part`/`old_members` -> `new_part`/`new_members`.
pub fn transfer_segments(
    old_part: &Partition,
    old_members: &[WorldRank],
    new_part: &Partition,
    new_members: &[WorldRank],
    alive: &dyn Fn(WorldRank) -> bool,
    buddy_k: usize,
    stride: usize,
) -> Vec<Segment> {
    assert_eq!(old_part.n(), new_part.n(), "row space must be preserved");
    let mut segs = Vec::new();
    let mut idx = 0;
    for (new_cr, &dest_wr) in new_members.iter().enumerate() {
        for src in sources(old_part, new_part.range(new_cr)) {
            let server_wr = server_for(src.owner, old_members, alive, buddy_k, stride)
                .expect("no live holder of a required segment — unrecoverable");
            segs.push(Segment {
                idx,
                rows: src.rows,
                owner_wr: old_members[src.owner],
                server_wr,
                dest_wr,
            });
            idx += 1;
        }
    }
    segs
}

/// This rank's view of a segment list.
#[derive(Debug, Default)]
pub struct MyTransfers {
    /// Segments I must send (server == me, dest != me).
    pub outgoing: Vec<Segment>,
    /// Segments I will receive (dest == me, server != me).
    pub incoming: Vec<Segment>,
    /// Segments I satisfy locally (dest == me, server == me).
    pub local: Vec<Segment>,
}

pub fn my_transfers(segs: &[Segment], me: WorldRank) -> MyTransfers {
    let mut t = MyTransfers::default();
    for s in segs {
        if s.dest_wr == me && s.server_wr == me {
            t.local.push(s.clone());
        } else if s.dest_wr == me {
            t.incoming.push(s.clone());
        } else if s.server_wr == me {
            t.outgoing.push(s.clone());
        }
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn alive_except(dead: Vec<WorldRank>) -> impl Fn(WorldRank) -> bool {
        move |r| !dead.contains(&r)
    }

    #[test]
    fn server_prefers_owner_then_buddy() {
        let members = vec![10, 11, 12, 13];
        let alive = alive_except(vec![12]);
        assert_eq!(server_for(1, &members, &alive, 1, 1), Some(11));
        assert_eq!(server_for(2, &members, &alive, 1, 1), Some(13)); // buddy of 2 is 3
    }

    #[test]
    fn server_none_when_owner_and_buddies_dead() {
        let members = vec![10, 11, 12, 13];
        let alive = alive_except(vec![12, 13]);
        assert_eq!(server_for(2, &members, &alive, 1, 1), None);
        // With two buddies the next one steps in.
        assert_eq!(server_for(2, &members, &alive, 2, 1), Some(10));
    }

    #[test]
    fn segments_cover_new_partition_exactly() {
        let n = 100;
        let old = Partition::balanced(n, 5);
        let new = Partition::balanced(n, 4);
        let old_members: Vec<usize> = (0..5).collect();
        let new_members = vec![0, 1, 2, 3];
        let alive = alive_except(vec![4]);
        let segs = transfer_segments(&old, &old_members, &new, &new_members, &alive, 1, 1);
        // Coverage: every global row exactly once.
        let mut seen = vec![false; n];
        for s in &segs {
            for r in s.rows.clone() {
                assert!(!seen[r], "row {r} covered twice");
                seen[r] = true;
            }
        }
        assert!(seen.iter().all(|&b| b));
        // Dead rank 4's rows are served by its buddy (old cr 0 — ring wrap).
        for s in segs.iter().filter(|s| s.owner_wr == 4) {
            assert_eq!(s.server_wr, 0);
        }
    }

    #[test]
    fn high_rank_failure_causes_more_transfers_than_low_rank() {
        // Paper Fig. 3 worst case: redistribution traffic (bytes moved
        // between distinct ranks) is larger when a high rank fails.
        let n = 10_000;
        let old = Partition::balanced(n, 10);
        let moved = |dead: usize| -> usize {
            let old_members: Vec<usize> = (0..10).collect();
            let new_members: Vec<usize> = (0..10).filter(|&r| r != dead).collect();
            let new = Partition::balanced(n, 9);
            let alive = move |r: usize| r != dead;
            transfer_segments(&old, &old_members, &new, &new_members, &alive, 1, 1)
                .iter()
                .filter(|s| s.server_wr != s.dest_wr)
                .map(|s| s.rows.len())
                .sum()
        };
        assert!(
            moved(9) > moved(0),
            "high-rank failure should move more rows: {} vs {}",
            moved(9),
            moved(0)
        );
    }

    #[test]
    fn my_transfers_partitions_segments() {
        let n = 100;
        let old = Partition::balanced(n, 4);
        let new = Partition::balanced(n, 3);
        let old_members = vec![0, 1, 2, 3];
        let new_members = vec![0, 1, 2];
        let alive = alive_except(vec![3]);
        let segs = transfer_segments(&old, &old_members, &new, &new_members, &alive, 1, 1);
        let total: usize = (0..4)
            .map(|me| {
                let t = my_transfers(&segs, me);
                t.incoming.len() + t.local.len()
            })
            .sum();
        assert_eq!(total, segs.len());
    }

    #[test]
    fn identity_repartition_is_all_local() {
        let old = Partition::balanced(64, 4);
        let members = vec![0, 1, 2, 3];
        let alive = |_r: usize| true;
        let segs = transfer_segments(&old, &members, &old, &members, &alive, 1, 1);
        assert!(segs.iter().all(|s| s.server_wr == s.dest_wr));
    }
}
