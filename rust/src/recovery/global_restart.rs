//! Analytic baseline: classic global checkpoint/restart through the parallel
//! file system (paper §I/§III's "increasingly inefficient strategy").
//!
//! The paper motivates in-situ recovery by contrast with global C/R; this
//! module provides the cost model used by the ablation bench to quantify
//! that contrast on the same workloads: Young's optimal interval, the
//! per-checkpoint PFS write time (aggregate bandwidth shared by all ranks),
//! and the expected waste per failure (restart latency + state re-read +
//! half-interval recomputation).
//!
//! It also executes the *escalation* path ([`restart_on_survivors`]): when
//! the checkpoint store reports an unrecoverable loss (e.g. two failures in
//! one `xor:<g>` parity group before re-encode,
//! [`crate::ckptstore::assess_loss`]), survivors rebuild the problem from
//! scratch — the test problem is analytic, so matrix, RHS and the zero
//! initial guess regenerate deterministically — and re-establish fresh
//! checkpoints, instead of wedging on state that no longer exists anywhere.

use crate::checkpoint::CkptStore;
use crate::ckptstore::CkptCfg;
use crate::metrics::Phase;
use crate::netsim::ComputeModel;
use crate::problem::Partition;
use crate::simmpi::{Comm, Ctx, MpiResult};
use crate::solver::state::{generate_local_problem, IterScalars, SolverState};

/// Parameters of the global C/R baseline.
#[derive(Debug, Clone)]
pub struct GlobalCrModel {
    /// Aggregate parallel-file-system bandwidth shared by the job (B/s).
    pub pfs_bandwidth: f64,
    /// Fixed job tear-down + reschedule + relaunch latency (s).
    pub restart_latency: f64,
    /// System MTTF assumed when choosing the checkpoint interval (s).
    pub mttf: f64,
}

impl Default for GlobalCrModel {
    fn default() -> Self {
        GlobalCrModel {
            // Shared PFS of the paper era: ~1 GB/s aggregate for a job slice.
            pfs_bandwidth: 1.0e9,
            restart_latency: 30.0,
            mttf: 24.0 * 3600.0,
        }
    }
}

impl GlobalCrModel {
    /// Seconds to write one global checkpoint of `bytes` total state.
    pub fn checkpoint_cost(&self, bytes: usize) -> f64 {
        bytes as f64 / self.pfs_bandwidth
    }

    /// Young's optimal checkpoint interval: sqrt(2 * C * MTTF).
    pub fn young_interval(&self, bytes: usize) -> f64 {
        (2.0 * self.checkpoint_cost(bytes) * self.mttf).sqrt()
    }

    /// Expected waste per failure: relaunch + re-read + half an interval of
    /// recomputation (uniform failure position assumption).
    pub fn waste_per_failure(&self, bytes: usize) -> f64 {
        self.restart_latency + self.checkpoint_cost(bytes) + 0.5 * self.young_interval(bytes)
    }

    /// Steady-state overhead fraction of global C/R during failure-free
    /// operation (checkpoint time per interval).
    pub fn steady_overhead_fraction(&self, bytes: usize) -> f64 {
        let c = self.checkpoint_cost(bytes);
        c / (c + self.young_interval(bytes))
    }
}

/// Restart from scratch on the survivor communicator after an
/// unrecoverable in-memory loss.
///
/// Every survivor regenerates its block of the analytic test problem under
/// the new partition (matrix rows, RHS, zero initial guess), resets the
/// iteration state, wipes the checkpoint store and establishes fresh
/// checkpoints — the simulation analogue of the paper's relaunch-the-job
/// strawman, whose scheduling/PFS waste the caller has already charged via
/// [`GlobalCrModel::waste_per_failure`].  Deterministic: every survivor
/// computes the identical rebuild, and the re-established store starts a
/// fresh version chain, so later failures recover normally.
///
/// Re-entrant under nested failures (DESIGN.md §10): the rebuild reads
/// nothing from the store, so `clear_all` + a torn establishment is simply
/// re-run by the next fence attempt — and because unrecoverability is
/// monotone in the dead set, a retry of this event can never flip back to
/// an in-situ branch that would need the cleared checkpoints.
pub async fn restart_on_survivors(
    ctx: &mut Ctx,
    new_comm: &mut Comm,
    state: &mut SolverState,
    store: &mut CkptStore,
    ckpt: &CkptCfg,
    host: &ComputeModel,
) -> MpiResult<()> {
    let prev = ctx.set_phase(Phase::Recovery);
    let result = restart_inner(ctx, new_comm, state, store, ckpt, host).await;
    ctx.set_phase(prev);
    result
}

async fn restart_inner(
    ctx: &mut Ctx,
    new_comm: &mut Comm,
    state: &mut SolverState,
    store: &mut CkptStore,
    ckpt: &CkptCfg,
    host: &ComputeModel,
) -> MpiResult<()> {
    let me = new_comm.rank;
    let part = Partition::balanced(state.grid.n(), new_comm.size());
    // Same rebuild recipe (and modeled cost) as initial setup.
    let (mat, blk, b) = generate_local_problem(ctx, host, state.grid, &part, me);

    let mut nsq = [b.iter().map(|v| v * v).sum::<f64>()];
    new_comm.allreduce_sum(ctx, &mut nsq).await?;
    let bnorm = nsq[0].sqrt();

    let rows = mat.rows;
    let next_version = state.scalars.next_version;
    state.part = part;
    state.mat = mat;
    state.blk = blk;
    state.x = vec![0.0; rows];
    state.b = b;
    state.v_out = crate::backend::DenseBasis::zeros(state.v_out.m, rows);
    state.z_out = crate::backend::DenseBasis::zeros(state.z_out.m, rows);
    state.cycle = None;
    // The restarted solve is new work, not recomputation: reset the
    // progress counter and the high-water mark together.
    state.scalars = IterScalars { inner_iters_done: 0, next_version, bnorm };
    state.hwm_iters = 0;

    // Nothing in the old store is trustworthy (that is why we are here);
    // start a fresh redundancy chain at the next version.
    store.clear_all();
    state.establish_checkpoints(ctx, new_comm, store, next_version, ckpt).await?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn young_interval_matches_formula() {
        let m = GlobalCrModel { pfs_bandwidth: 1e9, restart_latency: 10.0, mttf: 3600.0 };
        let bytes = 2_000_000_000; // 2 GB -> C = 2 s
        let c = m.checkpoint_cost(bytes);
        assert!((c - 2.0).abs() < 1e-12);
        assert!((m.young_interval(bytes) - (2.0 * 2.0 * 3600.0f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn waste_grows_with_state_size() {
        let m = GlobalCrModel::default();
        assert!(m.waste_per_failure(10_000_000_000) > m.waste_per_failure(1_000_000_000));
    }

    #[test]
    fn steady_overhead_below_one() {
        let m = GlobalCrModel::default();
        let f = m.steady_overhead_fraction(100_000_000_000);
        assert!(f > 0.0 && f < 1.0);
    }
}
