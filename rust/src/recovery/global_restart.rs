//! Analytic baseline: classic global checkpoint/restart through the parallel
//! file system (paper §I/§III's "increasingly inefficient strategy").
//!
//! The paper motivates in-situ recovery by contrast with global C/R; this
//! module provides the cost model used by the ablation bench to quantify
//! that contrast on the same workloads: Young's optimal interval, the
//! per-checkpoint PFS write time (aggregate bandwidth shared by all ranks),
//! and the expected waste per failure (restart latency + state re-read +
//! half-interval recomputation).

/// Parameters of the global C/R baseline.
#[derive(Debug, Clone)]
pub struct GlobalCrModel {
    /// Aggregate parallel-file-system bandwidth shared by the job (B/s).
    pub pfs_bandwidth: f64,
    /// Fixed job tear-down + reschedule + relaunch latency (s).
    pub restart_latency: f64,
    /// System MTTF assumed when choosing the checkpoint interval (s).
    pub mttf: f64,
}

impl Default for GlobalCrModel {
    fn default() -> Self {
        GlobalCrModel {
            // Shared PFS of the paper era: ~1 GB/s aggregate for a job slice.
            pfs_bandwidth: 1.0e9,
            restart_latency: 30.0,
            mttf: 24.0 * 3600.0,
        }
    }
}

impl GlobalCrModel {
    /// Seconds to write one global checkpoint of `bytes` total state.
    pub fn checkpoint_cost(&self, bytes: usize) -> f64 {
        bytes as f64 / self.pfs_bandwidth
    }

    /// Young's optimal checkpoint interval: sqrt(2 * C * MTTF).
    pub fn young_interval(&self, bytes: usize) -> f64 {
        (2.0 * self.checkpoint_cost(bytes) * self.mttf).sqrt()
    }

    /// Expected waste per failure: relaunch + re-read + half an interval of
    /// recomputation (uniform failure position assumption).
    pub fn waste_per_failure(&self, bytes: usize) -> f64 {
        self.restart_latency + self.checkpoint_cost(bytes) + 0.5 * self.young_interval(bytes)
    }

    /// Steady-state overhead fraction of global C/R during failure-free
    /// operation (checkpoint time per interval).
    pub fn steady_overhead_fraction(&self, bytes: usize) -> f64 {
        let c = self.checkpoint_cost(bytes);
        c / (c + self.young_interval(bytes))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn young_interval_matches_formula() {
        let m = GlobalCrModel { pfs_bandwidth: 1e9, restart_latency: 10.0, mttf: 3600.0 };
        let bytes = 2_000_000_000; // 2 GB -> C = 2 s
        let c = m.checkpoint_cost(bytes);
        assert!((c - 2.0).abs() < 1e-12);
        assert!((m.young_interval(bytes) - (2.0 * 2.0 * 3600.0f64).sqrt()).abs() < 1e-9);
    }

    #[test]
    fn waste_grows_with_state_size() {
        let m = GlobalCrModel::default();
        assert!(m.waste_per_failure(10_000_000_000) > m.waste_per_failure(1_000_000_000));
    }

    #[test]
    fn steady_overhead_below_one() {
        let m = GlobalCrModel::default();
        let f = m.steady_overhead_fraction(100_000_000_000);
        assert!(f > 0.0 && f < 1.0);
    }
}
