//! Fleet-level recovery arbitration (DESIGN.md §16).
//!
//! When a run belongs to a multi-tenant fleet ([`crate::coordinator::fleet`]),
//! every failure event stops being a private policy evaluation and becomes a
//! **[`RecoveryPlan`]** submitted to the shared arbiter: the action the
//! job's own policy would take with its local view, a cost estimate from the
//! same model the `cost-min` policy prices with, the job's priority, and
//! dependencies on other jobs' in-flight recoveries.  The arbiter ranks
//! plans deterministically and answers with the action the *fleet* can
//! afford:
//!
//! * a substitution is granted only if the shared [`LeaseLedger`] has a free
//!   slot at the event's canonical time — capacity already leased to
//!   earlier-arbitrated (higher-ranked) jobs **preempts** the request and
//!   forces the loser into degraded shrink, recorded as a `fleet-preempt`
//!   [`crate::metrics::DecisionRecord`] reason plus an
//!   [`ArbitrationRecord`];
//! * recoveries beyond the machine's recovery `bandwidth` are **deferred**:
//!   the event waits (in virtual time, charged to the Recovery phase) until
//!   enough earlier windows drain, and the plan records those windows as
//!   its dependencies;
//! * a job tripping its [`Breaker`] is **quarantined**: its leases are
//!   released back to the pool and the event escalates to one recorded
//!   global restart instead of burning more shared capacity.
//!
//! Consistency contract (the fleet extension of [`super::policy`]'s rules):
//! every input is either static fleet configuration, the liveness registry
//! (canonical event time = max death time over the failed set — never a
//! caller's clock, which is skewed by detection latency), or ledger state
//! produced by earlier deterministic arbitrations.  Answers are cached per
//! `(job, failed-set)` so every survivor — and every fence retry — of one
//! event observes the identical verdict, and the whole fleet digest is
//! bit-identical across `--engine threads|events` and across reruns.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

use crate::backend::costs;
use crate::netsim::{ComputeModel, NetParams};
use crate::recovery::breaker::{Breaker, BreakerState, BreakerVerdict};
use crate::recovery::global_restart::GlobalCrModel;
use crate::recovery::policy::{self, Decision, PolicyInputs, PolicyKind};
use crate::spares::{LeaseLedger, PoolStatus};

/// One job's requested recovery for one failure event, as submitted to the
/// arbiter (the ClusterSentry-shaped plan: action, cost, priority,
/// dependencies).
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryPlan {
    /// Arbiter-assigned id (submission order).
    pub id: usize,
    /// Index of the submitting job in the fleet spec.
    pub job: usize,
    /// Canonical event time (max registry death time over `failed`).
    pub at: f64,
    /// Failed world ranks of the event (job-local numbering).
    pub failed: Vec<usize>,
    /// What the job's own policy wanted with its local pool view.
    pub requested: Decision,
    /// What the arbiter granted with the fleet pool view.
    pub granted: Decision,
    /// Modeled seconds the granted recovery will take.
    pub est_cost: f64,
    /// Submitting job's priority (1 lowest .. 5 highest).
    pub priority: u8,
    /// Ids of other jobs' in-flight recovery plans this one waited on.
    pub dependencies: Vec<usize>,
}

/// The arbiter's ruling on one plan, for the fleet report.
#[derive(Debug, Clone, PartialEq)]
pub struct ArbitrationRecord {
    /// Ruling order (== plan id).
    pub seq: usize,
    pub job: usize,
    pub job_name: String,
    pub priority: u8,
    /// Canonical event time.
    pub at: f64,
    pub failed: Vec<usize>,
    /// Requested / granted action names.
    pub requested: &'static str,
    pub granted: &'static str,
    /// `granted`, `preempted`, `deferred` or `quarantine`.
    pub verdict: &'static str,
    /// Name of the lease-holding job blamed for a preemption.
    pub preempted_by: Option<String>,
    /// Fleet pool snapshot at the event time, before any new grant.
    pub warm_free: usize,
    pub cold_free: usize,
    /// Virtual seconds the recovery waited on the bandwidth gate.
    pub defer_secs: f64,
    /// Plan ids of the in-flight recoveries waited on.
    pub deps: Vec<usize>,
    /// Breaker state after the event.
    pub breaker: &'static str,
    /// Modeled cost of the granted action.
    pub est_cost: f64,
}

/// The answer handed back into the job's recovery path.
#[derive(Debug, Clone, PartialEq)]
pub struct FleetVerdict {
    pub decision: Decision,
    pub reason: String,
    /// Extra Recovery-phase virtual time every survivor charges before the
    /// recovery proceeds (the bandwidth gate).
    pub defer_secs: f64,
}

/// An in-flight recovery window (for the bandwidth gate and dependencies).
#[derive(Debug, Clone)]
struct RecoveryWindow {
    plan: usize,
    job: usize,
    failed: Vec<usize>,
    t0: f64,
    t1: f64,
}

/// Shared fleet arbitration state: the lease ledger, per-job breakers, the
/// plan/ruling logs, and the per-event verdict cache.
#[derive(Debug)]
pub struct FleetState {
    pub ledger: LeaseLedger,
    /// Max concurrent machine-wide recoveries before deferral.
    pub bandwidth: usize,
    names: Vec<String>,
    prios: Vec<u8>,
    breakers: Vec<Breaker>,
    plans: Vec<RecoveryPlan>,
    records: Vec<ArbitrationRecord>,
    verdicts: BTreeMap<(usize, Vec<usize>), FleetVerdict>,
    /// Open leases per event, for rollback when a nested failure grows the
    /// failed set and the event re-arbitrates on the union.
    event_leases: Vec<(usize, Vec<usize>, usize)>,
    windows: Vec<RecoveryWindow>,
}

impl FleetState {
    /// `jobs` is `(name, priority)` per job, in fleet-spec order.
    pub fn new(
        warm: usize,
        cold: usize,
        bandwidth: usize,
        breaker_k: usize,
        breaker_window: f64,
        jobs: &[(String, u8)],
    ) -> FleetState {
        FleetState {
            ledger: LeaseLedger::new(warm, cold),
            bandwidth: bandwidth.max(1),
            names: jobs.iter().map(|(n, _)| n.clone()).collect(),
            prios: jobs.iter().map(|&(_, p)| p).collect(),
            breakers: jobs.iter().map(|_| Breaker::new(breaker_k, breaker_window)).collect(),
            plans: Vec::new(),
            records: Vec::new(),
            verdicts: BTreeMap::new(),
            event_leases: Vec::new(),
            windows: Vec::new(),
        }
    }

    /// Close `job`'s open leases (finish or quarantine) at `t_end`.
    pub fn close_job(&mut self, job: usize, t_end: f64) {
        self.ledger.close_job(job, t_end);
    }

    pub fn plans(&self) -> &[RecoveryPlan] {
        &self.plans
    }

    pub fn records(&self) -> &[ArbitrationRecord] {
        &self.records
    }

    /// Breaker trip count for one job.
    pub fn trips(&self, job: usize) -> usize {
        self.breakers[job].trips()
    }

    pub fn breaker_state(&self, job: usize) -> BreakerState {
        self.breakers[job].state()
    }

    /// Rulings that denied a substitution because another job held the
    /// capacity.
    pub fn preemptions(&self) -> usize {
        self.records.iter().filter(|r| r.verdict == "preempted").count()
    }

    /// Rulings whose verdict was a deferral on the recovery-bandwidth gate.
    /// A `preempted` ruling may also have waited (`defer_secs > 0`), but it
    /// is counted once, under `preemptions()` — the two categories are
    /// disjoint so `contention_ratio` stays a true fraction of rulings.
    pub fn deferrals(&self) -> usize {
        self.records.iter().filter(|r| r.verdict == "deferred").count()
    }

    pub fn quarantines(&self) -> usize {
        self.records.iter().filter(|r| r.verdict == "quarantine").count()
    }

    /// Drop grants belonging to abandoned attempts of the same event: a
    /// nested failure grew the failed set, so any lease opened for a strict
    /// subset of it (same job) never materialized.  Leases already closed
    /// (job finish, quarantine) are history and survive the rollback — the
    /// ledger's `rescind` only removes open leases.
    fn rollback_subsumed(&mut self, job: usize, failed: &[usize]) {
        let subsumed = |old: &[usize]| {
            old.len() < failed.len() && old.iter().all(|r| failed.contains(r))
        };
        let mut dropped: Vec<usize> = Vec::new();
        self.event_leases.retain(|(j, old, lease)| {
            if *j == job && subsumed(old) {
                dropped.push(*lease);
                false
            } else {
                true
            }
        });
        for id in dropped {
            self.ledger.rescind(id);
        }
        self.windows.retain(|w| !(w.job == job && subsumed(&w.failed)));
    }
}

/// One job's handle on the shared arbiter, carried inside its
/// [`crate::config::RunConfig`] by the fleet driver.
#[derive(Debug, Clone)]
pub struct FleetSeat {
    /// Index of this job in the fleet spec.
    pub job: usize,
    /// Job name (fleet-unique).
    pub name: String,
    /// Priority, 1 (lowest) ..= 5 (highest).
    pub priority: u8,
    pub state: Arc<Mutex<FleetState>>,
}

/// Arbitrate one failure event for the seated job.  Called by
/// [`super::choose_recovery`] in place of the private policy evaluation;
/// idempotent per `(job, failed-set)` so every survivor and every fence
/// retry of the event observes the identical verdict.
pub fn arbitrate(
    seat: &FleetSeat,
    kind: PolicyKind,
    failed: &[usize],
    inputs: &PolicyInputs,
    host: &ComputeModel,
    net: &NetParams,
    t_event: f64,
) -> FleetVerdict {
    let mut failed_sorted = failed.to_vec();
    failed_sorted.sort_unstable();
    let key = (seat.job, failed_sorted.clone());
    let mut st = seat.state.lock().unwrap();
    if let Some(v) = st.verdicts.get(&key) {
        return v.clone();
    }
    st.rollback_subsumed(seat.job, &failed_sorted);
    let pool_before = st.ledger.status_at(t_event);
    let seq = st.plans.len();

    // Breaker first: a quarantined event never competes for shared capacity.
    if st.breakers[seat.job].on_recovery(t_event) == BreakerVerdict::Trip {
        let (k, w) = (st.breakers[seat.job].k, st.breakers[seat.job].window);
        st.ledger.close_job(seat.job, t_event);
        let reason = format!(
            "breaker-open: job {} hit {k} recoveries inside a {w:.3}s window; \
             quarantined — leases released, one global restart on a fresh node set",
            seat.name
        );
        let breaker = st.breakers[seat.job].state().name();
        st.plans.push(RecoveryPlan {
            id: seq,
            job: seat.job,
            at: t_event,
            failed: failed_sorted.clone(),
            requested: Decision::GlobalRestart,
            granted: Decision::GlobalRestart,
            est_cost: 0.0,
            priority: seat.priority,
            dependencies: Vec::new(),
        });
        st.records.push(ArbitrationRecord {
            seq,
            job: seat.job,
            job_name: seat.name.clone(),
            priority: seat.priority,
            at: t_event,
            failed: failed_sorted,
            requested: Decision::GlobalRestart.name(),
            granted: Decision::GlobalRestart.name(),
            verdict: "quarantine",
            preempted_by: None,
            warm_free: pool_before.warm_free,
            cold_free: pool_before.cold_free,
            defer_secs: 0.0,
            deps: Vec::new(),
            breaker,
            est_cost: 0.0,
        });
        let v = FleetVerdict { decision: Decision::GlobalRestart, reason, defer_secs: 0.0 };
        st.verdicts.insert(key, v.clone());
        return v;
    }

    // What the job's own policy wants with its local pool view...
    let (requested, _) = policy::decide(kind, inputs, host, net);
    // ...versus what the fleet can afford: clamp the pool to the shared
    // ledger's free capacity at the event instant.
    let mut fleet_inputs = *inputs;
    fleet_inputs.pool = PoolStatus {
        warm_free: inputs.pool.warm_free.min(pool_before.warm_free),
        cold_free: inputs.pool.cold_free.min(pool_before.cold_free),
    };
    let (granted, why) = policy::decide(kind, &fleet_inputs, host, net);

    let est = costs::recovery_estimates(host, net, &GlobalCrModel::default(), &inputs.cost);
    let est_cost = match granted {
        Decision::Substitute => est.substitute,
        Decision::SubstituteCold => est.substitute_cold,
        Decision::Shrink => est.shrink,
        Decision::GlobalRestart => est.global_restart,
    };

    // Bandwidth gate: recoveries of *other* jobs pending or still in flight
    // at the event instant (`t1 > t_event`).  A window already deferred past
    // the event (`t0 > t_event`) still occupies a future bandwidth slot, so
    // it must gate this event too — otherwise two deferred recoveries could
    // be scheduled into the same interval and exceed the budget.  Beyond the
    // budget, this one waits for the earliest windows to drain; all gating
    // windows become dependencies.
    let mut overlapping: Vec<(usize, f64)> = st
        .windows
        .iter()
        .filter(|wnd| wnd.job != seat.job && wnd.t1 > t_event)
        .map(|wnd| (wnd.plan, wnd.t1))
        .collect();
    overlapping.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap().then(a.0.cmp(&b.0)));
    let deps: Vec<usize> = overlapping.iter().map(|&(p, _)| p).collect();
    let defer_secs = if overlapping.len() >= st.bandwidth {
        let gate = overlapping[overlapping.len() - st.bandwidth].1;
        (gate - t_event).max(0.0)
    } else {
        0.0
    };

    // Classify the ruling and assemble the reason every survivor records.
    let demoted_sub = matches!(requested, Decision::Substitute | Decision::SubstituteCold)
        && granted != requested;
    let (verdict, preempted_by, reason) = if demoted_sub {
        let holders = st.ledger.warm_holders_at(t_event);
        let blame = holders
            .iter()
            .filter(|&&(j, _)| j != seat.job)
            .max_by_key(|&&(j, _)| (st.prios[j], std::cmp::Reverse(j)))
            .map(|&(j, _)| (st.names[j].clone(), st.prios[j]));
        let who = match &blame {
            Some((name, prio)) => format!("job {name} (prio {prio})"),
            None => "the shared pool".to_string(),
        };
        let reason = format!(
            "fleet-preempt: {} denied (warm {}/{} cold {}/{} leased to {who}); {why}",
            requested.name(),
            pool_before.warm_free,
            st.ledger.warm_total,
            pool_before.cold_free,
            st.ledger.cold_total,
        );
        ("preempted", blame.map(|(n, _)| n), reason)
    } else if defer_secs > 0.0 {
        (
            "deferred",
            None,
            format!(
                "fleet-defer: {} in-flight recoveries >= bandwidth {}; waited {defer_secs:.6}s; {why}",
                overlapping.len(),
                st.bandwidth
            ),
        )
    } else {
        ("granted", None, format!("fleet: {why}"))
    };

    // Grant the lease for a substitution out of the shared pool.
    match granted {
        Decision::Substitute => {
            let id = st.ledger.grant(seat.job, true, inputs.n_failed, t_event);
            st.event_leases.push((seat.job, failed_sorted.clone(), id));
        }
        Decision::SubstituteCold => {
            let id = st.ledger.grant(seat.job, false, inputs.n_failed, t_event);
            st.event_leases.push((seat.job, failed_sorted.clone(), id));
        }
        Decision::Shrink | Decision::GlobalRestart => {}
    }

    let t0 = t_event + defer_secs;
    st.windows.push(RecoveryWindow {
        plan: seq,
        job: seat.job,
        failed: failed_sorted.clone(),
        t0,
        t1: t0 + est_cost,
    });
    let breaker = st.breakers[seat.job].state().name();
    st.plans.push(RecoveryPlan {
        id: seq,
        job: seat.job,
        at: t_event,
        failed: failed_sorted.clone(),
        requested,
        granted,
        est_cost,
        priority: seat.priority,
        dependencies: deps.clone(),
    });
    st.records.push(ArbitrationRecord {
        seq,
        job: seat.job,
        job_name: seat.name.clone(),
        priority: seat.priority,
        at: t_event,
        failed: failed_sorted,
        requested: requested.name(),
        granted: granted.name(),
        verdict,
        preempted_by,
        warm_free: pool_before.warm_free,
        cold_free: pool_before.cold_free,
        defer_secs,
        deps,
        breaker,
        est_cost,
    });
    let v = FleetVerdict { decision: granted, reason, defer_secs };
    st.verdicts.insert(key, v.clone());
    v
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::costs::{ParityShape, RecoveryCostInputs};

    fn state(warm: usize, bandwidth: usize, k: usize, w: f64) -> Arc<Mutex<FleetState>> {
        Arc::new(Mutex::new(FleetState::new(
            warm,
            0,
            bandwidth,
            k,
            w,
            &[("alpha".to_string(), 5), ("beta".to_string(), 1)],
        )))
    }

    fn seat(state: &Arc<Mutex<FleetState>>, job: usize, name: &str, prio: u8) -> FleetSeat {
        FleetSeat { job, name: name.to_string(), priority: prio, state: state.clone() }
    }

    fn inputs(warm_local: usize) -> PolicyInputs {
        PolicyInputs {
            n_failed: 1,
            survivors: 7,
            pool: PoolStatus { warm_free: warm_local, cold_free: 0 },
            cost: RecoveryCostInputs {
                rows_per_rank: 256,
                basis_vecs: 41,
                n_failed: 1,
                survivors: 7,
                buddy_k: 1,
                horizon_iters: 50,
                m_inner: 10,
                parity: ParityShape::Mirror,
            },
            failures_so_far: 1,
            event_seq: 0,
        }
    }

    #[test]
    fn last_warm_slot_preempts_the_later_arbitrated_job() {
        let st = state(1, 4, 10, 100.0);
        let host = ComputeModel::default();
        let net = NetParams::default();
        let a = seat(&st, 0, "alpha", 5);
        let b = seat(&st, 1, "beta", 1);
        let va = arbitrate(&a, PolicyKind::SparesFirst, &[3], &inputs(1), &host, &net, 1.0);
        assert_eq!(va.decision, Decision::Substitute);
        // Beta's event overlaps alpha's open lease: denied, degraded shrink.
        let vb = arbitrate(&b, PolicyKind::SparesFirst, &[2], &inputs(1), &host, &net, 1.5);
        assert_eq!(vb.decision, Decision::Shrink);
        assert!(vb.reason.contains("fleet-preempt"), "{}", vb.reason);
        assert!(vb.reason.contains("alpha"), "{}", vb.reason);
        let st = st.lock().unwrap();
        assert_eq!(st.preemptions(), 1);
        assert_eq!(st.records()[1].verdict, "preempted");
        assert_eq!(st.records()[1].preempted_by.as_deref(), Some("alpha"));
    }

    #[test]
    fn verdicts_are_cached_per_event_and_rescinded_on_union_retry() {
        let st = state(2, 4, 10, 100.0);
        let host = ComputeModel::default();
        let net = NetParams::default();
        let a = seat(&st, 0, "alpha", 5);
        let v1 = arbitrate(&a, PolicyKind::SparesFirst, &[3], &inputs(2), &host, &net, 1.0);
        let v2 = arbitrate(&a, PolicyKind::SparesFirst, &[3], &inputs(2), &host, &net, 1.0);
        assert_eq!(v1, v2, "survivors and retries observe one verdict");
        assert_eq!(st.lock().unwrap().records().len(), 1);
        // Nested failure grows the set: the subset grant is rolled back and
        // the union re-arbitrated as a fresh plan.
        let mut inp = inputs(2);
        inp.n_failed = 2;
        inp.cost.n_failed = 2;
        let v3 = arbitrate(&a, PolicyKind::SparesFirst, &[3, 5], &inp, &host, &net, 2.0);
        assert_eq!(v3.decision, Decision::Substitute);
        let st = st.lock().unwrap();
        assert_eq!(st.records().len(), 2);
        // Only the union lease survives: 2 slots of 2 leased.
        assert_eq!(st.ledger.warm_free_at(2.0), 0);
        assert_eq!(st.ledger.leases().len(), 1);
    }

    #[test]
    fn breaker_trip_quarantines_and_releases_leases() {
        let st = state(2, 4, 2, 1000.0);
        let host = ComputeModel::default();
        let net = NetParams::default();
        let a = seat(&st, 0, "alpha", 5);
        let v1 = arbitrate(&a, PolicyKind::SparesFirst, &[3], &inputs(2), &host, &net, 1.0);
        assert_eq!(v1.decision, Decision::Substitute);
        let v2 = arbitrate(&a, PolicyKind::SparesFirst, &[5], &inputs(2), &host, &net, 2.0);
        assert_eq!(v2.decision, Decision::GlobalRestart);
        assert!(v2.reason.contains("breaker-open"), "{}", v2.reason);
        let st = st.lock().unwrap();
        assert_eq!(st.trips(0), 1);
        assert_eq!(st.quarantines(), 1);
        assert_eq!(st.breaker_state(0), BreakerState::HalfOpen);
        // The lease from the first event was released at the trip instant.
        assert_eq!(st.ledger.warm_free_at(2.0), 2);
    }

    #[test]
    fn bandwidth_gate_defers_and_records_dependencies() {
        let st = state(8, 1, 10, 1000.0);
        let host = ComputeModel::default();
        let net = NetParams::default();
        let a = seat(&st, 0, "alpha", 5);
        let b = seat(&st, 1, "beta", 1);
        let mut inp = inputs(8);
        inp.pool.warm_free = 8;
        let _ = arbitrate(&a, PolicyKind::SparesFirst, &[3], &inp, &host, &net, 1.0);
        let est = st.lock().unwrap().plans()[0].est_cost;
        assert!(est > 0.0);
        // Beta's event lands inside alpha's recovery window.
        let vb = arbitrate(&b, PolicyKind::SparesFirst, &[2], &inp, &host, &net, 1.0 + est / 2.0);
        assert!(vb.defer_secs > 0.0, "bandwidth 1 must defer the overlap");
        assert!(vb.reason.contains("fleet-defer"), "{}", vb.reason);
        let st = st.lock().unwrap();
        assert_eq!(st.deferrals(), 1);
        assert_eq!(st.plans()[1].dependencies, vec![0]);
    }

    #[test]
    fn pending_deferred_windows_gate_later_events_too() {
        // Three jobs, bandwidth 1: gamma's event lands inside alpha's active
        // window while beta's recovery is already deferred behind it.  The
        // gate must see beta's *pending* window (t0 in the future) and push
        // gamma behind it, not double-book beta's interval.
        let st = Arc::new(Mutex::new(FleetState::new(
            8,
            0,
            1,
            10,
            1000.0,
            &[
                ("alpha".to_string(), 5),
                ("beta".to_string(), 3),
                ("gamma".to_string(), 1),
            ],
        )));
        let host = ComputeModel::default();
        let net = NetParams::default();
        let a = seat(&st, 0, "alpha", 5);
        let b = seat(&st, 1, "beta", 3);
        let g = seat(&st, 2, "gamma", 1);
        let mut inp = inputs(8);
        inp.pool.warm_free = 8;
        let _ = arbitrate(&a, PolicyKind::SparesFirst, &[3], &inp, &host, &net, 1.0);
        let est = st.lock().unwrap().plans()[0].est_cost;
        let vb = arbitrate(&b, PolicyKind::SparesFirst, &[2], &inp, &host, &net, 1.0 + est * 0.25);
        assert!(vb.defer_secs > 0.0);
        let vg = arbitrate(&g, PolicyKind::SparesFirst, &[4], &inp, &host, &net, 1.0 + est * 0.5);
        let st = st.lock().unwrap();
        let beta_end = st.windows[1].t1;
        let gamma_start = 1.0 + est * 0.5 + vg.defer_secs;
        assert!(
            gamma_start >= beta_end - 1e-9,
            "gamma starts at {gamma_start} inside beta's pending window ending {beta_end}"
        );
        assert_eq!(st.plans()[2].dependencies, vec![0, 1], "both windows are dependencies");
    }

    #[test]
    fn preempted_rulings_do_not_double_count_as_deferrals() {
        // Warm pool of 1, bandwidth 1: beta's substitute request is both
        // preempted (alpha holds the last slot) and gated behind alpha's
        // in-flight window.  It must be counted once, as a preemption.
        let st = state(1, 1, 10, 1000.0);
        let host = ComputeModel::default();
        let net = NetParams::default();
        let a = seat(&st, 0, "alpha", 5);
        let b = seat(&st, 1, "beta", 1);
        let _ = arbitrate(&a, PolicyKind::SparesFirst, &[3], &inputs(1), &host, &net, 1.0);
        let est = st.lock().unwrap().plans()[0].est_cost;
        let vb = arbitrate(&b, PolicyKind::SparesFirst, &[2], &inputs(1), &host, &net, 1.0 + est / 2.0);
        assert_eq!(vb.decision, Decision::Shrink);
        assert!(vb.defer_secs > 0.0, "the shrink still waits on the bandwidth gate");
        let st = st.lock().unwrap();
        assert_eq!(st.preemptions(), 1);
        assert_eq!(st.deferrals(), 0, "one ruling, one category");
        assert_eq!(st.records()[1].verdict, "preempted");
    }
}
