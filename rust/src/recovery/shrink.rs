//! Shrink recovery: graceful degradation with survivors (paper §IV-B).
//!
//! After `MPI_Comm_shrink`, the global row space is re-balanced over the
//! P-1 survivors; matrix rows, rhs and the checkpointed solution vector are
//! redistributed using local data, survivor checkpoints and buddy copies of
//! the failed rank's blocks; finally every in-memory checkpoint is
//! re-established under the new layout ("this adds on to the cost of state
//! recovery").

use crate::checkpoint::{agree_restore_version, obj, CkptStore, ObjId, Version};
use crate::ckptstore::{self, CkptCfg};
use crate::failure::ProtoPhase;
use crate::metrics::Phase;
use crate::netsim::ComputeModel;
use crate::problem::{MatrixRows, Partition, K};
use crate::recovery::plan::{my_transfers, transfer_segments_scheme, Segment};
use crate::simmpi::{tags, Blob, Comm, Ctx, MpiResult, WorldRank};
use crate::solver::state::SolverState;

/// Objects that move during redistribution (BASIS rows are matrix-shaped:
/// several distributed vectors concatenated).
const REDIST_OBJS: [ObjId; 4] = [obj::MAT, obj::RHS, obj::X, obj::BASIS];

/// Serve one segment of `id` from this rank's store (its own data or a buddy
/// copy of the owner's), at the newest version <= `v`.
fn slice_for(
    store: &CkptStore,
    me: WorldRank,
    seg: &Segment,
    id: ObjId,
    v: Version,
    old_part: &Partition,
    owner_cr: usize,
) -> Blob {
    let blob = if seg.owner_wr == me {
        store.get_local_at_most(id, v).expect("own checkpoint missing").1
    } else {
        store
            .get_remote_at_most(seg.owner_wr, id, v)
            .expect("buddy checkpoint missing")
            .1
    };
    let owner_range = old_part.range(owner_cr);
    let a = seg.rows.start - owner_range.start;
    let b = seg.rows.end - owner_range.start;
    match id {
        obj::MAT => MatrixRows::from_blob(blob).slice(seg.rows.start, seg.rows.end).to_blob(),
        obj::BASIS => {
            // [n_vectors x owner_rows] row-major; slice every vector.
            let nvec = (blob.i[0] + blob.i[1]) as usize;
            let or = owner_range.len();
            debug_assert_eq!(blob.f.len(), nvec * or);
            let mut f = Vec::with_capacity(nvec * (b - a));
            for j in 0..nvec {
                f.extend_from_slice(&blob.f[j * or + a..j * or + b]);
            }
            Blob { f: f.into(), i: blob.i.clone(), wire: None }
        }
        // Contiguous single-vector objects ship as zero-copy views of the
        // stored checkpoint (DESIGN.md §11) — no `to_vec` split.
        _ => Blob { f: blob.f.slice(a..b), i: Default::default(), wire: None },
    }
}

fn xfer_tag(id: ObjId, seg_idx: usize) -> u32 {
    tags::RECOVER_BASE + id * 16384 + seg_idx as u32
}

/// Execute shrink recovery.  `old_comm` is the communicator the failure
/// happened in; `new_comm` the shrunken one.  On return, `state` is rolled
/// back to the last globally-committed checkpoint, redistributed over the
/// survivors, and all checkpoints are re-established.
pub async fn recover(
    ctx: &mut Ctx,
    old_comm: &Comm,
    new_comm: &mut Comm,
    state: &mut SolverState,
    store: &mut CkptStore,
    ckpt: &CkptCfg,
    host: &ComputeModel,
) -> MpiResult<()> {
    let prev = ctx.set_phase(Phase::Recovery);
    let result = recover_inner(ctx, old_comm, new_comm, state, store, ckpt, host).await;
    ctx.set_phase(prev);
    result
}

async fn recover_inner(
    ctx: &mut Ctx,
    old_comm: &Comm,
    new_comm: &mut Comm,
    state: &mut SolverState,
    store: &mut CkptStore,
    ckpt: &CkptCfg,
    host: &ComputeModel,
) -> MpiResult<()> {
    let me = ctx.rank;
    // 1. Agree on the restore version (newest globally committed).
    let v = agree_restore_version(ctx, new_comm, store).await?;

    // 1b. Recovery reader: materialize the failed ranks' objects on their
    //     designated servers (parity reconstruction under xor; a no-op for
    //     mirror, whose buddy copies already sit in the store).
    ckptstore::reconstruct_failed(
        ctx,
        new_comm,
        store,
        ckpt,
        &old_comm.members,
        v,
        &REDIST_OBJS,
    )
    .await?;

    // 2. Roll back iteration + least-squares state from my own checkpoint.
    let iter_blob = store
        .get_local_at_most(obj::ITER, v)
        .expect("ITER checkpoint missing")
        .1
        .clone();
    state.restore_iter(&iter_blob);

    // 3. Plan the repartition over survivors.
    let old_part = state.part.clone();
    let new_part = Partition::balanced(state.grid.n(), new_comm.size());
    let world = ctx.world.clone();
    let alive = move |r: WorldRank| world.is_alive(r);
    let segs = transfer_segments_scheme(
        &old_part,
        &old_comm.members,
        &new_part,
        &new_comm.members,
        &alive,
        &ckpt.scheme,
        crate::checkpoint::effective_stride(&ctx.world.net.params, old_comm.size()),
    );
    let mine = my_transfers(&segs, me);

    // Map world rank -> old comm rank for owner lookup.
    let owner_cr = |wr: WorldRank| {
        old_comm
            .rank_of_world(wr)
            .expect("owner must be an old member")
    };

    // Fault point: a survivor dying as row transfers begin.  The transfers
    // below only read the checkpoint store and write `state`, which the
    // fenced driver rolls back on abandon, so an interrupted
    // redistribution re-plans cleanly from the event-entry partition.
    ctx.phase_point(ProtoPhase::Redistribute)?;
    let (n_out, at) = (mine.outgoing.len() as i64, ctx.clock);
    ctx.trace_push(|| crate::trace::TraceEvent::Mark {
        label: "redistribute-plan",
        arg: n_out,
        t: at,
    });

    // 4. Ship my outgoing segments (all objects), then receive incoming.
    for id in REDIST_OBJS {
        for seg in &mine.outgoing {
            let blob = slice_for(store, me, seg, id, v, &old_part, owner_cr(seg.owner_wr))
                .scaled(ctx.world.net.params.data_scale);
            let dest_cr = new_comm
                .rank_of_world(seg.dest_wr)
                .expect("destination must be a survivor");
            new_comm.send(ctx, dest_cr, xfer_tag(id, seg.idx), blob)?;
        }
    }

    // Assemble per object: (global start, blob) pieces sorted by row start.
    let my_range = new_part.range(new_comm.rank);
    let mut pieces: Vec<(ObjId, usize, Blob)> = Vec::new();
    for id in REDIST_OBJS {
        for seg in &mine.local {
            pieces.push((
                id,
                seg.rows.start,
                slice_for(store, me, seg, id, v, &old_part, owner_cr(seg.owner_wr)),
            ));
        }
        for seg in &mine.incoming {
            let src_cr = new_comm
                .rank_of_world(seg.server_wr)
                .expect("server must be a survivor");
            let blob = new_comm.recv(ctx, src_cr, xfer_tag(id, seg.idx)).await?;
            pieces.push((id, seg.rows.start, blob));
        }
    }

    // 5. Rebuild state under the new partition.
    let assemble_f64 = |id: ObjId, pieces: &[(ObjId, usize, Blob)]| -> Vec<f64> {
        let mut parts: Vec<(usize, &Blob)> = pieces
            .iter()
            .filter(|(pid, _, _)| *pid == id)
            .map(|(_, s, b)| (*s, b))
            .collect();
        parts.sort_by_key(|(s, _)| *s);
        let mut out = Vec::with_capacity(my_range.len());
        for (_, b) in parts {
            out.extend_from_slice(&b.f);
        }
        assert_eq!(out.len(), my_range.len(), "obj {id} coverage mismatch");
        out
    };
    let mut mats: Vec<(usize, MatrixRows)> = pieces
        .iter()
        .filter(|(pid, _, _)| *pid == obj::MAT)
        .map(|(_, s, b)| (*s, MatrixRows::from_blob(b)))
        .collect();
    mats.sort_by_key(|(s, _)| *s);
    let mat = MatrixRows::concat(mats.into_iter().map(|(_, m)| m).collect());
    assert_eq!(mat.start, my_range.start);
    assert_eq!(mat.rows, my_range.len());

    state.b = assemble_f64(obj::RHS, &pieces);
    state.x = assemble_f64(obj::X, &pieces);
    state.mat = mat;
    state.part = new_part;
    state.relocalize(new_comm.rank);

    // Reassemble the Krylov bases under the new distribution: each basis
    // vector is a distributed vector, redistributed like x.
    {
        let mut parts: Vec<(usize, &Blob)> = pieces
            .iter()
            .filter(|(pid, _, _)| *pid == obj::BASIS)
            .map(|(_, s, b)| (*s, b))
            .collect();
        parts.sort_by_key(|(s, _)| *s);
        let nv = parts.first().map(|(_, b)| b.i.clone()).unwrap_or_else(|| vec![0, 0].into());
        let nvec = (nv[0] + nv[1]) as usize;
        let rnew = my_range.len();
        let mut f = vec![0.0; nvec * rnew];
        let mut col = 0usize;
        for (_, b) in &parts {
            debug_assert_eq!(b.i, nv, "inconsistent basis shape across segments");
            let seg_len = if nvec == 0 { 0 } else { b.f.len() / nvec };
            for j in 0..nvec {
                f[j * rnew + col..j * rnew + col + seg_len]
                    .copy_from_slice(&b.f[j * seg_len..(j + 1) * seg_len]);
            }
            col += seg_len;
        }
        debug_assert!(nvec == 0 || col == rnew, "basis coverage mismatch");
        state.restore_basis(&Blob { f: f.into(), i: nv, wire: None });
    }

    // Redistribution/localization CPU cost: touch every local slot once.
    ctx.advance(host.cost((state.rows() * K) as f64, (24 * state.rows() * K) as f64));

    // 6. Re-establish every checkpoint under the new layout (charged to
    //    Recovery — see the commit protocol).  Copies held for the dead are
    //    NOT dropped eagerly: if this establishment is torn by a nested
    //    failure, the retry must still be able to serve the dead ranks'
    //    blocks from them.  The committed-floor GC purges them one commit
    //    after the establishment proves globally visible
    //    ([`CkptStore::gc_committed`]).
    state.establish_checkpoints(ctx, new_comm, store, v + 1, ckpt).await?;
    Ok(())
}
