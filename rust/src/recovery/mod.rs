//! In-situ recovery (the paper's contribution): the *shrink* and
//! *substitute* strategies, the per-event [`policy`] engine that chooses
//! between them at runtime, and the recovery driver that turns a ULFM
//! failure notification into a repaired communicator and restored state.
//!
//! The repair pipeline every strategy shares (paper §IV): `revoke` the
//! failed communicator so all survivors unblock, `shrink` to a pristine
//! survivor communicator, then run strategy-specific state recovery —
//! redistribution for [`shrink`], spare stitching plus checkpoint-store
//! state transfer for [`substitute`], and the analytic relaunch penalty of
//! [`global_restart`] for the last-resort path.  Which branch runs is a
//! per-failure [`policy::Decision`]; fixed-strategy runs are the
//! `fixed:<strategy>` special case (see DESIGN.md §3).  The decision point
//! sits *after* the ULFM shrink, so adaptive policies may use one
//! leader-broadcast over the survivor communicator (the dynamic capacity
//! horizon of [`policy::agreed_capacity_horizon`]) and still hand every
//! survivor the identical decision.
//!
//! Failed state is read back through the checkpoint subsystem's recovery
//! reader ([`crate::ckptstore::reconstruct_failed`]); when the loss is
//! *unrecoverable* under the configured redundancy scheme (two failures in
//! one `xor:<g>` parity group before a re-encode, or three in one
//! `rs2:<g>` group — see [`crate::ckptstore::assess_loss`]), the
//! `GlobalRestart` branch rebuilds the problem from scratch on the
//! survivors instead of wedging on a checkpoint that no longer exists.

pub mod breaker;
pub mod degraded;
pub mod fleet;
pub mod global_restart;
pub mod plan;
pub mod policy;
pub mod shrink;
pub mod substitute;

use crate::backend::costs::{ParityShape, RecoveryCostInputs};
use crate::checkpoint::{agree_restore_version, effective_stride, CkptStore};
use crate::ckptstore::{self, CkptCfg, LossCheck, Scheme};
use crate::config::RunConfig;
use crate::failure::ProtoPhase;
use crate::metrics::{DecisionRecord, Phase};
use crate::netsim::ComputeModel;
use crate::recovery::policy::PolicyInputs;
use crate::simmpi::ulfm::EpochFence;
use crate::simmpi::{ulfm, Comm, Ctx, MpiError, MpiResult};
use crate::solver::state::SolverState;

pub use policy::{Decision, PolicyKind};

/// Which failure-handling strategy a run is *configured* with.  Adaptive
/// runs re-decide per failure event via [`policy`]; `Strategy` remains the
/// per-run surface the paper's campaigns (Figures 4-6) are expressed in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Baseline: no checkpointing, no recovery (and no failures injected) —
    /// the paper's "no protection" normalization.
    NoProtection,
    /// Continue with the survivors; redistribute the workload (§IV-B).
    Shrink,
    /// Restore the original configuration with warm spares (§IV-A).
    Substitute,
    /// Substitute with *cold* spares: processes spawned at failure time
    /// (§IV-A: "processes spawned at runtime are referred to as cold
    /// spares... spawning processes at runtime has more overhead").  Same
    /// recovery protocol as warm substitution plus the spawn latency.
    SubstituteCold,
}

impl Strategy {
    pub fn parse(s: &str) -> Option<Strategy> {
        match s {
            "none" | "no-protection" => Some(Strategy::NoProtection),
            "shrink" => Some(Strategy::Shrink),
            "substitute" | "spare" => Some(Strategy::Substitute),
            "substitute-cold" | "cold" => Some(Strategy::SubstituteCold),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::NoProtection => "no-protection",
            Strategy::Shrink => "shrink",
            Strategy::Substitute => "substitute",
            Strategy::SubstituteCold => "substitute-cold",
        }
    }
}

/// Survivor-side failure handling with a fixed per-run strategy: the
/// original paper configuration, kept as a thin wrapper over
/// [`handle_failure_with`] (a fixed strategy is just a constant
/// [`Decision`]).
pub async fn handle_failure(
    ctx: &mut Ctx,
    comm: &mut Comm,
    state: &mut SolverState,
    store: &mut CkptStore,
    strategy: Strategy,
    ckpt: &CkptCfg,
    host: &ComputeModel,
) -> MpiResult<()> {
    debug_assert!(
        strategy != Strategy::NoProtection,
        "no-protection runs never inject failures"
    );
    handle_failure_with(
        ctx,
        comm,
        state,
        store,
        Decision::from_strategy(strategy),
        ckpt,
        host,
    )
    .await
}

/// Survivor-side failure handling for one pre-made per-event [`Decision`]:
/// the epoch-fenced driver with a constant decision.  Every survivor of the
/// same event must pass the same decision.
pub async fn handle_failure_with(
    ctx: &mut Ctx,
    comm: &mut Comm,
    state: &mut SolverState,
    store: &mut CkptStore,
    decision: Decision,
    ckpt: &CkptCfg,
    host: &ComputeModel,
) -> MpiResult<()> {
    handle_failure_fenced(ctx, comm, state, store, ckpt, host, DecideVia::Fixed(decision))
        .await
        .map(|_| ())
}

/// How the epoch-fenced driver obtains each attempt's [`Decision`].
///
/// An async decide *callback* would have to lend `ctx`, the shrunken
/// communicator and the solver state mutably across an await point — a
/// lending closure today's Rust cannot express — so the two concrete
/// deciders are enumerated instead: a constant decision (the
/// fixed-strategy wrappers and the protocol tests) or the per-event policy
/// evaluation over the run configuration (the coordinator's solve loop).
#[derive(Clone, Copy)]
pub enum DecideVia<'a> {
    /// Always this decision; no [`DecisionRecord`] is produced.
    Fixed(Decision),
    /// Evaluate the run's recovery policy per attempt; the successful
    /// attempt's [`DecisionRecord`] is returned for the caller to append
    /// to the decision log.
    Policy(&'a RunConfig),
}

/// Epoch-fenced restartable recovery driver (DESIGN.md §10): turn one
/// observed failure into a repaired communicator and restored state, and
/// keep doing so under **nested failures** — a rank dying mid-agreement,
/// mid-reconstruction, mid-commit or mid-spare-join while this event's
/// recovery is running.
///
/// Each *attempt* runs the full pipeline in a fresh epoch window handed out
/// by the [`EpochFence`]: fenced shrink ([`ulfm::shrink_fenced`]), the
/// `decide` evaluation (re-run per attempt — the policy engine re-decides
/// on the *union* failure set, so a spare grant whose joiner died rolls
/// back to a different spare or to shrink), then [`execute_decision`].  Any
/// error other than this rank's own death abandons the attempt: the driver
/// revokes the attempt's whole epoch window at every world rank
/// ([`ulfm::revoke_epoch_world`]) so *every* survivor and mid-join spare
/// blocked in the poisoned protocol returns `Revoked` and re-enters a fresh
/// agree, rolls the solver state back to the event-entry snapshot, and
/// retries with the enlarged failure set.
///
/// Returns the number of abandoned attempts (0 = clean first try) plus the
/// successful attempt's [`DecisionRecord`] (present iff `decide` was
/// [`DecideVia::Policy`]); abandoned attempts never produce records, their
/// cost shows up as `recovery_retries`.  Decisions must be identical on
/// every survivor of an attempt (same consistency contract as [`policy`]).
#[allow(clippy::too_many_arguments)]
pub async fn handle_failure_fenced(
    ctx: &mut Ctx,
    comm: &mut Comm,
    state: &mut SolverState,
    store: &mut CkptStore,
    ckpt: &CkptCfg,
    host: &ComputeModel,
    decide: DecideVia<'_>,
) -> MpiResult<(u64, Option<DecisionRecord>)> {
    // Consecutive abandons without any *new* death in the registry.  A
    // genuine nested failure always grows the shared dead set, and the
    // post-death revoke cascade settles within a couple of fence windows,
    // so a long no-new-death abandon streak means the failure is
    // deterministic (e.g. a fixed-substitute run whose spare pool is
    // exhausted — a configuration error, per the policy contract): give up
    // and propagate, preserving the pre-fence fail-loudly semantics
    // instead of livelocking on retries that cannot succeed.
    const STALL_LIMIT: u32 = 16;
    let entered_at = ctx.clock;
    ctx.trace_push(|| crate::trace::TraceEvent::RecoveryBegin { t: entered_at });
    // Survivors CANCEL (never drain) a torn async commit at recovery entry:
    // draining would block on receives from peers that are dead or already
    // cancelled themselves, and the fenced protocol below assumes nobody is
    // sitting in commit-plane collectives.  Cancellation is safe because the
    // committed floor only advances in seal_commit — stranded above-floor
    // puts are invisible to `*_at_most(floor)` readers and idempotent by
    // version if the commit is re-run later.
    crate::ckptstore::cancel_in_flight(store);
    let mut fence = EpochFence::new(comm);
    let snap = state.snapshot();
    let mut stalls = 0u32;
    let mut dead_seen = ctx.world.dead_set().len();
    loop {
        if !ctx.world.is_alive(ctx.rank) {
            return Err(ctx.die());
        }
        let result =
            attempt_recovery(ctx, comm, state, store, ckpt, host, &mut fence, decide).await;
        match result {
            Ok(record) => {
                let (done_at, attempts) = (ctx.clock, fence.retries());
                ctx.trace_push(|| crate::trace::TraceEvent::RecoveryEnd {
                    t: done_at,
                    attempts,
                });
                return Ok((fence.retries(), record));
            }
            Err(MpiError::Killed) => return Err(MpiError::Killed),
            Err(e) => {
                let dead_now = ctx.world.dead_set().len();
                if dead_now > dead_seen {
                    dead_seen = dead_now;
                    stalls = 0;
                } else {
                    stalls += 1;
                    if stalls > STALL_LIMIT {
                        return Err(e);
                    }
                }
                // A nested failure (or a peer's revocation) poisoned the
                // attempt: fence off its epoch window machine-wide, roll
                // the solver state back to the event-entry image, and
                // re-enter with whatever the registry says has failed now.
                let prev = ctx.set_phase(Phase::Reconfig);
                ulfm::revoke_epoch_world(ctx, fence.shrink_epoch());
                ulfm::revoke_epoch_world(ctx, fence.stitch_epoch());
                ctx.set_phase(prev);
                state.rollback(&snap);
                fence.abandon();
                ctx.recovery_retries += 1;
            }
        }
    }
}

/// One recovery attempt inside [`handle_failure_fenced`]'s loop.
#[allow(clippy::too_many_arguments)]
async fn attempt_recovery(
    ctx: &mut Ctx,
    comm: &mut Comm,
    state: &mut SolverState,
    store: &mut CkptStore,
    ckpt: &CkptCfg,
    host: &ComputeModel,
    fence: &mut EpochFence,
    decide: DecideVia<'_>,
) -> MpiResult<Option<DecisionRecord>> {
    ctx.phase_point(ProtoPhase::Detect)?;
    ctx.recompute = false;
    let prev = ctx.set_phase(Phase::Reconfig);
    ulfm::revoke(ctx, comm);
    let shrunk = ulfm::shrink_fenced(ctx, comm, fence).await;
    ctx.set_phase(prev);
    let mut shrunk = shrunk?;
    let (decision, record) = match decide {
        DecideVia::Fixed(d) => (d, None),
        DecideVia::Policy(cfg) => {
            let (d, rec) =
                choose_recovery(ctx, &mut shrunk, comm, state, store, cfg, fence.retries())
                    .await?;
            (d, Some(rec))
        }
    };
    execute_decision(ctx, comm, shrunk, state, store, decision, ckpt, host).await?;
    Ok(record)
}

/// Evaluate the run's recovery policy for the failure event visible in the
/// failed communicator `old` and build (but do not yet record) the
/// [`DecisionRecord`] for this attempt.  Runs after the fenced shrink
/// produced the pristine survivor communicator `shrunk`, so adaptive
/// policies may use one leader broadcast over it (the dynamic capacity
/// horizon).  `attempt` is the epoch-fence attempt number: on a retry the
/// registry already contains the nested deaths, so the policy re-decides
/// on the *union* failure set (a spare grant whose joiner died rolls back
/// here — pool status is re-derived from liveness).
///
/// Every survivor calls this independently and must reach the same answer:
/// the inputs are the liveness registry, the failed communicator's
/// membership, static configuration, and leader-broadcast values (see the
/// consistency notes in [`policy`]).  Unrecoverable in-memory losses (e.g.
/// two failures in one parity group, [`crate::ckptstore::assess_loss`])
/// preempt the policy and escalate to a global restart — the only
/// remaining sound choice.
async fn choose_recovery(
    ctx: &mut Ctx,
    shrunk: &mut Comm,
    old: &Comm,
    state: &SolverState,
    store: &CkptStore,
    cfg: &RunConfig,
    attempt: u64,
) -> MpiResult<(Decision, DecisionRecord)> {
    let failed: Vec<usize> = old
        .members
        .iter()
        .copied()
        .filter(|&wr| !ctx.world.is_alive(wr))
        .collect();
    let status = cfg.spare_pool().status(&ctx.world, &old.members);
    let (decision, reason) = if failed.is_empty() {
        // Spurious wake-up (e.g. a stale revoke): repair the communicator
        // over the full membership without consuming any spares.
        (Decision::Shrink, "no failed members visible (stale revoke)".to_string())
    } else {
        let world = ctx.world.clone();
        let alive = move |wr: usize| world.is_alive(wr);
        let stride = effective_stride(&ctx.world.net.params, old.size());
        // rs2 recoverability depends on which rotation's holders carry the
        // restore version's stripes, so agree on that version first (one
        // allreduce over the survivor communicator — every survivor runs
        // the identical sequence).  Mirror/xor assessments are
        // version-free and skip the collective.  The recovery stages that
        // follow re-run the same agreement rather than threading this
        // value through their APIs: the repeated allreduce is cheap and
        // deterministic, and keeps the staged recovery entry points
        // independently callable.
        let restore_rot = if matches!(cfg.solver.ckpt.scheme, Scheme::Rs2 { .. }) {
            cfg.solver.ckpt.rot_index(agree_restore_version(ctx, shrunk, store).await?)
        } else {
            0
        };
        match ckptstore::assess_loss(&cfg.solver.ckpt, &old.members, &alive, stride, restore_rot)
        {
            LossCheck::Unrecoverable(why) => (
                Decision::GlobalRestart,
                format!("unrecoverable in-memory loss: {why}; escalating to global restart"),
            ),
            LossCheck::Recoverable => {
                let survivors = old.size() - failed.len();
                // The cost-min capacity horizon tracks actual remaining
                // work via a leader broadcast over the survivor
                // communicator — unless the operator pinned a static prior
                // with `policy_horizon`.  Other policies never pay the
                // extra broadcast.
                let cost_min = cfg.policy() == policy::PolicyKind::CostMin;
                let (horizon, dynamic) = match (cost_min, cfg.policy_horizon) {
                    (_, Some(prior)) => (prior, false),
                    (false, None) => (policy::DEFAULT_HORIZON_PRIOR, false),
                    (true, None) => (
                        policy::agreed_capacity_horizon(
                            ctx,
                            shrunk,
                            state,
                            cfg.solver.tol,
                            policy::DEFAULT_HORIZON_PRIOR,
                        )
                        .await?,
                        true,
                    ),
                };
                let inputs = PolicyInputs {
                    n_failed: failed.len(),
                    survivors,
                    pool: status,
                    cost: RecoveryCostInputs {
                        rows_per_rank: (cfg.grid.n() / old.size().max(1)).max(1),
                        basis_vecs: 2 * cfg.solver.m_outer + 1,
                        n_failed: failed.len(),
                        survivors,
                        buddy_k: cfg.solver.ckpt.scheme.mirror_k(),
                        horizon_iters: horizon,
                        m_inner: cfg.solver.m_inner,
                        parity: ParityShape::from_scheme(&cfg.solver.ckpt.scheme, old.size()),
                    },
                    failures_so_far: ctx.world.dead_set().len(),
                    event_seq: ctx.decisions.len(),
                };
                let (d, mut why) = match &cfg.fleet_seat {
                    Some(seat) => {
                        // Fleet runs route the event through the shared
                        // arbiter (DESIGN.md §16) instead of the private
                        // policy evaluation.  The canonical event time is
                        // the max registry death time over the failed set —
                        // engine-invariant, unlike this survivor's clock,
                        // which is skewed by its own detection latency.
                        let t_event = failed
                            .iter()
                            .filter_map(|&wr| ctx.world.death_time(wr))
                            .fold(0.0f64, f64::max);
                        let v = fleet::arbitrate(
                            seat,
                            cfg.policy(),
                            &failed,
                            &inputs,
                            &cfg.compute,
                            &cfg.net,
                            t_event,
                        );
                        if v.defer_secs > 0.0 {
                            // Bandwidth gate: wait out the deferral in
                            // virtual time before the recovery proceeds.
                            let prev = ctx.set_phase(Phase::Recovery);
                            ctx.advance(v.defer_secs);
                            ctx.set_phase(prev);
                        }
                        (v.decision, v.reason)
                    }
                    None => policy::decide(cfg.policy(), &inputs, &cfg.compute, &cfg.net),
                };
                if cost_min {
                    let src = if dynamic { "leader-agreed" } else { "pinned prior" };
                    why.push_str(&format!(" horizon={horizon} ({src})"));
                }
                (d, why)
            }
        }
    };
    let record = DecisionRecord {
        seq: ctx.decisions.len(),
        at: ctx.clock,
        failed_ranks: failed,
        decision: decision.name(),
        reason,
        warm_free: status.warm_free,
        cold_free: status.cold_free,
        attempt: attempt as usize,
    };
    Ok((decision, record))
}

/// Stage 1 of survivor-side failure handling — the ULFM repair sequence
/// every strategy shares (paper §IV): propagate the error so every survivor
/// unblocks, then build a pristine survivor communicator.  The caller
/// evaluates its recovery policy between this and [`execute_decision`]
/// (collectives over the returned communicator, like the leader horizon
/// broadcast, are allowed there — every survivor runs the same sequence).
pub async fn repair_membership(ctx: &mut Ctx, comm: &Comm) -> MpiResult<Comm> {
    let prev = ctx.set_phase(Phase::Reconfig);
    ulfm::revoke(ctx, comm);
    let shrunk = ulfm::shrink(ctx, comm).await;
    ctx.set_phase(prev);
    shrunk
}

/// Stage 2: run decision-specific state recovery over the `shrunk`
/// communicator produced by [`repair_membership`].  On success `comm` is
/// the repaired communicator and `state`/`store` are consistent at the
/// last committed checkpoint (or at a fresh restart for an
/// unrecoverable-loss `GlobalRestart`).
#[allow(clippy::too_many_arguments)]
pub async fn execute_decision(
    ctx: &mut Ctx,
    comm: &mut Comm,
    shrunk: Comm,
    state: &mut SolverState,
    store: &mut CkptStore,
    decision: Decision,
    ckpt: &CkptCfg,
    host: &ComputeModel,
) -> MpiResult<()> {
    let old = comm.clone();
    match decision {
        Decision::Shrink => {
            let mut new_comm = shrunk;
            shrink::recover(ctx, &old, &mut new_comm, state, store, ckpt, host).await?;
            *comm = new_comm;
        }
        Decision::Substitute | Decision::SubstituteCold => {
            *comm = substitute::recover_survivor(ctx, &old, shrunk, state, store, ckpt, host)
                .await?;
        }
        Decision::GlobalRestart => {
            // The §I strawman as the universal fallback: tear the job down
            // and relaunch on the survivors.  Mechanically this is shrink
            // recovery (survivors re-read state and continue) when the
            // in-memory checkpoints still cover every failed rank, preceded
            // by the analytic relaunch + PFS waste of the global C/R model
            // — priced with the SAME state-size formula the cost-min policy
            // used to (not) choose it, so the executed charge matches the
            // `restart=` figure recorded in the decision log.  When the
            // loss is unrecoverable (the escalation path), survivors
            // instead rebuild the problem from scratch.
            let model = global_restart::GlobalCrModel::default();
            let basis_vecs = state.v_out.m + state.z_out.m;
            let per_rank = crate::backend::costs::state_bytes_per_rank(
                &ctx.world.net.params,
                state.rows(),
                basis_vecs,
            );
            let total_bytes = (per_rank * old.size() as f64) as usize;
            let prev = ctx.set_phase(Phase::Recovery);
            ctx.advance(model.waste_per_failure(total_bytes));
            ctx.set_phase(prev);

            let world = ctx.world.clone();
            let alive = move |wr: usize| world.is_alive(wr);
            let stride = effective_stride(&ctx.world.net.params, old.size());
            let mut new_comm = shrunk;
            // Same rotation-aware assessment the policy ran (rs2 holders
            // depend on the restore version); the agreement is collective
            // over the survivors, who all execute this same branch.
            let restore_rot = if matches!(ckpt.scheme, Scheme::Rs2 { .. }) {
                ckpt.rot_index(agree_restore_version(ctx, &mut new_comm, store).await?)
            } else {
                0
            };
            match ckptstore::assess_loss(ckpt, &old.members, &alive, stride, restore_rot) {
                LossCheck::Recoverable => {
                    shrink::recover(ctx, &old, &mut new_comm, state, store, ckpt, host).await?;
                }
                LossCheck::Unrecoverable(_) => {
                    global_restart::restart_on_survivors(
                        ctx, &mut new_comm, state, store, ckpt, host,
                    )
                    .await?;
                }
            }
            *comm = new_comm;
        }
    }
    Ok(())
}
