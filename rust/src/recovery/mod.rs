//! In-situ recovery (the paper's contribution): the *shrink* and
//! *substitute* strategies, the per-event [`policy`] engine that chooses
//! between them at runtime, and the recovery driver that turns a ULFM
//! failure notification into a repaired communicator and restored state.
//!
//! The repair pipeline every strategy shares (paper §IV): `revoke` the
//! failed communicator so all survivors unblock, `shrink` to a pristine
//! survivor communicator, then run strategy-specific state recovery —
//! redistribution for [`shrink`], spare stitching plus checkpoint-store
//! state transfer for [`substitute`], and the analytic relaunch penalty of
//! [`global_restart`] for the last-resort path.  Which branch runs is a
//! per-failure [`policy::Decision`]; fixed-strategy runs are the
//! `fixed:<strategy>` special case (see DESIGN.md §3).  The decision point
//! sits *after* the ULFM shrink, so adaptive policies may use one
//! leader-broadcast over the survivor communicator (the dynamic capacity
//! horizon of [`policy::agreed_capacity_horizon`]) and still hand every
//! survivor the identical decision.
//!
//! Failed state is read back through the checkpoint subsystem's recovery
//! reader ([`crate::ckptstore::reconstruct_failed`]); when the loss is
//! *unrecoverable* under the configured redundancy scheme (two failures in
//! one `xor:<g>` parity group before a re-encode, or three in one
//! `rs2:<g>` group — see [`crate::ckptstore::assess_loss`]), the
//! `GlobalRestart` branch rebuilds the problem from scratch on the
//! survivors instead of wedging on a checkpoint that no longer exists.

pub mod global_restart;
pub mod plan;
pub mod policy;
pub mod shrink;
pub mod substitute;

use crate::checkpoint::{agree_restore_version, effective_stride, CkptStore};
use crate::ckptstore::{self, CkptCfg, LossCheck, Scheme};
use crate::failure::ProtoPhase;
use crate::metrics::Phase;
use crate::netsim::ComputeModel;
use crate::simmpi::ulfm::EpochFence;
use crate::simmpi::{ulfm, Comm, Ctx, MpiError, MpiResult};
use crate::solver::state::SolverState;

pub use policy::{Decision, PolicyKind};

/// Which failure-handling strategy a run is *configured* with.  Adaptive
/// runs re-decide per failure event via [`policy`]; `Strategy` remains the
/// per-run surface the paper's campaigns (Figures 4-6) are expressed in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Baseline: no checkpointing, no recovery (and no failures injected) —
    /// the paper's "no protection" normalization.
    NoProtection,
    /// Continue with the survivors; redistribute the workload (§IV-B).
    Shrink,
    /// Restore the original configuration with warm spares (§IV-A).
    Substitute,
    /// Substitute with *cold* spares: processes spawned at failure time
    /// (§IV-A: "processes spawned at runtime are referred to as cold
    /// spares... spawning processes at runtime has more overhead").  Same
    /// recovery protocol as warm substitution plus the spawn latency.
    SubstituteCold,
}

impl Strategy {
    pub fn parse(s: &str) -> Option<Strategy> {
        match s {
            "none" | "no-protection" => Some(Strategy::NoProtection),
            "shrink" => Some(Strategy::Shrink),
            "substitute" | "spare" => Some(Strategy::Substitute),
            "substitute-cold" | "cold" => Some(Strategy::SubstituteCold),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::NoProtection => "no-protection",
            Strategy::Shrink => "shrink",
            Strategy::Substitute => "substitute",
            Strategy::SubstituteCold => "substitute-cold",
        }
    }
}

/// Survivor-side failure handling with a fixed per-run strategy: the
/// original paper configuration, kept as a thin wrapper over
/// [`handle_failure_with`] (a fixed strategy is just a constant
/// [`Decision`]).
pub fn handle_failure(
    ctx: &mut Ctx,
    comm: &mut Comm,
    state: &mut SolverState,
    store: &mut CkptStore,
    strategy: Strategy,
    ckpt: &CkptCfg,
    host: &ComputeModel,
) -> MpiResult<()> {
    debug_assert!(
        strategy != Strategy::NoProtection,
        "no-protection runs never inject failures"
    );
    handle_failure_with(
        ctx,
        comm,
        state,
        store,
        Decision::from_strategy(strategy),
        ckpt,
        host,
    )
}

/// Survivor-side failure handling for one pre-made per-event [`Decision`]:
/// the epoch-fenced driver with a constant decision.  Every survivor of the
/// same event must pass the same decision.
pub fn handle_failure_with(
    ctx: &mut Ctx,
    comm: &mut Comm,
    state: &mut SolverState,
    store: &mut CkptStore,
    decision: Decision,
    ckpt: &CkptCfg,
    host: &ComputeModel,
) -> MpiResult<()> {
    handle_failure_fenced(ctx, comm, state, store, ckpt, host, |_, _, _, _, _, _| Ok(decision))
        .map(|_| ())
}

/// Epoch-fenced restartable recovery driver (DESIGN.md §10): turn one
/// observed failure into a repaired communicator and restored state, and
/// keep doing so under **nested failures** — a rank dying mid-agreement,
/// mid-reconstruction, mid-commit or mid-spare-join while this event's
/// recovery is running.
///
/// Each *attempt* runs the full pipeline in a fresh epoch window handed out
/// by the [`EpochFence`]: fenced shrink ([`ulfm::shrink_fenced`]), the
/// caller's `decide` callback (re-evaluated per attempt — the policy engine
/// re-decides on the *union* failure set, so a spare grant whose joiner died
/// rolls back to a different spare or to shrink), then
/// [`execute_decision`].  Any error other than this rank's own death
/// abandons the attempt: the driver revokes the attempt's whole epoch
/// window at every world rank ([`ulfm::revoke_epoch_world`]) so *every*
/// survivor and mid-join spare blocked in the poisoned protocol returns
/// `Revoked` and re-enters a fresh agree, rolls the solver state back to
/// the event-entry snapshot, and retries with the enlarged failure set.
///
/// Returns the number of abandoned attempts (0 = clean first try), which
/// the caller records in the decision log / metrics.
///
/// `decide` receives `(ctx, shrunk, old_comm, state, store, attempt)` and
/// must produce the same decision on every survivor of the attempt (same
/// consistency contract as [`policy`]).
#[allow(clippy::too_many_arguments)]
pub fn handle_failure_fenced<F>(
    ctx: &mut Ctx,
    comm: &mut Comm,
    state: &mut SolverState,
    store: &mut CkptStore,
    ckpt: &CkptCfg,
    host: &ComputeModel,
    mut decide: F,
) -> MpiResult<u64>
where
    F: FnMut(
        &mut Ctx,
        &mut Comm,
        &Comm,
        &SolverState,
        &CkptStore,
        u64,
    ) -> MpiResult<Decision>,
{
    // Consecutive abandons without any *new* death in the registry.  A
    // genuine nested failure always grows the shared dead set, and the
    // post-death revoke cascade settles within a couple of fence windows,
    // so a long no-new-death abandon streak means the failure is
    // deterministic (e.g. a fixed-substitute run whose spare pool is
    // exhausted — a configuration error, per the policy contract): give up
    // and propagate, preserving the pre-fence fail-loudly semantics
    // instead of livelocking on retries that cannot succeed.
    const STALL_LIMIT: u32 = 16;
    let mut fence = EpochFence::new(comm);
    let snap = state.snapshot();
    let mut stalls = 0u32;
    let mut dead_seen = ctx.world.dead_set().len();
    loop {
        if !ctx.world.is_alive(ctx.rank) {
            return Err(ctx.die());
        }
        let result = attempt_recovery(ctx, comm, state, store, ckpt, host, &mut fence, &mut decide);
        match result {
            Ok(()) => return Ok(fence.retries()),
            Err(MpiError::Killed) => return Err(MpiError::Killed),
            Err(e) => {
                let dead_now = ctx.world.dead_set().len();
                if dead_now > dead_seen {
                    dead_seen = dead_now;
                    stalls = 0;
                } else {
                    stalls += 1;
                    if stalls > STALL_LIMIT {
                        return Err(e);
                    }
                }
                // A nested failure (or a peer's revocation) poisoned the
                // attempt: fence off its epoch window machine-wide, roll
                // the solver state back to the event-entry image, and
                // re-enter with whatever the registry says has failed now.
                let prev = ctx.set_phase(Phase::Reconfig);
                ulfm::revoke_epoch_world(ctx, fence.shrink_epoch());
                ulfm::revoke_epoch_world(ctx, fence.stitch_epoch());
                ctx.set_phase(prev);
                state.rollback(&snap);
                fence.abandon();
                ctx.recovery_retries += 1;
            }
        }
    }
}

/// One recovery attempt inside [`handle_failure_fenced`]'s loop.
#[allow(clippy::too_many_arguments)]
fn attempt_recovery<F>(
    ctx: &mut Ctx,
    comm: &mut Comm,
    state: &mut SolverState,
    store: &mut CkptStore,
    ckpt: &CkptCfg,
    host: &ComputeModel,
    fence: &mut EpochFence,
    decide: &mut F,
) -> MpiResult<()>
where
    F: FnMut(
        &mut Ctx,
        &mut Comm,
        &Comm,
        &SolverState,
        &CkptStore,
        u64,
    ) -> MpiResult<Decision>,
{
    ctx.phase_point(ProtoPhase::Detect)?;
    ctx.recompute = false;
    let prev = ctx.set_phase(Phase::Reconfig);
    ulfm::revoke(ctx, comm);
    let shrunk = ulfm::shrink_fenced(ctx, comm, fence);
    ctx.set_phase(prev);
    let mut shrunk = shrunk?;
    let decision = decide(ctx, &mut shrunk, comm, state, store, fence.retries())?;
    execute_decision(ctx, comm, shrunk, state, store, decision, ckpt, host)
}

/// Stage 1 of survivor-side failure handling — the ULFM repair sequence
/// every strategy shares (paper §IV): propagate the error so every survivor
/// unblocks, then build a pristine survivor communicator.  The caller
/// evaluates its recovery policy between this and [`execute_decision`]
/// (collectives over the returned communicator, like the leader horizon
/// broadcast, are allowed there — every survivor runs the same sequence).
pub fn repair_membership(ctx: &mut Ctx, comm: &Comm) -> MpiResult<Comm> {
    let prev = ctx.set_phase(Phase::Reconfig);
    ulfm::revoke(ctx, comm);
    let shrunk = ulfm::shrink(ctx, comm);
    ctx.set_phase(prev);
    shrunk
}

/// Stage 2: run decision-specific state recovery over the `shrunk`
/// communicator produced by [`repair_membership`].  On success `comm` is
/// the repaired communicator and `state`/`store` are consistent at the
/// last committed checkpoint (or at a fresh restart for an
/// unrecoverable-loss `GlobalRestart`).
#[allow(clippy::too_many_arguments)]
pub fn execute_decision(
    ctx: &mut Ctx,
    comm: &mut Comm,
    shrunk: Comm,
    state: &mut SolverState,
    store: &mut CkptStore,
    decision: Decision,
    ckpt: &CkptCfg,
    host: &ComputeModel,
) -> MpiResult<()> {
    let old = comm.clone();
    match decision {
        Decision::Shrink => {
            let mut new_comm = shrunk;
            shrink::recover(ctx, &old, &mut new_comm, state, store, ckpt, host)?;
            *comm = new_comm;
        }
        Decision::Substitute | Decision::SubstituteCold => {
            *comm =
                substitute::recover_survivor(ctx, &old, shrunk, state, store, ckpt, host)?;
        }
        Decision::GlobalRestart => {
            // The §I strawman as the universal fallback: tear the job down
            // and relaunch on the survivors.  Mechanically this is shrink
            // recovery (survivors re-read state and continue) when the
            // in-memory checkpoints still cover every failed rank, preceded
            // by the analytic relaunch + PFS waste of the global C/R model
            // — priced with the SAME state-size formula the cost-min policy
            // used to (not) choose it, so the executed charge matches the
            // `restart=` figure recorded in the decision log.  When the
            // loss is unrecoverable (the escalation path), survivors
            // instead rebuild the problem from scratch.
            let model = global_restart::GlobalCrModel::default();
            let basis_vecs = state.v_out.m + state.z_out.m;
            let per_rank = crate::backend::costs::state_bytes_per_rank(
                &ctx.world.net.params,
                state.rows(),
                basis_vecs,
            );
            let total_bytes = (per_rank * old.size() as f64) as usize;
            let prev = ctx.set_phase(Phase::Recovery);
            ctx.advance(model.waste_per_failure(total_bytes));
            ctx.set_phase(prev);

            let world = ctx.world.clone();
            let alive = move |wr: usize| world.is_alive(wr);
            let stride = effective_stride(&ctx.world.net.params, old.size());
            let mut new_comm = shrunk;
            // Same rotation-aware assessment the policy ran (rs2 holders
            // depend on the restore version); the agreement is collective
            // over the survivors, who all execute this same branch.
            let restore_rot = if matches!(ckpt.scheme, Scheme::Rs2 { .. }) {
                ckpt.rot_index(agree_restore_version(ctx, &mut new_comm, store)?)
            } else {
                0
            };
            match ckptstore::assess_loss(ckpt, &old.members, &alive, stride, restore_rot) {
                LossCheck::Recoverable => {
                    shrink::recover(ctx, &old, &mut new_comm, state, store, ckpt, host)?;
                }
                LossCheck::Unrecoverable(_) => {
                    global_restart::restart_on_survivors(
                        ctx, &mut new_comm, state, store, ckpt, host,
                    )?;
                }
            }
            *comm = new_comm;
        }
    }
    Ok(())
}
