//! In-situ recovery strategies (the paper's contribution): *shrink* and
//! *substitute*, plus the recovery driver that turns a ULFM failure
//! notification into a repaired communicator and restored state.

pub mod global_restart;
pub mod plan;
pub mod shrink;
pub mod substitute;

use crate::checkpoint::CkptStore;
use crate::metrics::Phase;
use crate::netsim::ComputeModel;
use crate::simmpi::{ulfm, Comm, Ctx, MpiResult};
use crate::solver::state::SolverState;

/// Which failure-handling strategy a run uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Baseline: no checkpointing, no recovery (and no failures injected) —
    /// the paper's "no protection" normalization.
    NoProtection,
    /// Continue with the survivors; redistribute the workload (§IV-B).
    Shrink,
    /// Restore the original configuration with warm spares (§IV-A).
    Substitute,
    /// Substitute with *cold* spares: processes spawned at failure time
    /// (§IV-A: "processes spawned at runtime are referred to as cold
    /// spares... spawning processes at runtime has more overhead").  Same
    /// recovery protocol as warm substitution plus the spawn latency.
    SubstituteCold,
}

impl Strategy {
    pub fn parse(s: &str) -> Option<Strategy> {
        match s {
            "none" | "no-protection" => Some(Strategy::NoProtection),
            "shrink" => Some(Strategy::Shrink),
            "substitute" | "spare" => Some(Strategy::Substitute),
            "substitute-cold" | "cold" => Some(Strategy::SubstituteCold),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::NoProtection => "no-protection",
            Strategy::Shrink => "shrink",
            Strategy::Substitute => "substitute",
            Strategy::SubstituteCold => "substitute-cold",
        }
    }
}

/// Survivor-side failure handling: revoke, shrink, then strategy-specific
/// state recovery.  On success `comm` is the repaired communicator and
/// `state`/`store` are consistent at the last committed checkpoint.
pub fn handle_failure(
    ctx: &mut Ctx,
    comm: &mut Comm,
    state: &mut SolverState,
    store: &mut CkptStore,
    strategy: Strategy,
    buddy_k: usize,
    host: &ComputeModel,
) -> MpiResult<()> {
    // ULFM repair sequence (paper §IV): propagate the error so every
    // survivor unblocks, then build a pristine communicator.
    let prev = ctx.set_phase(Phase::Reconfig);
    ulfm::revoke(ctx, comm);
    let shrunk = ulfm::shrink(ctx, comm)?;
    ctx.set_phase(prev);

    let old = comm.clone();
    match strategy {
        Strategy::Shrink => {
            let mut new_comm = shrunk;
            shrink::recover(ctx, &old, &mut new_comm, state, store, buddy_k, host)?;
            *comm = new_comm;
        }
        Strategy::Substitute | Strategy::SubstituteCold => {
            *comm =
                substitute::recover_survivor(ctx, &old, shrunk, state, store, buddy_k, host)?;
        }
        Strategy::NoProtection => {
            unreachable!("no-protection runs never inject failures")
        }
    }
    Ok(())
}
