//! In-situ recovery (the paper's contribution): the *shrink* and
//! *substitute* strategies, the per-event [`policy`] engine that chooses
//! between them at runtime, and the recovery driver that turns a ULFM
//! failure notification into a repaired communicator and restored state.
//!
//! The repair pipeline every strategy shares (paper §IV): `revoke` the
//! failed communicator so all survivors unblock, `shrink` to a pristine
//! survivor communicator, then run strategy-specific state recovery —
//! redistribution for [`shrink`], spare stitching plus buddy state transfer
//! for [`substitute`], and the analytic relaunch penalty of
//! [`global_restart`] for the last-resort path.  Which branch runs is a
//! per-failure [`policy::Decision`]; fixed-strategy runs are the
//! `fixed:<strategy>` special case (see DESIGN.md §3).

pub mod global_restart;
pub mod plan;
pub mod policy;
pub mod shrink;
pub mod substitute;

use crate::checkpoint::CkptStore;
use crate::metrics::Phase;
use crate::netsim::ComputeModel;
use crate::simmpi::{ulfm, Comm, Ctx, MpiResult};
use crate::solver::state::SolverState;

pub use policy::{Decision, PolicyKind};

/// Which failure-handling strategy a run is *configured* with.  Adaptive
/// runs re-decide per failure event via [`policy`]; `Strategy` remains the
/// per-run surface the paper's campaigns (Figures 4-6) are expressed in.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Strategy {
    /// Baseline: no checkpointing, no recovery (and no failures injected) —
    /// the paper's "no protection" normalization.
    NoProtection,
    /// Continue with the survivors; redistribute the workload (§IV-B).
    Shrink,
    /// Restore the original configuration with warm spares (§IV-A).
    Substitute,
    /// Substitute with *cold* spares: processes spawned at failure time
    /// (§IV-A: "processes spawned at runtime are referred to as cold
    /// spares... spawning processes at runtime has more overhead").  Same
    /// recovery protocol as warm substitution plus the spawn latency.
    SubstituteCold,
}

impl Strategy {
    pub fn parse(s: &str) -> Option<Strategy> {
        match s {
            "none" | "no-protection" => Some(Strategy::NoProtection),
            "shrink" => Some(Strategy::Shrink),
            "substitute" | "spare" => Some(Strategy::Substitute),
            "substitute-cold" | "cold" => Some(Strategy::SubstituteCold),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Strategy::NoProtection => "no-protection",
            Strategy::Shrink => "shrink",
            Strategy::Substitute => "substitute",
            Strategy::SubstituteCold => "substitute-cold",
        }
    }
}

/// Survivor-side failure handling with a fixed per-run strategy: the
/// original paper configuration, kept as a thin wrapper over
/// [`handle_failure_with`] (a fixed strategy is just a constant
/// [`Decision`]).
pub fn handle_failure(
    ctx: &mut Ctx,
    comm: &mut Comm,
    state: &mut SolverState,
    store: &mut CkptStore,
    strategy: Strategy,
    buddy_k: usize,
    host: &ComputeModel,
) -> MpiResult<()> {
    debug_assert!(
        strategy != Strategy::NoProtection,
        "no-protection runs never inject failures"
    );
    handle_failure_with(
        ctx,
        comm,
        state,
        store,
        Decision::from_strategy(strategy),
        buddy_k,
        host,
    )
}

/// Survivor-side failure handling for one per-event [`Decision`]: revoke,
/// shrink, then decision-specific state recovery.  On success `comm` is the
/// repaired communicator and `state`/`store` are consistent at the last
/// committed checkpoint.
///
/// Every survivor of the same event must pass the same decision (see the
/// consistency notes in [`policy`]); the decision is made *before* calling
/// this, so the ULFM repair sequence below is common to all strategies.
pub fn handle_failure_with(
    ctx: &mut Ctx,
    comm: &mut Comm,
    state: &mut SolverState,
    store: &mut CkptStore,
    decision: Decision,
    buddy_k: usize,
    host: &ComputeModel,
) -> MpiResult<()> {
    // ULFM repair sequence (paper §IV): propagate the error so every
    // survivor unblocks, then build a pristine communicator.
    let prev = ctx.set_phase(Phase::Reconfig);
    ulfm::revoke(ctx, comm);
    let shrunk = ulfm::shrink(ctx, comm)?;
    ctx.set_phase(prev);

    let old = comm.clone();
    match decision {
        Decision::Shrink => {
            let mut new_comm = shrunk;
            shrink::recover(ctx, &old, &mut new_comm, state, store, buddy_k, host)?;
            *comm = new_comm;
        }
        Decision::Substitute | Decision::SubstituteCold => {
            *comm =
                substitute::recover_survivor(ctx, &old, shrunk, state, store, buddy_k, host)?;
        }
        Decision::GlobalRestart => {
            // The §I strawman as the universal fallback: tear the job down
            // and relaunch on the survivors.  Mechanically this is shrink
            // recovery (survivors re-read state and continue), preceded by
            // the analytic relaunch + PFS waste of the global C/R model —
            // priced with the SAME state-size formula the cost-min policy
            // used to (not) choose it, so the executed charge matches the
            // `restart=` figure recorded in the decision log.
            let model = global_restart::GlobalCrModel::default();
            let basis_vecs = state.v_out.m + state.z_out.m;
            let per_rank = crate::backend::costs::state_bytes_per_rank(
                &ctx.world.net.params,
                state.rows(),
                basis_vecs,
            );
            let total_bytes = (per_rank * old.size() as f64) as usize;
            let prev = ctx.set_phase(Phase::Recovery);
            ctx.advance(model.waste_per_failure(total_bytes));
            ctx.set_phase(prev);
            let mut new_comm = shrunk;
            shrink::recover(ctx, &old, &mut new_comm, state, store, buddy_k, host)?;
            *comm = new_comm;
        }
    }
    Ok(())
}
