//! Substitute recovery: restore the original configuration with warm spares
//! (paper §IV-A).
//!
//! Survivors keep their data distribution and restore the solution vector
//! from *local* checkpoint copies; the spare is stitched into the failed
//! rank's comm-rank slot (Figure 1), fetches the failed rank's static and
//! dynamic state from the rank the redundancy scheme designates — the
//! failed rank's first live mirror buddy, or the parity holder that the
//! recovery reader materialized the objects on — and synchronizes its local
//! scalars from a survivor.  Checkpointing then continues over the restored
//! configuration — with the spare on a distant node, which is exactly where
//! the paper's post-substitution checkpoint overhead comes from (Figure 2).

use crate::checkpoint::{agree_restore_version, effective_stride, obj, CkptStore, Version};
use crate::ckptstore::{self, CkptCfg};
use crate::metrics::Phase;
use crate::netsim::ComputeModel;
use crate::problem::{Grid3D, MatrixRows, Partition, K};
use crate::simmpi::{tags, ulfm, Blob, Comm, Ctx, MpiError, MpiResult, WorldRank};
use crate::solver::state::{IterScalars, SolverState};
use crate::backend::DenseBasis;

/// Objects the spare needs to adopt the failed rank's block.
const SPARE_OBJS: [crate::checkpoint::ObjId; 5] =
    [obj::MAT, obj::RHS, obj::X, obj::BASIS, obj::ITER];

/// Tag namespace for spare state transfer.
fn spare_tag(id: u32) -> u32 {
    tags::RECOVER_BASE + (1 << 18) + id
}

/// Deterministic spare assignment: failed old-comm slots (ascending) get the
/// lowest-world-rank alive spares not already serving in `old_comm`.
///
/// Because the [`crate::spares::SparePool`] lays warm spares out at lower
/// world ranks than cold slots, lowest-first assignment drains the warm
/// pool before any cold slot is touched — the cold-spawn latency is only
/// ever paid once no warm spare is free (paper §IV-A).
pub fn assign_spares(
    ctx: &Ctx,
    old_comm: &Comm,
) -> MpiResult<Vec<(usize, WorldRank)>> {
    let world = &ctx.world;
    let failed: Vec<usize> = (0..old_comm.size())
        .filter(|&cr| !world.is_alive(old_comm.members[cr]))
        .collect();
    let in_use: Vec<WorldRank> = old_comm.members.clone();
    let mut avail = (world.n_app..world.size)
        .filter(|wr| world.is_alive(*wr) && !in_use.contains(wr));
    let mut out = Vec::with_capacity(failed.len());
    for cr in failed {
        match avail.next() {
            Some(wr) => out.push((cr, wr)),
            None => return Err(MpiError::ProcFailed(vec![old_comm.members[cr]])),
        }
    }
    Ok(out)
}

/// Survivor side.  `shrunk` is the post-shrink communicator; returns the
/// stitched full-size communicator with `state` restored and all
/// checkpoints re-established.
pub async fn recover_survivor(
    ctx: &mut Ctx,
    old_comm: &Comm,
    mut shrunk: Comm,
    state: &mut SolverState,
    store: &mut CkptStore,
    ckpt: &CkptCfg,
    host: &ComputeModel,
) -> MpiResult<Comm> {
    // --- Reconfiguration: agree on the restore version over the survivors,
    // then stitch the spares in (paper: "the spare process can be stitched
    // in" once pristine communicators exist).
    let v = {
        let prev = ctx.set_phase(Phase::Recovery);
        let v = agree_restore_version(ctx, &mut shrunk, store).await;
        ctx.set_phase(prev);
        v?
    };
    let assignment = assign_spares(ctx, old_comm)?;
    let prev = ctx.set_phase(Phase::Reconfig);
    let stitched = ulfm::stitch_spares(ctx, old_comm, &shrunk, &assignment).await;
    ctx.set_phase(prev);
    let mut stitched = stitched?;

    let prev = ctx.set_phase(Phase::Recovery);
    let result = survivor_state_recovery(
        ctx, old_comm, &mut stitched, &assignment, state, store, v, ckpt, host,
    )
    .await;
    ctx.set_phase(prev);
    result?;
    Ok(stitched)
}

#[allow(clippy::too_many_arguments)]
async fn survivor_state_recovery(
    ctx: &mut Ctx,
    old_comm: &Comm,
    stitched: &mut Comm,
    assignment: &[(usize, WorldRank)],
    state: &mut SolverState,
    store: &mut CkptStore,
    v: Version,
    ckpt: &CkptCfg,
    host: &ComputeModel,
) -> MpiResult<()> {
    let n = old_comm.size();
    let stride = effective_stride(&ctx.world.net.params, n);
    // 1. Survivors restore dynamic state from their LOCAL copies (Fig. 1).
    let iter_blob = store
        .get_local_at_most(obj::ITER, v)
        .expect("ITER checkpoint missing")
        .1
        .clone();
    state.restore_iter(&iter_blob);
    let x_blob = store.get_local_at_most(obj::X, v).expect("X checkpoint missing").1.clone();
    state.x = x_blob.f.to_vec();
    let basis_blob =
        store.get_local_at_most(obj::BASIS, v).expect("BASIS checkpoint missing").1.clone();
    state.restore_basis(&basis_blob);
    ctx.advance(host.cost(state.rows() as f64, 16.0 * state.rows() as f64));

    // 2. Recovery reader: materialize the failed ranks' objects on their
    //    designated servers (parity reconstruction under xor; a no-op for
    //    mirror).  Runs among the old-comm survivors only — the spares are
    //    still blocked waiting for their state below.
    ckptstore::reconstruct_failed(
        ctx,
        stitched,
        store,
        ckpt,
        &old_comm.members,
        v,
        &SPARE_OBJS,
    )
    .await?;

    // 3. If I am the designated server of a failed rank, send its state to
    //    the spare (the paper's buddy-serves-the-spare transfer).
    let world = ctx.world.clone();
    let alive_cr = |cr: usize| world.is_alive(old_comm.members[cr]);
    for &(failed_cr, spare_wr) in assignment {
        let server = ckpt
            .scheme
            .server_cr_for(failed_cr, n, &alive_cr, stride)
            .expect("unrecoverable loss must be escalated before substitution");
        if server != old_comm.rank {
            continue;
        }
        let owner_wr = old_comm.members[failed_cr];
        let spare_cr = stitched
            .rank_of_world(spare_wr)
            .expect("spare must be stitched");
        for id in SPARE_OBJS {
            let blob = store
                .get_remote_at_most(owner_wr, id, v)
                .unwrap_or_else(|| panic!("serving copy of obj {id} missing"))
                .1
                .clone();
            // Stored blobs already carry their scaled wire size; the
            // compression layer applies to this transfer too.
            let blob =
                if ckpt.compress { ckptstore::delta::compress_blob(&blob) } else { blob };
            stitched.send(ctx, spare_cr, spare_tag(id), blob)?;
        }
        // Control blob: restore version + recompute high-water mark
        // ("use any surviving process to populate the local state").
        let ctl = Blob::from_i64s(vec![v, state.hwm_iters as i64]);
        stitched.send(ctx, spare_cr, spare_tag(99), ctl)?;
    }

    // 4. Re-establish checkpoints over the restored configuration (spare
    //    included — its distant node makes this and all future checkpoints
    //    costlier, the paper's Figure 2/5 effect).  Copies held for the
    //    dead are NOT dropped eagerly: a nested failure tearing this
    //    establishment sends everyone back through the epoch fence, and
    //    the retry must still be able to serve the dead slots' state.  The
    //    committed-floor GC purges them one commit after the establishment
    //    proves globally visible.
    state.establish_checkpoints(ctx, stitched, store, v + 1, ckpt).await?;
    Ok(())
}

/// Spare side: called after `ulfm::join_as_spare` produced `comm` (this
/// rank already holds comm rank = the failed slot).  Builds the full solver
/// state from the scheme-designated server's copies and joins checkpoint
/// re-establishment.
#[allow(clippy::too_many_arguments)]
pub async fn recover_spare(
    ctx: &mut Ctx,
    comm: &mut Comm,
    old_members: &[WorldRank],
    grid: Grid3D,
    m_outer: usize,
    store: &mut CkptStore,
    ckpt: &CkptCfg,
    host: &ComputeModel,
) -> MpiResult<SolverState> {
    let prev = ctx.set_phase(Phase::Recovery);
    let result =
        recover_spare_inner(ctx, comm, old_members, grid, m_outer, store, ckpt, host).await;
    ctx.set_phase(prev);
    result
}

#[allow(clippy::too_many_arguments)]
async fn recover_spare_inner(
    ctx: &mut Ctx,
    comm: &mut Comm,
    old_members: &[WorldRank],
    grid: Grid3D,
    m_outer: usize,
    store: &mut CkptStore,
    ckpt: &CkptCfg,
    host: &ComputeModel,
) -> MpiResult<SolverState> {
    let n = comm.size();
    let me = comm.rank;
    // The designated server of the failed slot this spare adopted: the
    // first live mirror buddy, or the slot's parity holder.  Liveness is
    // evaluated over the *failed* communicator's membership (carried by the
    // Join invitation) — exactly the function the surviving servers
    // evaluated — so both sides pick the same server with no negotiation,
    // even when several slots failed in the same event.
    debug_assert_eq!(old_members.len(), n);
    let world = ctx.world.clone();
    let alive_cr = |cr: usize| world.is_alive(old_members[cr]);
    let server_cr = ckpt
        .scheme
        .server_cr_for(me, n, &alive_cr, effective_stride(&ctx.world.net.params, n))
        .expect("unrecoverable loss must be escalated before substitution");
    async fn fetch(
        ctx: &mut Ctx,
        comm: &mut Comm,
        server_cr: usize,
        compress: bool,
        id: u32,
    ) -> MpiResult<Blob> {
        let blob = comm.recv(ctx, server_cr, spare_tag(id)).await?;
        Ok(if compress { ckptstore::delta::decompress_blob(&blob) } else { blob })
    }
    let mat_blob = fetch(ctx, comm, server_cr, ckpt.compress, obj::MAT).await?;
    let rhs_blob = fetch(ctx, comm, server_cr, ckpt.compress, obj::RHS).await?;
    let x_blob = fetch(ctx, comm, server_cr, ckpt.compress, obj::X).await?;
    let basis_blob = fetch(ctx, comm, server_cr, ckpt.compress, obj::BASIS).await?;
    let iter_blob = fetch(ctx, comm, server_cr, ckpt.compress, obj::ITER).await?;
    let ctl = comm.recv(ctx, server_cr, spare_tag(99)).await?;
    let v = ctl.i[0];
    let hwm = ctl.i[1] as u64;

    let part = Partition::balanced(grid.n(), n);
    let mat = MatrixRows::from_blob(&mat_blob);
    let range = part.range(me);
    assert_eq!(mat.start, range.start, "spare adopted wrong block");
    assert_eq!(mat.rows, range.len());

    let rows = mat.rows;
    let blk = crate::problem::EllBlock::build(&mat, &part, me);
    let mut state = SolverState {
        grid,
        part,
        mat,
        blk,
        x: x_blob.f.to_vec(),
        b: rhs_blob.f.to_vec(),
        v_out: DenseBasis::zeros(m_outer + 1, rows),
        z_out: DenseBasis::zeros(m_outer, rows),
        cycle: None,
        scalars: IterScalars { inner_iters_done: 0, next_version: 0, bnorm: 0.0 },
        hwm_iters: hwm,
    };
    state.restore_iter(&iter_blob);
    state.restore_basis(&basis_blob);
    state.hwm_iters = hwm;
    ctx.advance(host.cost((state.rows() * K) as f64, (24 * state.rows() * K) as f64));

    // Join the collective checkpoint re-establishment at v + 1.
    state.establish_checkpoints(ctx, comm, store, v + 1, ckpt).await?;
    Ok(state)
}
