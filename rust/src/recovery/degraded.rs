//! Degraded-rank (straggler) detection and the proactive shrink-away
//! decision (DESIGN.md §14).
//!
//! A straggler does not fail: it keeps answering the failure detector while
//! its *compute* runs `mult`× slower (the injector's
//! [`crate::failure::Straggler`] schedule scales every virtual-time charge
//! to [`Phase::Compute`]/[`Phase::Recompute`] on the afflicted rank).  ULFM
//! never notices, but the BSP solver does: every dot-product allreduce and
//! halo exchange now finishes at the straggler's pace, so one degraded rank
//! taxes the whole communicator.
//!
//! The detector piggybacks on the solver's outer-cycle cadence.  Each
//! member contributes its cumulative useful-work time (compute + recompute
//! phase timers) to one scalar allgather; everyone derives the same p50 and
//! per-rank slowdown estimate `m_est = t_rank / p50`, so the decision below
//! is collectively identical without a leader broadcast.  When the worst
//! estimate clears the noise floor, the cost model prices the two options
//! the paper's runtime has:
//!
//! * **tolerate** — keep the straggler; every remaining iteration pays the
//!   excess `(m_est − 1) × t_iter` because lockstep collectives wait for
//!   the slowest member;
//! * **shrink away** — treat the degraded rank like a failed one: it
//!   self-excludes ([`Ctx::die`]) and the ordinary fenced recovery path
//!   redistributes its block over the survivors (or substitutes a spare,
//!   if the policy so decides).
//!
//! The comparison reuses the same [`recovery_estimates`] the failure-time
//! policy engine runs, so the two decision points price recovery
//! identically.  A shrink-away is recorded by *every* member as a
//! `degraded-shrink` [`DecisionRecord`] before the victim dies; the
//! follow-up failure event then produces the normal executed-decision
//! record, and the decision-log merge keeps both (they differ in the
//! `decision` field).

use crate::backend::costs::{
    inner_iter_secs, recovery_estimates, ParityShape, RecoveryCostInputs,
};
use crate::metrics::{DecisionRecord, Phase};
use crate::netsim::ComputeModel;
use crate::recovery::global_restart::GlobalCrModel;
use crate::recovery::policy;
use crate::simmpi::{Blob, Comm, Ctx, MpiResult};
use crate::solver::{FtGmresCfg, SolverState};
use crate::spares::SparePool;
use crate::trace::TraceEvent;

/// Knobs for the straggler detector.  Carried on
/// [`FtGmresCfg::degraded`]; `None` there disables the detector (and its
/// per-cycle allgather) entirely, which keeps failure-only campaigns
/// bit-identical to runs that predate it.
#[derive(Debug, Clone)]
pub struct DegradedCfg {
    /// Spare-pool shape, used to stamp pool occupancy into the
    /// `degraded-shrink` decision record (the same fields the failure-time
    /// records carry).
    pub pool: SparePool,
    /// Slowdown estimates at or below this multiplier are treated as timer
    /// noise: no costing, no decision.
    pub min_mult: f64,
    /// Pinned capacity horizon (remaining inner iterations) for pricing
    /// toleration; `None` uses the static prior
    /// ([`policy::DEFAULT_HORIZON_PRIOR`]).  Kept static — not the dynamic
    /// leader-agreed horizon — so every member prices from the allgather
    /// alone.
    pub horizon: Option<u64>,
}

impl DegradedCfg {
    pub fn new(pool: SparePool) -> DegradedCfg {
        DegradedCfg { pool, min_mult: 1.05, horizon: None }
    }
}

/// One detector round: allgather useful-work timers, estimate per-rank
/// slowdown, and — when tolerating the worst straggler prices above
/// shrinking it away — record the `degraded-shrink` decision on every
/// member and have the victim self-exclude.
///
/// Runs after the outer-cycle checkpoint hook in
/// [`crate::solver::FtGmres::solve`]; no-ops unless `cfg.degraded` is set.
/// At most one victim per round: the fenced recovery that follows
/// re-partitions the world, and the next round re-measures against the new
/// membership.
pub async fn straggler_check(
    ctx: &mut Ctx,
    comm: &mut Comm,
    state: &SolverState,
    cfg: &FtGmresCfg,
    host: &ComputeModel,
) -> MpiResult<()> {
    let Some(dc) = &cfg.degraded else { return Ok(()) };
    let n = comm.size();
    if n < 2 {
        return Ok(());
    }
    // Cumulative useful work: the only timers the straggler multiplier
    // scales, so their ratio to the cohort's median estimates it directly.
    let mine = ctx.timers.get(Phase::Compute) + ctx.timers.get(Phase::Recompute);
    // The probe is solver communication, not application compute; charge it
    // to Comm so the straggler's own multiplier cannot inflate the probe.
    let prev = ctx.set_phase(Phase::Comm);
    let gathered = comm.allgather(ctx, Blob::scalar(mine)).await;
    ctx.set_phase(prev);
    let all: Vec<f64> = gathered?.iter().map(|b| b.f[0]).collect();

    let mut sorted = all.clone();
    sorted.sort_by(f64::total_cmp);
    // Lower median: deterministic for even n, robust to a minority of
    // stragglers inflating the mean.
    let p50 = sorted[(n - 1) / 2];
    if !(p50 > 0.0) {
        return Ok(());
    }
    // Worst member; ties break to the lowest comm rank so every member
    // names the same victim.
    let (victim_cr, worst) = all
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1).then(b.0.cmp(&a.0)))
        .map(|(i, &t)| (i, t))
        .expect("non-empty allgather");
    let m_est = worst / p50;
    if m_est <= dc.min_mult.max(1.0) {
        return Ok(());
    }

    let victim_world = comm.world_of(victim_cr);
    let horizon = dc.horizon.unwrap_or(policy::DEFAULT_HORIZON_PRIOR);
    // Excess wall time the cohort pays per lockstep iteration, summed over
    // the horizon, vs. the same shrink estimate the failure-time policy
    // would produce for losing this one rank.
    let tolerate =
        (m_est - 1.0) * inner_iter_secs(host, state.rows(), cfg.m_inner) * horizon as f64;
    let inp = RecoveryCostInputs {
        rows_per_rank: state.rows(),
        basis_vecs: 2 * cfg.m_outer + 1,
        n_failed: 1,
        survivors: n - 1,
        buddy_k: cfg.ckpt.scheme.mirror_k(),
        horizon_iters: horizon,
        m_inner: cfg.m_inner,
        parity: ParityShape::from_scheme(&cfg.ckpt.scheme, n),
    };
    let shrink =
        recovery_estimates(host, &ctx.world.net.params, &GlobalCrModel::default(), &inp).shrink;
    let at = ctx.clock;
    if tolerate <= shrink {
        ctx.trace_push(|| TraceEvent::Mark {
            label: "degraded-tolerate",
            arg: victim_world as i64,
            t: at,
        });
        return Ok(());
    }

    // Shrink away.  Every member (victim included) records the identical
    // proactive decision from the shared allgather, then the victim
    // self-excludes; survivors discover the death at their next collective
    // and run the ordinary fenced recovery.
    let status = dc.pool.status(&ctx.world, &comm.members);
    ctx.decisions.push(DecisionRecord {
        seq: ctx.decisions.len(),
        at,
        failed_ranks: vec![victim_world],
        decision: "degraded-shrink",
        reason: format!(
            "straggler w{victim_world} m_est={m_est:.2}: tolerate {tolerate:.3e}s > \
             shrink {shrink:.3e}s (horizon={horizon})"
        ),
        warm_free: status.warm_free,
        cold_free: status.cold_free,
        attempt: 0,
    });
    ctx.trace_push(|| TraceEvent::Mark {
        label: "degraded-shrink",
        arg: victim_world as i64,
        t: at,
    });
    if comm.rank == victim_cr {
        return Err(ctx.die());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ckptstore::Scheme;
    use crate::netsim::NetParams;

    fn cost_inputs(n: usize, rows: usize, m_inner: usize, horizon: u64) -> RecoveryCostInputs {
        RecoveryCostInputs {
            rows_per_rank: rows,
            basis_vecs: 2 * 20 + 1,
            n_failed: 1,
            survivors: n - 1,
            buddy_k: 1,
            horizon_iters: horizon,
            m_inner,
            parity: ParityShape::from_scheme(&Scheme::Mirror { k: 1 }, n),
        }
    }

    /// The cost-min crossover sits between the two multipliers the
    /// degraded-mode acceptance tests inject (1.2 tolerates, 3.0 shrinks)
    /// for the quick-campaign shape: 8 ranks, 1728-row cube, m_inner=10,
    /// static prior horizon.
    #[test]
    fn quick_campaign_crossover_separates_the_test_multipliers() {
        let host = ComputeModel::default();
        let net = NetParams::default();
        let (n, rows, m_inner) = (8usize, 1728 / 8, 10usize);
        let horizon = policy::DEFAULT_HORIZON_PRIOR;
        let iter = inner_iter_secs(&host, rows, m_inner);
        let shrink = recovery_estimates(
            &host,
            &net,
            &GlobalCrModel::default(),
            &cost_inputs(n, rows, m_inner, horizon),
        )
        .shrink;
        let tolerate = |m: f64| (m - 1.0) * iter * horizon as f64;
        assert!(
            tolerate(1.2) <= shrink,
            "mult 1.2 must be tolerated: tolerate={:.3e} shrink={:.3e}",
            tolerate(1.2),
            shrink
        );
        assert!(
            tolerate(3.0) > shrink,
            "mult 3.0 must shrink away: tolerate={:.3e} shrink={:.3e}",
            tolerate(3.0),
            shrink
        );
    }

    #[test]
    fn lower_median_is_deterministic_and_straggler_resistant() {
        // One straggler in eight: the lower median never lands on it.
        let mut all = vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 3.0];
        all.sort_by(f64::total_cmp);
        assert_eq!(all[(all.len() - 1) / 2], 1.0);
        // Even a straggler *pair* leaves the lower median clean.
        let mut all = vec![1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 3.0, 3.0];
        all.sort_by(f64::total_cmp);
        assert_eq!(all[(all.len() - 1) / 2], 1.0);
    }
}
