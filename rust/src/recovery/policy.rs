//! Adaptive recovery policy engine: choose shrink, substitute, cold
//! substitute, or global restart *per failure event* instead of fixing one
//! strategy per run (the paper's §IV tradeoff made into a runtime decision;
//! see DESIGN.md §3).
//!
//! The paper evaluates shrink and substitute as run-long configurations and
//! observes that which one wins depends on runtime conditions: substitute
//! preserves capacity but needs a spare (and pays distant-node checkpoints,
//! Fig. 2/5); shrink always works but loses capacity and pays
//! redistribution (Fig. 3).  FTHP-MPI-style replica pools and ReStore-style
//! adaptive redundancy push the same direction.  This module turns the
//! choice into a per-event decision function over:
//!
//! * **spare-pool state** — warm spares remaining, cold slots remaining
//!   ([`crate::spares::SparePool`]);
//! * **the recovery cost model** —
//!   [`crate::backend::costs::recovery_estimates`], fed by the network and
//!   compute models;
//! * **failure history** — failures so far and the per-run event sequence
//!   number (recorded with every decision in
//!   [`crate::metrics::DecisionRecord`]).
//!
//! # Distributed consistency
//!
//! Every survivor evaluates the policy independently during recovery, so
//! the decision function is deliberately restricted to inputs that are
//! identical across survivors at the same event: the liveness registry, the
//! failed communicator's membership, and static configuration.  Per-rank
//! clocks and timers are *not* admissible inputs — two survivors near a
//! cost crossover could otherwise pick different strategies and deadlock
//! the repair protocol.  This is the same construction
//! [`crate::recovery::substitute::assign_spares`] uses for deterministic
//! spare placement.
//!
//! # Policies (config key `policy`, CLI `--policy`)
//!
//! * `fixed:<strategy>` — always the named strategy (`shrink`,
//!   `substitute`, `substitute-cold`, `global-restart`); the paper's
//!   original per-run configuration.
//! * `spares-first` — substitute while warm spares last, fall back to cold
//!   slots, then degrade gracefully to shrink once the pool is dry.
//! * `cost-min` — evaluate the per-strategy cost estimates at every event
//!   and take the cheapest feasible strategy.

use crate::backend::costs::{self, RecoveryCostInputs, RecoveryEstimates};
use crate::netsim::{ComputeModel, NetParams};
use crate::recovery::global_restart::GlobalCrModel;
use crate::recovery::Strategy;
use crate::simmpi::{Blob, Comm, Ctx, MpiResult};
use crate::solver::state::SolverState;
use crate::spares::PoolStatus;

/// The per-event outcome of a policy evaluation: which recovery mechanism
/// to run for *this* failure.  Unlike [`Strategy`] (a per-run
/// configuration), a `Decision` is produced fresh at every ULFM failure
/// notification.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Decision {
    /// Continue with the survivors; redistribute the workload (§IV-B).
    Shrink,
    /// Stitch warm spares into the failed slots (§IV-A).
    Substitute,
    /// Stitch cold spares in, paying the spawn latency (§IV-A).
    SubstituteCold,
    /// Last resort: the §I global checkpoint/restart strawman — relaunch on
    /// the survivors, paying the analytic [`GlobalCrModel`] waste.
    GlobalRestart,
}

impl Decision {
    pub fn name(&self) -> &'static str {
        match self {
            Decision::Shrink => "shrink",
            Decision::Substitute => "substitute",
            Decision::SubstituteCold => "substitute-cold",
            Decision::GlobalRestart => "global-restart",
        }
    }

    pub fn parse(s: &str) -> Option<Decision> {
        match s {
            "shrink" => Some(Decision::Shrink),
            "substitute" | "spare" => Some(Decision::Substitute),
            "substitute-cold" | "cold" => Some(Decision::SubstituteCold),
            "global-restart" | "restart" => Some(Decision::GlobalRestart),
            _ => None,
        }
    }

    /// The fixed decision equivalent to a per-run [`Strategy`].
    pub fn from_strategy(s: Strategy) -> Decision {
        match s {
            Strategy::Shrink | Strategy::NoProtection => Decision::Shrink,
            Strategy::Substitute => Decision::Substitute,
            Strategy::SubstituteCold => Decision::SubstituteCold,
        }
    }
}

/// Which policy a run uses (config key `policy`; defaults to
/// `fixed:<strategy>` so existing fixed-strategy configs behave exactly as
/// before).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    /// Always the given decision — the paper's original configuration.
    Fixed(Decision),
    /// Substitute while spares last (warm before cold), then shrink.
    SparesFirst,
    /// Minimize the per-event estimate from
    /// [`crate::backend::costs::recovery_estimates`].
    CostMin,
}

impl PolicyKind {
    /// Parse the CLI/config surface: `fixed:<strategy>`, `spares-first`,
    /// `cost-min`.
    pub fn parse(s: &str) -> Option<PolicyKind> {
        match s {
            "spares-first" => Some(PolicyKind::SparesFirst),
            "cost-min" => Some(PolicyKind::CostMin),
            _ => {
                let rest = s.strip_prefix("fixed:")?;
                Decision::parse(rest).map(PolicyKind::Fixed)
            }
        }
    }

    pub fn name(&self) -> String {
        match self {
            PolicyKind::Fixed(d) => format!("fixed:{}", d.name()),
            PolicyKind::SparesFirst => "spares-first".to_string(),
            PolicyKind::CostMin => "cost-min".to_string(),
        }
    }
}

/// Everything the decision function may look at.  All fields are derived
/// from the liveness registry, the failed communicator, and static
/// configuration — see the module docs on distributed consistency.
#[derive(Debug, Clone, Copy)]
pub struct PolicyInputs {
    /// Ranks lost in this failure event (failed members of the old comm).
    pub n_failed: usize,
    /// Members of the old communicator that survive.
    pub survivors: usize,
    /// Spare-pool availability at decision time.
    pub pool: PoolStatus,
    /// Cost-model inputs (rows per rank, buddy count, horizon, ...).
    pub cost: RecoveryCostInputs,
    /// Failures observed in the whole run so far (registry dead-set size).
    pub failures_so_far: usize,
    /// 0-based sequence number of this recovery on the deciding rank.
    pub event_seq: usize,
}

/// Evaluate `kind` on `inputs`, returning the decision and a human-readable
/// reason that is recorded in the run report (the "why" of every choice).
///
/// Feasibility rules applied to every policy:
/// * substitution needs `pool.warm_free >= n_failed` (warm) or
///   `pool.total_free() >= n_failed` (cold-assisted);
/// * shrink needs at least 2 survivors (a 1-rank "cluster" cannot
///   redistribute);
/// * global restart is always feasible — it is the universal, expensive
///   fallback, exactly the role the paper assigns it.
///
/// `Fixed` policies skip the feasibility rules and fail later in recovery
/// if their strategy cannot proceed, preserving the seed semantics of
/// fixed-strategy runs (a substitute run without spares is a configuration
/// error, not something to silently paper over).
pub fn decide(
    kind: PolicyKind,
    inputs: &PolicyInputs,
    host: &ComputeModel,
    net: &NetParams,
) -> (Decision, String) {
    let p = &inputs.pool;
    match kind {
        PolicyKind::Fixed(d) => (
            d,
            format!("policy=fixed event={} failed={}", inputs.event_seq, inputs.n_failed),
        ),
        PolicyKind::SparesFirst => {
            let base = format!(
                "policy=spares-first event={} failed={} warm_free={} cold_free={}",
                inputs.event_seq, inputs.n_failed, p.warm_free, p.cold_free
            );
            if p.warm_free >= inputs.n_failed {
                (Decision::Substitute, format!("{base}: warm spares cover the event"))
            } else if p.total_free() >= inputs.n_failed {
                (
                    Decision::SubstituteCold,
                    format!("{base}: warm pool short, spawning cold spares"),
                )
            } else if inputs.survivors >= 2 {
                (Decision::Shrink, format!("{base}: pool exhausted, degrading to shrink"))
            } else {
                (
                    Decision::GlobalRestart,
                    format!("{base}: pool exhausted and too few survivors to shrink"),
                )
            }
        }
        PolicyKind::CostMin => {
            let est = costs::recovery_estimates(host, net, &GlobalCrModel::default(), &inputs.cost);
            let (d, secs) = cheapest_feasible(&est, inputs);
            (
                d,
                format!(
                    "policy=cost-min event={} failed={} warm_free={} cold_free={} \
                     est[s]: substitute={:.4} cold={:.4} shrink={:.4} restart={:.4} \
                     -> {} ({secs:.4}s)",
                    inputs.event_seq,
                    inputs.n_failed,
                    p.warm_free,
                    p.cold_free,
                    est.substitute,
                    est.substitute_cold,
                    est.shrink,
                    est.global_restart,
                    d.name(),
                ),
            )
        }
    }
}

/// Default capacity-horizon prior (inner iterations) when the operator has
/// not pinned `policy_horizon` and no convergence progress is observable
/// yet — the paper-era default the seed shipped with.
pub const DEFAULT_HORIZON_PRIOR: u64 = 50;

/// Leader-estimated inner iterations of work remaining, from observed
/// convergence progress (geometric extrapolation of the least-squares
/// residual), falling back to `prior` (the `policy_horizon` config key)
/// when no mid-cycle progress is visible.
///
/// Pure function of one rank's solver state — only the recovery *leader*
/// evaluates it; everyone else receives the result via
/// [`agreed_capacity_horizon`].
pub fn estimate_remaining_iters(state: &SolverState, tol: f64, prior: u64) -> u64 {
    let done = state.scalars.inner_iters_done;
    let Some(cycle) = state.cycle.as_ref() else {
        return prior;
    };
    if done == 0 || state.scalars.bnorm <= 0.0 {
        return prior;
    }
    let relres = cycle.ls.residual() / state.scalars.bnorm;
    if !relres.is_finite() || relres >= 1.0 {
        return prior;
    }
    if relres <= tol {
        return 0;
    }
    // relres ~ rho^done with rho = relres^(1/done); remaining iterations to
    // reach tol: done * ln(tol/relres) / ln(relres).
    let remaining = done as f64 * ((tol / relres).ln() / relres.ln());
    remaining.clamp(0.0, 1e12) as u64
}

/// The capacity horizon the `cost-min` policy prices shrink's lost capacity
/// with, tracking *actual remaining work* instead of the static
/// `policy_horizon` prior (ROADMAP open item; DESIGN.md §3).
///
/// Per-rank progress counters can differ by one iteration at the instant a
/// failure unblocks the survivors, so no rank may feed its *own* counter
/// into the decision — near a cost crossover two survivors could pick
/// different strategies and deadlock the repair.  Instead the recovery
/// leader (rank 0 of the post-shrink communicator) computes the estimate
/// from its local progress and broadcasts it; every survivor prices the
/// decision with the identical agreed value, keeping decisions
/// deterministic across survivors.
pub async fn agreed_capacity_horizon(
    ctx: &mut Ctx,
    shrunk: &mut Comm,
    state: &SolverState,
    tol: f64,
    prior: u64,
) -> MpiResult<u64> {
    let mine = if shrunk.rank == 0 {
        estimate_remaining_iters(state, tol, prior) as i64
    } else {
        0
    };
    let out = shrunk.bcast(ctx, Blob::from_i64s(vec![mine])).await?;
    Ok(out.i[0] as u64)
}

/// The cheapest strategy whose preconditions hold.  Global restart is the
/// always-feasible fallback, so the candidate set is never empty.
fn cheapest_feasible(est: &RecoveryEstimates, inputs: &PolicyInputs) -> (Decision, f64) {
    let p = &inputs.pool;
    let mut candidates: Vec<(Decision, f64)> = Vec::with_capacity(4);
    if p.warm_free >= inputs.n_failed {
        candidates.push((Decision::Substitute, est.substitute));
    } else if p.total_free() >= inputs.n_failed {
        // Short on warm spares: the event can still be covered if cold
        // slots make up the difference, at cold cost.
        candidates.push((Decision::SubstituteCold, est.substitute_cold));
    }
    if inputs.survivors >= 2 {
        candidates.push((Decision::Shrink, est.shrink));
    }
    candidates.push((Decision::GlobalRestart, est.global_restart));
    candidates
        .into_iter()
        .min_by(|a, b| a.1.partial_cmp(&b.1).expect("cost estimates are finite"))
        .expect("global restart is always a candidate")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs(warm_free: usize, cold_free: usize) -> PolicyInputs {
        PolicyInputs {
            n_failed: 1,
            survivors: 7,
            pool: PoolStatus { warm_free, cold_free },
            cost: RecoveryCostInputs {
                rows_per_rank: 2048,
                basis_vecs: 51,
                n_failed: 1,
                survivors: 7,
                buddy_k: 1,
                horizon_iters: 50,
                m_inner: 25,
                parity: costs::ParityShape::Mirror,
            },
            failures_so_far: 1,
            event_seq: 0,
        }
    }

    fn host() -> ComputeModel {
        ComputeModel::default()
    }

    fn net() -> NetParams {
        NetParams::default()
    }

    #[test]
    fn parse_surface() {
        assert_eq!(PolicyKind::parse("spares-first"), Some(PolicyKind::SparesFirst));
        assert_eq!(PolicyKind::parse("cost-min"), Some(PolicyKind::CostMin));
        assert_eq!(
            PolicyKind::parse("fixed:shrink"),
            Some(PolicyKind::Fixed(Decision::Shrink))
        );
        assert_eq!(
            PolicyKind::parse("fixed:substitute"),
            Some(PolicyKind::Fixed(Decision::Substitute))
        );
        assert_eq!(
            PolicyKind::parse("fixed:global-restart"),
            Some(PolicyKind::Fixed(Decision::GlobalRestart))
        );
        assert_eq!(PolicyKind::parse("fixed:bogus"), None);
        assert_eq!(PolicyKind::parse("bogus"), None);
        assert_eq!(PolicyKind::Fixed(Decision::SubstituteCold).name(), "fixed:substitute-cold");
    }

    #[test]
    fn fixed_never_adapts() {
        let (d, why) = decide(
            PolicyKind::Fixed(Decision::Substitute),
            &inputs(0, 0),
            &host(),
            &net(),
        );
        assert_eq!(d, Decision::Substitute);
        assert!(why.contains("fixed"));
    }

    #[test]
    fn spares_first_exhaustion_flips_substitute_to_shrink() {
        // Warm spare available: substitute.
        let (d, _) = decide(PolicyKind::SparesFirst, &inputs(1, 0), &host(), &net());
        assert_eq!(d, Decision::Substitute);
        // Warm pool dry, cold slot available: cold substitute.
        let (d, why) = decide(PolicyKind::SparesFirst, &inputs(0, 1), &host(), &net());
        assert_eq!(d, Decision::SubstituteCold);
        assert!(why.contains("cold"));
        // Pool fully exhausted: graceful degradation to shrink.
        let (d, why) = decide(PolicyKind::SparesFirst, &inputs(0, 0), &host(), &net());
        assert_eq!(d, Decision::Shrink);
        assert!(why.contains("exhausted"));
    }

    #[test]
    fn spares_first_global_restart_when_nothing_else_works() {
        let mut inp = inputs(0, 0);
        inp.survivors = 1;
        let (d, _) = decide(PolicyKind::SparesFirst, &inp, &host(), &net());
        assert_eq!(d, Decision::GlobalRestart);
    }

    #[test]
    fn cost_min_picks_shrink_when_redistribution_is_cheaper() {
        // Nearly-done run: no capacity horizon left, so shrink's
        // redistribution share beats shipping a full block to a spare.
        let mut inp = inputs(4, 0);
        inp.cost.horizon_iters = 0;
        let (d, why) = decide(PolicyKind::CostMin, &inp, &host(), &net());
        assert_eq!(d, Decision::Shrink, "{why}");
        assert!(why.contains("cost-min"));
    }

    #[test]
    fn cost_min_picks_substitute_when_capacity_matters() {
        // Long horizon: losing a rank for the rest of the run dominates.
        let mut inp = inputs(4, 0);
        inp.cost.horizon_iters = 100_000;
        let (d, why) = decide(PolicyKind::CostMin, &inp, &host(), &net());
        assert_eq!(d, Decision::Substitute, "{why}");
    }

    #[test]
    fn cost_min_respects_pool_feasibility() {
        // Substitution would win on cost, but the pool is dry.
        let mut inp = inputs(0, 0);
        inp.cost.horizon_iters = 100_000;
        let (d, _) = decide(PolicyKind::CostMin, &inp, &host(), &net());
        assert_eq!(d, Decision::Shrink);
    }

    #[test]
    fn horizon_estimate_extrapolates_observed_rate() {
        use crate::backend::DenseBasis;
        use crate::problem::{EllBlock, Grid3D, MatrixRows, Partition};
        use crate::solver::givens::GivensLs;
        use crate::solver::state::{CycleCtl, IterScalars, SolverState};
        let grid = Grid3D::cube(4);
        let part = Partition::balanced(grid.n(), 1);
        let mat = MatrixRows::generate(&grid, 0, grid.n());
        let blk = EllBlock::build(&mat, &part, 0);
        let rows = mat.rows;
        let mut state = SolverState {
            grid,
            part,
            mat,
            blk,
            x: vec![0.0; rows],
            b: vec![0.0; rows],
            v_out: DenseBasis::zeros(3, rows),
            z_out: DenseBasis::zeros(2, rows),
            cycle: None,
            scalars: IterScalars { inner_iters_done: 100, next_version: 1, bnorm: 1.0 },
            hwm_iters: 100,
        };
        // Between cycles there is no observable progress: the prior wins.
        assert_eq!(estimate_remaining_iters(&state, 1e-8, 42), 42);
        // Mid-cycle at relres 1e-4 after 100 iterations: extrapolating the
        // observed geometric rate needs ~100 more to reach 1e-8.
        state.cycle = Some(CycleCtl { j_done: 0, ls: GivensLs::new(2, 1e-4) });
        let h = estimate_remaining_iters(&state, 1e-8, 42);
        assert!((90..=110).contains(&h), "h={h}");
        // Already converged: nothing remains, shrink costs no capacity.
        state.cycle = Some(CycleCtl { j_done: 0, ls: GivensLs::new(2, 1e-9) });
        assert_eq!(estimate_remaining_iters(&state, 1e-8, 42), 0);
        // No iterations done yet: the prior wins.
        state.scalars.inner_iters_done = 0;
        state.cycle = Some(CycleCtl { j_done: 0, ls: GivensLs::new(2, 1e-4) });
        assert_eq!(estimate_remaining_iters(&state, 1e-8, 42), 42);
    }

    #[test]
    fn cost_min_charges_spawn_latency_to_cold_only_pools() {
        // Only cold slots left: the candidate is cold substitution, which
        // must carry the spawn latency in its estimate.
        let mut inp = inputs(0, 2);
        inp.cost.horizon_iters = 100_000;
        let (d, why) = decide(PolicyKind::CostMin, &inp, &host(), &net());
        assert_eq!(d, Decision::SubstituteCold, "{why}");
    }
}
