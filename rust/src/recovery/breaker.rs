//! Per-job recovery circuit breaker (DESIGN.md §16).
//!
//! A job whose node set keeps failing burns a spare (or a redistribution)
//! per event and can drain the whole fleet's pool.  The breaker watches the
//! job's recovery cadence in **virtual time** and evicts repeat offenders:
//!
//! * `CLOSED` — recoveries are admitted normally.  When `k` recoveries land
//!   inside one sliding `window` (seconds of virtual time, measured over
//!   canonical event times — the max registry death time of the failed set,
//!   never a caller's clock), the breaker **trips**.
//! * `OPEN` — the trip itself: the job is quarantined.  Its leases are
//!   released back to the shared pool and the event is escalated to one
//!   recorded global restart on a fresh node set.  The trip immediately
//!   arms the probe, so the observable resting state after a trip is
//!   `HALF_OPEN` (OPEN is instantaneous in a simulation where the restart
//!   executes synchronously with the decision).
//! * `HALF_OPEN` — probation.  The next recovery event is the probe: if it
//!   arrives within `window` of the trip, the node set is still failing and
//!   the breaker re-trips; if a clean window has elapsed, the breaker
//!   closes and the event is admitted as the first of a fresh count.
//!
//! Every transition is a pure function of `(k, window, event times)`, so
//! breaker behavior is bit-identical across engines and reruns.

/// Breaker state (the classic three-state machine).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum BreakerState {
    #[default]
    Closed,
    Open,
    HalfOpen,
}

impl BreakerState {
    pub fn name(self) -> &'static str {
        match self {
            BreakerState::Closed => "closed",
            BreakerState::Open => "open",
            BreakerState::HalfOpen => "half-open",
        }
    }
}

/// What the breaker says about one recovery event.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerVerdict {
    /// Proceed with ordinary arbitration.
    Admit,
    /// Quarantine: release the job's leases and take one recorded global
    /// restart instead of another in-situ recovery.
    Trip,
}

/// Sliding-window circuit breaker over one job's recovery events.
#[derive(Debug, Clone)]
pub struct Breaker {
    /// Recoveries inside one window that trip the breaker.
    pub k: usize,
    /// Sliding window length in virtual seconds.
    pub window: f64,
    state: BreakerState,
    /// Canonical event times admitted while `CLOSED` (pruned to the window).
    events: Vec<f64>,
    /// Trip instant of the most recent quarantine (probe reference).
    tripped_at: Option<f64>,
    trips: usize,
}

impl Breaker {
    pub fn new(k: usize, window: f64) -> Breaker {
        assert!(k >= 1, "breaker threshold must be >= 1");
        assert!(window > 0.0, "breaker window must be positive");
        Breaker { k, window, state: BreakerState::Closed, events: Vec::new(), tripped_at: None, trips: 0 }
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    /// Quarantine trips so far.
    pub fn trips(&self) -> usize {
        self.trips
    }

    fn trip(&mut self, t: f64) -> BreakerVerdict {
        self.trips += 1;
        self.events.clear();
        self.tripped_at = Some(t);
        // OPEN is instantaneous (the quarantine restart executes with the
        // decision); probation starts immediately.
        self.state = BreakerState::HalfOpen;
        BreakerVerdict::Trip
    }

    /// Feed one recovery event at canonical virtual time `t` (counted once
    /// per failure event — fence retries of the same event must not be
    /// re-fed).  Returns whether the event is admitted or quarantined.
    pub fn on_recovery(&mut self, t: f64) -> BreakerVerdict {
        match self.state {
            BreakerState::Closed => {
                self.events.retain(|&e| e > t - self.window);
                self.events.push(t);
                if self.events.len() >= self.k {
                    self.state = BreakerState::Open;
                    self.trip(t)
                } else {
                    BreakerVerdict::Admit
                }
            }
            BreakerState::Open | BreakerState::HalfOpen => {
                let since = t - self.tripped_at.unwrap_or(f64::NEG_INFINITY);
                if since <= self.window {
                    // Probe failed: the node set is still dying.
                    self.state = BreakerState::Open;
                    self.trip(t)
                } else {
                    // A clean window elapsed: close and admit this event as
                    // the first of a fresh count.
                    self.state = BreakerState::Closed;
                    self.tripped_at = None;
                    self.events.clear();
                    self.events.push(t);
                    BreakerVerdict::Admit
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trips_after_k_events_inside_the_window() {
        let mut b = Breaker::new(3, 10.0);
        assert_eq!(b.on_recovery(1.0), BreakerVerdict::Admit);
        assert_eq!(b.on_recovery(2.0), BreakerVerdict::Admit);
        assert_eq!(b.state(), BreakerState::Closed);
        assert_eq!(b.on_recovery(3.0), BreakerVerdict::Trip);
        assert_eq!(b.state(), BreakerState::HalfOpen);
        assert_eq!(b.trips(), 1);
    }

    #[test]
    fn window_slides_so_spaced_events_never_trip() {
        let mut b = Breaker::new(2, 1.0);
        assert_eq!(b.on_recovery(0.0), BreakerVerdict::Admit);
        assert_eq!(b.on_recovery(5.0), BreakerVerdict::Admit);
        assert_eq!(b.on_recovery(10.0), BreakerVerdict::Admit);
        assert_eq!(b.trips(), 0);
        // Two inside one window do trip.
        assert_eq!(b.on_recovery(10.5), BreakerVerdict::Trip);
    }

    #[test]
    fn half_open_probe_retrips_inside_the_window_and_closes_after_it() {
        let mut b = Breaker::new(2, 5.0);
        b.on_recovery(1.0);
        assert_eq!(b.on_recovery(2.0), BreakerVerdict::Trip);
        // Probe within the window of the trip: re-quarantine.
        assert_eq!(b.on_recovery(4.0), BreakerVerdict::Trip);
        assert_eq!(b.trips(), 2);
        // Next probe a clean window after the second trip: closed again.
        assert_eq!(b.on_recovery(20.0), BreakerVerdict::Admit);
        assert_eq!(b.state(), BreakerState::Closed);
        // The re-closed count starts fresh at the probe event.
        assert_eq!(b.on_recovery(21.0), BreakerVerdict::Trip);
        assert_eq!(b.trips(), 3);
    }

    #[test]
    fn identical_event_sequences_replay_identically() {
        let seq = [1.0, 1.5, 2.0, 9.0, 30.0, 30.1, 30.2];
        let run = |seq: &[f64]| {
            let mut b = Breaker::new(3, 5.0);
            seq.iter().map(|&t| b.on_recovery(t)).collect::<Vec<_>>()
        };
        assert_eq!(run(&seq), run(&seq));
    }
}
