//! Spare-pool bookkeeping: warm and cold spare capacity as a first-class
//! runtime resource (paper §IV-A; see DESIGN.md §3).
//!
//! The paper "assume[s] the presence of an adequate number of spares"; this
//! module drops that assumption so the recovery policy engine
//! ([`crate::recovery::policy`]) can react to the pool draining at runtime.
//! The pool itself is a *pure layout description*: which world ranks are
//! warm spares (allocated at job launch, idle until adopted — the paper's
//! "non-utilization of resources in the failure-free case") and which are
//! cold slots (processes spawned at failure time, paying
//! [`crate::netsim::NetParams::cold_spawn_latency`] before they join).
//!
//! Availability is always *derived* from the liveness registry plus the
//! current communicator membership, never cached: every survivor of a
//! failure must reach the identical policy decision independently, and the
//! registry is the only state they all observe consistently (the same
//! construction [`crate::recovery::substitute::assign_spares`] relies on).

pub mod lease;

pub use lease::{Lease, LeaseLedger};

use crate::simmpi::{World, WorldRank};

/// Static layout of the spare pool for one run.
///
/// World ranks `0..n_app` are application ranks, `n_app..n_app + warm` are
/// warm spares, and `n_app + warm..n_app + warm + cold` are cold slots.
/// Warm ranks sort below cold ranks, so the deterministic lowest-rank-first
/// assignment in [`crate::recovery::substitute::assign_spares`] naturally
/// drains warm spares before cold ones.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SparePool {
    /// Application process count (world ranks below this are not spares).
    pub n_app: usize,
    /// Warm spares allocated at launch.
    pub warm: usize,
    /// Cold slots that can be spawned at failure time.
    pub cold: usize,
}

/// Snapshot of how much of the pool is still usable, derived from the
/// liveness registry and the communicator membership at one instant.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct PoolStatus {
    /// Warm spares alive and not already serving in the communicator.
    pub warm_free: usize,
    /// Cold slots alive and not already serving in the communicator.
    pub cold_free: usize,
}

impl PoolStatus {
    /// Total spares still available.
    pub fn total_free(&self) -> usize {
        self.warm_free + self.cold_free
    }
}

impl SparePool {
    pub fn new(n_app: usize, warm: usize, cold: usize) -> SparePool {
        SparePool { n_app, warm, cold }
    }

    /// Total spare slots (warm + cold), i.e. how many extra rank threads the
    /// coordinator launches beyond the application ranks.
    pub fn total(&self) -> usize {
        self.warm + self.cold
    }

    /// Is `wr` any kind of spare slot?
    pub fn is_spare(&self, wr: WorldRank) -> bool {
        wr >= self.n_app && wr < self.n_app + self.total()
    }

    /// Is `wr` a warm spare slot?
    pub fn is_warm(&self, wr: WorldRank) -> bool {
        wr >= self.n_app && wr < self.n_app + self.warm
    }

    /// Is `wr` a cold slot?  Cold spares charge the spawn latency when they
    /// join (paper: "spawning processes at runtime has more overhead").
    pub fn is_cold(&self, wr: WorldRank) -> bool {
        wr >= self.n_app + self.warm && wr < self.n_app + self.total()
    }

    /// Availability snapshot: spares that are alive in the registry and not
    /// members of `in_use` (the communicator the failure hit — spares
    /// adopted by earlier recoveries appear there and are no longer free).
    pub fn status(&self, world: &World, in_use: &[WorldRank]) -> PoolStatus {
        let free = |wr: WorldRank| world.is_alive(wr) && !in_use.contains(&wr);
        PoolStatus {
            warm_free: (self.n_app..self.n_app + self.warm).filter(|&wr| free(wr)).count(),
            cold_free: (self.n_app + self.warm..self.n_app + self.total())
                .filter(|&wr| free(wr))
                .count(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::failure::{InjectionPlan, Injector};
    use crate::netsim::NetParams;

    #[test]
    fn rank_classification() {
        let pool = SparePool::new(8, 2, 1);
        assert_eq!(pool.total(), 3);
        assert!(!pool.is_spare(7));
        assert!(pool.is_warm(8));
        assert!(pool.is_warm(9));
        assert!(pool.is_cold(10));
        assert!(!pool.is_spare(11));
        assert!(!pool.is_cold(9));
    }

    #[test]
    fn status_excludes_dead_and_in_use() {
        let pool = SparePool::new(4, 2, 1);
        let w = crate::simmpi::World::new(
            4,
            3,
            NetParams::default(),
            Injector::new(InjectionPlan::none()),
        );
        // All free initially.
        let s = pool.status(&w, &[0, 1, 2, 3]);
        assert_eq!(s, PoolStatus { warm_free: 2, cold_free: 1 });
        // Warm spare 4 adopted into the communicator: no longer free.
        let s = pool.status(&w, &[0, 1, 2, 4]);
        assert_eq!(s.warm_free, 1);
        // A dead spare is not available either.
        w.mark_dead(5, 1.0);
        let s = pool.status(&w, &[0, 1, 2, 4]);
        assert_eq!(s, PoolStatus { warm_free: 0, cold_free: 1 });
        assert_eq!(s.total_free(), 1);
    }
}
