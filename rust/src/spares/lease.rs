//! Multi-tenant spare-pool lease ledger (DESIGN.md §16).
//!
//! A fleet of jobs shares one machine-wide spare pool.  Each substitution a
//! job is granted becomes a **lease**: an interval in virtual time during
//! which that many warm (or cold) slots are charged against the shared
//! capacity.  A lease opens at the failure event's canonical time and stays
//! open (`t1 = ∞`) until the fleet driver closes it — at the job's finish
//! time, or at its quarantine trip time when the circuit breaker evicts the
//! job and its slots return to the pool early.
//!
//! Availability is a pure function of the ledger and the query instant:
//! `warm_free_at(t)` is the total capacity minus every warm lease whose
//! interval covers `t`.  Because fleet jobs are arbitrated in a fixed
//! deterministic order and every lease timestamp is virtual, the ledger's
//! answers are identical across `--engine threads|events` and across reruns
//! — the same consistency contract as [`super::SparePool::status`], lifted
//! from one job's registry to the whole fleet's timeline.

use crate::spares::PoolStatus;

/// One granted spare reservation in fleet virtual time.
#[derive(Debug, Clone, PartialEq)]
pub struct Lease {
    /// Ledger-assigned id, monotonic in grant order.  Ids are never reused,
    /// even after a [`LeaseLedger::rescind`] removes an entry.
    pub id: usize,
    /// Index of the holding job in the fleet spec.
    pub job: usize,
    /// Warm lease (`true`) or cold-slot lease (`false`).
    pub warm: bool,
    /// Slots reserved (one per substituted rank).
    pub n: usize,
    /// Grant instant — the failure event's canonical virtual time.
    pub t0: f64,
    /// Release instant; `f64::INFINITY` while the lease is open.
    pub t1: f64,
}

impl Lease {
    /// Does this lease charge capacity at instant `t`?
    pub fn covers(&self, t: f64) -> bool {
        self.t0 <= t && t < self.t1
    }
}

/// The fleet-wide ledger: shared capacity plus every lease ever granted.
#[derive(Debug, Clone, Default)]
pub struct LeaseLedger {
    /// Machine-wide warm spare capacity.
    pub warm_total: usize,
    /// Machine-wide cold slot capacity.
    pub cold_total: usize,
    leases: Vec<Lease>,
    next_id: usize,
}

impl LeaseLedger {
    pub fn new(warm_total: usize, cold_total: usize) -> LeaseLedger {
        LeaseLedger { warm_total, cold_total, leases: Vec::new(), next_id: 0 }
    }

    /// Warm slots charged against the pool at instant `t`.
    fn warm_held_at(&self, t: f64) -> usize {
        self.leases.iter().filter(|l| l.warm && l.covers(t)).map(|l| l.n).sum()
    }

    fn cold_held_at(&self, t: f64) -> usize {
        self.leases.iter().filter(|l| !l.warm && l.covers(t)).map(|l| l.n).sum()
    }

    /// Free warm slots at instant `t`.
    pub fn warm_free_at(&self, t: f64) -> usize {
        self.warm_total.saturating_sub(self.warm_held_at(t))
    }

    /// Free cold slots at instant `t`.
    pub fn cold_free_at(&self, t: f64) -> usize {
        self.cold_total.saturating_sub(self.cold_held_at(t))
    }

    /// Fleet-level pool snapshot at instant `t` (the multi-tenant analogue
    /// of [`super::SparePool::status`]).
    pub fn status_at(&self, t: f64) -> PoolStatus {
        PoolStatus { warm_free: self.warm_free_at(t), cold_free: self.cold_free_at(t) }
    }

    /// Open a lease of `n` slots for `job` at instant `t`.  The caller must
    /// have checked availability; granting beyond capacity is a logic error.
    pub fn grant(&mut self, job: usize, warm: bool, n: usize, t: f64) -> usize {
        debug_assert!(
            n <= if warm { self.warm_free_at(t) } else { self.cold_free_at(t) },
            "lease over-grant: {n} slots requested, pool exhausted at t={t}"
        );
        let id = self.next_id;
        self.next_id += 1;
        self.leases.push(Lease { id, job, warm, n, t0: t, t1: f64::INFINITY });
        id
    }

    /// Drop an open lease entirely (an abandoned recovery attempt whose
    /// grant never materialized — e.g. the failure set grew and the event
    /// re-arbitrated on the union).  A lease that was already closed is
    /// history — it held real capacity over its interval — so it stays in
    /// the ledger and this call is a no-op for it.
    pub fn rescind(&mut self, id: usize) {
        self.leases.retain(|l| l.id != id || !l.t1.is_infinite());
    }

    /// Close every open lease held by `job` at instant `t_end` (job finish
    /// or quarantine trip): its slots return to the shared pool for any
    /// event arbitrated at a later instant.
    pub fn close_job(&mut self, job: usize, t_end: f64) {
        for l in &mut self.leases {
            if l.job == job && l.t1.is_infinite() {
                l.t1 = t_end.max(l.t0);
            }
        }
    }

    /// Jobs holding at least one warm lease covering instant `t`, with slot
    /// counts — the preemption-blame view the arbiter reports when a
    /// request is denied.
    pub fn warm_holders_at(&self, t: f64) -> Vec<(usize, usize)> {
        let mut out: Vec<(usize, usize)> = Vec::new();
        for l in self.leases.iter().filter(|l| l.warm && l.covers(t)) {
            match out.iter_mut().find(|(j, _)| *j == l.job) {
                Some((_, n)) => *n += l.n,
                None => out.push((l.job, l.n)),
            }
        }
        out
    }

    /// All leases, in grant order.
    pub fn leases(&self) -> &[Lease] {
        &self.leases
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overlapping_leases_deplete_capacity_only_inside_their_window() {
        let mut led = LeaseLedger::new(2, 1);
        assert_eq!(led.warm_free_at(0.0), 2);
        let a = led.grant(0, true, 1, 1.0);
        assert_eq!(led.warm_free_at(0.5), 2, "before the grant instant");
        assert_eq!(led.warm_free_at(1.0), 1, "grant instant is inclusive");
        led.grant(1, true, 1, 2.0);
        assert_eq!(led.warm_free_at(2.5), 0);
        assert_eq!(led.cold_free_at(2.5), 1, "cold capacity untouched");
        // Closing job 0 at t=3 frees its slot for later instants only.
        led.close_job(0, 3.0);
        assert_eq!(led.warm_free_at(2.5), 0);
        assert_eq!(led.warm_free_at(3.0), 1, "release instant is exclusive");
        assert_eq!(led.leases()[0].id, a);
    }

    #[test]
    fn rescind_drops_an_abandoned_grant() {
        let mut led = LeaseLedger::new(1, 0);
        let id = led.grant(0, true, 1, 1.0);
        assert_eq!(led.warm_free_at(1.0), 0);
        led.rescind(id);
        assert_eq!(led.warm_free_at(1.0), 1);
        assert!(led.leases().is_empty());
    }

    #[test]
    fn rescind_never_recycles_ids_onto_live_leases() {
        let mut led = LeaseLedger::new(4, 0);
        let a = led.grant(0, true, 1, 1.0);
        let b = led.grant(1, true, 1, 1.0);
        led.rescind(a);
        let c = led.grant(0, true, 2, 2.0);
        assert_ne!(c, b, "a rescinded slot must not re-issue a live lease's id");
        // Rescinding c must drop exactly c, not b.
        led.rescind(c);
        assert_eq!(led.leases().len(), 1);
        assert_eq!(led.leases()[0].id, b);
        assert_eq!(led.warm_free_at(2.5), 3);
    }

    #[test]
    fn rescind_leaves_closed_leases_as_history() {
        let mut led = LeaseLedger::new(2, 0);
        let id = led.grant(0, true, 1, 1.0);
        led.close_job(0, 3.0);
        led.rescind(id);
        assert_eq!(led.leases().len(), 1, "closed lease is history, not rescindable");
        assert_eq!(led.warm_free_at(2.0), 1, "its interval still charges capacity");
        assert_eq!(led.warm_free_at(3.0), 2);
    }

    #[test]
    fn holders_aggregate_by_job_for_preemption_blame() {
        let mut led = LeaseLedger::new(4, 0);
        led.grant(2, true, 1, 1.0);
        led.grant(2, true, 1, 1.5);
        led.grant(0, true, 2, 2.0);
        assert_eq!(led.warm_holders_at(2.0), vec![(2, 2), (0, 2)]);
        assert_eq!(led.warm_holders_at(1.2), vec![(2, 1)]);
        led.close_job(2, 3.0);
        assert_eq!(led.warm_holders_at(3.5), vec![(0, 2)]);
    }

    #[test]
    fn status_at_mirrors_the_free_counts() {
        let mut led = LeaseLedger::new(2, 2);
        led.grant(0, true, 1, 0.0);
        led.grant(1, false, 2, 0.0);
        let s = led.status_at(0.0);
        assert_eq!(s, PoolStatus { warm_free: 1, cold_free: 0 });
        assert_eq!(s.total_free(), 1);
    }
}
