//! Pure-Rust backend with modeled virtual compute cost.
//!
//! Numerically identical to the AOT graphs (same operation order up to
//! floating-point associativity in reductions — both reduce row-major over
//! K then rows, so results match bit-for-bit for these sizes; verified in
//! tests/backend_equivalence.rs).  Cost comes from the roofline
//! [`ComputeModel`], which makes figure campaigns deterministic on any host.

use crate::backend::{Backend, DenseBasis};
use crate::netsim::ComputeModel;
use crate::problem::laplacian::K;
use crate::problem::EllBlock;

#[derive(Debug, Clone)]
pub struct NativeBackend {
    pub model: ComputeModel,
}

impl NativeBackend {
    pub fn new(model: ComputeModel) -> Self {
        NativeBackend { model }
    }
}

impl Default for NativeBackend {
    fn default() -> Self {
        NativeBackend::new(ComputeModel::default())
    }
}

impl Backend for NativeBackend {
    fn spmv(&self, blk: &EllBlock, x_halo: &[f64], y: &mut [f64]) -> f64 {
        let r = blk.rows;
        debug_assert!(y.len() >= r && x_halo.len() >= blk.x_halo_len());
        for i in 0..r {
            let base = i * K;
            let mut acc = 0.0;
            for k in 0..K {
                acc += blk.vals[base + k] * x_halo[blk.cols[base + k] as usize];
            }
            y[i] = acc;
        }
        crate::backend::costs::spmv(&self.model, r, blk.x_halo_len())
    }

    fn dot_partials(&self, v: &DenseBasis, m_used: usize, w: &[f64], out: &mut [f64]) -> f64 {
        out.fill(0.0);
        for j in 0..m_used {
            let row = v.row(j);
            let mut acc = 0.0;
            for i in 0..v.r {
                acc += row[i] * w[i];
            }
            out[j] = acc;
        }
        crate::backend::costs::dot_partials(&self.model, m_used, v.r)
    }

    fn update_w(&self, v: &DenseBasis, m_used: usize, w: &mut [f64], h: &[f64]) -> (f64, f64) {
        for j in 0..m_used {
            let hj = h[j];
            if hj == 0.0 {
                continue;
            }
            let row = v.row(j);
            for i in 0..v.r {
                w[i] -= hj * row[i];
            }
        }
        let mut nsq = 0.0;
        for &wi in w.iter().take(v.r) {
            nsq += wi * wi;
        }
        (nsq, crate::backend::costs::update_w(&self.model, m_used, v.r))
    }

    fn update_x(&self, v: &DenseBasis, m_used: usize, y: &[f64], x: &mut [f64]) -> f64 {
        for j in 0..m_used {
            let yj = y[j];
            if yj == 0.0 {
                continue;
            }
            let row = v.row(j);
            for i in 0..v.r {
                x[i] += yj * row[i];
            }
        }
        crate::backend::costs::update_x(&self.model, m_used, v.r)
    }

    fn scale(&self, w: &mut [f64], alpha: f64) -> f64 {
        for wi in w.iter_mut() {
            *wi *= alpha;
        }
        crate::backend::costs::scale(&self.model, w.len())
    }

    fn name(&self) -> &'static str {
        "native"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::problem::{Grid3D, MatrixRows, Partition};

    fn blk() -> EllBlock {
        let g = Grid3D::cube(4);
        let part = Partition::balanced(g.n(), 1);
        let m = MatrixRows::generate(&g, 0, g.n());
        EllBlock::build(&m, &part, 0)
    }

    #[test]
    fn spmv_constant_vector() {
        let b = blk();
        let be = NativeBackend::default();
        let xh = vec![1.0; b.x_halo_len()];
        let mut y = vec![0.0; b.rows];
        let secs = be.spmv(&b, &xh, &mut y);
        assert!(secs > 0.0);
        // Laplacian * ones = 6 - (#neighbors); corner rows -> 3.
        assert_eq!(y[0], 3.0);
    }

    #[test]
    fn dots_and_update_w_consistency() {
        let be = NativeBackend::default();
        let r = 100;
        let mut v = DenseBasis::zeros(4, r);
        for j in 0..4 {
            for i in 0..r {
                v.row_mut(j)[i] = ((j * r + i) as f64 * 0.1).sin();
            }
        }
        let w0: Vec<f64> = (0..r).map(|i| (i as f64 * 0.2).cos()).collect();
        let mut h = vec![0.0; 5];
        be.dot_partials(&v, 3, &w0, &mut h);
        assert_eq!(h[3], 0.0, "masked slots stay zero");
        let mut w = w0.clone();
        let (nsq, _) = be.update_w(&v, 3, &mut w, &h);
        let manual: f64 = w.iter().map(|x| x * x).sum();
        assert!((nsq - manual).abs() < 1e-12);
    }

    #[test]
    fn update_x_and_scale() {
        let be = NativeBackend::default();
        let mut v = DenseBasis::zeros(2, 4);
        v.row_mut(0).copy_from_slice(&[1.0, 0.0, 0.0, 0.0]);
        v.row_mut(1).copy_from_slice(&[0.0, 1.0, 0.0, 0.0]);
        let mut x = vec![0.0; 4];
        be.update_x(&v, 2, &[2.0, 3.0], &mut x);
        assert_eq!(x, vec![2.0, 3.0, 0.0, 0.0]);
        be.scale(&mut x, 0.5);
        assert_eq!(x, vec![1.0, 1.5, 0.0, 0.0]);
    }
}
