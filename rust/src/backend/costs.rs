//! Shared virtual-cost formulas for the five solver ops, used by the native
//! backend and by the PJRT backend in modeled-clock mode (so both charge
//! identical virtual time for identical work).

use crate::netsim::ComputeModel;
use crate::problem::laplacian::K;

pub fn spmv(m: &ComputeModel, rows: usize, x_halo_len: usize) -> f64 {
    let bytes = (12 * rows * K + 8 * x_halo_len + 8 * rows) as f64;
    m.cost((2 * rows * K) as f64, bytes)
}

pub fn dot_partials(m: &ComputeModel, m_used: usize, r: usize) -> f64 {
    let work = (m_used * r) as f64;
    m.cost(2.0 * work, 8.0 * (work + r as f64))
}

pub fn update_w(m: &ComputeModel, m_used: usize, r: usize) -> f64 {
    let work = (m_used * r) as f64;
    m.cost(2.0 * work + 2.0 * r as f64, 8.0 * (work + 3.0 * r as f64))
}

pub fn update_x(m: &ComputeModel, m_used: usize, r: usize) -> f64 {
    let work = (m_used * r) as f64;
    m.cost(2.0 * work, 8.0 * (work + 2.0 * r as f64))
}

pub fn scale(m: &ComputeModel, r: usize) -> f64 {
    m.cost(r as f64, 16.0 * r as f64)
}
