//! Shared virtual-cost formulas: the five solver ops (used by the native
//! backend and by the PJRT backend in modeled-clock mode, so both charge
//! identical virtual time for identical work), plus the *a-priori recovery
//! cost estimates* the adaptive policy engine compares before committing to
//! a strategy (paper §IV's tradeoff as numbers; see DESIGN.md §3).
//!
//! The recovery estimates deliberately use only configuration-static and
//! registry-derived inputs (rows per rank, survivor count, pool state) so
//! that every survivor computes the identical estimate and the distributed
//! policy decision stays consistent without extra communication.

use crate::ckptstore::Scheme;
use crate::netsim::{ComputeModel, NetParams};
use crate::problem::laplacian::K;
use crate::recovery::global_restart::GlobalCrModel;

/// Shape of the checkpoint redundancy as the recovery estimates see it:
/// which encode/reconstruct formulas apply (mirror fetch, xor gather+fold,
/// or the rs2 double-stripe encode and two-erasure solve).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ParityShape {
    /// Buddy copies (also every parity scheme degraded below its
    /// activation bound).
    Mirror,
    /// Single XOR stripe per group of `g`.
    Xor {
        /// Parity-group size.
        g: usize,
    },
    /// Double parity (XOR + GF-weighted stripe) per group of `g`.
    Rs2 {
        /// Parity-group size.
        g: usize,
    },
}

impl ParityShape {
    /// The shape the configured scheme takes at communicator size `n`
    /// (inactive parity schemes degrade to mirror semantics).
    pub fn from_scheme(scheme: &Scheme, n: usize) -> ParityShape {
        match scheme {
            Scheme::Xor { g } if scheme.parity_active(n) => ParityShape::Xor { g: *g },
            Scheme::Rs2 { g } if scheme.parity_active(n) => ParityShape::Rs2 { g: *g },
            _ => ParityShape::Mirror,
        }
    }
}

pub fn spmv(m: &ComputeModel, rows: usize, x_halo_len: usize) -> f64 {
    let bytes = (12 * rows * K + 8 * x_halo_len + 8 * rows) as f64;
    m.cost((2 * rows * K) as f64, bytes)
}

pub fn dot_partials(m: &ComputeModel, m_used: usize, r: usize) -> f64 {
    let work = (m_used * r) as f64;
    m.cost(2.0 * work, 8.0 * (work + r as f64))
}

pub fn update_w(m: &ComputeModel, m_used: usize, r: usize) -> f64 {
    let work = (m_used * r) as f64;
    m.cost(2.0 * work + 2.0 * r as f64, 8.0 * (work + 3.0 * r as f64))
}

pub fn update_x(m: &ComputeModel, m_used: usize, r: usize) -> f64 {
    let work = (m_used * r) as f64;
    m.cost(2.0 * work, 8.0 * (work + 2.0 * r as f64))
}

pub fn scale(m: &ComputeModel, r: usize) -> f64 {
    m.cost(r as f64, 16.0 * r as f64)
}

// ---------------------------------------------------------------------
// Recovery cost estimates (policy-engine inputs)
// ---------------------------------------------------------------------

/// Configuration- and registry-derived inputs to the recovery estimates.
/// Everything here is identical on every survivor of the same failure
/// event: `rows_per_rank` comes from the grid and the old communicator
/// size, pool/survivor counts from the liveness registry, and the rest from
/// the run configuration.
#[derive(Debug, Clone, Copy)]
pub struct RecoveryCostInputs {
    /// Block rows per rank under the failed communicator's partition.
    pub rows_per_rank: usize,
    /// Checkpointed basis vectors per rank (outer V + Z slots).
    pub basis_vecs: usize,
    /// Ranks lost in this failure event.
    pub n_failed: usize,
    /// Ranks that survive the event.
    pub survivors: usize,
    /// Buddy copies per checkpointed object.
    pub buddy_k: usize,
    /// Inner iterations the policy assumes remain (the capacity-loss
    /// horizon; config key `policy_horizon`).
    ///
    /// Deliberately a *static* config value, not the work actually
    /// remaining: per-rank progress counters can differ by one iteration
    /// between survivors at the instant a failure unblocks them, and a
    /// dynamic horizon read from them could flip the decision on ranks
    /// near a cost crossover — divergent decisions deadlock the repair.
    /// A truly consistent dynamic horizon needs a leader decision
    /// broadcast over the post-shrink communicator (future work noted in
    /// DESIGN.md §3); until then the horizon is the operator's prior.
    pub horizon_iters: u64,
    /// Inner iterations per outer step (sizes the per-iteration estimate).
    pub m_inner: usize,
    /// Active redundancy shape ([`ParityShape::from_scheme`]).  Shifts the
    /// per-strategy estimates: parity reconstruction gathers surviving
    /// member blobs plus a fold (and, for rs2, the second stripe and the
    /// GF solve) instead of one buddy fetch, while re-encoding ships parity
    /// contributions instead of `k` full copies.
    pub parity: ParityShape,
}

/// Estimated seconds for each recovery strategy, comparable against each
/// other (the `cost-min` policy picks the minimum over the feasible set).
#[derive(Debug, Clone, Copy)]
pub struct RecoveryEstimates {
    pub substitute: f64,
    pub substitute_cold: f64,
    pub shrink: f64,
    pub global_restart: f64,
}

/// Checkpointed state bytes per rank: ELL values + global columns (8 B
/// each), solution and RHS blocks, and the outer Krylov bases, scaled by
/// the campaign's workload scale (see [`NetParams::data_scale`]).
pub fn state_bytes_per_rank(net: &NetParams, rows: usize, basis_vecs: usize) -> f64 {
    8.0 * rows as f64 * (2.0 * K as f64 + 2.0 + basis_vecs as f64) * net.data_scale
}

/// One point-to-point inter-node transfer of `bytes`.
fn inter_xfer(net: &NetParams, bytes: f64) -> f64 {
    net.inter_latency + bytes / net.inter_bandwidth
}

/// Modeled seconds to XOR-fold `bytes` of parity (memory-bound: read two
/// streams, write one).
pub fn xor_fold_secs(m: &ComputeModel, bytes: f64) -> f64 {
    m.cost(bytes / 8.0, 3.0 * bytes)
}

/// Modeled seconds to GF(2^8)-multiply `bytes` of stripe data (byte-wise
/// table lookups: ~2 ops and 3 streamed bytes per byte).
pub fn gf_mul_secs(m: &ComputeModel, bytes: f64) -> f64 {
    m.cost(2.0 * bytes, 3.0 * bytes)
}

/// Seconds to re-encode one rank's checkpoint redundancy after recovery:
/// `k` full buddy copies under mirror; one parity contribution plus the
/// stripe fold under xor; under rs2 additionally the amortized share of
/// the combined Q forward (`state / g` per member) plus the weighted fold.
pub fn reencode_secs(
    host: &ComputeModel,
    net: &NetParams,
    state_bytes: f64,
    buddy_k: usize,
    parity: ParityShape,
) -> f64 {
    match parity {
        ParityShape::Mirror => buddy_k as f64 * inter_xfer(net, state_bytes),
        ParityShape::Xor { .. } => {
            inter_xfer(net, state_bytes) + xor_fold_secs(host, state_bytes)
        }
        ParityShape::Rs2 { g } => {
            inter_xfer(net, state_bytes * (1.0 + 1.0 / g as f64))
                + xor_fold_secs(host, 2.0 * state_bytes)
                + gf_mul_secs(host, state_bytes)
        }
    }
}

/// Seconds to rebuild one failed rank's state from the store: one buddy
/// fetch under mirror; a gather of `g-1` surviving member blobs plus the
/// parity fold under xor (the group-reconstruction the recovery reader
/// runs); under rs2 the gather additionally pulls up to two stripes and
/// pays the GF-weighted fold and solve — followed by the ship to wherever
/// the state is needed.
pub fn reconstruct_secs(
    host: &ComputeModel,
    net: &NetParams,
    state_bytes: f64,
    parity: ParityShape,
) -> f64 {
    match parity {
        ParityShape::Mirror => inter_xfer(net, state_bytes),
        ParityShape::Xor { g } => {
            let gather = inter_xfer(net, (g.saturating_sub(1)) as f64 * state_bytes);
            gather + xor_fold_secs(host, g as f64 * state_bytes) + inter_xfer(net, state_bytes)
        }
        ParityShape::Rs2 { g } => {
            let gather = inter_xfer(net, (g.saturating_sub(1) + 2) as f64 * state_bytes);
            gather
                + xor_fold_secs(host, (g + 2) as f64 * state_bytes)
                + gf_mul_secs(host, 2.0 * state_bytes)
                + inter_xfer(net, state_bytes)
        }
    }
}

/// Modeled seconds of one inner solver iteration at this block size (SpMV
/// plus the orthogonalization ops), used to price the capacity lost by
/// shrinking over the policy horizon.
pub fn inner_iter_secs(m: &ComputeModel, rows: usize, m_inner: usize) -> f64 {
    spmv(m, rows, rows) + dot_partials(m, m_inner, rows) + update_w(m, m_inner, rows)
}

/// A-priori per-strategy recovery cost estimates (paper §IV as a decision
/// aid; see DESIGN.md §3 for the derivation and its deliberate coarseness):
///
/// * **substitute** — ship one failed rank's full checkpointed state from
///   its buddy to the spare node, rebuild locally, then re-establish every
///   buddy checkpoint over the restored configuration;
/// * **substitute-cold** — the same plus the cold-spawn latency;
/// * **shrink** — redistribute the failed blocks plus the rebalancing shift
///   over the survivors (≈ `2 * S * f / s` bytes per survivor), rebuild,
///   re-establish checkpoints, *plus* the slowdown of finishing the
///   remaining `horizon_iters` on fewer ranks — the term that makes shrink
///   lose to substitute early in a run and win once spares run dry or the
///   run is nearly done;
/// * **global_restart** — the paper's §I strawman, priced by the analytic
///   [`GlobalCrModel`]; in-situ strategies beat it by orders of magnitude,
///   which is exactly the paper's motivating contrast.
pub fn recovery_estimates(
    host: &ComputeModel,
    net: &NetParams,
    global: &GlobalCrModel,
    inp: &RecoveryCostInputs,
) -> RecoveryEstimates {
    let s_bytes = state_bytes_per_rank(net, inp.rows_per_rank, inp.basis_vecs);
    let rebuild = host.cost(
        (inp.rows_per_rank * K) as f64,
        (24 * inp.rows_per_rank * K) as f64,
    );
    let reestablish = reencode_secs(host, net, s_bytes, inp.buddy_k, inp.parity);
    let fetch = reconstruct_secs(host, net, s_bytes, inp.parity);

    let substitute = fetch + rebuild + reestablish;
    let substitute_cold = substitute + net.cold_spawn_latency;

    let survivors = inp.survivors.max(1) as f64;
    let redistribution =
        inter_xfer(net, 2.0 * s_bytes * inp.n_failed as f64 / survivors);
    // Shrink also rebuilds the failed blocks before redistributing them —
    // free under mirror relative to the redistribution it overlaps with,
    // but a real gather+fold round under the parity schemes.
    let shrink_fetch = match inp.parity {
        ParityShape::Mirror => 0.0,
        ParityShape::Xor { .. } | ParityShape::Rs2 { .. } => fetch * inp.n_failed as f64,
    };
    let capacity_loss = inner_iter_secs(host, inp.rows_per_rank, inp.m_inner)
        * inp.horizon_iters as f64
        * inp.n_failed as f64
        / survivors;
    let shrink = shrink_fetch + redistribution + rebuild + reestablish + capacity_loss;

    let total_bytes = s_bytes * (inp.survivors + inp.n_failed) as f64;
    let global_restart = global.waste_per_failure(total_bytes as usize);

    RecoveryEstimates { substitute, substitute_cold, shrink, global_restart }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn inputs() -> RecoveryCostInputs {
        RecoveryCostInputs {
            rows_per_rank: 4096,
            basis_vecs: 51,
            n_failed: 1,
            survivors: 31,
            buddy_k: 1,
            horizon_iters: 50,
            m_inner: 25,
            parity: ParityShape::Mirror,
        }
    }

    #[test]
    fn cold_costs_spawn_latency_more_than_warm() {
        let net = NetParams::default();
        let est = recovery_estimates(
            &ComputeModel::default(),
            &net,
            &GlobalCrModel::default(),
            &inputs(),
        );
        let diff = est.substitute_cold - est.substitute;
        assert!((diff - net.cold_spawn_latency).abs() < 1e-12);
    }

    #[test]
    fn global_restart_dwarfs_in_situ() {
        let est = recovery_estimates(
            &ComputeModel::default(),
            &NetParams::default(),
            &GlobalCrModel::default(),
            &inputs(),
        );
        assert!(est.global_restart > 10.0 * est.substitute);
        assert!(est.global_restart > 10.0 * est.shrink);
    }

    #[test]
    fn xor_trades_cheaper_reencode_for_costlier_reconstruction() {
        let host = ComputeModel::default();
        let net = NetParams::default();
        // Reconstruction: gathering g-1 blobs + fold beats one buddy fetch
        // only in memory, never in time.
        let s = state_bytes_per_rank(&net, 4096, 51);
        let (mir, xor4) = (ParityShape::Mirror, ParityShape::Xor { g: 4 });
        assert!(
            reconstruct_secs(&host, &net, s, xor4) > reconstruct_secs(&host, &net, s, mir)
        );
        // Re-encode: one parity contribution vs k=2 full copies.
        assert!(reencode_secs(&host, &net, s, 2, xor4) < reencode_secs(&host, &net, s, 2, mir));
        // End-to-end: the xor substitute estimate carries the gather.
        let mut inp = inputs();
        let base = recovery_estimates(&host, &net, &GlobalCrModel::default(), &inp);
        inp.parity = xor4;
        let xor = recovery_estimates(&host, &net, &GlobalCrModel::default(), &inp);
        assert!(xor.substitute > base.substitute, "{xor:?} vs {base:?}");
    }

    #[test]
    fn rs2_costs_sit_between_xor_and_mirror_reencode_and_above_xor_solve() {
        let host = ComputeModel::default();
        let net = NetParams::default();
        let s = state_bytes_per_rank(&net, 4096, 51);
        let (mir, xor4, rs2) =
            (ParityShape::Mirror, ParityShape::Xor { g: 4 }, ParityShape::Rs2 { g: 4 });
        // Second stripe: re-encode costs more than xor (forward share +
        // weighted fold) but still beats shipping k=2 full mirror copies.
        assert!(reencode_secs(&host, &net, s, 2, rs2) > reencode_secs(&host, &net, s, 2, xor4));
        assert!(reencode_secs(&host, &net, s, 2, rs2) < reencode_secs(&host, &net, s, 2, mir));
        // Two-erasure solve: strictly costlier than the single-stripe fold.
        assert!(reconstruct_secs(&host, &net, s, rs2) > reconstruct_secs(&host, &net, s, xor4));
        // Shape derivation honors the activation bounds.
        assert_eq!(ParityShape::from_scheme(&Scheme::Rs2 { g: 4 }, 8), rs2);
        assert_eq!(ParityShape::from_scheme(&Scheme::Rs2 { g: 4 }, 5), mir);
        assert_eq!(ParityShape::from_scheme(&Scheme::Xor { g: 4 }, 4), mir);
        assert_eq!(ParityShape::from_scheme(&Scheme::Mirror { k: 2 }, 8), mir);
    }

    #[test]
    fn horizon_shifts_shrink_vs_substitute() {
        let host = ComputeModel::default();
        let net = NetParams::default();
        let global = GlobalCrModel::default();
        // No remaining work: shrink pays no capacity penalty and its
        // redistribution share (2S/31) is cheaper than shipping a full
        // block to the spare (S), so shrink wins.
        let mut inp = inputs();
        inp.horizon_iters = 0;
        let est = recovery_estimates(&host, &net, &global, &inp);
        assert!(
            est.shrink < est.substitute,
            "short horizon must favor shrink: {est:?}"
        );
        // A long horizon makes the lost capacity dominate: substitute wins.
        inp.horizon_iters = 100_000;
        let est = recovery_estimates(&host, &net, &global, &inp);
        assert!(
            est.substitute < est.shrink,
            "long horizon must favor substitute: {est:?}"
        );
    }
}

