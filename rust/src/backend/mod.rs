//! Compute backends for the per-rank solver step graphs.
//!
//! Two implementations of the same five-op surface as the AOT artifacts:
//!
//! * [`native::NativeBackend`] — pure Rust, used by the deterministic
//!   figure campaigns (virtual compute cost from
//!   [`crate::netsim::ComputeModel`]);
//! * [`crate::runtime::PjrtEngine`] — loads `artifacts/*.hlo.txt` and runs
//!   them on the PJRT CPU client (the production path; Python is never
//!   involved at runtime).
//!
//! Each op returns the *virtual seconds* to charge the calling rank's clock.
//! tests/backend_equivalence.rs asserts both backends produce identical
//! numerics.

pub mod costs;
pub mod native;

use crate::problem::EllBlock;

/// Row-major (m x r) Krylov basis storage.
///
/// `id`/`gen` form the device-buffer cache key used by the PJRT runtime:
/// `id` is unique per allocation, `gen` bumps on every mutation, so the
/// runtime can keep the (large) basis resident on the device across the
/// several ops of one solver step that read it unchanged.
#[derive(Debug)]
pub struct DenseBasis {
    pub m: usize,
    pub r: usize,
    pub data: Vec<f64>,
    id: u64,
    gen: u64,
}

fn next_basis_id() -> u64 {
    use std::sync::atomic::{AtomicU64, Ordering};
    static NEXT: AtomicU64 = AtomicU64::new(1);
    NEXT.fetch_add(1, Ordering::Relaxed)
}

impl Clone for DenseBasis {
    fn clone(&self) -> Self {
        // A clone is a distinct mutable object: fresh cache identity.
        DenseBasis { m: self.m, r: self.r, data: self.data.clone(), id: next_basis_id(), gen: 0 }
    }
}

impl DenseBasis {
    pub fn zeros(m: usize, r: usize) -> Self {
        DenseBasis { m, r, data: vec![0.0; m * r], id: next_basis_id(), gen: 0 }
    }

    pub fn row(&self, j: usize) -> &[f64] {
        &self.data[j * self.r..(j + 1) * self.r]
    }

    pub fn row_mut(&mut self, j: usize) -> &mut [f64] {
        self.gen += 1;
        &mut self.data[j * self.r..(j + 1) * self.r]
    }

    pub fn reset(&mut self) {
        self.gen += 1;
        self.data.fill(0.0);
    }

    /// Device-cache key (id, generation).
    pub fn cache_key(&self) -> (u64, u64) {
        (self.id, self.gen)
    }
}

/// The five solver step ops (mirror of `python/compile/model.py::GRAPHS`).
/// `m_used` is the number of live basis vectors (the mask in the HLO graphs).
pub trait Backend: Send + Sync {
    /// y = A_local * x_halo.  Returns virtual seconds.
    fn spmv(&self, blk: &EllBlock, x_halo: &[f64], y: &mut [f64]) -> f64;

    /// out[0..m_used] = V[0..m_used] . w (local partials); rest zeroed.
    fn dot_partials(&self, v: &DenseBasis, m_used: usize, w: &[f64], out: &mut [f64]) -> f64;

    /// w -= V[0..m_used]^T h[0..m_used]; returns (local `<w,w>`, seconds).
    fn update_w(&self, v: &DenseBasis, m_used: usize, w: &mut [f64], h: &[f64]) -> (f64, f64);

    /// x += V[0..m_used]^T y[0..m_used].
    fn update_x(&self, v: &DenseBasis, m_used: usize, y: &[f64], x: &mut [f64]) -> f64;

    /// w *= alpha.
    fn scale(&self, w: &mut [f64], alpha: f64) -> f64;

    fn name(&self) -> &'static str;
}
