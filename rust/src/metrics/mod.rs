//! Per-rank phase accounting in virtual time.
//!
//! The paper's figures are all ratios of phase times (checkpoint, recovery,
//! reconfiguration, recomputation) to total time-to-solution.  Every virtual
//! second a rank spends is charged to exactly one [`Phase`]; the campaign
//! report aggregates per-rank timelines into the numbers Figures 4-6 plot.

use std::collections::BTreeMap;

/// What a rank is doing while virtual time advances.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    /// Local numerical work (SpMV, orthogonalization, updates).
    Compute,
    /// Ordinary solver communication (halo exchange, allreduce).
    Comm,
    /// Creating / shipping in-memory checkpoints to buddies.
    Checkpoint,
    /// State recovery after a failure (redistribution, restore, buddy
    /// re-establishment) — the paper's "recovery" overhead.
    Recovery,
    /// ULFM communicator repair: revoke, agreement, shrink, spare stitching —
    /// the paper's "reconfiguration" overhead.
    Reconfig,
    /// Re-executing iterations that were already done before a failure
    /// rolled the solver back to the last checkpoint.
    Recompute,
    /// Waiting for spares to be used (spare ranks only).
    Idle,
}

pub const ALL_PHASES: [Phase; 7] = [
    Phase::Compute,
    Phase::Comm,
    Phase::Checkpoint,
    Phase::Recovery,
    Phase::Reconfig,
    Phase::Recompute,
    Phase::Idle,
];

impl Phase {
    /// Stable lowercase name, used by trace exports and report tables.
    pub fn name(self) -> &'static str {
        match self {
            Phase::Compute => "compute",
            Phase::Comm => "comm",
            Phase::Checkpoint => "checkpoint",
            Phase::Recovery => "recovery",
            Phase::Reconfig => "reconfig",
            Phase::Recompute => "recompute",
            Phase::Idle => "idle",
        }
    }

    fn index(self) -> usize {
        match self {
            Phase::Compute => 0,
            Phase::Comm => 1,
            Phase::Checkpoint => 2,
            Phase::Recovery => 3,
            Phase::Reconfig => 4,
            Phase::Recompute => 5,
            Phase::Idle => 6,
        }
    }
}

/// Accumulated virtual seconds per phase for one rank.
#[derive(Debug, Clone, Default)]
pub struct PhaseTimers {
    pub compute: f64,
    pub comm: f64,
    pub checkpoint: f64,
    pub recovery: f64,
    pub reconfig: f64,
    pub recompute: f64,
    pub idle: f64,
}

impl PhaseTimers {
    pub fn charge(&mut self, phase: Phase, dt: f64) {
        debug_assert!(dt >= 0.0, "negative phase charge {dt}");
        match phase {
            Phase::Compute => self.compute += dt,
            Phase::Comm => self.comm += dt,
            Phase::Checkpoint => self.checkpoint += dt,
            Phase::Recovery => self.recovery += dt,
            Phase::Reconfig => self.reconfig += dt,
            Phase::Recompute => self.recompute += dt,
            Phase::Idle => self.idle += dt,
        }
    }

    pub fn get(&self, phase: Phase) -> f64 {
        match phase {
            Phase::Compute => self.compute,
            Phase::Comm => self.comm,
            Phase::Checkpoint => self.checkpoint,
            Phase::Recovery => self.recovery,
            Phase::Reconfig => self.reconfig,
            Phase::Recompute => self.recompute,
            Phase::Idle => self.idle,
        }
    }

    pub fn total(&self) -> f64 {
        ALL_PHASES.iter().map(|&p| self.get(p)).sum()
    }

    /// Element-wise max — campaign reports use the max over ranks because
    /// time-to-solution is set by the slowest process.
    pub fn max_with(&mut self, other: &PhaseTimers) {
        for p in ALL_PHASES {
            let m = self.get(p).max(other.get(p));
            self.set(p, m);
        }
    }

    fn set(&mut self, phase: Phase, v: f64) {
        match phase {
            Phase::Compute => self.compute = v,
            Phase::Comm => self.comm = v,
            Phase::Checkpoint => self.checkpoint = v,
            Phase::Recovery => self.recovery = v,
            Phase::Reconfig => self.reconfig = v,
            Phase::Recompute => self.recompute = v,
            Phase::Idle => self.idle = v,
        }
    }
}

/// Order statistics of one phase's per-rank virtual seconds.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct PhaseStat {
    pub p50: f64,
    pub p95: f64,
    pub max: f64,
}

/// Cross-rank per-phase distributions (nearest-rank percentiles over the
/// surviving ranks) — the spread behind the `max_phases` headline.
#[derive(Debug, Clone, Default)]
pub struct PhaseDist {
    stats: [PhaseStat; 7],
}

impl PhaseDist {
    pub fn from_timers<'a, I>(timers: I) -> Self
    where
        I: Iterator<Item = &'a PhaseTimers> + Clone,
    {
        let mut out = PhaseDist::default();
        for p in ALL_PHASES {
            let mut vals: Vec<f64> = timers.clone().map(|t| t.get(p)).collect();
            vals.sort_by(f64::total_cmp);
            out.stats[p.index()] = PhaseStat {
                p50: percentile(&vals, 0.50),
                p95: percentile(&vals, 0.95),
                max: vals.last().copied().unwrap_or(0.0),
            };
        }
        out
    }

    pub fn get(&self, p: Phase) -> PhaseStat {
        self.stats[p.index()]
    }
}

/// Nearest-rank percentile of an ascending-sorted sample (0.0 if empty).
fn percentile(sorted: &[f64], q: f64) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let k = ((q * sorted.len() as f64).ceil() as usize).clamp(1, sorted.len());
    sorted[k - 1]
}

/// One recovery-policy decision, recorded at the moment a survivor chose a
/// strategy for a failure event (see [`crate::recovery::policy`]).  The
/// campaign reports aggregate these so every figure row can be traced back
/// to *which* strategy handled *which* failure and *why*.
#[derive(Debug, Clone)]
pub struct DecisionRecord {
    /// 0-based failure-event sequence number on the recording rank.
    pub seq: usize,
    /// Virtual time at which the decision was made.
    pub at: f64,
    /// World ranks this event lost (failed members of the old comm).
    pub failed_ranks: Vec<usize>,
    /// Chosen strategy name (`shrink`, `substitute`, ...).
    pub decision: &'static str,
    /// Human-readable explanation produced by the policy engine.
    pub reason: String,
    /// Warm spares still free at decision time.
    pub warm_free: usize,
    /// Cold slots still free at decision time.
    pub cold_free: usize,
    /// Epoch-fence attempt that *executed* this decision (0 = the first
    /// attempt went through clean; n > 0 = n earlier attempts of this event
    /// were abandoned because further failures poisoned them, and the
    /// decision was re-made on the union failure set — see
    /// [`crate::recovery::handle_failure_fenced`]).
    pub attempt: usize,
}

/// One checkpoint commit as observed by one rank: how many bytes the full
/// state was worth, how many actually went on the wire for redundancy
/// (buddy copies, deltas or parity contributions), and the modeled encode
/// time (see [`crate::ckptstore`]).  Run reports merge these per version so
/// the checkpoint-overhead figures can plot bytes shipped per commit.
#[derive(Debug, Clone)]
pub struct CkptRecord {
    /// Committed checkpoint version.
    pub version: i64,
    /// Virtual time of the commit on the recording rank.
    pub at: f64,
    /// Charged bytes of the full object set (the redundancy input).
    pub logical_bytes: usize,
    /// Charged bytes this rank shipped for redundancy (post-compression
    /// when `ckpt_compress` is on).
    pub shipped_bytes: usize,
    /// Charged bytes the same payloads would have cost uncompressed;
    /// equals `shipped_bytes` when compression is off.
    pub raw_bytes: usize,
    /// Whether this commit shipped chunk deltas (vs full payloads).
    pub delta: bool,
    /// rs2 holder-rotation index of this commit (-1 for schemes without
    /// rotation).
    pub rotation: i64,
    /// Modeled encode/fold seconds spent by this rank.
    pub encode_secs: f64,
}

/// Degraded-fault observability counters (DESIGN.md §14): how often the
/// lossy-link retransmit path fired and what the checkpoint scrubber found
/// and fixed.  Zero across the board for pure crash-stop campaigns.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct FaultCounters {
    /// Data-message retransmits after an injected link drop
    /// ([`crate::failure::LinkFault`]); counts retries, not failed sends —
    /// a send that exhausts the retry budget also revokes the epoch.
    pub link_retries: u64,
    /// Committed checkpoint chunks whose stored checksum mismatched at a
    /// scrub pass (injected silent data corruption, detected).
    pub scrub_detected: u64,
    /// Corrupt chunks repaired in place from mirror/xor/rs2 parity; a
    /// shortfall vs `scrub_detected` escalated to the recovery policy.
    pub scrub_repaired: u64,
}

impl FaultCounters {
    /// Element-wise sum (campaign aggregation over ranks).
    pub fn add(&mut self, other: &FaultCounters) {
        self.link_retries += other.link_retries;
        self.scrub_detected += other.scrub_detected;
        self.scrub_repaired += other.scrub_repaired;
    }
}

/// Final report for one rank of one run.
#[derive(Debug, Clone)]
pub struct RankReport {
    pub world_rank: usize,
    /// Final virtual clock (seconds since run start).
    pub finish_time: f64,
    pub phases: PhaseTimers,
    /// Total inner iterations this rank executed (incl. recomputation).
    pub iterations: u64,
    /// Whether this rank was killed by the injector.
    pub killed: bool,
    /// Whether this rank started as a spare.
    pub was_spare: bool,
    /// Recovery decisions this rank participated in, in event order.
    pub decisions: Vec<DecisionRecord>,
    /// Checkpoint commits this rank participated in, in version order.
    pub ckpt: Vec<CkptRecord>,
    /// Recovery attempts this rank abandoned through the epoch fence
    /// (nested failures poisoning in-flight recovery protocol).
    pub recovery_retries: u64,
    /// Degraded-fault counters (link retries, scrub detections/repairs).
    pub faults: FaultCounters,
    /// Virtual-time trace stream (empty unless `RunConfig::trace` is on) —
    /// see [`crate::trace`].
    pub trace: Vec<crate::trace::TraceEvent>,
}

/// Aggregated result of one solver run (one configuration, one campaign leg).
#[derive(Debug, Clone)]
pub struct RunReport {
    /// Virtual time-to-solution: max finish time over surviving ranks.
    pub time_to_solution: f64,
    /// Per-phase maxima over surviving ranks.
    pub max_phases: PhaseTimers,
    /// Per-phase means over surviving ranks.
    pub mean_phases: PhaseTimers,
    pub ranks: Vec<RankReport>,
    /// Final relative residual reached by the solver.
    pub final_relres: f64,
    /// Total inner iterations of the surviving solve (max over ranks).
    pub iterations: u64,
    pub converged: bool,
    /// Number of failures actually injected.
    pub failures: usize,
    /// Per-event recovery decisions, merged over the surviving ranks'
    /// logs: records are ordered by decision time and deduplicated by the
    /// failed-rank set (unique per event, since deaths are permanent), then
    /// renumbered.  Merging — rather than taking any one rank's log —
    /// keeps the report complete even when every witness of an early event
    /// was itself killed later and only mid-run-adopted spares finished.
    /// Decisions are deterministic across survivors of the same event (see
    /// [`crate::recovery::policy`]), so deduplication is exact.
    pub decisions: Vec<DecisionRecord>,
    /// Per-commit checkpoint records, merged over the surviving ranks'
    /// logs and grouped by version: byte counts are summed across ranks
    /// (total wire volume of the commit), times are maxima.
    pub ckpt: Vec<CkptRecord>,
    /// Recovery-epoch retries: max over surviving ranks of abandoned
    /// recovery attempts (retries are per event and near-identical across
    /// survivors, so the max counts events-worth of retries, not the
    /// rank-count multiple a sum would).
    pub recovery_retries: u64,
    /// Degraded-fault counters summed over the surviving ranks (retries
    /// and scrub events are disjoint per rank, so the sum is the campaign
    /// total — unlike recovery retries, which survivors witness jointly).
    pub faults: FaultCounters,
    /// Cross-rank per-phase distributions over the surviving ranks.
    pub phase_dist: PhaseDist,
    /// Recovery critical-path analysis ([`crate::trace::critical_path`]);
    /// `None` unless the run was traced.
    pub critical_path: Option<crate::trace::CriticalPathReport>,
}

impl RunReport {
    pub fn from_ranks(ranks: Vec<RankReport>, final_relres: f64, converged: bool, failures: usize) -> Self {
        let survivors: Vec<&RankReport> =
            ranks.iter().filter(|r| !r.killed && !r.was_spare_unused()).collect();
        let n = survivors.len().max(1) as f64;
        let mut max_phases = PhaseTimers::default();
        let mut mean_phases = PhaseTimers::default();
        let mut tts = 0.0f64;
        let mut iters = 0u64;
        let mut retries = 0u64;
        let mut all_decisions: Vec<DecisionRecord> = Vec::new();
        let mut ckpt_by_version: BTreeMap<i64, CkptRecord> = BTreeMap::new();
        let mut faults = FaultCounters::default();
        for r in &survivors {
            retries = retries.max(r.recovery_retries);
            faults.add(&r.faults);
            max_phases.max_with(&r.phases);
            for p in ALL_PHASES {
                let cur = mean_phases.get(p);
                mean_phases.set(p, cur + r.phases.get(p) / n);
            }
            tts = tts.max(r.finish_time);
            iters = iters.max(r.iterations);
            all_decisions.extend(r.decisions.iter().cloned());
            for c in &r.ckpt {
                ckpt_by_version
                    .entry(c.version)
                    .and_modify(|e| {
                        e.logical_bytes += c.logical_bytes;
                        e.shipped_bytes += c.shipped_bytes;
                        e.raw_bytes += c.raw_bytes;
                        e.at = e.at.max(c.at);
                        e.encode_secs = e.encode_secs.max(c.encode_secs);
                        e.delta |= c.delta;
                        e.rotation = e.rotation.max(c.rotation);
                    })
                    .or_insert_with(|| c.clone());
            }
        }
        // Merge per-rank decision logs into one per-event log: order by
        // decision time, keep the first record of each event (identified by
        // its failed-rank set *and* the chosen strategy), renumber.
        // Per-rank clocks at the same event differ by at most the
        // failure-detection skew, which is far below the inter-event
        // spacing, so time-ordering is event-ordering.  The strategy is
        // part of the event key because a degraded-shrink decision on a
        // straggler is followed by the crash-recovery decision for the same
        // rank once it is shed ([`crate::recovery::degraded`]): same failed
        // set, two distinct events.  Deaths are permanent, so the same
        // (set, strategy) pair can never recur.
        all_decisions
            .sort_by(|a, b| a.at.partial_cmp(&b.at).unwrap_or(std::cmp::Ordering::Equal));
        let mut decisions: Vec<DecisionRecord> = Vec::new();
        for d in all_decisions {
            if !decisions
                .iter()
                .any(|e| e.failed_ranks == d.failed_ranks && e.decision == d.decision)
            {
                let mut d = d;
                d.seq = decisions.len();
                decisions.push(d);
            }
        }
        // `max_phases.max_with` above cannot double-count overlapping
        // recovery attempts: each rank's timers charge every virtual second
        // to exactly one phase (the clock only moves through `advance`/
        // `advance_to`, each of which charges its dt once), so per rank
        // `phases.total() == finish_time`, retries included — and the
        // element-wise max never adds across ranks.  Pinned by
        // `max_with_takes_max_not_sum_over_overlapping_recoveries` below and
        // by the `every_virtual_second_charged_once` integration test.
        let phase_dist = PhaseDist::from_timers(survivors.iter().map(|r| &r.phases));
        let critical_path = crate::trace::critical_path(&ranks);
        RunReport {
            time_to_solution: tts,
            max_phases,
            mean_phases,
            ranks,
            final_relres,
            iterations: iters,
            converged,
            failures,
            decisions,
            ckpt: ckpt_by_version.into_values().collect(),
            recovery_retries: retries,
            faults,
            phase_dist,
            critical_path,
        }
    }

    /// Executed global restarts in the merged decision log.  Decisions are
    /// recorded only after they actually ran (abandoned fence attempts are
    /// not logged), so this counts restarts that really happened — the
    /// nested-failure acceptance metric (`global_restarts == 0` for
    /// recoverable patterns).
    pub fn global_restarts(&self) -> usize {
        self.decisions.iter().filter(|d| d.decision == "global-restart").count()
    }

    /// Total redundancy bytes shipped and logical state bytes over all
    /// commits, plus the commit count — the checkpoint-volume headline the
    /// `bench_ckpt` target reports.
    pub fn ckpt_totals(&self) -> (usize, usize, usize) {
        let shipped = self.ckpt.iter().map(|c| c.shipped_bytes).sum();
        let logical = self.ckpt.iter().map(|c| c.logical_bytes).sum();
        (shipped, logical, self.ckpt.len())
    }

    /// Total *uncompressed* redundancy bytes over all commits — equals the
    /// shipped total when `ckpt_compress` is off; the gap is the
    /// compression saving.
    pub fn ckpt_raw_bytes(&self) -> usize {
        self.ckpt.iter().map(|c| c.raw_bytes).sum()
    }
}

impl RankReport {
    /// A spare that never did an iteration stayed idle; exclude it from
    /// time-to-solution (the paper measures application ranks).
    fn was_spare_unused(&self) -> bool {
        self.was_spare && self.iterations == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn charge_and_total() {
        let mut t = PhaseTimers::default();
        t.charge(Phase::Compute, 1.5);
        t.charge(Phase::Comm, 0.5);
        t.charge(Phase::Compute, 0.5);
        assert_eq!(t.compute, 2.0);
        assert!((t.total() - 2.5).abs() < 1e-15);
    }

    #[test]
    fn max_with_elementwise() {
        let mut a = PhaseTimers { compute: 1.0, comm: 5.0, ..Default::default() };
        let b = PhaseTimers { compute: 2.0, comm: 1.0, ..Default::default() };
        a.max_with(&b);
        assert_eq!(a.compute, 2.0);
        assert_eq!(a.comm, 5.0);
    }

    #[test]
    fn max_with_takes_max_not_sum_over_overlapping_recoveries() {
        // Two survivors recover over the same virtual window (every
        // nested-failure run does this); the campaign maximum must be the
        // slowest rank's time per phase, never a sum across ranks.
        let mut a = PhaseTimers { recovery: 3.0, reconfig: 1.0, ..Default::default() };
        let b = PhaseTimers { recovery: 2.5, reconfig: 1.5, ..Default::default() };
        a.max_with(&b);
        assert_eq!(a.recovery, 3.0);
        assert_eq!(a.reconfig, 1.5);
        assert!((a.total() - 4.5).abs() < 1e-15);
    }

    #[test]
    fn phase_dist_percentiles_over_ranks() {
        let t = |c: f64| PhaseTimers { compute: c, ..Default::default() };
        let timers = [t(1.0), t(2.0), t(3.0), t(4.0)];
        let d = PhaseDist::from_timers(timers.iter());
        let s = d.get(Phase::Compute);
        assert_eq!(s.p50, 2.0);
        assert_eq!(s.p95, 4.0);
        assert_eq!(s.max, 4.0);
        assert_eq!(d.get(Phase::Idle), PhaseStat::default());
    }

    #[test]
    fn run_report_excludes_killed_and_unused_spares() {
        let mk = |wr, fin, killed, spare, iters| RankReport {
            world_rank: wr,
            finish_time: fin,
            phases: PhaseTimers::default(),
            iterations: iters,
            killed,
            was_spare: spare,
            decisions: Vec::new(),
            ckpt: Vec::new(),
            recovery_retries: 0,
            faults: FaultCounters::default(),
            trace: Vec::new(),
        };
        let ranks = vec![
            mk(0, 10.0, false, false, 100),
            mk(1, 50.0, true, false, 40),   // killed: excluded
            mk(2, 99.0, false, true, 0),    // unused spare: excluded
            mk(3, 12.0, false, true, 60),   // used spare: included
        ];
        let rep = RunReport::from_ranks(ranks, 1e-9, true, 1);
        assert!((rep.time_to_solution - 12.0).abs() < 1e-12);
        assert_eq!(rep.iterations, 100);
    }

    #[test]
    fn merges_decision_logs_across_survivors() {
        // Event identity is the failed-rank set; `at` orders events; the
        // recording rank's local seq may be wrong (spares adopted mid-run
        // start counting at 0) and must be rewritten by the merge.
        let dec = |seq, at, failed: usize, name: &'static str| DecisionRecord {
            seq,
            at,
            failed_ranks: vec![failed],
            decision: name,
            reason: String::new(),
            warm_free: 0,
            cold_free: 0,
            attempt: 0,
        };
        let mk = |wr, killed, spare, decisions| RankReport {
            world_rank: wr,
            finish_time: 1.0,
            phases: PhaseTimers::default(),
            iterations: 10,
            killed,
            was_spare: spare,
            decisions,
            ckpt: Vec::new(),
            recovery_retries: 0,
            faults: FaultCounters::default(),
            trace: Vec::new(),
        };
        let ranks = vec![
            // Killed ranks are excluded from the merge entirely.
            mk(0, true, false, vec![dec(0, 1.0, 3, "substitute")]),
            // An original survivor witnessed both events.
            mk(1, false, false, vec![dec(0, 1.01, 3, "substitute"), dec(1, 2.0, 0, "shrink")]),
            // The adopted spare saw only event 1, locally numbered 0.
            mk(4, false, true, vec![dec(0, 2.02, 0, "shrink")]),
        ];
        let rep = RunReport::from_ranks(ranks, 1e-9, true, 2);
        assert_eq!(rep.decisions.len(), 2);
        assert_eq!(rep.decisions[0].decision, "substitute");
        assert_eq!(rep.decisions[0].seq, 0);
        assert_eq!(rep.decisions[0].failed_ranks, vec![3]);
        assert_eq!(rep.decisions[1].decision, "shrink");
        assert_eq!(rep.decisions[1].seq, 1);
    }

    #[test]
    fn merge_recovers_events_whose_witnesses_died() {
        // The code-review scenario: every witness of event 0 is killed by
        // event 1, and only the mid-run-adopted spare (local seq 0) plus a
        // late joiner survive.  The merged log must still show both events
        // in order with correct numbering.
        let dec = |seq, at, failed: usize, name: &'static str| DecisionRecord {
            seq,
            at,
            failed_ranks: vec![failed],
            decision: name,
            reason: String::new(),
            warm_free: 0,
            cold_free: 0,
            attempt: 0,
        };
        let mk = |wr, killed, spare, decisions| RankReport {
            world_rank: wr,
            finish_time: 1.0,
            phases: PhaseTimers::default(),
            iterations: 10,
            killed,
            was_spare: spare,
            decisions,
            ckpt: Vec::new(),
            recovery_retries: 0,
            faults: FaultCounters::default(),
            trace: Vec::new(),
        };
        let ranks = vec![
            mk(0, true, false, vec![dec(0, 1.0, 3, "substitute")]),
            mk(1, true, false, vec![dec(0, 1.01, 3, "substitute")]),
            // Spare 4 adopted at event 0, then witnessed event 1.
            mk(4, false, true, vec![dec(0, 2.0, 0, "shrink")]),
            // Spare 5 adopted at event 0 as well, witnessed event 1 too.
            mk(5, false, true, vec![dec(0, 2.01, 0, "shrink")]),
        ];
        let rep = RunReport::from_ranks(ranks, 1e-9, true, 2);
        // Event 0's only witnesses were killed: with killed ranks excluded
        // the merge can only recover event 1 — but it must recover it
        // exactly once, renumbered from the spares' local seq 0.
        assert_eq!(rep.decisions.len(), 1);
        assert_eq!(rep.decisions[0].decision, "shrink");
        assert_eq!(rep.decisions[0].seq, 0);
    }

    #[test]
    fn degraded_shrink_and_crash_records_for_the_same_rank_both_survive() {
        // A straggler shed by the policy produces two records over the
        // same failed set: the proactive "degraded-shrink" pricing event,
        // then the crash-recovery "shrink" once the rank is gone.  The
        // (failed set, strategy) dedup key must keep both while still
        // collapsing duplicate witnesses of each.
        let dec = |at, name: &'static str| DecisionRecord {
            seq: 0,
            at,
            failed_ranks: vec![2],
            decision: name,
            reason: String::new(),
            warm_free: 0,
            cold_free: 0,
            attempt: 0,
        };
        let mk = |wr, decisions| RankReport {
            world_rank: wr,
            finish_time: 1.0,
            phases: PhaseTimers::default(),
            iterations: 10,
            killed: false,
            was_spare: false,
            decisions,
            ckpt: Vec::new(),
            recovery_retries: 0,
            faults: FaultCounters { link_retries: 3, ..Default::default() },
            trace: Vec::new(),
        };
        let ranks = vec![
            mk(0, vec![dec(1.0, "degraded-shrink"), dec(1.5, "shrink")]),
            mk(1, vec![dec(1.01, "degraded-shrink"), dec(1.51, "shrink")]),
        ];
        let rep = RunReport::from_ranks(ranks, 1e-9, true, 1);
        assert_eq!(rep.decisions.len(), 2);
        assert_eq!(rep.decisions[0].decision, "degraded-shrink");
        assert_eq!(rep.decisions[0].seq, 0);
        assert_eq!(rep.decisions[1].decision, "shrink");
        assert_eq!(rep.decisions[1].seq, 1);
        // Fault counters sum across survivors.
        assert_eq!(rep.faults.link_retries, 6);
        assert_eq!(rep.faults.scrub_detected, 0);
    }

    #[test]
    fn ckpt_records_merge_by_version() {
        let rec = |version, shipped: usize| CkptRecord {
            version,
            at: version as f64,
            logical_bytes: 100,
            shipped_bytes: shipped,
            raw_bytes: shipped * 2,
            delta: version == 2,
            rotation: version,
            encode_secs: 0.001 * version as f64,
        };
        let mk = |wr, ckpt| RankReport {
            world_rank: wr,
            finish_time: 1.0,
            phases: PhaseTimers::default(),
            iterations: 10,
            killed: false,
            was_spare: false,
            decisions: Vec::new(),
            ckpt,
            recovery_retries: 0,
            faults: FaultCounters::default(),
            trace: Vec::new(),
        };
        let ranks = vec![
            mk(0, vec![rec(1, 800), rec(2, 80)]),
            mk(1, vec![rec(1, 800), rec(2, 120)]),
        ];
        let rep = RunReport::from_ranks(ranks, 1e-9, true, 0);
        assert_eq!(rep.ckpt.len(), 2);
        assert_eq!(rep.ckpt[0].version, 1);
        assert_eq!(rep.ckpt[0].shipped_bytes, 1600);
        assert_eq!(rep.ckpt[0].raw_bytes, 3200);
        assert_eq!(rep.ckpt[0].logical_bytes, 200);
        assert_eq!(rep.ckpt[0].rotation, 1);
        assert_eq!(rep.ckpt[1].shipped_bytes, 200);
        assert!(rep.ckpt[1].delta);
        let (shipped, logical, commits) = rep.ckpt_totals();
        assert_eq!((shipped, logical, commits), (1800, 400, 2));
        assert_eq!(rep.ckpt_raw_bytes(), 3600);
    }
}
