//! Virtual-clock network cost model.
//!
//! Substitutes for the paper's testbed: a 960-core Linux cluster, 24-core
//! nodes (2x 12-core Opterons), fully connected dual-bonded 1 GbE with a
//! measured non-blocking point-to-point bandwidth of 215 MB/s.
//!
//! The model is deliberately simple — the paper's Figures 4-6 are driven by
//! (a) message volume, (b) whether a message crosses a node boundary, and
//! (c) NIC serialization when many ranks on one node talk off-node at once.
//! Those are exactly the three terms modelled here.
//!
//! Causality note: NIC reservations are made in wall-clock call order while
//! rank clocks are only loosely synchronized.  The solver is bulk-synchronous
//! (allreduces every iteration), so clock skew between ranks is bounded by
//! one iteration and the approximation error is negligible; DESIGN.md §1
//! documents this.

use std::sync::Mutex;



pub type NodeId = usize;

/// Static cost parameters.  Defaults are calibrated to the paper's testbed.
#[derive(Debug, Clone)]
pub struct NetParams {
    /// One-way latency between ranks on different nodes (s).
    pub inter_latency: f64,
    /// Point-to-point bandwidth between nodes (B/s) — paper: 215 MB/s.
    pub inter_bandwidth: f64,
    /// One-way latency between ranks on the same node (s).
    pub intra_latency: f64,
    /// Intra-node (shared-memory transport) bandwidth (B/s).
    pub intra_bandwidth: f64,
    /// CPU overhead charged to the sender per message (s).
    pub send_overhead: f64,
    /// CPU overhead charged to the receiver per message (s).
    pub recv_overhead: f64,
    /// Extra latency before a dead peer is reported (ULFM failure detector:
    /// heartbeat timeout + consensus), charged once per detecting rank.
    pub detect_latency: f64,
    /// Per-hop latency growth for inter-node messages: nodes `h` apart see
    /// `inter_latency * (1 + hop_latency_factor * (h - 1))`.  Models the
    /// switch hierarchy the paper blames for "physically distant" spares.
    pub hop_latency_factor: f64,
    /// Per-hop bandwidth taper: effective bandwidth is
    /// `inter_bandwidth / (1 + hop_bw_taper * (h - 1))`.
    pub hop_bw_taper: f64,
    /// Fixed per-message header bytes.
    pub header_bytes: usize,
    /// Ranks per physical node (paper: 2 sockets x 12 cores).
    pub ranks_per_node: usize,
    /// Workload scale: rows-proportional payloads are charged at
    /// `data_scale` times their physical size (campaigns simulate the
    /// paper's 7M-row problem on 1/36-scale arrays; see DESIGN.md §1).
    pub data_scale: f64,
    /// Cold-spare process spawn latency (job launcher + binary load +
    /// MPI init on the fresh node), charged when a cold spare joins.
    pub cold_spawn_latency: f64,
    /// Node-crossing buddy placement: checkpoints go to the same rank slot
    /// on the next node instead of the next rank (tolerates whole-node
    /// loss; costlier).  Ablation knob — the paper's Figure 2 layout is the
    /// rank-ring default.
    pub ckpt_node_stride: bool,
    /// Model NIC serialization of concurrent off-node messages.
    /// Off by default: the paper's 215 MB/s is the *measured* per-flow
    /// bandwidth on the shared fabric, and the reservation queue interacts
    /// badly with loosely-synchronized virtual clocks (head-of-line
    /// inversions); kept as an ablation knob.
    pub nic_contention: bool,
    /// Sender-side retransmit timeout for lossy links (config
    /// `link_timeout`): each dropped data message charges the sender this
    /// long before the retry goes out.  GASPI-style timeout detection —
    /// deliberately much larger than a round trip and much smaller than
    /// `detect_latency`-scale death consensus.
    pub link_timeout: f64,
    /// Consecutive retransmits a sender tolerates on one message before it
    /// escalates the link as failed (config `link_retry_budget`): the epoch
    /// is revoked and recovery re-forms the communicator, but — unlike a
    /// crash-stop death — no rank is marked dead.
    pub link_retry_budget: u32,
}

impl Default for NetParams {
    fn default() -> Self {
        NetParams {
            inter_latency: 50e-6,
            inter_bandwidth: 215e6,
            intra_latency: 1.2e-6,
            intra_bandwidth: 6e9,
            send_overhead: 1.0e-6,
            recv_overhead: 0.6e-6,
            detect_latency: 1e-3,
            hop_latency_factor: 0.0,
            hop_bw_taper: 0.0,
            header_bytes: 64,
            ranks_per_node: 24,
            data_scale: 1.0,
            cold_spawn_latency: 2.0,
            ckpt_node_stride: false,
            nic_contention: false,
            link_timeout: 5e-3,
            link_retry_budget: 5,
        }
    }
}

impl NetParams {
    pub fn node_of(&self, world_rank: usize) -> NodeId {
        world_rank / self.ranks_per_node
    }

    pub fn same_node(&self, a: usize, b: usize) -> bool {
        self.node_of(a) == self.node_of(b)
    }
}

/// Result of routing one message through the model.
///
/// `arrival` is the stamp the receiver's clock jumps to and — when tracing
/// is on (DESIGN.md §13) — half of the `(send, recv)` edge key that pairs
/// the sender's flow-start with the receiver's flow-end in the exported
/// trace, so it must be a pure function of (route, bytes, depart).
#[derive(Debug, Clone, Copy)]
pub struct Transit {
    /// Virtual time at which the message is fully received.
    pub arrival: f64,
    /// Time the *sender* is occupied (overhead + its share of injection).
    pub sender_busy: f64,
}

/// Mutable network state: one NIC free-time per node.
#[derive(Debug)]
pub struct Network {
    pub params: NetParams,
    nic_free: Vec<Mutex<f64>>,
    nodes: usize,
}

impl Network {
    pub fn new(params: NetParams, world_size: usize) -> Self {
        let nodes = world_size.div_ceil(params.ranks_per_node).max(1);
        Network {
            params,
            nic_free: (0..nodes).map(|_| Mutex::new(0.0)).collect(),
            nodes,
        }
    }

    pub fn nodes(&self) -> usize {
        self.nodes
    }

    /// Route `bytes` of payload from `src` to `dst` departing at `depart`
    /// (ranks mapped to nodes by the default packing).
    pub fn transit(&self, src: usize, dst: usize, bytes: usize, depart: f64) -> Transit {
        self.transit_nodes(self.params.node_of(src), self.params.node_of(dst), bytes, depart)
    }

    /// Route between explicit nodes (used by `World`, which owns the real
    /// rank -> node mapping including spare placement).
    pub fn transit_nodes(&self, src_node: NodeId, dst_node: NodeId, bytes: usize, depart: f64) -> Transit {
        let p = &self.params;
        let total = (bytes + p.header_bytes) as f64;
        if src_node == dst_node {
            let wire = total / p.intra_bandwidth;
            Transit {
                arrival: depart + p.intra_latency + wire,
                sender_busy: p.send_overhead + wire,
            }
        } else {
            // Distance through the switch hierarchy grows logarithmically
            // with node separation (hops = 1 for adjacent nodes).
            let hops = (src_node as f64 - dst_node as f64).abs();
            let depth = hops.max(1.0).log2();
            let lat = p.inter_latency * (1.0 + p.hop_latency_factor * depth);
            let bw = p.inter_bandwidth / (1.0 + p.hop_bw_taper * depth);
            let wire = total / bw;
            let start = if p.nic_contention {
                // Serialize on the sending node's NIC.
                let mut free = self.nic_free[src_node].lock().unwrap();
                let start = free.max(depart);
                *free = start + wire;
                start
            } else {
                depart
            };
            Transit {
                arrival: start + lat + wire,
                sender_busy: p.send_overhead + (start - depart) + wire,
            }
        }
    }

    /// Reset NIC reservations (between runs sharing a Network).
    pub fn reset(&self) {
        for f in &self.nic_free {
            *f.lock().unwrap() = 0.0;
        }
    }
}

/// Modeled compute cost: max of the flop-rate and memory-bandwidth rooflines.
/// Used by the `Modeled` clock mode (deterministic figures on any host).
#[derive(Debug, Clone)]
pub struct ComputeModel {
    /// Sustained per-core flop rate (flops/s).  Paper-era Opteron core.
    pub flops_per_sec: f64,
    /// Sustained per-core memory bandwidth (B/s); 24 cores share the socket.
    pub mem_bytes_per_sec: f64,
}

impl Default for ComputeModel {
    fn default() -> Self {
        ComputeModel { flops_per_sec: 2.0e9, mem_bytes_per_sec: 1.7e9 }
    }
}

impl ComputeModel {
    /// Seconds to execute a kernel touching `bytes` and doing `flops`.
    pub fn cost(&self, flops: f64, bytes: f64) -> f64 {
        (flops / self.flops_per_sec).max(bytes / self.mem_bytes_per_sec)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn net() -> Network {
        Network::new(NetParams::default(), 96)
    }

    #[test]
    fn node_mapping() {
        let p = NetParams::default();
        assert_eq!(p.node_of(0), 0);
        assert_eq!(p.node_of(23), 0);
        assert_eq!(p.node_of(24), 1);
        assert!(p.same_node(0, 23));
        assert!(!p.same_node(23, 24));
    }

    #[test]
    fn intra_is_cheaper_than_inter() {
        let n = net();
        let intra = n.transit(0, 1, 1 << 20, 0.0);
        let inter = n.transit(0, 24, 1 << 20, 0.0);
        assert!(intra.arrival < inter.arrival);
    }

    #[test]
    fn bandwidth_term_scales_with_bytes() {
        let n = net();
        let small = n.transit(0, 24, 1_000, 0.0).arrival;
        n.reset();
        let big = n.transit(0, 24, 215_000_000, 0.0).arrival;
        // 215 MB at 215 MB/s ≈ 1 s.
        assert!(big > small + 0.9 && big < small + 1.2, "big={big}");
    }

    #[test]
    fn nic_contention_serializes() {
        let mut p = NetParams::default();
        p.nic_contention = true;
        let n = Network::new(p, 96);
        let a = n.transit(0, 24, 10_000_000, 0.0);
        let b = n.transit(1, 25, 10_000_000, 0.0); // same source node NIC
        assert!(b.arrival > a.arrival, "second message must queue behind first");
    }

    #[test]
    fn reset_clears_reservations() {
        let mut p = NetParams::default();
        p.nic_contention = true;
        let n = Network::new(p, 96);
        let a = n.transit(0, 24, 10_000_000, 0.0);
        n.reset();
        let b = n.transit(1, 25, 10_000_000, 0.0);
        assert!((a.arrival - b.arrival).abs() < 1e-12);
    }

    #[test]
    fn distant_nodes_cost_more_with_taper() {
        // Default network is flat; the hop knobs exist for the ablation.
        let mut p = NetParams::default();
        p.hop_latency_factor = 1.0;
        p.hop_bw_taper = 1.0;
        let n = Network::new(p, 24 * 8);
        let near = n.transit(0, 24, 1 << 20, 0.0); // 1 hop
        n.reset();
        let far = n.transit(0, 24 * 7, 1 << 20, 0.0); // 7 hops
        assert!(far.arrival > near.arrival * 1.5, "hop taper must bite: {} vs {}", far.arrival, near.arrival);

        let flat = Network::new(NetParams::default(), 24 * 8);
        let a = flat.transit(0, 24, 1 << 20, 0.0);
        flat.reset();
        let b = flat.transit(0, 24 * 7, 1 << 20, 0.0);
        assert!((a.arrival - b.arrival).abs() < 1e-12, "default network is flat");
    }

    #[test]
    fn link_fault_defaults_sit_between_rtt_and_death_detection() {
        let p = NetParams::default();
        // The retransmit timeout must dwarf a round trip (otherwise healthy
        // jitter would look like loss) yet stay well under the death
        // detector, so a lossy link is observably distinct from a crash.
        assert!(p.link_timeout > 20.0 * p.inter_latency, "timeout ~ RTT");
        assert!(
            p.link_retry_budget as f64 * p.link_timeout >= p.detect_latency,
            "budget exhaustion must cost at least a death detection"
        );
        assert!(p.link_retry_budget >= 1);
    }

    #[test]
    fn compute_model_roofline() {
        let m = ComputeModel::default();
        // Pure-flop bound.
        assert!((m.cost(2e9, 0.0) - 1.0).abs() < 1e-9);
        // Memory bound.
        assert!((m.cost(0.0, 1.7e9) - 1.0).abs() < 1e-9);
        // Max of the two.
        assert!((m.cost(2e9, 3.4e9) - 2.0).abs() < 1e-9);
    }
}
