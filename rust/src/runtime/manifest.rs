//! Parser for `artifacts/manifest.tsv` — the flat twin of `manifest.json`
//! emitted by `python/compile/aot.py` (this environment is offline, so no
//! JSON crate; the TSV carries exactly what the loader needs).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// The five graph kinds (mirror of `python/compile/model.py::GRAPHS`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Graph {
    Spmv,
    DotPartials,
    UpdateW,
    UpdateX,
    Scale,
}

impl Graph {
    pub fn parse(s: &str) -> Option<Graph> {
        match s {
            "spmv" => Some(Graph::Spmv),
            "dot_partials" => Some(Graph::DotPartials),
            "update_w" => Some(Graph::UpdateW),
            "update_x" => Some(Graph::UpdateX),
            "scale" => Some(Graph::Scale),
            _ => None,
        }
    }

    pub const ALL: [Graph; 5] =
        [Graph::Spmv, Graph::DotPartials, Graph::UpdateW, Graph::UpdateX, Graph::Scale];
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub dtype: String,
    /// Krylov basis slots in the fixed-shape graphs (M = m + 1 = 26).
    pub m: usize,
    /// ELL nonzeros per row.
    pub k: usize,
    /// Halo padding of the SpMV x input.
    pub halo_pad: usize,
    /// Available row buckets, ascending.
    pub buckets: Vec<usize>,
    /// (graph, bucket) -> HLO text file.
    pub files: HashMap<(Graph, usize), PathBuf>,
    pub dir: PathBuf,
}

impl Manifest {
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let path = dir.join("manifest.tsv");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("{}: {e} (run `make artifacts`)", path.display()))?;
        let mut m = Manifest {
            dtype: String::new(),
            m: 0,
            k: 0,
            halo_pad: 0,
            buckets: Vec::new(),
            files: HashMap::new(),
            dir: dir.to_path_buf(),
        };
        for (no, line) in text.lines().enumerate() {
            let fields: Vec<&str> = line.split('\t').collect();
            let bad = || anyhow::anyhow!("{}:{}: malformed line", path.display(), no + 1);
            match fields.as_slice() {
                ["dtype", v] => m.dtype = v.to_string(),
                ["m", v] => m.m = v.parse()?,
                ["k", v] => m.k = v.parse()?,
                ["halo_pad", v] => m.halo_pad = v.parse()?,
                ["buckets", v] => {
                    m.buckets = v
                        .split_whitespace()
                        .map(|b| b.parse())
                        .collect::<Result<_, _>>()?;
                }
                ["graph", name, rows, file] => {
                    let g = Graph::parse(name).ok_or_else(bad)?;
                    m.files.insert((g, rows.parse()?), dir.join(file));
                }
                _ => return Err(bad()),
            }
        }
        anyhow::ensure!(m.dtype == "float64", "expected f64 artifacts, got {}", m.dtype);
        anyhow::ensure!(!m.buckets.is_empty(), "no buckets in manifest");
        let mut sorted = m.buckets.clone();
        sorted.sort_unstable();
        anyhow::ensure!(sorted == m.buckets, "buckets must be ascending");
        for g in Graph::ALL {
            for &b in &m.buckets {
                anyhow::ensure!(
                    m.files.contains_key(&(g, b)),
                    "manifest missing graph {g:?} at bucket {b}"
                );
            }
        }
        Ok(m)
    }

    /// Smallest bucket that fits `rows` live rows.
    pub fn bucket_for(&self, rows: usize) -> anyhow::Result<usize> {
        self.buckets
            .iter()
            .copied()
            .find(|&b| b >= rows)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "no bucket fits {rows} rows (max {}); regenerate artifacts with larger buckets",
                    self.buckets.last().unwrap()
                )
            })
    }

    pub fn file(&self, g: Graph, bucket: usize) -> &Path {
        &self.files[&(g, bucket)]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn write_manifest(dir: &Path, body: &str) {
        std::fs::create_dir_all(dir).unwrap();
        std::fs::write(dir.join("manifest.tsv"), body).unwrap();
    }

    fn full_body() -> String {
        let mut s = String::from("dtype\tfloat64\nm\t26\nk\t7\nhalo_pad\t8192\nbuckets\t256 512\n");
        for g in ["spmv", "dot_partials", "update_w", "update_x", "scale"] {
            for b in [256, 512] {
                s.push_str(&format!("graph\t{g}\t{b}\t{g}_r{b}.hlo.txt\n"));
            }
        }
        s
    }

    #[test]
    fn parses_full_manifest() {
        let dir = std::env::temp_dir().join("ulfm_manifest_ok");
        write_manifest(&dir, &full_body());
        let m = Manifest::load(&dir).unwrap();
        assert_eq!(m.m, 26);
        assert_eq!(m.k, 7);
        assert_eq!(m.buckets, vec![256, 512]);
        assert_eq!(m.bucket_for(200).unwrap(), 256);
        assert_eq!(m.bucket_for(256).unwrap(), 256);
        assert_eq!(m.bucket_for(257).unwrap(), 512);
        assert!(m.bucket_for(513).is_err());
    }

    #[test]
    fn rejects_missing_graph() {
        let dir = std::env::temp_dir().join("ulfm_manifest_missing");
        let body = full_body().lines().filter(|l| !l.contains("scale\t256")).collect::<Vec<_>>().join("\n");
        write_manifest(&dir, &body);
        assert!(Manifest::load(&dir).is_err());
    }

    #[test]
    fn rejects_f32() {
        let dir = std::env::temp_dir().join("ulfm_manifest_f32");
        write_manifest(&dir, &full_body().replace("float64", "float32"));
        assert!(Manifest::load(&dir).is_err());
    }
}
