//! PJRT runtime: loads the AOT artifacts (`artifacts/*.hlo.txt`, produced
//! once by `python/compile/aot.py`) and executes them on the PJRT CPU
//! client.  Python is never on this path — HLO text in, numbers out.
//!
//! The `xla` crate's `PjRtClient` is `Rc`-based (`!Send`), so the client and
//! all compiled executables live on a dedicated **runtime service thread**;
//! rank threads submit compute requests over a channel and block for the
//! reply.  On this one-core container the serialization costs nothing, and
//! it mirrors how a real deployment shares an accelerator among many
//! coordinator tasks.
//!
//! Shapes are bucketed (fixed-shape HLO): inputs are zero-padded to the
//! smallest available row bucket — padding invariance is guaranteed by the
//! kernel contracts and tested in python/tests/test_model.py and
//! tests/backend_equivalence.rs.

pub mod manifest;

use std::collections::HashMap;
use std::path::Path;
use std::sync::mpsc::{channel, Sender};
use std::time::Instant;

use crate::backend::{costs, Backend, DenseBasis};
use crate::netsim::ComputeModel;
use crate::problem::laplacian::K;
use crate::problem::EllBlock;

pub use manifest::{Graph, Manifest};

/// Basis argument with device-cache identity: `data` is `None` when the
/// engine believes the server still holds the (id, gen) buffer; a server
/// cache miss replies `CACHE_MISS` and the engine retries with data.
struct BasisArg {
    id: u64,
    gen: u64,
    r: usize,
    /// Padded to the artifact's M rows when present.
    data: Option<Vec<f64>>,
}

/// Matrix block argument with the same cache protocol (vals/cols are static
/// per block identity).
struct MatArg {
    uid: u64,
    rows: usize,
    data: Option<(Vec<f64>, Vec<i32>)>,
}

/// One compute request (inputs pre-flattened; padding happens server-side).
enum Op {
    Spmv { mat: MatArg, x_halo: Vec<f64> },
    DotPartials { v: BasisArg, m_used: usize, w: Vec<f64> },
    UpdateW { v: BasisArg, w: Vec<f64>, h: Vec<f64> },
    UpdateX { v: BasisArg, y: Vec<f64>, x: Vec<f64> },
    Scale { w: Vec<f64>, alpha: f64 },
}

const CACHE_MISS: &str = "@cache-miss";

struct Reply {
    outs: Vec<Vec<f64>>,
    /// Wall seconds spent in the runtime (literal build + execute + fetch).
    elapsed: f64,
}

struct Request {
    op: Op,
    reply: Sender<Result<Reply, String>>,
}

/// PJRT-backed implementation of the solver [`Backend`].
pub struct PjrtEngine {
    tx: Sender<Request>,
    model: ComputeModel,
    /// true: charge measured wall time; false: charge the same modeled cost
    /// as the native backend (numerics via PJRT, deterministic clock).
    measured: bool,
    m: usize,
    /// Mirror of the server's basis-buffer cache: id -> generation last
    /// uploaded.  Conservative (server may evict; misses self-heal).
    basis_known: std::sync::Mutex<HashMap<u64, u64>>,
    /// Mirror of the server's matrix-buffer cache (uids uploaded).
    mat_known: std::sync::Mutex<std::collections::HashSet<u64>>,
}

/// Tune glibc malloc for the PJRT hot path: per-call literals/buffers are
/// hundreds of kB, which glibc serves via mmap/munmap by default — every
/// call then pays page faults on first touch.  Raising the mmap threshold
/// keeps those allocations on the (reused) heap: measured 6.3x end-to-end
/// wall-time reduction on the e2e driver (EXPERIMENTS.md §Perf).
fn tune_allocator() {
    use std::sync::Once;
    static ONCE: Once = Once::new();
    ONCE.call_once(|| unsafe {
        libc::mallopt(libc::M_MMAP_THRESHOLD, 1 << 30);
        // Keep freed memory for reuse instead of returning it to the OS.
        libc::mallopt(libc::M_TRIM_THRESHOLD, 1 << 30);
    });
}

impl PjrtEngine {
    /// Load the manifest and start the runtime service thread.  Executables
    /// are compiled lazily per (graph, bucket) on first use.
    pub fn load(dir: &Path, model: ComputeModel, measured: bool) -> anyhow::Result<PjrtEngine> {
        tune_allocator();
        let man = Manifest::load(dir)?;
        anyhow::ensure!(man.k == K, "artifact K={} != problem K={K}", man.k);
        let m = man.m;
        let (tx, rx) = channel::<Request>();
        std::thread::Builder::new()
            .name("pjrt-runtime".into())
            .spawn(move || server(man, rx))
            .expect("spawn pjrt runtime thread");
        Ok(PjrtEngine {
            tx,
            model,
            measured,
            m,
            basis_known: std::sync::Mutex::new(HashMap::new()),
            mat_known: std::sync::Mutex::new(std::collections::HashSet::new()),
        })
    }

    fn submit(&self, op: Op) -> Result<Reply, String> {
        let (rtx, rrx) = channel();
        self.tx
            .send(Request { op, reply: rtx })
            .expect("pjrt runtime thread is gone");
        rrx.recv().expect("pjrt runtime thread dropped reply")
    }

    /// Submit with the basis/matrix cache protocol: `build(force)` produces
    /// the op, with payloads included when `force` is true or the mirror
    /// says the server does not hold them.
    fn submit_cached(&self, build: &dyn Fn(bool) -> Op) -> Reply {
        match self.submit(build(false)) {
            Ok(r) => r,
            Err(e) if e == CACHE_MISS => self
                .submit(build(true))
                .unwrap_or_else(|e| panic!("pjrt runtime error after retry: {e}")),
            Err(e) => panic!("pjrt runtime error: {e}"),
        }
    }

    /// Build the basis argument, consulting (and updating) the mirror.
    fn basis_arg(&self, v: &DenseBasis, force: bool) -> BasisArg {
        let (id, gen) = v.cache_key();
        let mut known = self.basis_known.lock().unwrap();
        let hit = !force && known.get(&id) == Some(&gen);
        if !hit {
            known.insert(id, gen);
        }
        BasisArg { id, gen, r: v.r, data: if hit { None } else { Some(self.basis_data(v)) } }
    }

    fn mat_arg(&self, blk: &EllBlock, force: bool) -> MatArg {
        let mut known = self.mat_known.lock().unwrap();
        let hit = !force && known.contains(&blk.uid);
        if !hit {
            known.insert(blk.uid);
        }
        MatArg {
            uid: blk.uid,
            rows: blk.rows,
            data: if hit { None } else { Some((blk.vals.clone(), blk.cols.clone())) },
        }
    }

    fn charge(&self, modeled: f64, elapsed: f64) -> f64 {
        if self.measured {
            elapsed
        } else {
            modeled
        }
    }

    /// Basis data padded to the artifact's M rows (the Z basis has m_outer
    /// = M - 1 rows; missing rows are zeros and the matching coefficient
    /// slots are zeroed by the callers, so padding is exact).
    fn basis_data(&self, v: &DenseBasis) -> Vec<f64> {
        assert!(
            v.m <= self.m,
            "basis has {} slots but artifacts were built with M = {}              (solver m_inner/m_outer must be {})",
            v.m,
            self.m,
            self.m - 1
        );
        if v.m == self.m {
            v.data.clone()
        } else {
            let mut data = vec![0.0; self.m * v.r];
            data[..v.m * v.r].copy_from_slice(&v.data);
            data
        }
    }
}

impl Backend for PjrtEngine {
    fn spmv(&self, blk: &EllBlock, x_halo: &[f64], y: &mut [f64]) -> f64 {
        let reply = self.submit_cached(&|force| Op::Spmv {
            mat: self.mat_arg(blk, force),
            x_halo: x_halo[..blk.x_halo_len()].to_vec(),
        });
        y[..blk.rows].copy_from_slice(&reply.outs[0][..blk.rows]);
        self.charge(costs::spmv(&self.model, blk.rows, blk.x_halo_len()), reply.elapsed)
    }

    fn dot_partials(&self, v: &DenseBasis, m_used: usize, w: &[f64], out: &mut [f64]) -> f64 {
        let reply = self.submit_cached(&|force| Op::DotPartials {
            v: self.basis_arg(v, force),
            m_used,
            w: w[..v.r].to_vec(),
        });
        out.fill(0.0);
        let take = v.m.min(out.len());
        out[..take].copy_from_slice(&reply.outs[0][..take]);
        self.charge(costs::dot_partials(&self.model, m_used, v.r), reply.elapsed)
    }

    fn update_w(&self, v: &DenseBasis, m_used: usize, w: &mut [f64], h: &[f64]) -> (f64, f64) {
        // The HLO graph applies all M rows of h; zero the masked tail.
        let mut h_full = vec![0.0; self.m];
        h_full[..m_used].copy_from_slice(&h[..m_used]);
        let reply = self.submit_cached(&|force| Op::UpdateW {
            v: self.basis_arg(v, force),
            w: w[..v.r].to_vec(),
            h: h_full.clone(),
        });
        w[..v.r].copy_from_slice(&reply.outs[0][..v.r]);
        let nsq = reply.outs[1][0];
        (nsq, self.charge(costs::update_w(&self.model, m_used, v.r), reply.elapsed))
    }

    fn update_x(&self, v: &DenseBasis, m_used: usize, y: &[f64], x: &mut [f64]) -> f64 {
        let mut y_full = vec![0.0; self.m];
        y_full[..m_used].copy_from_slice(&y[..m_used]);
        let reply = self.submit_cached(&|force| Op::UpdateX {
            v: self.basis_arg(v, force),
            y: y_full.clone(),
            x: x[..v.r].to_vec(),
        });
        x[..v.r].copy_from_slice(&reply.outs[0][..v.r]);
        self.charge(costs::update_x(&self.model, m_used, v.r), reply.elapsed)
    }

    fn scale(&self, w: &mut [f64], alpha: f64) -> f64 {
        let r = w.len();
        let reply = self.submit_cached(&|force| {
            let _ = force;
            Op::Scale { w: w.to_vec(), alpha }
        });
        w.copy_from_slice(&reply.outs[0][..r]);
        self.charge(costs::scale(&self.model, r), reply.elapsed)
    }

    fn name(&self) -> &'static str {
        "pjrt"
    }
}

// ---------------------------------------------------------------------
// Runtime service thread
// ---------------------------------------------------------------------

struct Server {
    man: Manifest,
    client: xla::PjRtClient,
    execs: HashMap<(Graph, usize), xla::PjRtLoadedExecutable>,
    /// Device-resident basis buffers: id -> (gen, bucket, buffer).
    basis_cache: HashMap<u64, (u64, usize, xla::PjRtBuffer)>,
    /// Device-resident matrix blocks: uid -> (bucket, vals, cols).
    mat_cache: HashMap<u64, (usize, xla::PjRtBuffer, xla::PjRtBuffer)>,
}

/// Bound device memory: clear the caches wholesale past this many entries
/// (misses self-heal via the retry protocol).
const CACHE_CAP: usize = 96;

fn server(man: Manifest, rx: std::sync::mpsc::Receiver<Request>) {
    let client = match xla::PjRtClient::cpu() {
        Ok(c) => c,
        Err(e) => {
            while let Ok(req) = rx.recv() {
                let _ = req.reply.send(Err(format!("PJRT CPU client init failed: {e}")));
            }
            return;
        }
    };
    let mut srv = Server {
        man,
        client,
        execs: HashMap::new(),
        basis_cache: HashMap::new(),
        mat_cache: HashMap::new(),
    };
    while let Ok(req) = rx.recv() {
        let t0 = Instant::now();
        let result = srv
            .run(req.op)
            .map(|outs| Reply { outs, elapsed: t0.elapsed().as_secs_f64() });
        let _ = req.reply.send(result.map_err(|e| e.to_string()));
    }
}

impl Server {
    fn exec(&mut self, g: Graph, bucket: usize) -> anyhow::Result<&xla::PjRtLoadedExecutable> {
        if !self.execs.contains_key(&(g, bucket)) {
            let path = self.man.file(g, bucket).to_path_buf();
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow::anyhow!("non-utf8 path"))?,
            )?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.execs.insert((g, bucket), exe);
        }
        Ok(&self.execs[&(g, bucket)])
    }

    /// Fetch-or-upload the basis device buffer for (id, gen) at `bucket`.
    fn basis_buffer(&mut self, v: &BasisArg, bucket: usize) -> anyhow::Result<()> {
        let m = self.man.m;
        if let Some((gen, b, _)) = self.basis_cache.get(&v.id) {
            if *gen == v.gen && *b == bucket {
                return Ok(());
            }
        }
        let Some(data) = &v.data else {
            anyhow::bail!("{CACHE_MISS}");
        };
        anyhow::ensure!(data.len() == m * v.r, "basis payload shape mismatch");
        let padded = pad_basis(data, m, v.r, bucket);
        if self.basis_cache.len() >= CACHE_CAP {
            self.basis_cache.clear();
        }
        let buf = self.client.buffer_from_host_buffer::<f64>(&padded, &[m, bucket], None)?;
        self.basis_cache.insert(v.id, (v.gen, bucket, buf));
        Ok(())
    }

    fn mat_buffers(&mut self, mat: &MatArg, bucket: usize) -> anyhow::Result<()> {
        if let Some((b, _, _)) = self.mat_cache.get(&mat.uid) {
            if *b == bucket {
                return Ok(());
            }
        }
        let Some((vals, cols)) = &mat.data else {
            anyhow::bail!("{CACHE_MISS}");
        };
        let mut v = vec![0.0f64; bucket * K];
        v[..vals.len()].copy_from_slice(vals);
        let mut c = vec![0i32; bucket * K];
        c[..cols.len()].copy_from_slice(cols);
        if self.mat_cache.len() >= CACHE_CAP {
            self.mat_cache.clear();
        }
        let vb = self.client.buffer_from_host_buffer::<f64>(&v, &[bucket, K], None)?;
        let cb = self.client.buffer_from_host_buffer::<i32>(&c, &[bucket, K], None)?;
        self.mat_cache.insert(mat.uid, (bucket, vb, cb));
        Ok(())
    }

    fn upload_f64(&self, data: &[f64], len: usize) -> anyhow::Result<xla::PjRtBuffer> {
        if data.len() == len {
            Ok(self.client.buffer_from_host_buffer::<f64>(data, &[len], None)?)
        } else {
            let mut padded = vec![0.0f64; len];
            padded[..data.len()].copy_from_slice(data);
            Ok(self.client.buffer_from_host_buffer::<f64>(&padded, &[len], None)?)
        }
    }

    fn run(&mut self, op: Op) -> anyhow::Result<Vec<Vec<f64>>> {
        match op {
            Op::Spmv { mat, x_halo } => {
                let b = self.man.bucket_for(mat.rows)?;
                let rh = b + self.man.halo_pad;
                anyhow::ensure!(
                    x_halo.len() <= rh,
                    "halo too large: {} > {rh} (grid plane exceeds HALO_PAD)",
                    x_halo.len()
                );
                self.exec(Graph::Spmv, b)?;
                self.mat_buffers(&mat, b)?;
                let x_b = self.upload_f64(&x_halo, rh)?;
                let (_, vals_b, cols_b) = &self.mat_cache[&mat.uid];
                let exe = &self.execs[&(Graph::Spmv, b)];
                let out = exe.execute_b(&[vals_b, cols_b, &x_b])?[0][0]
                    .to_literal_sync()?;
                Ok(vec![out.to_tuple1()?.to_vec::<f64>()?])
            }
            Op::DotPartials { v, m_used, w } => {
                let m = self.man.m;
                let b = self.man.bucket_for(v.r)?;
                self.exec(Graph::DotPartials, b)?;
                self.basis_buffer(&v, b)?;
                let w_b = self.upload_f64(&w, b)?;
                let mask: Vec<f64> = (0..m).map(|i| if i < m_used { 1.0 } else { 0.0 }).collect();
                let mask_b = self.upload_f64(&mask, m)?;
                let (_, _, v_b) = &self.basis_cache[&v.id];
                let exe = &self.execs[&(Graph::DotPartials, b)];
                let out = exe.execute_b(&[v_b, &w_b, &mask_b])?[0][0].to_literal_sync()?;
                Ok(vec![out.to_tuple1()?.to_vec::<f64>()?])
            }
            Op::UpdateW { v, w, h } => {
                let b = self.man.bucket_for(v.r)?;
                self.exec(Graph::UpdateW, b)?;
                self.basis_buffer(&v, b)?;
                let w_b = self.upload_f64(&w, b)?;
                let h_b = self.upload_f64(&h, self.man.m)?;
                let (_, _, v_b) = &self.basis_cache[&v.id];
                let exe = &self.execs[&(Graph::UpdateW, b)];
                let out = exe.execute_b(&[v_b, &w_b, &h_b])?[0][0].to_literal_sync()?;
                let (wn, nsq) = out.to_tuple2()?;
                Ok(vec![wn.to_vec::<f64>()?, nsq.to_vec::<f64>()?])
            }
            Op::UpdateX { v, y, x } => {
                let b = self.man.bucket_for(v.r)?;
                self.exec(Graph::UpdateX, b)?;
                self.basis_buffer(&v, b)?;
                let y_b = self.upload_f64(&y, self.man.m)?;
                let x_b = self.upload_f64(&x, b)?;
                let (_, _, v_b) = &self.basis_cache[&v.id];
                let exe = &self.execs[&(Graph::UpdateX, b)];
                let out = exe.execute_b(&[v_b, &y_b, &x_b])?[0][0].to_literal_sync()?;
                Ok(vec![out.to_tuple1()?.to_vec::<f64>()?])
            }
            Op::Scale { w, alpha } => {
                let b = self.man.bucket_for(w.len())?;
                self.exec(Graph::Scale, b)?;
                let w_b = self.upload_f64(&w, b)?;
                let a_b = self.upload_f64(&[alpha], 1)?;
                let exe = &self.execs[&(Graph::Scale, b)];
                let out = exe.execute_b(&[&w_b, &a_b])?[0][0].to_literal_sync()?;
                Ok(vec![out.to_tuple1()?.to_vec::<f64>()?])
            }
        }
    }
}

/// Pad an (m x r) row-major basis to (m x bucket).
fn pad_basis(v: &[f64], m: usize, r: usize, bucket: usize) -> Vec<f64> {
    if r == bucket {
        return v.to_vec();
    }
    let mut padded = vec![0.0f64; m * bucket];
    for j in 0..m {
        padded[j * bucket..j * bucket + r].copy_from_slice(&v[j * r..(j + 1) * r]);
    }
    padded
}


