//! # ulfm-ftgmres
//!
//! A full reimplementation of *"Shrink or Substitute: Handling Process
//! Failures in HPC Systems using In-situ Recovery"* (Ashraf, Hukerikar,
//! Engelmann — ORNL, 2018) as a three-layer Rust + JAX + Pallas system.
//!
//! * **L3 (this crate)** — a simulated-cluster message-passing runtime with
//!   ULFM semantics ([`simmpi`]), an erasure-coded in-memory checkpoint
//!   store with mirror / XOR-parity / double-parity (`rs2`) schemes, delta
//!   commits and RLE wire compression ([`ckptstore`] over the per-rank
//!   store in [`checkpoint`]), the *shrink* and
//!   *substitute* in-situ recovery
//!   strategies plus the adaptive per-event policy engine and spare-pool
//!   manager ([`recovery`], [`recovery::policy`], [`spares`]), and a
//!   distributed FT-GMRES solver ([`solver`]) over a 3D-Laplacian test
//!   problem ([`problem`]).
//! * **L2/L1 (build time)** — the solver's local step graphs and the ELL
//!   SpMV Pallas kernel, AOT-lowered to `artifacts/*.hlo.txt` by
//!   `python/compile/aot.py` and executed via the PJRT CPU client
//!   ([`runtime`]).  Python never runs on the request path.
//!
//! See DESIGN.md for the system inventory and the experiment index mapping
//! every paper figure to a bench target, and EXPERIMENTS.md for measured
//! results.

pub mod backend;
pub mod checkpoint;
pub mod ckptstore;
pub mod config;
pub mod coordinator;
pub mod failure;
pub mod figures;
pub mod metrics;
pub mod netsim;
pub mod problem;
pub mod recovery;
pub mod runtime;
pub mod simmpi;
pub mod solver;
pub mod spares;
pub mod trace;
