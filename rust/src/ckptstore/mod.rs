//! Erasure-coded in-memory checkpoint subsystem (DESIGN.md §8).
//!
//! Replaces the flat ship-`k`-full-copies buddy scheme with three layers:
//!
//! * an **encoding layer** ([`scheme`]) — pluggable redundancy:
//!   `mirror:<k>` (the paper's buddy replication, default) and `xor:<g>`
//!   (parity groups of `g` ranks; one XOR stripe per group per object on a
//!   holder outside the group, cutting redundant memory from `k x state`
//!   to `state / g`);
//! * a **delta layer** ([`delta`]) — dynamic objects ship chunk-level
//!   diffs against the last committed version with periodic full rebases
//!   (`ckpt_delta`, `ckpt_chunk_kib`, `ckpt_rebase_every`), cutting bytes
//!   shipped per commit;
//! * a **recovery reader** ([`reconstruct_failed`]) — rebuilds a failed
//!   rank's objects from surviving group members plus parity (or serves
//!   mirror buddy copies), shared by shrink and substitute recovery, and a
//!   loss assessor ([`assess_loss`]) that detects *unrecoverable* losses
//!   (two failures in one parity group before a re-encode, a group member
//!   plus its holder, or a rank plus all its mirror buddies) so the policy
//!   engine can escalate to a global restart instead of wedging.
//!
//! Group-failure escalation matrix (`xor:<g>`, between re-encodes):
//!
//! | Loss pattern                    | Outcome                             |
//! |---------------------------------|-------------------------------------|
//! | 1 member of a group             | in-situ reconstruct via parity      |
//! | holder only                     | nothing lost; stripe rebuilt at next commit |
//! | ≥ 2 members of one group        | escalate: `GlobalRestart`           |
//! | 1 member + that group's holder  | escalate: `GlobalRestart`           |
//!
//! Every commit is still sealed by the fault-aware agreement, so a failure
//! mid-commit leaves the previous committed version intact, and commit
//! metrics ([`crate::metrics::CkptRecord`]) record bytes shipped and
//! encode time per commit for the checkpoint-overhead figures.

pub mod delta;
pub mod scheme;

pub use scheme::Scheme;

use crate::checkpoint::{
    buddy_of_stride, effective_stride, ward_of_stride, CkptStore, ObjId, ParityStripe, Version,
};
use crate::metrics::{CkptRecord, Phase};
use crate::simmpi::{tags, Blob, Comm, Ctx, MpiResult, Tag, WorldRank};

/// Checkpoint-store configuration (config keys `ckpt_scheme`, `ckpt_delta`,
/// `ckpt_chunk_kib`, `ckpt_rebase_every`; CLI `--ckpt-scheme` /
/// `--ckpt-delta`).
#[derive(Debug, Clone)]
pub struct CkptCfg {
    /// Redundancy scheme.
    pub scheme: Scheme,
    /// Ship dynamic commits as chunk deltas against the last committed
    /// version (full rebases every `rebase_every` versions).
    pub delta: bool,
    /// Delta chunk size in KiB (1 KiB = 128 words).
    pub chunk_kib: usize,
    /// Versions between full rebases when the delta layer is on.
    pub rebase_every: u32,
    /// Modeled encode/fold throughput (bytes/s) for XOR folding and delta
    /// scans — a deliberately simple memory-bandwidth-style knob so every
    /// rank charges identical, deterministic virtual time.
    pub encode_bytes_per_sec: f64,
}

impl Default for CkptCfg {
    fn default() -> Self {
        CkptCfg {
            scheme: Scheme::default(),
            delta: false,
            chunk_kib: 4,
            rebase_every: 8,
            encode_bytes_per_sec: 4e9,
        }
    }
}

impl CkptCfg {
    /// The paper's original configuration: `mirror:<k>`, no delta.
    pub fn mirror(k: usize) -> Self {
        CkptCfg { scheme: Scheme::Mirror { k }, ..CkptCfg::default() }
    }

    /// Delta chunk size in 64-bit words.
    pub fn chunk_words(&self) -> usize {
        (self.chunk_kib.max(1) * 1024) / 8
    }

    /// Whether commit `version` ships deltas (`fresh` commits — initial
    /// establishment and post-recovery re-establishment — always rebase,
    /// because membership or layout just changed).
    pub fn use_delta(&self, version: Version, fresh: bool) -> bool {
        self.delta
            && !fresh
            && version > 0
            && version % self.rebase_every.max(1) as i64 != 0
    }
}

/// Buddy-copy shipping tag (mirror scheme), object `id` to buddy distance
/// `d`.  Public so protocol tests can interleave with the real exchange.
pub fn ship_tag(id: ObjId, d: usize) -> Tag {
    tags::CKPT_BASE + id * 16 + d as u32
}

fn parity_tag(id: ObjId) -> Tag {
    tags::CKPT_PARITY_BASE + id
}

fn recon_tag(id: ObjId, failed_cr: usize) -> Tag {
    tags::RECON_BASE + id * 4096 + failed_cr as u32
}

/// Charge deterministic encode/fold time for touching `words` 64-bit words.
fn charge_encode(ctx: &mut Ctx, cfg: &CkptCfg, words: usize, acc: &mut f64) {
    let secs = (8 * words) as f64 / cfg.encode_bytes_per_sec;
    ctx.advance(secs);
    *acc += secs;
}

/// Coordinated checkpoint commit of `objs` at `version` under `cfg`.
///
/// Called at a quiescent point by every member of `comm`.  `fresh` marks
/// establishment commits (initial setup and post-recovery), which always
/// ship full payloads.  The version is committed only after a fault-aware
/// agreement, so a failure mid-commit leaves the previous committed version
/// intact; afterwards versions below the committed floor are garbage-
/// collected on both the local and the redundancy side.
pub fn commit(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &mut CkptStore,
    objs: &[(ObjId, Blob)],
    version: Version,
    cfg: &CkptCfg,
    fresh: bool,
) -> MpiResult<()> {
    // Post-recovery re-establishment is charged to Recovery (the paper
    // counts "updating all the in-memory checkpoints" as recovery cost);
    // steady-state checkpoints get their own bucket.
    let prev = if ctx.phase == Phase::Recovery {
        Phase::Recovery
    } else {
        ctx.set_phase(Phase::Checkpoint)
    };
    let result = commit_inner(ctx, comm, store, objs, version, cfg, fresh);
    ctx.set_phase(prev);
    result
}

fn commit_inner(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &mut CkptStore,
    objs: &[(ObjId, Blob)],
    version: Version,
    cfg: &CkptCfg,
    fresh: bool,
) -> MpiResult<()> {
    let n = comm.size();
    let use_delta = cfg.use_delta(version, fresh);
    let mut shipped = 0usize;
    let mut encode_secs = 0.0f64;
    let logical: usize = objs.iter().map(|(_, b)| b.bytes()).sum();

    let result = if cfg.scheme.xor_active(n) {
        let Scheme::Xor { g } = cfg.scheme else { unreachable!() };
        exchange_xor(
            ctx, comm, store, objs, version, cfg, g, use_delta, &mut shipped, &mut encode_secs,
        )
    } else {
        let k = cfg.scheme.mirror_k().min(n.saturating_sub(1));
        exchange_mirror(
            ctx, comm, store, objs, version, cfg, k, use_delta, &mut shipped, &mut encode_secs,
        )
    };
    result?;

    // Global commit: everyone stored everything.
    comm.agree(ctx, u64::MAX)?;
    store.commit(version);
    if fresh {
        store.note_fresh(version);
    }
    store.gc_committed();
    ctx.ckpt_log.push(CkptRecord {
        version,
        at: ctx.clock,
        logical_bytes: logical,
        shipped_bytes: shipped,
        delta: use_delta,
        encode_secs,
    });
    Ok(())
}

/// Mirror exchange: store locally, ship (full or delta) copies to `k` ring
/// buddies, materialize the copies received for this rank's wards.
#[allow(clippy::too_many_arguments)]
fn exchange_mirror(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &mut CkptStore,
    objs: &[(ObjId, Blob)],
    version: Version,
    cfg: &CkptCfg,
    k: usize,
    use_delta: bool,
    shipped: &mut usize,
    encode_secs: &mut f64,
) -> MpiResult<()> {
    let n = comm.size();
    let me = comm.rank;
    let stride = effective_stride(&ctx.world.net.params, n);
    // Delta mode: encode wires against the pre-commit store state.  Full
    // mode ships the objects themselves, with no intermediate copies.
    let wires: Option<Vec<Blob>> = if use_delta {
        let mut w = Vec::with_capacity(objs.len());
        for (id, blob) in objs {
            let (bv, base) = store
                .get_local_at_most(*id, version - 1)
                .unwrap_or_else(|| panic!("delta base for obj {id} missing"));
            let wire = delta::mirror_delta_wire(base, blob, bv, cfg.chunk_words());
            charge_encode(
                ctx,
                cfg,
                blob.f.len() + blob.i.len() + base.f.len() + base.i.len(),
                encode_secs,
            );
            let factor = delta::wire_factor(blob);
            w.push(wire.scaled(factor));
        }
        Some(w)
    } else {
        None
    };
    for (id, blob) in objs {
        store.put_local(*id, version, blob.clone());
    }
    // Ship to all buddies first (unbounded channels: no deadlock), then
    // receive the copies this rank holds for its wards.
    for d in 1..=k {
        let buddy = buddy_of_stride(me, d, n, stride);
        for (i, (id, blob)) in objs.iter().enumerate() {
            let wire = match &wires {
                Some(w) => w[i].clone(),
                None => blob.clone(),
            };
            *shipped += wire.bytes();
            comm.send(ctx, buddy, ship_tag(*id, d), wire)?;
        }
    }
    for d in 1..=k {
        let ward = ward_of_stride(me, d, n, stride);
        let owner_wr = comm.world_of(ward);
        for (id, _) in objs {
            let wire = comm.recv(ctx, ward, ship_tag(*id, d))?;
            if use_delta {
                let bv = wire.i[1];
                let factor = delta::wire_factor(&wire);
                let base = store
                    .get_remote(owner_wr, *id, bv)
                    .unwrap_or_else(|| {
                        panic!("buddy delta base v{bv} for owner {owner_wr} obj {id} missing")
                    })
                    .clone();
                let (bv2, out) = delta::apply_mirror_delta(&base, &wire);
                debug_assert_eq!(bv2, bv);
                charge_encode(ctx, cfg, out.f.len() + out.i.len(), encode_secs);
                store.put_remote(owner_wr, *id, version, out.scaled(factor));
            } else {
                store.put_remote(owner_wr, *id, version, wire);
            }
        }
    }
    Ok(())
}

/// Xor exchange: store locally, ship one (full or delta) parity
/// contribution per object to the group's holder; holders fold the stripes
/// for the groups they protect.
#[allow(clippy::too_many_arguments)]
fn exchange_xor(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &mut CkptStore,
    objs: &[(ObjId, Blob)],
    version: Version,
    cfg: &CkptCfg,
    g: usize,
    use_delta: bool,
    shipped: &mut usize,
    encode_secs: &mut f64,
) -> MpiResult<()> {
    let n = comm.size();
    let me = comm.rank;
    let my_holder = scheme::holder_cr(scheme::group_of(me, g), g, n);
    // Encode contributions against the pre-commit store, then store.
    let mut wires: Vec<Blob> = Vec::with_capacity(objs.len());
    for (id, blob) in objs {
        let words = blob.f.len() + blob.i.len();
        let wire = if use_delta {
            let (bv, base) = store
                .get_local_at_most(*id, version - 1)
                .unwrap_or_else(|| panic!("delta base for obj {id} missing"));
            charge_encode(ctx, cfg, words + base.f.len() + base.i.len(), encode_secs);
            delta::xor_delta_wire(base, blob, bv, cfg.chunk_words())
        } else {
            charge_encode(ctx, cfg, words, encode_secs);
            delta::xor_full_wire(blob)
        };
        wires.push(wire.scaled(delta::wire_factor(blob)));
    }
    for (id, blob) in objs {
        store.put_local(*id, version, blob.clone());
    }
    for ((id, _), wire) in objs.iter().zip(&wires) {
        *shipped += wire.bytes();
        comm.send(ctx, my_holder, parity_tag(*id), wire.clone())?;
    }
    // Fold stripes for every group this rank holds parity for.
    for grp in 0..scheme::n_groups(n, g) {
        if scheme::holder_cr(grp, g, n) != me {
            continue;
        }
        let (start, len) = scheme::group_span(grp, g, n);
        let anchor = comm.world_of(start);
        let members: Vec<WorldRank> = (start..start + len).map(|cr| comm.world_of(cr)).collect();
        for (id, _) in objs {
            let mut stripe = if use_delta {
                let (sv, base) = store
                    .get_parity_at_most(anchor, *id, version - 1)
                    .unwrap_or_else(|| panic!("parity base stripe for obj {id} missing"));
                debug_assert_eq!(sv, version - 1, "stripe chain broken");
                debug_assert_eq!(base.members, members, "group membership changed mid-chain");
                base.clone()
            } else {
                ParityStripe {
                    members: members.clone(),
                    f_lens: vec![0; len],
                    i_lens: vec![0; len],
                    wire_factors: vec![1.0; len],
                    words: Vec::new(),
                }
            };
            for slot in 0..len {
                let wire = comm.recv(ctx, start + slot, parity_tag(*id))?;
                let factor = delta::wire_factor(&wire);
                if use_delta {
                    let (bv, f_len, i_len) = delta::fold_xor_delta(&mut stripe.words, &wire);
                    debug_assert_eq!(bv, version - 1, "contribution diffed a stale base");
                    stripe.f_lens[slot] = f_len;
                    stripe.i_lens[slot] = i_len;
                } else {
                    let (f_len, i_len) = delta::fold_xor_full(&mut stripe.words, &wire);
                    stripe.f_lens[slot] = f_len;
                    stripe.i_lens[slot] = i_len;
                }
                stripe.wire_factors[slot] = factor;
                charge_encode(ctx, cfg, wire.i.len(), encode_secs);
            }
            store.put_parity(anchor, *id, version, stripe);
        }
    }
    Ok(())
}

/// Whether the objects lost with the currently-dead members of
/// `old_members` can be rebuilt in situ.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LossCheck {
    /// Every failed rank's state has a live server (buddy or parity group).
    Recoverable,
    /// At least one failed rank's state cannot be rebuilt; the reason names
    /// the rank and the redundancy that died with it.
    Unrecoverable(String),
}

/// Deterministic in-situ recoverability check, evaluated identically by
/// every survivor from the shared liveness registry (the same construction
/// the policy engine and the redistribution planner use).
pub fn assess_loss(
    cfg: &CkptCfg,
    old_members: &[WorldRank],
    alive: &dyn Fn(WorldRank) -> bool,
    stride: usize,
) -> LossCheck {
    let n = old_members.len();
    let alive_cr = |cr: usize| alive(old_members[cr]);
    for (cr, &wr) in old_members.iter().enumerate() {
        if alive(wr) {
            continue;
        }
        if cfg.scheme.server_cr_for(cr, n, &alive_cr, stride).is_none() {
            let why = match cfg.scheme {
                Scheme::Mirror { k } => format!(
                    "rank {wr} (comm rank {cr}) and all {k} of its buddy copies are lost"
                ),
                Scheme::Xor { g } => {
                    let grp = scheme::group_of(cr, g);
                    format!(
                        "rank {wr} (comm rank {cr}) lost with a second failure in \
                         parity group {grp} (or the group's parity holder) before re-encode"
                    )
                }
            };
            return LossCheck::Unrecoverable(why);
        }
    }
    LossCheck::Recoverable
}

/// Recovery reader: materialize every currently-dead old member's objects
/// at (or below) restore version `v` into the store of the rank that will
/// serve them, reconstructing from surviving group members plus parity for
/// the xor scheme.  Mirror schemes are a no-op (buddy copies already sit in
/// the store).  Must be called by every *survivor* of `old_members` (not by
/// adopted spares) with the same arguments, over a repaired communicator
/// `comm` that contains all survivors; afterwards the usual
/// `get_remote_at_most` serving paths work unchanged for both shrink and
/// substitute recovery.
pub fn reconstruct_failed(
    ctx: &mut Ctx,
    comm: &Comm,
    store: &mut CkptStore,
    cfg: &CkptCfg,
    old_members: &[WorldRank],
    v: Version,
    objs: &[ObjId],
) -> MpiResult<()> {
    let Scheme::Xor { g } = cfg.scheme else {
        return Ok(());
    };
    let n_old = old_members.len();
    if !cfg.scheme.xor_active(n_old) {
        return Ok(());
    }
    let world = ctx.world.clone();
    let Some(me_old) = old_members.iter().position(|&wr| wr == ctx.rank) else {
        return Ok(());
    };
    let failed: Vec<usize> =
        (0..n_old).filter(|&cr| !world.is_alive(old_members[cr])).collect();
    for &fr in &failed {
        let grp = scheme::group_of(fr, g);
        let (start, len) = scheme::group_span(grp, g, n_old);
        let holder = scheme::holder_cr(grp, g, n_old);
        debug_assert!(
            world.is_alive(old_members[holder]),
            "unrecoverable loss must be escalated before reconstruction"
        );
        if me_old == holder {
            let anchor = old_members[start];
            for &id in objs {
                let (sv, stripe) = {
                    let (sv, s) = store
                        .get_parity_at_most(anchor, id, v)
                        .unwrap_or_else(|| panic!("parity stripe for obj {id} missing"));
                    (sv, s.clone())
                };
                let mut acc = stripe.words.clone();
                for cr in start..start + len {
                    if cr == fr {
                        continue;
                    }
                    let src = comm
                        .rank_of_world(old_members[cr])
                        .expect("surviving group member must be in the repaired comm");
                    let blob = comm.recv(ctx, src, recon_tag(id, fr))?;
                    delta::xor_into(&mut acc, &delta::pack_words(&blob));
                    ctx.advance(
                        (8 * (blob.f.len() + blob.i.len())) as f64 / cfg.encode_bytes_per_sec,
                    );
                }
                let slot = fr - start;
                let mut out =
                    delta::unpack_words(&acc, stripe.f_lens[slot], stripe.i_lens[slot]);
                let factor = stripe.wire_factors[slot];
                if factor != 1.0 {
                    out = out.scaled(factor);
                }
                store.put_remote(old_members[fr], id, sv, out);
            }
        } else if scheme::group_of(me_old, g) == grp && me_old != fr {
            let dst = comm
                .rank_of_world(old_members[holder])
                .expect("parity holder must be in the repaired comm");
            for &id in objs {
                let blob = store
                    .get_local_at_most(id, v)
                    .unwrap_or_else(|| panic!("local checkpoint for obj {id} missing"))
                    .1
                    .clone();
                comm.send(ctx, dst, recon_tag(id, fr), blob)?;
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cfg_surface() {
        let cfg = CkptCfg::default();
        assert_eq!(cfg.scheme, Scheme::Mirror { k: 1 });
        assert!(!cfg.delta);
        assert_eq!(cfg.chunk_words(), 512);
        let m2 = CkptCfg::mirror(2);
        assert_eq!(m2.scheme, Scheme::Mirror { k: 2 });
    }

    #[test]
    fn delta_rebase_schedule() {
        let mut cfg = CkptCfg { delta: true, rebase_every: 4, ..CkptCfg::default() };
        // Fresh commits always rebase.
        assert!(!cfg.use_delta(5, true));
        // Multiples of rebase_every rebase.
        assert!(!cfg.use_delta(8, false));
        assert!(cfg.use_delta(5, false));
        assert!(cfg.use_delta(7, false));
        // Delta off: never.
        cfg.delta = false;
        assert!(!cfg.use_delta(5, false));
    }

    #[test]
    fn assess_loss_mirror_and_xor() {
        let members: Vec<usize> = (0..8).collect();
        let m1 = CkptCfg::mirror(1);
        let dead_pair = |a: usize, b: usize| move |wr: usize| wr != a && wr != b;
        // Adjacent pair under mirror:1 loses rank 2's only copy (on 3).
        assert!(matches!(
            assess_loss(&m1, &members, &dead_pair(2, 3), 1),
            LossCheck::Unrecoverable(_)
        ));
        // Non-adjacent pair is fine.
        assert_eq!(assess_loss(&m1, &members, &dead_pair(2, 5), 1), LossCheck::Recoverable);
        let x4 = CkptCfg { scheme: Scheme::Xor { g: 4 }, ..CkptCfg::default() };
        // Two losses in group 0: unrecoverable.
        match assess_loss(&x4, &members, &dead_pair(1, 2), 1) {
            LossCheck::Unrecoverable(why) => assert!(why.contains("parity group 0"), "{why}"),
            other => panic!("expected unrecoverable, got {other:?}"),
        }
        // One loss per group: recoverable.
        assert_eq!(assess_loss(&x4, &members, &dead_pair(1, 5), 1), LossCheck::Recoverable);
        // Member + its group's holder (rank 4 holds group 0): unrecoverable.
        assert!(matches!(
            assess_loss(&x4, &members, &dead_pair(1, 4), 1),
            LossCheck::Unrecoverable(_)
        ));
    }

    #[test]
    fn tag_namespaces_stay_in_their_windows() {
        // Mirror ship tags stay below the parity window.
        assert!(ship_tag(crate::checkpoint::obj::BASIS, 15) < parity_tag(0));
        // Parity tags stay inside the checkpoint window.
        assert!(parity_tag(crate::checkpoint::obj::BASIS) < tags::HALO_BASE);
        // Reconstruction tags stay inside the recovery window.
        assert!(recon_tag(crate::checkpoint::obj::BASIS, 4095) < tags::CKPT_BASE);
        assert!(recon_tag(0, 0) >= tags::RECON_BASE);
    }
}
