//! Erasure-coded in-memory checkpoint subsystem (DESIGN.md §8–§9).
//!
//! Replaces the flat ship-`k`-full-copies buddy scheme with four layers:
//!
//! * an **encoding layer** ([`scheme`]) — pluggable redundancy:
//!   `mirror:<k>` (the paper's buddy replication, default), `xor:<g>`
//!   (parity groups of `g` ranks; one XOR stripe per group per object on a
//!   holder outside the group, cutting redundant memory from `k x state`
//!   to `state / g`), and `rs2:<g>` (RAID-6-style *double* parity: an XOR
//!   `P` stripe plus a GF(2^8)-weighted `Q` stripe ([`gf256`]) on two
//!   rotating holders outside the group, so any two in-group losses
//!   reconstruct in situ);
//! * a **delta layer** ([`delta`]) — dynamic objects ship chunk-level
//!   diffs against the last committed version with periodic full rebases
//!   (`ckpt_delta`, `ckpt_chunk_kib`, `ckpt_rebase_every`), cutting bytes
//!   shipped per commit;
//! * a **compression layer** ([`delta::rle_compress`]; `ckpt_compress`,
//!   CLI `--ckpt-compress`) — word-level RLE with zero-run elision over
//!   every buddy, parity and reconstruction payload; transport-only and
//!   loss-less, with per-commit raw-vs-compressed byte metrics;
//! * an **integrity layer** (`ckpt_integrity`, DESIGN.md §14) — per-chunk
//!   digests ([`chunk_sums`]) recorded at every commit, plus a pre-commit
//!   **scrubber** that detects silently corrupted committed blobs
//!   (`--inject-bitflip`) and repairs them bit-identically from the
//!   scheme's own redundancy (buddy copy, XOR stripe fold, or the rs2
//!   one-/two-erasure solve), escalating to a crash-stop failure only
//!   when the corruption exceeds what the parity covers;
//! * a **recovery reader** ([`reconstruct_failed`]) — rebuilds a failed
//!   rank's objects from surviving group members plus parity (or serves
//!   mirror buddy copies), shared by shrink, substitute and the
//!   global-restart assessment, and a loss assessor ([`assess_loss`])
//!   that detects *unrecoverable* losses so the policy engine can
//!   escalate to a global restart instead of wedging.
//!
//! # Commit protocol
//!
//! [`commit`] runs at a quiescent point on every member of the
//! communicator: each rank stores its objects locally, ships the
//! scheme-specific redundancy (full copies, deltas, or parity
//! contributions), materializes/folds what it holds for others, and then
//! seals the version with a fault-aware agreement — a failure mid-commit
//! leaves the previous committed version intact on every rank.  Under
//! `rs2`, members ship one contribution to the epoch's `P` holder, which
//! folds the XOR stripe, builds the combined GF-weighted `Q` update from
//! the same payloads, and forwards it to the `Q` holder (one extra wire
//! per group instead of a second full contribution per member).  Holder
//! pairs advance one rotation slot per rebase epoch
//! ([`CkptCfg::rot_index`], [`scheme::rs2_holders`]); commits at epoch
//! boundaries re-encode *every* object — including the statics — so all
//! stripes for a restorable version live on that version's holder pair.
//!
//! # Recovery-reader contract
//!
//! Every *survivor* of the failed communicator calls
//! [`reconstruct_failed`] with identical arguments after the loss was
//! assessed [`LossCheck::Recoverable`]; the reader materializes each
//! failed rank's objects on the rank [`Scheme::server_cr_for`] designates
//! (mirror buddy, xor holder, or the rs2 reconstruction leader, which
//! gathers survivor blobs plus the needed stripes and runs the one- or
//! two-erasure solve), after which the ordinary `get_remote_at_most`
//! serving paths work unchanged for shrink, substitute and global-restart
//! recovery alike.
//!
//! Group-failure escalation matrix (between re-encodes):
//!
//! | Loss pattern                    | `xor:<g>`            | `rs2:<g>` |
//! |---------------------------------|----------------------|-----------|
//! | 1 member of a group             | reconstruct (stripe) | reconstruct (`P` or `Q`) |
//! | holder(s) only                  | nothing lost; stripe re-homed at next commit | same |
//! | 2 members of one group          | escalate: `GlobalRestart` | reconstruct (two-erasure solve) |
//! | 1 member + a stripe holder      | escalate: `GlobalRestart` | reconstruct (surviving stripe) |
//! | 3+ members (or 2 + both holders)| escalate             | escalate: `GlobalRestart` |
//!
//! Holder-only losses are scheme-generic: a failed rank that merely held
//! some *other* group's stripe never blocks in-situ recovery — its own
//! objects are covered by its own group's redundancy, and the orphaned
//! stripe is re-homed by the next (establishment) commit's re-encode.
//!
//! Commit metrics ([`crate::metrics::CkptRecord`]) record logical, raw and
//! compressed bytes shipped, the rotation index, and encode time per
//! commit for the checkpoint-overhead figures.

pub mod delta;
pub mod gf256;
pub mod scheme;

pub use scheme::Scheme;

use crate::checkpoint::{
    buddy_of_stride, effective_stride, ward_of_stride, CkptStore, ObjId, ParityStripe, Version,
};
use crate::failure::ProtoPhase;
use crate::metrics::{CkptRecord, Phase};
use crate::simmpi::{tags, Blob, Comm, Ctx, MpiResult, Tag, WorldRank};

/// Checkpoint-store configuration (config keys `ckpt_scheme`, `ckpt_delta`,
/// `ckpt_chunk_kib`, `ckpt_rebase_every`, `ckpt_compress`, `ckpt_async`; CLI
/// `--ckpt-scheme` / `--ckpt-delta` / `--ckpt-compress` / `--ckpt-async`).
#[derive(Debug, Clone)]
pub struct CkptCfg {
    /// Redundancy scheme.
    pub scheme: Scheme,
    /// Ship dynamic commits as chunk deltas against the last committed
    /// version (full rebases every `rebase_every` versions).
    pub delta: bool,
    /// Delta chunk size in KiB (1 KiB = 128 words).
    pub chunk_kib: usize,
    /// Versions between full rebases when the delta layer is on; also the
    /// `rs2` holder-rotation period (see [`CkptCfg::rot_index`]).
    pub rebase_every: u32,
    /// Compress every redundancy payload with word-level RLE
    /// ([`delta::rle_compress`]) before it goes on the wire.
    pub compress: bool,
    /// Integrity layer (config key `ckpt_integrity`): record per-chunk
    /// digests of every committed object and run the corruption scrubber
    /// at the start of each steady-state commit.  Auto-enabled by the
    /// coordinator when the injection plan carries `--inject-bitflip`
    /// faults.
    pub integrity: bool,
    /// Modeled encode/fold throughput (bytes/s) for XOR folding and delta
    /// scans — a deliberately simple memory-bandwidth-style knob so every
    /// rank charges identical, deterministic virtual time.
    pub encode_bytes_per_sec: f64,
    /// Non-blocking commits (config key `ckpt_async`; CLI `--ckpt-async`).
    /// When on, a steady-state commit returns after the cheap publish half
    /// (encode + sends + local puts) and leaves the receive/fold/agree half
    /// *in flight*; the solver overlaps the next outer cycle's compute
    /// against it, and the commit seals at the next commit entry (or at
    /// solve end) via [`drain_in_flight`].  Named `async_commit` because
    /// `async` is a reserved word.  See DESIGN.md §15.
    pub async_commit: bool,
}

impl Default for CkptCfg {
    fn default() -> Self {
        CkptCfg {
            scheme: Scheme::default(),
            delta: false,
            chunk_kib: 4,
            rebase_every: 8,
            compress: false,
            integrity: false,
            encode_bytes_per_sec: 4e9,
            async_commit: false,
        }
    }
}

impl CkptCfg {
    /// The paper's original configuration: `mirror:<k>`, no delta.
    pub fn mirror(k: usize) -> Self {
        CkptCfg { scheme: Scheme::Mirror { k }, ..CkptCfg::default() }
    }

    /// Delta chunk size in 64-bit words.
    pub fn chunk_words(&self) -> usize {
        (self.chunk_kib.max(1) * 1024) / 8
    }

    /// Whether commit `version` ships deltas (`fresh` commits — initial
    /// establishment and post-recovery re-establishment — always rebase,
    /// because membership or layout just changed).
    pub fn use_delta(&self, version: Version, fresh: bool) -> bool {
        self.delta
            && !fresh
            && version > 0
            && version % self.rebase_every.max(1) as i64 != 0
    }

    /// `rs2` holder-rotation index of `version`: one slot per rebase
    /// epoch, i.e. `version / rebase_every`.
    ///
    /// Rotating per *epoch* rather than per version is deliberate: a delta
    /// contribution folds into the stripe at `version - 1`, which must
    /// therefore live on the *same* holder — and `use_delta` is false at
    /// every epoch boundary (`version % rebase_every == 0`), so each
    /// rotation step coincides with a full re-encode that cleanly hands
    /// the stripes to the incoming holder pair.  Every rank derives the
    /// same index from the version alone, so the recovery reader and the
    /// loss assessor agree on the holder pair with no negotiation.
    pub fn rot_index(&self, version: Version) -> u64 {
        (version / self.rebase_every.max(1) as i64).max(0) as u64
    }

    /// Whether commit `version` must re-encode the *static* objects too
    /// (`rs2` only): at every rotation boundary the incoming holder pair
    /// starts with no stripes at all, so statics — which otherwise ship
    /// only at establishment — are re-encoded along with the rebase.  This
    /// is what keeps *all* of a restorable version's stripes on that
    /// version's holder pair (one rotation index per restore, see
    /// [`assess_loss`]).
    pub fn static_reencode_due(&self, version: Version) -> bool {
        matches!(self.scheme, Scheme::Rs2 { .. })
            && version % self.rebase_every.max(1) as i64 == 0
    }
}

/// Buddy-copy shipping tag (mirror scheme), object `id` to buddy distance
/// `d`.  Public so protocol tests can interleave with the real exchange.
pub fn ship_tag(id: ObjId, d: usize) -> Tag {
    tags::CKPT_BASE + id * 16 + d as u32
}

fn parity_tag(id: ObjId) -> Tag {
    tags::CKPT_PARITY_BASE + id
}

/// rs2 combined Q-stripe forward (P holder -> Q holder) for one object of
/// one parity group.
fn qpar_tag(id: ObjId, grp: usize) -> Tag {
    tags::CKPT_QPAR_BASE + id * 1024 + grp as u32
}

fn recon_tag(id: ObjId, failed_cr: usize) -> Tag {
    tags::RECON_BASE + id * 4096 + failed_cr as u32
}

/// rs2 reconstruction gather (surviving member -> leader).
fn recon_member_tag(id: ObjId, grp: usize) -> Tag {
    tags::RECON_MEMBER_BASE + id * 1024 + grp as u32
}

/// rs2 stripe transfer (holder -> leader); `which` is 0 for P, 1 for Q.
fn recon_stripe_tag(id: ObjId, grp: usize, which: usize) -> Tag {
    tags::RECON_STRIPE_BASE + id * 2048 + (grp as u32) * 2 + which as u32
}

/// Charge deterministic encode/fold time for touching `words` 64-bit words.
fn charge_encode(ctx: &mut Ctx, cfg: &CkptCfg, words: usize, acc: &mut f64) {
    let secs = (8 * words) as f64 / cfg.encode_bytes_per_sec;
    ctx.advance(secs);
    *acc += secs;
}

/// Scrub repair traffic (stripe or blob transfer to a corrupt rank):
/// object `id` destined for comm rank `cr` (DESIGN.md §14).
fn scrub_tag(id: ObjId, cr: usize) -> Tag {
    tags::SCRUB_BASE + id * 65_536 + cr as u32
}

/// Per-chunk 64-bit FNV-1a digests over the packed words of `blob`
/// ([`delta::pack_words`]), one digest per `chunk_words` window — the same
/// chunking the delta layer diffs at, so a corrupt chunk names exactly the
/// data a repair must replace.  Used by the integrity layer
/// (`ckpt_integrity`) to detect silent checkpoint corruption.
pub fn chunk_sums(blob: &Blob, chunk_words: usize) -> Vec<u64> {
    let words = delta::pack_words(blob);
    let cw = chunk_words.max(1);
    words
        .chunks(cw)
        .map(|c| {
            let mut h = 0xcbf2_9ce4_8422_2325u64;
            for &w in c {
                for b in (w as u64).to_le_bytes() {
                    h ^= b as u64;
                    h = h.wrapping_mul(0x0000_0100_0000_01b3);
                }
            }
            h
        })
        .collect()
}

/// Silent-data-corruption injection (`--inject-bitflip`): flip `bits`
/// deterministic bit positions in the freshly committed solution block
/// ([`crate::checkpoint::obj::X`]).  Only the *local* copy is corrupted —
/// the buddy copies and parity stripes this commit just shipped stay
/// clean, which is exactly the redundancy the scrubber repairs from.
fn inject_bitflip(ctx: &mut Ctx, store: &mut CkptStore, version: Version, bits: u32) {
    use crate::checkpoint::obj;
    let Some((v, blob)) = store.get_local_at_most(obj::X, version) else { return };
    let factor = delta::wire_factor(blob);
    let (f_len, i_len) = (blob.f.len(), blob.i.len());
    let mut words = delta::pack_words(blob);
    if words.is_empty() {
        return;
    }
    let nbits = words.len() * 64;
    let mut flipped = std::collections::BTreeSet::new();
    for j in 0..(bits as usize).min(nbits) {
        // Deterministic spread over the block; linear-probe duplicates.
        let mut p = (j * 0x9e37 + 0x79b9) % nbits;
        while !flipped.insert(p) {
            p = (p + 1) % nbits;
        }
        words[p / 64] ^= 1i64 << (p % 64);
    }
    let mut out = delta::unpack_words(&words, f_len, i_len);
    if factor != 1.0 {
        out = out.scaled(factor);
    }
    store.put_local(obj::X, v, out);
    let (at, n) = (ctx.clock, flipped.len() as i64);
    ctx.trace_push(|| crate::trace::TraceEvent::Mark { label: "bitflip", arg: n, t: at });
}

/// Install a repaired blob if it verifies bit-identical against the
/// recorded digest; returns whether it did.
fn finish_repair(
    ctx: &mut Ctx,
    store: &mut CkptStore,
    cfg: &CkptCfg,
    id: ObjId,
    v: Version,
    blob: Blob,
) -> bool {
    let ok = store
        .sums_for(id, v)
        .is_some_and(|s| chunk_sums(&blob, cfg.chunk_words()) == s);
    if ok {
        store.put_local(id, v, blob);
        ctx.faults.scrub_repaired += 1;
        let at = ctx.clock;
        ctx.trace_push(|| crate::trace::TraceEvent::Mark {
            label: "scrub-repaired",
            arg: id as i64,
            t: at,
        });
    }
    ok
}

/// Background corruption scrubber (DESIGN.md §14), run collectively at the
/// start of every steady-state commit when the integrity layer is on.
///
/// Each rank verifies its committed objects against their recorded
/// digests, the damage reports are allgathered so every rank derives the
/// same deterministic repair schedule, and each corrupt blob is rebuilt
/// bit-identically from the scheme's own redundancy: the first buddy's
/// full copy under `mirror:<k>`, the group stripe XOR-folded with the
/// clean members' blobs under `xor:<g>`, and the one- or two-erasure
/// GF(2^8) solve under `rs2:<g>`.  Corruption the parity cannot cover
/// (two corrupt members of an `xor` group, three of an `rs2` group) is
/// escalated to the policy engine the same way any other unrecoverable
/// state is: the corrupt rank converts to a crash-stop failure
/// ([`Ctx::die`]) and the ordinary recovery path — which sees the clean
/// redundancy, not the corrupt local copy — takes over.
async fn scrub(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &mut CkptStore,
    cfg: &CkptCfg,
) -> MpiResult<()> {
    let n = comm.size();
    let me = comm.rank;
    // Verify my own committed objects against their recorded digests.
    let mut bad: Vec<(ObjId, Version)> = Vec::new();
    for (id, v) in store.summed_objects() {
        let Some(blob) = store.get_local(id, v) else { continue };
        let fine = store
            .sums_for(id, v)
            .is_some_and(|s| chunk_sums(blob, cfg.chunk_words()) == s);
        if !fine {
            bad.push((id, v));
        }
    }
    ctx.faults.scrub_detected += bad.len() as u64;
    // Share the damage reports — collective even when everyone is clean,
    // so all ranks agree on the repair schedule (and on virtual time).
    let mut wire: Vec<i64> = vec![bad.len() as i64];
    for &(id, v) in &bad {
        wire.push(id as i64);
        wire.push(v);
    }
    let all = comm.allgather(ctx, Blob::from_i64s(wire)).await?;
    let mut entries: Vec<(usize, ObjId, Version)> = Vec::new();
    for (cr, b) in all.iter().enumerate() {
        for j in 0..b.i[0] as usize {
            entries.push((cr, b.i[1 + 2 * j] as ObjId, b.i[2 + 2 * j]));
        }
    }
    if entries.is_empty() {
        return Ok(());
    }
    // Ranks whose corruption the redundancy cannot cover: they escalate
    // below, after serving whatever clean data other repairs need.
    let mut doomed: std::collections::BTreeSet<usize> = Default::default();
    let stride = effective_stride(&ctx.world.net.params, n);
    match cfg.scheme {
        Scheme::Xor { g } if cfg.scheme.parity_active(n) => {
            for (grp, id, v, crs) in scrub_groups(&entries, g) {
                if crs.len() > 1 {
                    // Two corrupt members of one group: the single stripe
                    // cannot separate them.
                    doomed.extend(crs);
                    continue;
                }
                let cr = crs[0];
                let (start, len) = scheme::group_span(grp, g, n);
                let holder = scheme::holder_cr(grp, g, n);
                let anchor = comm.world_of(start);
                if me == holder {
                    let wire = {
                        let (sv, s) = store
                            .get_parity_at_most(anchor, id, v)
                            .unwrap_or_else(|| panic!("scrub stripe for obj {id} missing"));
                        stripe_wire(sv, s)
                    };
                    comm.send(ctx, cr, scrub_tag(id, cr), wire)?;
                } else if me != cr && scheme::group_of(me, g) == grp {
                    let blob = store
                        .get_local_at_most(id, v)
                        .unwrap_or_else(|| panic!("scrub contribution for obj {id} missing"))
                        .1
                        .clone();
                    comm.send(ctx, cr, scrub_tag(id, cr), blob)?;
                }
                if me == cr {
                    let members: Vec<WorldRank> =
                        (start..start + len).map(|c| comm.world_of(c)).collect();
                    let recvd = comm.recv(ctx, holder, scrub_tag(id, cr)).await?;
                    let (_, stripe) = parse_stripe_wire(&recvd, &members);
                    let mut acc = stripe.words.clone();
                    for c in start..start + len {
                        if c == cr {
                            continue;
                        }
                        let b = comm.recv(ctx, c, scrub_tag(id, cr)).await?;
                        delta::xor_into(&mut acc, &delta::pack_words(&b));
                        ctx.advance(
                            (8 * (b.f.len() + b.i.len())) as f64 / cfg.encode_bytes_per_sec,
                        );
                    }
                    let slot = cr - start;
                    let mut out =
                        delta::unpack_words(&acc, stripe.f_lens[slot], stripe.i_lens[slot]);
                    let factor = stripe.wire_factors[slot];
                    if factor != 1.0 {
                        out = out.scaled(factor);
                    }
                    if !finish_repair(ctx, store, cfg, id, v, out) {
                        doomed.insert(me);
                    }
                }
            }
        }
        Scheme::Rs2 { g } if cfg.scheme.parity_active(n) => {
            for (grp, id, v, crs) in scrub_groups(&entries, g) {
                if crs.len() > 2 {
                    doomed.extend(crs);
                    continue;
                }
                let (start, len) = scheme::group_span(grp, g, n);
                let anchor = comm.world_of(start);
                let (p_cr, q_cr) = scheme::rs2_holders(grp, g, n, cfg.rot_index(v));
                let two = crs.len() == 2;
                // Holders ship their stripes to every corrupt member; the
                // corrupt members run the solve themselves (everyone is
                // alive during a scrub, unlike reconstruction).
                if me == p_cr || (two && me == q_cr) {
                    let wire = {
                        let (sv, s) = store
                            .get_parity_at_most(anchor, id, v)
                            .unwrap_or_else(|| panic!("scrub stripe for obj {id} missing"));
                        stripe_wire(sv, s)
                    };
                    for &cr in &crs {
                        comm.send(ctx, cr, scrub_tag(id, cr), wire.clone())?;
                    }
                }
                if scheme::group_of(me, g) == grp && !crs.contains(&me) {
                    let blob = store
                        .get_local_at_most(id, v)
                        .unwrap_or_else(|| panic!("scrub contribution for obj {id} missing"))
                        .1
                        .clone();
                    for &cr in &crs {
                        comm.send(ctx, cr, scrub_tag(id, cr), blob.clone())?;
                    }
                }
                if crs.contains(&me) {
                    let members: Vec<WorldRank> =
                        (start..start + len).map(|c| comm.world_of(c)).collect();
                    let recvd = comm.recv(ctx, p_cr, scrub_tag(id, me)).await?;
                    let (_, p) = parse_stripe_wire(&recvd, &members);
                    let mut pw = p.words.clone();
                    let mut qw = if two {
                        let recvd = comm.recv(ctx, q_cr, scrub_tag(id, me)).await?;
                        Some(parse_stripe_wire(&recvd, &members).1.words)
                    } else {
                        None
                    };
                    for c in start..start + len {
                        if crs.contains(&c) {
                            continue;
                        }
                        let b = comm.recv(ctx, c, scrub_tag(id, me)).await?;
                        let words = delta::pack_words(&b);
                        delta::xor_into(&mut pw, &words);
                        if let Some(qw) = qw.as_mut() {
                            gf256::mul_xor_into(qw, &words, gf256::coef(c - start));
                        }
                        ctx.advance(
                            (8 * (b.f.len() + b.i.len())) as f64 / cfg.encode_bytes_per_sec,
                        );
                    }
                    let my_slot = me - start;
                    let words = match qw.take() {
                        Some(qw) => {
                            let (s0, s1) = (crs[0] - start, crs[1] - start);
                            let (wi, wj) = gf256::solve_two_erasures(
                                &pw,
                                &qw,
                                gf256::coef(s0),
                                gf256::coef(s1),
                            );
                            if my_slot == s0 {
                                wi
                            } else {
                                wj
                            }
                        }
                        None => pw,
                    };
                    let mut out =
                        delta::unpack_words(&words, p.f_lens[my_slot], p.i_lens[my_slot]);
                    let factor = p.wire_factors[my_slot];
                    if factor != 1.0 {
                        out = out.scaled(factor);
                    }
                    if !finish_repair(ctx, store, cfg, id, v, out) {
                        doomed.insert(me);
                    }
                }
            }
        }
        // Mirror, and parity schemes degraded below their activation
        // bound: the first buddy holds a clean full copy.
        _ => {
            let k = cfg.scheme.mirror_k().min(n.saturating_sub(1));
            for &(cr, id, v) in &entries {
                if k == 0 {
                    doomed.insert(cr);
                    continue;
                }
                let buddy = buddy_of_stride(cr, 1, n, stride);
                if me == buddy {
                    let blob = store
                        .get_remote_at_most(comm.world_of(cr), id, v)
                        .unwrap_or_else(|| panic!("scrub buddy copy for obj {id} missing"))
                        .1
                        .clone();
                    comm.send(ctx, cr, scrub_tag(id, cr), blob)?;
                }
                if me == cr {
                    let blob = comm.recv(ctx, buddy, scrub_tag(id, cr)).await?;
                    ctx.advance(
                        (8 * (blob.f.len() + blob.i.len())) as f64 / cfg.encode_bytes_per_sec,
                    );
                    if !finish_repair(ctx, store, cfg, id, v, blob) {
                        doomed.insert(me);
                    }
                }
            }
        }
    }
    if doomed.contains(&me) {
        // Parity cannot cover this corruption in situ: escalate to the
        // policy engine by converting the silent fault into a crash-stop
        // failure.  Recovery then restores from the *clean* redundancy —
        // or, when that too is insufficient (the same group pattern that
        // doomed the scrub), assess_loss escalates to a global restart.
        let at = ctx.clock;
        ctx.trace_push(|| crate::trace::TraceEvent::Mark {
            label: "scrub-unrepairable",
            arg: me as i64,
            t: at,
        });
        return Err(ctx.die());
    }
    Ok(())
}

/// Damage entries grouped per (parity group, object), corrupt comm ranks
/// ascending — the shared deterministic repair schedule.
fn scrub_groups(
    entries: &[(usize, ObjId, Version)],
    g: usize,
) -> Vec<(usize, ObjId, Version, Vec<usize>)> {
    let mut groups: Vec<(usize, ObjId, Version, Vec<usize>)> = Vec::new();
    for &(cr, id, v) in entries {
        let grp = scheme::group_of(cr, g);
        match groups.iter_mut().find(|(gg, ii, _, _)| *gg == grp && *ii == id) {
            Some((_, _, _, crs)) => crs.push(cr),
            None => groups.push((grp, id, v, vec![cr])),
        }
    }
    groups.sort_by_key(|&(gg, ii, _, _)| (gg, ii));
    groups
}

/// Coordinated checkpoint commit of `objs` at `version` under `cfg`.
///
/// Called at a quiescent point by every member of `comm`.  `fresh` marks
/// establishment commits (initial setup and post-recovery), which always
/// ship full payloads.  The version is committed only after a fault-aware
/// agreement, so a failure mid-commit leaves the previous committed version
/// intact; afterwards versions below the committed floor are garbage-
/// collected on both the local and the redundancy side.
pub async fn commit(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &mut CkptStore,
    objs: &[(ObjId, Blob)],
    version: Version,
    cfg: &CkptCfg,
    fresh: bool,
) -> MpiResult<()> {
    // Post-recovery re-establishment is charged to Recovery (the paper
    // counts "updating all the in-memory checkpoints" as recovery cost);
    // steady-state checkpoints get their own bucket.
    let prev = if ctx.phase == Phase::Recovery {
        Phase::Recovery
    } else {
        ctx.set_phase(Phase::Checkpoint)
    };
    let result = commit_inner(ctx, comm, store, objs, version, cfg, fresh).await;
    ctx.set_phase(prev);
    result
}

/// A published-but-unsealed commit (DESIGN.md §15): the cheap synchronous
/// half ran — wires encoded against the pre-commit store, local versions
/// stored, every redundancy payload sent — and the receive/fold/agree half
/// is still owed.  Everything the drain needs is re-derivable from this
/// record plus the communicator: the receive schedule is a pure function of
/// `(scheme, version, obj_ids, comm)`, so no blob payloads are retained.
///
/// Safety is the committed-floor story: nothing here is reachable by a
/// restore until [`seal_commit`] runs the fault-aware agreement and
/// advances the floor, and every store write is idempotent-by-version, so
/// cancelling an in-flight commit (recovery entry does) just strands
/// above-floor versions that the next commit overwrites or GC drops.
#[derive(Debug, Clone)]
pub struct InFlightCommit {
    pub(crate) version: Version,
    pub(crate) use_delta: bool,
    pub(crate) obj_ids: Vec<ObjId>,
    pub(crate) logical_bytes: usize,
    pub(crate) shipped: usize,
    pub(crate) raw: usize,
    pub(crate) encode_secs: f64,
    pub(crate) cfg: CkptCfg,
}

/// Seal the in-flight async commit, if any: run its receive/fold half, the
/// commit agreement and the bookkeeping tail.  A fast no-op (no clock, no
/// trace, no messages) when nothing is in flight, so sync-mode call sites
/// cost nothing.  Collective when a commit *is* in flight — every member of
/// `comm` published the same version, so every member has the same drain
/// owed and the agreement schedule stays in lockstep.
pub async fn drain_in_flight(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &mut CkptStore,
) -> MpiResult<()> {
    if !store.has_in_flight() {
        return Ok(());
    }
    let prev = if ctx.phase == Phase::Recovery {
        Phase::Recovery
    } else {
        ctx.set_phase(Phase::Checkpoint)
    };
    let result = drain_inner(ctx, comm, store).await;
    ctx.set_phase(prev);
    result
}

/// Drop the in-flight async commit without sealing it; returns whether one
/// was actually cancelled.  Called by every survivor at fenced-recovery
/// entry: survivors must never *drain* there — a drain's agreement crosses
/// the dead rank and the attempt would just re-enter the fence — and a
/// uniform cancel keeps them collectively consistent.  The stranded
/// above-floor puts are harmless (idempotent-by-version, invisible to
/// `*_at_most(floor)` readers) and the post-recovery establishment commit
/// rewrites them wholesale.
pub fn cancel_in_flight(store: &mut CkptStore) -> bool {
    store.take_in_flight().is_some()
}

/// Take-then-drain: ownership of the in-flight record moves out of the
/// store *before* the receive half runs, so an error mid-drain (a peer died
/// under the agreement) leaves nothing behind — the failed drain degrades
/// into a cancel and fenced recovery finds a clean store.
async fn drain_inner(ctx: &mut Ctx, comm: &mut Comm, store: &mut CkptStore) -> MpiResult<()> {
    let Some(mut fl) = store.take_in_flight() else {
        return Ok(());
    };
    drain_commit(ctx, comm, store, &mut fl).await?;
    seal_commit(ctx, comm, store, &mut fl, false).await
}

async fn commit_inner(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &mut CkptStore,
    objs: &[(ObjId, Blob)],
    version: Version,
    cfg: &CkptCfg,
    fresh: bool,
) -> MpiResult<()> {
    // One-deep commit pipeline: a previous commit still in flight seals
    // before this one publishes, so delta bases and parity-stripe chains
    // always step version by version.  Zero-op when nothing is in flight
    // (the sync path never is), keeping sync digests byte-identical.
    drain_inner(ctx, comm, store).await?;
    // Fault point: a member (or stripe holder) dying as the commit starts.
    // Atomicity-by-version holds regardless of where in the exchange the
    // death lands: the version is committed only by the agreement in
    // `seal_commit`, so survivors of a torn commit keep the previous
    // committed floor intact and the commit is re-runnable after recovery.
    ctx.phase_point(ProtoPhase::CkptCommit)?;
    // Integrity scrub: verify the committed blobs against their recorded
    // digests and repair corrupt ones from redundancy *before* this
    // commit's delta encoding reads them as bases (DESIGN.md §14).  Fresh
    // commits skip it — membership just changed and every blob and stripe
    // is about to be rewritten from live state anyway.
    if cfg.integrity && !fresh {
        scrub(ctx, comm, store, cfg).await?;
    }
    let mut fl = InFlightCommit {
        version,
        use_delta: cfg.use_delta(version, fresh),
        obj_ids: objs.iter().map(|(id, _)| *id).collect(),
        logical_bytes: objs.iter().map(|(_, b)| b.bytes()).sum(),
        shipped: 0,
        raw: 0,
        encode_secs: 0.0,
        cfg: cfg.clone(),
    };
    publish_commit(ctx, comm, store, objs, &mut fl)?;
    if cfg.async_commit && !fresh {
        // Fault point: the published-but-unsealed window (`--inject-phase
        // <rank>:ckpt-ship`).  A death here strands the publish on every
        // survivor; recovery entry cancels it and restores from the floor.
        ctx.phase_point(ProtoPhase::CkptShip)?;
        store.set_in_flight(fl);
        return Ok(());
    }
    drain_commit(ctx, comm, store, &mut fl).await?;
    seal_commit(ctx, comm, store, &mut fl, fresh).await
}

/// Publish half of the commit state machine: encode redundancy wires
/// against the pre-commit store, store the new local versions, and send
/// every payload.  Entirely synchronous — sends never block in simmpi
/// (unbounded mailboxes) — which is what makes the async return cheap.
fn publish_commit(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &mut CkptStore,
    objs: &[(ObjId, Blob)],
    fl: &mut InFlightCommit,
) -> MpiResult<()> {
    let n = comm.size();
    let version = fl.version;
    let use_delta = fl.use_delta;
    let cfg = fl.cfg.clone();
    match cfg.scheme {
        Scheme::Xor { g } if cfg.scheme.parity_active(n) => publish_xor(
            ctx, comm, store, objs, version, &cfg, g, use_delta, &mut fl.shipped, &mut fl.raw,
            &mut fl.encode_secs,
        ),
        Scheme::Rs2 { g } if cfg.scheme.parity_active(n) => publish_rs2(
            ctx, comm, store, objs, version, &cfg, g, use_delta, &mut fl.shipped, &mut fl.raw,
            &mut fl.encode_secs,
        ),
        _ => {
            let k = cfg.scheme.mirror_k().min(n.saturating_sub(1));
            publish_mirror(
                ctx, comm, store, objs, version, &cfg, k, use_delta, &mut fl.shipped,
                &mut fl.raw, &mut fl.encode_secs,
            )
        }
    }
}

/// Drain half of the commit state machine: the receive/fold side of the
/// exchange.  In sync mode it runs back-to-back with the publish (the op
/// sequence is exactly the pre-refactor blocking exchange); in async mode
/// it runs at the *next* commit entry, by which point the receiver's clock
/// has advanced through an outer cycle of compute and the modeled arrivals
/// are already in the past — that no-op wait is the hidden commit time.
async fn drain_commit(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &mut CkptStore,
    fl: &mut InFlightCommit,
) -> MpiResult<()> {
    let n = comm.size();
    match fl.cfg.scheme {
        Scheme::Xor { g } if fl.cfg.scheme.parity_active(n) => {
            drain_xor(ctx, comm, store, fl, g).await
        }
        Scheme::Rs2 { g } if fl.cfg.scheme.parity_active(n) => {
            drain_rs2(ctx, comm, store, fl, g).await
        }
        _ => {
            let k = fl.cfg.scheme.mirror_k().min(n.saturating_sub(1));
            drain_mirror(ctx, comm, store, fl, k).await
        }
    }
}

/// Seal: the commit agreement plus all post-agreement bookkeeping (floor
/// advance, GC, integrity digests, fault injection, the `CkptRecord`).
/// Runs with the exchange fully drained on this rank.
async fn seal_commit(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &mut CkptStore,
    fl: &mut InFlightCommit,
    fresh: bool,
) -> MpiResult<()> {
    let version = fl.version;
    let cfg = fl.cfg.clone();
    let n = comm.size();
    // Sub-phase boundary: redundancy exchange done, commit agreement next.
    let at = ctx.clock;
    ctx.trace_push(|| crate::trace::TraceEvent::Mark {
        label: "ckpt-exchanged",
        arg: version,
        t: at,
    });

    // Global commit: everyone stored everything.
    comm.agree(ctx, u64::MAX).await?;
    let at = ctx.clock;
    ctx.trace_push(|| crate::trace::TraceEvent::Mark {
        label: "ckpt-committed",
        arg: version,
        t: at,
    });
    store.commit(version);
    if fresh {
        store.note_fresh(version);
    }
    store.gc_committed();
    if cfg.integrity {
        // Digest the committed blobs out of the store (the publish half put
        // them there; shared buffers make this the caller's payload too).
        let pending: Vec<_> = fl
            .obj_ids
            .iter()
            .map(|&id| {
                let (v, blob) = store
                    .get_local_at_most(id, version)
                    .unwrap_or_else(|| panic!("committed blob for obj {id} missing"));
                debug_assert_eq!(v, version, "sealing a version that was never published");
                (id, chunk_sums(blob, cfg.chunk_words()), blob.f.len() + blob.i.len())
            })
            .collect();
        for (id, sums, words) in pending {
            charge_encode(ctx, &cfg, words, &mut fl.encode_secs);
            store.record_sums(id, version, sums);
        }
    }
    // Fault injection: one silent corruption of the freshly committed
    // solution block per flagged rank, caught by the next scrub pass.
    if !ctx.bitflip_done {
        let due = ctx
            .world
            .injector
            .bitflip_for(ctx.rank)
            .filter(|b| version >= b.at_version)
            .map(|b| b.bits);
        if let Some(bits) = due {
            inject_bitflip(ctx, store, version, bits);
            ctx.bitflip_done = true;
        }
    }
    let rotation = if matches!(cfg.scheme, Scheme::Rs2 { .. }) && cfg.scheme.parity_active(n) {
        cfg.rot_index(version) as i64
    } else {
        -1
    };
    ctx.ckpt_log.push(CkptRecord {
        version,
        at: ctx.clock,
        logical_bytes: fl.logical_bytes,
        shipped_bytes: fl.shipped,
        raw_bytes: fl.raw,
        delta: fl.use_delta,
        rotation,
        encode_secs: fl.encode_secs,
    });
    Ok(())
}

/// Mirror publish: store locally, ship (full or delta, optionally
/// compressed) copies to `k` ring buddies.  The matching [`drain_mirror`]
/// materializes the copies received for this rank's wards.
#[allow(clippy::too_many_arguments)]
fn publish_mirror(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &mut CkptStore,
    objs: &[(ObjId, Blob)],
    version: Version,
    cfg: &CkptCfg,
    k: usize,
    use_delta: bool,
    shipped: &mut usize,
    raw: &mut usize,
    encode_secs: &mut f64,
) -> MpiResult<()> {
    let n = comm.size();
    let me = comm.rank;
    let stride = effective_stride(&ctx.world.net.params, n);
    // Delta mode: encode wires against the pre-commit store state.  Full
    // mode ships the objects themselves (compressed as whole blobs when
    // the compression layer is on).
    let mut raw_per_obj: Vec<usize> = Vec::with_capacity(objs.len());
    let wires: Vec<Blob> = if use_delta {
        let mut w = Vec::with_capacity(objs.len());
        for (id, blob) in objs {
            let (bv, base) = store
                .get_local_at_most(*id, version - 1)
                .unwrap_or_else(|| panic!("delta base for obj {id} missing"));
            let base_words = base.f.len() + base.i.len();
            let wire = delta::mirror_delta_wire_in(
                &mut ctx.arena,
                base,
                blob,
                bv,
                cfg.chunk_words(),
            );
            charge_encode(
                ctx,
                cfg,
                blob.f.len() + blob.i.len() + base_words,
                encode_secs,
            );
            let factor = delta::wire_factor(blob);
            raw_per_obj.push(((8 * wire.i.len()) as f64 * factor) as usize);
            let wire = if cfg.compress {
                charge_encode(ctx, cfg, wire.i.len(), encode_secs);
                delta::compress_wire_in(&mut ctx.arena, &wire)
            } else {
                wire
            };
            w.push(wire.scaled(factor));
        }
        w
    } else {
        objs.iter()
            .map(|(_, blob)| {
                raw_per_obj.push(blob.bytes());
                if cfg.compress {
                    charge_encode(ctx, cfg, blob.f.len() + blob.i.len(), encode_secs);
                    delta::compress_blob_in(&mut ctx.arena, blob)
                } else {
                    // Shared-buffer clone: the store, the in-flight buddy
                    // copies and the caller's object all reference one
                    // payload (DESIGN.md §11).
                    blob.clone()
                }
            })
            .collect()
    };
    for (id, blob) in objs {
        store.put_local(*id, version, blob.clone());
    }
    // Ship to all buddies (unbounded channels: no deadlock); the drain half
    // receives the copies this rank holds for its wards.
    for d in 1..=k {
        let buddy = buddy_of_stride(me, d, n, stride);
        for (i, (id, _)) in objs.iter().enumerate() {
            *shipped += wires[i].bytes();
            *raw += raw_per_obj[i];
            comm.send(ctx, buddy, ship_tag(*id, d), wires[i].clone())?;
        }
    }
    Ok(())
}

/// Mirror drain: receive and materialize the buddy copies this rank holds
/// for its wards.
async fn drain_mirror(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &mut CkptStore,
    fl: &mut InFlightCommit,
    k: usize,
) -> MpiResult<()> {
    let n = comm.size();
    let me = comm.rank;
    let stride = effective_stride(&ctx.world.net.params, n);
    let version = fl.version;
    let use_delta = fl.use_delta;
    let cfg = fl.cfg.clone();
    let ids = fl.obj_ids.clone();
    for d in 1..=k {
        let ward = ward_of_stride(me, d, n, stride);
        let owner_wr = comm.world_of(ward);
        for id in &ids {
            let recvd = comm.recv(ctx, ward, ship_tag(*id, d)).await?;
            if use_delta {
                let factor = delta::wire_factor(&recvd);
                let wire =
                    if cfg.compress { delta::decompress_wire(&recvd) } else { recvd };
                let bv = wire.i[1];
                let base = store
                    .get_remote(owner_wr, *id, bv)
                    .unwrap_or_else(|| {
                        panic!("buddy delta base v{bv} for owner {owner_wr} obj {id} missing")
                    })
                    .clone();
                let (bv2, out) = delta::apply_mirror_delta(&base, &wire);
                debug_assert_eq!(bv2, bv);
                charge_encode(ctx, &cfg, out.f.len() + out.i.len(), &mut fl.encode_secs);
                store.put_remote(owner_wr, *id, version, out.scaled(factor));
            } else if cfg.compress {
                let out = delta::decompress_blob(&recvd);
                charge_encode(ctx, &cfg, out.f.len() + out.i.len(), &mut fl.encode_secs);
                store.put_remote(owner_wr, *id, version, out);
            } else {
                store.put_remote(owner_wr, *id, version, recvd);
            }
        }
    }
    Ok(())
}

/// Encode one parity contribution (full or delta) for `blob` against the
/// pre-commit store, charging encode time.  Returns the *uncompressed*
/// wire; callers compress and scale.
fn parity_contribution(
    ctx: &mut Ctx,
    store: &CkptStore,
    cfg: &CkptCfg,
    id: ObjId,
    blob: &Blob,
    version: Version,
    use_delta: bool,
    encode_secs: &mut f64,
) -> Blob {
    let words = blob.f.len() + blob.i.len();
    if use_delta {
        let (bv, base) = store
            .get_local_at_most(id, version - 1)
            .unwrap_or_else(|| panic!("delta base for obj {id} missing"));
        charge_encode(ctx, cfg, words + base.f.len() + base.i.len(), encode_secs);
        delta::xor_delta_wire_in(&mut ctx.arena, base, blob, bv, cfg.chunk_words())
    } else {
        charge_encode(ctx, cfg, words, encode_secs);
        delta::xor_full_wire(blob)
    }
}

/// Xor publish: store locally, ship one (full or delta, optionally
/// compressed) parity contribution per object to the group's holder.  The
/// matching [`drain_xor`] folds the stripes on the holders.
#[allow(clippy::too_many_arguments)]
fn publish_xor(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &mut CkptStore,
    objs: &[(ObjId, Blob)],
    version: Version,
    cfg: &CkptCfg,
    g: usize,
    use_delta: bool,
    shipped: &mut usize,
    raw: &mut usize,
    encode_secs: &mut f64,
) -> MpiResult<()> {
    let n = comm.size();
    let me = comm.rank;
    let my_holder = scheme::holder_cr(scheme::group_of(me, g), g, n);
    // Encode contributions against the pre-commit store, then store.
    let mut wires: Vec<Blob> = Vec::with_capacity(objs.len());
    for (id, blob) in objs {
        let wire =
            parity_contribution(ctx, store, cfg, *id, blob, version, use_delta, encode_secs);
        let factor = delta::wire_factor(blob);
        *raw += ((8 * wire.i.len()) as f64 * factor) as usize;
        let wire = if cfg.compress {
            charge_encode(ctx, cfg, wire.i.len(), encode_secs);
            delta::compress_wire_in(&mut ctx.arena, &wire)
        } else {
            wire
        };
        wires.push(wire.scaled(factor));
    }
    for (id, blob) in objs {
        store.put_local(*id, version, blob.clone());
    }
    for ((id, _), wire) in objs.iter().zip(&wires) {
        *shipped += wire.bytes();
        comm.send(ctx, my_holder, parity_tag(*id), wire.clone())?;
    }
    Ok(())
}

/// Xor drain: fold stripes for every group this rank holds parity for.
async fn drain_xor(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &mut CkptStore,
    fl: &mut InFlightCommit,
    g: usize,
) -> MpiResult<()> {
    let n = comm.size();
    let me = comm.rank;
    let version = fl.version;
    let use_delta = fl.use_delta;
    let cfg = fl.cfg.clone();
    let ids = fl.obj_ids.clone();
    for grp in 0..scheme::n_groups(n, g) {
        if scheme::holder_cr(grp, g, n) != me {
            continue;
        }
        let (start, len) = scheme::group_span(grp, g, n);
        let anchor = comm.world_of(start);
        let members: Vec<WorldRank> = (start..start + len).map(|cr| comm.world_of(cr)).collect();
        for id in &ids {
            let mut stripe = if use_delta {
                let (sv, base) = store
                    .get_parity_at_most(anchor, *id, version - 1)
                    .unwrap_or_else(|| panic!("parity base stripe for obj {id} missing"));
                debug_assert_eq!(sv, version - 1, "stripe chain broken");
                debug_assert_eq!(base.members, members, "group membership changed mid-chain");
                base.clone()
            } else {
                ParityStripe {
                    members: members.clone(),
                    f_lens: vec![0; len],
                    i_lens: vec![0; len],
                    wire_factors: vec![1.0; len],
                    words: Vec::new(),
                }
            };
            for slot in 0..len {
                let recvd = comm.recv(ctx, start + slot, parity_tag(*id)).await?;
                let factor = delta::wire_factor(&recvd);
                let wire =
                    if cfg.compress { delta::decompress_wire(&recvd) } else { recvd };
                if use_delta {
                    let (bv, f_len, i_len) = delta::fold_xor_delta(&mut stripe.words, &wire);
                    debug_assert_eq!(bv, version - 1, "contribution diffed a stale base");
                    stripe.f_lens[slot] = f_len;
                    stripe.i_lens[slot] = i_len;
                } else {
                    let (f_len, i_len) = delta::fold_xor_full(&mut stripe.words, &wire);
                    stripe.f_lens[slot] = f_len;
                    stripe.i_lens[slot] = i_len;
                }
                stripe.wire_factors[slot] = factor;
                charge_encode(ctx, &cfg, wire.i.len(), &mut fl.encode_secs);
            }
            store.put_parity(anchor, *id, version, stripe);
        }
    }
    Ok(())
}

/// rs2 publish (DESIGN.md §9): store locally, ship one contribution per
/// object to the epoch's `P` holder.  In the matching [`drain_rs2`], `P`
/// holders fold the XOR stripe, build the combined GF-weighted `Q` update
/// from the same payloads and forward it; `Q` holders apply the forward.
/// Members therefore ship each contribution once — double parity costs one
/// extra group-level wire per object, not a second per-member contribution.
#[allow(clippy::too_many_arguments)]
fn publish_rs2(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &mut CkptStore,
    objs: &[(ObjId, Blob)],
    version: Version,
    cfg: &CkptCfg,
    g: usize,
    use_delta: bool,
    shipped: &mut usize,
    raw: &mut usize,
    encode_secs: &mut f64,
) -> MpiResult<()> {
    let n = comm.size();
    let me = comm.rank;
    let rot = cfg.rot_index(version);
    let (my_p, _) = scheme::rs2_holders(scheme::group_of(me, g), g, n, rot);
    // Encode one contribution per object; the identical payload feeds both
    // stripes (the P holder re-weights it for Q), so members ship once.
    let mut wires: Vec<Blob> = Vec::with_capacity(objs.len());
    for (id, blob) in objs {
        let wire =
            parity_contribution(ctx, store, cfg, *id, blob, version, use_delta, encode_secs);
        let factor = delta::wire_factor(blob);
        *raw += ((8 * wire.i.len()) as f64 * factor) as usize;
        let wire = if cfg.compress {
            charge_encode(ctx, cfg, wire.i.len(), encode_secs);
            delta::compress_wire_in(&mut ctx.arena, &wire)
        } else {
            wire
        };
        wires.push(wire.scaled(factor));
    }
    for (id, blob) in objs {
        store.put_local(*id, version, blob.clone());
    }
    for ((id, _), wire) in objs.iter().zip(&wires) {
        *shipped += wire.bytes();
        comm.send(ctx, my_p, parity_tag(*id), wire.clone())?;
    }
    Ok(())
}

/// rs2 drain: the stripe work — P-holder folds, the Q forward, and the
/// Q-holder apply.  The Q forward is the one redundancy *send* that lives
/// in the drain half (it is derived from the received payloads), so its
/// bytes accrue to the in-flight counters here.
async fn drain_rs2(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &mut CkptStore,
    fl: &mut InFlightCommit,
    g: usize,
) -> MpiResult<()> {
    let n = comm.size();
    let me = comm.rank;
    let version = fl.version;
    let use_delta = fl.use_delta;
    let cfg = fl.cfg.clone();
    let ids = fl.obj_ids.clone();
    let rot = cfg.rot_index(version);
    // Stripe work, in group order.  P-fold work for a group depends only
    // on the upfront member sends, and Q holders wait only on P holders,
    // so processing groups in ascending order cannot deadlock.
    for grp in 0..scheme::n_groups(n, g) {
        let (p_cr, q_cr) = scheme::rs2_holders(grp, g, n, rot);
        let (start, len) = scheme::group_span(grp, g, n);
        let anchor = comm.world_of(start);
        let members: Vec<WorldRank> = (start..start + len).map(|cr| comm.world_of(cr)).collect();
        if p_cr == me {
            for id in &ids {
                let mut stripe = if use_delta {
                    let (sv, base) = store
                        .get_parity_at_most(anchor, *id, version - 1)
                        .unwrap_or_else(|| panic!("parity base stripe for obj {id} missing"));
                    debug_assert_eq!(sv, version - 1, "stripe chain broken");
                    debug_assert_eq!(base.members, members, "group membership changed mid-chain");
                    base.clone()
                } else {
                    ParityStripe {
                        members: members.clone(),
                        f_lens: vec![0; len],
                        i_lens: vec![0; len],
                        wire_factors: vec![1.0; len],
                        words: Vec::new(),
                    }
                };
                // Combined Q update: weighted fold of the same payloads,
                // accumulated in an arena scratch through the widened
                // GF(2^8) kernels (one `WideMul` per member slot).
                let mut q_words = ctx.arena.take();
                let mut q_chunks: std::collections::BTreeSet<usize> = Default::default();
                let mut q_total = 0usize;
                let mut q_cw = cfg.chunk_words();
                for slot in 0..len {
                    let recvd = comm.recv(ctx, start + slot, parity_tag(*id)).await?;
                    let factor = delta::wire_factor(&recvd);
                    let wire =
                        if cfg.compress { delta::decompress_wire(&recvd) } else { recvd };
                    let c = gf256::coef(slot);
                    if use_delta {
                        let (bv, f_len, i_len) =
                            delta::fold_xor_delta(&mut stripe.words, &wire);
                        debug_assert_eq!(bv, version - 1, "contribution diffed a stale base");
                        stripe.f_lens[slot] = f_len;
                        stripe.i_lens[slot] = i_len;
                        let view = delta::xdelta_view(&wire);
                        q_cw = view.chunk_words;
                        q_total = q_total.max(view.total);
                        if q_words.len() < view.total {
                            q_words.resize(view.total, 0);
                        }
                        let wm = gf256::WideMul::new(c);
                        for (ci, cwords) in &view.chunks {
                            q_chunks.insert(*ci);
                            let lo = ci * view.chunk_words;
                            for (off, w) in cwords.iter().enumerate() {
                                q_words[lo + off] ^= wm.mul(*w);
                            }
                        }
                    } else {
                        let (f_len, i_len) = delta::fold_xor_full(&mut stripe.words, &wire);
                        stripe.f_lens[slot] = f_len;
                        stripe.i_lens[slot] = i_len;
                        gf256::mul_xor_into(&mut q_words, &wire.i[3..], c);
                    }
                    stripe.wire_factors[slot] = factor;
                    charge_encode(ctx, &cfg, 2 * wire.i.len(), &mut fl.encode_secs);
                }
                // Forward the combined Q update to the Q holder.
                let q_wire = if use_delta {
                    qdelta_wire(version - 1, q_cw, q_total, &stripe, &q_chunks, &q_words)
                } else {
                    qfull_wire(version, &stripe, &q_words)
                };
                ctx.arena.put(q_words);
                let q_factor =
                    stripe.wire_factors.iter().copied().fold(1.0f64, f64::max);
                fl.raw += ((8 * q_wire.i.len()) as f64 * q_factor) as usize;
                let q_wire = if cfg.compress {
                    charge_encode(ctx, &cfg, q_wire.i.len(), &mut fl.encode_secs);
                    delta::compress_wire_in(&mut ctx.arena, &q_wire)
                } else {
                    q_wire
                };
                let q_wire = q_wire.scaled(q_factor);
                fl.shipped += q_wire.bytes();
                comm.send(ctx, q_cr, qpar_tag(*id, grp), q_wire)?;
                store.put_parity(anchor, *id, version, stripe);
            }
        }
        if q_cr == me {
            for id in &ids {
                let recvd = comm.recv(ctx, p_cr, qpar_tag(*id, grp)).await?;
                let wire =
                    if cfg.compress { delta::decompress_wire(&recvd) } else { recvd };
                charge_encode(ctx, &cfg, wire.i.len(), &mut fl.encode_secs);
                let stripe = match delta::wire_fmt(&wire) {
                    delta::FMT_QFULL => {
                        let (v2, stripe) = parse_qfull_wire(&wire, &members);
                        debug_assert_eq!(v2, version, "Q forward for the wrong version");
                        stripe
                    }
                    delta::FMT_QDELTA => {
                        let (sv, base) = store
                            .get_parity_at_most(anchor, *id, version - 1)
                            .unwrap_or_else(|| {
                                panic!("Q base stripe for obj {id} missing")
                            });
                        debug_assert_eq!(sv, version - 1, "Q stripe chain broken");
                        debug_assert_eq!(base.members, members, "group changed mid-chain");
                        apply_qdelta_wire(&wire, base)
                    }
                    fmt => panic!("unexpected Q-forward format {fmt}"),
                };
                store.put_parity(anchor, *id, version, stripe);
            }
        }
    }
    Ok(())
}

/// Shared stripe serialization used by both the Q forward
/// ([`delta::FMT_QFULL`]) and the holder-to-leader transfer
/// ([`delta::FMT_STRIPE`]): `[tag, version, n_slots, f_lens.., i_lens..,
/// factor_bits.., n_words, words...]` (factors ride as f64 bit patterns so
/// the whole wire stays in the compressible `i` lane).
fn encode_stripe(tag: i64, version: Version, stripe: &ParityStripe, words: &[i64]) -> Blob {
    let ns = stripe.f_lens.len();
    let mut i = Vec::with_capacity(4 + 3 * ns + words.len());
    i.push(tag);
    i.push(version);
    i.push(ns as i64);
    i.extend(stripe.f_lens.iter().map(|&v| v as i64));
    i.extend(stripe.i_lens.iter().map(|&v| v as i64));
    i.extend(stripe.wire_factors.iter().map(|&v| v.to_bits() as i64));
    i.push(words.len() as i64);
    i.extend_from_slice(words);
    Blob::from_i64s(i)
}

/// Inverse of [`encode_stripe`]; `expect_tag` guards against window mix-ups.
fn decode_stripe(expect_tag: i64, wire: &Blob, members: &[WorldRank]) -> (Version, ParityStripe) {
    debug_assert_eq!(wire.i[0], expect_tag, "unexpected stripe wire tag");
    let version = wire.i[1];
    let ns = wire.i[2] as usize;
    debug_assert_eq!(ns, members.len(), "stripe slot count mismatch");
    let f_lens: Vec<usize> = wire.i[3..3 + ns].iter().map(|&v| v as usize).collect();
    let i_lens: Vec<usize> = wire.i[3 + ns..3 + 2 * ns].iter().map(|&v| v as usize).collect();
    let wire_factors: Vec<f64> =
        wire.i[3 + 2 * ns..3 + 3 * ns].iter().map(|&v| f64::from_bits(v as u64)).collect();
    let nw = wire.i[3 + 3 * ns] as usize;
    let words = wire.i[4 + 3 * ns..4 + 3 * ns + nw].to_vec();
    (
        version,
        ParityStripe { members: members.to_vec(), f_lens, i_lens, wire_factors, words },
    )
}

/// Build a [`delta::FMT_QFULL`] forward: the complete Q stripe plus the
/// per-slot metadata the Q holder stores alongside it.
fn qfull_wire(version: Version, stripe: &ParityStripe, q_words: &[i64]) -> Blob {
    encode_stripe(delta::FMT_QFULL, version, stripe, q_words)
}

fn parse_qfull_wire(wire: &Blob, members: &[WorldRank]) -> (Version, ParityStripe) {
    decode_stripe(delta::FMT_QFULL, wire, members)
}

/// Build a [`delta::FMT_QDELTA`] forward: the union of the members'
/// changed chunks, already GF-weighted and folded.  Layout:
/// `[FMT_QDELTA, base_version, chunk_words, total, n_slots, f_lens..,
/// i_lens.., factor_bits.., n_chunks, idx.., chunk words...]`.
fn qdelta_wire(
    base_version: Version,
    cw: usize,
    total: usize,
    stripe: &ParityStripe,
    chunks: &std::collections::BTreeSet<usize>,
    q_words: &[i64],
) -> Blob {
    let ns = stripe.f_lens.len();
    let mut i = Vec::with_capacity(6 + 3 * ns + chunks.len() * (cw + 1));
    i.push(delta::FMT_QDELTA);
    i.push(base_version);
    i.push(cw as i64);
    i.push(total as i64);
    i.push(ns as i64);
    i.extend(stripe.f_lens.iter().map(|&v| v as i64));
    i.extend(stripe.i_lens.iter().map(|&v| v as i64));
    i.extend(stripe.wire_factors.iter().map(|&v| v.to_bits() as i64));
    i.push(chunks.len() as i64);
    for &c in chunks {
        i.push(c as i64);
    }
    for &c in chunks {
        let lo = c * cw;
        let hi = total.min(lo + cw);
        for j in lo..hi {
            i.push(if j < q_words.len() { q_words[j] } else { 0 });
        }
    }
    Blob::from_i64s(i)
}

/// Apply a [`delta::FMT_QDELTA`] forward to the Q holder's base stripe,
/// returning the updated stripe for the new version.
fn apply_qdelta_wire(wire: &Blob, base: &ParityStripe) -> ParityStripe {
    debug_assert_eq!(wire.i[0], delta::FMT_QDELTA);
    let cw = wire.i[2] as usize;
    let total = wire.i[3] as usize;
    let ns = wire.i[4] as usize;
    let off0 = 5;
    let f_lens: Vec<usize> = wire.i[off0..off0 + ns].iter().map(|&v| v as usize).collect();
    let i_lens: Vec<usize> =
        wire.i[off0 + ns..off0 + 2 * ns].iter().map(|&v| v as usize).collect();
    let wire_factors: Vec<f64> = wire.i[off0 + 2 * ns..off0 + 3 * ns]
        .iter()
        .map(|&v| f64::from_bits(v as u64))
        .collect();
    let n_chunks = wire.i[off0 + 3 * ns] as usize;
    let idx0 = off0 + 3 * ns + 1;
    let mut words = base.words.clone();
    if words.len() < total {
        words.resize(total, 0);
    }
    let mut off = idx0 + n_chunks;
    for ci in 0..n_chunks {
        let c = wire.i[idx0 + ci] as usize;
        let lo = c * cw;
        let hi = total.min(lo + cw);
        for j in lo..hi {
            words[j] ^= wire.i[off + (j - lo)];
        }
        off += hi - lo;
    }
    ParityStripe { members: base.members.clone(), f_lens, i_lens, wire_factors, words }
}

/// Whether the objects lost with the currently-dead members of
/// `old_members` can be rebuilt in situ.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum LossCheck {
    /// Every failed rank's state has a live server (buddy or parity group).
    Recoverable,
    /// At least one failed rank's state cannot be rebuilt; the reason names
    /// the rank and the redundancy that died with it.
    Unrecoverable(String),
}

/// Deterministic in-situ recoverability check, evaluated identically by
/// every survivor from the shared liveness registry (the same construction
/// the policy engine and the redistribution planner use).
///
/// `restore_rot` is the `rs2` holder-rotation index of the restore version
/// ([`CkptCfg::rot_index`] of the survivors' agreed
/// `min(committed)`) — it determines *which* two ranks carry the stripes
/// the solve would need; mirror and xor ignore it, so callers on those
/// schemes may pass 0.
///
/// Recoverability is judged **per failed rank's own data**, for every
/// scheme alike: a failed rank that merely held some *other* group's
/// stripe never makes the loss unrecoverable — the orphaned stripe is
/// re-homed by the re-encode of the post-recovery establishment commit
/// (and, under `rs2`, by the next rotation).  Under `rs2` a group's data
/// is recoverable while `dead members + max(0, needed stripes - alive
/// holders) <= 2` erasures can be solved: one dead member needs one alive
/// holder, two dead members need both.
pub fn assess_loss(
    cfg: &CkptCfg,
    old_members: &[WorldRank],
    alive: &dyn Fn(WorldRank) -> bool,
    stride: usize,
    restore_rot: u64,
) -> LossCheck {
    let n = old_members.len();
    let alive_cr = |cr: usize| alive(old_members[cr]);
    if let Scheme::Rs2 { g } = cfg.scheme {
        if cfg.scheme.parity_active(n) {
            for grp in 0..scheme::n_groups(n, g) {
                let (start, len) = scheme::group_span(grp, g, n);
                let dead: Vec<usize> =
                    (start..start + len).filter(|&cr| !alive_cr(cr)).collect();
                if dead.is_empty() {
                    continue;
                }
                let (p, q) = scheme::rs2_holders(grp, g, n, restore_rot);
                let holders_alive = alive_cr(p) as usize + alive_cr(q) as usize;
                let ok = match dead.len() {
                    1 => holders_alive >= 1,
                    2 => holders_alive == 2,
                    _ => false,
                };
                if !ok {
                    let wrs: Vec<usize> = dead.iter().map(|&cr| old_members[cr]).collect();
                    return LossCheck::Unrecoverable(format!(
                        "parity group {grp} lost {} member(s) (world ranks {wrs:?}) with \
                         {holders_alive}/2 stripe holders alive at rotation {restore_rot} — \
                         a {}-erasure solve needs {} stripe(s)",
                        dead.len(),
                        dead.len().min(3),
                        dead.len().min(2),
                    ));
                }
            }
            return LossCheck::Recoverable;
        }
    }
    for (cr, &wr) in old_members.iter().enumerate() {
        if alive(wr) {
            continue;
        }
        if cfg.scheme.server_cr_for(cr, n, &alive_cr, stride).is_none() {
            let why = match cfg.scheme {
                Scheme::Mirror { k } => format!(
                    "rank {wr} (comm rank {cr}) and all {k} of its buddy copies are lost"
                ),
                Scheme::Xor { g } => {
                    let grp = scheme::group_of(cr, g);
                    format!(
                        "rank {wr} (comm rank {cr}) lost with a second failure in \
                         parity group {grp} (or the group's parity holder) before re-encode"
                    )
                }
                // Only reachable below the activation bound (mirror:1
                // degradation) — active rs2 is handled above.
                Scheme::Rs2 { .. } => format!(
                    "rank {wr} (comm rank {cr}) and its degraded mirror:1 buddy are lost"
                ),
            };
            return LossCheck::Unrecoverable(why);
        }
    }
    LossCheck::Recoverable
}

/// Recovery reader: materialize every currently-dead old member's objects
/// at (or below) restore version `v` into the store of the rank that will
/// serve them ([`Scheme::server_cr_for`]), reconstructing from surviving
/// group members plus parity for the xor scheme and running the one- or
/// two-erasure GF(2^8) solve for `rs2` (DESIGN.md §9).  Mirror schemes are
/// a no-op (buddy copies already sit in the store).
///
/// Contract: must be called by every *survivor* of `old_members` (not by
/// adopted spares) with the same arguments, over a repaired communicator
/// `comm` that contains all survivors, after [`assess_loss`] returned
/// [`LossCheck::Recoverable`] for the same liveness snapshot; afterwards
/// the usual `get_remote_at_most` serving paths work unchanged for shrink,
/// substitute and global-restart recovery.
pub async fn reconstruct_failed(
    ctx: &mut Ctx,
    comm: &Comm,
    store: &mut CkptStore,
    cfg: &CkptCfg,
    old_members: &[WorldRank],
    v: Version,
    objs: &[ObjId],
) -> MpiResult<()> {
    // Fault point: a survivor dying as reconstruction starts (nested
    // failure inside recovery).  All writes below are idempotent puts at
    // fixed versions, so an interrupted reconstruction is re-runnable by
    // the next recovery attempt with the enlarged failure set.
    ctx.phase_point(ProtoPhase::Reconstruct)?;
    let n_old = old_members.len();
    if !cfg.scheme.parity_active(n_old) {
        return Ok(());
    }
    if cfg.async_commit {
        // Fault point: the pipelined-reconstruction window (`--inject-phase
        // <rank>:recon-pipeline`).  Async mode gathers reconstruction
        // inputs through the split-phase `recv_all` below, folding blocks
        // in virtual-arrival order as they land instead of in a fixed
        // member order — a death here lands between posting the receives
        // and the folds.  Sync mode never emits this phase point (it would
        // perturb the traced event stream).
        ctx.phase_point(ProtoPhase::ReconPipeline)?;
    }
    match cfg.scheme {
        Scheme::Mirror { .. } => Ok(()),
        Scheme::Xor { g } => {
            reconstruct_xor(ctx, comm, store, cfg, old_members, v, objs, g).await
        }
        Scheme::Rs2 { g } => {
            reconstruct_rs2(ctx, comm, store, cfg, old_members, v, objs, g).await
        }
    }
}

/// Single-erasure xor reconstruction: surviving group members stream their
/// local blobs to the holder, which XORs them with the stripe.
#[allow(clippy::too_many_arguments)]
async fn reconstruct_xor(
    ctx: &mut Ctx,
    comm: &Comm,
    store: &mut CkptStore,
    cfg: &CkptCfg,
    old_members: &[WorldRank],
    v: Version,
    objs: &[ObjId],
    g: usize,
) -> MpiResult<()> {
    let n_old = old_members.len();
    let world = ctx.world.clone();
    let Some(me_old) = old_members.iter().position(|&wr| wr == ctx.rank) else {
        return Ok(());
    };
    let failed: Vec<usize> =
        (0..n_old).filter(|&cr| !world.is_alive(old_members[cr])).collect();
    for &fr in &failed {
        let grp = scheme::group_of(fr, g);
        let (start, len) = scheme::group_span(grp, g, n_old);
        let holder = scheme::holder_cr(grp, g, n_old);
        debug_assert!(
            world.is_alive(old_members[holder]),
            "unrecoverable loss must be escalated before reconstruction"
        );
        if me_old == holder {
            let anchor = old_members[start];
            for &id in objs {
                let (sv, stripe) = {
                    let (sv, s) = store
                        .get_parity_at_most(anchor, id, v)
                        .unwrap_or_else(|| panic!("parity stripe for obj {id} missing"));
                    (sv, s.clone())
                };
                let mut acc = stripe.words.clone();
                if cfg.async_commit {
                    // Pipelined gather: post every surviving member's
                    // receive at once and fold blocks in virtual-arrival
                    // order.  XOR is commutative and associative, so the
                    // accumulated words are bit-identical to the fixed
                    // member-order fold of the sync path.
                    let posts: Vec<(usize, Tag)> = (start..start + len)
                        .filter(|&cr| cr != fr)
                        .map(|cr| {
                            let src = comm
                                .rank_of_world(old_members[cr])
                                .expect("surviving group member must be in the repaired comm");
                            (src, recon_tag(id, fr))
                        })
                        .collect();
                    for (_, _, recvd) in comm.recv_all(ctx, &posts).await? {
                        let blob =
                            if cfg.compress { delta::decompress_blob(&recvd) } else { recvd };
                        delta::xor_into(&mut acc, &delta::pack_words(&blob));
                        ctx.advance(
                            (8 * (blob.f.len() + blob.i.len())) as f64
                                / cfg.encode_bytes_per_sec,
                        );
                    }
                } else {
                    for cr in start..start + len {
                        if cr == fr {
                            continue;
                        }
                        let src = comm
                            .rank_of_world(old_members[cr])
                            .expect("surviving group member must be in the repaired comm");
                        let recvd = comm.recv(ctx, src, recon_tag(id, fr)).await?;
                        let blob =
                            if cfg.compress { delta::decompress_blob(&recvd) } else { recvd };
                        delta::xor_into(&mut acc, &delta::pack_words(&blob));
                        ctx.advance(
                            (8 * (blob.f.len() + blob.i.len())) as f64
                                / cfg.encode_bytes_per_sec,
                        );
                    }
                }
                let slot = fr - start;
                let mut out =
                    delta::unpack_words(&acc, stripe.f_lens[slot], stripe.i_lens[slot]);
                let factor = stripe.wire_factors[slot];
                if factor != 1.0 {
                    out = out.scaled(factor);
                }
                store.put_remote(old_members[fr], id, sv, out);
            }
        } else if scheme::group_of(me_old, g) == grp && me_old != fr {
            let dst = comm
                .rank_of_world(old_members[holder])
                .expect("parity holder must be in the repaired comm");
            for &id in objs {
                let blob = store
                    .get_local_at_most(id, v)
                    .unwrap_or_else(|| panic!("local checkpoint for obj {id} missing"))
                    .1
                    .clone();
                let blob = if cfg.compress {
                    delta::compress_blob_in(&mut ctx.arena, &blob)
                } else {
                    blob
                };
                comm.send(ctx, dst, recon_tag(id, fr), blob)?;
            }
        }
    }
    Ok(())
}

/// Stripe transfer wire (holder -> rs2 reconstruction leader); same layout
/// as the Q forward via [`encode_stripe`], under [`delta::FMT_STRIPE`].
fn stripe_wire(sv: Version, stripe: &ParityStripe) -> Blob {
    encode_stripe(delta::FMT_STRIPE, sv, stripe, &stripe.words)
}

fn parse_stripe_wire(wire: &Blob, members: &[WorldRank]) -> (Version, ParityStripe) {
    decode_stripe(delta::FMT_STRIPE, wire, members)
}

/// Double-parity rs2 reconstruction (DESIGN.md §9).  Per parity group with
/// failures, the *reconstruction leader* ([`Scheme::server_cr_for`] — the
/// first alive rank scanning the ring from the group base) gathers the
/// surviving members' blobs plus the needed stripe(s) from the rotation's
/// holders, runs the one- or two-erasure solve, and materializes every
/// failed member's objects in its own store for the ordinary serving
/// paths.
#[allow(clippy::too_many_arguments)]
async fn reconstruct_rs2(
    ctx: &mut Ctx,
    comm: &Comm,
    store: &mut CkptStore,
    cfg: &CkptCfg,
    old_members: &[WorldRank],
    v: Version,
    objs: &[ObjId],
    g: usize,
) -> MpiResult<()> {
    let n_old = old_members.len();
    let world = ctx.world.clone();
    let Some(me_old) = old_members.iter().position(|&wr| wr == ctx.rank) else {
        return Ok(());
    };
    let alive_cr = |cr: usize| world.is_alive(old_members[cr]);
    let rot = cfg.rot_index(v);
    // Failed ranks, grouped by parity group in ascending group order.
    let mut by_grp: Vec<(usize, Vec<usize>)> = Vec::new();
    for cr in 0..n_old {
        if alive_cr(cr) {
            continue;
        }
        let grp = scheme::group_of(cr, g);
        match by_grp.iter_mut().find(|(gg, _)| *gg == grp) {
            Some((_, frs)) => frs.push(cr),
            None => by_grp.push((grp, vec![cr])),
        }
    }
    by_grp.sort_by_key(|(gg, _)| *gg);
    for (grp, frs) in by_grp {
        let (start, len) = scheme::group_span(grp, g, n_old);
        let anchor = old_members[start];
        let (p_cr, q_cr) = scheme::rs2_holders(grp, g, n_old, rot);
        debug_assert!(frs.len() <= 2, "unrecoverable loss must be escalated first");
        let need_p = alive_cr(p_cr);
        let need_q = frs.len() == 2 || !need_p;
        debug_assert!(
            (!need_q || alive_cr(q_cr)) && (need_p || alive_cr(q_cr)),
            "assess_loss admits enough alive holders"
        );
        let leader = cfg
            .scheme
            .server_cr_for(frs[0], n_old, &alive_cr, 1)
            .expect("assess_loss admits a live reconstruction leader");
        let survivors: Vec<usize> =
            (start..start + len).filter(|&cr| alive_cr(cr)).collect();
        if me_old == leader {
            for &id in objs {
                // Gather the needed stripes (local when the leader is a
                // holder itself, e.g. when a whole group died).
                let p_stripe = if need_p {
                    Some(
                        gather_stripe(
                            ctx, comm, store, cfg, old_members, me_old, p_cr, anchor, id, v,
                            grp, 0,
                        )
                        .await?,
                    )
                } else {
                    None
                };
                let q_stripe = if need_q {
                    Some(
                        gather_stripe(
                            ctx, comm, store, cfg, old_members, me_old, q_cr, anchor, id, v,
                            grp, 1,
                        )
                        .await?,
                    )
                } else {
                    None
                };
                // Gather surviving members' blobs (slot, packed words).
                let mut contributions: Vec<(usize, Vec<i64>)> =
                    Vec::with_capacity(survivors.len());
                if cfg.async_commit {
                    // Pipelined gather: the leader's own (locally
                    // available) contribution folds first while the remote
                    // blobs are still in flight, then the rest land in
                    // virtual-arrival order.  The downstream XOR/GF(2^8)
                    // folds carry the slot with each contribution and are
                    // commutative, so the solve is order-invariant.
                    if let Some(&cr) = survivors.iter().find(|&&cr| cr == me_old) {
                        let blob = store
                            .get_local_at_most(id, v)
                            .unwrap_or_else(|| panic!("local checkpoint for obj {id} missing"))
                            .1;
                        let words = delta::pack_words(blob);
                        ctx.advance((8 * words.len()) as f64 / cfg.encode_bytes_per_sec);
                        contributions.push((cr - start, words));
                    }
                    let remote: Vec<usize> =
                        survivors.iter().copied().filter(|&cr| cr != me_old).collect();
                    let posts: Vec<(usize, Tag)> = remote
                        .iter()
                        .map(|&cr| {
                            let src = comm
                                .rank_of_world(old_members[cr])
                                .expect("surviving member must be in the repaired comm");
                            (src, recon_member_tag(id, grp))
                        })
                        .collect();
                    for (src, _, recvd) in comm.recv_all(ctx, &posts).await? {
                        let cr = *remote
                            .iter()
                            .find(|&&cr| comm.rank_of_world(old_members[cr]) == Some(src))
                            .expect("recv_all returns only posted sources");
                        let blob =
                            if cfg.compress { delta::decompress_blob(&recvd) } else { recvd };
                        let words = delta::pack_words(&blob);
                        ctx.advance((8 * words.len()) as f64 / cfg.encode_bytes_per_sec);
                        contributions.push((cr - start, words));
                    }
                } else {
                    for &cr in &survivors {
                        let words = if cr == me_old {
                            let blob = store
                                .get_local_at_most(id, v)
                                .unwrap_or_else(|| {
                                    panic!("local checkpoint for obj {id} missing")
                                })
                                .1;
                            delta::pack_words(blob)
                        } else {
                            let src = comm
                                .rank_of_world(old_members[cr])
                                .expect("surviving member must be in the repaired comm");
                            let recvd = comm.recv(ctx, src, recon_member_tag(id, grp)).await?;
                            let blob = if cfg.compress {
                                delta::decompress_blob(&recvd)
                            } else {
                                recvd
                            };
                            delta::pack_words(&blob)
                        };
                        ctx.advance((8 * words.len()) as f64 / cfg.encode_bytes_per_sec);
                        contributions.push((cr - start, words));
                    }
                }
                // Solve and materialize each failed member.
                let (sv, meta) = p_stripe
                    .as_ref()
                    .or(q_stripe.as_ref())
                    .map(|(sv, s)| (*sv, s.clone()))
                    .expect("at least one stripe is required");
                if let (Some((svq, _)), Some((svp, _))) =
                    (q_stripe.as_ref(), p_stripe.as_ref())
                {
                    debug_assert_eq!(svp, svq, "stripe versions diverged across holders");
                }
                let failed_slots: Vec<usize> = frs.iter().map(|&fr| fr - start).collect();
                let solved: Vec<Vec<i64>> = match (&p_stripe, &q_stripe) {
                    (Some((_, p)), None) => {
                        let mut acc = p.words.clone();
                        for (_, words) in &contributions {
                            delta::xor_into(&mut acc, words);
                        }
                        vec![acc]
                    }
                    (None, Some((_, q))) => {
                        let mut acc = q.words.clone();
                        for (slot, words) in &contributions {
                            gf256::mul_xor_into(&mut acc, words, gf256::coef(*slot));
                        }
                        gf256::div_words(&mut acc, gf256::coef(failed_slots[0]));
                        vec![acc]
                    }
                    (Some((_, p)), Some((_, q))) => {
                        let mut pw = p.words.clone();
                        let mut qw = q.words.clone();
                        for (slot, words) in &contributions {
                            delta::xor_into(&mut pw, words);
                            gf256::mul_xor_into(&mut qw, words, gf256::coef(*slot));
                        }
                        let (wi, wj) = gf256::solve_two_erasures(
                            &pw,
                            &qw,
                            gf256::coef(failed_slots[0]),
                            gf256::coef(failed_slots[1]),
                        );
                        vec![wi, wj]
                    }
                    (None, None) => unreachable!("need_p || need_q always holds"),
                };
                ctx.advance(
                    (8 * solved.iter().map(Vec::len).sum::<usize>()) as f64
                        / cfg.encode_bytes_per_sec,
                );
                for (k, words) in solved.iter().enumerate() {
                    let slot = failed_slots[k];
                    let mut out =
                        delta::unpack_words(words, meta.f_lens[slot], meta.i_lens[slot]);
                    let factor = meta.wire_factors[slot];
                    if factor != 1.0 {
                        out = out.scaled(factor);
                    }
                    store.put_remote(old_members[frs[k]], id, sv, out);
                }
            }
        } else {
            // Surviving member: stream local blobs to the leader.
            if scheme::group_of(me_old, g) == grp {
                let dst = comm
                    .rank_of_world(old_members[leader])
                    .expect("leader must be in the repaired comm");
                for &id in objs {
                    let blob = store
                        .get_local_at_most(id, v)
                        .unwrap_or_else(|| panic!("local checkpoint for obj {id} missing"))
                        .1
                        .clone();
                    let blob = if cfg.compress {
                        delta::compress_blob_in(&mut ctx.arena, &blob)
                    } else {
                        blob
                    };
                    comm.send(ctx, dst, recon_member_tag(id, grp), blob)?;
                }
            }
            // Holder of a needed stripe: ship it to the leader.
            for (holder, which, needed) in [(p_cr, 0usize, need_p), (q_cr, 1usize, need_q)] {
                if !needed || me_old != holder {
                    continue;
                }
                let dst = comm
                    .rank_of_world(old_members[leader])
                    .expect("leader must be in the repaired comm");
                for &id in objs {
                    let wire = {
                        let (sv, stripe) = store
                            .get_parity_at_most(anchor, id, v)
                            .unwrap_or_else(|| panic!("stripe for obj {id} missing on holder"));
                        stripe_wire(sv, stripe)
                    };
                    let wire = if cfg.compress {
                        delta::compress_wire_in(&mut ctx.arena, &wire)
                    } else {
                        wire
                    };
                    comm.send(ctx, dst, recon_stripe_tag(id, grp, which), wire)?;
                }
            }
        }
    }
    Ok(())
}

/// Leader-side stripe acquisition: local when the leader is the holder,
/// otherwise received from the holder over the repaired communicator.
#[allow(clippy::too_many_arguments)]
async fn gather_stripe(
    ctx: &mut Ctx,
    comm: &Comm,
    store: &CkptStore,
    cfg: &CkptCfg,
    old_members: &[WorldRank],
    me_old: usize,
    holder_cr: usize,
    anchor: WorldRank,
    id: ObjId,
    v: Version,
    grp: usize,
    which: usize,
) -> MpiResult<(Version, ParityStripe)> {
    if holder_cr == me_old {
        let (sv, s) = store
            .get_parity_at_most(anchor, id, v)
            .unwrap_or_else(|| panic!("stripe for obj {id} missing on leader-holder"));
        return Ok((sv, s.clone()));
    }
    let src = comm
        .rank_of_world(old_members[holder_cr])
        .expect("stripe holder must be in the repaired comm");
    let recvd = comm.recv(ctx, src, recon_stripe_tag(id, grp, which)).await?;
    let wire = if cfg.compress { delta::decompress_wire(&recvd) } else { recvd };
    ctx.advance((8 * wire.i.len()) as f64 / cfg.encode_bytes_per_sec);
    let (start, len) = scheme::group_span(grp, cfg_group(cfg), old_members.len());
    let members: Vec<WorldRank> = old_members[start..start + len].to_vec();
    Ok(parse_stripe_wire(&wire, &members))
}

/// Group size of the configured parity scheme (callers guarantee a parity
/// scheme is active).
fn cfg_group(cfg: &CkptCfg) -> usize {
    match cfg.scheme {
        Scheme::Xor { g } | Scheme::Rs2 { g } => g,
        Scheme::Mirror { .. } => unreachable!("parity group size on a mirror scheme"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cfg_surface() {
        let cfg = CkptCfg::default();
        assert_eq!(cfg.scheme, Scheme::Mirror { k: 1 });
        assert!(!cfg.delta);
        assert!(!cfg.compress);
        assert_eq!(cfg.chunk_words(), 512);
        let m2 = CkptCfg::mirror(2);
        assert_eq!(m2.scheme, Scheme::Mirror { k: 2 });
    }

    #[test]
    fn rotation_advances_per_rebase_epoch() {
        let cfg = CkptCfg {
            scheme: Scheme::Rs2 { g: 4 },
            delta: true,
            rebase_every: 4,
            ..CkptCfg::default()
        };
        assert_eq!(cfg.rot_index(0), 0);
        assert_eq!(cfg.rot_index(3), 0);
        assert_eq!(cfg.rot_index(4), 1);
        assert_eq!(cfg.rot_index(11), 2);
        // Delta commits never straddle a rotation boundary: any version with
        // use_delta shares its epoch with version - 1.
        for v in 1..64 {
            if cfg.use_delta(v, false) {
                assert_eq!(cfg.rot_index(v), cfg.rot_index(v - 1), "v={v}");
            }
        }
        // Statics re-encode exactly at the epoch boundaries (rs2 only).
        assert!(cfg.static_reencode_due(0));
        assert!(cfg.static_reencode_due(8));
        assert!(!cfg.static_reencode_due(5));
        let xor = CkptCfg { scheme: Scheme::Xor { g: 4 }, ..CkptCfg::default() };
        assert!(!xor.static_reencode_due(8));
    }

    #[test]
    fn delta_rebase_schedule() {
        let mut cfg = CkptCfg { delta: true, rebase_every: 4, ..CkptCfg::default() };
        // Fresh commits always rebase.
        assert!(!cfg.use_delta(5, true));
        // Multiples of rebase_every rebase.
        assert!(!cfg.use_delta(8, false));
        assert!(cfg.use_delta(5, false));
        assert!(cfg.use_delta(7, false));
        // Delta off: never.
        cfg.delta = false;
        assert!(!cfg.use_delta(5, false));
    }

    #[test]
    fn assess_loss_mirror_and_xor() {
        let members: Vec<usize> = (0..8).collect();
        let m1 = CkptCfg::mirror(1);
        let dead_pair = |a: usize, b: usize| move |wr: usize| wr != a && wr != b;
        // Adjacent pair under mirror:1 loses rank 2's only copy (on 3).
        assert!(matches!(
            assess_loss(&m1, &members, &dead_pair(2, 3), 1, 0),
            LossCheck::Unrecoverable(_)
        ));
        // Non-adjacent pair is fine.
        assert_eq!(assess_loss(&m1, &members, &dead_pair(2, 5), 1, 0), LossCheck::Recoverable);
        let x4 = CkptCfg { scheme: Scheme::Xor { g: 4 }, ..CkptCfg::default() };
        // Two losses in group 0: unrecoverable.
        match assess_loss(&x4, &members, &dead_pair(1, 2), 1, 0) {
            LossCheck::Unrecoverable(why) => assert!(why.contains("parity group 0"), "{why}"),
            other => panic!("expected unrecoverable, got {other:?}"),
        }
        // One loss per group: recoverable.
        assert_eq!(assess_loss(&x4, &members, &dead_pair(1, 5), 1, 0), LossCheck::Recoverable);
        // Member + its group's holder (rank 4 holds group 0): unrecoverable.
        assert!(matches!(
            assess_loss(&x4, &members, &dead_pair(1, 4), 1, 0),
            LossCheck::Unrecoverable(_)
        ));
        // Holder-loss is scheme-generic: a dead rank that merely holds
        // ANOTHER group's stripe (rank 0 holds group 1's parity) is
        // recoverable — its own data is covered by its own group, and the
        // orphaned stripe is re-homed by the next re-encode.
        let dead_one = |a: usize| move |wr: usize| wr != a;
        assert_eq!(assess_loss(&x4, &members, &dead_one(0), 1, 0), LossCheck::Recoverable);
        assert_eq!(assess_loss(&x4, &members, &dead_one(4), 1, 0), LossCheck::Recoverable);
    }

    #[test]
    fn assess_loss_rs2_double_faults() {
        let members: Vec<usize> = (0..8).collect();
        let rs2 = CkptCfg { scheme: Scheme::Rs2 { g: 4 }, ..CkptCfg::default() };
        let dead = |dead: Vec<usize>| move |wr: usize| !dead.contains(&wr);
        // At rotation 0, group 0 = {0..3} has holders (4, 5).
        // member + member: solvable while both holders live.
        assert_eq!(
            assess_loss(&rs2, &members, &dead(vec![1, 2]), 1, 0),
            LossCheck::Recoverable
        );
        // member + one holder: the surviving stripe covers it.
        assert_eq!(
            assess_loss(&rs2, &members, &dead(vec![1, 4]), 1, 0),
            LossCheck::Recoverable
        );
        // both holders only: no group data lost at all.
        assert_eq!(
            assess_loss(&rs2, &members, &dead(vec![4, 5]), 1, 0),
            LossCheck::Recoverable
        );
        // two members + a holder: three erasures, escalate.
        assert!(matches!(
            assess_loss(&rs2, &members, &dead(vec![1, 2, 4]), 1, 0),
            LossCheck::Unrecoverable(_)
        ));
        // three members of one group: escalate.
        match assess_loss(&rs2, &members, &dead(vec![0, 1, 2]), 1, 0) {
            LossCheck::Unrecoverable(why) => assert!(why.contains("parity group 0"), "{why}"),
            other => panic!("expected unrecoverable, got {other:?}"),
        }
        // Rotation matters: at rotation 1 group 0's holders are (5, 6), so
        // losing {1, 4} is member + unrelated rank — still recoverable —
        // while losing {1, 5, 6} kills both stripes plus a member.
        assert_eq!(
            assess_loss(&rs2, &members, &dead(vec![1, 4]), 1, 1),
            LossCheck::Recoverable
        );
        assert!(matches!(
            assess_loss(&rs2, &members, &dead(vec![1, 5, 6]), 1, 1),
            LossCheck::Unrecoverable(_)
        ));
        // A dead rank that merely *holds* another group's stripes is not an
        // escalation for any scheme: {4} alone (group 1 member, group 0
        // holder) is recoverable — group 1 solves it via its own stripes.
        assert_eq!(assess_loss(&rs2, &members, &dead(vec![4]), 1, 0), LossCheck::Recoverable);
        // Degraded below the activation bound: mirror:1 semantics.
        let small: Vec<usize> = (0..5).collect();
        assert!(matches!(
            assess_loss(&rs2, &small, &dead(vec![2, 3]), 1, 0),
            LossCheck::Unrecoverable(_)
        ));
        assert_eq!(assess_loss(&rs2, &small, &dead(vec![2]), 1, 0), LossCheck::Recoverable);
    }

    #[test]
    fn tag_namespaces_stay_in_their_windows() {
        // Mirror ship tags stay below the parity window.
        assert!(ship_tag(crate::checkpoint::obj::BASIS, 15) < parity_tag(0));
        // Parity tags stay inside the checkpoint window, below Q forwards.
        assert!(parity_tag(crate::checkpoint::obj::BASIS) < qpar_tag(0, 0));
        assert!(qpar_tag(crate::checkpoint::obj::BASIS, 255) < tags::HALO_BASE);
        // Reconstruction tags stay inside the recovery window.
        assert!(recon_tag(crate::checkpoint::obj::BASIS, 4095) < recon_member_tag(0, 0));
        assert!(recon_member_tag(crate::checkpoint::obj::BASIS, 255) < recon_stripe_tag(0, 0, 0));
        assert!(recon_stripe_tag(crate::checkpoint::obj::BASIS, 255, 1) < tags::CKPT_BASE);
        assert!(recon_tag(0, 0) >= tags::RECON_BASE);
        // Scrub repair traffic sits above the Q forwards, below the halo
        // window.
        assert!(qpar_tag(crate::checkpoint::obj::BASIS, 1023) < scrub_tag(0, 0));
        assert!(scrub_tag(0, 0) >= tags::SCRUB_BASE);
        assert!(scrub_tag(crate::checkpoint::obj::BASIS, 65_535) < tags::HALO_BASE);
    }

    #[test]
    fn chunk_sums_flag_exactly_the_corrupt_chunk() {
        let blob = Blob::new(
            (0..1000).map(|k| k as f64).collect(),
            (0..500).map(|k| k as i64).collect(),
        );
        let cw = CkptCfg::default().chunk_words();
        let clean = chunk_sums(&blob, cw);
        assert_eq!(clean.len(), 3, "1500 words over 512-word chunks");
        for bit in [0usize, 7, 63, 512 * 64, 520 * 64 + 5, 1499 * 64 + 63] {
            let mut words = delta::pack_words(&blob);
            words[bit / 64] ^= 1i64 << (bit % 64);
            let corrupt = delta::unpack_words(&words, 1000, 500);
            let sums = chunk_sums(&corrupt, cw);
            for (ci, (a, b)) in clean.iter().zip(&sums).enumerate() {
                if ci == bit / 64 / cw {
                    assert_ne!(a, b, "bit {bit} must flag chunk {ci}");
                } else {
                    assert_eq!(a, b, "bit {bit} must not flag chunk {ci}");
                }
            }
        }
        // The digest covers both lanes and is chunking-stable.
        assert_eq!(chunk_sums(&blob, cw), clean);
    }

    #[test]
    fn scrub_schedule_groups_damage_per_parity_group() {
        let entries = vec![(5usize, 1u32, 7i64), (1, 1, 7), (2, 1, 7), (2, 4, 7)];
        let groups = scrub_groups(&entries, 4);
        assert_eq!(
            groups,
            vec![(0, 1, 7, vec![1, 2]), (0, 4, 7, vec![2]), (1, 1, 7, vec![5])]
        );
    }

    #[test]
    fn q_wire_roundtrips() {
        let stripe = ParityStripe {
            members: vec![10, 11, 12],
            f_lens: vec![4, 5, 6],
            i_lens: vec![1, 0, 2],
            wire_factors: vec![1.0, 36.0, 1.0],
            words: vec![0; 8],
        };
        let q_words: Vec<i64> = (0..8).map(|k| 100 + k).collect();
        let (v2, full) = parse_qfull_wire(&qfull_wire(7, &stripe, &q_words), &stripe.members);
        assert_eq!(v2, 7);
        assert_eq!(full.words, q_words);
        assert_eq!(full.f_lens, stripe.f_lens);
        assert_eq!(full.i_lens, stripe.i_lens);
        assert_eq!(full.wire_factors, stripe.wire_factors);
        // Delta forward: chunks {0, 2} of a 3-word-chunk stream over 8 words.
        let mut chunks = std::collections::BTreeSet::new();
        chunks.insert(0usize);
        chunks.insert(2usize);
        let dq = qdelta_wire(6, 3, 8, &stripe, &chunks, &q_words);
        let base = ParityStripe { words: vec![1; 8], ..stripe.clone() };
        let out = apply_qdelta_wire(&dq, &base);
        // Chunk 0 = words 0..3, chunk 2 = words 6..8 (clipped): XORed in.
        assert_eq!(out.words[0], 1 ^ 100);
        assert_eq!(out.words[2], 1 ^ 102);
        assert_eq!(out.words[3], 1, "untouched chunk survives");
        assert_eq!(out.words[6], 1 ^ 106);
        assert_eq!(out.words[7], 1 ^ 107);
        assert_eq!(out.f_lens, stripe.f_lens);
        // Stripe transfer wire roundtrips too.
        let (sv, back) = parse_stripe_wire(&stripe_wire(9, &stripe), &stripe.members);
        assert_eq!(sv, 9);
        assert_eq!(back.words, stripe.words);
        assert_eq!(back.wire_factors, stripe.wire_factors);
    }
}
