//! Redundancy schemes for the in-memory checkpoint store (DESIGN.md §8).
//!
//! Two pluggable schemes decide *where* the redundant bits of every
//! checkpointed object live:
//!
//! * [`Scheme::Mirror`] — the paper's buddy replication: each rank ships a
//!   full copy of every object to `k` ring successors.  Redundant memory
//!   and wire volume are `k x state` per rank.
//! * [`Scheme::Xor`] — parity groups: the communicator is partitioned into
//!   groups of `g` consecutive comm ranks; one XOR parity stripe per group
//!   per object lives on the *parity holder* (the base rank of the next
//!   group on the group ring, so the stripe never shares fate with its own
//!   group).  Redundant memory is `state / g` per rank amortized, at the
//!   cost of tolerating only one failure per group between re-encodes —
//!   two failures in one group (or a member plus its group's holder) are an
//!   *unrecoverable* loss that escalates to global restart (see
//!   [`crate::ckptstore::assess_loss`]).
//!
//! Group layout is a pure function of the communicator size, so every rank
//! derives identical groups with no negotiation — the same construction the
//! redistribution planner and the policy engine rely on.

use crate::checkpoint::buddy_of_stride;

/// Which redundancy scheme the checkpoint store uses (config key
/// `ckpt_scheme`, CLI `--ckpt-scheme`; values `mirror:<k>` / `xor:<g>`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// Full buddy copies to `k` ring successors (the paper's layout).
    Mirror {
        /// Buddy copies per object.
        k: usize,
    },
    /// One XOR parity stripe per group of `g` consecutive comm ranks.
    Xor {
        /// Parity-group size.
        g: usize,
    },
}

impl Default for Scheme {
    fn default() -> Self {
        Scheme::Mirror { k: 1 }
    }
}

impl Scheme {
    /// Parse `mirror`, `mirror:<k>`, `xor`, `xor:<g>`.
    pub fn parse(s: &str) -> Option<Scheme> {
        let s = s.trim();
        if let Some(rest) = s.strip_prefix("mirror") {
            let k = match rest.strip_prefix(':') {
                Some(n) => n.trim().parse().ok()?,
                None if rest.is_empty() => 1,
                None => return None,
            };
            if k == 0 {
                return None;
            }
            return Some(Scheme::Mirror { k });
        }
        if let Some(rest) = s.strip_prefix("xor") {
            let g = match rest.strip_prefix(':') {
                Some(n) => n.trim().parse().ok()?,
                None if rest.is_empty() => 4,
                None => return None,
            };
            if g < 2 {
                return None;
            }
            return Some(Scheme::Xor { g });
        }
        None
    }

    pub fn name(&self) -> String {
        match self {
            Scheme::Mirror { k } => format!("mirror:{k}"),
            Scheme::Xor { g } => format!("xor:{g}"),
        }
    }

    /// Buddy count for mirror semantics (estimate inputs; 1 for xor, whose
    /// re-encode ships one parity contribution instead of full copies).
    pub fn mirror_k(&self) -> usize {
        match self {
            Scheme::Mirror { k } => *k,
            Scheme::Xor { .. } => 1,
        }
    }

    /// Whether the xor encoding is actually usable at communicator size
    /// `n`: a single group cannot place its parity outside itself, so runs
    /// (or shrunken survivor sets) with `n <= g` degrade to `mirror:1`.
    pub fn xor_active(&self, n: usize) -> bool {
        matches!(self, Scheme::Xor { g } if n > *g)
    }

    /// The comm rank that, if `owner_cr` fails, serves its checkpointed
    /// objects to the recovery reader — or `None` when the loss is
    /// unrecoverable in situ.
    ///
    /// * mirror: the first *alive* buddy on the ring (every buddy holds a
    ///   full copy);
    /// * xor (active): the owner's parity holder, feasible only while the
    ///   holder *and* every other member of the owner's group are alive;
    /// * xor at `n <= g`: the degraded `mirror:1` buddy.
    ///
    /// Every rank (survivors and adopted spares alike) evaluates this from
    /// the shared liveness registry, so server choice needs no negotiation.
    pub fn server_cr_for(
        &self,
        owner_cr: usize,
        n: usize,
        alive_cr: &dyn Fn(usize) -> bool,
        stride: usize,
    ) -> Option<usize> {
        match self {
            Scheme::Mirror { k } => (1..=(*k).min(n.saturating_sub(1)))
                .map(|d| buddy_of_stride(owner_cr, d, n, stride))
                .find(|&cr| alive_cr(cr)),
            Scheme::Xor { g } => {
                if !self.xor_active(n) {
                    return (1..n.min(2))
                        .map(|d| buddy_of_stride(owner_cr, d, n, stride))
                        .find(|&cr| alive_cr(cr));
                }
                let grp = group_of(owner_cr, *g);
                let holder = holder_cr(grp, *g, n);
                if !alive_cr(holder) {
                    return None;
                }
                let (start, len) = group_span(grp, *g, n);
                for cr in start..start + len {
                    if cr != owner_cr && !alive_cr(cr) {
                        return None;
                    }
                }
                Some(holder)
            }
        }
    }
}

/// Parity group of comm rank `cr` for group size `g`.
pub fn group_of(cr: usize, g: usize) -> usize {
    cr / g
}

/// Number of parity groups in a communicator of `n`.
pub fn n_groups(n: usize, g: usize) -> usize {
    n.div_ceil(g)
}

/// `(start comm rank, member count)` of group `grp` (the last group may be
/// short when `g` does not divide `n`).
pub fn group_span(grp: usize, g: usize, n: usize) -> (usize, usize) {
    let start = grp * g;
    (start, g.min(n - start))
}

/// Parity holder of group `grp`: the base rank of the next group on the
/// group ring.  For any `n > g` this rank is outside `grp` itself, so a
/// whole-group stripe never shares fate with the data it protects.
pub fn holder_cr(grp: usize, g: usize, n: usize) -> usize {
    ((grp + 1) * g) % n
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_surface() {
        assert_eq!(Scheme::parse("mirror:2"), Some(Scheme::Mirror { k: 2 }));
        assert_eq!(Scheme::parse("mirror"), Some(Scheme::Mirror { k: 1 }));
        assert_eq!(Scheme::parse("xor:4"), Some(Scheme::Xor { g: 4 }));
        assert_eq!(Scheme::parse("xor"), Some(Scheme::Xor { g: 4 }));
        assert_eq!(Scheme::parse("xor:1"), None);
        assert_eq!(Scheme::parse("mirror:0"), None);
        assert_eq!(Scheme::parse("raid6"), None);
        assert_eq!(Scheme::Xor { g: 4 }.name(), "xor:4");
        assert_eq!(Scheme::Mirror { k: 1 }.name(), "mirror:1");
    }

    #[test]
    fn holder_is_always_outside_its_group() {
        for n in [5usize, 6, 8, 10, 12, 16, 48] {
            for g in [2usize, 3, 4] {
                if n <= g {
                    continue;
                }
                for grp in 0..n_groups(n, g) {
                    let h = holder_cr(grp, g, n);
                    let (start, len) = group_span(grp, g, n);
                    assert!(
                        h < start || h >= start + len,
                        "holder {h} inside group {grp} (n={n}, g={g})"
                    );
                }
            }
        }
    }

    #[test]
    fn holders_are_distinct_per_group() {
        for n in [6usize, 8, 10, 12, 16, 48] {
            for g in [2usize, 4] {
                if n <= g {
                    continue;
                }
                let mut holders: Vec<usize> =
                    (0..n_groups(n, g)).map(|grp| holder_cr(grp, g, n)).collect();
                holders.sort_unstable();
                holders.dedup();
                assert_eq!(holders.len(), n_groups(n, g), "n={n} g={g}");
            }
        }
    }

    #[test]
    fn mirror_server_is_first_alive_buddy() {
        let s = Scheme::Mirror { k: 2 };
        let alive = |cr: usize| cr != 3 && cr != 4;
        // Owner 3 dead: buddy 4 also dead, buddy 5 serves.
        assert_eq!(s.server_cr_for(3, 8, &alive, 1), Some(5));
        // k=1 with the only buddy dead: unrecoverable.
        let s1 = Scheme::Mirror { k: 1 };
        assert_eq!(s1.server_cr_for(3, 8, &alive, 1), None);
    }

    #[test]
    fn xor_server_is_parity_holder_when_group_intact() {
        let s = Scheme::Xor { g: 4 };
        // n=8: groups {0..3} and {4..7}; holders 4 and 0.
        let alive = |cr: usize| cr != 1;
        assert_eq!(s.server_cr_for(1, 8, &alive, 1), Some(4));
        let alive2 = |cr: usize| cr != 5;
        assert_eq!(s.server_cr_for(5, 8, &alive2, 1), Some(0));
    }

    #[test]
    fn xor_two_losses_in_one_group_are_unrecoverable() {
        let s = Scheme::Xor { g: 4 };
        let alive = |cr: usize| cr != 1 && cr != 2;
        assert_eq!(s.server_cr_for(1, 8, &alive, 1), None);
        assert_eq!(s.server_cr_for(2, 8, &alive, 1), None);
        // One loss per group stays recoverable.
        let alive2 = |cr: usize| cr != 1 && cr != 5;
        assert_eq!(s.server_cr_for(1, 8, &alive2, 1), Some(4));
        assert_eq!(s.server_cr_for(5, 8, &alive2, 1), Some(0));
    }

    #[test]
    fn xor_dead_holder_is_unrecoverable() {
        let s = Scheme::Xor { g: 4 };
        // Member 1 (group 0) and holder 4 (group 0's stripe) both dead.
        let alive = |cr: usize| cr != 1 && cr != 4;
        assert_eq!(s.server_cr_for(1, 8, &alive, 1), None);
    }

    #[test]
    fn xor_degrades_to_mirror_when_group_covers_comm() {
        let s = Scheme::Xor { g: 4 };
        assert!(!s.xor_active(4));
        assert!(!s.xor_active(3));
        assert!(s.xor_active(5));
        let alive = |cr: usize| cr != 2;
        // n=3 <= g: mirror:1 fallback, buddy 0 serves owner 2.
        assert_eq!(s.server_cr_for(2, 3, &alive, 1), Some(0));
    }
}
