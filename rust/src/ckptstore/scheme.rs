//! Redundancy schemes for the in-memory checkpoint store (DESIGN.md §8–§9).
//!
//! Three pluggable schemes decide *where* the redundant bits of every
//! checkpointed object live:
//!
//! * [`Scheme::Mirror`] — the paper's buddy replication: each rank ships a
//!   full copy of every object to `k` ring successors.  Redundant memory
//!   and wire volume are `k x state` per rank.
//! * [`Scheme::Xor`] — parity groups: the communicator is partitioned into
//!   groups of `g` consecutive comm ranks; one XOR parity stripe per group
//!   per object lives on the *parity holder* (the base rank of the next
//!   group on the group ring, so the stripe never shares fate with its own
//!   group).  Redundant memory is `state / g` per rank amortized, at the
//!   cost of tolerating only one failure per group between re-encodes —
//!   two failures in one group (or a member plus its group's holder) are an
//!   *unrecoverable* loss that escalates to global restart (see
//!   [`crate::ckptstore::assess_loss`]).
//! * [`Scheme::Rs2`] — RAID-6-style double parity (DESIGN.md §9): each
//!   group keeps *two* independent stripes — the XOR stripe `P` plus a
//!   GF(2^8)-weighted stripe `Q` ([`crate::ckptstore::gf256`]) — on two
//!   distinct holders outside the group, chosen per rebase epoch by the
//!   rotation schedule of [`rs2_holders`].  Any two in-group losses
//!   (member+member, member+holder, or both holders) reconstruct in situ;
//!   only a third concurrent loss in one group escalates.
//!
//! Group layout is a pure function of the communicator size (plus, for
//! `rs2`, the rotation index derived from the restore version), so every
//! rank derives identical groups and holders with no negotiation — the
//! same construction the redistribution planner and the policy engine rely
//! on.

use crate::checkpoint::buddy_of_stride;

/// Which redundancy scheme the checkpoint store uses (config key
/// `ckpt_scheme`, CLI `--ckpt-scheme`; values `mirror:<k>` / `xor:<g>` /
/// `rs2:<g>`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Scheme {
    /// Full buddy copies to `k` ring successors (the paper's layout).
    Mirror {
        /// Buddy copies per object.
        k: usize,
    },
    /// One XOR parity stripe per group of `g` consecutive comm ranks.
    Xor {
        /// Parity-group size.
        g: usize,
    },
    /// Two independent parity stripes (XOR + GF(2^8)-weighted) per group of
    /// `g` consecutive comm ranks, with holder rotation per rebase epoch.
    Rs2 {
        /// Parity-group size.
        g: usize,
    },
}

impl Default for Scheme {
    fn default() -> Self {
        Scheme::Mirror { k: 1 }
    }
}

impl Scheme {
    /// Parse `mirror`, `mirror:<k>`, `xor`, `xor:<g>`, `rs2`, `rs2:<g>`.
    ///
    /// ```
    /// use ulfm_ftgmres::ckptstore::Scheme;
    /// assert_eq!(Scheme::parse("rs2:4"), Some(Scheme::Rs2 { g: 4 }));
    /// assert_eq!(Scheme::parse("rs2"), Some(Scheme::Rs2 { g: 4 }));
    /// assert_eq!(Scheme::parse("mirror:2"), Some(Scheme::Mirror { k: 2 }));
    /// assert_eq!(Scheme::parse("rs2:1"), None);
    /// assert_eq!(Scheme::parse("raid6"), None);
    /// ```
    pub fn parse(s: &str) -> Option<Scheme> {
        let s = s.trim();
        if let Some(rest) = s.strip_prefix("mirror") {
            let k = match rest.strip_prefix(':') {
                Some(n) => n.trim().parse().ok()?,
                None if rest.is_empty() => 1,
                None => return None,
            };
            if k == 0 {
                return None;
            }
            return Some(Scheme::Mirror { k });
        }
        if let Some(rest) = s.strip_prefix("xor") {
            let g = match rest.strip_prefix(':') {
                Some(n) => n.trim().parse().ok()?,
                None if rest.is_empty() => 4,
                None => return None,
            };
            if g < 2 {
                return None;
            }
            return Some(Scheme::Xor { g });
        }
        if let Some(rest) = s.strip_prefix("rs2") {
            let g = match rest.strip_prefix(':') {
                Some(n) => n.trim().parse().ok()?,
                None if rest.is_empty() => 4,
                None => return None,
            };
            if g < 2 {
                return None;
            }
            return Some(Scheme::Rs2 { g });
        }
        None
    }

    pub fn name(&self) -> String {
        match self {
            Scheme::Mirror { k } => format!("mirror:{k}"),
            Scheme::Xor { g } => format!("xor:{g}"),
            Scheme::Rs2 { g } => format!("rs2:{g}"),
        }
    }

    /// Buddy count for mirror semantics (estimate inputs; 1 for the parity
    /// schemes, whose re-encode ships parity contributions instead of full
    /// copies).
    pub fn mirror_k(&self) -> usize {
        match self {
            Scheme::Mirror { k } => *k,
            Scheme::Xor { .. } | Scheme::Rs2 { .. } => 1,
        }
    }

    /// Whether the parity encoding is actually usable at communicator size
    /// `n`.  `xor:<g>` needs one rank outside every group (`n > g`);
    /// `rs2:<g>` needs two distinct holder slots outside every group
    /// (`n >= g + 2`).  Runs (or shrunken survivor sets) below the bound
    /// degrade to `mirror:1` deterministically on every rank.
    pub fn parity_active(&self, n: usize) -> bool {
        match self {
            Scheme::Mirror { .. } => false,
            Scheme::Xor { g } => n > *g,
            Scheme::Rs2 { g } => n >= g + 2,
        }
    }

    /// Whether the xor encoding is active at communicator size `n` (see
    /// [`Scheme::parity_active`]; kept for the original xor-only call
    /// sites and tests).
    pub fn xor_active(&self, n: usize) -> bool {
        matches!(self, Scheme::Xor { .. }) && self.parity_active(n)
    }

    /// The comm rank that, if `owner_cr` fails, serves its checkpointed
    /// objects to the recovery reader — or `None` when the loss is
    /// unrecoverable in situ.
    ///
    /// * mirror: the first *alive* buddy on the ring (every buddy holds a
    ///   full copy);
    /// * xor (active): the owner's parity holder, feasible only while the
    ///   holder *and* every other member of the owner's group are alive;
    /// * rs2 (active): the *reconstruction leader* — the first alive comm
    ///   rank scanning the ring from the owner's group base (so both failed
    ///   members of a double fault share one leader, and the leader is a
    ///   surviving group member whenever one exists).  Note rs2 feasibility
    ///   is *rotation-dependent* (which holders carry the stripes depends
    ///   on the restore version) and is therefore judged by
    ///   [`crate::ckptstore::assess_loss`], not here; this function only
    ///   names the rank that serves once the loss was assessed recoverable.
    /// * any parity scheme below its [`Scheme::parity_active`] bound: the
    ///   degraded `mirror:1` buddy.
    ///
    /// Every rank (survivors and adopted spares alike) evaluates this from
    /// the shared liveness registry, so server choice needs no negotiation.
    pub fn server_cr_for(
        &self,
        owner_cr: usize,
        n: usize,
        alive_cr: &dyn Fn(usize) -> bool,
        stride: usize,
    ) -> Option<usize> {
        match self {
            Scheme::Mirror { k } => (1..=(*k).min(n.saturating_sub(1)))
                .map(|d| buddy_of_stride(owner_cr, d, n, stride))
                .find(|&cr| alive_cr(cr)),
            Scheme::Xor { g } => {
                if !self.parity_active(n) {
                    return (1..n.min(2))
                        .map(|d| buddy_of_stride(owner_cr, d, n, stride))
                        .find(|&cr| alive_cr(cr));
                }
                let grp = group_of(owner_cr, *g);
                let holder = holder_cr(grp, *g, n);
                if !alive_cr(holder) {
                    return None;
                }
                let (start, len) = group_span(grp, *g, n);
                for cr in start..start + len {
                    if cr != owner_cr && !alive_cr(cr) {
                        return None;
                    }
                }
                Some(holder)
            }
            Scheme::Rs2 { g } => {
                if !self.parity_active(n) {
                    return (1..n.min(2))
                        .map(|d| buddy_of_stride(owner_cr, d, n, stride))
                        .find(|&cr| alive_cr(cr));
                }
                let (start, _) = group_span(group_of(owner_cr, *g), *g, n);
                (0..n).map(|d| (start + d) % n).find(|&cr| alive_cr(cr))
            }
        }
    }
}

/// Parity group of comm rank `cr` for group size `g`.
pub fn group_of(cr: usize, g: usize) -> usize {
    cr / g
}

/// Number of parity groups in a communicator of `n`.
pub fn n_groups(n: usize, g: usize) -> usize {
    n.div_ceil(g)
}

/// `(start comm rank, member count)` of group `grp` (the last group may be
/// short when `g` does not divide `n`).
pub fn group_span(grp: usize, g: usize, n: usize) -> (usize, usize) {
    let start = grp * g;
    (start, g.min(n - start))
}

/// Parity holder of group `grp`: the base rank of the next group on the
/// group ring.  For any `n > g` this rank is outside `grp` itself, so a
/// whole-group stripe never shares fate with the data it protects.
pub fn holder_cr(grp: usize, g: usize, n: usize) -> usize {
    ((grp + 1) * g) % n
}

/// The two `rs2` stripe holders (`P` = XOR, `Q` = GF-weighted) of group
/// `grp` at rotation index `rot` (DESIGN.md §9).
///
/// The ranks *outside* the group are enumerated in ring order starting
/// just past the group's end; `P` sits at offset `rot mod s` into that
/// list (`s` = outside-rank count) and `Q` at the next offset, so:
///
/// * both holders are provably outside the group they protect (the group
///   is a contiguous ring arc, so everything from `start + len` around to
///   `start` is outside);
/// * `P != Q` always (`s >= 2` whenever the scheme is active,
///   [`Scheme::parity_active`]);
/// * consecutive rotation indices shift both stripes one rank around the
///   outside ring, spreading stripe memory and reconstruction load across
///   every non-member instead of pinning one holder — and at `rot = 0`
///   with `g | n`, `P` coincides with the static xor holder
///   ([`holder_cr`]).
///
/// The rotation index advances once per rebase epoch
/// ([`crate::ckptstore::CkptCfg::rot_index`]): delta chains between
/// rebases must fold into a stripe that stays put, so holders hand over at
/// exactly the full re-encode commits.
///
/// ```
/// use ulfm_ftgmres::ckptstore::scheme::rs2_holders;
/// // 8 ranks, groups of 4: group 0 = {0..3}, outside ranks = [4,5,6,7].
/// assert_eq!(rs2_holders(0, 4, 8, 0), (4, 5));
/// assert_eq!(rs2_holders(0, 4, 8, 1), (5, 6));
/// assert_eq!(rs2_holders(0, 4, 8, 3), (7, 4)); // wraps around the list
/// // Group 1 = {4..7}: its outside list starts at rank 0.
/// assert_eq!(rs2_holders(1, 4, 8, 0), (0, 1));
/// ```
pub fn rs2_holders(grp: usize, g: usize, n: usize, rot: u64) -> (usize, usize) {
    let (start, len) = group_span(grp, g, n);
    let s = n - len;
    debug_assert!(s >= 2, "rs2 needs two holder slots outside every group (n={n}, g={g})");
    let r = (rot % s as u64) as usize;
    let p = (start + len + r) % n;
    let q = (start + len + (r + 1) % s) % n;
    (p, q)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_surface() {
        assert_eq!(Scheme::parse("mirror:2"), Some(Scheme::Mirror { k: 2 }));
        assert_eq!(Scheme::parse("mirror"), Some(Scheme::Mirror { k: 1 }));
        assert_eq!(Scheme::parse("xor:4"), Some(Scheme::Xor { g: 4 }));
        assert_eq!(Scheme::parse("xor"), Some(Scheme::Xor { g: 4 }));
        assert_eq!(Scheme::parse("xor:1"), None);
        assert_eq!(Scheme::parse("mirror:0"), None);
        assert_eq!(Scheme::parse("raid6"), None);
        assert_eq!(Scheme::Xor { g: 4 }.name(), "xor:4");
        assert_eq!(Scheme::Mirror { k: 1 }.name(), "mirror:1");
    }

    #[test]
    fn holder_is_always_outside_its_group() {
        for n in [5usize, 6, 8, 10, 12, 16, 48] {
            for g in [2usize, 3, 4] {
                if n <= g {
                    continue;
                }
                for grp in 0..n_groups(n, g) {
                    let h = holder_cr(grp, g, n);
                    let (start, len) = group_span(grp, g, n);
                    assert!(
                        h < start || h >= start + len,
                        "holder {h} inside group {grp} (n={n}, g={g})"
                    );
                }
            }
        }
    }

    #[test]
    fn holders_are_distinct_per_group() {
        for n in [6usize, 8, 10, 12, 16, 48] {
            for g in [2usize, 4] {
                if n <= g {
                    continue;
                }
                let mut holders: Vec<usize> =
                    (0..n_groups(n, g)).map(|grp| holder_cr(grp, g, n)).collect();
                holders.sort_unstable();
                holders.dedup();
                assert_eq!(holders.len(), n_groups(n, g), "n={n} g={g}");
            }
        }
    }

    #[test]
    fn mirror_server_is_first_alive_buddy() {
        let s = Scheme::Mirror { k: 2 };
        let alive = |cr: usize| cr != 3 && cr != 4;
        // Owner 3 dead: buddy 4 also dead, buddy 5 serves.
        assert_eq!(s.server_cr_for(3, 8, &alive, 1), Some(5));
        // k=1 with the only buddy dead: unrecoverable.
        let s1 = Scheme::Mirror { k: 1 };
        assert_eq!(s1.server_cr_for(3, 8, &alive, 1), None);
    }

    #[test]
    fn xor_server_is_parity_holder_when_group_intact() {
        let s = Scheme::Xor { g: 4 };
        // n=8: groups {0..3} and {4..7}; holders 4 and 0.
        let alive = |cr: usize| cr != 1;
        assert_eq!(s.server_cr_for(1, 8, &alive, 1), Some(4));
        let alive2 = |cr: usize| cr != 5;
        assert_eq!(s.server_cr_for(5, 8, &alive2, 1), Some(0));
    }

    #[test]
    fn xor_two_losses_in_one_group_are_unrecoverable() {
        let s = Scheme::Xor { g: 4 };
        let alive = |cr: usize| cr != 1 && cr != 2;
        assert_eq!(s.server_cr_for(1, 8, &alive, 1), None);
        assert_eq!(s.server_cr_for(2, 8, &alive, 1), None);
        // One loss per group stays recoverable.
        let alive2 = |cr: usize| cr != 1 && cr != 5;
        assert_eq!(s.server_cr_for(1, 8, &alive2, 1), Some(4));
        assert_eq!(s.server_cr_for(5, 8, &alive2, 1), Some(0));
    }

    #[test]
    fn xor_dead_holder_is_unrecoverable() {
        let s = Scheme::Xor { g: 4 };
        // Member 1 (group 0) and holder 4 (group 0's stripe) both dead.
        let alive = |cr: usize| cr != 1 && cr != 4;
        assert_eq!(s.server_cr_for(1, 8, &alive, 1), None);
    }

    #[test]
    fn xor_degrades_to_mirror_when_group_covers_comm() {
        let s = Scheme::Xor { g: 4 };
        assert!(!s.xor_active(4));
        assert!(!s.xor_active(3));
        assert!(s.xor_active(5));
        let alive = |cr: usize| cr != 2;
        // n=3 <= g: mirror:1 fallback, buddy 0 serves owner 2.
        assert_eq!(s.server_cr_for(2, 3, &alive, 1), Some(0));
    }

    #[test]
    fn rs2_parse_and_activation() {
        assert_eq!(Scheme::parse("rs2:4"), Some(Scheme::Rs2 { g: 4 }));
        assert_eq!(Scheme::parse("rs2"), Some(Scheme::Rs2 { g: 4 }));
        assert_eq!(Scheme::parse("rs2:1"), None);
        assert_eq!(Scheme::Rs2 { g: 4 }.name(), "rs2:4");
        assert_eq!(Scheme::Rs2 { g: 4 }.mirror_k(), 1);
        let s = Scheme::Rs2 { g: 4 };
        // Needs two holder slots outside every (full) group.
        assert!(!s.parity_active(5));
        assert!(s.parity_active(6));
        assert!(s.parity_active(8));
        assert!(!s.xor_active(8), "xor_active stays xor-specific");
    }

    #[test]
    fn rs2_holders_are_outside_distinct_and_rotate_over_all_slots() {
        for n in [6usize, 8, 10, 12, 48] {
            for g in [2usize, 4] {
                if n < g + 2 {
                    continue;
                }
                for grp in 0..n_groups(n, g) {
                    let (start, len) = group_span(grp, g, n);
                    let s = n - len;
                    let mut p_seen = std::collections::BTreeSet::new();
                    for rot in 0..2 * s as u64 {
                        let (p, q) = rs2_holders(grp, g, n, rot);
                        assert_ne!(p, q, "n={n} g={g} grp={grp} rot={rot}");
                        for h in [p, q] {
                            assert!(
                                h < start || h >= start + len,
                                "holder {h} inside group {grp} (n={n}, g={g}, rot={rot})"
                            );
                        }
                        p_seen.insert(p);
                    }
                    // A full rotation cycle spreads P over every outside rank.
                    assert_eq!(p_seen.len(), s, "n={n} g={g} grp={grp}");
                }
            }
        }
    }

    #[test]
    fn rs2_rot0_p_holder_matches_the_xor_holder_when_g_divides_n() {
        for (n, g) in [(8usize, 4usize), (12, 4), (8, 2), (48, 4)] {
            for grp in 0..n_groups(n, g) {
                assert_eq!(rs2_holders(grp, g, n, 0).0, holder_cr(grp, g, n), "n={n} g={g}");
            }
        }
    }

    #[test]
    fn rs2_server_is_the_group_scan_leader() {
        let s = Scheme::Rs2 { g: 4 };
        // Owner 1 (group 0) dead, everyone else alive: leader = rank 0.
        let alive = |cr: usize| cr != 1;
        assert_eq!(s.server_cr_for(1, 8, &alive, 1), Some(0));
        // Double fault 0+1: both served by the first alive member, rank 2.
        let alive2 = |cr: usize| cr != 0 && cr != 1;
        assert_eq!(s.server_cr_for(0, 8, &alive2, 1), Some(2));
        assert_eq!(s.server_cr_for(1, 8, &alive2, 1), Some(2));
        // Whole group of 2 dead (g=2): leader scans past the group.
        let s2 = Scheme::Rs2 { g: 2 };
        let alive3 = |cr: usize| cr != 2 && cr != 3;
        assert_eq!(s2.server_cr_for(2, 8, &alive3, 1), Some(4));
        assert_eq!(s2.server_cr_for(3, 8, &alive3, 1), Some(4));
        // Degraded (n < g+2): mirror:1 fallback.
        let alive4 = |cr: usize| cr != 2;
        assert_eq!(s.server_cr_for(2, 5, &alive4, 1), Some(3));
    }
}
