//! GF(2^8) arithmetic for the second parity stripe of the `rs2:<g>`
//! checkpoint scheme (DESIGN.md §9).
//!
//! The `rs2` scheme stores two *independent* stripes per parity group: the
//! plain XOR stripe `P = ⊕ m_k` it shares with `xor:<g>`, and a
//! RAID-6-style weighted stripe `Q = ⊕ c_k · m_k`, where `c_k = α^k` is the
//! [`coef`] of member slot `k` and `·` is multiplication in GF(2^8)
//! (polynomial `x^8 + x^4 + x^3 + x^2 + 1`, i.e. `0x11d`, generator
//! `α = 2`).  Addition in GF(2^8) is XOR, so:
//!
//! * the same member contribution updates both stripes — `Q' = Q ⊕ c_k·Δ_k`
//!   because multiplication distributes over XOR, which is what lets delta
//!   shipping, compression and double parity compose;
//! * losing any *two* members leaves a 2x2 linear system over GF(2^8) with
//!   matrix `[[1, 1], [c_i, c_j]]`, whose determinant `c_i ⊕ c_j` is
//!   non-zero whenever `i != j` (powers of the generator are distinct below
//!   order 255) — so every member+member double loss is solvable, see
//!   [`solve_two_erasures`].
//!
//! All operations act byte-wise on the packed 64-bit checkpoint words
//! ([`crate::ckptstore::delta::pack_words`]); no floating-point arithmetic
//! ever touches the payloads, so reconstruction stays bit-exact.

/// The RAID-6 field polynomial (x^8 + x^4 + x^3 + x^2 + 1).
const POLY: u16 = 0x11d;

const fn build_exp() -> [u8; 512] {
    let mut exp = [0u8; 512];
    let mut x: u16 = 1;
    let mut i = 0;
    while i < 255 {
        exp[i] = x as u8;
        x <<= 1;
        if x & 0x100 != 0 {
            x ^= POLY;
        }
        i += 1;
    }
    // Mirror the cycle so `EXP[log_a + log_b]` never needs a modulo.
    let mut j = 0;
    while j < 257 {
        exp[255 + j] = exp[j % 255];
        j += 1;
    }
    exp
}

const fn build_log(exp: &[u8; 512]) -> [u8; 256] {
    let mut log = [0u8; 256];
    let mut i = 0;
    while i < 255 {
        log[exp[i] as usize] = i as u8;
        i += 1;
    }
    log
}

/// `EXP[i] = α^i` (doubled so products of logs index without a modulo).
const EXP: [u8; 512] = build_exp();
/// `LOG[α^i] = i`; `LOG[0]` is unused (0 has no logarithm).
const LOG: [u8; 256] = build_log(&EXP);

/// Multiply in GF(2^8).
///
/// ```
/// use ulfm_ftgmres::ckptstore::gf256;
/// assert_eq!(gf256::gmul(7, 1), 7);
/// assert_eq!(gf256::gmul(0, 0x53), 0);
/// // gdiv inverts gmul for any non-zero divisor.
/// assert_eq!(gf256::gdiv(gf256::gmul(0x57, 0x13), 0x13), 0x57);
/// ```
pub fn gmul(a: u8, b: u8) -> u8 {
    if a == 0 || b == 0 {
        return 0;
    }
    EXP[LOG[a as usize] as usize + LOG[b as usize] as usize]
}

/// Divide in GF(2^8) (`b` must be non-zero).
pub fn gdiv(a: u8, b: u8) -> u8 {
    assert_ne!(b, 0, "GF(2^8) division by zero");
    if a == 0 {
        return 0;
    }
    EXP[255 + LOG[a as usize] as usize - LOG[b as usize] as usize]
}

/// Weight of member slot `k` in the `Q` stripe: `α^k`.  Distinct (and
/// hence solvable against any other slot) for every `k < 255`, far above
/// any practical parity-group size.
pub fn coef(slot: usize) -> u8 {
    debug_assert!(slot < 255, "rs2 group size limited to 255 slots");
    EXP[slot]
}

/// Multiply one packed 64-bit checkpoint word byte-wise by `c`.
pub fn mul_word(w: i64, c: u8) -> i64 {
    if c == 1 {
        return w;
    }
    let bytes = w.to_le_bytes();
    let mut out = [0u8; 8];
    for (o, b) in out.iter_mut().zip(bytes) {
        *o = gmul(b, c);
    }
    i64::from_le_bytes(out)
}

/// XOR `c · words` into `acc`, growing `acc` with zeros as needed — the `Q`
/// analogue of [`crate::ckptstore::delta::xor_into`].
pub fn mul_xor_into(acc: &mut Vec<i64>, words: &[i64], c: u8) {
    if acc.len() < words.len() {
        acc.resize(words.len(), 0);
    }
    for (a, w) in acc.iter_mut().zip(words.iter()) {
        *a ^= mul_word(*w, c);
    }
}

/// Divide every word of `words` byte-wise by `c` in place (single-erasure
/// solve against the `Q` stripe alone: `m_f = (Q ⊕ Σ c_k·m_k) / c_f`).
pub fn div_words(words: &mut [i64], c: u8) {
    if c == 1 {
        return;
    }
    let inv = gdiv(1, c);
    for w in words.iter_mut() {
        *w = mul_word(*w, inv);
    }
}

/// Solve the two-erasure system for member slots `i` and `j` (`c_i = coef(i)`,
/// `c_j = coef(j)`, `i != j`) given the survivor-folded stripes
/// `pp = m_i ⊕ m_j` and `qq = c_i·m_i ⊕ c_j·m_j`.  Returns `(m_i, m_j)`.
///
/// Derivation (all arithmetic in GF(2^8), per byte):
/// `c_j·pp ⊕ qq = (c_i ⊕ c_j)·m_i`, hence `m_i = (c_j·pp ⊕ qq)/(c_i ⊕ c_j)`
/// and `m_j = pp ⊕ m_i`.
pub fn solve_two_erasures(pp: &[i64], qq: &[i64], ci: u8, cj: u8) -> (Vec<i64>, Vec<i64>) {
    assert_ne!(ci, cj, "two-erasure solve needs distinct member weights");
    let denom = ci ^ cj;
    let n = pp.len().max(qq.len());
    let at = |s: &[i64], k: usize| if k < s.len() { s[k] } else { 0 };
    let mut mi = Vec::with_capacity(n);
    let mut mj = Vec::with_capacity(n);
    for k in 0..n {
        let pb = at(pp, k).to_le_bytes();
        let qb = at(qq, k).to_le_bytes();
        let mut bi = [0u8; 8];
        let mut bj = [0u8; 8];
        for t in 0..8 {
            let x = gdiv(gmul(cj, pb[t]) ^ qb[t], denom);
            bi[t] = x;
            bj[t] = pb[t] ^ x;
        }
        mi.push(i64::from_le_bytes(bi));
        mj.push(i64::from_le_bytes(bj));
    }
    (mi, mj)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic dependency-free PRNG for the algebra tests.
    struct Lcg(u64);
    impl Lcg {
        fn next(&mut self) -> u64 {
            self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            self.0
        }
    }

    #[test]
    fn field_axioms_on_samples() {
        let mut rng = Lcg(7);
        for _ in 0..200 {
            let a = (rng.next() >> 24) as u8;
            let b = (rng.next() >> 24) as u8;
            let c = (rng.next() >> 24) as u8;
            // Commutativity and distributivity over XOR (= field addition).
            assert_eq!(gmul(a, b), gmul(b, a));
            assert_eq!(gmul(a, b ^ c), gmul(a, b) ^ gmul(a, c));
            // Multiplicative inverses.
            if b != 0 {
                assert_eq!(gdiv(gmul(a, b), b), a);
            }
            assert_eq!(gmul(a, 1), a);
            assert_eq!(gmul(a, 0), 0);
        }
    }

    #[test]
    fn coefs_are_distinct() {
        let mut seen = [false; 256];
        for slot in 0..255 {
            let c = coef(slot);
            assert_ne!(c, 0);
            assert!(!seen[c as usize], "coef({slot}) repeats");
            seen[c as usize] = true;
        }
        assert_eq!(coef(0), 1);
        assert_eq!(coef(1), 2);
    }

    #[test]
    fn mul_word_is_bytewise_linear() {
        let mut rng = Lcg(99);
        for _ in 0..50 {
            let w = rng.next() as i64;
            let v = rng.next() as i64;
            let c = (rng.next() >> 40) as u8;
            assert_eq!(mul_word(w ^ v, c), mul_word(w, c) ^ mul_word(v, c));
            assert_eq!(mul_word(w, 1), w);
            assert_eq!(mul_word(w, 0), 0);
        }
    }

    #[test]
    fn two_erasure_solve_recovers_members() {
        let mut rng = Lcg(2024);
        // Four members of differing lengths, slots 0..4.
        let members: Vec<Vec<i64>> = (0..4)
            .map(|k| (0..10 + 3 * k).map(|_| rng.next() as i64).collect())
            .collect();
        let mut pp: Vec<i64> = Vec::new();
        let mut qq: Vec<i64> = Vec::new();
        for (k, m) in members.iter().enumerate() {
            crate::ckptstore::delta::xor_into(&mut pp, m);
            mul_xor_into(&mut qq, m, coef(k));
        }
        // Erase slots 1 and 3: fold the survivors back out of both stripes.
        for k in [0usize, 2] {
            crate::ckptstore::delta::xor_into(&mut pp, &members[k]);
            mul_xor_into(&mut qq, &members[k], coef(k));
        }
        let (m1, m3) = solve_two_erasures(&pp, &qq, coef(1), coef(3));
        assert_eq!(&m1[..members[1].len()], &members[1][..]);
        assert_eq!(&m3[..members[3].len()], &members[3][..]);
        // Padding beyond the true lengths is zero.
        assert!(m1[members[1].len()..].iter().all(|&w| w == 0));
    }

    #[test]
    fn single_erasure_via_q_alone() {
        let mut rng = Lcg(5);
        let members: Vec<Vec<i64>> =
            (0..3).map(|_| (0..16).map(|_| rng.next() as i64).collect()).collect();
        let mut qq: Vec<i64> = Vec::new();
        for (k, m) in members.iter().enumerate() {
            mul_xor_into(&mut qq, m, coef(k));
        }
        // Lose slot 2; fold survivors 0 and 1 back out, divide by coef(2).
        for k in [0usize, 1] {
            mul_xor_into(&mut qq, &members[k], coef(k));
        }
        div_words(&mut qq, coef(2));
        assert_eq!(&qq[..16], &members[2][..]);
    }
}
