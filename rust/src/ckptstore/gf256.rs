//! GF(2^8) arithmetic for the second parity stripe of the `rs2:<g>`
//! checkpoint scheme (DESIGN.md §9), with whole-word widened kernels for
//! the hot encode/solve paths (DESIGN.md §11).
//!
//! The `rs2` scheme stores two *independent* stripes per parity group: the
//! plain XOR stripe `P = ⊕ m_k` it shares with `xor:<g>`, and a
//! RAID-6-style weighted stripe `Q = ⊕ c_k · m_k`, where `c_k = α^k` is the
//! [`coef`] of member slot `k` and `·` is multiplication in GF(2^8)
//! (polynomial `x^8 + x^4 + x^3 + x^2 + 1`, i.e. `0x11d`, generator
//! `α = 2`).  Addition in GF(2^8) is XOR, so:
//!
//! * the same member contribution updates both stripes — `Q' = Q ⊕ c_k·Δ_k`
//!   because multiplication distributes over XOR, which is what lets delta
//!   shipping, compression and double parity compose;
//! * losing any *two* members leaves a 2x2 linear system over GF(2^8) with
//!   matrix `[[1, 1], [c_i, c_j]]`, whose determinant `c_i ⊕ c_j` is
//!   non-zero whenever `i != j` (powers of the generator are distinct below
//!   order 255) — so every member+member double loss is solvable, see
//!   [`solve_two_erasures`].
//!
//! # Kernel layers
//!
//! The scalar log/exp reference ([`gmul`], [`mul_word_bytewise`]) is kept
//! as the semantic ground truth; the hot paths multiply whole 64-bit words
//! (or slices of them) per step instead of one byte at a time:
//!
//! * [`WideMul`] — branch-free SWAR: the coefficient is decomposed into
//!   its α-powers once, then each 8-byte word is folded with 8 masked
//!   xtime steps (no table lookups, no per-byte branches);
//! * per-coefficient 256-entry product table ([`WideMul::table`]) — for
//!   mid-size slices, one L1 lookup per byte with no zero-checks;
//! * AVX2 `pshufb` split-nibble kernel (x86-64, detected at runtime) —
//!   32 payload bytes per shuffle pair, the classic RAID-6/ISA-L layout.
//!
//! All layers are bit-identical to the bytewise reference (property-tested
//! over every coefficient in `tests/gf256_kernels.rs`); the `hotpath`
//! bench asserts the widened slice kernel beats the bytewise reference by
//! >= 4x.
//!
//! All operations act byte-wise on the packed 64-bit checkpoint words
//! ([`crate::ckptstore::delta::pack_words`]); no floating-point arithmetic
//! ever touches the payloads, so reconstruction stays bit-exact.

/// The RAID-6 field polynomial (x^8 + x^4 + x^3 + x^2 + 1).
const POLY: u16 = 0x11d;

const fn build_exp() -> [u8; 512] {
    let mut exp = [0u8; 512];
    let mut x: u16 = 1;
    let mut i = 0;
    while i < 255 {
        exp[i] = x as u8;
        x <<= 1;
        if x & 0x100 != 0 {
            x ^= POLY;
        }
        i += 1;
    }
    // Mirror the cycle so `EXP[log_a + log_b]` never needs a modulo.
    let mut j = 0;
    while j < 257 {
        exp[255 + j] = exp[j % 255];
        j += 1;
    }
    exp
}

const fn build_log(exp: &[u8; 512]) -> [u8; 256] {
    let mut log = [0u8; 256];
    let mut i = 0;
    while i < 255 {
        log[exp[i] as usize] = i as u8;
        i += 1;
    }
    log
}

/// `EXP[i] = α^i` (doubled so products of logs index without a modulo).
const EXP: [u8; 512] = build_exp();
/// `LOG[α^i] = i`; `LOG[0]` is unused (0 has no logarithm).
const LOG: [u8; 256] = build_log(&EXP);

/// Multiply in GF(2^8).
///
/// ```
/// use ulfm_ftgmres::ckptstore::gf256;
/// assert_eq!(gf256::gmul(7, 1), 7);
/// assert_eq!(gf256::gmul(0, 0x53), 0);
/// // gdiv inverts gmul for any non-zero divisor.
/// assert_eq!(gf256::gdiv(gf256::gmul(0x57, 0x13), 0x13), 0x57);
/// ```
pub fn gmul(a: u8, b: u8) -> u8 {
    if a == 0 || b == 0 {
        return 0;
    }
    EXP[LOG[a as usize] as usize + LOG[b as usize] as usize]
}

/// Divide in GF(2^8) (`b` must be non-zero).
pub fn gdiv(a: u8, b: u8) -> u8 {
    assert_ne!(b, 0, "GF(2^8) division by zero");
    if a == 0 {
        return 0;
    }
    EXP[255 + LOG[a as usize] as usize - LOG[b as usize] as usize]
}

/// Weight of member slot `k` in the `Q` stripe: `α^k`.  Distinct (and
/// hence solvable against any other slot) for every `k < 255`, far above
/// any practical parity-group size.
pub fn coef(slot: usize) -> u8 {
    debug_assert!(slot < 255, "rs2 group size limited to 255 slots");
    EXP[slot]
}

// ---------------------------------------------------------------------
// Bytewise reference kernels (the pre-§11 implementation, kept as the
// ground truth for property tests and as the bench baseline leg)
// ---------------------------------------------------------------------

/// Multiply one packed 64-bit word byte-wise by `c` through the log/exp
/// tables — the scalar reference the widened kernels are verified against.
pub fn mul_word_bytewise(w: i64, c: u8) -> i64 {
    if c == 1 {
        return w;
    }
    let bytes = w.to_le_bytes();
    let mut out = [0u8; 8];
    for (o, b) in out.iter_mut().zip(bytes) {
        *o = gmul(b, c);
    }
    i64::from_le_bytes(out)
}

/// Bytewise reference of [`mul_xor_into`] (bench baseline leg).
pub fn mul_xor_into_bytewise(acc: &mut Vec<i64>, words: &[i64], c: u8) {
    if acc.len() < words.len() {
        acc.resize(words.len(), 0);
    }
    for (a, w) in acc.iter_mut().zip(words.iter()) {
        *a ^= mul_word_bytewise(*w, c);
    }
}

/// Bytewise reference of [`solve_two_erasures`] (kernel property tests).
pub fn solve_two_erasures_bytewise(
    pp: &[i64],
    qq: &[i64],
    ci: u8,
    cj: u8,
) -> (Vec<i64>, Vec<i64>) {
    assert_ne!(ci, cj, "two-erasure solve needs distinct member weights");
    let denom = ci ^ cj;
    let n = pp.len().max(qq.len());
    let at = |s: &[i64], k: usize| if k < s.len() { s[k] } else { 0 };
    let mut mi = Vec::with_capacity(n);
    let mut mj = Vec::with_capacity(n);
    for k in 0..n {
        let pb = at(pp, k).to_le_bytes();
        let qb = at(qq, k).to_le_bytes();
        let mut bi = [0u8; 8];
        let mut bj = [0u8; 8];
        for t in 0..8 {
            let x = gdiv(gmul(cj, pb[t]) ^ qb[t], denom);
            bi[t] = x;
            bj[t] = pb[t] ^ x;
        }
        mi.push(i64::from_le_bytes(bi));
        mj.push(i64::from_le_bytes(bj));
    }
    (mi, mj)
}

// ---------------------------------------------------------------------
// Widened kernels (DESIGN.md §11)
// ---------------------------------------------------------------------

/// SWAR doubling: multiply all 8 packed bytes of `w` by α at once.
/// Per byte: `(b << 1) ^ (0x1d if the top bit was set)`; the mask-and-
/// multiply spreads the conditional reduction across lanes without
/// branches or cross-byte carries.
#[inline]
fn xtimes_wide(w: u64) -> u64 {
    let hi = w & 0x8080_8080_8080_8080;
    ((w ^ hi) << 1) ^ ((hi >> 7) * 0x1d)
}

/// A GF(2^8) coefficient prepared for whole-word multiplication: the
/// constant is decomposed into per-bit lane masks once, then every word
/// costs 8 branch-free masked xtime steps — no table lookups, no
/// per-byte zero checks (DESIGN.md §11).
#[derive(Debug, Clone, Copy)]
pub struct WideMul {
    masks: [u64; 8],
    c: u8,
}

impl WideMul {
    pub fn new(c: u8) -> Self {
        let mut masks = [0u64; 8];
        for (k, m) in masks.iter_mut().enumerate() {
            if c >> k & 1 != 0 {
                *m = u64::MAX;
            }
        }
        WideMul { masks, c }
    }

    /// The coefficient this kernel multiplies by.
    pub fn coef(&self) -> u8 {
        self.c
    }

    /// Multiply all 8 bytes of `w` by the coefficient.
    #[inline]
    pub fn mul(&self, w: i64) -> i64 {
        let mut t = w as u64;
        let mut acc = 0u64;
        for m in self.masks {
            acc ^= t & m;
            t = xtimes_wide(t);
        }
        acc as i64
    }

    /// Full 256-entry product table for this coefficient (one L1 lookup
    /// per payload byte on the mid-size slice path; also the source of
    /// the AVX2 kernel's split-nibble tables).
    pub fn table(&self) -> [u8; 256] {
        let mut tab = [0u8; 256];
        for (x, e) in tab.iter_mut().enumerate() {
            *e = (self.mul(x as i64) & 0xff) as u8;
        }
        tab
    }
}

/// Multiply one packed 64-bit checkpoint word byte-wise by `c`.
/// Thin wrapper over [`WideMul`]; prefer hoisting a `WideMul` out of
/// loops when the coefficient is fixed.
pub fn mul_word(w: i64, c: u8) -> i64 {
    WideMul::new(c).mul(w)
}

#[inline]
fn mul_word_table(tab: &[u8; 256], w: i64) -> i64 {
    let b = w.to_le_bytes();
    i64::from_le_bytes([
        tab[b[0] as usize],
        tab[b[1] as usize],
        tab[b[2] as usize],
        tab[b[3] as usize],
        tab[b[4] as usize],
        tab[b[5] as usize],
        tab[b[6] as usize],
        tab[b[7] as usize],
    ])
}

/// Slices at or above this many words take the table (and, where
/// available, AVX2) path; shorter ones stay on the pure-ALU SWAR kernel
/// so the table build cost is never paid for tiny payloads.
const TABLE_CUTOVER_WORDS: usize = 64;

/// Whether the SIMD (AVX2 `pshufb`) slice path is active on this machine.
/// The `hotpath` bench keys its speedup gate on this: the >= 4x
/// widened-vs-bytewise expectation holds for the shuffle kernel, while
/// scalar-table-only hosts (non-x86-64, or x86-64 without AVX2) are held
/// to a relaxed floor.
pub fn wide_simd_active() -> bool {
    #[cfg(target_arch = "x86_64")]
    {
        avx2::available()
    }
    #[cfg(not(target_arch = "x86_64"))]
    {
        false
    }
}

#[cfg(target_arch = "x86_64")]
mod avx2 {
    //! Split-nibble `pshufb` kernels: the product byte of `b` is
    //! `lo_tab[b & 0xf] ^ hi_tab[b >> 4]`, and `vpshufb` evaluates 32 such
    //! lookups per instruction.  Indices are masked to 0..15, so the
    //! shuffle's sign-bit zeroing rule is never triggered.

    #[cfg(target_arch = "x86_64")]
    use std::arch::x86_64::*;

    /// Whether the AVX2 path is usable on this machine (cached by std).
    pub fn available() -> bool {
        is_x86_feature_detected!("avx2")
    }

    /// `acc[k] ^= c * words[k]` over the common prefix, 4 words per step.
    /// Returns the number of words processed (the scalar tail follows).
    ///
    /// # Safety
    /// Caller must have verified [`available`].
    #[target_feature(enable = "avx2")]
    pub unsafe fn mul_xor(acc: &mut [i64], words: &[i64], tab: &[u8; 256]) -> usize {
        let n = acc.len().min(words.len());
        let (lo_tab, hi_tab) = nibble_tables(tab);
        let ltab = _mm256_broadcastsi128_si256(_mm_loadu_si128(lo_tab.as_ptr() as *const __m128i));
        let htab = _mm256_broadcastsi128_si256(_mm_loadu_si128(hi_tab.as_ptr() as *const __m128i));
        let mask = _mm256_set1_epi8(0x0f);
        let mut k = 0usize;
        while k + 4 <= n {
            let src = _mm256_loadu_si256(words.as_ptr().add(k) as *const __m256i);
            let lo = _mm256_and_si256(src, mask);
            let hi = _mm256_and_si256(_mm256_srli_epi64::<4>(src), mask);
            let prod = _mm256_xor_si256(
                _mm256_shuffle_epi8(ltab, lo),
                _mm256_shuffle_epi8(htab, hi),
            );
            let dst = acc.as_mut_ptr().add(k) as *mut __m256i;
            _mm256_storeu_si256(dst, _mm256_xor_si256(_mm256_loadu_si256(dst), prod));
            k += 4;
        }
        k
    }

    /// `words[k] = c * words[k]` in place, 4 words per step.  Returns the
    /// number of words processed.
    ///
    /// # Safety
    /// Caller must have verified [`available`].
    #[target_feature(enable = "avx2")]
    pub unsafe fn mul_in_place(words: &mut [i64], tab: &[u8; 256]) -> usize {
        let n = words.len();
        let (lo_tab, hi_tab) = nibble_tables(tab);
        let ltab = _mm256_broadcastsi128_si256(_mm_loadu_si128(lo_tab.as_ptr() as *const __m128i));
        let htab = _mm256_broadcastsi128_si256(_mm_loadu_si128(hi_tab.as_ptr() as *const __m128i));
        let mask = _mm256_set1_epi8(0x0f);
        let mut k = 0usize;
        while k + 4 <= n {
            let p = words.as_mut_ptr().add(k) as *mut __m256i;
            let src = _mm256_loadu_si256(p);
            let lo = _mm256_and_si256(src, mask);
            let hi = _mm256_and_si256(_mm256_srli_epi64::<4>(src), mask);
            let prod = _mm256_xor_si256(
                _mm256_shuffle_epi8(ltab, lo),
                _mm256_shuffle_epi8(htab, hi),
            );
            _mm256_storeu_si256(p, prod);
            k += 4;
        }
        k
    }

    /// Low-/high-nibble product tables from the full byte table: products
    /// are linear over XOR, so `tab[b] = tab[b & 0xf] ^ tab[(b >> 4) << 4]`.
    fn nibble_tables(tab: &[u8; 256]) -> ([u8; 16], [u8; 16]) {
        let mut lo = [0u8; 16];
        let mut hi = [0u8; 16];
        for (k, (l, h)) in lo.iter_mut().zip(hi.iter_mut()).enumerate() {
            *l = tab[k];
            *h = tab[k << 4];
        }
        (lo, hi)
    }
}

/// Core widened slice kernel: `acc[k] ^= c * words[k]` over the common
/// prefix (callers guarantee `acc` is at least as long where it matters).
fn mul_xor_slices(acc: &mut [i64], words: &[i64], wm: &WideMul) {
    let n = acc.len().min(words.len());
    if n >= TABLE_CUTOVER_WORDS {
        let tab = wm.table();
        let mut done = 0usize;
        #[cfg(target_arch = "x86_64")]
        if avx2::available() {
            // SAFETY: availability checked above.
            done = unsafe { avx2::mul_xor(&mut acc[..n], &words[..n], &tab) };
        }
        for (a, w) in acc[done..n].iter_mut().zip(&words[done..n]) {
            *a ^= mul_word_table(&tab, *w);
        }
    } else {
        for (a, w) in acc[..n].iter_mut().zip(&words[..n]) {
            *a ^= wm.mul(*w);
        }
    }
}

/// `words[k] = c * words[k]` in place across the whole slice.
fn mul_slice_in_place(words: &mut [i64], wm: &WideMul) {
    let n = words.len();
    if n >= TABLE_CUTOVER_WORDS {
        let tab = wm.table();
        let mut done = 0usize;
        #[cfg(target_arch = "x86_64")]
        if avx2::available() {
            // SAFETY: availability checked above.
            done = unsafe { avx2::mul_in_place(words, &tab) };
        }
        for w in words[done..].iter_mut() {
            *w = mul_word_table(&tab, *w);
        }
    } else {
        for w in words.iter_mut() {
            *w = wm.mul(*w);
        }
    }
}

/// XOR `c · words` into `acc`, growing `acc` with zeros as needed — the `Q`
/// analogue of [`crate::ckptstore::delta::xor_into`], on the widened
/// kernels (bit-identical to [`mul_xor_into_bytewise`]).
pub fn mul_xor_into(acc: &mut Vec<i64>, words: &[i64], c: u8) {
    if acc.len() < words.len() {
        acc.resize(words.len(), 0);
    }
    match c {
        0 => {}
        1 => {
            for (a, w) in acc.iter_mut().zip(words.iter()) {
                *a ^= *w;
            }
        }
        _ => mul_xor_slices(acc, words, &WideMul::new(c)),
    }
}

/// Divide every word of `words` byte-wise by `c` in place (single-erasure
/// solve against the `Q` stripe alone: `m_f = (Q ⊕ Σ c_k·m_k) / c_f`).
pub fn div_words(words: &mut [i64], c: u8) {
    if c == 1 {
        return;
    }
    mul_slice_in_place(words, &WideMul::new(gdiv(1, c)));
}

/// Solve the two-erasure system for member slots `i` and `j` (`c_i = coef(i)`,
/// `c_j = coef(j)`, `i != j`) given the survivor-folded stripes
/// `pp = m_i ⊕ m_j` and `qq = c_i·m_i ⊕ c_j·m_j`.  Returns `(m_i, m_j)`.
///
/// Derivation (all arithmetic in GF(2^8), per byte):
/// `c_j·pp ⊕ qq = (c_i ⊕ c_j)·m_i`, hence `m_i = (c_j·pp ⊕ qq)/(c_i ⊕ c_j)`
/// and `m_j = pp ⊕ m_i`.  Runs entirely on the widened slice kernels:
/// `mi = inv(c_i ⊕ c_j) · (c_j·pp ⊕ qq)`, then `mj = pp ⊕ mi`.
pub fn solve_two_erasures(pp: &[i64], qq: &[i64], ci: u8, cj: u8) -> (Vec<i64>, Vec<i64>) {
    assert_ne!(ci, cj, "two-erasure solve needs distinct member weights");
    let n = pp.len().max(qq.len());
    // mi <- cj * pp  (zero-padded to the union length).
    let mut mi = vec![0i64; n];
    mul_xor_slices(&mut mi, pp, &WideMul::new(cj));
    // mi <- cj*pp ^ qq.
    for (a, q) in mi.iter_mut().zip(qq.iter()) {
        *a ^= *q;
    }
    // mi <- (cj*pp ^ qq) / (ci ^ cj).
    mul_slice_in_place(&mut mi, &WideMul::new(gdiv(1, ci ^ cj)));
    // mj <- pp ^ mi.
    let mut mj = mi.clone();
    for (b, p) in mj.iter_mut().zip(pp.iter()) {
        *b ^= *p;
    }
    (mi, mj)
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Deterministic dependency-free PRNG for the algebra tests.
    struct Lcg(u64);
    impl Lcg {
        fn next(&mut self) -> u64 {
            self.0 = self.0.wrapping_mul(6364136223846793005).wrapping_add(1442695040888963407);
            self.0
        }
    }

    #[test]
    fn field_axioms_on_samples() {
        let mut rng = Lcg(7);
        for _ in 0..200 {
            let a = (rng.next() >> 24) as u8;
            let b = (rng.next() >> 24) as u8;
            let c = (rng.next() >> 24) as u8;
            // Commutativity and distributivity over XOR (= field addition).
            assert_eq!(gmul(a, b), gmul(b, a));
            assert_eq!(gmul(a, b ^ c), gmul(a, b) ^ gmul(a, c));
            // Multiplicative inverses.
            if b != 0 {
                assert_eq!(gdiv(gmul(a, b), b), a);
            }
            assert_eq!(gmul(a, 1), a);
            assert_eq!(gmul(a, 0), 0);
        }
    }

    #[test]
    fn coefs_are_distinct() {
        let mut seen = [false; 256];
        for slot in 0..255 {
            let c = coef(slot);
            assert_ne!(c, 0);
            assert!(!seen[c as usize], "coef({slot}) repeats");
            seen[c as usize] = true;
        }
        assert_eq!(coef(0), 1);
        assert_eq!(coef(1), 2);
    }

    #[test]
    fn wide_mul_matches_bytewise_for_every_coefficient() {
        let mut rng = Lcg(42);
        let words: Vec<i64> = (0..32).map(|_| rng.next() as i64).collect();
        for c in 0..=255u8 {
            let wm = WideMul::new(c);
            let tab = wm.table();
            for &w in &words {
                let want = mul_word_bytewise(w, c);
                assert_eq!(wm.mul(w), want, "SWAR c={c} w={w:#x}");
                assert_eq!(mul_word_table(&tab, w), want, "table c={c} w={w:#x}");
                assert_eq!(mul_word(w, c), want, "mul_word c={c}");
            }
        }
    }

    #[test]
    fn mul_word_is_bytewise_linear() {
        let mut rng = Lcg(99);
        for _ in 0..50 {
            let w = rng.next() as i64;
            let v = rng.next() as i64;
            let c = (rng.next() >> 40) as u8;
            assert_eq!(mul_word(w ^ v, c), mul_word(w, c) ^ mul_word(v, c));
            assert_eq!(mul_word(w, 1), w);
            assert_eq!(mul_word(w, 0), 0);
        }
    }

    #[test]
    fn slice_kernels_match_bytewise_across_cutover() {
        // Lengths straddle the SWAR/table/AVX2 cutover and vector tails.
        let mut rng = Lcg(11);
        for len in [0usize, 1, 3, 5, 63, 64, 65, 67, 130, 257] {
            let words: Vec<i64> = (0..len).map(|_| rng.next() as i64).collect();
            for c in [0u8, 1, 2, 0x1d, 0x53, 0xfe, 0xff] {
                let mut wide: Vec<i64> = (0..len).map(|_| rng.next() as i64).collect();
                let mut byte = wide.clone();
                mul_xor_into(&mut wide, &words, c);
                mul_xor_into_bytewise(&mut byte, &words, c);
                assert_eq!(wide, byte, "len={len} c={c}");
                // In-place multiply agrees too (div by the inverse).
                if c > 1 {
                    let mut a = words.clone();
                    div_words(&mut a, gdiv(1, c));
                    let b: Vec<i64> =
                        words.iter().map(|&w| mul_word_bytewise(w, c)).collect();
                    assert_eq!(a, b, "in-place len={len} c={c}");
                }
            }
        }
    }

    #[test]
    fn two_erasure_solve_recovers_members() {
        let mut rng = Lcg(2024);
        // Four members of differing lengths, slots 0..4.
        let members: Vec<Vec<i64>> = (0..4)
            .map(|k| (0..10 + 3 * k).map(|_| rng.next() as i64).collect())
            .collect();
        let mut pp: Vec<i64> = Vec::new();
        let mut qq: Vec<i64> = Vec::new();
        for (k, m) in members.iter().enumerate() {
            crate::ckptstore::delta::xor_into(&mut pp, m);
            mul_xor_into(&mut qq, m, coef(k));
        }
        // Erase slots 1 and 3: fold the survivors back out of both stripes.
        for k in [0usize, 2] {
            crate::ckptstore::delta::xor_into(&mut pp, &members[k]);
            mul_xor_into(&mut qq, &members[k], coef(k));
        }
        let (m1, m3) = solve_two_erasures(&pp, &qq, coef(1), coef(3));
        assert_eq!(&m1[..members[1].len()], &members[1][..]);
        assert_eq!(&m3[..members[3].len()], &members[3][..]);
        // Padding beyond the true lengths is zero.
        assert!(m1[members[1].len()..].iter().all(|&w| w == 0));
        // And the widened solve agrees with the bytewise reference.
        let (b1, b3) = solve_two_erasures_bytewise(&pp, &qq, coef(1), coef(3));
        assert_eq!(m1, b1);
        assert_eq!(m3, b3);
    }

    #[test]
    fn single_erasure_via_q_alone() {
        let mut rng = Lcg(5);
        let members: Vec<Vec<i64>> =
            (0..3).map(|_| (0..16).map(|_| rng.next() as i64).collect()).collect();
        let mut qq: Vec<i64> = Vec::new();
        for (k, m) in members.iter().enumerate() {
            mul_xor_into(&mut qq, m, coef(k));
        }
        // Lose slot 2; fold survivors 0 and 1 back out, divide by coef(2).
        for k in [0usize, 1] {
            mul_xor_into(&mut qq, &members[k], coef(k));
        }
        div_words(&mut qq, coef(2));
        assert_eq!(&qq[..16], &members[2][..]);
    }
}
