//! Chunk-level delta encoding and the compressed wire format for
//! checkpoint shipping (DESIGN.md §8–§9).
//!
//! Checkpointed objects are serialized to a flat array of 64-bit *words*
//! (f64 bit patterns followed by i64 values) and compared chunk-by-chunk
//! against the previous committed version; only changed chunks travel on
//! the wire.  The uncompressed wire formats:
//!
//! * [`FMT_MDELTA`] — mirror delta: changed chunks carry the *new* words;
//!   the buddy overlays them on its stored copy of the base version and
//!   materializes a full blob, so the store always holds full objects and
//!   recovery never chases delta chains.
//! * [`FMT_XFULL`] — parity full contribution: the complete packed words
//!   of one group member, folded into a fresh stripe (rebase commits).
//! * [`FMT_XDELTA`] — parity delta contribution: changed chunks carry
//!   `old ^ new`, which is exactly the parity-stripe update
//!   (`stripe' = stripe ^ old ^ new`), so delta shipping and parity
//!   encoding compose without the holder ever seeing the member's data.
//!   The `rs2` scheme folds the *same* payload into its GF-weighted `Q`
//!   stripe as `Q' = Q ^ c_k·(old ^ new)` ([`crate::ckptstore::gf256`]).
//! * [`FMT_QFULL`] / [`FMT_QDELTA`] — the combined `Q`-stripe update the
//!   `P` holder forwards to the `Q` holder under `rs2` (built in
//!   [`crate::ckptstore`], format documented in DESIGN.md §9), so members
//!   ship each contribution once instead of twice.
//!
//! **Compression** (`ckpt_compress`, CLI `--ckpt-compress`): every wire
//! payload above — plus whole-blob reconstruction and spare-transfer
//! traffic — can additionally be wrapped in a word-level
//! run-length-encoded envelope ([`FMT_CWIRE`] for `i`-lane wires,
//! [`FMT_CBLOB`] for full blobs; see [`rle_compress`] for the token
//! grammar).  Zero runs dominate in practice: inside a changed chunk, the
//! `old ^ new` representation zeroes every *unchanged* word, so
//! compression recovers word-granular deltas from chunk-granular shipping
//! regardless of `ckpt_chunk_kib`.  Compression is transport-only and
//! loss-less — charged wire bytes drop, the decoded payload is
//! bit-identical.
//!
//! Word-level XOR is bit-exact (no floating-point arithmetic touches the
//! payloads), so reconstruction returns bit-identical objects.  Length
//! changes between versions (the Krylov basis grows every outer step) are
//! handled by comparing over zero-padded arrays: the common prefix still
//! dedupes, and only the tail plus genuinely changed chunks ship.
//!
//! All payloads ride in the `i` lane of a [`Blob`] so the virtual network
//! charges them at exactly 8 bytes per word.

use crate::checkpoint::Version;
use crate::simmpi::{Blob, WordArena};

/// Mirror delta wire format tag.
pub const FMT_MDELTA: i64 = 2;
/// Parity full-contribution wire format tag.
pub const FMT_XFULL: i64 = 3;
/// Parity delta-contribution wire format tag.
pub const FMT_XDELTA: i64 = 4;
/// Compressed `i`-lane wire envelope tag (see [`compress_wire`]).
pub const FMT_CWIRE: i64 = 5;
/// Compressed whole-blob envelope tag (see [`compress_blob`]).
pub const FMT_CBLOB: i64 = 6;
/// `rs2` combined Q-stripe full forward (P holder -> Q holder).
pub const FMT_QFULL: i64 = 7;
/// `rs2` combined Q-stripe delta forward (P holder -> Q holder).
pub const FMT_QDELTA: i64 = 8;
/// `rs2` stripe transfer to the reconstruction leader (holder -> leader).
pub const FMT_STRIPE: i64 = 9;

/// Serialize a blob into 64-bit words: f64 bit patterns, then i64 values.
pub fn pack_words(b: &Blob) -> Vec<i64> {
    let mut w = Vec::with_capacity(b.f.len() + b.i.len());
    pack_words_into(b, &mut w);
    w
}

/// [`pack_words`] into a caller-provided (arena) buffer, clearing it first
/// — the commit path packs two full objects per delta encode and must not
/// allocate fresh `Vec`s for them every commit (DESIGN.md §11).
pub fn pack_words_into(b: &Blob, out: &mut Vec<i64>) {
    out.clear();
    out.reserve(b.f.len() + b.i.len());
    out.extend(b.f.iter().map(|v| v.to_bits() as i64));
    out.extend_from_slice(&b.i);
}

/// Inverse of [`pack_words`] given the original lane lengths.  `words` may
/// be longer (parity stripes are padded to the longest group member).
pub fn unpack_words(words: &[i64], f_len: usize, i_len: usize) -> Blob {
    debug_assert!(
        words.len() >= f_len + i_len,
        "packed words shorter than recorded lengths"
    );
    Blob::new(
        words[..f_len].iter().map(|&w| f64::from_bits(w as u64)).collect(),
        words[f_len..f_len + i_len].to_vec(),
    )
}

/// XOR `words` into `acc`, growing `acc` with zeros as needed.
pub fn xor_into(acc: &mut Vec<i64>, words: &[i64]) {
    if acc.len() < words.len() {
        acc.resize(words.len(), 0);
    }
    for (a, w) in acc.iter_mut().zip(words.iter()) {
        *a ^= *w;
    }
}

/// Ratio of charged wire bytes to physical payload bytes of `b` (the
/// campaign `data_scale` for rows-proportional objects, 1 otherwise).
/// Derived payloads (deltas, parity contributions, reconstructed blobs)
/// inherit this factor so the network model keeps pricing them like the
/// full objects they stand in for.
pub fn wire_factor(b: &Blob) -> f64 {
    let physical = 8 * (b.f.len() + b.i.len());
    match b.wire {
        Some(w) if physical > 0 => w as f64 / physical as f64,
        _ => 1.0,
    }
}

/// Wire format tag of an encoded payload.
pub fn wire_fmt(wire: &Blob) -> i64 {
    wire.i[0]
}

fn word_at(words: &[i64], j: usize) -> i64 {
    if j < words.len() {
        words[j]
    } else {
        0
    }
}

/// Chunk indices (over `total` zero-padded words, `cw` words per chunk)
/// where `base` and `new_w` differ, written into an arena scratch (as
/// i64s — they ship verbatim in the wire header).
fn changed_chunks_into(base: &[i64], new_w: &[i64], total: usize, cw: usize, out: &mut Vec<i64>) {
    out.clear();
    let n_chunks = total.div_ceil(cw);
    for c in 0..n_chunks {
        let lo = c * cw;
        let hi = total.min(lo + cw);
        if (lo..hi).any(|j| word_at(base, j) != word_at(new_w, j)) {
            out.push(c as i64);
        }
    }
}

/// Shared delta wire layout:
/// `[fmt, base_version, f_len, i_len, chunk_words, total_words, n_chunks,
///   idx_0..idx_{n-1}, chunk words...]`.
///
/// Scratch comes from `arena`; the returned wire itself is the single
/// fresh allocation (it outlives the call inside the shipped [`Blob`]).
#[allow(clippy::too_many_arguments)]
fn delta_wire(
    arena: &mut WordArena,
    fmt: i64,
    base_w: &[i64],
    new_w: &[i64],
    total: usize,
    f_len: usize,
    i_len: usize,
    base_version: Version,
    cw: usize,
    xor: bool,
) -> Blob {
    let mut changed = arena.take();
    changed_chunks_into(base_w, new_w, total, cw, &mut changed);
    let mut i = Vec::with_capacity(7 + changed.len() * (cw + 1));
    i.push(fmt);
    i.push(base_version);
    i.push(f_len as i64);
    i.push(i_len as i64);
    i.push(cw as i64);
    i.push(total as i64);
    i.push(changed.len() as i64);
    i.extend_from_slice(&changed);
    for &c in &changed {
        let lo = c as usize * cw;
        let hi = total.min(lo + cw);
        for j in lo..hi {
            let v = if xor {
                word_at(base_w, j) ^ word_at(new_w, j)
            } else {
                word_at(new_w, j)
            };
            i.push(v);
        }
    }
    arena.put(changed);
    Blob::from_i64s(i)
}

/// Encode a mirror delta of `new` against `base` (chunks carry new words;
/// comparison runs over `new`'s length, zero-padding or truncating the
/// base), with all scratch drawn from `arena`.
pub fn mirror_delta_wire_in(
    arena: &mut WordArena,
    base: &Blob,
    new: &Blob,
    base_version: Version,
    chunk_words: usize,
) -> Blob {
    let mut base_w = arena.take();
    pack_words_into(base, &mut base_w);
    let mut new_w = arena.take();
    pack_words_into(new, &mut new_w);
    let total = new_w.len();
    let wire = delta_wire(
        arena,
        FMT_MDELTA,
        &base_w,
        &new_w,
        total,
        new.f.len(),
        new.i.len(),
        base_version,
        chunk_words.max(1),
        false,
    );
    arena.put(base_w);
    arena.put(new_w);
    wire
}

/// [`mirror_delta_wire_in`] with throwaway scratch (tests, cold paths).
pub fn mirror_delta_wire(
    base: &Blob,
    new: &Blob,
    base_version: Version,
    chunk_words: usize,
) -> Blob {
    mirror_delta_wire_in(&mut WordArena::default(), base, new, base_version, chunk_words)
}

/// Encode an xor delta contribution (`old ^ new` chunks over the padded
/// union length, so stale tail bits are cleared out of the stripe too),
/// with all scratch drawn from `arena`.
pub fn xor_delta_wire_in(
    arena: &mut WordArena,
    base: &Blob,
    new: &Blob,
    base_version: Version,
    chunk_words: usize,
) -> Blob {
    let mut base_w = arena.take();
    pack_words_into(base, &mut base_w);
    let mut new_w = arena.take();
    pack_words_into(new, &mut new_w);
    let total = base_w.len().max(new_w.len());
    let wire = delta_wire(
        arena,
        FMT_XDELTA,
        &base_w,
        &new_w,
        total,
        new.f.len(),
        new.i.len(),
        base_version,
        chunk_words.max(1),
        true,
    );
    arena.put(base_w);
    arena.put(new_w);
    wire
}

/// [`xor_delta_wire_in`] with throwaway scratch (tests, cold paths).
pub fn xor_delta_wire(
    base: &Blob,
    new: &Blob,
    base_version: Version,
    chunk_words: usize,
) -> Blob {
    xor_delta_wire_in(&mut WordArena::default(), base, new, base_version, chunk_words)
}

/// Encode a full xor contribution: `[FMT_XFULL, f_len, i_len, words...]`.
pub fn xor_full_wire(new: &Blob) -> Blob {
    let mut i = Vec::with_capacity(3 + new.f.len() + new.i.len());
    i.push(FMT_XFULL);
    i.push(new.f.len() as i64);
    i.push(new.i.len() as i64);
    i.extend(new.f.iter().map(|v| v.to_bits() as i64));
    i.extend_from_slice(&new.i);
    Blob::from_i64s(i)
}

// ---------------------------------------------------------------------
// Word-level RLE compression (DESIGN.md §9)
// ---------------------------------------------------------------------

/// Zero-run token: `[0, n]` stands for `n` zero words.
const TOK_ZERO: i64 = 0;
/// Repeat token: `[1, n, w]` stands for `n` copies of word `w`.
const TOK_RUN: i64 = 1;
/// Literal token: `[2, n, w_0..w_{n-1}]` carries `n` verbatim words.
const TOK_LIT: i64 = 2;

/// Word-level run-length encode: zero runs of >= 3 words collapse to
/// `[0, n]` (zero-run elision), non-zero runs of >= 4 to `[1, n, w]`,
/// everything else rides in literal blocks `[2, n, words...]`.  The
/// output is never more than `words.len() + 2` words (degenerate inputs
/// fall back to one literal block), so compression can be applied
/// unconditionally.
///
/// ```
/// use ulfm_ftgmres::ckptstore::delta::{rle_compress, rle_decompress};
/// let words = vec![9, 0, 0, 0, 0, 0, 0, 0, 7, 7, 7, 7, 7, 7, -1];
/// let toks = rle_compress(&words);
/// assert!(toks.len() < words.len()); // lit[9] + 7 zeros elided + run of 7s + lit[-1]
/// assert_eq!(rle_decompress(&toks), words);
/// ```
pub fn rle_compress(words: &[i64]) -> Vec<i64> {
    let mut out = Vec::new();
    rle_compress_into(words, &mut out);
    out
}

/// [`rle_compress`] into a caller-provided (arena) buffer, clearing it
/// first — the commit path compresses every wire and must not pay the
/// token buffer's growth reallocations per commit (DESIGN.md §11).
pub fn rle_compress_into(words: &[i64], out: &mut Vec<i64>) {
    out.clear();
    let n = words.len();
    let mut lit_start = 0usize;
    let mut i = 0usize;
    while i < n {
        let w = words[i];
        let mut j = i + 1;
        while j < n && words[j] == w {
            j += 1;
        }
        let run = j - i;
        let qualifies = if w == 0 { run >= 3 } else { run >= 4 };
        if qualifies {
            if lit_start < i {
                out.push(TOK_LIT);
                out.push((i - lit_start) as i64);
                out.extend_from_slice(&words[lit_start..i]);
            }
            if w == 0 {
                out.push(TOK_ZERO);
                out.push(run as i64);
            } else {
                out.push(TOK_RUN);
                out.push(run as i64);
                out.push(w);
            }
            lit_start = j;
        }
        i = j;
    }
    if lit_start < n {
        out.push(TOK_LIT);
        out.push((n - lit_start) as i64);
        out.extend_from_slice(&words[lit_start..n]);
    }
    if out.len() > n + 2 {
        // Pathological run/literal interleaving: ship one literal block.
        out.clear();
        out.push(TOK_LIT);
        out.push(n as i64);
        out.extend_from_slice(words);
    }
}

/// Inverse of [`rle_compress`].
pub fn rle_decompress(tokens: &[i64]) -> Vec<i64> {
    let mut out = Vec::new();
    let mut k = 0usize;
    while k < tokens.len() {
        match tokens[k] {
            TOK_ZERO => {
                let n = tokens[k + 1] as usize;
                out.resize(out.len() + n, 0);
                k += 2;
            }
            TOK_RUN => {
                let n = tokens[k + 1] as usize;
                let w = tokens[k + 2];
                out.resize(out.len() + n, w);
                k += 3;
            }
            TOK_LIT => {
                let n = tokens[k + 1] as usize;
                out.extend_from_slice(&tokens[k + 2..k + 2 + n]);
                k += 2 + n;
            }
            t => panic!("corrupt RLE stream: unknown token {t}"),
        }
    }
    out
}

/// Wrap an `i`-lane wire payload in a compressed envelope:
/// `[FMT_CWIRE, raw_words, tokens...]`.  Apply any charged-wire scaling
/// *after* compressing (the commit paths do), so [`wire_factor`] of the
/// shipped envelope still reports the original campaign scale factor.
pub fn compress_wire(wire: &Blob) -> Blob {
    compress_wire_in(&mut WordArena::default(), wire)
}

/// [`compress_wire`] with token scratch drawn from `arena`; the returned
/// envelope is the single fresh allocation.
pub fn compress_wire_in(arena: &mut WordArena, wire: &Blob) -> Blob {
    debug_assert!(wire.f.is_empty(), "wire payloads ride the i lane only");
    let mut toks = arena.take();
    rle_compress_into(&wire.i, &mut toks);
    let mut i = Vec::with_capacity(2 + toks.len());
    i.push(FMT_CWIRE);
    i.push(wire.i.len() as i64);
    i.extend_from_slice(&toks);
    arena.put(toks);
    Blob::from_i64s(i)
}

/// Unwrap a [`compress_wire`] envelope back to the inner `i`-lane wire.
pub fn decompress_wire(wire: &Blob) -> Blob {
    assert_eq!(wire.i[0], FMT_CWIRE, "not a compressed wire envelope");
    let raw_len = wire.i[1] as usize;
    let out = rle_decompress(&wire.i[2..]);
    debug_assert_eq!(out.len(), raw_len, "compressed wire length mismatch");
    Blob::from_i64s(out)
}

/// Compress a whole blob (reconstruction gathers, spare state transfers,
/// full mirror copies): `f = [original wire factor]`,
/// `i = [FMT_CBLOB, f_len, i_len, raw_words, tokens...]`, already scaled so
/// the charged bytes are `compressed physical x original factor`.
pub fn compress_blob(b: &Blob) -> Blob {
    compress_blob_in(&mut WordArena::default(), b)
}

/// [`compress_blob`] with pack/token scratch drawn from `arena`.
pub fn compress_blob_in(arena: &mut WordArena, b: &Blob) -> Blob {
    let factor = wire_factor(b);
    let mut words = arena.take();
    pack_words_into(b, &mut words);
    let mut toks = arena.take();
    rle_compress_into(&words, &mut toks);
    let mut i = Vec::with_capacity(4 + toks.len());
    i.push(FMT_CBLOB);
    i.push(b.f.len() as i64);
    i.push(b.i.len() as i64);
    i.push(words.len() as i64);
    i.extend_from_slice(&toks);
    arena.put(words);
    arena.put(toks);
    Blob { f: vec![factor].into(), i: i.into(), wire: None }.scaled(factor)
}

/// Inverse of [`compress_blob`]: restores the original blob including its
/// charged-wire scale factor.
pub fn decompress_blob(wire: &Blob) -> Blob {
    assert_eq!(wire.i[0], FMT_CBLOB, "not a compressed blob envelope");
    let f_len = wire.i[1] as usize;
    let i_len = wire.i[2] as usize;
    let raw_len = wire.i[3] as usize;
    let words = rle_decompress(&wire.i[4..]);
    debug_assert_eq!(words.len(), raw_len, "compressed blob length mismatch");
    let factor = wire.f[0];
    unpack_words(&words, f_len, i_len).scaled(factor)
}

/// Parsed read-only view of a [`FMT_XDELTA`] contribution — header fields
/// plus `(chunk index, chunk words)` slices — used by the `rs2` `P` holder
/// to fold the same payload into the GF-weighted `Q` update.
pub struct XDeltaView<'a> {
    /// Version the member diffed against.
    pub base_version: Version,
    /// New f-lane length of the member's object.
    pub f_len: usize,
    /// New i-lane length.
    pub i_len: usize,
    /// Chunk size in words.
    pub chunk_words: usize,
    /// Padded comparison length in words.
    pub total: usize,
    /// Changed chunks: `(chunk index, chunk words)`.
    pub chunks: Vec<(usize, &'a [i64])>,
}

/// Parse a [`FMT_XDELTA`] wire into an [`XDeltaView`] without copying the
/// chunk payloads.
pub fn xdelta_view(wire: &Blob) -> XDeltaView<'_> {
    assert_eq!(wire.i[0], FMT_XDELTA, "not an xor delta contribution");
    let base_version = wire.i[1];
    let f_len = wire.i[2] as usize;
    let i_len = wire.i[3] as usize;
    let cw = wire.i[4] as usize;
    let total = wire.i[5] as usize;
    let n_chunks = wire.i[6] as usize;
    let mut off = 7 + n_chunks;
    let mut chunks = Vec::with_capacity(n_chunks);
    for ci in 0..n_chunks {
        let c = wire.i[7 + ci] as usize;
        let lo = c * cw;
        let hi = total.min(lo + cw);
        chunks.push((c, &wire.i[off..off + (hi - lo)]));
        off += hi - lo;
    }
    XDeltaView { base_version, f_len, i_len, chunk_words: cw, total, chunks }
}

/// Apply a mirror delta to the receiver's materialized `base` copy.
/// Returns `(base_version the sender diffed against, materialized blob)`;
/// the caller must check the version against its own store.
pub fn apply_mirror_delta(base: &Blob, wire: &Blob) -> (Version, Blob) {
    assert_eq!(wire.i[0], FMT_MDELTA, "not a mirror delta payload");
    let base_version = wire.i[1];
    let f_len = wire.i[2] as usize;
    let i_len = wire.i[3] as usize;
    let cw = wire.i[4] as usize;
    let total = wire.i[5] as usize;
    let n_chunks = wire.i[6] as usize;
    let mut words = pack_words(base);
    words.resize(total, 0);
    let mut off = 7 + n_chunks;
    for ci in 0..n_chunks {
        let c = wire.i[7 + ci] as usize;
        let lo = c * cw;
        let hi = total.min(lo + cw);
        words[lo..hi].copy_from_slice(&wire.i[off..off + (hi - lo)]);
        off += hi - lo;
    }
    (base_version, unpack_words(&words, f_len, i_len))
}

/// Fold a full xor contribution into a stripe accumulator.  Returns the
/// member's `(f_len, i_len)`.
pub fn fold_xor_full(acc: &mut Vec<i64>, wire: &Blob) -> (usize, usize) {
    assert_eq!(wire.i[0], FMT_XFULL, "not a full xor contribution");
    let f_len = wire.i[1] as usize;
    let i_len = wire.i[2] as usize;
    xor_into(acc, &wire.i[3..]);
    (f_len, i_len)
}

/// Fold an xor delta contribution into a stripe accumulator.  Returns the
/// `(base version the member diffed against, new f_len, new i_len)`; the
/// caller must have seeded `acc` from its stripe at that base version.
pub fn fold_xor_delta(acc: &mut Vec<i64>, wire: &Blob) -> (Version, usize, usize) {
    assert_eq!(wire.i[0], FMT_XDELTA, "not an xor delta contribution");
    let base_version = wire.i[1];
    let f_len = wire.i[2] as usize;
    let i_len = wire.i[3] as usize;
    let cw = wire.i[4] as usize;
    let total = wire.i[5] as usize;
    let n_chunks = wire.i[6] as usize;
    if acc.len() < total {
        acc.resize(total, 0);
    }
    let mut off = 7 + n_chunks;
    for ci in 0..n_chunks {
        let c = wire.i[7 + ci] as usize;
        let lo = c * cw;
        let hi = total.min(lo + cw);
        for j in lo..hi {
            acc[j] ^= wire.i[off + (j - lo)];
        }
        off += hi - lo;
    }
    (base_version, f_len, i_len)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn blob(f: Vec<f64>, i: Vec<i64>) -> Blob {
        Blob::new(f, i)
    }

    #[test]
    fn pack_unpack_roundtrip_preserves_bits() {
        let b = blob(vec![1.5, -0.0, f64::NAN, 3.25e-300], vec![-7, 0, 42]);
        let w = pack_words(&b);
        let r = unpack_words(&w, 4, 3);
        assert_eq!(r.i, b.i);
        for (x, y) in r.f.iter().zip(&b.f) {
            assert_eq!(x.to_bits(), y.to_bits(), "bit-exact f64 roundtrip");
        }
    }

    #[test]
    fn mirror_delta_roundtrips_same_length() {
        let base = blob((0..100).map(|i| i as f64).collect(), vec![1, 2]);
        let mut new = base.clone();
        new.f[3] = -3.0;
        new.f[97] = 99.5;
        let wire = mirror_delta_wire(&base, &new, 7, 8);
        // Two changed chunks out of ~13: far fewer words than full.
        assert!(wire.i.len() < 100 / 2);
        let (bv, out) = apply_mirror_delta(&base, &wire);
        assert_eq!(bv, 7);
        assert_eq!(out.f, new.f);
        assert_eq!(out.i, new.i);
    }

    #[test]
    fn mirror_delta_handles_growth_and_shrink() {
        let base = blob((0..40).map(|i| i as f64).collect(), vec![2, 1]);
        // Growth: prefix intact, tail appended.
        let mut grown = base.clone();
        grown.f.extend((0..16).map(|i| -(i as f64)));
        grown.i = vec![3, 2].into();
        let wire = mirror_delta_wire(&base, &grown, 1, 8);
        let (_, out) = apply_mirror_delta(&base, &wire);
        assert_eq!(out.f, grown.f);
        assert_eq!(out.i, grown.i);
        // Shrink: result truncates.
        let mut small = base.clone();
        small.f.truncate(10);
        let wire = mirror_delta_wire(&base, &small, 1, 8);
        let (_, out) = apply_mirror_delta(&base, &wire);
        assert_eq!(out.f, small.f);
        assert_eq!(out.i, small.i);
    }

    #[test]
    fn unchanged_blob_ships_header_only() {
        let base = blob((0..512).map(|i| (i as f64).sin()).collect(), vec![9]);
        let wire = mirror_delta_wire(&base, &base, 3, 64);
        assert_eq!(wire.i[6], 0, "no changed chunks");
        assert_eq!(wire.i.len(), 7, "header only");
        let (_, out) = apply_mirror_delta(&base, &wire);
        assert_eq!(out.f, base.f);
    }

    #[test]
    fn xor_full_fold_reconstructs_missing_member() {
        // Three members; stripe = xor of all; losing m1 reconstructs from
        // stripe ^ m0 ^ m2.
        let m0 = blob(vec![1.0, 2.0, 3.0], vec![5]);
        let m1 = blob(vec![-4.0, 0.5], vec![7, 8]);
        let m2 = blob(vec![9.0; 5], vec![]);
        let mut stripe: Vec<i64> = Vec::new();
        let mut lens = Vec::new();
        for m in [&m0, &m1, &m2] {
            lens.push(fold_xor_full(&mut stripe, &xor_full_wire(m)));
        }
        assert_eq!(lens[1], (2, 2));
        let mut acc = stripe.clone();
        xor_into(&mut acc, &pack_words(&m0));
        xor_into(&mut acc, &pack_words(&m2));
        let rec = unpack_words(&acc, 2, 2);
        assert_eq!(rec.f, m1.f);
        assert_eq!(rec.i, m1.i);
    }

    #[test]
    fn xor_delta_updates_stripe_exactly() {
        // Stripe over two members; member 0 changes (and grows); the delta
        // contribution must leave the stripe equal to a fresh re-encode.
        let m0 = blob((0..64).map(|i| i as f64).collect(), vec![1]);
        let m1 = blob((0..50).map(|i| -(i as f64)).collect(), vec![2, 3]);
        let mut stripe: Vec<i64> = Vec::new();
        fold_xor_full(&mut stripe, &xor_full_wire(&m0));
        fold_xor_full(&mut stripe, &xor_full_wire(&m1));

        let mut m0b = m0.clone();
        m0b.f[10] = 1e9;
        m0b.f.extend([7.0, 8.0]);
        let wire = xor_delta_wire(&m0, &m0b, 4, 8);
        let (bv, f_len, i_len) = fold_xor_delta(&mut stripe, &wire);
        assert_eq!(bv, 4);
        assert_eq!((f_len, i_len), (66, 1));

        let mut fresh: Vec<i64> = Vec::new();
        fold_xor_full(&mut fresh, &xor_full_wire(&m0b));
        fold_xor_full(&mut fresh, &xor_full_wire(&m1));
        assert_eq!(stripe, fresh, "delta fold == fresh re-encode");
        // And the updated stripe reconstructs the changed member.
        let mut acc = stripe.clone();
        xor_into(&mut acc, &pack_words(&m1));
        let rec = unpack_words(&acc, f_len, i_len);
        assert_eq!(rec.f, m0b.f);
        assert_eq!(rec.i, m0b.i);
    }

    #[test]
    fn rle_roundtrips_and_bounds() {
        let cases: Vec<Vec<i64>> = vec![
            vec![],
            vec![0; 100],
            vec![42; 100],
            (0..100).collect(),
            vec![1, 0, 0, 0, 0, 2, 2, 2, 2, 2, 3, 0, 0, 7],
            vec![0, 0],          // short zero run stays literal
            vec![5, 5, 5],       // short repeat stays literal
        ];
        for words in cases {
            let toks = rle_compress(&words);
            assert!(toks.len() <= words.len() + 2, "bound violated for {words:?}");
            assert_eq!(rle_decompress(&toks), words, "roundtrip for {words:?}");
        }
        // Zero-heavy input compresses hard.
        let mut sparse = vec![0i64; 4096];
        sparse[100] = 9;
        sparse[3000] = -9;
        let toks = rle_compress(&sparse);
        assert!(toks.len() < 20, "sparse vector must collapse: {} tokens", toks.len());
    }

    #[test]
    fn compressed_wire_envelope_roundtrips_with_scaling() {
        let base = blob((0..300).map(|i| (i as f64).cos()).collect(), vec![4]);
        let mut new = base.clone();
        new.f[7] = 1.25;
        let wire = xor_delta_wire(&base, &new, 3, 64);
        let comp = compress_wire(&wire).scaled(36.0);
        // One changed word inside a 64-word chunk: 63 zeros elide.
        assert!(comp.bytes() < wire.clone().scaled(36.0).bytes());
        assert!((wire_factor(&comp) - 36.0).abs() < 1e-9);
        let inner = decompress_wire(&comp);
        assert_eq!(inner.i, wire.i);
    }

    #[test]
    fn compressed_blob_envelope_preserves_bits_and_factor() {
        let b = blob(vec![0.0, 1.5, f64::NAN, 0.0, 0.0, 0.0, 0.0, 0.0], vec![-3, 0, 0, 0])
            .scaled(2.0);
        let comp = compress_blob(&b);
        let out = decompress_blob(&comp);
        assert_eq!(out.i, b.i);
        for (x, y) in out.f.iter().zip(&b.f) {
            assert_eq!(x.to_bits(), y.to_bits());
        }
        assert_eq!(out.bytes(), b.bytes(), "charged size survives the roundtrip");
    }

    #[test]
    fn xdelta_view_matches_fold() {
        let base = blob((0..64).map(|i| i as f64).collect(), vec![1]);
        let mut new = base.clone();
        new.f[3] = -1.0;
        new.f[60] = 7.5;
        let wire = xor_delta_wire(&base, &new, 9, 16);
        let view = xdelta_view(&wire);
        assert_eq!(view.base_version, 9);
        assert_eq!((view.f_len, view.i_len), (64, 1));
        assert_eq!(view.chunk_words, 16);
        assert_eq!(view.chunks.len(), 2);
        // Reassembling the view's chunks reproduces fold_xor_delta exactly.
        let mut from_view = vec![0i64; view.total];
        for (c, words) in &view.chunks {
            let lo = c * view.chunk_words;
            from_view[lo..lo + words.len()].copy_from_slice(words);
        }
        let mut from_fold: Vec<i64> = Vec::new();
        fold_xor_delta(&mut from_fold, &wire);
        assert_eq!(from_view, from_fold);
    }

    #[test]
    fn arena_variants_match_allocating_paths() {
        let mut arena = WordArena::default();
        let base = blob((0..100).map(|i| i as f64).collect(), vec![1, 2]);
        let mut new = base.clone();
        new.f[3] = -3.0;
        new.f[97] = 99.5;
        assert_eq!(
            mirror_delta_wire_in(&mut arena, &base, &new, 7, 8).i,
            mirror_delta_wire(&base, &new, 7, 8).i
        );
        assert_eq!(
            xor_delta_wire_in(&mut arena, &base, &new, 7, 8).i,
            xor_delta_wire(&base, &new, 7, 8).i
        );
        let wire = xor_delta_wire(&base, &new, 7, 8);
        assert_eq!(compress_wire_in(&mut arena, &wire).i, compress_wire(&wire).i);
        let cb = compress_blob_in(&mut arena, &new);
        assert_eq!(cb.i, compress_blob(&new).i);
        assert_eq!(cb.bytes(), compress_blob(&new).bytes());
    }

    #[test]
    fn wire_factor_tracks_data_scale() {
        let b = blob(vec![0.0; 10], vec![]).scaled(36.0);
        assert!((wire_factor(&b) - 36.0).abs() < 1e-12);
        assert_eq!(wire_factor(&blob(vec![0.0; 4], vec![1])), 1.0);
        assert_eq!(wire_factor(&Blob::empty()), 1.0);
    }
}
