//! Application-driven in-memory buddy checkpointing (paper §III-IV).
//!
//! Each rank keeps its checkpointed objects in local memory and ships a
//! redundant copy to `k` buddy ranks (comm-rank successors on the ring) via
//! point-to-point messages — the paper's "checkpoints are stored in the
//! memory of neighboring nodes".  Static objects (matrix block, rhs) are
//! replicated once at startup and re-established after every recovery;
//! dynamic objects (solution vector, iteration scalars) are checkpointed at
//! user-defined intervals (after each inner solve).
//!
//! A checkpoint version is *committed* only after the fault-aware agreement
//! at the end of [`checkpoint`] succeeds, so recovery always restores a
//! globally consistent version: survivors agree on `min(committed)`.

use std::collections::{BTreeMap, HashMap};

use crate::metrics::Phase;
use crate::simmpi::{tags, Blob, Comm, Ctx, MpiResult, WorldRank};

pub type ObjId = u32;
pub type Version = i64;

/// Well-known object ids used by the FT-GMRES application.
pub mod obj {
    use super::ObjId;
    /// Dynamic: solution vector block.
    pub const X: ObjId = 1;
    /// Static: local matrix rows (ELL values + global columns).
    pub const MAT: ObjId = 2;
    /// Static: right-hand-side block.
    pub const RHS: ObjId = 3;
    /// Dynamic: iteration scalars + replicated least-squares state.
    pub const ITER: ObjId = 4;
    /// Dynamic: outer Krylov bases V and Z (live rows of the cycle).
    pub const BASIS: ObjId = 5;
}

/// How many predecessor/successor buddies hold a copy of each object.
pub const DEFAULT_BUDDIES: usize = 1;

/// In-memory checkpoint store of one rank.
#[derive(Debug, Default)]
pub struct CkptStore {
    /// Last version whose global commit succeeded.
    committed: Version,
    /// My own objects: obj -> version -> blob.
    local: HashMap<ObjId, BTreeMap<Version, Blob>>,
    /// Buddy copies held for other ranks: (owner world rank, obj) -> ...
    remote: HashMap<(WorldRank, ObjId), BTreeMap<Version, Blob>>,
}

impl CkptStore {
    pub fn new() -> Self {
        CkptStore::default()
    }

    pub fn committed(&self) -> Version {
        self.committed
    }

    pub fn put_local(&mut self, id: ObjId, version: Version, blob: Blob) {
        self.local.entry(id).or_default().insert(version, blob);
    }

    pub fn put_remote(&mut self, owner: WorldRank, id: ObjId, version: Version, blob: Blob) {
        self.remote.entry((owner, id)).or_default().insert(version, blob);
    }

    pub fn get_local(&self, id: ObjId, version: Version) -> Option<&Blob> {
        self.local.get(&id)?.get(&version)
    }

    /// Latest local version of `id` at or below `version`.
    pub fn get_local_at_most(&self, id: ObjId, version: Version) -> Option<(Version, &Blob)> {
        let (v, b) = self.local.get(&id)?.range(..=version).next_back()?;
        Some((*v, b))
    }

    pub fn get_remote(&self, owner: WorldRank, id: ObjId, version: Version) -> Option<&Blob> {
        self.remote.get(&(owner, id))?.get(&version)
    }

    pub fn get_remote_at_most(
        &self,
        owner: WorldRank,
        id: ObjId,
        version: Version,
    ) -> Option<(Version, &Blob)> {
        let (v, b) = self.remote.get(&(owner, id))?.range(..=version).next_back()?;
        Some((*v, b))
    }

    /// Drop remote copies held for `owner` (after its data was re-homed).
    pub fn drop_owner(&mut self, owner: WorldRank) {
        self.remote.retain(|(o, _), _| *o != owner);
    }

    /// Garbage-collect: keep only the newest `keep` versions of everything.
    pub fn gc(&mut self, keep: usize) {
        let trim = |m: &mut BTreeMap<Version, Blob>| {
            while m.len() > keep {
                let oldest = *m.keys().next().unwrap();
                m.remove(&oldest);
            }
        };
        self.local.values_mut().for_each(trim);
        self.remote.values_mut().for_each(trim);
    }

    fn commit(&mut self, version: Version) {
        self.committed = version;
    }

    /// Total resident bytes (local + buddy copies) — memory-overhead metric.
    pub fn resident_bytes(&self) -> usize {
        let l: usize = self.local.values().flat_map(|m| m.values()).map(Blob::bytes).sum();
        let r: usize = self.remote.values().flat_map(|m| m.values()).map(Blob::bytes).sum();
        l + r
    }
}

/// Buddy ring stride.  The paper's Figure 2 shows backups shifted by one
/// *rank* (A's copy lives on B): with ranks packed 24 to a node most buddy
/// pairs are intra-node and cheap, and the node-boundary pairs plus any
/// substituted spare (whose neighbors become inter-node) set the pace of
/// the coordinated checkpoint — the Figure 5 placement effect.  A stride of
/// `ranks_per_node` instead makes every pair cross nodes (tolerates whole-
/// node loss at higher cost); the ablation bench compares both.
pub fn buddy_stride(_ranks_per_node: usize, _n: usize) -> usize {
    1
}

/// Stride as configured: rank ring by default, node-crossing when
/// `NetParams::ckpt_node_stride` is set.
pub fn effective_stride(params: &crate::netsim::NetParams, n: usize) -> usize {
    if params.ckpt_node_stride {
        node_buddy_stride(params.ranks_per_node, n)
    } else {
        1
    }
}

/// Node-crossing stride variant (whole-node-loss tolerance; ablation).
pub fn node_buddy_stride(ranks_per_node: usize, n: usize) -> usize {
    let s = ranks_per_node % n;
    if s == 0 {
        1
    } else {
        s
    }
}

/// The `d`-th buddy of comm rank `r` in a communicator of `n` with the given
/// node stride.
pub fn buddy_of_stride(r: usize, d: usize, n: usize, stride: usize) -> usize {
    (r + d * stride) % n
}

/// The rank whose `d`-th buddy is `r` (its `d`-th predecessor).
pub fn ward_of_stride(r: usize, d: usize, n: usize, stride: usize) -> usize {
    (r + n - (d * stride) % n) % n
}

/// Coordinated checkpoint of `objs` at `version` with `k` buddies.
///
/// Called at a quiescent point by every member of `comm` (the paper
/// checkpoints after each completed inner solve, when no solver messages are
/// in flight).  Commits the version only after a fault-aware agreement, so a
/// failure mid-checkpoint leaves the previous committed version intact.
pub fn checkpoint(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &mut CkptStore,
    objs: &[(ObjId, Blob)],
    version: Version,
    k: usize,
) -> MpiResult<()> {
    // Post-recovery re-establishment is charged to Recovery (the paper
    // counts "updating all the in-memory checkpoints" as recovery cost);
    // steady-state checkpoints get their own bucket.
    let prev = if ctx.phase == Phase::Recovery {
        Phase::Recovery
    } else {
        ctx.set_phase(Phase::Checkpoint)
    };
    let result = checkpoint_inner(ctx, comm, store, objs, version, k);
    ctx.set_phase(prev);
    result
}

fn checkpoint_inner(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &mut CkptStore,
    objs: &[(ObjId, Blob)],
    version: Version,
    k: usize,
) -> MpiResult<()> {
    let n = comm.size();
    let me = comm.rank;
    let k = k.min(n.saturating_sub(1));
    let stride = effective_stride(&ctx.world.net.params, n);
    for (id, blob) in objs {
        store.put_local(*id, version, blob.clone());
    }
    // Ship to all buddies first (unbounded channels: no deadlock), then
    // receive the copies this rank holds for its wards.
    for d in 1..=k {
        let buddy = buddy_of_stride(me, d, n, stride);
        for (id, blob) in objs {
            comm.send(ctx, buddy, ckpt_tag(*id, d), blob.clone())?;
        }
    }
    for d in 1..=k {
        let ward = ward_of_stride(me, d, n, stride);
        let owner_wr = comm.world_of(ward);
        for (id, _) in objs {
            let blob = comm.recv(ctx, ward, ckpt_tag(*id, d))?;
            store.put_remote(owner_wr, *id, version, blob);
        }
    }
    // Global commit: everyone stored everything.
    comm.agree(ctx, u64::MAX)?;
    store.commit(version);
    store.gc(2);
    Ok(())
}

fn ckpt_tag(id: ObjId, d: usize) -> u32 {
    tags::CKPT_BASE + id * 16 + d as u32
}

/// Agree on the restore version: the newest version every survivor has
/// committed.  Called by all members of the (post-recovery) communicator.
pub fn agree_restore_version(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &CkptStore,
) -> MpiResult<Version> {
    let mut v = [store.committed()];
    comm.allreduce_min_i64(ctx, &mut v)?;
    Ok(v[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buddy_ring_roundtrip() {
        for n in [2usize, 3, 5, 8, 48] {
            for stride in [1usize, 3, 24] {
                let stride = if stride % n == 0 { 1 } else { stride % n };
                for r in 0..n {
                    for d in 1..n.min(3) {
                        assert_eq!(
                            ward_of_stride(buddy_of_stride(r, d, n, stride), d, n, stride),
                            r
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn buddy_strides() {
        // Default: rank ring (paper Fig. 2).
        assert_eq!(buddy_stride(24, 48), 1);
        // Node-crossing variant for the ablation.
        assert_eq!(node_buddy_stride(24, 48), 24);
        assert_eq!(buddy_of_stride(0, 1, 48, 24), 24);
        assert_eq!(node_buddy_stride(24, 8), 1);
        assert_eq!(node_buddy_stride(24, 24), 1);
    }

    #[test]
    fn store_versions_and_gc() {
        let mut s = CkptStore::new();
        for v in 0..5 {
            s.put_local(obj::X, v, Blob::scalar(v as f64));
        }
        s.gc(2);
        assert!(s.get_local(obj::X, 2).is_none());
        assert_eq!(s.get_local(obj::X, 4).unwrap().f, vec![4.0]);
        let (v, b) = s.get_local_at_most(obj::X, 100).unwrap();
        assert_eq!(v, 4);
        assert_eq!(b.f, vec![4.0]);
    }

    #[test]
    fn remote_ownership_and_drop() {
        let mut s = CkptStore::new();
        s.put_remote(7, obj::X, 1, Blob::scalar(7.0));
        s.put_remote(8, obj::X, 1, Blob::scalar(8.0));
        assert!(s.get_remote(7, obj::X, 1).is_some());
        s.drop_owner(7);
        assert!(s.get_remote(7, obj::X, 1).is_none());
        assert!(s.get_remote(8, obj::X, 1).is_some());
    }

    #[test]
    fn resident_bytes_counts_both_sides() {
        let mut s = CkptStore::new();
        s.put_local(obj::X, 1, Blob::from_f64s(vec![0.0; 10]));
        s.put_remote(3, obj::X, 1, Blob::from_f64s(vec![0.0; 5]));
        assert_eq!(s.resident_bytes(), 120);
    }
}
