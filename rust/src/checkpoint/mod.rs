//! Per-rank in-memory checkpoint **storage** (paper §III-IV).
//!
//! Each rank keeps its checkpointed objects in local memory plus whatever
//! redundancy the configured scheme assigns it: full buddy copies of its
//! wards' objects (`mirror:<k>`, the paper's "checkpoints are stored in the
//! memory of neighboring nodes") and/or parity stripes for the groups it
//! holds (`xor:<g>`; the rotating `P`/`Q` stripe pairs of `rs2:<g>`).  The
//! coordinated commit protocol, the encoding schemes, the delta codec and
//! the wire compression live in [`crate::ckptstore`]; this module owns the
//! versioned object store and the buddy-ring placement math.
//!
//! A checkpoint version is *committed* only after the fault-aware agreement
//! at the end of [`crate::ckptstore::commit`] succeeds, so recovery always
//! restores a globally consistent version: survivors agree on
//! `min(committed)`.

use std::collections::{BTreeMap, HashMap};

use crate::simmpi::{Blob, Comm, Ctx, MpiResult, WorldRank};

pub type ObjId = u32;
pub type Version = i64;

/// Well-known object ids used by the FT-GMRES application.
pub mod obj {
    use super::ObjId;
    /// Dynamic: solution vector block.
    pub const X: ObjId = 1;
    /// Static: local matrix rows (ELL values + global columns).
    pub const MAT: ObjId = 2;
    /// Static: right-hand-side block.
    pub const RHS: ObjId = 3;
    /// Dynamic: iteration scalars + replicated least-squares state.
    pub const ITER: ObjId = 4;
    /// Dynamic: outer Krylov bases V and Z (live rows of the cycle).
    pub const BASIS: ObjId = 5;
}

/// How many predecessor/successor buddies hold a copy of each object.
pub const DEFAULT_BUDDIES: usize = 1;

/// One parity stripe: the word-wise fold of every group member's packed
/// object (see [`crate::ckptstore::delta::pack_words`]), padded to the
/// longest member, plus the per-member metadata needed to carve a single
/// member back out of it.  Under `xor:<g>` this is the plain XOR of the
/// members; under `rs2:<g>` the same struct also carries the
/// GF(2^8)-weighted `Q` stripe on its own holder (which fold a given
/// holder stores is determined by the rotation schedule,
/// [`crate::ckptstore::scheme::rs2_holders`]).
#[derive(Debug, Clone)]
pub struct ParityStripe {
    /// World ranks of the group members, in comm-rank order at encode time.
    pub members: Vec<WorldRank>,
    /// Per-member f-lane lengths (same order as `members`).
    pub f_lens: Vec<usize>,
    /// Per-member i-lane lengths.
    pub i_lens: Vec<usize>,
    /// Per-member charged-wire scale factors (campaign `data_scale`).
    pub wire_factors: Vec<f64>,
    /// The stripe words.
    pub words: Vec<i64>,
}

impl ParityStripe {
    /// Resident bytes of the stripe payload, in the same *charged* units
    /// as [`Blob::bytes`]: physical words scaled by the campaign
    /// `data_scale` the members' objects were charged at (carried per
    /// member in `wire_factors`), so mirror copies and parity stripes are
    /// comparable in the memory-overhead metric.
    pub fn bytes(&self) -> usize {
        let factor = self.wire_factors.iter().copied().fold(1.0, f64::max);
        ((8 * self.words.len()) as f64 * factor) as usize
    }
}

/// In-memory checkpoint store of one rank.
#[derive(Debug, Default)]
pub struct CkptStore {
    /// Last version whose global commit succeeded.
    committed: Version,
    /// Version of the newest *fresh* (establishment) commit: every object,
    /// buddy copy and parity stripe of the current layout was re-written at
    /// this version, which makes it the purge watermark for entries from
    /// pre-recovery layouts (see [`CkptStore::gc_committed`]).
    last_fresh: Version,
    /// My own objects: obj -> version -> blob.
    local: HashMap<ObjId, BTreeMap<Version, Blob>>,
    /// Buddy copies held for other ranks: (owner world rank, obj) -> ...
    remote: HashMap<(WorldRank, ObjId), BTreeMap<Version, Blob>>,
    /// Parity stripes held for groups anchored at a world rank (the group's
    /// first member at encode time): (anchor, obj) -> version -> stripe.
    parity: HashMap<(WorldRank, ObjId), BTreeMap<Version, ParityStripe>>,
    /// Integrity digests of this rank's own committed objects, one per
    /// delta chunk ([`crate::ckptstore::chunk_sums`]); recorded at commit
    /// when the integrity layer (`ckpt_integrity`) is on and verified by
    /// the pre-commit scrubber (DESIGN.md §14).
    sums: HashMap<(ObjId, Version), Vec<u64>>,
    /// The published-but-unsealed async commit, if one is in flight
    /// (`--ckpt-async`, DESIGN.md §15).  At most one: the commit pipeline
    /// is one deep, and the next commit entry (or solve end) drains it.
    in_flight: Option<crate::ckptstore::InFlightCommit>,
}

impl CkptStore {
    pub fn new() -> Self {
        CkptStore::default()
    }

    pub fn committed(&self) -> Version {
        self.committed
    }

    pub fn put_local(&mut self, id: ObjId, version: Version, blob: Blob) {
        self.local.entry(id).or_default().insert(version, blob);
    }

    pub fn put_remote(&mut self, owner: WorldRank, id: ObjId, version: Version, blob: Blob) {
        self.remote.entry((owner, id)).or_default().insert(version, blob);
    }

    pub fn put_parity(
        &mut self,
        anchor: WorldRank,
        id: ObjId,
        version: Version,
        stripe: ParityStripe,
    ) {
        self.parity.entry((anchor, id)).or_default().insert(version, stripe);
    }

    pub fn get_local(&self, id: ObjId, version: Version) -> Option<&Blob> {
        self.local.get(&id)?.get(&version)
    }

    /// Latest local version of `id` at or below `version`.
    pub fn get_local_at_most(&self, id: ObjId, version: Version) -> Option<(Version, &Blob)> {
        let (v, b) = self.local.get(&id)?.range(..=version).next_back()?;
        Some((*v, b))
    }

    pub fn get_remote(&self, owner: WorldRank, id: ObjId, version: Version) -> Option<&Blob> {
        self.remote.get(&(owner, id))?.get(&version)
    }

    pub fn get_remote_at_most(
        &self,
        owner: WorldRank,
        id: ObjId,
        version: Version,
    ) -> Option<(Version, &Blob)> {
        let (v, b) = self.remote.get(&(owner, id))?.range(..=version).next_back()?;
        Some((*v, b))
    }

    pub fn get_parity_at_most(
        &self,
        anchor: WorldRank,
        id: ObjId,
        version: Version,
    ) -> Option<(Version, &ParityStripe)> {
        let (v, s) = self.parity.get(&(anchor, id))?.range(..=version).next_back()?;
        Some((*v, s))
    }

    /// Drop remote copies held for `owner` (after its data was re-homed).
    pub fn drop_owner(&mut self, owner: WorldRank) {
        self.remote.retain(|(o, _), _| *o != owner);
    }

    /// Record the per-chunk integrity digests of a local object committed
    /// at `version` (integrity layer, DESIGN.md §14).
    pub fn record_sums(&mut self, id: ObjId, version: Version, sums: Vec<u64>) {
        self.sums.insert((id, version), sums);
    }

    /// Recorded digests of `(id, version)`, if the integrity layer wrote
    /// them at that commit.
    pub fn sums_for(&self, id: ObjId, version: Version) -> Option<&[u64]> {
        self.sums.get(&(id, version)).map(Vec::as_slice)
    }

    /// Every object with a recorded digest, at its newest summed version,
    /// in ascending object order — the scrubber's deterministic verify
    /// schedule (identical on both engines).
    pub fn summed_objects(&self) -> Vec<(ObjId, Version)> {
        let mut newest: BTreeMap<ObjId, Version> = BTreeMap::new();
        for &(id, v) in self.sums.keys() {
            let e = newest.entry(id).or_insert(v);
            *e = (*e).max(v);
        }
        newest.into_iter().collect()
    }

    /// Injection seam: mutate a committed local blob in place (the
    /// `--inject-bitflip` fault and corruption tests go through this).
    #[doc(hidden)]
    pub fn local_mut(&mut self, id: ObjId, version: Version) -> Option<&mut Blob> {
        self.local.get_mut(&id)?.get_mut(&version)
    }

    /// Record that `version` was a *fresh* (establishment) commit: the
    /// whole current layout was re-encoded at it.  Called by the commit
    /// protocol after the fault-aware agreement succeeds.
    pub(crate) fn note_fresh(&mut self, version: Version) {
        self.last_fresh = self.last_fresh.max(version);
    }

    /// Garbage-collect versions below the globally committed floor.
    ///
    /// Commit skew between any two live ranks is at most one version (a
    /// torn agreement leaves some ranks one commit behind; the next
    /// successful recovery re-synchronizes everyone), so the restore
    /// version `min(committed)` can be at most `committed - 1` on this
    /// rank.  Per object, keep the newest version at or below that floor —
    /// the version any restore could still ask for — plus everything newer.
    /// Static objects written once at establishment keep exactly their
    /// single version; dynamic objects keep two.
    ///
    /// Additionally, once a commit *after* the newest establishment has
    /// succeeded, every participant of that later commit has provably
    /// committed at least the establishment version, so no future restore
    /// can agree on anything older: whole entries whose newest version
    /// predates the establishment — buddy copies and parity stripes keyed
    /// under pre-recovery layouts (stale owners, stale group anchors) —
    /// are dropped outright.  Purging is deliberately deferred by that one
    /// commit: right after the establishment itself, a torn agreement
    /// could still roll survivors back to the previous layout, whose
    /// redundancy must stay readable.
    pub fn gc_committed(&mut self) {
        let floor = self.committed - 1;
        fn trim<T>(m: &mut BTreeMap<Version, T>, floor: Version) {
            if let Some((&pin, _)) = m.range(..=floor).next_back() {
                // Everything strictly older than the pinned floor version
                // can never be restored again.
                let keep = m.split_off(&pin);
                *m = keep;
            }
        }
        self.local.values_mut().for_each(|m| trim(m, floor));
        self.remote.values_mut().for_each(|m| trim(m, floor));
        self.parity.values_mut().for_each(|m| trim(m, floor));
        if self.committed > self.last_fresh {
            let vf = self.last_fresh;
            let live = |newest: Option<Version>| newest.is_some_and(|v| v >= vf);
            self.local.retain(|_, m| live(m.keys().next_back().copied()));
            self.remote.retain(|_, m| live(m.keys().next_back().copied()));
            self.parity.retain(|_, m| live(m.keys().next_back().copied()));
        }
        // Digests follow their blobs: keep exactly the (obj, version)
        // pairs the local side still holds.
        let local = &self.local;
        self.sums.retain(|&(id, v), _| local.get(&id).is_some_and(|m| m.contains_key(&v)));
    }

    /// Forget everything (global restart from scratch: survivors rebuild
    /// state analytically and re-establish fresh checkpoints).
    pub fn clear_all(&mut self) {
        self.local.clear();
        self.remote.clear();
        self.parity.clear();
        self.sums.clear();
        self.in_flight = None;
    }

    /// Whether an async commit is published but not yet sealed (see
    /// [`crate::ckptstore::drain_in_flight`]).  Public so tests can pin the
    /// pipeline depth and the drain/cancel transitions.
    pub fn has_in_flight(&self) -> bool {
        self.in_flight.is_some()
    }

    pub(crate) fn set_in_flight(&mut self, fl: crate::ckptstore::InFlightCommit) {
        debug_assert!(
            self.in_flight.is_none(),
            "commit pipeline is one deep: drain before publishing the next version"
        );
        self.in_flight = Some(fl);
    }

    pub(crate) fn take_in_flight(&mut self) -> Option<crate::ckptstore::InFlightCommit> {
        self.in_flight.take()
    }

    pub(crate) fn commit(&mut self, version: Version) {
        self.committed = version;
    }

    /// Test seam: force the committed watermark without running the
    /// agreement (models a torn commit where only some ranks advanced).
    #[doc(hidden)]
    pub fn force_committed(&mut self, version: Version) {
        self.commit(version);
    }

    /// Total resident bytes (local + buddy copies + parity stripes) — the
    /// memory-overhead metric.
    pub fn resident_bytes(&self) -> usize {
        let l: usize = self.local.values().flat_map(|m| m.values()).map(Blob::bytes).sum();
        let r: usize = self.remote.values().flat_map(|m| m.values()).map(Blob::bytes).sum();
        let p: usize =
            self.parity.values().flat_map(|m| m.values()).map(ParityStripe::bytes).sum();
        l + r + p
    }
}

/// Buddy ring stride.  The paper's Figure 2 shows backups shifted by one
/// *rank* (A's copy lives on B): with ranks packed 24 to a node most buddy
/// pairs are intra-node and cheap, and the node-boundary pairs plus any
/// substituted spare (whose neighbors become inter-node) set the pace of
/// the coordinated checkpoint — the Figure 5 placement effect.  A stride of
/// `ranks_per_node` instead makes every pair cross nodes (tolerates whole-
/// node loss at higher cost); the ablation bench compares both.
pub fn buddy_stride(_ranks_per_node: usize, _n: usize) -> usize {
    1
}

/// Stride as configured: rank ring by default, node-crossing when
/// `NetParams::ckpt_node_stride` is set.
pub fn effective_stride(params: &crate::netsim::NetParams, n: usize) -> usize {
    if params.ckpt_node_stride {
        node_buddy_stride(params.ranks_per_node, n)
    } else {
        1
    }
}

/// Node-crossing stride variant (whole-node-loss tolerance; ablation).
pub fn node_buddy_stride(ranks_per_node: usize, n: usize) -> usize {
    let s = ranks_per_node % n;
    if s == 0 {
        1
    } else {
        s
    }
}

/// The `d`-th buddy of comm rank `r` in a communicator of `n` with the given
/// node stride.
pub fn buddy_of_stride(r: usize, d: usize, n: usize, stride: usize) -> usize {
    (r + d * stride) % n
}

/// The rank whose `d`-th buddy is `r` (its `d`-th predecessor).
pub fn ward_of_stride(r: usize, d: usize, n: usize, stride: usize) -> usize {
    (r + n - (d * stride) % n) % n
}

/// Coordinated full-copy checkpoint of `objs` at `version` with `k`
/// buddies: the paper's original protocol, kept as a thin wrapper over
/// [`crate::ckptstore::commit`] with a `mirror:<k>` scheme and the delta
/// layer off.
pub async fn checkpoint(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &mut CkptStore,
    objs: &[(ObjId, Blob)],
    version: Version,
    k: usize,
) -> MpiResult<()> {
    let cfg = crate::ckptstore::CkptCfg::mirror(k);
    crate::ckptstore::commit(ctx, comm, store, objs, version, &cfg, false).await
}

/// Agree on the restore version: the newest version every survivor has
/// committed.  Called by all members of the (post-recovery) communicator.
pub async fn agree_restore_version(
    ctx: &mut Ctx,
    comm: &mut Comm,
    store: &CkptStore,
) -> MpiResult<Version> {
    let mut v = [store.committed()];
    comm.allreduce_min_i64(ctx, &mut v).await?;
    Ok(v[0])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buddy_ring_roundtrip() {
        for n in [2usize, 3, 5, 8, 48] {
            for stride in [1usize, 3, 24] {
                let stride = if stride % n == 0 { 1 } else { stride % n };
                for r in 0..n {
                    for d in 1..n.min(3) {
                        assert_eq!(
                            ward_of_stride(buddy_of_stride(r, d, n, stride), d, n, stride),
                            r
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn buddy_strides() {
        // Default: rank ring (paper Fig. 2).
        assert_eq!(buddy_stride(24, 48), 1);
        // Node-crossing variant for the ablation.
        assert_eq!(node_buddy_stride(24, 48), 24);
        assert_eq!(buddy_of_stride(0, 1, 48, 24), 24);
        assert_eq!(node_buddy_stride(24, 8), 1);
        assert_eq!(node_buddy_stride(24, 24), 1);
    }

    #[test]
    fn store_versions_and_gc() {
        let mut s = CkptStore::new();
        for v in 0..5 {
            s.put_local(obj::X, v, Blob::scalar(v as f64));
        }
        s.force_committed(4);
        s.gc_committed();
        assert!(s.get_local(obj::X, 2).is_none());
        assert_eq!(s.get_local(obj::X, 3).unwrap().f, vec![3.0]);
        assert_eq!(s.get_local(obj::X, 4).unwrap().f, vec![4.0]);
        let (v, b) = s.get_local_at_most(obj::X, 100).unwrap();
        assert_eq!(v, 4);
        assert_eq!(b.f, vec![4.0]);
    }

    #[test]
    fn gc_committed_keeps_restore_floor_and_statics() {
        let mut s = CkptStore::new();
        // Static object written once at establishment (version 0).
        s.put_local(obj::MAT, 0, Blob::scalar(10.0));
        s.put_remote(3, obj::MAT, 0, Blob::scalar(30.0));
        // Dynamic object at every commit.
        for v in 0..=4 {
            s.put_local(obj::X, v, Blob::scalar(v as f64));
            s.put_remote(3, obj::X, v, Blob::scalar(10.0 + v as f64));
        }
        s.force_committed(4);
        s.gc_committed();
        // Floor = 3: versions 3 and 4 survive (a peer may only have
        // committed 3), older dynamic versions are gone.
        assert!(s.get_local(obj::X, 2).is_none());
        assert!(s.get_local(obj::X, 3).is_some());
        assert!(s.get_local(obj::X, 4).is_some());
        assert!(s.get_remote(3, obj::X, 2).is_none());
        assert!(s.get_remote(3, obj::X, 3).is_some());
        // The static object's single version is pinned, not collected.
        assert!(s.get_local(obj::MAT, 0).is_some());
        assert!(s.get_remote(3, obj::MAT, 0).is_some());
    }

    #[test]
    fn sums_follow_their_blobs_through_gc_and_clear() {
        let mut s = CkptStore::new();
        for v in 0..5 {
            s.put_local(obj::X, v, Blob::scalar(v as f64));
            s.record_sums(obj::X, v, vec![v as u64]);
        }
        s.put_local(obj::MAT, 0, Blob::scalar(9.0));
        s.record_sums(obj::MAT, 0, vec![99]);
        assert_eq!(s.sums_for(obj::X, 2), Some(&[2u64][..]));
        s.force_committed(4);
        s.gc_committed();
        // Digests of collected versions are gone; survivors keep theirs,
        // and summed_objects reports each object's newest summed version.
        assert!(s.sums_for(obj::X, 2).is_none());
        assert_eq!(s.sums_for(obj::X, 3), Some(&[3u64][..]));
        assert_eq!(s.summed_objects(), vec![(obj::X, 4), (obj::MAT, 0)]);
        // The injection seam reaches the committed blob.
        assert!(s.local_mut(obj::X, 4).is_some());
        assert!(s.local_mut(obj::X, 2).is_none());
        s.clear_all();
        assert!(s.summed_objects().is_empty());
    }

    #[test]
    fn remote_ownership_and_drop() {
        let mut s = CkptStore::new();
        s.put_remote(7, obj::X, 1, Blob::scalar(7.0));
        s.put_remote(8, obj::X, 1, Blob::scalar(8.0));
        assert!(s.get_remote(7, obj::X, 1).is_some());
        s.drop_owner(7);
        assert!(s.get_remote(7, obj::X, 1).is_none());
        assert!(s.get_remote(8, obj::X, 1).is_some());
    }

    #[test]
    fn parity_versioning_and_clear() {
        let mut s = CkptStore::new();
        let stripe = |w: i64| ParityStripe {
            members: vec![0, 1, 2, 3],
            f_lens: vec![1; 4],
            i_lens: vec![0; 4],
            wire_factors: vec![1.0; 4],
            words: vec![w, w],
        };
        s.put_parity(0, obj::X, 1, stripe(1));
        s.put_parity(0, obj::X, 2, stripe(2));
        let (v, got) = s.get_parity_at_most(0, obj::X, 5).unwrap();
        assert_eq!(v, 2);
        assert_eq!(got.words, vec![2, 2]);
        assert_eq!(s.resident_bytes(), 32);
        s.clear_all();
        assert!(s.get_parity_at_most(0, obj::X, 5).is_none());
        assert_eq!(s.resident_bytes(), 0);
    }

    #[test]
    fn resident_bytes_counts_both_sides() {
        let mut s = CkptStore::new();
        s.put_local(obj::X, 1, Blob::from_f64s(vec![0.0; 10]));
        s.put_remote(3, obj::X, 1, Blob::from_f64s(vec![0.0; 5]));
        assert_eq!(s.resident_bytes(), 120);
    }
}
